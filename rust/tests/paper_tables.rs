//! Acceptance tests over the paper-table regenerators: every table and
//! figure harness must run and expose the qualitative result the paper
//! reports (DESIGN.md §5's acceptance column).

use stp::bench;

#[test]
fn fig1_comm_share_grows_with_tp() {
    let out = bench::fig1();
    // Parse the "comm share" column for tp = 2, 4, 8.
    let shares: Vec<f64> = out
        .lines()
        .skip(3)
        .filter_map(|l| l.split_whitespace().nth(1)?.parse().ok())
        .collect();
    assert_eq!(shares.len(), 3, "{out}");
    assert!(shares[0] < shares[1] && shares[1] < shares[2], "{shares:?}");
    // Paper Fig. 1: substantial share at TP=8 (tens of percent).
    assert!(shares[2] > 10.0, "TP=8 share {:.1}% too small", shares[2]);
}

#[test]
fn fig1_braiding_speeds_up_every_tp() {
    let out = bench::fig1();
    let speedups: Vec<f64> = out
        .lines()
        .skip(3)
        .filter_map(|l| l.split_whitespace().last()?.trim_end_matches('x').parse().ok())
        .collect();
    assert_eq!(speedups.len(), 3);
    assert!(speedups.iter().all(|&s| s > 1.0), "{speedups:?}");
    // And the benefit grows with TP size.
    assert!(speedups[2] > speedups[0]);
}

#[test]
fn table1_renders_theory_and_sim() {
    let out = bench::table1();
    assert!(out.contains("1f1b-i") && out.contains("zb-v") && out.contains("stp"));
    assert!(out.contains("T_F="));
}

#[test]
fn fig7_ours_wins_every_row() {
    // STP strictly wins every TP=8 row (the paper's headline); TP=4 rows
    // must be at worst a sub-percent tie (the greedy constructor leaves a
    // little of the paper's handcrafted tp4 margin on the table — see
    // EXPERIMENTS.md "deviations").
    let out = bench::fig7();
    for line in out.lines().skip(3) {
        let cols: Vec<&str> = line.split_whitespace().collect();
        if cols.len() < 8 {
            continue;
        }
        let tp: usize = cols[1].parse().unwrap();
        let gain: f64 = cols[7].trim_end_matches('%').parse().unwrap();
        if tp >= 8 {
            assert!(gain > 0.0, "negative TP=8 gain row: {line}");
        } else {
            assert!(gain > -1.5, "large negative TP=4 gain row: {line}");
        }
    }
}

#[test]
fn table4_has_both_oom_and_ok_rows() {
    let out = bench::table4();
    assert!(out.contains("OOM"), "expected OOM rows:\n{out}");
    assert!(out.contains("ok"), "expected feasible rows:\n{out}");
}

#[test]
fn fig10_offload_balances_stages() {
    let out = bench::fig10();
    assert!(out.contains("stp-offload"));
    // The offload row's peak must be below the plain STP row's.
    let peaks: Vec<f64> = out
        .lines()
        .filter(|l| l.contains("stp"))
        .filter_map(|l| l.split_whitespace().last()?.parse().ok())
        .collect();
    assert!(peaks.len() >= 2);
    let plain = peaks[0];
    let off = *peaks.last().unwrap();
    assert!(off < plain, "offload {off} !< plain {plain}");
}

#[test]
fn fig13_h20_has_lower_comm_share() {
    let out = bench::fig13();
    let shares: Vec<f64> = out
        .lines()
        .skip(3)
        .filter_map(|l| l.split_whitespace().last()?.parse().ok())
        .collect();
    assert_eq!(shares.len(), 4, "{out}"); // a800 x {4,8}, h20 x {4,8}
    assert!(shares[2] < shares[0], "h20 tp4 !< a800 tp4: {shares:?}");
    assert!(shares[3] < shares[1], "h20 tp8 !< a800 tp8: {shares:?}");
}

#[test]
fn table10_all_modes_positive() {
    let out = bench::table10();
    let thrs: Vec<f64> = out
        .lines()
        .skip(3)
        .filter_map(|l| l.split_whitespace().last()?.parse().ok())
        .collect();
    assert_eq!(thrs.len(), 6);
    assert!(thrs.iter().all(|&t| t > 0.0));
}

#[test]
fn table11_overlap_beats_sequential() {
    let out = bench::table11_sim();
    for line in out.lines().skip(3) {
        let cols: Vec<&str> = line.split_whitespace().collect();
        if cols.len() < 6 {
            continue;
        }
        let saving: f64 = cols[cols.len() - 1].parse().unwrap();
        assert!(saving > 5.0, "overlap saves too little: {line}");
    }
}

#[test]
fn dispatch_covers_every_experiment_id() {
    for id in [
        "fig1", "table1", "fig7", "fig8", "fig9", "table3", "fig10", "table4", "table567",
        "table8", "fig13", "table9", "table10", "table11",
    ] {
        assert!(bench::by_name(id).is_some(), "missing regenerator {id}");
    }
    assert!(bench::by_name("nope").is_none());
}
