//! Integration over the backend-abstract executor (no `pjrt` feature
//! needed): the planner → executor handoff end-to-end.
//!
//! `stp plan --emit-plan` → `stp train --plan --backend virtual` must
//! (a) complete a multi-stage braided run whose per-device op sequence
//! equals the simulator's [`CompiledSchedule`] order for the same
//! candidate, and (b) be bit-deterministic across runs with the same
//! seed — the acceptance criteria of the executor refactor
//! (DESIGN.md §10).

use stp::cluster::{ClusterSpec, GroupOrder, HardwareProfile};
use stp::exec::{train, virtual_dims, BackendKind, KernelPath, TrainConfig};
use stp::model::ModelConfig;
use stp::plan::{plan, PlanArtifact, PlanModel, PlanQuery};
use stp::schedule::{OffloadParams, ScheduleKind};

/// A tiny-model plan query small enough to search and execute in-test.
fn tiny_query() -> PlanQuery {
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::tiny_100m()),
        ClusterSpec::uniform(HardwareProfile::a800()),
        4,
    );
    q.seq = 1024;
    q.n_mb_options = vec![4];
    q.threads = 2;
    q
}

/// The paper's braided candidate at tp2-pp2 on the tiny model — a
/// guaranteed multi-stage STP shape, independent of what the search
/// happens to rank first.
fn braided_artifact() -> PlanArtifact {
    let q = tiny_query();
    let ctx = q.eval_context();
    let candidate = stp::plan::Candidate {
        id: 0,
        tp: 2,
        pp: 2,
        dp: 1,
        kind: ScheduleKind::Stp,
        n_mb: 4,
        order: GroupOrder::Declared,
        offload: OffloadParams::default(),
        offload_variant: 0,
        ac: stp::sim::AcMode::None,
        map: None,
        vpp_gene: 0,
    };
    let e = stp::plan::evaluate(&ctx, &candidate);
    assert!(e.feasible, "tiny model at tp2-pp2 must fit");
    PlanArtifact::for_evaluation(&ctx, &e)
}

fn train_cfg(a: &PlanArtifact, steps: usize, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::virtual_default();
    cfg.steps = steps;
    cfg.seed = seed;
    cfg.plan = Some(a.clone());
    cfg
}

#[test]
fn braided_plan_executes_and_matches_the_compiled_order() {
    let a = braided_artifact();
    assert_eq!((a.tp, a.pp, a.vpp), (2, 2, 2));
    let report = train(&train_cfg(&a, 2, 42)).unwrap();

    // (a) the executor walked exactly the simulator's compiled op order.
    let compiled = a.build_schedule().compile();
    assert_eq!(report.device_ops.len(), a.pp);
    for d in 0..a.pp {
        let (lo, hi) = (compiled.dev_start[d] as usize, compiled.dev_start[d + 1] as usize);
        assert_eq!(
            report.device_ops[d].as_slice(),
            &compiled.ops[lo..hi],
            "stage {d} op sequence diverged from the compiled schedule"
        );
    }
    // A braided run: the executed program actually contains braids.
    assert!(
        report.device_ops.iter().flatten().any(|op| op.fwd_ar_overlapped()),
        "no braided blocks executed"
    );

    // The run trained: finite, plausible losses from ln(V).
    let v = virtual_dims(2, 2, 2, a.total_layers()).vocab as f32;
    assert!((report.first_loss() - v.ln()).abs() < 0.2, "first loss {}", report.first_loss());
    assert!(report.last_loss().is_finite());
    assert!(report.allreduce_bytes > 0, "TP all-reduce must actually run");
    assert_eq!(report.backend, BackendKind::Virtual);
}

#[test]
fn steady_state_workspace_allocations_are_zero_under_a_plan() {
    // The arena contract (DESIGN.md §11) on the braided multi-stage
    // path: step 0 populates every device thread's workspace pools, and
    // no thread heap-allocates kernel scratch again for the rest of the
    // run.
    let a = braided_artifact();
    let r = train(&train_cfg(&a, 3, 11)).unwrap();
    assert_eq!(r.workspace_steady_allocs, 0, "steady-state steps must not allocate scratch");
    assert!(
        r.workspace_peak_bytes.iter().all(|&b| b > 0),
        "every stage must report arena usage: {:?}",
        r.workspace_peak_bytes
    );
}

#[test]
fn virtual_training_is_bit_deterministic_across_runs() {
    let a = braided_artifact();
    let r1 = train(&train_cfg(&a, 2, 7)).unwrap();
    let r2 = train(&train_cfg(&a, 2, 7)).unwrap();
    assert_eq!(r1.steps.len(), r2.steps.len());
    for (x, y) in r1.steps.iter().zip(&r2.steps) {
        assert_eq!(
            x.mean_loss.to_bits(),
            y.mean_loss.to_bits(),
            "step {}: {} != {}",
            x.step,
            x.mean_loss,
            y.mean_loss
        );
    }
    // A different seed trains a different model.
    let r3 = train(&train_cfg(&a, 2, 8)).unwrap();
    assert_ne!(r1.steps[0].mean_loss.to_bits(), r3.steps[0].mean_loss.to_bits());
}

#[test]
fn plan_emit_train_roundtrip_through_the_cli() {
    // The full user journey: `stp plan --emit-plan` on the tiny model,
    // then `stp train --plan --backend virtual` on the written artifact.
    let dir = std::env::temp_dir().join(format!("stp-plan-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    let path_s = path.to_str().unwrap().to_string();

    let code = stp::coordinator::run_cli(vec![
        "plan".into(),
        "--gpus".into(),
        "4".into(),
        "--model".into(),
        "tiny".into(),
        "--seq".into(),
        "1024".into(),
        "--emit-plan".into(),
        path_s.clone(),
    ])
    .unwrap();
    assert_eq!(code, 0, "stp plan failed");

    // The emitted artifact strictly validates and covers the model.
    let a = PlanArtifact::load(&path_s).unwrap();
    assert_eq!(a.total_layers(), ModelConfig::tiny_100m().layers);

    let code = stp::coordinator::run_cli(vec![
        "train".into(),
        "--plan".into(),
        path_s,
        "--backend".into(),
        "virtual".into(),
        "--steps".into(),
        "1".into(),
        "--quiet".into(),
    ])
    .unwrap();
    assert_eq!(code, 0, "stp train --plan failed");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn search_winner_executes_via_the_handoff() {
    // Whatever candidate the search ranks first must lower and run.
    let r = plan(&tiny_query());
    let a = r.best_artifact.expect("tiny model on 4 GPUs must produce a plan");
    let report = train(&train_cfg(&a, 1, 3)).unwrap();
    assert!(report.last_loss().is_finite());
    let compiled = a.build_schedule().compile();
    for d in 0..a.pp {
        let (lo, hi) = (compiled.dev_start[d] as usize, compiled.dev_start[d + 1] as usize);
        assert_eq!(report.device_ops[d].as_slice(), &compiled.ops[lo..hi]);
    }
}

#[test]
fn pjrt_backend_without_feature_is_a_clear_error() {
    // The seam still names the missing capability instead of panicking.
    if cfg!(feature = "pjrt") {
        return; // with real bindings this path is exercised elsewhere
    }
    let mut cfg = TrainConfig::virtual_default();
    cfg.backend = BackendKind::Pjrt;
    cfg.artifacts_dir = std::path::PathBuf::from("/nonexistent");
    let err = train(&cfg).unwrap_err().to_string();
    assert!(
        err.contains("manifest") || err.contains("pjrt") || err.contains("reading"),
        "unhelpful error: {err}"
    );
}

#[test]
fn dp_replicas_are_bit_deterministic_at_any_worker_interleaving() {
    // dp=2 doubles the thread grid; the fixed replica-index reduction
    // order (DESIGN.md §14) must keep the run bit-reproducible no matter
    // how the OS interleaves the extra threads.
    let a = braided_artifact();
    let mut cfg = train_cfg(&a, 2, 19);
    cfg.dp = Some(2);
    let r1 = train(&cfg).unwrap();
    let r2 = train(&cfg).unwrap();
    assert_eq!(r1.steps.len(), r2.steps.len());
    for (x, y) in r1.steps.iter().zip(&r2.steps) {
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "step {}", x.step);
    }

    // The replicas really reduced: DP gradient traffic rides on top of
    // the TP traffic a dp=1 run reports.
    let solo = train(&train_cfg(&a, 2, 19)).unwrap();
    assert!(
        r1.allreduce_bytes > solo.allreduce_bytes,
        "dp=2 must add gradient all-reduce bytes: {} !> {}",
        r1.allreduce_bytes,
        solo.allreduce_bytes
    );

    // SIMD worker pools of different widths must agree bit-for-bit too.
    let mut narrow = cfg.clone();
    narrow.kernels = KernelPath::Simd;
    narrow.workers = 1;
    let mut wide = narrow.clone();
    wide.workers = 3;
    let rn = train(&narrow).unwrap();
    let rw = train(&wide).unwrap();
    for (x, y) in rn.steps.iter().zip(&rw.steps) {
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "step {} (simd)", x.step);
    }
}
