//! Integration over the PJRT runtime: load the AOT-lowered HLO artifacts
//! (`make artifacts` must have produced `artifacts/test/`) and verify the
//! numerics against the python-side golden vectors — the rust half of the
//! L1/L2 correctness contract.
//!
//! Needs the `pjrt` feature (and real xla bindings + artifacts).
#![cfg(feature = "pjrt")]

use std::path::Path;

use stp::config::{Json, Manifest};
use stp::runtime::{Runtime, Tensor};

fn test_dir() -> &'static Path {
    Path::new("artifacts/test")
}

fn have_artifacts() -> bool {
    test_dir().join("manifest.json").exists()
}

#[test]
fn manifest_loads_and_describes_units() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let m = Manifest::load(test_dir()).unwrap();
    assert_eq!(m.preset, "test");
    for name in [
        "attn_fwd",
        "attn_bwd_x",
        "attn_bwd_w",
        "mlp_fwd",
        "mlp_bwd_x",
        "mlp_bwd_w",
        "embed_fwd",
        "embed_bwd",
        "head_loss_grad",
        "smoke",
    ] {
        assert!(m.artifacts.contains_key(name), "missing {name}");
    }
    // Forward partials must be marked for All-Reduce; endpoints must not.
    assert_eq!(m.artifact("attn_fwd").unwrap().ar_outputs, vec![0]);
    assert_eq!(m.artifact("mlp_bwd_x").unwrap().ar_outputs, vec![0]);
    assert!(m.artifact("embed_fwd").unwrap().ar_outputs.is_empty());
}

#[test]
fn smoke_artifact_known_answer() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load(test_dir()).unwrap();
    let mut rt = Runtime::load(&m, &["smoke"]).unwrap();
    let x = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
    let y = Tensor::f32(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
    let out = rt.run("smoke", &[&x, &y]).unwrap();
    assert_eq!(out[0].as_f32().unwrap(), &[5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn pallas_units_match_python_golden() {
    if !have_artifacts() {
        return;
    }
    let golden_path = test_dir().join("golden.json");
    if !golden_path.exists() {
        eprintln!("skipping: golden.json not generated");
        return;
    }
    let g = Json::parse(&std::fs::read_to_string(golden_path).unwrap()).unwrap();
    let m = Manifest::load(test_dir()).unwrap();
    let d = &m.dims;
    let mut rt = Runtime::load(&m, &["attn_fwd", "mlp_fwd"]).unwrap();

    let vec_of = |k: &str| g.get(k).unwrap().as_f32_vec().unwrap();
    let x = Tensor::f32(vec_of("x"), &[d.mb, d.seq, d.d]);
    let dh = d.head_dim();
    let qr = d.q_heads_per_rank() * dh;
    let kr = d.kv_heads_per_rank() * dh;

    // Attn unit: rust-executed HLO vs python-executed pallas kernel.
    let g1 = Tensor::f32(vec_of("gamma1"), &[d.d]);
    let wq = Tensor::f32(vec_of("wq"), &[d.d, qr]);
    let wk = Tensor::f32(vec_of("wk"), &[d.d, kr]);
    let wv = Tensor::f32(vec_of("wv"), &[d.d, kr]);
    let wo = Tensor::f32(vec_of("wo"), &[qr, d.d]);
    let out = rt.run("attn_fwd", &[&x, &g1, &wq, &wk, &wv, &wo]).unwrap();
    let want = vec_of("attn_fwd_out");
    let got = out[0].as_f32().unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4 + 1e-4 * b.abs(), "attn_fwd[{i}]: {a} vs {b}");
    }

    // MLP unit.
    let g2 = Tensor::f32(vec_of("gamma2"), &[d.d]);
    let wg = Tensor::f32(vec_of("wg"), &[d.d, d.ffn_per_rank()]);
    let wu = Tensor::f32(vec_of("wu"), &[d.d, d.ffn_per_rank()]);
    let wd = Tensor::f32(vec_of("wd"), &[d.ffn_per_rank(), d.d]);
    let out = rt.run("mlp_fwd", &[&x, &g2, &wg, &wu, &wd]).unwrap();
    let want = vec_of("mlp_fwd_out");
    let got = out[0].as_f32().unwrap();
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4 + 1e-4 * b.abs(), "mlp_fwd[{i}]: {a} vs {b}");
    }
}

#[test]
fn runtime_rejects_shape_mismatch() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load(test_dir()).unwrap();
    let mut rt = Runtime::load(&m, &["smoke"]).unwrap();
    let bad = Tensor::f32(vec![0.0; 9], &[3, 3]);
    let ok = Tensor::f32(vec![0.0; 4], &[2, 2]);
    assert!(rt.run("smoke", &[&bad, &ok]).is_err());
    assert!(rt.run("smoke", &[&ok]).is_err());
    assert!(rt.run("nonexistent", &[&ok]).is_err());
}

#[test]
fn head_loss_of_uniform_logits_is_ln_vocab() {
    if !have_artifacts() {
        return;
    }
    let m = Manifest::load(test_dir()).unwrap();
    let d = &m.dims;
    let mut rt = Runtime::load(&m, &["head_loss_grad"]).unwrap();
    let x = Tensor::zeros(&[d.mb, d.seq, d.d]);
    let wh = Tensor::zeros(&[d.d, d.vocab]);
    let targets = Tensor::i32(vec![0; d.mb * d.seq], &[d.mb, d.seq]);
    let out = rt.run("head_loss_grad", &[&x, &wh, &targets]).unwrap();
    let loss = out[0].scalar_f32().unwrap();
    let want = (d.vocab as f32).ln();
    assert!((loss - want).abs() < 1e-3, "loss {loss} != ln V {want}");
}
