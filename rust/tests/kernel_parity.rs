//! Golden parity suite: the fast, workspace-backed kernel paths must
//! match the preserved naive oracle (`kernels::reference`) on every AOT
//! unit — the executor-side analogue of the `sim::reference`
//! bit-equivalence suite (DESIGN.md §11, §13).
//!
//! Oracle policy (DESIGN.md §13): wherever the per-element accumulation
//! order is preserved the fast path must be **bit-equal** — that covers
//! every blocked-path unit and every simd-path unit except attention,
//! whose flash (online-softmax) tiling legitimately reassociates and is
//! held to a documented ≤ 1e-5 tolerance instead. Both properties are
//! pinned here so a future reassociating kernel relaxes the bit test
//! deliberately, not by accident. The simd path must also be
//! **deterministic in the worker count**: fixed band→worker assignment
//! means 1, 2 and 8 workers produce identical losses, asserted below.

use stp::config::ManifestDims;
use stp::exec::{train, Backend, KernelPath, Rng, TrainConfig, VirtualBackend};
use stp::runtime::Tensor;

fn randn(seed: u64, n: usize) -> Vec<f32> {
    Rng::for_purpose(42, seed, 5, 0).normal_vec(n, 0.5)
}

/// Ragged dims: rows (= mb·seq = 66) not a multiple of the register
/// tile, d = 72 and vocab = 130 not multiples of the column tile, and
/// the head GEMM large enough to leave the small-product fallback — so
/// the blocked core's edge tiles are exercised, not just the naive
/// fallback. tp = 2 exercises the `/t` residual terms.
fn ragged_dims() -> ManifestDims {
    ManifestDims {
        vocab: 130,
        d: 72,
        q_heads: 4,
        kv_heads: 2,
        ffn: 100,
        layers: 2,
        seq: 22,
        mb: 3,
        tp: 2,
        pp: 1,
        vpp: 1,
    }
}

/// Tiny dims that stay entirely on the small-product fallback.
fn tiny_dims() -> ManifestDims {
    ManifestDims {
        vocab: 11,
        d: 8,
        q_heads: 2,
        kv_heads: 1,
        ffn: 6,
        layers: 1,
        seq: 3,
        mb: 2,
        tp: 1,
        pp: 1,
        vpp: 1,
    }
}

/// The python `test` preset's dims (`python/compile/config.py::TEST`) —
/// what `stp bench train` runs.
fn test_preset_dims() -> ManifestDims {
    ManifestDims::test_preset()
}

/// Run all nine units on `path` and the reference oracle and compare
/// outputs with `check` (called per (unit, output index, want, got)).
fn compare_paths(
    dims: &ManifestDims,
    path: KernelPath,
    mut check: impl FnMut(&str, usize, &Tensor, &Tensor),
) {
    let mut fast = VirtualBackend::with_path(dims.clone(), path);
    let mut reference = VirtualBackend::with_path(dims.clone(), KernelPath::Reference);

    let d = dims.d;
    let (mb, s) = (dims.mb, dims.seq);
    let qr = dims.q_heads_per_rank() * dims.head_dim();
    let kr = dims.kv_heads_per_rank() * dims.head_dim();
    let fr = dims.ffn_per_rank();
    let x = Tensor::f32(randn(1, mb * s * d), &[mb, s, d]);
    let dy = Tensor::f32(randn(2, mb * s * d), &[mb, s, d]);
    let g1 = Tensor::f32(randn(3, d).iter().map(|v| 1.0 + v).collect(), &[d]);
    let g2 = Tensor::f32(randn(4, d).iter().map(|v| 1.0 + v).collect(), &[d]);
    let wq = Tensor::f32(randn(5, d * qr), &[d, qr]);
    let wk = Tensor::f32(randn(6, d * kr), &[d, kr]);
    let wv = Tensor::f32(randn(7, d * kr), &[d, kr]);
    let wo = Tensor::f32(randn(8, qr * d), &[qr, d]);
    let wg = Tensor::f32(randn(9, d * fr), &[d, fr]);
    let wu = Tensor::f32(randn(10, d * fr), &[d, fr]);
    let wd = Tensor::f32(randn(11, fr * d), &[fr, d]);
    let wh = Tensor::f32(randn(12, d * dims.vocab), &[d, dims.vocab]);
    let emb = Tensor::f32(randn(13, dims.vocab * d), &[dims.vocab, d]);
    let tok =
        Tensor::i32((0..(mb * s) as i32).map(|i| i % dims.vocab as i32).collect(), &[mb, s]);

    let units: Vec<(&str, Vec<&Tensor>)> = vec![
        ("attn_fwd", vec![&x, &g1, &wq, &wk, &wv, &wo]),
        ("attn_bwd_x", vec![&x, &dy, &g1, &wq, &wk, &wv, &wo]),
        ("attn_bwd_w", vec![&x, &dy, &g1, &wq, &wk, &wv, &wo]),
        ("mlp_fwd", vec![&x, &g2, &wg, &wu, &wd]),
        ("mlp_bwd_x", vec![&x, &dy, &g2, &wg, &wu, &wd]),
        ("mlp_bwd_w", vec![&x, &dy, &g2, &wg, &wu, &wd]),
        ("embed_fwd", vec![&tok, &emb]),
        ("embed_bwd", vec![&tok, &dy]),
        ("head_loss_grad", vec![&x, &wh, &tok]),
    ];
    for (name, args) in units {
        let got = fast.run(name, &args).unwrap();
        let want = reference.run(name, &args).unwrap();
        assert_eq!(got.len(), want.len(), "{name}: output arity");
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.shape(), g.shape(), "{name} out {i}: shape");
            check(name, i, w, g);
        }
    }
}

fn assert_rel(name: &str, i: usize, want: &Tensor, got: &Tensor, tol: f32) {
    let (w, g) = match (want.as_f32(), got.as_f32()) {
        (Ok(w), Ok(g)) => (w, g),
        _ => return, // i32 outputs have no tolerance question
    };
    for (j, (a, b)) in w.iter().zip(g).enumerate() {
        assert!(
            (a - b).abs() <= tol * a.abs().max(1.0),
            "{name} out {i}[{j}]: fast {b} vs reference {a}"
        );
    }
}

fn assert_bits(name: &str, i: usize, want: &Tensor, got: &Tensor) {
    if let (Ok(ws), Ok(gs)) = (want.as_f32(), got.as_f32()) {
        for (j, (a, b)) in ws.iter().zip(gs).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{name} out {i}[{j}]: fast {b} != reference {a}"
            );
        }
    }
}

#[test]
fn units_match_reference_within_1e5_on_ragged_shapes() {
    compare_paths(&ragged_dims(), KernelPath::Blocked, |name, i, w, g| {
        assert_rel(name, i, w, g, 1e-5)
    });
}

#[test]
fn units_match_reference_within_1e5_on_tiny_shapes() {
    compare_paths(&tiny_dims(), KernelPath::Blocked, |name, i, w, g| {
        assert_rel(name, i, w, g, 1e-5)
    });
}

#[test]
fn units_are_bit_equal_to_reference() {
    // The stronger property the blocked GEMMs are designed for: same
    // per-element accumulation order ⇒ identical bits (see gemm.rs).
    for dims in [tiny_dims(), ragged_dims(), test_preset_dims()] {
        compare_paths(&dims, KernelPath::Blocked, assert_bits);
    }
}

#[test]
fn simd_units_bit_equal_except_flash_attention_within_1e5() {
    // The simd oracle policy: GEMM-only units keep the accumulation
    // order (one accumulator per element, depth order) ⇒ bit-equal; the
    // attn units run the flash core, which reassociates the softmax ⇒
    // mixed abs+rel ≤ 1e-5 (the denominator cancellations in the
    // backward make a pure-relative bound too brittle near zero).
    for dims in [tiny_dims(), ragged_dims(), test_preset_dims()] {
        compare_paths(&dims, KernelPath::Simd, |name, i, w, g| {
            if name.starts_with("attn") {
                if let (Ok(ws), Ok(gs)) = (w.as_f32(), g.as_f32()) {
                    for (j, (a, b)) in ws.iter().zip(gs).enumerate() {
                        assert!(
                            (a - b).abs() <= 1e-5 + 1e-5 * a.abs().max(b.abs()),
                            "{name} out {i}[{j}]: simd {b} vs reference {a}"
                        );
                    }
                }
            } else {
                assert_bits(name, i, w, g);
            }
        });
    }
}

#[test]
fn training_losses_agree_across_kernel_paths() {
    // Whole-run parity on the `test` preset (big enough to use the
    // blocked core): per-step mean losses must be bit-equal, which is
    // what keeps `--kernels reference` a valid baseline for
    // `stp bench train` speedup numbers.
    let run = |path: KernelPath| {
        let mut cfg = TrainConfig::virtual_default();
        cfg.kernels = path;
        cfg.steps = 2;
        cfg.dims = Some(test_preset_dims());
        train(&cfg).unwrap()
    };
    let blocked = run(KernelPath::Blocked);
    let reference = run(KernelPath::Reference);
    assert_eq!(blocked.steps.len(), reference.steps.len());
    for (a, b) in blocked.steps.iter().zip(&reference.steps) {
        assert_eq!(
            a.mean_loss.to_bits(),
            b.mean_loss.to_bits(),
            "step {}: blocked {} != reference {}",
            a.step,
            a.mean_loss,
            b.mean_loss
        );
    }
    // Only the blocked path touches the arena.
    assert!(blocked.workspace_peak_bytes.iter().all(|&b| b > 0));
    assert!(reference.workspace_peak_bytes.iter().all(|&b| b == 0));
}

#[test]
fn simd_training_losses_track_reference_within_tolerance() {
    // Whole-run parity for the reassociating path: flash attention's
    // ≤ 1e-5 per-unit drift compounds through two SGD steps, so the
    // bound loosens with depth — tight on the first loss (pre-update
    // forward), looser once the updated weights diverge.
    let run = |path: KernelPath| {
        let mut cfg = TrainConfig::virtual_default();
        cfg.kernels = path;
        cfg.steps = 2;
        cfg.dims = Some(test_preset_dims());
        train(&cfg).unwrap()
    };
    let simd = run(KernelPath::Simd);
    let reference = run(KernelPath::Reference);
    assert_eq!(simd.steps.len(), reference.steps.len());
    for (i, (a, b)) in simd.steps.iter().zip(&reference.steps).enumerate() {
        let tol = if i == 0 { 2e-5 } else { 5e-4 };
        let rel = (a.mean_loss - b.mean_loss).abs() / b.mean_loss.abs().max(1e-12);
        assert!(
            rel <= tol,
            "step {}: simd loss {} vs reference {} (rel {rel:.2e} > {tol:.0e})",
            a.step,
            a.mean_loss,
            b.mean_loss
        );
    }
    assert!(simd.workspace_peak_bytes.iter().all(|&b| b > 0));
}

#[test]
fn simd_training_is_invariant_in_the_worker_count() {
    // Determinism at any pool width: band→worker assignment is fixed and
    // each worker packs into its own arena, so 1, 2 and 8 workers must
    // produce bit-identical losses. Dims are sized so the head GEMM
    // (256×32×512 ≈ 4.2 MFLOP) clears the parallel-engagement floor and
    // the pool genuinely runs.
    let dims = ManifestDims {
        vocab: 512,
        d: 32,
        q_heads: 4,
        kv_heads: 2,
        ffn: 96,
        layers: 4,
        seq: 64,
        mb: 4,
        tp: 1,
        pp: 2,
        vpp: 2,
    };
    let run = |workers: usize| {
        let mut cfg = TrainConfig::virtual_default();
        cfg.kernels = KernelPath::Simd;
        cfg.workers = workers;
        cfg.steps = 2;
        cfg.dims = Some(dims.clone());
        train(&cfg).unwrap()
    };
    let one = run(1);
    for workers in [2usize, 8] {
        let multi = run(workers);
        assert_eq!(one.steps.len(), multi.steps.len());
        for (a, b) in one.steps.iter().zip(&multi.steps) {
            assert_eq!(
                a.mean_loss.to_bits(),
                b.mean_loss.to_bits(),
                "step {}: 1 worker {} != {workers} workers {}",
                a.step,
                a.mean_loss,
                b.mean_loss
            );
        }
    }
}
