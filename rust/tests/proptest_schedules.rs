//! Property-based tests over the schedule generators and simulator
//! (hand-rolled generator — no proptest crate in this offline build; a
//! seeded PRNG sweeps the parameter space and every failure prints its
//! case for replay).

use stp::cluster::{ClusterSpec, HardwareProfile, Topology};
use stp::exec::Rng;
use stp::model::ModelConfig;
use stp::schedule::{validate, build_schedule, Op, ScheduleKind};
use stp::sim::{CostModel, Simulator};

/// Deterministic case sweep: 64 random (kind, tp, pp, m) tuples.
fn cases(seed: u64, n: usize) -> Vec<(ScheduleKind, usize, usize, usize)> {
    let mut rng = Rng::new(seed);
    let kinds = ScheduleKind::all();
    (0..n)
        .map(|_| {
            let kind = kinds[rng.below(kinds.len())];
            let tp = [1, 2, 4, 8][rng.below(4)];
            let pp = [1, 2, 3, 4, 6, 8][rng.below(6)];
            // Multiple of pp (1F1B-I constraint), at least 2·pp.
            let m = pp * (2 + rng.below(9));
            (kind, tp, pp, m)
        })
        .collect()
}

#[test]
fn prop_every_random_case_is_legal() {
    for (kind, tp, pp, m) in cases(0xC0FFEE, 64) {
        let topo = Topology::new(tp, pp, 1);
        let s = build_schedule(kind, &topo, m);
        let v = validate(&s);
        assert!(v.is_empty(), "case ({kind:?}, tp{tp}, pp{pp}, m{m}): {} violations: {}", v.len(), v[0]);
    }
}

#[test]
fn prop_simulation_never_deadlocks_and_conserves_time() {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    for (kind, tp, pp, m) in cases(0xBEEF, 32) {
        let topo = Topology::new(tp, pp, 1);
        let cost = CostModel::analytic(&model, &topo, &cluster, 2048, 1);
        let s = build_schedule(kind, &topo, m);
        let r = Simulator::new(&cost).run(&s);
        assert!(r.iteration_secs.is_finite() && r.iteration_secs > 0.0);
        // Per device: busy + idle == iteration (accounting identity).
        for (d, dev) in r.devices.iter().enumerate() {
            let total = dev.busy + dev.idle;
            assert!(
                (total - r.iteration_secs).abs() < 1e-6 * r.iteration_secs.max(1.0),
                "case ({kind:?}, tp{tp}, pp{pp}, m{m}) dev {d}: busy+idle {total} != iter {}",
                r.iteration_secs
            );
        }
        // Compute time is schedule-invariant: busy >= compute.
        for dev in &r.devices {
            assert!(dev.busy + 1e-9 >= dev.compute);
        }
    }
}

#[test]
fn prop_total_compute_is_schedule_invariant() {
    // Same model+topo ⇒ identical total unit-compute regardless of the
    // schedule (bubbles move, work doesn't) — modulo braids changing
    // nothing about compute totals.
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let mut rng = Rng::new(7);
    for _ in 0..8 {
        let tp = [2, 4][rng.below(2)];
        let pp = [2, 4][rng.below(2)];
        let m = pp * (2 + rng.below(4));
        let topo = Topology::new(tp, pp, 1);
        let cost = CostModel::analytic(&model, &topo, &cluster, 2048, 1);
        let compute_of = |kind| {
            let s = build_schedule(kind, &topo, m);
            let r = Simulator::new(&cost).run(&s);
            r.devices.iter().map(|d| d.compute).sum::<f64>()
        };
        let base = compute_of(ScheduleKind::GPipe);
        for kind in [ScheduleKind::OneF1BInterleaved, ScheduleKind::ZbV, ScheduleKind::Stp] {
            let c = compute_of(kind);
            assert!(
                (c - base).abs() < 1e-6 * base,
                "tp{tp} pp{pp} m{m} {kind:?}: compute {c} != gpipe {base}"
            );
        }
    }
}

#[test]
fn prop_memory_replay_never_negative() {
    // Replaying any schedule's ops, live activation count stays >= 0 and
    // returns to zero (matched alloc/free).
    for (kind, _tp, pp, m) in cases(0xABCD, 48) {
        let topo = Topology::new(1, pp, 1);
        let s = build_schedule(kind, &topo, m);
        for (d, ops) in s.devices.iter().enumerate() {
            let mut live = 0i64;
            for op in ops {
                if op.forward_part().is_some() {
                    live += 1;
                }
                if op.weight_part().is_some() {
                    live -= 1;
                }
                assert!(live >= 0, "case ({kind:?}, pp{pp}, m{m}) dev {d}: negative live");
            }
            assert_eq!(live, 0, "case ({kind:?}, pp{pp}, m{m}) dev {d}: leak {live}");
        }
    }
}

#[test]
fn prop_braids_always_satisfy_fig11_constraint() {
    for (_, _, pp, m) in cases(0x5EED, 32) {
        let topo = Topology::new(2, pp, 1);
        for kind in [ScheduleKind::Stp, ScheduleKind::StpMemEff, ScheduleKind::StpOffload] {
            let s = build_schedule(kind, &topo, m);
            for (_, op) in s.iter_ops() {
                if let Op::Braided { f_chunk, f_mb, b_chunk, b_mb, .. } = op {
                    if f_chunk == b_chunk {
                        assert!(f_mb > b_mb, "({kind:?}, pp{pp}, m{m}): {op:?}");
                    }
                }
            }
        }
    }
}
