//! Integration: the simulator must reproduce the paper's qualitative
//! results (who wins, where, by roughly what factor) across the evaluated
//! configurations — the acceptance criteria of DESIGN.md §5.

use stp::cluster::{partition_mllm, ClusterSpec, HardwareProfile, Topology};
use stp::model::{MllmConfig, ModelConfig};
use stp::schedule::{build_schedule, build_schedule_scaled, theory, ScheduleKind};
use stp::sim::{AcMode, CostModel, Simulator};

fn thr(
    model: &ModelConfig,
    cluster: &ClusterSpec,
    tp: usize,
    pp: usize,
    seq: usize,
    m: usize,
    k: ScheduleKind,
) -> f64 {
    let topo = Topology::new(tp, pp, 1);
    let cost = CostModel::analytic(model, &topo, cluster, seq, 1);
    let s = build_schedule(k, &topo, m);
    Simulator::new(&cost).run(&s).throughput()
}

#[test]
fn fig7_stp_wins_every_12b_configuration() {
    // Strict wins at TP=8 (headline); at TP=4 the greedy construction may
    // land within a sub-percent tie of 1F1B-I (see EXPERIMENTS.md).
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    for (tp, pp, seq) in [(4, 4, 3072), (8, 2, 3072), (4, 4, 6144), (8, 2, 6144)] {
        let ours = thr(&model, &cluster, tp, pp, seq, 128, ScheduleKind::Stp);
        let i = thr(&model, &cluster, tp, pp, seq, 128, ScheduleKind::OneF1BInterleaved);
        let z = thr(&model, &cluster, tp, pp, seq, 128, ScheduleKind::ZbV);
        if tp >= 8 {
            assert!(ours > i, "tp{tp} pp{pp} seq{seq}: ours {ours:.2} !> 1f1b-i {i:.2}");
        } else {
            assert!(ours > 0.99 * i, "tp{tp} pp{pp} seq{seq}: ours {ours:.2} well below 1f1b-i {i:.2}");
        }
        assert!(ours > z, "tp{tp} pp{pp} seq{seq}: ours {ours:.2} !> zb-v {z:.2}");
    }
}

#[test]
fn gains_grow_with_tp_size() {
    // Paper: "the highest throughput improvements ... achieved at TP=8"
    // (larger TP ⇒ more overlappable communication).
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let gain = |tp, pp| {
        thr(&model, &cluster, tp, pp, 6144, 128, ScheduleKind::Stp)
            / thr(&model, &cluster, tp, pp, 6144, 128, ScheduleKind::OneF1BInterleaved)
    };
    assert!(gain(8, 2) > gain(4, 4), "tp8 {:.3} !> tp4 {:.3}", gain(8, 2), gain(4, 4));
}

#[test]
fn gains_shrink_on_h20() {
    // Appendix D: the H20's bandwidth/FLOPs ratio shrinks the TP bubble,
    // so STP's advantage diminishes vs the A800.
    let model = ModelConfig::qwen2_12b();
    let gain = |cluster: &ClusterSpec| {
        thr(&model, cluster, 8, 2, 6144, 128, ScheduleKind::Stp)
            / thr(&model, cluster, 8, 2, 6144, 128, ScheduleKind::OneF1BInterleaved)
    };
    let a800 = gain(&ClusterSpec::uniform(HardwareProfile::a800()));
    let h20 = gain(&ClusterSpec::uniform(HardwareProfile::h20()));
    assert!(h20 < a800, "h20 gain {h20:.3} !< a800 gain {a800:.3}");
    assert!(h20 > 0.99, "STP should not lose on H20 ({h20:.3})");
}

#[test]
fn memory_ranking_zbv_lowest_ours_highest() {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(4, 4, 1);
    let cost = CostModel::analytic(&model, &topo, &cluster, 6144, 1);
    let peak = |k| {
        let s = build_schedule(k, &topo, 64);
        Simulator::new(&cost).run(&s).peak_activation_gb()
    };
    let z = peak(ScheduleKind::ZbV);
    let i = peak(ScheduleKind::OneF1BInterleaved);
    let ours = peak(ScheduleKind::Stp);
    assert!(z < i && z < ours, "zb-v {z:.1} should be lowest ({i:.1}, {ours:.1})");
    assert!(ours > 1.2 * z, "ours should clearly exceed zb-v");
}

#[test]
fn offload_recovers_memory_with_small_throughput_cost() {
    // Paper §5.4: 10–19.2% peak reduction, negligible throughput loss.
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::h20());
    let topo = Topology::new(4, 4, 1);
    let cost = CostModel::analytic(&model, &topo, &cluster, 6144, 1);
    let run = |k| {
        let s = build_schedule(k, &topo, 128);
        Simulator::new(&cost).run(&s)
    };
    let plain = run(ScheduleKind::Stp);
    let off = run(ScheduleKind::StpOffload);
    let mem_saving = 1.0 - off.peak_activation_gb() / plain.peak_activation_gb();
    assert!(mem_saving > 0.08, "only {:.1}% saved", 100.0 * mem_saving);
    let thr_loss = 1.0 - off.throughput() / plain.throughput();
    assert!(thr_loss < 0.05, "{:.1}% throughput lost", 100.0 * thr_loss);
}

#[test]
fn mllm_stp_wins_and_biggest_gain_at_unbalanced_low_pp() {
    // Table 3 shape: STP > baselines; PP=2 unbalanced case gives the
    // largest relative win (paper: +16.7%).
    let mllm = MllmConfig::qwen2vl_14_9b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let gain_at = |tp: usize, pp: usize| {
        let topo = Topology::new(tp, pp, 1);
        let plan = partition_mllm(&mllm, topo.chunks());
        let cost = CostModel::analytic_mllm(
            &mllm.lm, &mllm.vit, &plan, &topo, &cluster, 5120, 3136, 1,
        );
        let run = |k| {
            let s = build_schedule_scaled(k, &topo, 128, cost.chunk_scales());
            Simulator::new(&cost).run(&s).throughput()
        };
        run(ScheduleKind::Stp) / run(ScheduleKind::OneF1BInterleaved)
    };
    let pp4 = gain_at(4, 4);
    let pp2 = gain_at(8, 2);
    assert!(pp4 > 1.0, "MLLM pp4 gain {pp4:.3}");
    assert!(pp2 > 1.0, "MLLM pp2 gain {pp2:.3}");
    assert!(pp2 > pp4, "pp2 {pp2:.3} should beat pp4 {pp4:.3} (paper's 16.7% case)");
}

#[test]
fn theory_and_simulation_agree_on_tp_bubble_order() {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(8, 4, 1);
    let cost = CostModel::analytic(&model, &topo, &cluster, 4096, 1);
    let ti = cost.theory_inputs(64);
    for kind in ScheduleKind::paper_trio() {
        let row = theory(kind, &ti);
        let s = build_schedule(kind, &topo, 64);
        let r = Simulator::new(&cost).run(&s);
        // Simulated per-device TP bubble within 3x of the closed form
        // (construction overhead, braid tails).
        let sim = r.tp_bubble_per_device();
        assert!(
            sim < 3.0 * row.tp_bubble.max(0.15),
            "{kind:?}: sim {sim:.3} vs theory {:.3}",
            row.tp_bubble
        );
    }
}

#[test]
fn activation_checkpointing_trades_memory_for_time() {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(4, 4, 1);
    let run = |mode| {
        let cost =
            CostModel::analytic(&model, &topo, &cluster, 6144, 1).with_activation_checkpoint(mode);
        let s = build_schedule_scaled(ScheduleKind::Stp, &topo, 64, cost.chunk_scales());
        Simulator::new(&cost).run(&s)
    };
    let none = run(AcMode::None);
    let all = run(AcMode::All);
    assert!(all.peak_activation_gb() < 0.75 * none.peak_activation_gb());
    assert!(all.throughput() < none.throughput());
    // Paper Table 9: full AC ≈ −22% throughput, −35% memory. Shape check.
    let thr_drop = 1.0 - all.throughput() / none.throughput();
    assert!((0.05..0.45).contains(&thr_drop), "thr drop {thr_drop:.2}");
}

#[test]
fn cp_and_dp_topologies_simulate() {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    for topo in [Topology::new(2, 4, 1).with_cp(2), Topology::new(2, 4, 2)] {
        let cost = CostModel::analytic(&model, &topo, &cluster, 12288, 1);
        for kind in ScheduleKind::paper_trio() {
            let s = build_schedule_scaled(kind, &topo, 64, cost.chunk_scales());
            let r = Simulator::new(&cost).run(&s);
            assert!(r.throughput() > 0.0, "{kind:?} {topo}");
        }
    }
}
