//! Integration: every scheduler × a grid of topologies and microbatch
//! counts must produce complete, legal schedules with the paper's
//! structural properties.

use stp::cluster::Topology;
use stp::schedule::{assert_valid, build_schedule, Op, Schedule, ScheduleKind};

fn grid() -> Vec<(usize, usize)> {
    // (pp, n_mb) — n_mb always a multiple of pp (1F1B-I's constraint).
    vec![(1, 4), (2, 4), (2, 8), (4, 8), (4, 16), (8, 16), (4, 12)]
}

#[test]
fn all_schedules_legal_across_grid() {
    for (pp, n_mb) in grid() {
        let topo = Topology::new(2, pp, 1);
        for kind in ScheduleKind::all() {
            if kind == ScheduleKind::OneF1B && n_mb < pp {
                continue;
            }
            let s = build_schedule(kind, &topo, n_mb);
            assert_valid(&s);
        }
    }
}

#[test]
fn work_conservation() {
    // Exactly one F, one B, one W per (chunk, microbatch) everywhere.
    for (pp, n_mb) in grid() {
        let topo = Topology::new(1, pp, 1);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, n_mb);
            let chunks = s.n_chunks();
            assert_eq!(s.count_forwards(), chunks * n_mb, "{kind:?} pp{pp} m{n_mb}");
            assert_eq!(s.count_backwards(), chunks * n_mb, "{kind:?}");
            assert_eq!(s.count_weight_grads(), chunks * n_mb, "{kind:?}");
        }
    }
}

#[test]
fn stp_tp_exposure_constant_in_m() {
    // Paper Table 1: STP's TP bubble is (2p+1)·T_AR — independent of m —
    // while ZB-V's grows 4m and 1F1B-I's 2m.
    let topo = Topology::new(4, 4, 1);
    let exposure = |kind, m| {
        let s = build_schedule(kind, &topo, m);
        s.exposed_fwd_ars() + s.exposed_bwd_ars()
    };
    let stp_64 = exposure(ScheduleKind::Stp, 64);
    let stp_192 = exposure(ScheduleKind::Stp, 192);
    assert!(
        stp_192 < stp_64 * 2,
        "STP exposure should be ~constant in m: {stp_64} -> {stp_192}"
    );
    let zbv_64 = exposure(ScheduleKind::ZbV, 64);
    let zbv_192 = exposure(ScheduleKind::ZbV, 192);
    assert_eq!(zbv_192, zbv_64 * 3, "ZB-V exposes every AR (4m)");
    // Cross-schedule: at m=192 STP exposes far fewer ARs.
    assert!(stp_192 * 5 < zbv_192);
}

#[test]
fn one_f1b_i_exposes_only_forward_ars() {
    // Full backward hides the backward AR under W (2m total).
    let topo = Topology::new(4, 4, 1);
    let s = build_schedule(ScheduleKind::OneF1BInterleaved, &topo, 16);
    assert_eq!(s.exposed_fwd_ars(), s.count_forwards());
    assert_eq!(s.exposed_bwd_ars(), 0);
}

#[test]
fn vshape_places_head_on_device_zero() {
    // The V dataflow puts the last chunk (loss) back on device 0, which is
    // what enables the early backward (paper Fig. 4).
    for kind in [ScheduleKind::ZbV, ScheduleKind::Stp] {
        let topo = Topology::new(1, 4, 1);
        let s = build_schedule(kind, &topo, 8);
        assert_eq!(s.device_of(s.n_chunks() - 1), 0, "{kind:?}");
    }
}

#[test]
fn offload_variant_only_adds_transfer_ops() {
    let topo = Topology::new(2, 4, 1);
    let plain = build_schedule(ScheduleKind::Stp, &topo, 8);
    let off = build_schedule(ScheduleKind::StpOffload, &topo, 8);
    let strip = |s: &Schedule| {
        s.devices
            .iter()
            .map(|ops| {
                ops.iter()
                    .filter(|o| !matches!(o, Op::Offload { .. } | Op::Reload { .. }))
                    .copied()
                    .collect::<Vec<_>>()
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(strip(&plain), strip(&off));
}

#[test]
fn schedules_are_deterministic() {
    let topo = Topology::new(2, 4, 1);
    for kind in ScheduleKind::all() {
        let a = build_schedule(kind, &topo, 12);
        let b = build_schedule(kind, &topo, 12);
        assert_eq!(a.devices, b.devices, "{kind:?} not deterministic");
    }
}

#[test]
fn large_scale_schedule_builds_quickly() {
    // p=8, m=256: construction must stay interactive.
    let topo = Topology::new(8, 8, 1);
    let t0 = std::time::Instant::now();
    let s = build_schedule(ScheduleKind::Stp, &topo, 256);
    assert_valid(&s);
    assert!(t0.elapsed().as_secs_f64() < 5.0, "took {:?}", t0.elapsed());
}
