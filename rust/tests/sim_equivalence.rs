//! Golden equivalence suite: the event-driven simulator core must be
//! **bit-identical** to the polling oracle (`sim::reference`) — iteration
//! time, bubble decomposition, peak memory, every per-device accumulator
//! and every per-device event sequence — across all schedule kinds,
//! uniform and mixed clusters, MLLM chunk imbalance and offload
//! variants. Plus the planner-level contract: beam search finds the
//! exhaustive best plan at 16 GPUs while simulating fewer candidates.
//!
//! The symmetry-fold section pins DESIGN.md §15's invariants: the folded
//! fleet replay is bit-identical to replaying every DP replica across
//! all schedule kinds, uniform and mixed pools and dp ∈ {1, 2, 4};
//! transparent over the plain single-replica replay; and declines
//! honestly on replica-targeted faults and group-straddling replicas.

use stp::cluster::{partition_mllm, ClusterSpec, GroupOrder, HardwareProfile, Topology};
use stp::elastic::{FaultEvent, FaultPlan};
use stp::model::{MllmConfig, ModelConfig};
use stp::plan::{plan, PlanModel, PlanQuery, SearchMode};
use stp::schedule::{
    build_schedule_scaled, stp::build_stp_offload, OffloadParams, Placement, Schedule,
    ScheduleKind, ShapeCosts,
};
use stp::sim::{
    reference, CostModel, FleetSim, FoldDecline, FoldedTopology, SimArena, SimReport, Simulator,
};

/// Assert two reports are bit-identical: scalars, per-device accounting,
/// and the per-device event sequences (the engines may interleave
/// devices differently in the global event order; within one device both
/// emit program order).
fn assert_bit_identical(oracle: &SimReport, event: &SimReport, label: &str) {
    assert_eq!(oracle.kind, event.kind, "{label}");
    assert_eq!(
        oracle.iteration_secs.to_bits(),
        event.iteration_secs.to_bits(),
        "{label}: iteration"
    );
    assert_eq!(oracle.n_mb, event.n_mb, "{label}");
    assert_eq!(oracle.mb_size, event.mb_size, "{label}");
    assert_eq!(oracle.static_bytes, event.static_bytes, "{label}");
    assert_eq!(oracle.world_size, event.world_size, "{label}");
    assert_eq!(
        oracle.aggregate_peak_flops.to_bits(),
        event.aggregate_peak_flops.to_bits(),
        "{label}: peak flops"
    );
    assert_eq!(
        oracle.model_flops_per_sample.to_bits(),
        event.model_flops_per_sample.to_bits(),
        "{label}: model flops"
    );
    assert_eq!(oracle.devices.len(), event.devices.len(), "{label}");
    for (d, (a, b)) in oracle.devices.iter().zip(&event.devices).enumerate() {
        assert_eq!(a.busy.to_bits(), b.busy.to_bits(), "{label}: dev{d} busy");
        assert_eq!(a.compute.to_bits(), b.compute.to_bits(), "{label}: dev{d} compute");
        assert_eq!(
            a.exposed_ar.to_bits(),
            b.exposed_ar.to_bits(),
            "{label}: dev{d} exposed AR (TP bubble)"
        );
        assert_eq!(a.idle.to_bits(), b.idle.to_bits(), "{label}: dev{d} idle (PP bubble)");
        assert_eq!(
            a.peak_activation_bytes, b.peak_activation_bytes,
            "{label}: dev{d} peak memory"
        );
        assert_eq!(a.pcie_busy.to_bits(), b.pcie_busy.to_bits(), "{label}: dev{d} pcie");
        assert_eq!(a.mem_capacity_bytes, b.mem_capacity_bytes, "{label}: dev{d} capacity");
        assert_eq!(a.hw_name, b.hw_name, "{label}: dev{d} hw");
    }
    assert_eq!(oracle.events.len(), event.events.len(), "{label}: event count");
    for d in 0..oracle.devices.len() {
        let ea: Vec<_> = oracle.events.iter().filter(|e| e.device == d).collect();
        let eb: Vec<_> = event.events.iter().filter(|e| e.device == d).collect();
        assert_eq!(ea.len(), eb.len(), "{label}: dev{d} event count");
        for (i, (x, y)) in ea.iter().zip(&eb).enumerate() {
            assert_eq!(x.op, y.op, "{label}: dev{d} event {i} op");
            assert_eq!(x.start.to_bits(), y.start.to_bits(), "{label}: dev{d} event {i} start");
            assert_eq!(x.end.to_bits(), y.end.to_bits(), "{label}: dev{d} event {i} end");
        }
    }
}

fn compare(cost: &CostModel, s: &Schedule, label: &str) {
    let oracle = reference::Simulator::new(cost).run(s);
    let event = Simulator::new(cost).run(s);
    assert_bit_identical(&oracle, &event, label);
}

#[test]
fn golden_all_kinds_uniform_cluster() {
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    for (tp, pp, n_mb) in [(4usize, 4usize, 16usize), (8, 2, 64), (2, 8, 32)] {
        let topo = Topology::new(tp, pp, 1);
        let cost = CostModel::analytic(&m, &topo, &cluster, 3072, 1);
        for kind in ScheduleKind::all() {
            let s = build_schedule_scaled(kind, &topo, n_mb, cost.chunk_scales());
            compare(&cost, &s, &format!("{kind:?} tp{tp} pp{pp} m{n_mb} uniform"));
        }
    }
}

#[test]
fn golden_all_kinds_mixed_cluster() {
    let m = ModelConfig::qwen2_12b();
    let spec = ClusterSpec::mixed_a800_h20();
    let topo = Topology::new(4, 4, 1); // 16 GPUs over the 8+8 pool
    for order in [GroupOrder::Declared, GroupOrder::FastFirst, GroupOrder::Interleaved] {
        for kind in ScheduleKind::all() {
            let cost =
                CostModel::analytic_for(&m, &topo, &spec, order, kind.placement(), 3072, 1);
            let s = build_schedule_scaled(kind, &topo, 16, cost.chunk_scales());
            compare(&cost, &s, &format!("{kind:?} mixed order={order:?}"));
        }
    }
}

#[test]
fn golden_mllm_chunk_imbalance() {
    let m = MllmConfig::qwen2vl_14_9b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(4, 4, 1);
    let stage_plan = partition_mllm(&m, topo.chunks());
    let cost =
        CostModel::analytic_mllm(&m.lm, &m.vit, &stage_plan, &topo, &cluster, 5120, 3136, 1);
    for kind in ScheduleKind::paper_trio() {
        let s = build_schedule_scaled(kind, &topo, 24, cost.chunk_scales());
        compare(&cost, &s, &format!("{kind:?} mllm"));
    }
}

#[test]
fn golden_offload_variants() {
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::h20());
    let topo = Topology::new(4, 4, 1);
    let cost = CostModel::analytic(&m, &topo, &cluster, 6144, 1);
    for params in [
        OffloadParams::default(),
        OffloadParams { alpha_warmup: 0.5, alpha_steady: 0.9, reload_lead: 2 },
        OffloadParams { alpha_warmup: 0.0, alpha_steady: 1.0, reload_lead: 3 },
    ] {
        let s =
            build_stp_offload(&topo, 32, ShapeCosts::default(), cost.chunk_scales(), params);
        compare(&cost, &s, &format!("offload {params:?}"));
    }
}

#[test]
fn golden_explicit_p2p_overrides() {
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(4, 4, 1);
    let cost = CostModel::analytic(&m, &topo, &cluster, 3072, 1);
    for kind in [ScheduleKind::Stp, ScheduleKind::OneF1BInterleaved] {
        for explicit in [true, false] {
            let s = build_schedule_scaled(kind, &topo, 16, cost.chunk_scales());
            let oracle = reference::Simulator::new(&cost).with_explicit_p2p(explicit).run(&s);
            let event = Simulator::new(&cost).with_explicit_p2p(explicit).run(&s);
            assert_bit_identical(&oracle, &event, &format!("{kind:?} explicit={explicit}"));
        }
    }
}

#[test]
fn deadlock_is_an_error_in_both_cores() {
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(1, 2, 1);
    let cost = CostModel::analytic(&m, &topo, &cluster, 2048, 1);
    // B(0,0) with no F(0,0) anywhere: the polling replay never finds it
    // ready; the event-driven replay never resolves its dependency.
    let s = Schedule {
        kind: ScheduleKind::Stp,
        topo,
        n_mb: 1,
        placement: Placement::VShape,
        devices: vec![vec![stp::schedule::Op::b(0, 0)], vec![]],
    };
    let a = reference::Simulator::new(&cost).try_run(&s).unwrap_err();
    let b = Simulator::new(&cost).try_run(&s).unwrap_err();
    assert_eq!(a.device, b.device);
    assert_eq!(a.op_index, b.op_index);
    assert_eq!(a.ops_left, b.ops_left);
    assert_eq!(a.op, b.op);
}

#[test]
fn duplicate_producers_replay_natively_and_match_the_oracle() {
    // Two ops produce F(0,0) — a recomputation-style shape no builder
    // emits. The compiled replay handles it natively (per-edge dependency
    // counting through CSR consumer lists: the first producer completion
    // releases the slot's consumers, later ones only refresh the done
    // time) and must still match the polling oracle bit-for-bit.
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(1, 1, 1).with_vpp(1); // one chunk, one device
    let cost = CostModel::analytic(&m, &topo, &cluster, 2048, 1);
    let s = Schedule {
        kind: ScheduleKind::GPipe,
        topo,
        n_mb: 1,
        placement: Placement::Interleaved,
        devices: vec![vec![
            stp::schedule::Op::f(0, 0),
            stp::schedule::Op::f(0, 0),
            stp::schedule::Op::b_full(0, 0),
        ]],
    };
    assert!(!s.compile().unique_producers);
    let oracle = reference::Simulator::new(&cost).run(&s);
    let event = Simulator::new(&cost).run(&s);
    assert_bit_identical(&oracle, &event, "duplicate producers");
}

#[test]
fn duplicate_producers_across_stages_match_the_oracle() {
    // Duplicate producers with real cross-stage edges: device 0 recomputes
    // F(0,0) before its full backward while device 1 runs the steady
    // F(1,0)/B(1,0) pair. Program order keeps one writer per device, so
    // the event core's first-completion rule reproduces the oracle's
    // polling times exactly.
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(1, 2, 1).with_vpp(1);
    let cost = CostModel::analytic(&m, &topo, &cluster, 2048, 1);
    let s = Schedule {
        kind: ScheduleKind::GPipe,
        topo,
        n_mb: 1,
        placement: Placement::Interleaved,
        devices: vec![
            vec![
                stp::schedule::Op::f(0, 0),
                stp::schedule::Op::f(0, 0),
                stp::schedule::Op::b_full(0, 0),
            ],
            vec![stp::schedule::Op::f(1, 0), stp::schedule::Op::b_full(1, 0)],
        ],
    };
    assert!(!s.compile().unique_producers);
    let oracle = reference::Simulator::new(&cost).run(&s);
    let event = Simulator::new(&cost).run(&s);
    assert_bit_identical(&oracle, &event, "duplicate producers across stages");
}

#[test]
fn folded_matches_unfolded_across_kinds_clusters_and_dp() {
    let m = ModelConfig::qwen2_12b();
    let pools = [
        (ClusterSpec::uniform(HardwareProfile::a800()), GroupOrder::Declared),
        (ClusterSpec::mixed_a800_h20(), GroupOrder::FastFirst),
    ];
    for (cluster, order) in &pools {
        for dp in [1usize, 2, 4] {
            let topo = Topology::new(2, 2, dp);
            for kind in ScheduleKind::all() {
                let cost =
                    CostModel::analytic_for(&m, &topo, cluster, *order, kind.placement(), 3072, 1);
                let s = build_schedule_scaled(kind, &topo, 16, cost.chunk_scales());
                let fold = FoldedTopology::derive(cluster, &topo, *order, None)
                    .expect("symmetric pool must fold");
                assert!(fold.is_folded(), "{}: dp{dp} must fold to one class", cluster.name);
                let fleet = FleetSim::new(&cost);
                let mut arena = SimArena::default();
                let folded = fleet.run_folded(&s, &fold, &mut arena).unwrap();
                let unfolded = fleet.run_unfolded(&s, dp, &mut arena).unwrap();
                assert_bit_identical(
                    &folded,
                    &unfolded,
                    &format!("{kind:?} dp{dp} {} fold", cluster.name),
                );
            }
        }
    }
}

#[test]
fn folded_replay_is_transparent_over_the_plain_simulator() {
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(2, 2, 4);
    let cost = CostModel::analytic(&m, &topo, &cluster, 3072, 1);
    let s = build_schedule_scaled(ScheduleKind::Stp, &topo, 16, cost.chunk_scales());
    let plain = Simulator::new(&cost).run(&s);
    let fold = FoldedTopology::derive(&cluster, &topo, GroupOrder::Declared, None).unwrap();
    let mut arena = SimArena::default();
    let folded = FleetSim::new(&cost).run_folded(&s, &fold, &mut arena).unwrap();
    assert_bit_identical(&plain, &folded, "fold transparency");
}

#[test]
fn replica_faults_decline_the_fold_but_stay_bit_exact() {
    let m = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(2, 2, 2);
    let cost = CostModel::analytic(&m, &topo, &cluster, 3072, 1);
    let s = build_schedule_scaled(ScheduleKind::Stp, &topo, 16, cost.chunk_scales());
    let mut faults = FaultPlan::none();
    faults.events.push(FaultEvent::Straggler {
        step: 0,
        stage: 1,
        replica: 1,
        slowdown: 2.0,
        from_secs: 0.0,
    });
    let fold = FoldedTopology::derive(&cluster, &topo, GroupOrder::Declared, Some(&faults))
        .expect("uniform pool still partitions under faults");
    assert!(!fold.is_folded());
    assert_eq!(fold.decline, Some(FoldDecline::ReplicaFaults));
    let fleet = FleetSim::new(&cost).with_faults(faults);
    let mut arena = SimArena::default();
    let folded = fleet.run_folded(&s, &fold, &mut arena).unwrap();
    let unfolded = fleet.run_unfolded(&s, 2, &mut arena).unwrap();
    assert_bit_identical(&folded, &unfolded, "replica-faulted fleet");
    let clean = Simulator::new(&cost).run(&s);
    assert!(
        folded.iteration_secs > clean.iteration_secs,
        "the straggler replica must set the fleet's pace"
    );
}

#[test]
fn straddling_mixed_pool_declines_as_heterogeneous() {
    // (tp=2, pp=1, dp=6) on the 8+8 mixed pool: no stage-granular view
    // exists, and the per-replica packing puts replicas 0–3 on A800s and
    // 4–5 on H20s — different physics, so the fold must not collapse
    // them into one replay.
    let cluster = ClusterSpec::mixed_a800_h20();
    let topo = Topology::new(2, 1, 6);
    let fold = FoldedTopology::derive(&cluster, &topo, GroupOrder::Declared, None).unwrap();
    assert!(!fold.is_folded());
    assert_eq!(fold.decline, Some(FoldDecline::HeterogeneousReplicas));
    assert_eq!(fold.n_replays(), 2);
}

#[test]
fn beam_finds_the_exhaustive_best_plan_at_16_gpus() {
    let mut ex = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::uniform(HardwareProfile::a800()),
        16,
    );
    ex.seq = 3072;
    ex.n_mb_options = vec![16, 64];
    ex.threads = 2;
    let mut beam = ex.clone();
    beam.search = SearchMode::Beam { width: 8 };

    let re = plan(&ex);
    let rb = plan(&beam);
    assert!(
        rb.n_simulated() < re.n_simulated(),
        "beam simulated {} !< exhaustive {}",
        rb.n_simulated(),
        re.n_simulated()
    );
    let best_ex = re.best().expect("exhaustive best");
    let best_beam = rb.best().expect("beam best");
    assert_eq!(
        best_ex.candidate.id, best_beam.candidate.id,
        "beam best {} != exhaustive best {}",
        best_beam.candidate.label(),
        best_ex.candidate.label()
    );
    assert_eq!(best_ex.throughput.to_bits(), best_beam.throughput.to_bits());
}
