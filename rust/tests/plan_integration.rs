//! Integration + property tests over the parallelism auto-planner
//! (hand-rolled sweep in the `proptest_schedules.rs` style — no proptest
//! crate in this offline build). The contract under test:
//!
//! * the chosen plan is memory-feasible (simulated peak under the cap);
//! * planning is deterministic for fixed inputs (bit-identical ranking,
//!   independent of worker-thread count);
//! * the chosen plan is never ranked below any feasible candidate the
//!   search evaluated — including every fixed baseline configuration;
//! * at the acceptance budget (16 GPUs) the search simulates a wide
//!   field spanning every schedule kind.

use stp::cluster::HardwareProfile;
use stp::model::{MllmConfig, ModelConfig};
use stp::plan::{evaluate, plan, PlanModel, PlanQuery};
use stp::schedule::ScheduleKind;

/// A fast-but-wide query used by most tests (shorter sequence and a
/// reduced microbatch sweep keep debug-build runtime in check).
fn query_16() -> PlanQuery {
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        HardwareProfile::a800(),
        16,
    );
    q.seq = 2048;
    q.n_mb_options = vec![8, 16, 32, 64];
    // The test harness already runs tests concurrently; keep each
    // planner's own pool small to avoid oversubscription.
    q.threads = 2;
    q
}

#[test]
fn acceptance_16_gpus_wide_field_all_kinds() {
    let r = plan(&query_16());
    assert!(
        r.n_simulated() >= 100,
        "only {} candidates simulated at 16 GPUs",
        r.n_simulated()
    );
    assert_eq!(
        r.kinds_covered(),
        ScheduleKind::all().len(),
        "simulated field does not span all schedule kinds"
    );
    assert!(r.best().is_some());
    // Funnel accounting: nothing silently dropped.
    assert_eq!(
        r.n_enumerated,
        r.n_rejected_shape + r.n_pruned_memory + r.n_pruned_theory + r.n_simulated()
    );
}

#[test]
fn chosen_plan_is_memory_feasible() {
    for gpus in [8usize, 16] {
        let mut q = query_16();
        q.gpus = gpus;
        let r = plan(&q);
        let best = r.best().unwrap_or_else(|| panic!("no feasible plan at {gpus} GPUs"));
        assert!(best.feasible);
        assert!(
            best.peak_mem_bytes <= q.mem_cap_bytes(),
            "best plan peak {} exceeds cap {}",
            best.peak_mem_bytes,
            q.mem_cap_bytes()
        );
    }
}

#[test]
fn chosen_plan_never_below_any_feasible_candidate() {
    let r = plan(&query_16());
    let best = r.best().unwrap();
    for e in r.feasible() {
        assert!(
            best.throughput + 1e-12 >= e.throughput,
            "best {:.4} ranked below evaluated {:.4} ({})",
            best.throughput,
            e.throughput,
            e.candidate.label()
        );
    }
}

#[test]
fn chosen_plan_beats_fixed_baselines() {
    // Every hand-pickable fixed baseline for the budget — the paper's own
    // tp8/pp2 among them, across the compared schedules — must not beat
    // the planner's choice.
    let q = query_16();
    let r = plan(&q);
    let best = r.best().unwrap();
    let ctx = q.eval_context();
    for (tp, pp) in [(8, 2), (4, 4), (4, 2), (2, 8)] {
        for kind in [
            ScheduleKind::OneF1B,
            ScheduleKind::OneF1BInterleaved,
            ScheduleKind::ZbV,
            ScheduleKind::Stp,
        ] {
            let c = stp::plan::Candidate {
                id: usize::MAX,
                tp,
                pp,
                dp: 16 / (tp * pp),
                kind,
                n_mb: 32,
                offload: stp::schedule::OffloadParams::default(),
                offload_variant: 0,
            };
            let e = evaluate(&ctx, &c);
            if e.feasible {
                assert!(
                    best.throughput + 1e-12 >= e.throughput,
                    "baseline {} ({:.3} samples/s) beats planned {} ({:.3})",
                    c.label(),
                    e.throughput,
                    best.candidate.label(),
                    best.throughput
                );
            }
        }
    }
}

#[test]
fn planning_is_deterministic_across_runs_and_threads() {
    let mut q = query_16();
    q.n_mb_options = vec![16, 32]; // smaller field: this test runs plan() three times
    let a = plan(&q);
    let b = plan(&q);
    let mut q1 = q.clone();
    q1.threads = 1;
    let c = plan(&q1);
    for other in [&b, &c] {
        assert_eq!(a.n_simulated(), other.n_simulated());
        for (x, y) in a.ranked.iter().zip(&other.ranked) {
            assert_eq!(x.candidate.id, y.candidate.id);
            assert_eq!(x.throughput.to_bits(), y.throughput.to_bits());
            assert_eq!(x.peak_mem_bytes, y.peak_mem_bytes);
            assert_eq!(x.feasible, y.feasible);
        }
    }
}

#[test]
fn tighter_memory_cap_changes_the_frontier_not_the_contract() {
    // Constrain memory hard enough to matter: everything still ranked
    // must be feasible under the tighter cap, and the funnel must show
    // more memory pruning than the permissive run.
    let mut q = query_16();
    q.n_mb_options = vec![16, 32];
    let loose = plan(&q);
    q.mem_cap_gib = 40.0;
    let tight = plan(&q);
    assert!(tight.n_pruned_memory > loose.n_pruned_memory);
    if let Some(best) = tight.best() {
        assert!(best.peak_mem_bytes <= q.mem_cap_bytes());
    }
}

#[test]
fn mllm_planning_exercises_scaled_builders() {
    // The MLLM path routes chunk-imbalance scales into the builders; the
    // planner must produce a feasible plan for the 14.9B MLLM on 16 GPUs.
    let mut q = PlanQuery::new(
        PlanModel::Mllm(MllmConfig::qwen2vl_14_9b()),
        HardwareProfile::a800(),
        16,
    );
    q.seq = 2048;
    q.vit_tokens = 1024;
    q.n_mb_options = vec![16];
    q.threads = 2;
    let r = plan(&q);
    let best = r.best().expect("MLLM plan exists at 16 GPUs");
    assert!(best.feasible);
    // ViT-first split needs at least two chunks everywhere.
    assert!(best.candidate.pp * best.candidate.vpp() >= 2);
}

#[test]
fn plan_report_json_roundtrips() {
    let mut q = query_16();
    q.n_mb_options = vec![16];
    let r = plan(&q);
    let json = r.to_json().to_string();
    let v = stp::config::Json::parse(&json).expect("report JSON parses");
    assert_eq!(v.get("gpus").and_then(|x| x.as_usize()), Some(16));
    let cands = v.get("candidates").and_then(|x| x.as_arr()).expect("candidates array");
    assert_eq!(cands.len(), r.n_simulated());
    assert!(cands[0].get("schedule").and_then(|s| s.as_str()).is_some());
}
