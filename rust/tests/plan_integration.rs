//! Integration + property tests over the parallelism auto-planner
//! (hand-rolled sweep in the `proptest_schedules.rs` style — no proptest
//! crate in this offline build). The contract under test:
//!
//! * the chosen plan is memory-feasible (simulated peak under the cap);
//! * planning is deterministic for fixed inputs (bit-identical ranking,
//!   independent of worker-thread count);
//! * the chosen plan is never ranked below any feasible candidate the
//!   search evaluated — including every fixed baseline configuration;
//! * at the acceptance budget (16 GPUs) the search simulates a wide
//!   field spanning every schedule kind.

use stp::cluster::{ClusterSpec, GroupOrder, HardwareProfile, Topology};
use stp::model::{MllmConfig, ModelConfig};
use stp::plan::{evaluate, plan, PlanModel, PlanQuery};
use stp::schedule::{build_schedule_scaled, ScheduleKind};
use stp::sim::{CostModel, Simulator};

/// A fast-but-wide query used by most tests (shorter sequence and a
/// reduced microbatch sweep keep debug-build runtime in check).
fn query_16() -> PlanQuery {
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::uniform(HardwareProfile::a800()),
        16,
    );
    q.seq = 2048;
    q.n_mb_options = vec![8, 16, 32, 64];
    // The test harness already runs tests concurrently; keep each
    // planner's own pool small to avoid oversubscription.
    q.threads = 2;
    q
}

#[test]
fn acceptance_16_gpus_wide_field_all_kinds() {
    let r = plan(&query_16());
    assert!(
        r.n_simulated() >= 100,
        "only {} candidates simulated at 16 GPUs",
        r.n_simulated()
    );
    assert_eq!(
        r.kinds_covered(),
        ScheduleKind::all().len(),
        "simulated field does not span all schedule kinds"
    );
    assert!(r.best().is_some());
    // Funnel accounting: nothing silently dropped.
    assert_eq!(
        r.n_enumerated,
        r.n_rejected_shape + r.n_pruned_memory + r.n_pruned_theory + r.n_simulated()
    );
}

#[test]
fn chosen_plan_is_memory_feasible() {
    for gpus in [8usize, 16] {
        let mut q = query_16();
        q.gpus = gpus;
        let r = plan(&q);
        let best = r.best().unwrap_or_else(|| panic!("no feasible plan at {gpus} GPUs"));
        assert!(best.feasible);
        assert!(
            best.peak_mem_bytes <= q.mem_cap_bytes(),
            "best plan peak {} exceeds cap {}",
            best.peak_mem_bytes,
            q.mem_cap_bytes()
        );
    }
}

#[test]
fn chosen_plan_never_below_any_feasible_candidate() {
    let r = plan(&query_16());
    let best = r.best().unwrap();
    for e in r.feasible() {
        assert!(
            best.throughput + 1e-12 >= e.throughput,
            "best {:.4} ranked below evaluated {:.4} ({})",
            best.throughput,
            e.throughput,
            e.candidate.label()
        );
    }
}

#[test]
fn chosen_plan_beats_fixed_baselines() {
    // Every hand-pickable fixed baseline for the budget — the paper's own
    // tp8/pp2 among them, across the compared schedules — must not beat
    // the planner's choice.
    let q = query_16();
    let r = plan(&q);
    let best = r.best().unwrap();
    let ctx = q.eval_context();
    for (tp, pp) in [(8, 2), (4, 4), (4, 2), (2, 8)] {
        for kind in [
            ScheduleKind::OneF1B,
            ScheduleKind::OneF1BInterleaved,
            ScheduleKind::ZbV,
            ScheduleKind::Stp,
        ] {
            let c = stp::plan::Candidate {
                id: usize::MAX,
                tp,
                pp,
                dp: 16 / (tp * pp),
                kind,
                n_mb: 32,
                order: GroupOrder::Declared,
                offload: stp::schedule::OffloadParams::default(),
                offload_variant: 0,
                ac: stp::sim::AcMode::None,
                map: None,
                vpp_gene: 0,
            };
            let e = evaluate(&ctx, &c);
            if e.feasible {
                assert!(
                    best.throughput + 1e-12 >= e.throughput,
                    "baseline {} ({:.3} samples/s) beats planned {} ({:.3})",
                    c.label(),
                    e.throughput,
                    best.candidate.label(),
                    best.throughput
                );
            }
        }
    }
}

#[test]
fn planning_is_deterministic_across_runs_and_threads() {
    let mut q = query_16();
    q.n_mb_options = vec![16, 32]; // smaller field: this test runs plan() three times
    let a = plan(&q);
    let b = plan(&q);
    let mut q1 = q.clone();
    q1.threads = 1;
    let c = plan(&q1);
    for other in [&b, &c] {
        assert_eq!(a.n_simulated(), other.n_simulated());
        for (x, y) in a.ranked.iter().zip(&other.ranked) {
            assert_eq!(x.candidate.id, y.candidate.id);
            assert_eq!(x.throughput.to_bits(), y.throughput.to_bits());
            assert_eq!(x.peak_mem_bytes, y.peak_mem_bytes);
            assert_eq!(x.feasible, y.feasible);
        }
    }
}

#[test]
fn tighter_memory_cap_changes_the_frontier_not_the_contract() {
    // Constrain memory hard enough to matter: everything still ranked
    // must be feasible under the tighter cap, and the funnel must show
    // more memory pruning than the permissive run.
    let mut q = query_16();
    q.n_mb_options = vec![16, 32];
    let loose = plan(&q);
    q.mem_cap_gib = 40.0;
    let tight = plan(&q);
    assert!(tight.n_pruned_memory > loose.n_pruned_memory);
    if let Some(best) = tight.best() {
        assert!(best.peak_mem_bytes <= q.mem_cap_bytes());
    }
}

#[test]
fn mllm_planning_exercises_scaled_builders() {
    // The MLLM path routes chunk-imbalance scales into the builders; the
    // planner must produce a feasible plan for the 14.9B MLLM on 16 GPUs.
    let mut q = PlanQuery::new(
        PlanModel::Mllm(MllmConfig::qwen2vl_14_9b()),
        ClusterSpec::uniform(HardwareProfile::a800()),
        16,
    );
    q.seq = 2048;
    q.vit_tokens = 1024;
    q.n_mb_options = vec![16];
    q.threads = 2;
    let r = plan(&q);
    let best = r.best().expect("MLLM plan exists at 16 GPUs");
    assert!(best.feasible);
    // ViT-first split needs at least two chunks everywhere.
    assert!(best.candidate.pp * best.candidate.vpp() >= 2);
}

#[test]
fn plan_report_json_roundtrips() {
    let mut q = query_16();
    q.n_mb_options = vec![16];
    let r = plan(&q);
    let json = r.to_json().to_string();
    let v = stp::config::Json::parse(&json).expect("report JSON parses");
    assert_eq!(v.get("gpus").and_then(|x| x.as_usize()), Some(16));
    let cands = v.get("candidates").and_then(|x| x.as_arr()).expect("candidates array");
    assert_eq!(cands.len(), r.n_simulated());
    assert!(cands[0].get("schedule").and_then(|s| s.as_str()).is_some());
}

// ---------------------------------------------------------------------------
// Heterogeneous clusters (ClusterSpec): the uniform path must be
// behavior-preserving, and mixed A800+H20 pools must change what is
// optimal — the Fig. 13-style "who wins flips with hardware" result.
// ---------------------------------------------------------------------------

#[test]
fn uniform_cluster_spec_is_behavior_preserving() {
    // `ClusterSpec::uniform(hw)` routes every chunk, hop and capacity
    // through the exact single-profile arithmetic the planner used before
    // the refactor: same partition, same AR/P2P formulas, one profile on
    // every device.
    let model = ModelConfig::qwen2_12b();
    let hw = HardwareProfile::a800();
    let cluster = ClusterSpec::uniform(hw.clone());
    let topo = Topology::new(8, 2, 1);
    let cm = CostModel::analytic(&model, &topo, &cluster, 4096, 1);

    // Uniform layer split (the seed §5.1 partition), not the weighted one.
    assert_eq!(cm.stage_plan, stp::cluster::partition_llm(&model, topo.chunks()));

    // Chunk AR charged with the single profile's formula, on every chunk.
    let expect_ar = hw.allreduce_secs(model.ar_bytes_per_layer(4096, 1) / 2, topo.tp);
    for c in &cm.chunks {
        let u = c.fwd.iter().find(|u| u.ar > 0.0).expect("AR-carrying unit");
        assert_eq!(u.ar, expect_ar);
    }

    // Pipeline hops priced with the single profile's P2P formula.
    let cross = topo.pp_hop_cross_node(0, 1, hw.gpus_per_node);
    assert_eq!(cm.p2p_secs(0, 1), hw.p2p_secs(cm.p2p_bytes, cross));

    // Every simulated device reports the single profile's capacity/name.
    let s = build_schedule_scaled(ScheduleKind::Stp, &topo, 16, cm.chunk_scales());
    let r = Simulator::new(&cm).run(&s);
    for d in &r.devices {
        assert_eq!(d.hw_name, hw.name);
        assert_eq!(d.mem_capacity_bytes, (hw.mem_gib * (1u64 << 30) as f64) as usize);
    }

    // And the ranked search over the uniform spec stays deterministic.
    let a = plan(&query_16());
    let b = plan(&query_16());
    for (x, y) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(x.candidate.id, y.candidate.id);
        assert_eq!(x.throughput.to_bits(), y.throughput.to_bits());
    }
}

#[test]
fn mixed_pool_balanced_partition_beats_uniform_split() {
    // On 1 A800 node + 1 H20 node (tp8-pp2: fast devices hold chunks 0,3;
    // slow ones 1,2), balancing *stage time* (layers ÷ effective FLOPs)
    // must beat the paper's uniform layer split on simulated throughput:
    // uniform layers make the H20 stage the critical path.
    let model = ModelConfig::qwen2_12b();
    let spec = ClusterSpec::mixed_a800_h20();
    let topo = Topology::new(8, 2, 1);

    let balanced = CostModel::analytic(&model, &topo, &spec, 3072, 1);
    let uniform = CostModel::analytic_planned(
        &model,
        &stp::cluster::partition_llm(&model, topo.chunks()),
        &topo,
        &spec,
        3072,
        1,
    );
    // The balanced split is genuinely non-uniform: A800 chunks carry more.
    let counts: Vec<usize> =
        balanced.stage_plan.chunks.iter().map(|c| c.lm_layers).collect();
    assert!(counts[0] > counts[1], "A800 chunk should carry more layers: {counts:?}");
    assert_eq!(balanced.stage_plan.total_lm_layers(), model.layers);

    // V-shape kinds only: both cost models above attribute chunks under
    // the V-shape placement (the planner handles interleaved-placement
    // kinds through their own per-placement cost models).
    for kind in [ScheduleKind::Stp, ScheduleKind::ZbV] {
        let thr = |cm: &CostModel| {
            let s = build_schedule_scaled(kind, &topo, 32, cm.chunk_scales());
            Simulator::new(cm).run(&s).throughput()
        };
        let bal = thr(&balanced);
        let unif = thr(&uniform);
        assert!(
            bal > 1.05 * unif,
            "{kind:?}: balanced {bal:.3} !> uniform {unif:.3} samples/s"
        );
    }

    // Per-device OOM data reflects each device's own profile.
    let s = build_schedule_scaled(ScheduleKind::Stp, &topo, 16, balanced.chunk_scales());
    let r = Simulator::new(&balanced).run(&s);
    assert_eq!(r.devices[0].mem_capacity_bytes, 80 << 30);
    assert_eq!(r.devices[1].mem_capacity_bytes, 96 << 30);
    assert!(r.devices[0].hw_name.contains("a800"));
    assert!(r.devices[1].hw_name.contains("h20"));
}

#[test]
fn mixed_pool_planner_searches_orderings_and_flips_the_partition() {
    // The planner on the mixed pool enumerates device→group orderings and
    // lands on a stage-time-balanced (non-uniform) partition — an optimum
    // that *cannot* arise on either uniform pool, whose winners always use
    // the uniform §5.1 split (the Fig. 13-style flip, partition axis).
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::mixed_a800_h20(),
        16,
    );
    q.seq = 2048;
    q.n_mb_options = vec![16, 32];
    q.threads = 2;
    let r = plan(&q);
    let best = r.best().expect("mixed pool admits a feasible plan");
    assert!(best.feasible);

    // Both orderings were actually explored.
    for order in [GroupOrder::FastFirst, GroupOrder::Interleaved] {
        assert!(
            r.ranked.iter().any(|e| e.candidate.order == order),
            "no simulated candidate with order {order:?}"
        );
    }

    // The chosen plan's partition is not the uniform layer split.
    let ctx = q.eval_context();
    let cm = ctx.cost_model(&best.candidate);
    let model = ModelConfig::qwen2_12b();
    assert_ne!(
        cm.stage_plan,
        stp::cluster::partition_llm(&model, best.candidate.topo().chunks()),
        "mixed-pool optimum should use a non-uniform partition"
    );
    assert_eq!(cm.stage_plan.total_lm_layers(), model.layers);

    // Funnel accounting still closes with the wider (ordered) space.
    assert_eq!(
        r.n_enumerated,
        r.n_rejected_shape + r.n_pruned_memory + r.n_pruned_theory + r.n_simulated()
    );
}

// ---------------------------------------------------------------------------
// The keyed plan cache (`stp serve`'s engine): exact repeats answer from
// the report store, cluster deltas re-search with memoized evaluations —
// and every answer is byte-identical to a cold `plan()`.
// ---------------------------------------------------------------------------

#[test]
fn plan_cache_repeats_and_deltas_are_byte_identical_to_cold_plans() {
    use stp::plan::PlanCache;

    let mut q = query_16();
    q.n_mb_options = vec![16, 32];
    let cold = plan(&q).to_json().to_string();
    let mut cache = PlanCache::new();
    let first = cache.query(&q);
    assert!(!first.hit);
    assert!(first.sims_run > 0);
    assert_eq!(first.json, cold);
    let second = cache.query(&q);
    assert!(second.hit, "exact repeat must answer from the report store");
    assert_eq!(second.json, cold);
    assert_eq!(cache.len(), 1);

    // Pool swap: a fresh canonical key, a fresh search — and still
    // byte-identical to that query's own cold plan.
    let mut dq = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::uniform(HardwareProfile::h20()),
        16,
    );
    dq.seq = q.seq;
    dq.n_mb_options = q.n_mb_options.clone();
    dq.threads = q.threads;
    let delta = cache.query(&dq);
    assert!(!delta.hit);
    assert_eq!(delta.json, plan(&dq).to_json().to_string());
    assert_eq!(cache.len(), 2);
}

#[test]
fn cluster_deltas_reuse_untouched_evaluations() {
    use stp::plan::PlanCache;

    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::mixed_a800_h20(),
        8,
    );
    q.seq = 2048;
    q.n_mb_options = vec![16];
    q.threads = 2;
    let mut cache = PlanCache::new();
    let first = cache.query(&q);
    assert!(!first.hit && first.sims_run > 0);

    // Slow down the inter-group fabric: only candidates whose pipeline
    // actually crosses node groups resolve to different physics; the
    // rest must answer from the evaluation memo.
    let mut dq = q.clone();
    dq.cluster.intergroup_gbps /= 2.0;
    let delta = cache.query(&dq);
    assert!(!delta.hit, "a changed pool is a new canonical key");
    assert!(delta.sims_reused > 0, "intra-group candidates must be reused");
    assert_eq!(delta.json, plan(&dq).to_json().to_string());
}

// ---------------------------------------------------------------------------
// Evolutionary search (`SearchMode::Evo`): bit-deterministic at any
// thread count, never worse than the enumerated field it seeds from,
// and competitive with beam at fleet scale while simulating a small
// fraction of the exhaustive space (DESIGN.md §16).
// ---------------------------------------------------------------------------

use stp::plan::SearchMode;

#[test]
fn evo_reports_are_byte_deterministic_across_runs_and_threads() {
    let mut q = query_16();
    q.n_mb_options = vec![16, 32];
    q.search = SearchMode::Evo { generations: 4, population: 10, seed: 5 };
    let a = plan(&q).to_json().to_string();
    let b = plan(&q).to_json().to_string();
    assert_eq!(a, b, "same seed, same bytes");
    let mut q1 = q.clone();
    q1.threads = 1;
    let c = plan(&q1).to_json().to_string();
    assert_eq!(a, c, "thread count must only change wall clock");

    // A different seed is a different (valid) search.
    let mut q2 = q.clone();
    q2.search = SearchMode::Evo { generations: 4, population: 10, seed: 6 };
    let r2 = plan(&q2);
    assert!(r2.best().is_some());
    assert_eq!(
        r2.n_enumerated,
        r2.n_rejected_shape + r2.n_pruned_memory + r2.n_pruned_theory + r2.n_simulated()
    );
}

#[test]
fn evo_finds_the_exhaustive_best_on_a_tiny_space() {
    // A space small enough that the seed generation covers every scored
    // candidate: evo's winner can then never rank below the exhaustive
    // winner, and its extra genes (AC, vpp, maps) may only improve it.
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::uniform(HardwareProfile::a800()),
        8,
    );
    q.seq = 2048;
    q.n_mb_options = vec![8, 16];
    q.kinds = vec![ScheduleKind::OneF1B, ScheduleKind::ZbV, ScheduleKind::Stp];
    q.offload_variants = vec![stp::schedule::OffloadParams::default()];
    q.threads = 2;
    let ex = plan(&q);
    let best_ex = ex.best().expect("exhaustive best at 8 GPUs");

    // Seed the whole scored field (everything past shape/memory checks).
    let scored = ex.n_enumerated - ex.n_rejected_shape - ex.n_pruned_memory;
    let mut evo_q = q.clone();
    evo_q.search = SearchMode::Evo { generations: 4, population: scored, seed: 9 };
    let evo = plan(&evo_q);
    let best_evo = evo.best().expect("evo best at 8 GPUs");
    assert!(
        best_evo.throughput + 1e-12 >= best_ex.throughput,
        "evo best {:.4} ({}) below exhaustive best {:.4} ({})",
        best_evo.throughput,
        best_evo.candidate.label(),
        best_ex.throughput,
        best_ex.candidate.label()
    );
    assert_eq!(
        evo.n_enumerated,
        evo.n_rejected_shape + evo.n_pruned_memory + evo.n_pruned_theory + evo.n_simulated()
    );
}

#[test]
fn evo_matches_beam_at_fleet_scale_with_a_fraction_of_the_sims() {
    // The acceptance criterion: on the 128-GPU mixed preset the evo
    // winner's step time is no worse than the beam winner's, while evo
    // simulates at most a quarter of the exhaustive candidate count.
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::mixed_a800_h20_large(),
        128,
    );
    q.seq = 2048;
    q.n_mb_options = vec![16, 32];
    q.threads = 2;
    let mut bq = q.clone();
    bq.search = SearchMode::Beam { width: 8 };
    let mut eq = q.clone();
    eq.search = SearchMode::Evo { generations: 8, population: 16, seed: 42 };

    let beam = plan(&bq);
    let evo = plan(&eq);
    let best_beam = beam.best().expect("beam best at 128 GPUs");
    let best_evo = evo.best().expect("evo best at 128 GPUs");
    assert!(
        best_evo.throughput + 1e-12 >= best_beam.throughput,
        "evo best {:.4} ({}) below beam best {:.4} ({})",
        best_evo.throughput,
        best_evo.candidate.label(),
        best_beam.throughput,
        best_beam.candidate.label()
    );
    // `beam.n_enumerated` is the pure enumerated-space size (evo's own
    // counter additionally includes the genomes it generated).
    assert!(
        evo.n_simulated() * 4 <= beam.n_enumerated,
        "evo simulated {} of an exhaustive space of {}",
        evo.n_simulated(),
        beam.n_enumerated
    );
    assert_eq!(
        evo.n_enumerated,
        evo.n_rejected_shape + evo.n_pruned_memory + evo.n_pruned_theory + evo.n_simulated()
    );
}

#[test]
fn plan_cache_keys_distinguish_evo_budgets() {
    use stp::plan::PlanCache;

    let mut q = query_16();
    q.n_mb_options = vec![16];
    q.search = SearchMode::Evo { generations: 3, population: 8, seed: 7 };
    let cold = plan(&q).to_json().to_string();
    let mut cache = PlanCache::new();
    let first = cache.query(&q);
    assert!(!first.hit);
    assert_eq!(first.json, cold);
    let second = cache.query(&q);
    assert!(second.hit, "identical evo budget must answer from the report store");
    assert_eq!(second.json, cold);

    // A different evo seed is a different canonical key — a fresh search.
    let mut dq = q.clone();
    dq.search = SearchMode::Evo { generations: 3, population: 8, seed: 8 };
    let delta = cache.query(&dq);
    assert!(!delta.hit, "evo params must be part of the canonical key");
    assert_eq!(cache.len(), 2);
}

#[test]
fn folded_and_unfolded_plans_serialize_identically() {
    use stp::sim::SimMode;

    let mut q = query_16();
    q.n_mb_options = vec![16, 32];
    let folded = plan(&q).to_json().to_string();
    q.sim = SimMode::Unfolded;
    let unfolded = plan(&q).to_json().to_string();
    assert_eq!(folded, unfolded, "sim mode must never leak into the report");
}
