//! Elastic-training acceptance (DESIGN.md §12): checkpoint/restore is
//! bit-exact, dead-rank faults trigger replanning onto a shrunk pool,
//! and the fault machinery is invisible when no fault fires.

use stp::cluster::{ClusterSpec, GroupOrder, HardwareProfile, NodeGroup};
use stp::elastic::{run_elastic, Checkpoint, ElasticConfig, FaultPlan, ReplanContext};
use stp::exec::{train, TrainConfig};
use stp::model::ModelConfig;
use stp::plan::{PlanArtifact, PlanModel, PlanQuery};
use stp::schedule::{OffloadParams, ScheduleKind};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("stp-elastic-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn loss_bits(steps: &[stp::exec::StepStat]) -> Vec<(usize, u32)> {
    steps.iter().map(|s| (s.step, s.mean_loss.to_bits())).collect()
}

/// Checkpoint at step 2, restore, train 2 more: every per-step loss must
/// be bit-identical to the uninterrupted 4-step run — for the paper's
/// schedule and the baselines with different grids (ZB-V's vpp=2 V-shape,
/// GPipe's single-chunk pipeline).
#[test]
fn restore_is_bit_identical_to_an_uninterrupted_run() {
    for kind in [ScheduleKind::Stp, ScheduleKind::ZbV, ScheduleKind::GPipe] {
        let mut base = TrainConfig::virtual_default();
        base.schedule = kind;
        base.steps = 4;
        base.seed = 7;

        let uninterrupted = train(&base).unwrap();
        assert_eq!(uninterrupted.steps.len(), 4);

        let dir = tmp_dir(kind.name());
        let mut first = base.clone();
        first.steps = 2;
        first.checkpoint_dir = Some(dir.clone());
        let seg1 = train(&first).unwrap();
        let ckpt_path = seg1.checkpoint_path.clone().expect("segment must snapshot");
        assert!(ckpt_path.ends_with("ckpt-step-2.json"));

        let ck = Checkpoint::load(&dir.join("latest.json")).unwrap();
        assert_eq!(ck.step, 2, "{}: snapshot taken at the wrong cut", kind.name());
        let mut second = base.clone();
        second.steps = 2;
        second.resume = Some(ck);
        let seg2 = train(&second).unwrap();

        let mut stitched = loss_bits(&seg1.steps);
        stitched.extend(loss_bits(&seg2.steps));
        assert_eq!(
            stitched,
            loss_bits(&uninterrupted.steps),
            "{}: restore diverged from the uninterrupted run",
            kind.name()
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// A bounded pool of 4 single-node groups x 2 GPUs, with the tiny model
/// braided at tp2-pp4. Killing stage 1's device mid-run must shrink the
/// pool to 3 groups, re-search onto pp3, migrate the snapshot and run to
/// the original step target with a finite, decreasing loss trajectory.
#[test]
fn dead_rank_replans_onto_the_shrunk_pool_and_finishes() {
    let mut hw = HardwareProfile::a800();
    hw.gpus_per_node = 2;
    let pool = ClusterSpec {
        name: "bounded-4x2".into(),
        groups: (0..4).map(|_| NodeGroup { nodes: 1, hw: hw.clone() }).collect(),
        intergroup_gbps: 0.0,
    };
    let model = PlanModel::Llm(ModelConfig::tiny_100m());
    let mut q = PlanQuery::new(model.clone(), pool.clone(), 8);
    q.seq = 512;
    q.n_mb_options = vec![8];
    q.threads = 2;
    let ctx = q.eval_context();
    let c = stp::plan::Candidate {
        id: 0,
        tp: 2,
        pp: 4,
        dp: 1,
        kind: ScheduleKind::Stp,
        n_mb: 8,
        order: GroupOrder::Declared,
        offload: OffloadParams::default(),
        offload_variant: 0,
    };
    let e = stp::plan::evaluate(&ctx, &c);
    assert!(e.feasible, "tiny model at tp2-pp4 must fit");
    let artifact = PlanArtifact::for_evaluation(&ctx, &e);

    let dir = tmp_dir("replan");
    let mut cfg = TrainConfig::virtual_default();
    cfg.steps = 4;
    cfg.seed = 11;
    cfg.plan = Some(artifact.clone());
    cfg.faults = Some(FaultPlan::dead_rank_at(2, 1));
    cfg.checkpoint_dir = Some(dir.clone());
    let replan = ReplanContext {
        model,
        cluster: pool,
        seq: 512,
        mb_size: 1,
        mem_cap_gib: 0.0,
        beam_width: 4,
    };
    let report = run_elastic(&ElasticConfig { train: cfg, replan: Some(replan) }).unwrap();

    assert_eq!(report.segments.len(), 2, "one fault, two segments");
    assert_eq!(report.replanned.len(), 1, "the loss must trigger exactly one replan");
    let new_plan = &report.replanned[0];
    assert_eq!(new_plan.tp, 2, "TP width is fixed across replans");
    assert_eq!(new_plan.pp, 3, "6 surviving GPUs in 2-GPU groups force pp3");
    assert_eq!(new_plan.n_mb, artifact.n_mb, "global batch is pinned");
    assert_eq!(report.cluster.as_ref().unwrap().groups.len(), 3);

    // Loss trajectory is continuous to the original target and trains.
    let steps: Vec<usize> = report.steps.iter().map(|s| s.step).collect();
    assert_eq!(steps, vec![0, 1, 2, 3], "steps must be contiguous across the replan");
    assert!(report.steps.iter().all(|s| s.mean_loss.is_finite()));
    assert!(
        report.last_loss() < report.first_loss(),
        "loss must keep decreasing across the migration: {} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The fault machinery compiled in but never firing must not perturb a
/// single bit: an empty fault plan trains bit-equal to `faults: None`.
#[test]
fn empty_fault_plan_is_bit_equal_to_no_faults() {
    let mut plain = TrainConfig::virtual_default();
    plain.steps = 3;
    plain.seed = 5;
    let mut armed = plain.clone();
    armed.faults = Some(FaultPlan::none());

    let r1 = train(&plain).unwrap();
    let r2 = train(&armed).unwrap();
    assert_eq!(loss_bits(&r1.steps), loss_bits(&r2.steps));
    assert!(r2.interrupted_at.is_none());
    assert!(r2.checkpoint_path.is_none(), "no checkpoint dir, no snapshot");
}
