//! Elastic-training acceptance (DESIGN.md §12, §14): checkpoint/restore
//! is bit-exact, a dead replica shrinks dp without re-splitting the
//! pipeline, dead-rank faults on the last replica trigger replanning
//! onto a shrunk pool, torn snapshots fall back to a complete one, v1
//! documents upgrade, and the fault machinery is invisible when no
//! fault fires.

use stp::cluster::{ClusterSpec, GroupOrder, HardwareProfile, NodeGroup};
use stp::elastic::{
    run_elastic, shrink_dp_checkpoint, Checkpoint, ElasticConfig, FaultPlan, ReplanContext,
};
use stp::exec::{train, TrainConfig};
use stp::model::ModelConfig;
use stp::plan::{PlanArtifact, PlanModel, PlanQuery};
use stp::schedule::{OffloadParams, ScheduleKind};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("stp-elastic-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn loss_bits(steps: &[stp::exec::StepStat]) -> Vec<(usize, u32)> {
    steps.iter().map(|s| (s.step, s.mean_loss.to_bits())).collect()
}

/// Checkpoint at step 2, restore, train 2 more: every per-step loss must
/// be bit-identical to the uninterrupted 4-step run — for the paper's
/// schedule and the baselines with different grids (ZB-V's vpp=2 V-shape,
/// GPipe's single-chunk pipeline).
#[test]
fn restore_is_bit_identical_to_an_uninterrupted_run() {
    for kind in [ScheduleKind::Stp, ScheduleKind::ZbV, ScheduleKind::GPipe] {
        let mut base = TrainConfig::virtual_default();
        base.schedule = kind;
        base.steps = 4;
        base.seed = 7;

        let uninterrupted = train(&base).unwrap();
        assert_eq!(uninterrupted.steps.len(), 4);

        let dir = tmp_dir(kind.name());
        let mut first = base.clone();
        first.steps = 2;
        first.checkpoint_dir = Some(dir.clone());
        let seg1 = train(&first).unwrap();
        let ckpt_path = seg1.checkpoint_path.clone().expect("segment must snapshot");
        assert!(ckpt_path.ends_with("ckpt-step-2.json"));

        let ck = Checkpoint::load(&dir.join("latest.json")).unwrap();
        assert_eq!(ck.step, 2, "{}: snapshot taken at the wrong cut", kind.name());
        let mut second = base.clone();
        second.steps = 2;
        second.resume = Some(ck);
        let seg2 = train(&second).unwrap();

        let mut stitched = loss_bits(&seg1.steps);
        stitched.extend(loss_bits(&seg2.steps));
        assert_eq!(
            stitched,
            loss_bits(&uninterrupted.steps),
            "{}: restore diverged from the uninterrupted run",
            kind.name()
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// A bounded pool of 4 single-node groups x 2 GPUs, with the tiny model
/// braided at tp2-pp4. Killing stage 1's device mid-run must shrink the
/// pool to 3 groups, re-search onto pp3, migrate the snapshot and run to
/// the original step target with a finite, decreasing loss trajectory.
#[test]
fn dead_rank_replans_onto_the_shrunk_pool_and_finishes() {
    let mut hw = HardwareProfile::a800();
    hw.gpus_per_node = 2;
    let pool = ClusterSpec {
        name: "bounded-4x2".into(),
        groups: (0..4).map(|_| NodeGroup { nodes: 1, hw: hw.clone() }).collect(),
        intergroup_gbps: 0.0,
    };
    let model = PlanModel::Llm(ModelConfig::tiny_100m());
    let mut q = PlanQuery::new(model.clone(), pool.clone(), 8);
    q.seq = 512;
    q.n_mb_options = vec![8];
    q.threads = 2;
    let ctx = q.eval_context();
    let c = stp::plan::Candidate {
        id: 0,
        tp: 2,
        pp: 4,
        dp: 1,
        kind: ScheduleKind::Stp,
        n_mb: 8,
        order: GroupOrder::Declared,
        offload: OffloadParams::default(),
        offload_variant: 0,
        ac: stp::sim::AcMode::None,
        map: None,
        vpp_gene: 0,
    };
    let e = stp::plan::evaluate(&ctx, &c);
    assert!(e.feasible, "tiny model at tp2-pp4 must fit");
    let artifact = PlanArtifact::for_evaluation(&ctx, &e);

    let dir = tmp_dir("replan");
    let mut cfg = TrainConfig::virtual_default();
    cfg.steps = 4;
    cfg.seed = 11;
    cfg.plan = Some(artifact.clone());
    cfg.faults = Some(FaultPlan::dead_rank_at(2, 1));
    cfg.checkpoint_dir = Some(dir.clone());
    let replan = ReplanContext {
        model,
        cluster: pool,
        seq: 512,
        mb_size: 1,
        mem_cap_gib: 0.0,
        beam_width: 4,
    };
    let report = run_elastic(&ElasticConfig { train: cfg, replan: Some(replan) }).unwrap();

    assert_eq!(report.segments.len(), 2, "one fault, two segments");
    assert_eq!(report.replanned.len(), 1, "the loss must trigger exactly one replan");
    let new_plan = &report.replanned[0];
    assert_eq!(new_plan.tp, 2, "TP width is fixed across replans");
    assert_eq!(new_plan.pp, 3, "6 surviving GPUs in 2-GPU groups force pp3");
    assert_eq!(new_plan.n_mb, artifact.n_mb, "global batch is pinned");
    assert_eq!(report.cluster.as_ref().unwrap().groups.len(), 3);

    // Loss trajectory is continuous to the original target and trains.
    let steps: Vec<usize> = report.steps.iter().map(|s| s.step).collect();
    assert_eq!(steps, vec![0, 1, 2, 3], "steps must be contiguous across the replan");
    assert!(report.steps.iter().all(|s| s.mean_loss.is_finite()));
    assert!(
        report.last_loss() < report.first_loss(),
        "loss must keep decreasing across the migration: {} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The tentpole acceptance (DESIGN.md §14): at dp=2, killing replica 1
/// mid-run must quarantine it at the step-2 cut and continue at dp=1
/// with the global batch preserved (4 mb x 2 replicas -> 8 mb x 1) —
/// no pipeline re-split, and the survivors' continuation bit-identical
/// to a fresh dp=1 run seeded from the quarantine-shrunk snapshot.
#[test]
fn dead_replica_shrinks_dp_and_matches_a_fresh_resume_bit_for_bit() {
    let dir = tmp_dir("shrink-dp");
    let mut cfg = TrainConfig::virtual_default();
    cfg.steps = 4;
    cfg.seed = 13;
    cfg.dp = Some(2);
    cfg.faults = Some(FaultPlan::dead_rank_in_replica(2, 0, 1));
    cfg.checkpoint_dir = Some(dir.clone());
    let report = run_elastic(&ElasticConfig { train: cfg, replan: None }).unwrap();

    assert_eq!(report.segments.len(), 2, "one fault, two segments");
    assert!(report.replanned.is_empty(), "a replica loss must not re-split the pipeline");
    assert_eq!(report.recoveries.len(), 1);
    assert!(report.recoveries[0].starts_with("shrink-dp"), "{}", report.recoveries[0]);
    let steps: Vec<usize> = report.steps.iter().map(|s| s.step).collect();
    assert_eq!(steps, vec![0, 1, 2, 3], "steps must be contiguous across the shrink");
    assert!(report.steps.iter().all(|s| s.mean_loss.is_finite()));

    // Reference: shrink the halt snapshot by hand and resume a fresh
    // dp=1 run from it — the elastic continuation must match it bit for
    // bit (replica-identical weights make the shrink a pure re-label).
    let ck = Checkpoint::load(&dir.join("ckpt-step-2.json")).unwrap();
    assert_eq!((ck.dp, ck.n_mb), (2, 4), "halt snapshot must predate the shrink");
    let shrunk = shrink_dp_checkpoint(&ck, 1).unwrap();
    assert_eq!((shrunk.dp, shrunk.n_mb), (1, 8), "global batch must be preserved");
    let mut fresh = TrainConfig::virtual_default();
    fresh.steps = 2;
    fresh.seed = 13;
    fresh.dp = Some(1);
    fresh.n_mb = 8;
    fresh.resume = Some(shrunk);
    let reference = train(&fresh).unwrap();
    assert_eq!(
        loss_bits(&report.steps[2..]),
        loss_bits(&reference.steps),
        "post-recovery losses diverged from the from-scratch dp=1 resume"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Killing replica 0 must shift survivorship to replica 1 (the lowest
/// surviving index becomes the canonical replica 0), and `--keep-checkpoints 1`
/// must prune the halt snapshot once the final one lands, leaving a
/// loadable chain.
#[test]
fn killing_replica_zero_survives_and_retention_prunes_old_snapshots() {
    let dir = tmp_dir("retention");
    let mut cfg = TrainConfig::virtual_default();
    cfg.steps = 4;
    cfg.seed = 29;
    cfg.dp = Some(2);
    cfg.faults = Some(FaultPlan::dead_rank_in_replica(2, 0, 0));
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.keep_checkpoints = Some(1);
    let report = run_elastic(&ElasticConfig { train: cfg, replan: None }).unwrap();
    assert_eq!(report.segments.len(), 2);
    assert!(report.recoveries[0].contains("replica 0 quarantined"), "{}", report.recoveries[0]);
    assert!(report.steps.iter().all(|s| s.mean_loss.is_finite()));

    assert!(!dir.join("ckpt-step-2.json").exists(), "K=1 retention must prune the halt snapshot");
    assert!(dir.join("ckpt-step-4.json").exists());
    let latest = Checkpoint::load_latest(&dir).unwrap();
    assert_eq!((latest.step, latest.dp, latest.n_mb), (4, 1, 8));
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-safety end to end: tear `latest.json` mid-file (as a dirty
/// shutdown would) — `load_latest` must fall back to the complete
/// `ckpt-step-2.json`, and resuming from it must stay bit-identical to
/// the uninterrupted run.
#[test]
fn torn_latest_checkpoint_falls_back_and_resumes_bit_identically() {
    let mut base = TrainConfig::virtual_default();
    base.steps = 4;
    base.seed = 17;
    let uninterrupted = train(&base).unwrap();

    let dir = tmp_dir("torn");
    let mut first = base.clone();
    first.steps = 2;
    first.checkpoint_dir = Some(dir.clone());
    let seg1 = train(&first).unwrap();

    let full = std::fs::read_to_string(dir.join("latest.json")).unwrap();
    std::fs::write(dir.join("latest.json"), &full[..full.len() / 2]).unwrap();
    let ck = Checkpoint::load_latest(&dir).unwrap();
    assert_eq!(ck.step, 2, "the fallback must land on the complete snapshot");

    let mut second = base.clone();
    second.steps = 2;
    second.resume = Some(ck);
    let seg2 = train(&second).unwrap();
    let mut stitched = loss_bits(&seg1.steps);
    stitched.extend(loss_bits(&seg2.steps));
    assert_eq!(stitched, loss_bits(&uninterrupted.steps));
    std::fs::remove_dir_all(&dir).ok();
}

/// Schema migration end to end: demote a real snapshot to the v1 wire
/// format an older binary wrote, load it (upgrading to replica 0 of a
/// dp=1 grid), re-save it as v2, and resume training from it — all
/// bit-identical to the uninterrupted run.
#[test]
fn v1_checkpoints_upgrade_and_resume_bit_identically() {
    use std::collections::BTreeMap;

    use stp::config::Json;

    let mut base = TrainConfig::virtual_default();
    base.steps = 4;
    base.seed = 23;
    let uninterrupted = train(&base).unwrap();

    let dir = tmp_dir("v1-upgrade");
    let mut first = base.clone();
    first.steps = 2;
    first.checkpoint_dir = Some(dir.clone());
    let seg1 = train(&first).unwrap();

    // Strip the DP-era fields and keys: no `dp`, no ViT splits, shards
    // keyed `c{c}r{r}`, RNG streams keyed `s{s}r{r}`.
    let text = std::fs::read_to_string(dir.join("latest.json")).unwrap();
    let Json::Obj(mut root) = Json::parse(&text).unwrap() else { unreachable!() };
    root.insert("schema".into(), Json::Str("stp-ckpt-v1".into()));
    root.remove("dp");
    root.remove("stage_vit_layers");
    let Some(Json::Obj(shards)) = root.remove("shards") else { unreachable!() };
    let mut v1_shards = BTreeMap::new();
    for (key, shard) in shards {
        let Json::Obj(mut o) = shard else { unreachable!() };
        o.remove("replica");
        o.remove("vit_layers");
        v1_shards.insert(key.strip_prefix("d0").unwrap().to_string(), Json::Obj(o));
    }
    root.insert("shards".into(), Json::Obj(v1_shards));
    let Some(Json::Obj(rngs)) = root.remove("rng_states") else { unreachable!() };
    let v1_rngs: BTreeMap<String, Json> =
        rngs.into_iter().map(|(k, x)| (k.strip_prefix("d0").unwrap().to_string(), x)).collect();
    root.insert("rng_states".into(), Json::Obj(v1_rngs));
    let v1_path = dir.join("v1.json");
    std::fs::write(&v1_path, Json::Obj(root).to_string()).unwrap();

    // Load upgrades in place; re-saving always writes v2.
    let ck = Checkpoint::load(&v1_path).unwrap();
    assert_eq!((ck.step, ck.dp), (2, 1));
    let v2_path = dir.join("rewritten.json");
    ck.save(&v2_path).unwrap();
    assert!(std::fs::read_to_string(&v2_path).unwrap().contains("stp-ckpt-v2"));
    assert_eq!(Checkpoint::load(&v2_path).unwrap(), ck);

    let mut second = base.clone();
    second.steps = 2;
    second.resume = Some(ck);
    let seg2 = train(&second).unwrap();
    let mut stitched = loss_bits(&seg1.steps);
    stitched.extend(loss_bits(&seg2.steps));
    assert_eq!(stitched, loss_bits(&uninterrupted.steps));
    std::fs::remove_dir_all(&dir).ok();
}

/// MLLM plans are executable: a hand-built tp1-pp2 artifact with a ViT
/// prefix on chunk 0 trains with a finite loss, snapshots the ViT split
/// and restores bit-identically through the v2 schema.
#[test]
fn mllm_vit_chunk_plan_trains_and_restores_bit_identically() {
    let artifact = PlanArtifact {
        model: "mllm-proxy".into(),
        cluster: "a800-sxm4-80g".into(),
        seq: 512,
        mb_size: 1,
        kind: ScheduleKind::GPipe,
        tp: 1,
        pp: 2,
        dp: 1,
        vpp: 1,
        n_mb: 2,
        order: GroupOrder::Declared,
        offload: OffloadParams::default(),
        ac: stp::sim::AcMode::None,
        stage_layers: vec![2, 2],
        stage_vit_layers: vec![2, 0],
        chunk_scales: vec![1.0, 1.0],
        throughput: 0.0,
    };
    artifact.validate().unwrap();

    let mut base = TrainConfig::virtual_default();
    base.steps = 4;
    base.seed = 31;
    base.plan = Some(artifact);
    let uninterrupted = train(&base).unwrap();
    assert!(uninterrupted.steps.iter().all(|s| s.mean_loss.is_finite()));
    assert!(
        uninterrupted.last_loss() < uninterrupted.first_loss(),
        "the ViT-prefixed proxy must train: {} -> {}",
        uninterrupted.first_loss(),
        uninterrupted.last_loss()
    );

    let dir = tmp_dir("mllm");
    let mut first = base.clone();
    first.steps = 2;
    first.checkpoint_dir = Some(dir.clone());
    let seg1 = train(&first).unwrap();
    let ck = Checkpoint::load(&dir.join("latest.json")).unwrap();
    assert_eq!(ck.stage_vit_layers, vec![2, 0], "the snapshot must carry the ViT split");
    assert_eq!(ck.shard(0, 0, 0).unwrap().vit_layers.len(), 2);

    let mut second = base.clone();
    second.steps = 2;
    second.resume = Some(ck);
    let seg2 = train(&second).unwrap();
    let mut stitched = loss_bits(&seg1.steps);
    stitched.extend(loss_bits(&seg2.steps));
    assert_eq!(stitched, loss_bits(&uninterrupted.steps));
    std::fs::remove_dir_all(&dir).ok();
}

/// The fault machinery compiled in but never firing must not perturb a
/// single bit: an empty fault plan trains bit-equal to `faults: None`.
#[test]
fn empty_fault_plan_is_bit_equal_to_no_faults() {
    let mut plain = TrainConfig::virtual_default();
    plain.steps = 3;
    plain.seed = 5;
    let mut armed = plain.clone();
    armed.faults = Some(FaultPlan::none());

    let r1 = train(&plain).unwrap();
    let r2 = train(&armed).unwrap();
    assert_eq!(loss_bits(&r1.steps), loss_bits(&r2.steps));
    assert!(r2.interrupted_at.is_none());
    assert!(r2.checkpoint_path.is_none(), "no checkpoint dir, no snapshot");
}
