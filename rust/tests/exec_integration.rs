//! Integration over the real executor: pipeline training on the `test`
//! preset artifacts with every schedule must produce identical numerics
//! (same seed, same data ⇒ same losses) and decreasing loss.
//!
//! Needs the `pjrt` feature (and real xla bindings + artifacts).
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use stp::exec::{train, BackendKind, KernelPath, TrainConfig};
use stp::schedule::ScheduleKind;

fn have_artifacts() -> bool {
    Path::new("artifacts/test/manifest.json").exists()
}

fn cfg(kind: ScheduleKind, steps: usize) -> TrainConfig {
    TrainConfig {
        backend: BackendKind::Pjrt,
        kernels: KernelPath::Blocked,
        artifacts_dir: PathBuf::from("artifacts/test"),
        schedule: kind,
        n_mb: 4,
        steps,
        lr: 0.3,
        seed: 42,
        verbose: false,
        dims: None,
        virtual_scale: 1.0,
        plan: None,
        faults: None,
        checkpoint_dir: None,
        resume: None,
        workers: 0,
    }
}

#[test]
fn stp_training_reduces_loss() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let r = train(&cfg(ScheduleKind::Stp, 6)).unwrap();
    assert_eq!(r.steps.len(), 6);
    // Starts at ln(V) for the miniature vocab (V=256 ⇒ ≈5.545).
    assert!((r.first_loss() - 5.545).abs() < 0.05, "first loss {}", r.first_loss());
    assert!(r.last_loss() < r.first_loss(), "{} -> {}", r.first_loss(), r.last_loss());
    assert!(r.allreduce_bytes > 0, "TP all-reduce must actually run");
    assert!(r.executions > 0);
}

#[test]
fn all_schedules_compute_identical_losses() {
    // The decisive numerics test: every schedule is a different *order*
    // of the same computation, so per-step mean losses must agree to
    // floating-point reassociation tolerance across schedules.
    if !have_artifacts() {
        return;
    }
    let kinds = [
        ScheduleKind::GPipe,
        ScheduleKind::OneF1BInterleaved,
        ScheduleKind::ZbV,
        ScheduleKind::Stp,
        ScheduleKind::StpOffload,
    ];
    let mut baseline: Option<Vec<f32>> = None;
    for kind in kinds {
        let r = train(&cfg(kind, 3)).unwrap();
        let losses: Vec<f32> = r.steps.iter().map(|s| s.mean_loss).collect();
        match &baseline {
            None => baseline = Some(losses),
            Some(base) => {
                for (i, (a, b)) in base.iter().zip(&losses).enumerate() {
                    assert!(
                        (a - b).abs() < 2e-3 * a.abs().max(1.0),
                        "{kind:?} step {i}: loss {b} != baseline {a}"
                    );
                }
            }
        }
    }
}

#[test]
fn deterministic_across_runs() {
    if !have_artifacts() {
        return;
    }
    let a = train(&cfg(ScheduleKind::Stp, 2)).unwrap();
    let b = train(&cfg(ScheduleKind::Stp, 2)).unwrap();
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert!((x.mean_loss - y.mean_loss).abs() < 1e-6);
    }
}

#[test]
fn offload_variant_trains_and_uses_arena() {
    if !have_artifacts() {
        return;
    }
    let r = train(&cfg(ScheduleKind::StpOffload, 2)).unwrap();
    assert!(r.last_loss().is_finite());
}
