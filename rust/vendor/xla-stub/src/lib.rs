//! Compile-time stub of the `xla` (xla_extension 0.5.1) API surface used
//! by `stp::runtime`. Host-side literal handling works for real; client
//! construction, compilation and execution fail with a descriptive error
//! (there is no libpjrt in this environment). See Cargo.toml.

use anyhow::{anyhow, bail, ensure, Result};

const UNAVAILABLE: &str =
    "PJRT is unavailable: the `pjrt` feature was built against the in-tree \
     xla stub (rust/vendor/xla-stub); link the real xla_extension bindings \
     to execute artifacts";

/// XLA element types (subset + padding variants so consumer matches have a
/// reachable wildcard arm).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host scalar types a [`Literal`] can hold.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_f64(self) -> f64;
    fn from_f64(x: f64) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(x: f64) -> Self {
        x as f32
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(x: f64) -> Self {
        x as i32
    }
}

/// A host-side array (or tuple) literal.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    data: Vec<f64>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            data: data.iter().map(|x| x.to_f64()).collect(),
            dims: vec![data.len() as i64],
            tuple: None,
        }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        ensure!(
            n as usize == self.data.len(),
            "reshape to {:?} ({} elements) from {} elements",
            dims,
            n,
            self.data.len()
        );
        self.dims = dims.to_vec();
        Ok(self)
    }

    /// Array shape (dims + element type).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        ensure!(self.tuple.is_none(), "array_shape of a tuple literal");
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty })
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        ensure!(
            self.ty == T::TY,
            "literal holds {:?}, requested {:?}",
            self.ty,
            T::TY
        );
        Ok(self.data.iter().map(|&x| T::from_f64(x)).collect())
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(parts) => Ok(parts.clone()),
            None => bail!("to_tuple on a non-tuple literal"),
        }
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// HLO module handle (stub: never constructible from files here).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(anyhow!(UNAVAILABLE))
    }
}

/// Computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stub: construction fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(anyhow!(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Compiled executable (stub: never produced, execution errors defensively).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(anyhow!(UNAVAILABLE))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(anyhow!(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = l.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn client_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
