//! Minimal, API-compatible subset of the `anyhow` crate for this offline
//! build (see Cargo.toml). Provides [`Error`], [`Result`], and the
//! `anyhow!` / `bail!` / `ensure!` macros with the semantics the `stp`
//! crate relies on:
//!
//! * `Error` is a cheap string-backed error that optionally wraps a
//!   source error (preserved for `{:#}` chains).
//! * `Result<T>` defaults the error type to [`Error`].
//! * `?` works on `std::io::Error` and the common std parse errors.
//!
//! Like real `anyhow::Error`, this type deliberately does **not**
//! implement `std::error::Error` (that would conflict with the generic
//! conversions).

use std::fmt;

/// A string-backed error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message (what `anyhow!` emits).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete error, preserving it as the source.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Attach context, demoting the current error to the source position
    /// of the chain (mirrors `anyhow::Context` for the owned case).
    pub fn context<M: fmt::Display>(self, message: M) -> Error {
        Error { msg: format!("{message}: {}", self.msg), source: self.source }
    }

    /// The root-cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut next = self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            // `{:#}` prints the whole cause chain, anyhow-style.
            let mut seen = self.msg.clone();
            for cause in self.chain() {
                let c = cause.to_string();
                if c != seen {
                    write!(f, ": {c}")?;
                    seen = c;
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::new(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::new(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::new(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::new(e)
    }
}

impl From<std::str::Utf8Error> for Error {
    fn from(e: std::str::Utf8Error) -> Error {
        Error::new(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

/// Construct an [`Error`] from a format string (`anyhow!("bad {x}")`),
/// or from any `Display` expression (`anyhow!(err)`), mirroring the real
/// crate's arms.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn helper(fail: bool) -> Result<u32> {
        ensure!(!fail, "flagged failure {}", 42);
        Ok(7)
    }

    #[test]
    fn macros_and_result() {
        assert_eq!(helper(false).unwrap(), 7);
        let e = helper(true).unwrap_err();
        assert_eq!(e.to_string(), "flagged failure 42");
    }

    #[test]
    fn io_error_converts_and_chains() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        let e = read().unwrap_err();
        assert!(e.chain().next().is_some());
        // `{:#}` includes the chain without panicking.
        let _ = format!("{e:#}");
    }

    #[test]
    fn bail_formats() {
        fn f() -> Result<()> {
            bail!("x = {}", 3);
        }
        assert_eq!(f().unwrap_err().to_string(), "x = 3");
    }
}
