//! Schedule timeline rendering: Chrome-trace JSON and ASCII timelines.
//!
//! Regenerates the paper's schedule figures (Fig. 5 — the STP timeline;
//! Fig. 12 — the side-by-side schedule comparison; Fig. 4/6 — dataflow
//! and offload illustrations) from simulated [`SimReport`] events.

use crate::config::json::Json;
use crate::schedule::Op;
use crate::sim::SimReport;

use std::collections::BTreeMap;

/// Short label for an op (the paper's F/B/W vocabulary).
pub fn op_label(op: &Op) -> String {
    match *op {
        Op::Pass { kind, chunk, mb } => {
            let k = match kind {
                crate::schedule::PassKind::F => "F",
                crate::schedule::PassKind::B => "B",
                crate::schedule::PassKind::W => "W",
                crate::schedule::PassKind::BFull => "B+W",
            };
            format!("{k} c{chunk} m{mb}")
        }
        Op::Braided { f_chunk, f_mb, b_chunk, b_mb, b_full } => {
            let tail = if b_full { "" } else { " (sep W)" };
            format!("F&B c{f_chunk}m{f_mb}/c{b_chunk}m{b_mb}{tail}")
        }
        Op::BraidedFW { f_chunk, f_mb, w_chunk, w_mb } => {
            format!("F&W c{f_chunk}m{f_mb}/c{w_chunk}m{w_mb}")
        }
        Op::Offload { chunk, mb, ratio } => format!("offload c{chunk}m{mb} α={ratio}"),
        Op::Reload { chunk, mb } => format!("reload c{chunk}m{mb}"),
    }
}

fn op_category(op: &Op) -> &'static str {
    match op {
        Op::Pass { kind: crate::schedule::PassKind::F, .. } => "forward",
        Op::Pass { kind: crate::schedule::PassKind::B, .. } => "backward",
        Op::Pass { kind: crate::schedule::PassKind::W, .. } => "weight",
        Op::Pass { kind: crate::schedule::PassKind::BFull, .. } => "backward",
        Op::Braided { .. } => "braided",
        Op::BraidedFW { .. } => "braided",
        Op::Offload { .. } | Op::Reload { .. } => "pcie",
    }
}

/// Chrome `about:tracing` / Perfetto JSON for a simulated iteration.
/// Each device row is named after its hardware profile
/// ("dev0 a800-sxm4-80g") so mixed-pool timelines stay readable.
pub fn chrome_trace(report: &SimReport) -> String {
    let mut events = Vec::new();
    for (d, dev) in report.devices.iter().enumerate() {
        let mut args = BTreeMap::new();
        args.insert("name".into(), Json::Str(format!("dev{d} {}", dev.hw_name)));
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::Str("thread_name".into()));
        obj.insert("ph".into(), Json::Str("M".into()));
        obj.insert("pid".into(), Json::Num(0.0));
        obj.insert("tid".into(), Json::Num(d as f64));
        obj.insert("args".into(), Json::Obj(args));
        events.push(Json::Obj(obj));
    }
    for e in &report.events {
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Json::Str(op_label(&e.op)));
        obj.insert("cat".into(), Json::Str(op_category(&e.op).into()));
        obj.insert("ph".into(), Json::Str("X".into()));
        obj.insert("ts".into(), Json::Num(e.start * 1e6));
        obj.insert("dur".into(), Json::Num((e.end - e.start) * 1e6));
        obj.insert("pid".into(), Json::Num(0.0));
        obj.insert("tid".into(), Json::Num(e.device as f64));
        events.push(Json::Obj(obj));
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert(
        "displayTimeUnit".into(),
        Json::Str("ms".into()),
    );
    Json::Obj(root).to_string()
}

/// Write a Chrome trace for `report` into `dir` (created if missing) as
/// `stp-trace-<label>.json`; returns the path. Shared by
/// `examples/schedule_explorer.rs` and the auto-planner's top-k dumps.
pub fn write_chrome_trace(
    dir: &std::path::Path,
    label: &str,
    report: &SimReport,
) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("stp-trace-{label}.json"));
    std::fs::write(&path, chrome_trace(report))?;
    Ok(path)
}

/// ASCII timeline: one row per device, `width` columns spanning the
/// iteration. Braided blocks render as '#', F as 'f', full backward 'b',
/// decoupled B as 'x', W as 'w' — the visual shape of paper Fig. 5/12.
pub fn ascii_timeline(report: &SimReport, width: usize) -> String {
    let n_dev = report.devices.len();
    let total = report.iteration_secs.max(1e-12);
    let mut rows = vec![vec!['.'; width]; n_dev];
    for e in &report.events {
        let c = match e.op {
            Op::Pass { kind: crate::schedule::PassKind::F, .. } => 'f',
            Op::Pass { kind: crate::schedule::PassKind::B, .. } => 'x',
            Op::Pass { kind: crate::schedule::PassKind::BFull, .. } => 'b',
            Op::Pass { kind: crate::schedule::PassKind::W, .. } => 'w',
            Op::Braided { .. } => '#',
            Op::BraidedFW { .. } => '@',
            Op::Offload { .. } | Op::Reload { .. } => continue,
        };
        let a = ((e.start / total) * width as f64) as usize;
        let b = (((e.end / total) * width as f64).ceil() as usize).min(width);
        for col in a..b.max(a + 1).min(width) {
            rows[e.device][col] = c;
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{} | p={} m={} | iter {:.3}s | f=F b=B+W x=B w=W #=F&B @=F&W\n",
        report.kind.name(),
        n_dev,
        report.n_mb,
        report.iteration_secs
    ));
    // Tag rows with the profile only when the pool is actually mixed.
    let mixed = report.devices.windows(2).any(|w| w[0].hw_name != w[1].hw_name);
    for (d, row) in rows.iter().enumerate() {
        if mixed {
            out.push_str(&format!("dev{d}[{}] |", report.devices[d].hw_name));
        } else {
            out.push_str(&format!("dev{d} |"));
        }
        out.extend(row.iter());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, HardwareProfile, Topology};
    use crate::model::ModelConfig;
    use crate::schedule::{build_schedule, ScheduleKind};
    use crate::sim::{CostModel, Simulator};

    fn report() -> SimReport {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(2, 2, 1);
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        let cost = CostModel::analytic(&m, &topo, &cluster, 1024, 1);
        let s = build_schedule(ScheduleKind::Stp, &topo, 6);
        Simulator::new(&cost).run(&s)
    }

    #[test]
    fn chrome_trace_is_valid_json_and_names_devices() {
        let r = report();
        let t = chrome_trace(&r);
        let v = Json::parse(&t).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // One thread_name metadata event per device, then the op events.
        assert_eq!(events.len(), r.devices.len() + r.events.len());
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("M"));
        assert!(t.contains("dev0 a800-sxm4-80g"));
        assert!(events[r.devices.len()].get("ts").is_some());
    }

    #[test]
    fn ascii_timeline_has_device_rows() {
        let r = report();
        let t = ascii_timeline(&r, 80);
        assert_eq!(t.lines().count(), 1 + r.devices.len());
        assert!(t.contains('#'), "braids should appear:\n{t}");
    }

    #[test]
    fn labels_cover_all_ops() {
        assert!(op_label(&Op::f(1, 2)).contains("F c1 m2"));
        assert!(op_label(&Op::Braided { f_chunk: 0, f_mb: 3, b_chunk: 1, b_mb: 2, b_full: false })
            .contains("sep W"));
    }

    #[test]
    fn write_chrome_trace_creates_parseable_file() {
        let r = report();
        let dir = std::env::temp_dir().join("stp-trace-test");
        let path = write_chrome_trace(&dir, "unit", &r).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(path).ok();
    }
}
