//! Schedule intermediate representation.
//!
//! Every scheduler (GPipe, 1F1B, 1F1B-I, ZB-V, STP and its variants) emits
//! the same IR: an ordered list of [`Op`]s per PP device. The discrete-event
//! simulator ([`crate::sim`]), the real multi-threaded executor
//! ([`crate::exec`]), the legality validator ([`super::validate`]) and the
//! timeline tracer ([`crate::trace`]) all consume this one representation —
//! that is what makes baselines, variants and property tests cheap
//! (DESIGN.md §6.1).
//!
//! Communication is *implicit*: cross-stage dependencies (`F(c,m)` needs
//! `F(c-1,m)`, `B(c,m)` needs `B(c+1,m)`) are derived from the chunk
//! placement; consumers charge P2P transfer cost on those edges. TP
//! All-Reduce is a property of the op (every F carries a forward AR, every
//! B an activation-backward AR) whose *exposure* is determined by the op
//! shape: braided blocks hide it, full backwards hide the backward AR under
//! `W`, bare `F`/`B` expose it. This single rule is the paper's Table 1.


use crate::cluster::Topology;

/// Which pass a plain op performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PassKind {
    /// Forward pass of one chunk for one microbatch.
    F,
    /// Activation-gradient backward only (Zero-Bubble decoupling): the
    /// weight-gradient is deferred to a separate [`PassKind::W`] op.
    B,
    /// Deferred weight-gradient computation.
    W,
    /// Full backward (B and W fused) — the classic 1F1B/GPipe backward.
    /// Its backward All-Reduce overlaps naturally with the W part
    /// (paper Fig. 3a, blue blocks).
    BFull,
}

/// One scheduled item on a device's compute stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// A plain (non-braided) pass.
    Pass { kind: PassKind, chunk: usize, mb: usize },
    /// A **braided execution block** (paper §3, Fig. 3): the forward units
    /// of `(f_chunk, f_mb)` interleaved with the backward units of
    /// `(b_chunk, b_mb)` so each stream's All-Reduce overlaps the other
    /// stream's compute. `b_full` selects Fig. 3a (backward includes weight
    /// grad) vs Fig. 3b (weight grads separated out as later `W` ops).
    Braided { f_chunk: usize, f_mb: usize, b_chunk: usize, b_mb: usize, b_full: bool },
    /// A forward braided with a *stored* weight-gradient computation
    /// (the warm-up phase's F&W blocks).
    BraidedFW { f_chunk: usize, f_mb: usize, w_chunk: usize, w_mb: usize },
    /// Offload a fraction of `(chunk, mb)`'s activations to host, in
    /// parallel with subsequent compute (enhanced variant, §4.4).
    /// `ratio` is the paper's α in [0,1].
    Offload { chunk: usize, mb: usize, ratio: f32 },
    /// Reload previously offloaded activations (must complete before the
    /// corresponding backward).
    Reload { chunk: usize, mb: usize },
}

impl Op {
    pub fn f(chunk: usize, mb: usize) -> Op {
        Op::Pass { kind: PassKind::F, chunk, mb }
    }
    pub fn b(chunk: usize, mb: usize) -> Op {
        Op::Pass { kind: PassKind::B, chunk, mb }
    }
    pub fn w(chunk: usize, mb: usize) -> Op {
        Op::Pass { kind: PassKind::W, chunk, mb }
    }
    pub fn b_full(chunk: usize, mb: usize) -> Op {
        Op::Pass { kind: PassKind::BFull, chunk, mb }
    }

    /// The forward work this op performs, if any: `(chunk, mb)`.
    pub fn forward_part(&self) -> Option<(usize, usize)> {
        match *self {
            Op::Pass { kind: PassKind::F, chunk, mb } => Some((chunk, mb)),
            Op::Braided { f_chunk, f_mb, .. } => Some((f_chunk, f_mb)),
            Op::BraidedFW { f_chunk, f_mb, .. } => Some((f_chunk, f_mb)),
            _ => None,
        }
    }

    /// The activation-backward work this op performs, if any.
    pub fn backward_part(&self) -> Option<(usize, usize)> {
        match *self {
            Op::Pass { kind: PassKind::B | PassKind::BFull, chunk, mb } => Some((chunk, mb)),
            Op::Braided { b_chunk, b_mb, .. } => Some((b_chunk, b_mb)),
            _ => None,
        }
    }

    /// The weight-gradient work this op performs, if any.
    pub fn weight_part(&self) -> Option<(usize, usize)> {
        match *self {
            Op::Pass { kind: PassKind::W | PassKind::BFull, chunk, mb } => Some((chunk, mb)),
            Op::Braided { b_chunk, b_mb, b_full: true, .. } => Some((b_chunk, b_mb)),
            Op::BraidedFW { w_chunk, w_mb, .. } => Some((w_chunk, w_mb)),
            _ => None,
        }
    }

    /// Whether this op hides its forward All-Reduce (braided blocks do).
    pub fn fwd_ar_overlapped(&self) -> bool {
        matches!(self, Op::Braided { .. } | Op::BraidedFW { .. })
    }

    /// Whether this op hides its activation-backward All-Reduce: braided
    /// blocks hide it under forward compute; full backwards hide it under
    /// the fused weight-gradient compute.
    pub fn bwd_ar_overlapped(&self) -> bool {
        matches!(
            self,
            Op::Braided { .. } | Op::Pass { kind: PassKind::BFull, .. }
        )
    }
}

/// Chunk → device placement strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Megatron interleaving: chunk `c` on device `c % pp` (parallel flow).
    Interleaved,
    /// "V"-shape: chunk path descends then ascends the device grid
    /// (paper §4.1; used by ZB-V and STP).
    VShape,
}

impl Placement {
    pub fn device_of(&self, chunk: usize, topo: &Topology) -> usize {
        match self {
            Placement::Interleaved => topo.interleaved_device(chunk),
            Placement::VShape => topo.v_shape_device(chunk),
        }
    }
}

/// Which scheduling algorithm produced a schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    GPipe,
    OneF1B,
    /// Interleaved 1F1B (Megatron-LM) — paper baseline (a).
    OneF1BInterleaved,
    /// Zero Bubble V — paper baseline (b).
    ZbV,
    /// Zero Bubble H1 (ablation baseline).
    ZbH1,
    /// The paper's synergistic schedule.
    Stp,
    /// STP with the memory-efficient warm-up (appendix Fig. 11(b)/12(d)).
    StpMemEff,
    /// STP enhanced variant with activation offloading (§4.4).
    StpOffload,
}

impl ScheduleKind {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::OneF1B => "1f1b",
            ScheduleKind::OneF1BInterleaved => "1f1b-i",
            ScheduleKind::ZbV => "zb-v",
            ScheduleKind::ZbH1 => "zb-h1",
            ScheduleKind::Stp => "stp",
            ScheduleKind::StpMemEff => "stp-memeff",
            ScheduleKind::StpOffload => "stp-offload",
        }
    }

    pub fn all() -> [ScheduleKind; 8] {
        [
            ScheduleKind::GPipe,
            ScheduleKind::OneF1B,
            ScheduleKind::OneF1BInterleaved,
            ScheduleKind::ZbV,
            ScheduleKind::ZbH1,
            ScheduleKind::Stp,
            ScheduleKind::StpMemEff,
            ScheduleKind::StpOffload,
        ]
    }

    /// The paper's three compared schedules (Figures 7–10, Tables 3–8).
    pub fn paper_trio() -> [ScheduleKind; 3] {
        [ScheduleKind::OneF1BInterleaved, ScheduleKind::ZbV, ScheduleKind::Stp]
    }

    /// Chunk→device placement this kind's builder emits (must match the
    /// generators — the per-device cost attribution on heterogeneous
    /// clusters relies on it).
    pub fn placement(&self) -> Placement {
        match self {
            ScheduleKind::GPipe
            | ScheduleKind::OneF1B
            | ScheduleKind::OneF1BInterleaved
            | ScheduleKind::ZbH1 => Placement::Interleaved,
            ScheduleKind::ZbV
            | ScheduleKind::Stp
            | ScheduleKind::StpMemEff
            | ScheduleKind::StpOffload => Placement::VShape,
        }
    }
}

impl std::str::FromStr for ScheduleKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gpipe" => Ok(ScheduleKind::GPipe),
            "1f1b" => Ok(ScheduleKind::OneF1B),
            "1f1b-i" | "1f1bi" | "interleaved" => Ok(ScheduleKind::OneF1BInterleaved),
            "zb-v" | "zbv" => Ok(ScheduleKind::ZbV),
            "zb-h1" | "zbh1" => Ok(ScheduleKind::ZbH1),
            "stp" | "ours" => Ok(ScheduleKind::Stp),
            "stp-memeff" | "memeff" => Ok(ScheduleKind::StpMemEff),
            "stp-offload" | "offload" | "ours*" => Ok(ScheduleKind::StpOffload),
            other => Err(format!("unknown schedule '{other}'")),
        }
    }
}

/// A complete schedule: per-device op lists plus the metadata consumers
/// need to derive dependencies.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub topo: Topology,
    /// Number of microbatches per iteration.
    pub n_mb: usize,
    pub placement: Placement,
    /// `devices[d]` = ordered ops for PP rank `d`.
    pub devices: Vec<Vec<Op>>,
}

impl Schedule {
    /// Total chunks (virtual stages).
    pub fn n_chunks(&self) -> usize {
        self.topo.chunks()
    }

    /// Device owning a chunk under this schedule's placement.
    pub fn device_of(&self, chunk: usize) -> usize {
        self.placement.device_of(chunk, &self.topo)
    }

    /// Total op count across devices.
    pub fn num_ops(&self) -> usize {
        self.devices.iter().map(|d| d.len()).sum()
    }

    /// Iterate all ops with their device.
    pub fn iter_ops(&self) -> impl Iterator<Item = (usize, &Op)> + '_ {
        self.devices.iter().enumerate().flat_map(|(d, ops)| ops.iter().map(move |op| (d, op)))
    }

    /// Count of forward passes scheduled (including braided forwards).
    pub fn count_forwards(&self) -> usize {
        self.iter_ops().filter(|(_, op)| op.forward_part().is_some()).count()
    }

    /// Count of activation-backward passes scheduled.
    pub fn count_backwards(&self) -> usize {
        self.iter_ops().filter(|(_, op)| op.backward_part().is_some()).count()
    }

    /// Count of weight-gradient computations scheduled.
    pub fn count_weight_grads(&self) -> usize {
        self.iter_ops().filter(|(_, op)| op.weight_part().is_some()).count()
    }

    /// Number of *exposed* forward All-Reduce instances (non-braided F ops).
    pub fn exposed_fwd_ars(&self) -> usize {
        self.iter_ops()
            .filter(|(_, op)| op.forward_part().is_some() && !op.fwd_ar_overlapped())
            .count()
    }

    /// Number of exposed activation-backward All-Reduce instances.
    pub fn exposed_bwd_ars(&self) -> usize {
        self.iter_ops()
            .filter(|(_, op)| op.backward_part().is_some() && !op.bwd_ar_overlapped())
            .count()
    }
}

/// Sentinel for "no op produces this (chunk, mb) slot" in the
/// [`CompiledSchedule`] producer tables.
pub const NO_OP: u32 = u32::MAX;

/// A schedule lowered to flat index arrays for the event-driven simulator
/// (`sim::Simulator`): every op gets a dense id (device-major, program
/// order preserved), every cross-chunk F/B edge is resolved to a static
/// producer id, and each op carries its *dependency count* — the number
/// of completions (program-order predecessor + cross-chunk producers)
/// that must land before the op may start. Replay is then a single
/// ready-queue pass in O(ops) instead of round-robin polling.
///
/// When every `(chunk, mb)` forward/backward has exactly one producing
/// op (which every builder guarantees and `validate` checks), consumers
/// are resolved through the producer tables directly. Schedules with
/// **duplicate producers** (recomputation-style hand-built shapes) are
/// still replayed natively: compilation records the violation in
/// [`CompiledSchedule::unique_producers`] and additionally builds
/// per-slot **consumer lists** (CSR over the `(chunk, mb)` slots), so
/// the replay can count dependencies per *edge* — the first completion
/// of any producer of a slot releases that slot's consumers exactly
/// once, mirroring the polling oracle's "ready as soon as some producer
/// has finished" rule.
#[derive(Debug, Clone, Default)]
pub struct CompiledSchedule {
    pub n_chunks: usize,
    pub n_mb: usize,
    /// Flat op array: device 0's program, then device 1's, …
    pub ops: Vec<Op>,
    /// Device (PP rank) executing each flat op.
    pub op_dev: Vec<u32>,
    /// Per-device start offsets into `ops` (length `n_dev + 1`).
    pub dev_start: Vec<u32>,
    /// `(chunk * n_mb + mb)` → id of the op producing that forward part
    /// ([`NO_OP`] when the schedule has none).
    pub f_producer: Vec<u32>,
    /// Same for the activation-backward part.
    pub b_producer: Vec<u32>,
    /// Static dependency count per op. An op whose producer is missing
    /// keeps an undecrementable dependency and is reported as a deadlock,
    /// exactly like the polling replay's never-ready op.
    pub base_deps: Vec<u32>,
    /// Chunk → executing device under the schedule's placement.
    pub chunk_dev: Vec<u32>,
    /// False when some `(chunk, mb)` forward/backward has more than one
    /// producing op. The producer tables then keep only the last writer,
    /// so the replay must resolve consumers through the CSR consumer
    /// lists below instead.
    pub unique_producers: bool,
    /// CSR consumer lists, built only for duplicate-producer schedules
    /// (empty otherwise): `f_cons[f_cons_start[s]..f_cons_start[s+1]]`
    /// are the ops consuming the *forward* of slot `s` (the next chunk's
    /// forward and the slot's own backward); `b_cons*` likewise for ops
    /// consuming the slot's *backward* (the previous chunk's backward).
    pub f_cons_start: Vec<u32>,
    pub f_cons: Vec<u32>,
    pub b_cons_start: Vec<u32>,
    pub b_cons: Vec<u32>,
}

impl CompiledSchedule {
    /// Number of devices.
    pub fn n_dev(&self) -> usize {
        self.dev_start.len().saturating_sub(1)
    }

    /// Flat slot index of `(chunk, mb)`.
    #[inline]
    pub fn slot(&self, chunk: usize, mb: usize) -> usize {
        chunk * self.n_mb + mb
    }

    /// Ops consuming the forward of `slot` (duplicate-producer schedules
    /// only; empty for unique-producer compilations).
    #[inline]
    pub fn f_consumers(&self, slot: usize) -> &[u32] {
        if self.f_cons_start.is_empty() {
            return &[];
        }
        &self.f_cons[self.f_cons_start[slot] as usize..self.f_cons_start[slot + 1] as usize]
    }

    /// Ops consuming the backward of `slot` (see [`Self::f_consumers`]).
    #[inline]
    pub fn b_consumers(&self, slot: usize) -> &[u32] {
        if self.b_cons_start.is_empty() {
            return &[];
        }
        &self.b_cons[self.b_cons_start[slot] as usize..self.b_cons_start[slot + 1] as usize]
    }

    /// Recompile in place, reusing every buffer (the planner compiles one
    /// schedule per candidate; this keeps that loop allocation-free once
    /// the buffers have grown to the working size).
    pub fn compile_from(&mut self, s: &Schedule) {
        let n_chunks = s.n_chunks();
        let n_mb = s.n_mb;
        let n_dev = s.devices.len();
        let total = s.num_ops();
        self.n_chunks = n_chunks;
        self.n_mb = n_mb;

        self.ops.clear();
        self.op_dev.clear();
        self.dev_start.clear();
        self.ops.reserve(total);
        self.op_dev.reserve(total);
        self.dev_start.reserve(n_dev + 1);
        let slots = n_chunks * n_mb;
        self.f_producer.clear();
        self.f_producer.resize(slots, NO_OP);
        self.b_producer.clear();
        self.b_producer.resize(slots, NO_OP);
        self.chunk_dev.clear();
        self.chunk_dev.extend((0..n_chunks).map(|c| s.device_of(c) as u32));

        // Pass 1: flatten and index the producers.
        self.unique_producers = true;
        for (d, ops) in s.devices.iter().enumerate() {
            self.dev_start.push(self.ops.len() as u32);
            for op in ops {
                let id = self.ops.len() as u32;
                if let Some((c, m)) = op.forward_part() {
                    let slot = &mut self.f_producer[c * n_mb + m];
                    self.unique_producers &= *slot == NO_OP;
                    *slot = id;
                }
                if let Some((c, m)) = op.backward_part() {
                    let slot = &mut self.b_producer[c * n_mb + m];
                    self.unique_producers &= *slot == NO_OP;
                    *slot = id;
                }
                self.ops.push(*op);
                self.op_dev.push(d as u32);
            }
        }
        self.dev_start.push(self.ops.len() as u32);

        // Pass 2: count each op's static dependencies. These mirror the
        // polling replay's readiness rules exactly: F(c,m) waits on
        // F(c-1,m); B(c,m) waits on its own F(c,m) and on B(c+1,m);
        // braided ops combine the rules of their two halves; W, Offload
        // and Reload wait only on program order.
        self.base_deps.clear();
        self.base_deps.reserve(total);
        for (j, op) in self.ops.iter().enumerate() {
            let d = self.op_dev[j] as usize;
            let mut deps = u32::from(j as u32 > self.dev_start[d]);
            if let Some((c, _)) = op.forward_part() {
                if c > 0 {
                    deps += 1;
                }
            }
            if let Some((c, _)) = op.backward_part() {
                deps += 1; // own forward
                if c + 1 < n_chunks {
                    deps += 1;
                }
            }
            self.base_deps.push(deps);
        }

        // Pass 3 (duplicate producers only): CSR consumer lists, one entry
        // per dependency edge counted above, so the replay can release a
        // slot's consumers on the *first* producer completion.
        self.f_cons_start.clear();
        self.b_cons_start.clear();
        self.f_cons.clear();
        self.b_cons.clear();
        if !self.unique_producers {
            self.f_cons_start.resize(slots + 1, 0);
            self.b_cons_start.resize(slots + 1, 0);
            for op in &self.ops {
                if let Some((c, m)) = op.forward_part() {
                    if c > 0 {
                        self.f_cons_start[(c - 1) * n_mb + m + 1] += 1;
                    }
                }
                if let Some((c, m)) = op.backward_part() {
                    self.f_cons_start[c * n_mb + m + 1] += 1;
                    if c + 1 < n_chunks {
                        self.b_cons_start[(c + 1) * n_mb + m + 1] += 1;
                    }
                }
            }
            for s in 0..slots {
                self.f_cons_start[s + 1] += self.f_cons_start[s];
                self.b_cons_start[s + 1] += self.b_cons_start[s];
            }
            self.f_cons.resize(self.f_cons_start[slots] as usize, 0);
            self.b_cons.resize(self.b_cons_start[slots] as usize, 0);
            let mut f_cur: Vec<u32> = self.f_cons_start[..slots].to_vec();
            let mut b_cur: Vec<u32> = self.b_cons_start[..slots].to_vec();
            for (j, op) in self.ops.iter().enumerate() {
                if let Some((c, m)) = op.forward_part() {
                    if c > 0 {
                        let s = (c - 1) * n_mb + m;
                        self.f_cons[f_cur[s] as usize] = j as u32;
                        f_cur[s] += 1;
                    }
                }
                if let Some((c, m)) = op.backward_part() {
                    let s = c * n_mb + m;
                    self.f_cons[f_cur[s] as usize] = j as u32;
                    f_cur[s] += 1;
                    if c + 1 < n_chunks {
                        let s = (c + 1) * n_mb + m;
                        self.b_cons[b_cur[s] as usize] = j as u32;
                        b_cur[s] += 1;
                    }
                }
            }
        }
    }
}

impl Schedule {
    /// Lower this schedule to the flat dependency-counted form consumed
    /// by the event-driven simulator.
    pub fn compile(&self) -> CompiledSchedule {
        let mut c = CompiledSchedule::default();
        c.compile_from(self);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_parts() {
        let f = Op::f(1, 2);
        assert_eq!(f.forward_part(), Some((1, 2)));
        assert_eq!(f.backward_part(), None);
        assert_eq!(f.weight_part(), None);

        let bf = Op::b_full(3, 4);
        assert_eq!(bf.backward_part(), Some((3, 4)));
        assert_eq!(bf.weight_part(), Some((3, 4)));

        let br = Op::Braided { f_chunk: 0, f_mb: 5, b_chunk: 0, b_mb: 2, b_full: false };
        assert_eq!(br.forward_part(), Some((0, 5)));
        assert_eq!(br.backward_part(), Some((0, 2)));
        assert_eq!(br.weight_part(), None);
    }

    #[test]
    fn ar_exposure_rules_match_paper_table1() {
        // Bare F exposes fwd AR (1F1B-I / ZB-V forward).
        assert!(!Op::f(0, 0).fwd_ar_overlapped());
        // Full backward hides bwd AR under W (1F1B-I backward).
        assert!(Op::b_full(0, 0).bwd_ar_overlapped());
        // Decoupled B exposes bwd AR (ZB-V's 4m·T_AR).
        assert!(!Op::b(0, 0).bwd_ar_overlapped());
        // Braided blocks hide both (STP's near-zero TP bubble).
        let br = Op::Braided { f_chunk: 0, f_mb: 1, b_chunk: 0, b_mb: 0, b_full: true };
        assert!(br.fwd_ar_overlapped() && br.bwd_ar_overlapped());
    }

    #[test]
    fn compile_flattens_device_major_with_producers() {
        let topo = Topology::new(1, 2, 1); // 4 chunks over 2 devices
        let s = crate::schedule::build_schedule(ScheduleKind::Stp, &topo, 4);
        let c = s.compile();
        assert_eq!(c.ops.len(), s.num_ops());
        assert_eq!(c.n_dev(), 2);
        assert_eq!(c.n_chunks, 4);
        assert!(c.unique_producers);
        // Device-major program order is preserved.
        for d in 0..2 {
            let (a, b) = (c.dev_start[d] as usize, c.dev_start[d + 1] as usize);
            assert_eq!(&c.ops[a..b], s.devices[d].as_slice());
            assert!(c.op_dev[a..b].iter().all(|&x| x as usize == d));
        }
        // Every (chunk, mb) has exactly one F and one B producer, and the
        // producer sits on the chunk's device.
        for chunk in 0..4 {
            for mb in 0..4 {
                let f = c.f_producer[c.slot(chunk, mb)];
                let b = c.b_producer[c.slot(chunk, mb)];
                assert_ne!(f, NO_OP, "F({chunk},{mb}) missing");
                assert_ne!(b, NO_OP, "B({chunk},{mb}) missing");
                assert_eq!(c.op_dev[f as usize], c.chunk_dev[chunk]);
                assert_eq!(c.ops[f as usize].forward_part(), Some((chunk, mb)));
                assert_eq!(c.ops[b as usize].backward_part(), Some((chunk, mb)));
            }
        }
    }

    #[test]
    fn compile_dependency_counts_match_readiness_rules() {
        let topo = Topology::new(1, 2, 1);
        let s = crate::schedule::build_schedule(ScheduleKind::ZbV, &topo, 4);
        let c = s.compile();
        let n_chunks = c.n_chunks;
        for (j, op) in c.ops.iter().enumerate() {
            let d = c.op_dev[j] as usize;
            let mut want = u32::from(j as u32 > c.dev_start[d]);
            if let Some((ch, _)) = op.forward_part() {
                want += u32::from(ch > 0);
            }
            if let Some((ch, _)) = op.backward_part() {
                want += 1 + u32::from(ch + 1 < n_chunks);
            }
            assert_eq!(c.base_deps[j], want, "op {j} {op:?}");
        }
        // At least one op is immediately runnable (F(0,0) on its device).
        assert!(c.base_deps.iter().any(|&d| d == 0));
    }

    #[test]
    fn compile_from_reuses_buffers_across_schedules() {
        let topo = Topology::new(1, 4, 1);
        let big = crate::schedule::build_schedule(ScheduleKind::Stp, &topo, 16);
        let small = crate::schedule::build_schedule(ScheduleKind::GPipe, &topo, 8);
        let mut c = big.compile();
        c.compile_from(&small);
        let fresh = small.compile();
        assert_eq!(c.ops, fresh.ops);
        assert_eq!(c.base_deps, fresh.base_deps);
        assert_eq!(c.f_producer, fresh.f_producer);
        assert_eq!(c.b_producer, fresh.b_producer);
        assert_eq!(c.dev_start, fresh.dev_start);
    }

    #[test]
    fn unique_schedules_skip_consumer_tables() {
        let topo = Topology::new(1, 2, 1);
        let c = crate::schedule::build_schedule(ScheduleKind::Stp, &topo, 4).compile();
        assert!(c.unique_producers);
        assert!(c.f_cons_start.is_empty() && c.b_cons_start.is_empty());
        assert!(c.f_consumers(0).is_empty() && c.b_consumers(0).is_empty());
    }

    #[test]
    fn duplicate_producers_build_per_edge_consumer_lists() {
        // Recomputation shape: F(0,0) twice, then the full backward; a
        // second chunk so the cross-chunk edges exist too.
        let topo = Topology::new(1, 2, 1).with_vpp(1);
        let s = Schedule {
            kind: ScheduleKind::GPipe,
            topo,
            n_mb: 1,
            placement: Placement::Interleaved,
            devices: vec![
                vec![Op::f(0, 0), Op::f(0, 0), Op::b_full(0, 0)],
                vec![Op::f(1, 0), Op::b_full(1, 0)],
            ],
        };
        let c = s.compile();
        assert!(!c.unique_producers);
        // Consumers of F(0,0): F(1,0) (op 3) and B(0,0) (op 2).
        let mut f0: Vec<u32> = c.f_consumers(c.slot(0, 0)).to_vec();
        f0.sort_unstable();
        assert_eq!(f0, vec![2, 3]);
        // Consumers of F(1,0): its own backward (op 4).
        assert_eq!(c.f_consumers(c.slot(1, 0)), &[4]);
        // Consumers of B(1,0): B(0,0) (op 2); B(0,0) itself has none.
        assert_eq!(c.b_consumers(c.slot(1, 0)), &[2]);
        assert!(c.b_consumers(c.slot(0, 0)).is_empty());
        // One CSR entry per counted cross edge.
        let cross: u32 = c
            .base_deps
            .iter()
            .enumerate()
            .map(|(j, &d)| {
                let dev = c.op_dev[j] as usize;
                d - u32::from(j as u32 > c.dev_start[dev])
            })
            .sum();
        assert_eq!(cross as usize, c.f_cons.len() + c.b_cons.len());
    }

    #[test]
    fn schedule_kind_parse_roundtrip() {
        for k in ScheduleKind::all() {
            let parsed: ScheduleKind = k.name().parse().unwrap();
            assert_eq!(parsed, k);
        }
        assert!("bogus".parse::<ScheduleKind>().is_err());
    }
}
