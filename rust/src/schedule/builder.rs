//! Greedy list-scheduling framework used to synthesize the decoupled
//! schedules (ZB-V, ZB-H1, STP and its variants).
//!
//! The builder maintains a global virtual clock per device and a table of
//! completion times for every `(F|B|W, chunk, mb)` work item. At each step
//! the per-schedule [`Policy`] proposes the next op for each device; the
//! builder commits the op with the globally-smallest feasible start time.
//! The committed order per device *is* the schedule IR — the discrete-event
//! simulator then re-times it under a real cost model, and the validator
//! checks legality independently, so the shape costs used here only steer
//! construction quality, never correctness.

use crate::cluster::Topology;

use super::ir::{Op, Placement, Schedule, ScheduleKind};

/// Normalized work-item durations used while *constructing* schedules.
/// `T_B > T_F > T_W` per the paper's appendix B observation; `t_ar` is the
/// per-chunk one-direction TP communication time.
#[derive(Debug, Clone, Copy)]
pub struct ShapeCosts {
    pub t_f: f64,
    pub t_b: f64,
    pub t_w: f64,
    pub t_ar: f64,
    pub t_p2p: f64,
}

impl Default for ShapeCosts {
    fn default() -> Self {
        ShapeCosts { t_f: 1.0, t_b: 1.1, t_w: 0.8, t_ar: 0.25, t_p2p: 0.05 }
    }
}

/// Work-item identifier: pass kind is implicit in which table it indexes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Item {
    pub chunk: usize,
    pub mb: usize,
}

/// What a policy may propose for a device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Proposal {
    F(Item),
    /// Decoupled activation backward (weight grad deferred to queue).
    B(Item),
    /// Full backward (B+W fused).
    BFull(Item),
    W(Item),
    /// Braided F&B block.
    Fb { f: Item, b: Item, b_full: bool },
    /// Braided F&W block (warm-up filler).
    Fw { f: Item, w: Item },
}

/// Construction-time state shared with policies (read-only view).
pub struct BuildState {
    pub topo: Topology,
    pub n_mb: usize,
    pub placement: Placement,
    pub costs: ShapeCosts,
    /// Per-chunk relative compute scale (MLLM imbalance; 1.0 for LLM).
    pub chunk_scale: Vec<f64>,
    pub dev_time: Vec<f64>,
    /// Completion time of F/B/W per `[chunk][mb]`; `None` = unscheduled.
    pub done_f: Vec<Vec<Option<f64>>>,
    pub done_b: Vec<Vec<Option<f64>>>,
    pub done_w: Vec<Vec<Option<f64>>>,
    /// Next unscheduled microbatch per chunk for F and B.
    pub next_f: Vec<usize>,
    pub next_b: Vec<usize>,
    /// Pending deferred weight grads per device (FIFO).
    pub w_queue: Vec<Vec<Item>>,
    /// Activations currently held per device (count of chunk-microbatch
    /// activations: +1 at F, −1 when the matching W completes — under
    /// decoupling the weight-grad inputs keep the buffers alive).
    pub in_flight: Vec<i64>,
    /// Per device, per chunk class (0 = descending leg `chunk < pp`,
    /// 1 = ascending leg): live activations. Policies cap the classes
    /// separately so the warm-up can never starve the V's return leg
    /// (which would deadlock the first backward).
    pub in_flight_class: Vec<[i64; 2]>,
    /// Peak of `in_flight` per device (exposed for tests/policies).
    pub peak_in_flight: Vec<i64>,
    pub ops: Vec<Vec<Op>>,
}

impl BuildState {
    fn new(topo: &Topology, n_mb: usize, placement: Placement, costs: ShapeCosts, chunk_scale: Vec<f64>) -> Self {
        let n_chunks = topo.chunks();
        assert_eq!(chunk_scale.len(), n_chunks);
        BuildState {
            topo: *topo,
            n_mb,
            placement,
            costs,
            chunk_scale,
            dev_time: vec![0.0; topo.pp],
            done_f: vec![vec![None; n_mb]; n_chunks],
            done_b: vec![vec![None; n_mb]; n_chunks],
            done_w: vec![vec![None; n_mb]; n_chunks],
            next_f: vec![0; n_chunks],
            next_b: vec![0; n_chunks],
            w_queue: vec![Vec::new(); topo.pp],
            in_flight: vec![0; topo.pp],
            in_flight_class: vec![[0; 2]; topo.pp],
            peak_in_flight: vec![0; topo.pp],
            ops: vec![Vec::new(); topo.pp],
        }
    }

    pub fn n_chunks(&self) -> usize {
        self.topo.chunks()
    }

    pub fn device_of(&self, chunk: usize) -> usize {
        self.placement.device_of(chunk, &self.topo)
    }

    /// Chunks owned by `dev`, ascending.
    pub fn chunks_of(&self, dev: usize) -> Vec<usize> {
        (0..self.n_chunks()).filter(|&c| self.device_of(c) == dev).collect()
    }

    /// Chunk class: 0 = descending leg (`chunk < pp`), 1 = ascending.
    pub fn class_of(&self, chunk: usize) -> usize {
        usize::from(chunk >= self.topo.pp)
    }

    /// Ready time of the next F of `chunk` (None = predecessor unscheduled
    /// or chunk exhausted).
    pub fn f_ready(&self, chunk: usize) -> Option<(Item, f64)> {
        let mb = *self.next_f.get(chunk)?;
        if mb >= self.n_mb {
            return None;
        }
        let t = if chunk == 0 {
            0.0
        } else {
            let up = self.done_f[chunk - 1][mb]?;
            up + self.hop_cost(chunk - 1, chunk)
        };
        Some((Item { chunk, mb }, t))
    }

    /// Ready time of the next B of `chunk`.
    pub fn b_ready(&self, chunk: usize) -> Option<(Item, f64)> {
        let mb = *self.next_b.get(chunk)?;
        if mb >= self.n_mb {
            return None;
        }
        let own_f = self.done_f[chunk][mb]?;
        let t = if chunk == self.n_chunks() - 1 {
            own_f // loss is computed on the last chunk
        } else {
            let down = self.done_b[chunk + 1][mb]?;
            own_f.max(down + self.hop_cost(chunk + 1, chunk))
        };
        Some((Item { chunk, mb }, t))
    }

    /// P2P cost between the devices owning two adjacent chunks.
    pub fn hop_cost(&self, from_chunk: usize, to_chunk: usize) -> f64 {
        if self.device_of(from_chunk) == self.device_of(to_chunk) {
            0.0
        } else {
            self.costs.t_p2p
        }
    }

    /// Remaining unscheduled forwards across the chunks of `dev`.
    pub fn fwd_remaining(&self, dev: usize) -> usize {
        self.chunks_of(dev).iter().map(|&c| self.n_mb - self.next_f[c]).sum()
    }

    /// Remaining unscheduled backwards across the chunks of `dev`.
    pub fn bwd_remaining(&self, dev: usize) -> usize {
        self.chunks_of(dev).iter().map(|&c| self.n_mb - self.next_b[c]).sum()
    }

    /// Backwards already scheduled on `dev`.
    pub fn bwd_scheduled(&self, dev: usize) -> usize {
        self.chunks_of(dev).iter().map(|&c| self.next_b[c]).sum()
    }

    fn scale(&self, chunk: usize) -> f64 {
        self.chunk_scale[chunk]
    }

    /// Duration of a proposal under the shape costs (ARs hidden inside
    /// braided blocks, exposed on bare F/B, hidden under W in full B).
    fn duration(&self, p: &Proposal) -> f64 {
        let c = &self.costs;
        match *p {
            Proposal::F(i) => c.t_f * self.scale(i.chunk) + c.t_ar,
            Proposal::B(i) => c.t_b * self.scale(i.chunk) + c.t_ar,
            Proposal::BFull(i) => {
                let s = self.scale(i.chunk);
                c.t_b * s + (c.t_w * s).max(c.t_ar)
            }
            Proposal::W(i) => c.t_w * self.scale(i.chunk),
            Proposal::Fb { f, b, b_full } => {
                let base = c.t_f * self.scale(f.chunk) + c.t_b * self.scale(b.chunk);
                if b_full {
                    base + c.t_w * self.scale(b.chunk)
                } else {
                    base
                }
            }
            Proposal::Fw { f, w } => c.t_f * self.scale(f.chunk) + c.t_w * self.scale(w.chunk),
        }
    }

    /// Earliest start time of a proposal on `dev` (deps + device clock).
    fn start_time(&self, dev: usize, p: &Proposal) -> Option<f64> {
        let ready = match *p {
            Proposal::F(i) => self.f_ready(i.chunk).filter(|(it, _)| *it == i)?.1,
            Proposal::B(i) | Proposal::BFull(i) => self.b_ready(i.chunk).filter(|(it, _)| *it == i)?.1,
            Proposal::W(i) => self.done_b[i.chunk][i.mb]?,
            Proposal::Fb { f, b, .. } => {
                let tf = self.f_ready(f.chunk).filter(|(it, _)| *it == f)?.1;
                let tb = self.b_ready(b.chunk).filter(|(it, _)| *it == b)?.1;
                tf.max(tb)
            }
            Proposal::Fw { f, w } => {
                let tf = self.f_ready(f.chunk).filter(|(it, _)| *it == f)?.1;
                let tw = self.done_w.get(w.chunk).and_then(|v| v[w.mb].map(|_| 0.0));
                // W dep is just its B being done.
                let twr = self.done_b[w.chunk][w.mb]?;
                let _ = tw;
                tf.max(twr)
            }
        };
        Some(ready.max(self.dev_time[dev]))
    }

    /// Commit a proposal on `dev`. Returns the emitted op.
    fn commit(&mut self, dev: usize, p: Proposal) -> Op {
        let start = self.start_time(dev, &p).expect("commit of non-ready proposal");
        let finish = start + self.duration(&p);
        self.dev_time[dev] = finish;

        let mark_f = |s: &mut Self, i: Item| {
            debug_assert_eq!(s.next_f[i.chunk], i.mb);
            s.next_f[i.chunk] += 1;
            s.done_f[i.chunk][i.mb] = Some(finish);
            s.in_flight[dev] += 1;
            let cls = s.class_of(i.chunk);
            s.in_flight_class[dev][cls] += 1;
            s.peak_in_flight[dev] = s.peak_in_flight[dev].max(s.in_flight[dev]);
        };
        let mark_b = |s: &mut Self, i: Item| {
            debug_assert_eq!(s.next_b[i.chunk], i.mb);
            s.next_b[i.chunk] += 1;
            s.done_b[i.chunk][i.mb] = Some(finish);
        };
        let mark_w = |s: &mut Self, i: Item, dev: usize| {
            s.done_w[i.chunk][i.mb] = Some(finish);
            s.in_flight[dev] -= 1;
            let cls = s.class_of(i.chunk);
            s.in_flight_class[dev][cls] -= 1;
        };

        let op = match p {
            Proposal::F(i) => {
                mark_f(self, i);
                Op::f(i.chunk, i.mb)
            }
            Proposal::B(i) => {
                mark_b(self, i);
                self.w_queue[dev].push(i);
                Op::b(i.chunk, i.mb)
            }
            Proposal::BFull(i) => {
                mark_b(self, i);
                mark_w(self, i, dev);
                Op::b_full(i.chunk, i.mb)
            }
            Proposal::W(i) => {
                let pos = self.w_queue[dev].iter().position(|x| *x == i).expect("W not queued");
                self.w_queue[dev].remove(pos);
                mark_w(self, i, dev);
                Op::w(i.chunk, i.mb)
            }
            Proposal::Fb { f, b, b_full } => {
                mark_f(self, f);
                mark_b(self, b);
                if b_full {
                    mark_w(self, b, dev);
                } else {
                    self.w_queue[dev].push(b);
                }
                Op::Braided { f_chunk: f.chunk, f_mb: f.mb, b_chunk: b.chunk, b_mb: b.mb, b_full }
            }
            Proposal::Fw { f, w } => {
                mark_f(self, f);
                let pos = self.w_queue[dev].iter().position(|x| *x == w).expect("W not queued");
                self.w_queue[dev].remove(pos);
                mark_w(self, w, dev);
                Op::BraidedFW { f_chunk: f.chunk, f_mb: f.mb, w_chunk: w.chunk, w_mb: w.mb }
            }
        };
        self.ops[dev].push(op);
        op
    }

    fn all_done(&self) -> bool {
        (0..self.n_chunks()).all(|c| {
            self.next_f[c] == self.n_mb
                && self.next_b[c] == self.n_mb
                && self.done_w[c].iter().all(|w| w.is_some())
        })
    }
}

/// A schedule-construction policy: proposes the next op for a device.
pub trait Policy {
    /// Propose the next op for `dev`, or `None` if the device must idle
    /// until other devices make progress.
    fn propose(&mut self, dev: usize, st: &BuildState) -> Option<Proposal>;
}

/// Run the greedy builder to completion and freeze the schedule.
pub fn run_builder<P: Policy>(
    kind: ScheduleKind,
    topo: &Topology,
    n_mb: usize,
    placement: Placement,
    costs: ShapeCosts,
    chunk_scale: Vec<f64>,
    policy: &mut P,
) -> Schedule {
    let mut st = BuildState::new(topo, n_mb, placement, costs, chunk_scale);
    let max_steps = 16 * topo.pp * topo.chunks() * n_mb + 1024;
    let mut steps = 0usize;
    while !st.all_done() {
        steps += 1;
        assert!(steps < max_steps, "builder did not converge — policy deadlock for {kind:?} p={} m={n_mb}", topo.pp);
        // Each device proposes; commit the globally earliest-starting one.
        let mut best: Option<(usize, Proposal, f64)> = None;
        for dev in 0..topo.pp {
            if let Some(p) = policy.propose(dev, &st) {
                if let Some(t) = st.start_time(dev, &p) {
                    // Prefer earlier start; tie-break on lower device id
                    // (deterministic).
                    let better = match &best {
                        None => true,
                        Some((_, _, bt)) => t < *bt - 1e-12,
                    };
                    if better {
                        best = Some((dev, p, t));
                    }
                }
            }
        }
        let (dev, p, _) = best.expect("no device has a feasible proposal but work remains");
        st.commit(dev, p);
    }
    Schedule { kind, topo: *topo, n_mb, placement, devices: st.ops }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial policy: strict F-then-B-then-W order (GPipe-like) to
    /// exercise the builder machinery.
    struct Naive;
    impl Policy for Naive {
        fn propose(&mut self, dev: usize, st: &BuildState) -> Option<Proposal> {
            let chunks = st.chunks_of(dev);
            for &c in &chunks {
                if let Some((i, _)) = st.f_ready(c) {
                    return Some(Proposal::F(i));
                }
            }
            for &c in chunks.iter().rev() {
                if let Some((i, _)) = st.b_ready(c) {
                    return Some(Proposal::BFull(i));
                }
            }
            None
        }
    }

    #[test]
    fn builder_completes_all_work() {
        let topo = Topology::new(1, 4, 1);
        let s = run_builder(
            ScheduleKind::GPipe,
            &topo,
            6,
            Placement::VShape,
            ShapeCosts::default(),
            vec![1.0; topo.chunks()],
            &mut Naive,
        );
        assert_eq!(s.count_forwards(), 6 * topo.chunks());
        assert_eq!(s.count_backwards(), 6 * topo.chunks());
        assert_eq!(s.count_weight_grads(), 6 * topo.chunks());
    }

    #[test]
    fn builder_is_deterministic() {
        let topo = Topology::new(1, 2, 1);
        let build = || {
            run_builder(
                ScheduleKind::GPipe,
                &topo,
                4,
                Placement::VShape,
                ShapeCosts::default(),
                vec![1.0; topo.chunks()],
                &mut Naive,
            )
        };
        let a = build();
        let b = build();
        assert_eq!(a.devices, b.devices);
    }
}
