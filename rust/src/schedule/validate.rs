//! Schedule legality validator.
//!
//! Independent of both the builder and the simulator: replays a schedule's
//! per-device op lists against the dependency rules and reports every
//! violation. Used by unit/property tests and by the CLI (`stp validate`).
//!
//! Rules checked:
//! 1. **Completeness** — every `(chunk, mb)` has exactly one F, one B and
//!    one W (W possibly fused via `BFull`/braided-full).
//! 2. **Placement** — ops only appear on the device owning their chunk.
//! 3. **Dependency order** — a global topological replay succeeds:
//!    `F(c,m)` after `F(c-1,m)`; `B(c,m)` after `F(c,m)` and `B(c+1,m)`;
//!    `W(c,m)` after `B(c,m)`.
//! 4. **Braiding constraint** (paper Fig. 11a): same-chunk braids have
//!    `f_mb > b_mb`.
//! 5. **Offload pairing** — every `Reload` has a preceding `Offload`; every
//!    offloaded activation is reloaded before its backward.
//! 6. **Per-chunk microbatch order** — F (and B) of a chunk run in
//!    ascending microbatch order (required by the FIFO activation queues
//!    of the real executor).

use std::collections::HashSet;

use super::ir::{Op, Schedule};

/// A single validation failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub device: usize,
    pub index: usize,
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "dev {} op#{}: {}", self.device, self.index, self.message)
    }
}

/// Validate a schedule; empty vec = legal.
pub fn validate(s: &Schedule) -> Vec<Violation> {
    let mut v = Vec::new();
    check_completeness(s, &mut v);
    check_placement(s, &mut v);
    check_braiding(s, &mut v);
    check_mb_order(s, &mut v);
    check_dependencies(s, &mut v);
    check_offload(s, &mut v);
    v
}

/// Convenience: panic with a readable report if the schedule is illegal.
pub fn assert_valid(s: &Schedule) {
    let v = validate(s);
    assert!(
        v.is_empty(),
        "schedule {:?} (p={}, m={}) has {} violations:\n{}",
        s.kind,
        s.topo.pp,
        s.n_mb,
        v.len(),
        v.iter().take(20).map(|x| x.to_string()).collect::<Vec<_>>().join("\n")
    );
}

fn check_completeness(s: &Schedule, v: &mut Vec<Violation>) {
    let n_chunks = s.n_chunks();
    let mut f = vec![vec![0usize; s.n_mb]; n_chunks];
    let mut b = vec![vec![0usize; s.n_mb]; n_chunks];
    let mut w = vec![vec![0usize; s.n_mb]; n_chunks];
    for (d, op) in s.iter_ops() {
        let mut tag = |table: &mut Vec<Vec<usize>>, part: Option<(usize, usize)>, what: &str| {
            if let Some((c, m)) = part {
                if c >= n_chunks || m >= s.n_mb {
                    v.push(Violation {
                        device: d,
                        index: 0,
                        message: format!("{what} ({c},{m}) out of range"),
                    });
                } else {
                    table[c][m] += 1;
                }
            }
        };
        tag(&mut f, op.forward_part(), "F");
        tag(&mut b, op.backward_part(), "B");
        tag(&mut w, op.weight_part(), "W");
    }
    for c in 0..n_chunks {
        for m in 0..s.n_mb {
            for (table, what) in [(&f, "F"), (&b, "B"), (&w, "W")] {
                if table[c][m] != 1 {
                    v.push(Violation {
                        device: s.device_of(c),
                        index: 0,
                        message: format!("{what}({c},{m}) scheduled {} times", table[c][m]),
                    });
                }
            }
        }
    }
}

fn check_placement(s: &Schedule, v: &mut Vec<Violation>) {
    for (d, ops) in s.devices.iter().enumerate() {
        for (i, op) in ops.iter().enumerate() {
            for part in [op.forward_part(), op.backward_part(), op.weight_part()] {
                if let Some((c, _)) = part {
                    if s.device_of(c) != d {
                        v.push(Violation {
                            device: d,
                            index: i,
                            message: format!("chunk {c} belongs to device {}", s.device_of(c)),
                        });
                    }
                }
            }
        }
    }
}

fn check_braiding(s: &Schedule, v: &mut Vec<Violation>) {
    for (d, ops) in s.devices.iter().enumerate() {
        for (i, op) in ops.iter().enumerate() {
            if let Op::Braided { f_chunk, f_mb, b_chunk, b_mb, .. } = op {
                if f_chunk == b_chunk && f_mb <= b_mb {
                    v.push(Violation {
                        device: d,
                        index: i,
                        message: format!(
                            "braid F({f_chunk},{f_mb}) with B({b_chunk},{b_mb}): needs f_mb > b_mb"
                        ),
                    });
                }
            }
        }
    }
}

fn check_mb_order(s: &Schedule, v: &mut Vec<Violation>) {
    let n_chunks = s.n_chunks();
    let mut next_f = vec![0usize; n_chunks];
    let mut next_b = vec![0usize; n_chunks];
    // Per-device in-order walk; chunk streams are per-chunk so a global
    // interleave across devices is fine to check per device op order.
    for (d, ops) in s.devices.iter().enumerate() {
        for (i, op) in ops.iter().enumerate() {
            if let Some((c, m)) = op.forward_part() {
                if m != next_f[c] {
                    v.push(Violation {
                        device: d,
                        index: i,
                        message: format!("F({c},{m}) out of order (expected mb {})", next_f[c]),
                    });
                }
                next_f[c] = m + 1;
            }
            if let Some((c, m)) = op.backward_part() {
                if m != next_b[c] {
                    v.push(Violation {
                        device: d,
                        index: i,
                        message: format!("B({c},{m}) out of order (expected mb {})", next_b[c]),
                    });
                }
                next_b[c] = m + 1;
            }
        }
    }
}

/// Topological replay: repeatedly scan device cursors, executing any head
/// op whose dependencies are satisfied. If no cursor can advance and work
/// remains, the schedule deadlocks.
fn check_dependencies(s: &Schedule, v: &mut Vec<Violation>) {
    let n_chunks = s.n_chunks();
    let mut cursor = vec![0usize; s.devices.len()];
    let mut f_done = vec![vec![false; s.n_mb]; n_chunks];
    let mut b_done = vec![vec![false; s.n_mb]; n_chunks];

    let ready = |op: &Op, f_done: &Vec<Vec<bool>>, b_done: &Vec<Vec<bool>>| -> bool {
        let f_ok = |c: usize, m: usize| c == 0 || f_done[c - 1][m];
        let b_ok =
            |c: usize, m: usize| f_done[c][m] && (c + 1 == n_chunks || b_done[c + 1][m]);
        match *op {
            Op::Pass { kind: super::ir::PassKind::F, chunk, mb } => f_ok(chunk, mb),
            Op::Pass { kind: super::ir::PassKind::B | super::ir::PassKind::BFull, chunk, mb } => {
                b_ok(chunk, mb)
            }
            Op::Pass { kind: super::ir::PassKind::W, chunk, mb } => b_done[chunk][mb],
            Op::Braided { f_chunk, f_mb, b_chunk, b_mb, .. } => {
                f_ok(f_chunk, f_mb) && b_ok(b_chunk, b_mb)
            }
            Op::BraidedFW { f_chunk, f_mb, w_chunk, w_mb } => {
                f_ok(f_chunk, f_mb) && b_done[w_chunk][w_mb]
            }
            Op::Offload { .. } | Op::Reload { .. } => true,
        }
    };

    loop {
        let mut advanced = false;
        for d in 0..s.devices.len() {
            while cursor[d] < s.devices[d].len() {
                let op = &s.devices[d][cursor[d]];
                if !ready(op, &f_done, &b_done) {
                    break;
                }
                if let Some((c, m)) = op.forward_part() {
                    f_done[c][m] = true;
                }
                if let Some((c, m)) = op.backward_part() {
                    b_done[c][m] = true;
                }
                cursor[d] += 1;
                advanced = true;
            }
        }
        if !advanced {
            break;
        }
    }
    for (d, ops) in s.devices.iter().enumerate() {
        if cursor[d] < ops.len() {
            v.push(Violation {
                device: d,
                index: cursor[d],
                message: format!("deadlock: op {:?} never becomes ready", ops[cursor[d]]),
            });
        }
    }
}

fn check_offload(s: &Schedule, v: &mut Vec<Violation>) {
    for (d, ops) in s.devices.iter().enumerate() {
        let mut offloaded: HashSet<(usize, usize)> = HashSet::new();
        let mut reloaded: HashSet<(usize, usize)> = HashSet::new();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                Op::Offload { chunk, mb, ratio } => {
                    if !(0.0..=1.0).contains(&ratio) {
                        v.push(Violation {
                            device: d,
                            index: i,
                            message: format!("offload ratio {ratio} outside [0,1]"),
                        });
                    }
                    offloaded.insert((chunk, mb));
                }
                Op::Reload { chunk, mb } => {
                    if !offloaded.contains(&(chunk, mb)) {
                        v.push(Violation {
                            device: d,
                            index: i,
                            message: format!("reload of ({chunk},{mb}) without offload"),
                        });
                    }
                    reloaded.insert((chunk, mb));
                }
                _ => {
                    if let Some((c, m)) = op.backward_part() {
                        if offloaded.contains(&(c, m)) && !reloaded.contains(&(c, m)) {
                            v.push(Violation {
                                device: d,
                                index: i,
                                message: format!("backward of ({c},{m}) before reload"),
                            });
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::schedule::ir::{Placement, ScheduleKind};

    fn tiny_legal() -> Schedule {
        // p=1, vpp=2, m=1: F0 F1 B1 B0 with fused W on one device.
        let topo = Topology::new(1, 1, 1);
        Schedule {
            kind: ScheduleKind::GPipe,
            topo,
            n_mb: 1,
            placement: Placement::Interleaved,
            devices: vec![vec![Op::f(0, 0), Op::f(1, 0), Op::b_full(1, 0), Op::b_full(0, 0)]],
        }
    }

    #[test]
    fn legal_schedule_passes() {
        assert!(validate(&tiny_legal()).is_empty());
    }

    #[test]
    fn missing_backward_detected() {
        let mut s = tiny_legal();
        s.devices[0].pop();
        let v = validate(&s);
        assert!(v.iter().any(|x| x.message.contains("scheduled 0 times")));
    }

    #[test]
    fn double_forward_detected() {
        let mut s = tiny_legal();
        s.devices[0].insert(0, Op::f(0, 0));
        let v = validate(&s);
        assert!(v.iter().any(|x| x.message.contains("scheduled 2 times")));
    }

    #[test]
    fn deadlock_detected() {
        let topo = Topology::new(1, 1, 1);
        // B before its F.
        let s = Schedule {
            kind: ScheduleKind::GPipe,
            topo,
            n_mb: 1,
            placement: Placement::Interleaved,
            devices: vec![vec![Op::b_full(1, 0), Op::f(0, 0), Op::f(1, 0), Op::b_full(0, 0)]],
        };
        let v = validate(&s);
        assert!(v.iter().any(|x| x.message.contains("deadlock")));
    }

    #[test]
    fn illegal_braid_detected() {
        let topo = Topology::new(1, 1, 1);
        let s = Schedule {
            kind: ScheduleKind::Stp,
            topo,
            n_mb: 2,
            placement: Placement::VShape,
            devices: vec![vec![
                Op::f(0, 0),
                Op::f(1, 0),
                // Same chunk, f_mb <= b_mb: illegal (Fig. 11a).
                Op::Braided { f_chunk: 1, f_mb: 1, b_chunk: 1, b_mb: 1, b_full: true },
                Op::f(0, 1),
                Op::b_full(1, 0),
                Op::b_full(0, 1),
                Op::b_full(0, 0),
            ]],
        };
        let v = validate(&s);
        assert!(v.iter().any(|x| x.message.contains("needs f_mb > b_mb")));
    }

    #[test]
    fn wrong_device_detected() {
        let topo = Topology::new(1, 2, 1);
        let mut devices = vec![Vec::new(), Vec::new()];
        // chunk 1 belongs to device 1 under VShape(p=2): path 0,1,1,0.
        devices[0].push(Op::f(0, 0));
        devices[0].push(Op::f(1, 0)); // wrong device
        devices[0].push(Op::f(2, 0)); // wrong device (chunk2 -> dev1)
        devices[0].push(Op::f(3, 0));
        devices[0].push(Op::b_full(3, 0));
        devices[1].push(Op::b_full(2, 0));
        devices[0].push(Op::b_full(1, 0));
        devices[0].push(Op::b_full(0, 0));
        let s = Schedule {
            kind: ScheduleKind::Stp,
            topo,
            n_mb: 1,
            placement: Placement::VShape,
            devices,
        };
        let v = validate(&s);
        assert!(v.iter().any(|x| x.message.contains("belongs to device")));
    }

    #[test]
    fn reload_without_offload_detected() {
        let mut s = tiny_legal();
        s.devices[0].insert(2, Op::Reload { chunk: 1, mb: 0 });
        let v = validate(&s);
        assert!(v.iter().any(|x| x.message.contains("without offload")));
    }
}
