//! GPipe schedule (Huang et al., 2019): all forwards, then all backwards.
//!
//! Baseline of historical interest (paper §2); used by tests as the
//! maximally-simple legal schedule and by the ablation benches.

use crate::cluster::Topology;

use super::ir::{Op, Placement, Schedule, ScheduleKind};

/// Build a GPipe schedule: every device runs all microbatch forwards of its
/// chunks (in chunk order), then all full backwards (reverse order).
pub fn build(topo: &Topology, n_mb: usize) -> Schedule {
    let placement = Placement::Interleaved;
    let n_chunks = topo.chunks();
    let mut devices: Vec<Vec<Op>> = vec![Vec::new(); topo.pp];

    // Forwards: chunk-major so chunk c+1 never waits on unscheduled work.
    for c in 0..n_chunks {
        let d = placement.device_of(c, topo);
        for mb in 0..n_mb {
            devices[d].push(Op::f(c, mb));
        }
    }
    // Backwards: reverse chunk-major, full (B+W fused).
    for c in (0..n_chunks).rev() {
        let d = placement.device_of(c, topo);
        for mb in 0..n_mb {
            devices[d].push(Op::b_full(c, mb));
        }
    }

    Schedule { kind: ScheduleKind::GPipe, topo: *topo, n_mb, placement, devices }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_counts() {
        let topo = Topology::new(1, 4, 1);
        let s = build(&topo, 8);
        // Each device: 2 chunks x 8 mb forwards + same backwards.
        assert_eq!(s.count_forwards(), 8 * topo.chunks());
        assert_eq!(s.count_backwards(), 8 * topo.chunks());
        assert_eq!(s.count_weight_grads(), 8 * topo.chunks());
        for d in &s.devices {
            assert_eq!(d.len(), 2 * 8 * topo.vpp);
        }
    }

    #[test]
    fn all_forwards_before_any_backward_per_device() {
        let s = build(&Topology::new(1, 2, 1), 4);
        for ops in &s.devices {
            let first_b = ops.iter().position(|o| o.backward_part().is_some()).unwrap();
            let last_f = ops.iter().rposition(|o| o.forward_part().is_some()).unwrap();
            assert!(last_f < first_b);
        }
    }
}
