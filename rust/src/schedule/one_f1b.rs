//! Classic non-interleaved 1F1B (PipeDream-flush, Narayanan et al. 2019).
//!
//! One chunk per device (`vpp` is ignored: the model is split into exactly
//! `pp` stages). Device `d` warms up with `p-1-d` forwards, then alternates
//! 1F1B, then drains backwards.

use crate::cluster::Topology;

use super::ir::{Op, Placement, Schedule, ScheduleKind};

/// Build the classic 1F1B schedule (one chunk per device).
pub fn build(topo: &Topology, n_mb: usize) -> Schedule {
    let mut topo1 = *topo;
    topo1.vpp = 1;
    let p = topo1.pp;
    assert!(n_mb >= p, "1F1B needs at least p microbatches (got {n_mb} < {p})");
    let mut devices: Vec<Vec<Op>> = vec![Vec::new(); p];

    for d in 0..p {
        let chunk = d;
        let warmup = p - 1 - d;
        let ops = &mut devices[d];
        for mb in 0..warmup {
            ops.push(Op::f(chunk, mb));
        }
        // Steady: 1F1B.
        let mut next_f = warmup;
        let mut next_b = 0;
        while next_f < n_mb {
            ops.push(Op::f(chunk, next_f));
            next_f += 1;
            ops.push(Op::b_full(chunk, next_b));
            next_b += 1;
        }
        // Cool-down.
        while next_b < n_mb {
            ops.push(Op::b_full(chunk, next_b));
            next_b += 1;
        }
    }

    Schedule { kind: ScheduleKind::OneF1B, topo: topo1, n_mb, placement: Placement::Interleaved, devices }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_chunk_per_device() {
        let s = build(&Topology::new(1, 4, 1), 8);
        assert_eq!(s.topo.vpp, 1);
        assert_eq!(s.n_chunks(), 4);
        assert_eq!(s.count_forwards(), 4 * 8);
        assert_eq!(s.count_backwards(), 4 * 8);
    }

    #[test]
    fn warmup_depth_decreases_with_rank() {
        let s = build(&Topology::new(1, 4, 1), 8);
        for (d, ops) in s.devices.iter().enumerate() {
            let warmup = ops.iter().take_while(|o| o.backward_part().is_none()).count();
            assert_eq!(warmup, 4 - d, "device {d}");
        }
    }

    #[test]
    fn in_flight_never_exceeds_p() {
        // 1F1B's defining property: at most p microbatches in flight.
        let s = build(&Topology::new(1, 4, 1), 16);
        for ops in &s.devices {
            let mut in_flight = 0i64;
            let mut peak = 0i64;
            for op in ops {
                if op.forward_part().is_some() {
                    in_flight += 1;
                }
                if op.backward_part().is_some() {
                    in_flight -= 1;
                }
                peak = peak.max(in_flight);
            }
            assert!(peak <= 4);
        }
    }
}
