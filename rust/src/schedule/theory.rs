//! Theoretical bubble / memory formulas — paper Table 1.
//!
//! These closed forms are what the discrete-event simulator is
//! cross-checked against (`rust/tests/paper_tables.rs`), and what the
//! `stp bench table1` harness prints next to the simulated values.

use super::ir::ScheduleKind;

/// Inputs of Table 1: per-chunk timings and the pipeline geometry.
#[derive(Debug, Clone, Copy)]
pub struct TheoryInputs {
    /// PP stages.
    pub p: usize,
    /// Microbatches per iteration.
    pub m: usize,
    /// Forward time of one model chunk.
    pub t_f: f64,
    /// Activation-gradient time of one chunk.
    pub t_b: f64,
    /// Weight-gradient time of one chunk.
    pub t_w: f64,
    /// TP communication (All-Reduce) time of one chunk, one direction.
    pub t_ar: f64,
}

/// Closed-form predictions for one schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TheoryRow {
    /// PP bubble per iteration (time units).
    pub pp_bubble: f64,
    /// Non-overlapped TP communication per iteration (time units).
    pub tp_bubble: f64,
    /// Peak activation memory in units of `M_a` (per-chunk-per-microbatch).
    pub peak_act_ma: f64,
}

/// Paper Table 1, row by row. Only the three compared schedules have
/// closed forms in the paper; GPipe/1F1B classics are included for the
/// ablation benches (standard results from their own papers, with the TP
/// term added under the same exposure rules).
pub fn theory(kind: ScheduleKind, x: &TheoryInputs) -> TheoryRow {
    let p = x.p as f64;
    let m = x.m as f64;
    match kind {
        ScheduleKind::OneF1BInterleaved => TheoryRow {
            pp_bubble: (p - 1.0) * (x.t_f + x.t_ar + x.t_b + x.t_w),
            tp_bubble: 2.0 * m * x.t_ar,
            peak_act_ma: 3.0 * p - 2.0,
        },
        ScheduleKind::ZbV => TheoryRow {
            pp_bubble: (p - 1.0) * (x.t_f + 2.0 * x.t_ar + x.t_b - 2.0 * x.t_w),
            tp_bubble: 4.0 * m * x.t_ar,
            peak_act_ma: 2.0 * p,
        },
        ScheduleKind::Stp | ScheduleKind::StpOffload => TheoryRow {
            pp_bubble: (p - 1.0) * (x.t_f + x.t_ar + x.t_b - x.t_w),
            tp_bubble: (2.0 * p + 1.0) * x.t_ar,
            peak_act_ma: 3.0 * p,
        },
        ScheduleKind::StpMemEff => TheoryRow {
            pp_bubble: (p - 1.0) * (x.t_f + x.t_ar + x.t_b - x.t_w) + p * x.t_w,
            tp_bubble: (2.0 * p + 1.0) * x.t_ar + p * x.t_ar,
            peak_act_ma: 2.0 * p,
        },
        // Classic results (GPipe paper / PipeDream-flush), with both ARs
        // exposed forward and the backward AR hidden under fused W.
        ScheduleKind::GPipe => TheoryRow {
            pp_bubble: (p - 1.0) * (2.0 * (x.t_f + x.t_ar) + x.t_b + x.t_w + x.t_ar),
            tp_bubble: 2.0 * m * x.t_ar,
            peak_act_ma: 2.0 * m,
        },
        ScheduleKind::OneF1B => TheoryRow {
            pp_bubble: (p - 1.0) * (2.0 * x.t_f + x.t_b + x.t_w + 3.0 * x.t_ar),
            tp_bubble: 2.0 * m * x.t_ar,
            peak_act_ma: 2.0 * p, // one chunk per device of 2x size
        },
        ScheduleKind::ZbH1 => TheoryRow {
            pp_bubble: (p - 1.0) * (2.0 * x.t_f + x.t_b - x.t_w + 3.0 * x.t_ar),
            tp_bubble: 4.0 * m * x.t_ar,
            peak_act_ma: 2.0 * p,
        },
    }
}

impl TheoryInputs {
    /// Ideal (bubble-free) iteration time: every device busy with
    /// `m` microbatches × `vpp` chunks of compute.
    pub fn ideal_iteration(&self, vpp: usize) -> f64 {
        self.m as f64 * vpp as f64 * (self.t_f + self.t_b + self.t_w)
    }

    /// Bubble rate implied by a theory row (bubble / ideal).
    pub fn bubble_rate(&self, row: &TheoryRow, vpp: usize) -> f64 {
        (row.pp_bubble + row.tp_bubble) / self.ideal_iteration(vpp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x() -> TheoryInputs {
        TheoryInputs { p: 4, m: 64, t_f: 1.0, t_b: 1.1, t_w: 0.8, t_ar: 0.25 }
    }

    #[test]
    fn stp_tp_bubble_constant_in_m() {
        let a = theory(ScheduleKind::Stp, &TheoryInputs { m: 64, ..x() });
        let b = theory(ScheduleKind::Stp, &TheoryInputs { m: 192, ..x() });
        assert_eq!(a.tp_bubble, b.tp_bubble);
    }

    #[test]
    fn baseline_tp_bubbles_linear_in_m() {
        let a = theory(ScheduleKind::ZbV, &TheoryInputs { m: 64, ..x() });
        let b = theory(ScheduleKind::ZbV, &TheoryInputs { m: 128, ..x() });
        assert!((b.tp_bubble - 2.0 * a.tp_bubble).abs() < 1e-9);
    }

    #[test]
    fn table1_orderings() {
        let x = x();
        let i = theory(ScheduleKind::OneF1BInterleaved, &x);
        let z = theory(ScheduleKind::ZbV, &x);
        let s = theory(ScheduleKind::Stp, &x);
        // PP bubble: ours < zbv < 1f1b-i (for T_W < T_AR + 2T_W etc.).
        assert!(s.pp_bubble < i.pp_bubble);
        assert!(z.pp_bubble < i.pp_bubble);
        // TP bubble: ours << 1f1b-i < zbv at large m.
        assert!(s.tp_bubble < i.tp_bubble);
        assert!(i.tp_bubble < z.tp_bubble);
        // Memory: zbv < 1f1b-i < ours.
        assert!(z.peak_act_ma < i.peak_act_ma);
        assert!(i.peak_act_ma < s.peak_act_ma);
    }

    #[test]
    fn zbv_total_bubble_can_exceed_1f1bi() {
        // The paper's Fig. 8 observation: ZB-V's exposed backward ARs can
        // erase its PP-bubble advantage. At TP=8-like t_ar this shows up
        // as a larger total bubble.
        let big_ar = TheoryInputs { t_ar: 0.4, ..x() };
        let i = theory(ScheduleKind::OneF1BInterleaved, &big_ar);
        let z = theory(ScheduleKind::ZbV, &big_ar);
        let total = |r: TheoryRow| r.pp_bubble + r.tp_bubble;
        assert!(total(z) > total(i));
    }
}
