//! Zero-Bubble V (ZB-V, Qi et al. 2024 "Pipeline Parallelism with
//! Controllable Memory") — the paper's baseline (b), plus ZB-H1.
//!
//! ZB-V decouples every backward into activation-grad `B` and deferred
//! weight-grad `W`, places chunks on the **V-shape** path, prioritizes
//! `B > F > W` (W fills what would otherwise be bubbles), and caps
//! in-flight activations at `2p` per device — giving the `2p·M_a` peak of
//! paper Table 1 at the cost of *exposing* the backward All-Reduce
//! (`4m·T_AR` total TP bubble, the effect the paper's Fig. 8 discussion
//! attributes ZB-V's losses to).

use crate::cluster::Topology;

use super::builder::{run_builder, BuildState, Policy, Proposal, ShapeCosts};
use super::ir::{Placement, Schedule, ScheduleKind};

/// B > F > W priority with per-leg in-flight caps.
pub struct ZbPolicy {
    /// Max live activations per device for the descending (`chunk < p`)
    /// and ascending chunk classes. Separate caps guarantee the warm-up
    /// can never starve the V's return leg (deadlock-freedom).
    pub caps: [i64; 2],
}

impl ZbPolicy {
    fn cap_ok(&self, dev: usize, chunk: usize, st: &BuildState) -> bool {
        let cls = st.class_of(chunk);
        st.in_flight_class[dev][cls] < self.caps[cls]
    }
}

impl Policy for ZbPolicy {
    fn propose(&mut self, dev: usize, st: &BuildState) -> Option<Proposal> {
        let chunks = st.chunks_of(dev);
        let now = st.dev_time[dev];
        let eps = 1e-9;

        // 1. A backward that is ready by the device clock — highest chunk
        //    first (closest to the loss; unblocks downstream soonest).
        let mut b_cands: Vec<_> = chunks.iter().filter_map(|&c| st.b_ready(c)).collect();
        b_cands.sort_by(|a, b| b.0.chunk.cmp(&a.0.chunk));
        for (i, t) in &b_cands {
            if *t <= now + eps {
                return Some(Proposal::B(*i));
            }
        }
        // 2. A forward ready by the clock, if the memory cap allows.
        //    Higher chunk first: completing the V's return leg unblocks
        //    the backward chain soonest.
        let mut f_cands: Vec<_> = chunks
            .iter()
            .filter_map(|&c| st.f_ready(c))
            .filter(|(i, _)| self.cap_ok(dev, i.chunk, st))
            .collect();
        f_cands.sort_by(|a, b| b.0.chunk.cmp(&a.0.chunk));
        for (i, t) in &f_cands {
            if *t <= now + eps {
                return Some(Proposal::F(*i));
            }
        }
        // 3. Fill the bubble with a stored weight-grad.
        if let Some(&w) = st.w_queue[dev].first() {
            return Some(Proposal::W(w));
        }
        // 4. Nothing ready now: wait for the earliest B or (cap allowing) F.
        let mut best: Option<(Proposal, f64)> = None;
        for (i, t) in b_cands {
            if best.as_ref().map_or(true, |(_, bt)| t < *bt) {
                best = Some((Proposal::B(i), t));
            }
        }
        for (i, t) in f_cands {
            if best.as_ref().map_or(true, |(_, bt)| t < *bt) {
                best = Some((Proposal::F(i), t));
            }
        }
        best.map(|(p, _)| p)
    }
}

/// Build ZB-V: V-shape placement, `2p` in-flight cap.
pub fn build_zbv(topo: &Topology, n_mb: usize, costs: ShapeCosts, chunk_scale: Vec<f64>) -> Schedule {
    assert!(topo.vpp == 2, "ZB-V is defined for 2 virtual stages per device");
    let p = topo.pp as i64;
    let mut policy = ZbPolicy { caps: [p, p] };
    run_builder(ScheduleKind::ZbV, topo, n_mb, Placement::VShape, costs, chunk_scale, &mut policy)
}

/// Build ZB-H1 (Zero Bubble, handcrafted-1): one chunk per device
/// (vpp = 1), decoupled B/W, 1F1B-like `p` in-flight cap. Ablation
/// baseline showing decoupling without the V placement.
pub fn build_zbh1(topo: &Topology, n_mb: usize, costs: ShapeCosts) -> Schedule {
    let mut topo1 = *topo;
    topo1.vpp = 1;
    let mut policy = ZbPolicy { caps: [topo1.pp as i64, topo1.pp as i64] };
    let scale = vec![1.0; topo1.chunks()];
    run_builder(ScheduleKind::ZbH1, &topo1, n_mb, Placement::Interleaved, costs, scale, &mut policy)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zbv_completes_all_work() {
        let topo = Topology::new(1, 4, 1);
        let s = build_zbv(&topo, 12, ShapeCosts::default(), vec![1.0; topo.chunks()]);
        assert_eq!(s.count_forwards(), 12 * 8);
        assert_eq!(s.count_backwards(), 12 * 8);
        assert_eq!(s.count_weight_grads(), 12 * 8);
    }

    #[test]
    fn zbv_exposes_all_ars() {
        // Table 1: ZB-V TP bubble = 4·m·T_AR — every F and every B exposed.
        let topo = Topology::new(4, 4, 1);
        let s = build_zbv(&topo, 8, ShapeCosts::default(), vec![1.0; topo.chunks()]);
        assert_eq!(s.exposed_fwd_ars(), s.count_forwards());
        assert_eq!(s.exposed_bwd_ars(), s.count_backwards());
    }

    #[test]
    fn zbv_respects_memory_cap() {
        let p = 4;
        let topo = Topology::new(1, p, 1);
        let s = build_zbv(&topo, 16, ShapeCosts::default(), vec![1.0; topo.chunks()]);
        // Replay in-flight per device: +1 at F, -1 at W (weight grad frees).
        for (d, ops) in s.devices.iter().enumerate() {
            let mut live = 0i64;
            let mut peak = 0i64;
            for op in ops {
                if op.forward_part().is_some() {
                    live += 1;
                }
                if op.weight_part().is_some() {
                    live -= 1;
                }
                peak = peak.max(live);
            }
            assert!(peak <= 2 * p as i64, "device {d}: peak {peak} > 2p");
        }
    }

    #[test]
    fn zbh1_single_chunk_per_device() {
        let s = build_zbh1(&Topology::new(1, 4, 1), 8, ShapeCosts::default());
        assert_eq!(s.topo.vpp, 1);
        assert_eq!(s.count_forwards(), 4 * 8);
    }
}
