//! STP — the paper's Synergistic Tensor and Pipeline schedule (§4).
//!
//! Construction follows the paper's three phases:
//!
//! * **Warm-up** — the maximum feasible number of in-flight microbatches
//!   (cap `3p` activations per device, Table 1's `3p·M_a` peak) is admitted
//!   before the first backward. The first overlapped F&B braids the second
//!   microbatch's forward with the first's backward, with **weight-grad
//!   separation active** (except where there is no next stage to feed) so
//!   gradients propagate quickly; the deferred `W`s are drained by braided
//!   **F&W** blocks.
//! * **Steady** — weight separation is deactivated: full-backward braids
//!   (`F&B`, Fig. 3a) alternate between the device's chunk 1 and chunk 0.
//! * **Degraded/cool-down** — when microbatches run out, full backwards and
//!   separated F&B re-appear; remaining PP bubbles are filled with the
//!   stored weight-gradient computations.
//!
//! Placement is the **V-shape** (paper §4.1), so braiding pattern (2)
//! (same chunk, forward microbatch index > backward index — always true
//! because `B(c,m)` requires `F(c,m)` scheduled) is available on every
//! device; pattern (1) cross-chunk braids are used as a fallback, which is
//! exactly what keeps the schedule universal for MLLM-imbalanced chunks.

use crate::cluster::Topology;

use super::builder::{run_builder, BuildState, Policy, Proposal, ShapeCosts};
use super::ir::{Op, Placement, Schedule, ScheduleKind};

/// STP construction policy.
pub struct StpPolicy {
    /// In-flight activation caps per device and chunk class
    /// (descending leg / ascending leg). Standard STP admits `2p + p = 3p`
    /// (Table 1's `3p·M_a` peak); the memory-efficient warm-up variant
    /// admits `p + p = 2p`.
    pub caps: [i64; 2],
    /// Memory-efficient warm-up (appendix Fig. 11b / schedule (d)): keep
    /// weight separation on through a longer warm-up window.
    pub mem_eff: bool,
    /// Per-device: chunk used by the previous braid (for the steady-phase
    /// chunk-1/chunk-0 alternation).
    last_braid_chunk: Vec<Option<usize>>,
}

impl StpPolicy {
    pub fn new(topo: &Topology, mem_eff: bool) -> Self {
        let p = topo.pp as i64;
        let caps = if mem_eff { [p, p] } else { [2 * p, p] };
        StpPolicy { caps, mem_eff, last_braid_chunk: vec![None; topo.pp] }
    }

    fn cap_ok(&self, dev: usize, chunk: usize, st: &BuildState) -> bool {
        let cls = st.class_of(chunk);
        st.in_flight_class[dev][cls] < self.caps[cls]
    }

    /// Cap check for cross-class braids: one slot of headroom. Steady-state
    /// braiding at the V's turn-around pairs (F₀,B₁) with (F₁,B₀); the
    /// first braid of the pair transiently holds one extra activation that
    /// the second returns, so the net peak cost is a single `M_a`.
    fn braid_cap_ok(&self, dev: usize, chunk: usize, st: &BuildState) -> bool {
        let cls = st.class_of(chunk);
        st.in_flight_class[dev][cls] < self.caps[cls] + 1
    }

    /// Should this braid separate the weight grad (`b_full = false`)?
    ///
    /// Warm-up rule: the first backward of each chunk propagates with
    /// separation so the next stage unblocks early — unless the backward
    /// has no downstream stage (chunk 0 ends the backward chain). The
    /// degraded phase (forwards nearly exhausted on this device) also
    /// reactivates separation so F&B blocks align with full backwards.
    fn separate_w(&self, dev: usize, st: &BuildState, b_chunk: usize, b_mb: usize) -> bool {
        if b_chunk == 0 {
            return false; // "except for the last stage"
        }
        let warmup_window = if self.mem_eff { st.topo.pp } else { 1 };
        let in_warmup = b_mb < warmup_window;
        let degraded = st.fwd_remaining(dev) <= 1;
        in_warmup || degraded
    }
}

impl Policy for StpPolicy {
    fn propose(&mut self, dev: usize, st: &BuildState) -> Option<Proposal> {
        let chunks = st.chunks_of(dev);
        let now = st.dev_time[dev];
        let eps = 1e-9;

        // Braiding look-ahead: a braid starts at max(f_ready, b_ready), so
        // pairing with a partner that arrives a fraction of a pass later
        // still beats emitting a bare op now and exposing an All-Reduce.
        let slack = st.costs.t_f * 1.0;

        let b_soon: Vec<_> = chunks
            .iter()
            .filter_map(|&c| st.b_ready(c))
            .filter(|(_, t)| *t <= now + slack + eps)
            .collect();
        let b_now_exists = b_soon.iter().any(|(_, t)| *t <= now + eps);
        // Braid F candidates are *not* cap-checked: a braided block is
        // memory-neutral (its F admits one activation, its B retires one).
        let mut f_soon: Vec<_> = chunks
            .iter()
            .filter_map(|&c| st.f_ready(c))
            .filter(|(_, t)| *t <= now + slack + eps)
            .collect();
        // Higher chunk first: completing the V's return leg unblocks the
        // backward chain soonest.
        f_soon.sort_by(|a, b| b.0.chunk.cmp(&a.0.chunk));
        let f_now: Vec<_> = f_soon
            .iter()
            .filter(|(i, t)| *t <= now + eps && self.cap_ok(dev, i.chunk, st))
            .copied()
            .collect();

        // 1. Braid a (soon-)ready backward with a (soon-)ready forward.
        //    Pattern (2) (same chunk) is preferred and cap-exempt: its F
        //    admits one activation exactly as its B retires one of the
        //    same chunk, so the braid is memory-neutral per class.
        //    Pattern (1) (cross-chunk) shifts memory between the V's legs
        //    and therefore must respect the F-class cap.
        if let Some((b, _)) = pick_b(&b_soon, self.last_braid_chunk[dev]) {
            // Choose the forward partner that keeps the per-class
            // activation balance lowest (the braid's B retires one unit of
            // its own class); ties prefer pattern (2) (same chunk), then
            // the V's return leg.
            let b_cls = st.class_of(b.chunk);
            let f = f_soon
                .iter()
                .filter(|(f, _)| {
                    st.class_of(f.chunk) == b_cls || self.braid_cap_ok(dev, f.chunk, st)
                })
                .min_by_key(|(f, _)| {
                    let cls = st.class_of(f.chunk);
                    let net = st.in_flight_class[dev][cls] - i64::from(cls == b_cls);
                    (net, usize::from(f.chunk != b.chunk), usize::MAX - f.chunk)
                })
                .map(|(f, _)| *f);
            if let Some(f) = f {
                let b_full = !self.separate_w(dev, st, b.chunk, b.mb);
                self.last_braid_chunk[dev] = Some(b.chunk);
                return Some(Proposal::Fb { f, b, b_full });
            }
            if b_now_exists {
                // 2. Backward alone. Degraded phase: full backward;
                //    cool-down (no forwards left on this device):
                //    separated B — stored W fills the remaining bubbles.
                if st.fwd_remaining(dev) == 0 {
                    return Some(Proposal::B(b));
                }
                return Some(Proposal::BFull(b));
            }
        }

        // 3. Forward alone; drain a stored weight-grad under it if any
        //    (warm-up F&W blocks).
        if let Some((f, _)) = f_now.first() {
            if let Some(&w) = st.w_queue[dev].first() {
                return Some(Proposal::Fw { f: *f, w });
            }
            return Some(Proposal::F(*f));
        }

        // 4. Nothing ready now: fill the bubble with a stored weight-grad.
        if let Some(&w) = st.w_queue[dev].first() {
            return Some(Proposal::W(w));
        }

        // 5. Idle: wait on the earliest future candidate (backward first).
        let mut best: Option<(Proposal, f64)> = None;
        for &c in &chunks {
            if let Some((i, t)) = st.b_ready(c) {
                if best.as_ref().map_or(true, |(_, bt)| t < *bt) {
                    let p = if st.fwd_remaining(dev) == 0 { Proposal::B(i) } else { Proposal::BFull(i) };
                    best = Some((p, t));
                }
            }
        }
        for &c in &chunks {
            if let Some((i, t)) = st.f_ready(c) {
                if self.cap_ok(dev, i.chunk, st) && best.as_ref().map_or(true, |(_, bt)| t < *bt) {
                    best = Some((Proposal::F(i), t));
                }
            }
        }
        best.map(|(p, _)| p)
    }
}

/// Steady-phase alternation: prefer the chunk that was *not* braided last
/// ("one F&B for chunk 1, followed by one F&B for chunk 0"); fall back to
/// the highest ready chunk (unblocks the backward chain soonest).
fn pick_b(
    b_now: &[(super::builder::Item, f64)],
    last: Option<usize>,
) -> Option<(super::builder::Item, f64)> {
    if b_now.is_empty() {
        return None;
    }
    if let Some(last_c) = last {
        if let Some(x) = b_now.iter().find(|(i, _)| i.chunk != last_c) {
            return Some(*x);
        }
    }
    b_now.iter().max_by_key(|(i, _)| i.chunk).copied()
}

/// Build the standard STP schedule (paper Fig. 5).
pub fn build_stp(topo: &Topology, n_mb: usize, costs: ShapeCosts, chunk_scale: Vec<f64>) -> Schedule {
    assert!(topo.vpp == 2, "STP is defined for 2 virtual stages per device");
    let mut policy = StpPolicy::new(topo, false);
    run_builder(ScheduleKind::Stp, topo, n_mb, Placement::VShape, costs, chunk_scale, &mut policy)
}

/// Build the memory-efficient-warm-up variant (appendix schedule (d)).
pub fn build_stp_memeff(topo: &Topology, n_mb: usize, costs: ShapeCosts, chunk_scale: Vec<f64>) -> Schedule {
    assert!(topo.vpp == 2);
    let mut policy = StpPolicy::new(topo, true);
    let mut s =
        run_builder(ScheduleKind::StpMemEff, topo, n_mb, Placement::VShape, costs, chunk_scale, &mut policy);
    s.kind = ScheduleKind::StpMemEff;
    s
}

/// Offloading parameters for the enhanced variant (§4.4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OffloadParams {
    /// Warm-up offload ratio (constrained so `T_o < T_F`).
    pub alpha_warmup: f32,
    /// Steady-phase offload ratio (may be higher — braided blocks give the
    /// PCIe stream more time to hide under).
    pub alpha_steady: f32,
    /// How many ops before the backward to issue the reload (prefetch).
    pub reload_lead: usize,
}

impl Default for OffloadParams {
    fn default() -> Self {
        OffloadParams { alpha_warmup: 0.3, alpha_steady: 0.7, reload_lead: 2 }
    }
}

/// Build the enhanced STP variant with activation offloading: the standard
/// schedule decorated with `Offload` after each *descending-leg* (chunk 0
/// class, chunk id < p) forward and a prefetched `Reload` before the
/// matching backward. Chunk-1-class activations have short lifespans and
/// are never offloaded (paper §4.4: avoids dual PCIe contention).
pub fn build_stp_offload(
    topo: &Topology,
    n_mb: usize,
    costs: ShapeCosts,
    chunk_scale: Vec<f64>,
    params: OffloadParams,
) -> Schedule {
    let mut s = build_stp(topo, n_mb, costs, chunk_scale);
    s.kind = ScheduleKind::StpOffload;
    let p = topo.pp;

    for ops in s.devices.iter_mut() {
        let mut out: Vec<Op> = Vec::with_capacity(ops.len() * 2);
        // First pass: insert Offload right after qualifying forwards.
        for (idx, op) in ops.iter().enumerate() {
            out.push(*op);
            if let Some((c, mb)) = op.forward_part() {
                if c < p {
                    // Warm-up = before this device's first backward.
                    let warmup = ops[..=idx].iter().all(|o| o.backward_part().is_none());
                    let ratio = if warmup { params.alpha_warmup } else { params.alpha_steady };
                    out.push(Op::Offload { chunk: c, mb, ratio });
                }
            }
        }
        // Second pass: insert Reload `reload_lead` compute-ops before the
        // backward that consumes each offloaded activation.
        let mut with_reloads: Vec<Op> = Vec::with_capacity(out.len() * 2);
        let mut pending: Vec<(usize, Op)> = Vec::new(); // (insert_before_idx, reload)
        for (idx, op) in out.iter().enumerate() {
            if let Some((c, mb)) = op.backward_part() {
                if c < p && out.iter().any(|o| matches!(o, Op::Offload { chunk, mb: m, .. } if *chunk == c && *m == mb)) {
                    let at = idx.saturating_sub(params.reload_lead);
                    pending.push((at, Op::Reload { chunk: c, mb }));
                }
            }
        }
        for (idx, op) in out.iter().enumerate() {
            for (at, r) in &pending {
                if *at == idx {
                    with_reloads.push(*r);
                }
            }
            with_reloads.push(*op);
        }
        *ops = with_reloads;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo4() -> Topology {
        Topology::new(1, 4, 1)
    }

    fn scale(topo: &Topology) -> Vec<f64> {
        vec![1.0; topo.chunks()]
    }

    #[test]
    fn stp_completes_all_work() {
        let t = topo4();
        let s = build_stp(&t, 12, ShapeCosts::default(), scale(&t));
        assert_eq!(s.count_forwards(), 12 * 8);
        assert_eq!(s.count_backwards(), 12 * 8);
        assert_eq!(s.count_weight_grads(), 12 * 8);
    }

    #[test]
    fn stp_braids_dominate_steady_state() {
        // Most backwards should ride inside braided blocks: the TP bubble
        // must be O(p), not O(m) (paper Table 1: (2p+1)·T_AR vs 4m·T_AR).
        let t = topo4();
        let m = 64;
        let s = build_stp(&t, m, ShapeCosts::default(), scale(&t));
        let braided = s
            .iter_ops()
            .filter(|(_, op)| matches!(op, Op::Braided { .. }))
            .count();
        let total_b = s.count_backwards();
        assert!(
            braided as f64 > 0.75 * total_b as f64,
            "only {braided}/{total_b} backwards braided"
        );
        // The braided fraction grows with m (bare ops are O(p) ramps).
        let small = build_stp(&t, 16, ShapeCosts::default(), scale(&t));
        let frac = |s: &Schedule| {
            s.iter_ops().filter(|(_, op)| matches!(op, Op::Braided { .. })).count() as f64
                / s.count_backwards() as f64
        };
        assert!(frac(&s) > frac(&small) - 0.05);
    }

    #[test]
    fn stp_exposed_ars_scale_with_p_not_m() {
        let t = topo4();
        let costs = ShapeCosts::default();
        let small = build_stp(&t, 16, costs, scale(&t));
        let large = build_stp(&t, 64, costs, scale(&t));
        let exposed = |s: &Schedule| s.exposed_fwd_ars() + s.exposed_bwd_ars();
        // Exposure grows sub-linearly in m (paper: constant in m).
        let e_small = exposed(&small) as f64;
        let e_large = exposed(&large) as f64;
        assert!(
            e_large < 2.0 * e_small,
            "exposed ARs grew {e_small} -> {e_large} for 4x microbatches"
        );
    }

    #[test]
    fn stp_same_chunk_braids_have_later_forward_mb() {
        // Fig. 11(a): the braiding constraint f_mb > b_mb for pattern (2).
        let t = topo4();
        let s = build_stp(&t, 12, ShapeCosts::default(), scale(&t));
        for (_, op) in s.iter_ops() {
            if let Op::Braided { f_chunk, f_mb, b_chunk, b_mb, .. } = op {
                if f_chunk == b_chunk {
                    assert!(f_mb > b_mb, "braid {op:?} violates f_mb > b_mb");
                }
            }
        }
    }

    #[test]
    fn stp_peak_in_flight_about_3p() {
        let p = 4;
        let t = Topology::new(1, p, 1);
        let s = build_stp(&t, 24, ShapeCosts::default(), scale(&t));
        for (d, ops) in s.devices.iter().enumerate() {
            let mut live = 0i64;
            let mut peak = 0i64;
            for op in ops {
                if op.forward_part().is_some() {
                    live += 1;
                }
                if op.weight_part().is_some() {
                    live -= 1;
                }
                peak = peak.max(live);
            }
            // Cross-class braids may transiently hold one extra activation
            // (see `braid_cap_ok`).
            assert!(peak <= 3 * p as i64 + 2, "device {d} peak {peak} > 3p+2");
            if d == 0 {
                assert!(peak >= 2 * p as i64, "device 0 peak {peak} below 2p — warm-up too shy");
            }
        }
    }

    #[test]
    fn memeff_has_lower_peak_than_standard() {
        let t = topo4();
        let m = 16;
        let peak0 = |s: &Schedule| {
            let mut live = 0i64;
            let mut peak = 0i64;
            for op in &s.devices[0] {
                if op.forward_part().is_some() {
                    live += 1;
                }
                if op.weight_part().is_some() {
                    live -= 1;
                }
                peak = peak.max(live);
            }
            peak
        };
        let std = build_stp(&t, m, ShapeCosts::default(), scale(&t));
        let eff = build_stp_memeff(&t, m, ShapeCosts::default(), scale(&t));
        assert!(peak0(&eff) <= peak0(&std));
    }

    #[test]
    fn offload_variant_pairs_offloads_with_reloads() {
        let t = topo4();
        let s = build_stp_offload(&t, 8, ShapeCosts::default(), scale(&t), OffloadParams::default());
        let offloads: Vec<(usize, usize)> = s
            .iter_ops()
            .filter_map(|(_, op)| match op {
                Op::Offload { chunk, mb, .. } => Some((*chunk, *mb)),
                _ => None,
            })
            .collect();
        assert!(!offloads.is_empty());
        for (c, mb) in &offloads {
            assert!(*c < t.pp, "only descending-leg chunks are offloaded");
            let has_reload = s
                .iter_ops()
                .any(|(_, op)| matches!(op, Op::Reload { chunk, mb: m } if chunk == c && m == mb));
            assert!(has_reload, "offloaded ({c},{mb}) never reloaded");
        }
        // Chunk-1-class activations are never offloaded.
        assert!(offloads.iter().all(|(c, _)| *c < t.pp));
    }

    #[test]
    fn reload_precedes_backward() {
        let t = topo4();
        let s = build_stp_offload(&t, 8, ShapeCosts::default(), scale(&t), OffloadParams::default());
        for ops in &s.devices {
            for (c, mb) in ops.iter().filter_map(|o| match o {
                Op::Reload { chunk, mb } => Some((*chunk, *mb)),
                _ => None,
            }) {
                let rl = ops
                    .iter()
                    .position(|o| matches!(o, Op::Reload { chunk, mb: m } if *chunk == c && *m == mb))
                    .unwrap();
                let bw = ops.iter().position(|o| o.backward_part() == Some((c, mb)));
                if let Some(bw) = bw {
                    assert!(rl < bw, "reload of ({c},{mb}) after its backward");
                }
            }
        }
    }
}
