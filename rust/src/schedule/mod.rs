//! Pipeline-parallel schedule generators — the paper's L3 contribution.
//!
//! All generators emit the common IR of [`ir`]; see `DESIGN.md` §4 for the
//! inventory. [`build_schedule`] is the one-stop entry point used by the
//! CLI, the benches and the executor.

pub mod builder;
mod gpipe;
mod interleaved;
pub mod ir;
mod one_f1b;
pub mod stp;
pub mod theory;
pub mod validate;
pub mod zbv;

pub use builder::ShapeCosts;
pub use ir::{CompiledSchedule, Op, PassKind, Placement, Schedule, ScheduleKind, NO_OP};
pub use stp::OffloadParams;
pub use theory::{theory, TheoryInputs, TheoryRow};
pub use validate::{assert_valid, validate, Violation};

use crate::cluster::Topology;

/// Build a schedule of the given kind with uniform chunk costs.
pub fn build_schedule(kind: ScheduleKind, topo: &Topology, n_mb: usize) -> Schedule {
    build_schedule_scaled(kind, topo, n_mb, vec![1.0; topo.chunks()])
}

/// Build a schedule with per-chunk relative compute scales (MLLM chunk
/// imbalance). `chunk_scale.len()` must equal `topo.chunks()` (for the
/// single-chunk-per-device schedules the scales are averaged pairwise).
pub fn build_schedule_scaled(
    kind: ScheduleKind,
    topo: &Topology,
    n_mb: usize,
    chunk_scale: Vec<f64>,
) -> Schedule {
    let costs = ShapeCosts::default();
    match kind {
        ScheduleKind::GPipe => gpipe::build(topo, n_mb),
        ScheduleKind::OneF1B => one_f1b::build(topo, n_mb),
        ScheduleKind::OneF1BInterleaved => interleaved::build(topo, n_mb),
        ScheduleKind::ZbV => zbv::build_zbv(topo, n_mb, costs, chunk_scale),
        ScheduleKind::ZbH1 => zbv::build_zbh1(topo, n_mb, costs),
        ScheduleKind::Stp => stp::build_stp(topo, n_mb, costs, chunk_scale),
        ScheduleKind::StpMemEff => stp::build_stp_memeff(topo, n_mb, costs, chunk_scale),
        ScheduleKind::StpOffload => {
            stp::build_stp_offload(topo, n_mb, costs, chunk_scale, OffloadParams::default())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kind_builds_and_validates() {
        let topo = Topology::new(2, 4, 1);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, 8);
            assert_valid(&s);
        }
    }

    #[test]
    fn every_kind_schedules_complete_work() {
        let topo = Topology::new(1, 2, 1);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, 6);
            let chunks = s.n_chunks();
            assert_eq!(s.count_forwards(), 6 * chunks, "{kind:?}");
            assert_eq!(s.count_backwards(), 6 * chunks, "{kind:?}");
            assert_eq!(s.count_weight_grads(), 6 * chunks, "{kind:?}");
        }
    }
}
