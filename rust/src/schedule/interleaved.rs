//! Interleaved 1F1B (1F1B-I) — Megatron-LM's virtual-stage schedule
//! (Narayanan et al. 2021), the paper's baseline (a).
//!
//! Faithful port of `forward_backward_pipelining_with_interleaving`:
//! device `r` warms up with `min((p-r-1)·2 + (vpp-1)·p, m·vpp)` forward
//! *virtual microbatches*, runs 1F1B over virtual microbatches, then drains
//! backwards. Chunk placement is the parallel flow (`chunk c` on device
//! `c % p`), which is exactly what gives the first device its
//! `(3p-2)·M_a` activation peak (paper Table 1 / Fig. 4).

use crate::cluster::Topology;

use super::ir::{Op, Placement, Schedule, ScheduleKind};

/// Map a forward virtual-microbatch index to `(chunk_on_device, mb)`.
/// Virtual ids walk `p` microbatches of chunk-slot 0, then `p` of slot 1,
/// …, then the next group of `p` microbatches.
fn fwd_item(vid: usize, p: usize, vpp: usize) -> (usize, usize) {
    let group = p * vpp;
    let slot = (vid % group) / p;
    let mb = (vid / group) * p + vid % p;
    (slot, mb)
}

/// Backward virtual-microbatch index → `(chunk_slot, mb)` (slots reversed).
fn bwd_item(vid: usize, p: usize, vpp: usize) -> (usize, usize) {
    let (slot, mb) = fwd_item(vid, p, vpp);
    (vpp - 1 - slot, mb)
}

/// Build the 1F1B-I schedule. Requires `n_mb % p == 0` (Megatron's own
/// constraint for interleaving) and `n_mb >= p`.
pub fn build(topo: &Topology, n_mb: usize) -> Schedule {
    let p = topo.pp;
    let vpp = topo.vpp;
    assert!(n_mb % p == 0, "1F1B-I requires n_mb % pp == 0 (got {n_mb} % {p})");
    assert!(n_mb >= p);
    let total = n_mb * vpp;
    let placement = Placement::Interleaved;
    let mut devices: Vec<Vec<Op>> = vec![Vec::new(); p];

    for r in 0..p {
        let ops = &mut devices[r];
        let warmup = if n_mb == p { total } else { ((p - r - 1) * 2 + (vpp - 1) * p).min(total) };
        // Device r owns chunk slots {0..vpp} → global chunk = slot*p + r.
        let chunk_of = |slot: usize| slot * p + r;

        for vid in 0..warmup {
            let (slot, mb) = fwd_item(vid, p, vpp);
            ops.push(Op::f(chunk_of(slot), mb));
        }
        let mut bwd_vid = 0usize;
        for vid in warmup..total {
            let (fslot, fmb) = fwd_item(vid, p, vpp);
            ops.push(Op::f(chunk_of(fslot), fmb));
            let (bslot, bmb) = bwd_item(bwd_vid, p, vpp);
            ops.push(Op::b_full(chunk_of(bslot), bmb));
            bwd_vid += 1;
        }
        while bwd_vid < total {
            let (bslot, bmb) = bwd_item(bwd_vid, p, vpp);
            ops.push(Op::b_full(chunk_of(bslot), bmb));
            bwd_vid += 1;
        }
    }

    Schedule { kind: ScheduleKind::OneF1BInterleaved, topo: *topo, n_mb, placement, devices }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_id_mapping() {
        // p=4, vpp=2: vids 0..3 -> slot0 mbs 0..3; 4..7 -> slot1 mbs 0..3;
        // 8..11 -> slot0 mbs 4..7.
        assert_eq!(fwd_item(0, 4, 2), (0, 0));
        assert_eq!(fwd_item(3, 4, 2), (0, 3));
        assert_eq!(fwd_item(4, 4, 2), (1, 0));
        assert_eq!(fwd_item(7, 4, 2), (1, 3));
        assert_eq!(fwd_item(8, 4, 2), (0, 4));
        assert_eq!(bwd_item(0, 4, 2), (1, 0));
    }

    #[test]
    fn op_counts_complete() {
        let topo = Topology::new(1, 4, 1);
        let s = build(&topo, 8);
        assert_eq!(s.count_forwards(), 8 * topo.chunks());
        assert_eq!(s.count_backwards(), 8 * topo.chunks());
    }

    #[test]
    fn warmup_matches_megatron_formula() {
        let topo = Topology::new(1, 4, 1);
        let s = build(&topo, 8);
        for (r, ops) in s.devices.iter().enumerate() {
            // Leading forwards = warmup Fs plus the first steady-phase F
            // (1F1B runs F-then-B).
            let leading_f = ops.iter().take_while(|o| o.backward_part().is_none()).count();
            assert_eq!(leading_f, (4 - r - 1) * 2 + 4 + 1, "rank {r}");
        }
    }

    #[test]
    fn first_device_peak_in_flight_is_about_3p_minus_2() {
        // Paper Table 1: 1F1B-I peak activation memory (3p-2)·M_a on dev 0.
        // The F-before-B steady ordering transiently holds one more.
        let p = 4;
        let topo = Topology::new(1, p, 1);
        let s = build(&topo, 16);
        let mut in_flight = 0i64;
        let mut peak = 0i64;
        for op in &s.devices[0] {
            if op.forward_part().is_some() {
                in_flight += 1;
            }
            if op.backward_part().is_some() {
                in_flight -= 1;
            }
            peak = peak.max(in_flight);
        }
        let peak = peak as usize;
        assert!((3 * p - 2..=3 * p - 1).contains(&peak), "peak={peak}");
    }

    #[test]
    #[should_panic(expected = "n_mb % pp")]
    fn rejects_ragged_microbatch_count() {
        build(&Topology::new(1, 4, 1), 6);
    }
}
