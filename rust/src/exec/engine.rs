//! The real pipeline executor: runs compiled schedules over a pluggable
//! [`Backend`] with genuine TP All-Reduce and pipeline P2P between
//! threads.
//!
//! One OS thread per (dp replica, pp stage, tp rank). Every TP rank of a
//! stage walks the same per-device op list (collectives stay aligned, the
//! NCCL contract); cross-stage edges are bounded channels per replica; the
//! braided blocks' TP boundary is exactly where
//! [`crate::comm::TpGroup::all_reduce`] runs, so the executor validates
//! the paper's Eq. 1–2 numerics end-to-end. DP replicas each walk their
//! own copy of the compiled schedule over a disjoint shard of the fixed
//! global batch and meet at `optimizer_step`'s gradient all-reduce
//! (replica-index summation order — bit-deterministic, DESIGN.md §14).
//!
//! The op walk consumes [`crate::schedule::CompiledSchedule`] — the same
//! lowered IR the event-driven simulator replays — so sim and exec agree
//! on the per-device op order *by construction* (DESIGN.md §10), and
//! `stp plan --emit-plan` → `stp train --plan` hands the planner's
//! winning candidate straight to this engine.
//!
//! The walks are **zero-copy** (DESIGN.md §11): [`Backend::run`] borrows
//! its inputs, so weights go straight from the parameter tables and
//! activations move in and out of the [`ActivationStore`] without the
//! per-op clones the pre-arena executor paid; the virtual backend's
//! kernel scratch comes from a per-thread workspace arena whose
//! steady-state allocation count must be zero ([`RunReport`] reports it,
//! `tests/train_virtual.rs` asserts it).

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use super::backend::{make_backend, virtual_dims_scaled, Backend, BackendKind, KernelPath};
use super::data::global_mb_index;
use super::rng::Rng;
use super::{ChunkParams, Corpus, LayerGrads};
use crate::cluster::{partition_llm, StagePlan, Topology};
use crate::config::{Manifest, ManifestDims};
use crate::elastic::{rng_key, shard_key, Checkpoint, ChunkShard, FaultPlan};
use crate::memory::{ActKey, ActTag, ActivationStore, OffloadManager};
use crate::model::ModelConfig;
use crate::plan::PlanArtifact;
use crate::runtime::Tensor;
use crate::schedule::{build_schedule, CompiledSchedule, Op, PassKind, ScheduleKind};
use crate::Result;

/// Training-run configuration for the executor.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Which execution backend computes the units.
    pub backend: BackendKind,
    /// Virtual-backend kernel implementation (blocked hot path vs the
    /// naive reference oracle — bit-equal, see DESIGN.md §11).
    pub kernels: KernelPath,
    /// Directory with `manifest.json` + HLO artifacts (PJRT backend).
    pub artifacts_dir: PathBuf,
    /// Schedule to build when no plan artifact is given.
    pub schedule: ScheduleKind,
    /// Microbatches per replica per optimizer step (overridden by a
    /// plan artifact).
    pub n_mb: usize,
    /// Data-parallel replica count. `None` follows the plan artifact's
    /// `dp` (1 without a plan); `Some(d)` overrides it — dp never
    /// changes the per-replica schedule, only how many copies walk it.
    pub dp: Option<usize>,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Print per-step losses.
    pub verbose: bool,
    /// Virtual-backend model dims; `None` derives a miniature default
    /// scaled by `virtual_scale` (the PJRT backend always reads dims
    /// from the manifest).
    pub dims: Option<ManifestDims>,
    /// Width multiplier for the derived virtual dims (≥ 1; see
    /// [`super::virtual_dims_scaled`] / `stp train --virtual-scale`).
    pub virtual_scale: f64,
    /// Planner handoff: run this plan's schedule, topology and layer
    /// split instead of the `schedule`/`n_mb`/dims-derived defaults.
    pub plan: Option<PlanArtifact>,
    /// Deterministic fault schedule. A dead rank halts the segment at
    /// that step's boundary (a consistent cut — no step is half-applied);
    /// stragglers stretch wall-clock at op boundaries, numerics untouched.
    pub faults: Option<FaultPlan>,
    /// Write an `stp-ckpt-v2` snapshot here when the segment ends,
    /// whether it ran to completion or halted at a fault.
    pub checkpoint_dir: Option<PathBuf>,
    /// Keep only the newest K `ckpt-step-N.json` snapshots after a
    /// successful write (`latest.json` is never pruned). `None`: keep
    /// all.
    pub keep_checkpoints: Option<usize>,
    /// Resume from this snapshot instead of initializing at step 0.
    pub resume: Option<Checkpoint>,
    /// GEMM worker-pool threads per device thread (virtual backend,
    /// `--kernels simd` only). `0` auto-sizes from the host's cores
    /// divided by the thread grid, clamped to [1, 8].
    pub workers: usize,
}

impl TrainConfig {
    /// A virtual-backend config with miniature dims — the offline
    /// default used by tests and the e2e example.
    pub fn virtual_default() -> TrainConfig {
        TrainConfig {
            backend: BackendKind::Virtual,
            kernels: KernelPath::Blocked,
            artifacts_dir: PathBuf::from("artifacts/e2e"),
            schedule: ScheduleKind::Stp,
            n_mb: 4,
            dp: None,
            steps: 4,
            lr: 0.1,
            seed: 42,
            verbose: false,
            dims: None,
            virtual_scale: 1.0,
            plan: None,
            faults: None,
            checkpoint_dir: None,
            keep_checkpoints: None,
            resume: None,
            workers: 0,
        }
    }
}

/// One optimizer step's outcome.
#[derive(Debug, Clone)]
pub struct StepStat {
    pub step: usize,
    pub mean_loss: f32,
    pub secs: f64,
}

/// Whole-run report.
#[derive(Debug)]
pub struct RunReport {
    pub backend: BackendKind,
    pub steps: Vec<StepStat>,
    /// Peak activation bytes per PP stage (max over its TP ranks).
    pub peak_activation_bytes: Vec<usize>,
    /// Peak kernel-workspace bytes per PP stage (max over its TP ranks;
    /// all zero on the reference path and PJRT).
    pub workspace_peak_bytes: Vec<usize>,
    /// Workspace heap allocations after the warm-up step, summed over
    /// every device thread — the arena contract says this is 0 for any
    /// run with ≥ 2 steps.
    pub workspace_steady_allocs: u64,
    /// Total bytes all-reduced across all TP groups.
    pub allreduce_bytes: u64,
    /// Total backend unit executions.
    pub executions: u64,
    pub wall_secs: f64,
    /// The op sequence each stage actually executed in step 0 (tp rank
    /// 0's log) — the handoff evidence `tests/train_virtual.rs` compares
    /// against the simulator's [`CompiledSchedule`] order.
    pub device_ops: Vec<Vec<Op>>,
    /// Absolute step a dead-rank fault halted the segment at (`None`:
    /// the segment ran to its planned end).
    pub interrupted_at: Option<usize>,
    /// Pipeline stage whose device died, when `interrupted_at` is set.
    pub fault_stage: Option<usize>,
    /// DP replica whose device died, when `interrupted_at` is set — the
    /// coordinate the shrink-dp recovery quarantines.
    pub fault_replica: Option<usize>,
    /// The snapshot written at segment end (requires `checkpoint_dir`).
    pub checkpoint_path: Option<PathBuf>,
}

impl RunReport {
    pub fn first_loss(&self) -> f32 {
        self.steps.first().map(|s| s.mean_loss).unwrap_or(f32::NAN)
    }
    pub fn last_loss(&self) -> f32 {
        self.steps.last().map(|s| s.mean_loss).unwrap_or(f32::NAN)
    }
    pub fn throughput_samples_per_sec(&self, n_mb: usize, mb: usize) -> f64 {
        let total: f64 = self.steps.iter().map(|s| s.secs).sum();
        (self.steps.len() * n_mb * mb) as f64 / total
    }
    /// Steady-state trained tokens per wall-clock second (`mb · seq`
    /// tokens per microbatch) — the `stp bench train` headline number.
    /// When the run has more than one step, step 0 is excluded: it pays
    /// thread spawn and workspace-arena warm-up.
    pub fn tokens_per_sec(&self, n_mb: usize, mb: usize, seq: usize) -> f64 {
        let skip = usize::from(self.steps.len() > 1);
        let secs: f64 = self.steps.iter().skip(skip).map(|s| s.secs).sum();
        ((self.steps.len() - skip) * n_mb * mb * seq) as f64 / secs.max(1e-12)
    }
}

/// Per-thread slice of the run configuration (what [`DeviceThread`]
/// actually needs after `train` has resolved plan/dims overrides).
#[derive(Debug, Clone, Copy)]
struct RunParams {
    backend: BackendKind,
    kernels: KernelPath,
    /// Microbatches per replica per step.
    n_mb: usize,
    /// Data-parallel replica count (gradient all-reduce divisor is
    /// `dp · n_mb` — the fixed global batch).
    dp: usize,
    /// First step this segment runs (the resume point; 0 for fresh runs).
    start_step: usize,
    /// One past the last step (already clamped to any dead-rank halt).
    end_step: usize,
    lr: f32,
    seed: u64,
    /// Send parameter shards + RNG positions back for a checkpoint.
    snapshot: bool,
    /// Resolved GEMM worker-pool width per device thread.
    workers: usize,
}

/// What a device thread hands back when its walk completes.
struct ThreadStats {
    execs: u64,
    /// Workspace heap allocations after step 0 (0 in steady state).
    steady_allocs: u64,
}

/// Resolve the run's model dims (and, for PJRT, the manifest).
fn resolve_dims(cfg: &TrainConfig) -> Result<(Option<Manifest>, ManifestDims)> {
    match cfg.backend {
        BackendKind::Pjrt => {
            let m = Manifest::load(&cfg.artifacts_dir)?;
            let dims = m.dims.clone();
            Ok((Some(m), dims))
        }
        BackendKind::Virtual => {
            let dims = match (&cfg.dims, &cfg.plan) {
                (Some(d), _) => d.clone(),
                (None, Some(p)) => {
                    virtual_dims_scaled(p.tp, p.pp, p.vpp, p.total_layers(), cfg.virtual_scale)
                }
                (None, None) => virtual_dims_scaled(2, 2, 2, 8, cfg.virtual_scale),
            };
            Ok((None, dims))
        }
    }
}

/// Run synchronous pipeline training per `cfg`. Blocks until done.
pub fn train(cfg: &TrainConfig) -> Result<RunReport> {
    let (manifest, dims) = resolve_dims(cfg)?;

    // Topology, schedule and layer split: the plan artifact wins (the
    // planner → executor handoff), else dims + cfg defaults.
    let (topo, schedule, plan, n_mb) = match &cfg.plan {
        Some(p) => {
            anyhow::ensure!(
                dims.tp == p.tp,
                "dims carry tp={} but the plan needs tp={}",
                dims.tp,
                p.tp
            );
            anyhow::ensure!(
                dims.layers == p.total_layers(),
                "dims carry {} layers but the plan splits {}",
                dims.layers,
                p.total_layers()
            );
            (p.topology(), p.build_schedule(), p.stage_plan(), p.n_mb)
        }
        None => {
            let topo = Topology { tp: dims.tp, pp: dims.pp, dp: 1, cp: 1, vpp: dims.vpp };
            let schedule = build_schedule(cfg.schedule, &topo, cfg.n_mb);
            // Some builders normalize the topology (1f1b/zb-h1 force
            // vpp = 1) — the chunk plan must follow the schedule's grid,
            // not the requested one.
            let topo = schedule.topo;
            let mc = ModelConfig {
                name: "exec".into(),
                layers: dims.layers,
                hidden: dims.d,
                q_heads: dims.q_heads,
                kv_heads: dims.kv_heads,
                ffn: dims.ffn,
                vocab: dims.vocab,
                dtype_bytes: 4,
            };
            let plan = even_plan(&mc, topo.chunks());
            (topo, schedule, plan, cfg.n_mb)
        }
    };
    crate::schedule::assert_valid(&schedule);
    let sched_kind = schedule.kind;
    let compiled = Arc::new(schedule.compile());

    // DP replica count: explicit override, else the plan artifact's dp
    // (1 without a plan — `topo.dp` is 1 on the no-plan path). The
    // schedule above is per-replica either way.
    let dp = cfg.dp.unwrap_or(topo.dp).max(1);

    let start_step = cfg.resume.as_ref().map(|ck| ck.step).unwrap_or(0);
    if let Some(ck) = &cfg.resume {
        ck.validate()?;
        anyhow::ensure!(
            ck.tp == topo.tp && ck.pp == topo.pp && ck.vpp == topo.vpp,
            "resume: checkpoint shape tp{}-pp{}-v{} != run shape tp{}-pp{}-v{}",
            ck.tp,
            ck.pp,
            ck.vpp,
            topo.tp,
            topo.pp,
            topo.vpp
        );
        anyhow::ensure!(ck.dp == dp, "resume: checkpoint dp {} != run dp {dp}", ck.dp);
        anyhow::ensure!(
            ck.n_mb == n_mb,
            "resume: checkpoint n_mb {} != run n_mb {n_mb}",
            ck.n_mb
        );
        anyhow::ensure!(
            ck.seed == cfg.seed,
            "resume: checkpoint seed {} != run seed {}",
            ck.seed,
            cfg.seed
        );
        anyhow::ensure!(
            ck.dims == dims,
            "resume: checkpoint dims do not match the run's resolved dims"
        );
        let split: Vec<usize> = plan.chunks.iter().map(|c| c.lm_layers).collect();
        anyhow::ensure!(
            ck.stage_layers == split,
            "resume: checkpoint split {:?} != run split {split:?}",
            ck.stage_layers
        );
        let vit_split: Vec<usize> = plan.chunks.iter().map(|c| c.vit_layers).collect();
        anyhow::ensure!(
            ck.stage_vit_layers == vit_split,
            "resume: checkpoint ViT split {:?} != run ViT split {vit_split:?}",
            ck.stage_vit_layers
        );
    }
    let end_step = start_step + cfg.steps;

    // Elastic envelope: fault feasibility, fault-clamped end, snapshots.
    // An unfireable event (stage/replica off-grid, step past the end) is
    // rejected here — before any thread spawns — instead of silently
    // never triggering.
    if let Some(f) = &cfg.faults {
        f.validate()?;
        f.validate_for(topo.pp, dp, end_step)?;
    }
    let halt = cfg.faults.as_ref().and_then(|f| f.first_death_in(start_step, end_step));
    let run_end = halt.map(|(s, _, _)| s).unwrap_or(end_step);

    // Worker-pool width per device thread: explicit, or the host's cores
    // spread over the (dp × pp × tp) thread grid so the pools never
    // oversubscribe the machine.
    let workers = if cfg.workers > 0 {
        cfg.workers
    } else {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        (cores / (dp * topo.pp * topo.tp).max(1)).clamp(1, 8)
    };

    let run = RunParams {
        backend: cfg.backend,
        kernels: cfg.kernels,
        n_mb,
        dp,
        start_step,
        end_step: run_end,
        lr: cfg.lr,
        seed: cfg.seed,
        snapshot: cfg.checkpoint_dir.is_some(),
        workers,
    };
    let faults = cfg.faults.clone().map(Arc::new);
    let resume = cfg.resume.clone().map(Arc::new);

    let corpus = Arc::new(Corpus::new(dims.vocab, cfg.seed));

    // Communication fabric, one P2P mesh + TP-group row per replica.
    // Channel maps key by (replica, chunk, rank).
    let n_chunks = compiled.n_chunks;
    let mut fwd_tx: HashMap<(usize, usize, usize), SyncSender<Tensor>> = HashMap::new();
    let mut fwd_rx: HashMap<(usize, usize, usize), Receiver<Tensor>> = HashMap::new();
    let mut bwd_tx: HashMap<(usize, usize, usize), SyncSender<Tensor>> = HashMap::new();
    let mut bwd_rx: HashMap<(usize, usize, usize), Receiver<Tensor>> = HashMap::new();
    for q in 0..dp {
        for c in 0..n_chunks - 1 {
            for r in 0..topo.tp {
                let (tx, rx) = crate::comm::P2p::channel(n_mb.max(4));
                fwd_tx.insert((q, c, r), tx);
                fwd_rx.insert((q, c, r), rx);
                let (tx, rx) = crate::comm::P2p::channel(n_mb.max(4));
                bwd_tx.insert((q, c + 1, r), tx);
                bwd_rx.insert((q, c + 1, r), rx);
            }
        }
    }
    // TP groups: [replica][stage]. DP groups: [stage][rank], each of
    // size dp — its member rank IS the replica index, so the summation
    // order inside `TpGroup::all_reduce` is replica-index order: fixed,
    // interleaving-independent, bit-deterministic. At dp = 1 every DP
    // group is size 1 and `all_reduce` returns before touching bytes or
    // counters, so single-replica runs stay bit- and metrics-identical
    // to the pre-DP engine.
    let tp_groups: Vec<Vec<Arc<crate::comm::TpGroup>>> = (0..dp)
        .map(|_| (0..topo.pp).map(|_| crate::comm::TpGroup::new(topo.tp)).collect())
        .collect();
    let dp_groups: Vec<Vec<Arc<crate::comm::TpGroup>>> = (0..topo.pp)
        .map(|_| (0..topo.tp).map(|_| crate::comm::TpGroup::new(dp)).collect())
        .collect();
    let (loss_tx, loss_rx) = std::sync::mpsc::channel::<(usize, usize, f32)>();
    // (stage, activation-store peak bytes, workspace peak bytes)
    let (stat_tx, stat_rx) = std::sync::mpsc::channel::<(usize, usize, usize)>();
    let (ops_tx, ops_rx) = std::sync::mpsc::channel::<(usize, Vec<Op>)>();
    // (replica, stage, rank, the thread's chunk shards, RNG position)
    let (ckpt_tx, ckpt_rx) =
        std::sync::mpsc::channel::<(usize, usize, usize, Vec<ChunkShard>, u64)>();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for replica in 0..dp {
        for stage in 0..topo.pp {
            for rank in 0..topo.tp {
                let ctx = DeviceCtx {
                    replica,
                    stage,
                    rank,
                    dims: dims.clone(),
                    manifest: manifest.clone(),
                    compiled: compiled.clone(),
                    plan: plan.clone(),
                    tp: tp_groups[replica][stage].clone(),
                    dp_group: dp_groups[stage][rank].clone(),
                    corpus: corpus.clone(),
                    run,
                    faults: faults.clone(),
                    resume: resume.clone(),
                };
                // Move this thread's channel endpoints in.
                let mut my_fwd_tx = HashMap::new();
                let mut my_fwd_rx = HashMap::new();
                let mut my_bwd_tx = HashMap::new();
                let mut my_bwd_rx = HashMap::new();
                for c in 0..n_chunks {
                    if compiled.chunk_dev[c] as usize == stage {
                        if c + 1 < n_chunks {
                            my_fwd_tx.insert(c, fwd_tx.remove(&(replica, c, rank)).unwrap());
                            my_bwd_rx.insert(c, bwd_rx.remove(&(replica, c + 1, rank)).unwrap());
                        }
                        if c > 0 {
                            my_fwd_rx.insert(c, fwd_rx.remove(&(replica, c - 1, rank)).unwrap());
                            my_bwd_tx.insert(c, bwd_tx.remove(&(replica, c, rank)).unwrap());
                        }
                    }
                }
                let loss_tx = loss_tx.clone();
                let stat_tx = stat_tx.clone();
                let ops_tx = ops_tx.clone();
                let ckpt_tx = ckpt_tx.clone();
                handles.push(std::thread::spawn(move || -> Result<ThreadStats> {
                    let mut dev = DeviceThread::new(
                        ctx,
                        my_fwd_tx,
                        my_fwd_rx,
                        my_bwd_tx,
                        my_bwd_rx,
                        loss_tx,
                    )?;
                    let stats = dev.run()?;
                    let ws = dev.backend.workspace_stats();
                    let ws_peak = ws.map(|s| s.peak_bytes).unwrap_or(0);
                    stat_tx.send((dev.ctx.stage, dev.store.peak_bytes(), ws_peak)).ok();
                    if dev.ctx.replica == 0 && dev.ctx.rank == 0 {
                        ops_tx.send((dev.ctx.stage, std::mem::take(&mut dev.op_log))).ok();
                    }
                    if dev.ctx.run.snapshot {
                        let mut shards: Vec<ChunkShard> = dev
                            .params
                            .iter()
                            .map(|(&c, p)| ChunkShard {
                                replica: dev.ctx.replica,
                                chunk: c,
                                rank: dev.ctx.rank,
                                vit_layers: p.layers[..p.n_vit].to_vec(),
                                layers: p.layers[p.n_vit..].to_vec(),
                                emb: p.emb.clone(),
                                head: p.head.clone(),
                            })
                            .collect();
                        shards.sort_by_key(|s| s.chunk);
                        ckpt_tx
                            .send((
                                dev.ctx.replica,
                                dev.ctx.stage,
                                dev.ctx.rank,
                                shards,
                                dev.rng.state(),
                            ))
                            .ok();
                    }
                    Ok(stats)
                }));
            }
        }
    }
    drop(loss_tx);
    drop(stat_tx);
    drop(ops_tx);
    drop(ckpt_tx);

    // Collect per-step losses from every replica's head owner (tp rank 0
    // of the last chunk's stage reports each microbatch loss). Losses
    // bucket per (step, replica) in arrival order, and the step mean sums
    // the per-replica partial sums in replica-index order — so the value
    // is interleaving-independent, and at dp = 1 it reduces bit-exactly
    // to the single-replica arrival-order sum. Steps are absolute; a
    // resumed segment's first entry is `start_step`.
    let seg_steps = run_end - start_step;
    let mut step_losses: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); dp]; seg_steps];
    let mut step_n: Vec<usize> = vec![0; seg_steps];
    let mut step_t: Vec<f64> = vec![0.0; seg_steps];
    let mut last = t0.elapsed().as_secs_f64();
    let step_mean = |buckets: &[Vec<f32>], n: usize| -> f32 {
        buckets.iter().map(|ls| ls.iter().sum::<f32>()).sum::<f32>() / n.max(1) as f32
    };
    for (step, replica, loss) in loss_rx {
        let i = step - start_step;
        step_losses[i][replica].push(loss);
        step_n[i] += 1;
        if step_n[i] == dp * n_mb {
            let now = t0.elapsed().as_secs_f64();
            step_t[i] = now - last;
            last = now;
            if cfg.verbose {
                let mean = step_mean(&step_losses[i], step_n[i]);
                eprintln!("step {step:4}  loss {mean:.4}  ({:.2}s)", step_t[i]);
            }
        }
    }

    let mut executions = 0;
    let mut steady_allocs = 0;
    for h in handles {
        let stats = h.join().map_err(|_| anyhow::anyhow!("device thread panicked"))??;
        executions += stats.execs;
        steady_allocs += stats.steady_allocs;
    }
    let mut peaks = vec![0usize; topo.pp];
    let mut ws_peaks = vec![0usize; topo.pp];
    for (stage, peak, ws_peak) in stat_rx {
        peaks[stage] = peaks[stage].max(peak);
        ws_peaks[stage] = ws_peaks[stage].max(ws_peak);
    }
    let mut device_ops = vec![Vec::new(); topo.pp];
    for (stage, ops) in ops_rx {
        device_ops[stage] = ops;
    }

    // Assemble and write the `stp-ckpt-v2` snapshot. Threads stopped at
    // the `run_end` step boundary (sgd_step zeroed every accumulator),
    // so parameters + RNG positions are the complete engine state.
    let mut checkpoint_path = None;
    if let Some(dir) = &cfg.checkpoint_dir {
        let mut shard_map = BTreeMap::new();
        let mut rng_states = BTreeMap::new();
        for (replica, stage, rank, shards, rng_state) in ckpt_rx {
            rng_states.insert(rng_key(replica, stage, rank), rng_state);
            for s in shards {
                shard_map.insert(shard_key(s.replica, s.chunk, s.rank), s);
            }
        }
        let ck = Checkpoint {
            step: run_end,
            seed: cfg.seed,
            n_mb,
            dp,
            schedule: sched_kind.name().to_string(),
            tp: topo.tp,
            pp: topo.pp,
            vpp: topo.vpp,
            dims: dims.clone(),
            stage_layers: plan.chunks.iter().map(|c| c.lm_layers).collect(),
            stage_vit_layers: plan.chunks.iter().map(|c| c.vit_layers).collect(),
            data_cursor: run_end,
            optimizer: "sgd".into(),
            rng_states,
            shards: shard_map,
        };
        ck.validate()?;
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("creating checkpoint dir {}: {e}", dir.display()))?;
        let path = dir.join(format!("ckpt-step-{run_end}.json"));
        ck.save(&path)?;
        // A stable alias the CLI's `--resume latest` convention reads.
        ck.save(&dir.join("latest.json"))?;
        // Retention runs only after both writes landed — a failed write
        // never costs an older, still-good snapshot.
        if let Some(keep) = cfg.keep_checkpoints {
            crate::elastic::prune_snapshots(dir, keep)?;
        }
        checkpoint_path = Some(path);
    }

    let steps = step_losses
        .iter()
        .enumerate()
        .map(|(i, buckets)| StepStat {
            step: start_step + i,
            mean_loss: step_mean(buckets, step_n[i]),
            secs: step_t[i],
        })
        .collect();

    let tp_bytes: u64 = tp_groups.iter().flatten().map(|g| g.bytes_reduced()).sum();
    let dp_bytes: u64 = dp_groups.iter().flatten().map(|g| g.bytes_reduced()).sum();
    Ok(RunReport {
        backend: cfg.backend,
        steps,
        peak_activation_bytes: peaks,
        workspace_peak_bytes: ws_peaks,
        workspace_steady_allocs: steady_allocs,
        allreduce_bytes: tp_bytes + dp_bytes,
        executions,
        wall_secs: t0.elapsed().as_secs_f64(),
        device_ops,
        interrupted_at: halt.map(|(s, _, _)| s),
        fault_stage: halt.map(|(_, st, _)| st),
        fault_replica: halt.map(|(_, _, q)| q),
        checkpoint_path,
    })
}

/// Even layer split (the AOT config guarantees divisibility).
fn even_plan(mc: &ModelConfig, n_chunks: usize) -> StagePlan {
    if mc.layers % n_chunks == 0 {
        let mut plan = partition_llm(mc, n_chunks);
        let per = mc.layers / n_chunks;
        for (i, c) in plan.chunks.iter_mut().enumerate() {
            c.lm_layers = per;
            c.has_embed = i == 0;
            c.has_head = i == n_chunks - 1;
        }
        plan
    } else {
        partition_llm(mc, n_chunks)
    }
}

struct DeviceCtx {
    /// DP replica this thread belongs to (0 at dp = 1).
    replica: usize,
    stage: usize,
    rank: usize,
    dims: ManifestDims,
    manifest: Option<Manifest>,
    compiled: Arc<CompiledSchedule>,
    plan: StagePlan,
    tp: Arc<crate::comm::TpGroup>,
    /// DP gradient all-reduce group for this (stage, rank); the member
    /// rank is `replica`. Size 1 (a no-op) at dp = 1.
    dp_group: Arc<crate::comm::TpGroup>,
    corpus: Arc<Corpus>,
    run: RunParams,
    faults: Option<Arc<FaultPlan>>,
    resume: Option<Arc<Checkpoint>>,
}

struct DeviceThread {
    ctx: DeviceCtx,
    backend: Box<dyn Backend>,
    params: HashMap<usize, ChunkParams>,
    store: ActivationStore,
    offload: OffloadManager,
    fwd_tx: HashMap<usize, SyncSender<Tensor>>,
    fwd_rx: HashMap<usize, Receiver<Tensor>>,
    bwd_tx: HashMap<usize, SyncSender<Tensor>>,
    bwd_rx: HashMap<usize, Receiver<Tensor>>,
    loss_tx: std::sync::mpsc::Sender<(usize, usize, f32)>,
    step: usize,
    /// Ops executed in step 0 (rank 0 reports them for the handoff check).
    op_log: Vec<Op>,
    /// This thread's reserved stream: one draw per step, position
    /// snapshotted into `stp-ckpt-v2` and restored bit-exactly on resume.
    rng: Rng,
}

/// Rebuild one chunk's parameters from a checkpoint shard (ViT prefix
/// first, then LM layers — the in-memory layout `ChunkParams::init`
/// produces). Gradient accumulators come back as zeros: snapshots are
/// taken at step boundaries, where `sgd_step` has just zeroed them.
fn restore_chunk(shard: &ChunkShard) -> ChunkParams {
    let n_vit = shard.vit_layers.len();
    let mut layers: Vec<_> = shard.vit_layers.clone();
    layers.extend(shard.layers.iter().cloned());
    let grads = layers.iter().map(LayerGrads::zeros_like).collect();
    let emb = shard.emb.clone();
    let head = shard.head.clone();
    let emb_grad = emb.as_ref().map(|t| vec![0.0; t.len()]);
    let head_grad = head.as_ref().map(|t| vec![0.0; t.len()]);
    ChunkParams { layers, n_vit, grads, emb, emb_grad, head, head_grad }
}

/// Accumulate one attention unit's weight gradients. A free function
/// over the thread's disjoint fields so activation tensors can stay
/// *borrowed* from the store while the backend runs.
fn attn_weight_grad(
    backend: &mut dyn Backend,
    params: &mut HashMap<usize, ChunkParams>,
    chunk: usize,
    l: usize,
    x: &Tensor,
    dy: &Tensor,
) -> Result<()> {
    let p = &params[&chunk].layers[l];
    let out = backend.run("attn_bwd_w", &[x, dy, &p.gamma1, &p.wq, &p.wk, &p.wv, &p.wo])?;
    let g = &mut params.get_mut(&chunk).unwrap().grads[l];
    ChunkParams::accumulate(&mut g.gamma1, &out[0]);
    ChunkParams::accumulate(&mut g.wq, &out[1]);
    ChunkParams::accumulate(&mut g.wk, &out[2]);
    ChunkParams::accumulate(&mut g.wv, &out[3]);
    ChunkParams::accumulate(&mut g.wo, &out[4]);
    for t in out {
        backend.recycle(t);
    }
    Ok(())
}

/// Accumulate one MLP unit's weight gradients (see [`attn_weight_grad`]).
fn mlp_weight_grad(
    backend: &mut dyn Backend,
    params: &mut HashMap<usize, ChunkParams>,
    chunk: usize,
    l: usize,
    y: &Tensor,
    dz: &Tensor,
) -> Result<()> {
    let p = &params[&chunk].layers[l];
    let out = backend.run("mlp_bwd_w", &[y, dz, &p.gamma2, &p.wg, &p.wu, &p.wd])?;
    let g = &mut params.get_mut(&chunk).unwrap().grads[l];
    ChunkParams::accumulate(&mut g.gamma2, &out[0]);
    ChunkParams::accumulate(&mut g.wg, &out[1]);
    ChunkParams::accumulate(&mut g.wu, &out[2]);
    ChunkParams::accumulate(&mut g.wd, &out[3]);
    for t in out {
        backend.recycle(t);
    }
    Ok(())
}

impl DeviceThread {
    fn new(
        ctx: DeviceCtx,
        fwd_tx: HashMap<usize, SyncSender<Tensor>>,
        fwd_rx: HashMap<usize, Receiver<Tensor>>,
        bwd_tx: HashMap<usize, SyncSender<Tensor>>,
        bwd_rx: HashMap<usize, Receiver<Tensor>>,
        loss_tx: std::sync::mpsc::Sender<(usize, usize, f32)>,
    ) -> Result<DeviceThread> {
        let backend = make_backend(
            ctx.run.backend,
            ctx.manifest.as_ref(),
            &ctx.dims,
            ctx.run.kernels,
            ctx.run.workers,
        )?;
        let mut params = HashMap::new();
        for c in 0..ctx.compiled.n_chunks {
            if ctx.compiled.chunk_dev[c] as usize == ctx.stage {
                let content = ctx.plan.chunks[c];
                let cp = match &ctx.resume {
                    Some(ck) => {
                        restore_chunk(ck.shard(ctx.replica, c, ctx.rank).ok_or_else(|| {
                            anyhow::anyhow!(
                                "resume: checkpoint missing shard d{}c{c}r{}",
                                ctx.replica,
                                ctx.rank
                            )
                        })?)
                    }
                    // Seed keying is replica-independent: every replica
                    // initializes bit-identical weights, the invariant
                    // that lets shrink-dp clone a survivor's shards.
                    None => ChunkParams::init(
                        &ctx.dims,
                        c,
                        ctx.rank,
                        content.vit_layers,
                        content.lm_layers,
                        content.has_embed,
                        content.has_head,
                        ctx.run.seed,
                    ),
                };
                params.insert(c, cp);
            }
        }
        // Saved stream position if the checkpoint has one for this
        // (replica, stage, rank); otherwise derive and fast-forward — a
        // migrated checkpoint renumbers coordinates, so its RNG map is
        // empty and the two paths must land on the same position.
        let rng = match ctx
            .resume
            .as_ref()
            .and_then(|ck| ck.rng_states.get(&rng_key(ctx.replica, ctx.stage, ctx.rank)))
        {
            Some(&state) => Rng::from_state(state),
            None => {
                let mut r = Rng::for_purpose(
                    ctx.run.seed,
                    ctx.stage as u64,
                    ctx.rank as u64,
                    99 + ctx.replica as u64,
                );
                r.advance(ctx.run.start_step as u64);
                r
            }
        };
        Ok(DeviceThread {
            ctx,
            backend,
            params,
            store: ActivationStore::new(),
            offload: OffloadManager::new(),
            fwd_tx,
            fwd_rx,
            bwd_tx,
            bwd_rx,
            loss_tx,
            step: 0,
            op_log: Vec::new(),
            rng,
        })
    }

    fn ws_fresh_allocs(&self) -> u64 {
        self.backend.workspace_stats().map(|s| s.fresh_allocs).unwrap_or(0)
    }

    fn run(&mut self) -> Result<ThreadStats> {
        let lo = self.ctx.compiled.dev_start[self.ctx.stage] as usize;
        let hi = self.ctx.compiled.dev_start[self.ctx.stage + 1] as usize;
        let start = self.ctx.run.start_step;
        let mut warm_allocs = 0;
        for step in start..self.ctx.run.end_step {
            self.step = step;
            // Op-boundary fault observation: stragglers stretch wall-clock
            // (numerics untouched — fault-free bit-parity holds by
            // construction); dead ranks were lowered into `end_step` by
            // `train`, so every thread stops at the same consistent cut.
            let slow = self
                .ctx
                .faults
                .as_ref()
                .map(|f| f.straggler_factor(step, self.ctx.stage, self.ctx.replica))
                .unwrap_or(1.0);
            for j in lo..hi {
                let op = self.ctx.compiled.ops[j];
                if step == start && self.ctx.rank == 0 {
                    self.op_log.push(op);
                }
                if slow > 1.0 {
                    std::thread::sleep(std::time::Duration::from_micros(
                        ((slow - 1.0) * 100.0) as u64,
                    ));
                }
                self.exec_op(&op)?;
            }
            self.optimizer_step()?;
            // One reserved draw per step: the position (not the values)
            // is the state `stp-ckpt-v2` must round-trip.
            self.rng.advance(1);
            if step == start {
                // The segment's first step populates the workspace pools;
                // everything after must recycle (the zero-steady-state-
                // alloc contract).
                warm_allocs = self.ws_fresh_allocs();
            }
        }
        Ok(ThreadStats {
            execs: self.backend.executions(),
            steady_allocs: self.ws_fresh_allocs() - warm_allocs,
        })
    }

    fn exec_op(&mut self, op: &Op) -> Result<()> {
        match *op {
            Op::Pass { kind: PassKind::F, chunk, mb } => self.forward(chunk, mb),
            Op::Pass { kind: PassKind::B, chunk, mb } => self.backward(chunk, mb, false),
            Op::Pass { kind: PassKind::BFull, chunk, mb } => self.backward(chunk, mb, true),
            Op::Pass { kind: PassKind::W, chunk, mb } => self.weight_pass(chunk, mb),
            Op::Braided { f_chunk, f_mb, b_chunk, b_mb, b_full } => {
                // Numerically a braid is F then B (true interleaving is a
                // wall-clock property the simulator models; dependencies
                // permit any serial order — validator-checked).
                self.forward(f_chunk, f_mb)?;
                self.backward(b_chunk, b_mb, b_full)
            }
            Op::BraidedFW { f_chunk, f_mb, w_chunk, w_mb } => {
                self.forward(f_chunk, f_mb)?;
                self.weight_pass(w_chunk, w_mb)
            }
            Op::Offload { chunk, mb, ratio } => {
                self.store.offload_matching(&mut self.offload, chunk, mb, ratio);
                Ok(())
            }
            Op::Reload { chunk, mb } => {
                self.store.reload_all(&mut self.offload, chunk, mb);
                Ok(())
            }
        }
    }

    /// The global microbatch id this thread's local `mb` maps to — the
    /// corpus keys on it, so DP replicas shard the fixed global batch.
    fn global_mb(&self, mb: usize) -> usize {
        global_mb_index(self.ctx.replica, self.ctx.run.n_mb, mb)
    }

    /// Total layers in a chunk's parameter table: ViT prefix + LM.
    fn chunk_layers(content: crate::cluster::ChunkContent) -> usize {
        content.vit_layers + content.lm_layers
    }

    fn forward(&mut self, chunk: usize, mb: usize) -> Result<()> {
        let content = self.ctx.plan.chunks[chunk];
        let mut x = if content.has_embed {
            // Fixed tiny corpus: the e2e demo overfits a constant set of
            // microbatches so the loss curve is step-comparable.
            let (mb_rows, seq) = (self.ctx.dims.mb, self.ctx.dims.seq);
            let (tokens, _) = self.ctx.corpus.batch(0, self.global_mb(mb), mb_rows, seq);
            let tok = Tensor::i32(tokens, &[mb_rows, seq]);
            let emb = self.params[&chunk].emb.as_ref().unwrap();
            let out = self.backend.run("embed_fwd", &[&tok, emb])?.remove(0);
            // Stash tokens for the embedding backward.
            self.store.put(ActKey { chunk, mb, layer: usize::MAX, tag: ActTag::ChunkOut }, tok);
            out
        } else {
            self.fwd_rx
                .get(&chunk)
                .ok_or_else(|| anyhow::anyhow!("no fwd rx for chunk {chunk}"))?
                .recv()
                .map_err(|_| anyhow::anyhow!("fwd channel into chunk {chunk} closed"))?
        };

        for l in 0..Self::chunk_layers(content) {
            let p = &self.params[&chunk].layers[l];
            let mut partial = self
                .backend
                .run("attn_fwd", &[&x, &p.gamma1, &p.wq, &p.wk, &p.wv, &p.wo])?
                .remove(0);
            // The unit ran on a borrow, so `x` moves into the store
            // without a copy.
            self.store.put(ActKey { chunk, mb, layer: l, tag: ActTag::AttnIn }, x);
            self.ctx.tp.all_reduce_tensor(self.ctx.rank, &mut partial)?;
            let y = partial;
            let p = &self.params[&chunk].layers[l];
            let mut partial = self
                .backend
                .run("mlp_fwd", &[&y, &p.gamma2, &p.wg, &p.wu, &p.wd])?
                .remove(0);
            self.store.put(ActKey { chunk, mb, layer: l, tag: ActTag::MlpIn }, y);
            self.ctx.tp.all_reduce_tensor(self.ctx.rank, &mut partial)?;
            x = partial;
        }

        if content.has_head {
            self.store.put(ActKey { chunk, mb, layer: usize::MAX - 1, tag: ActTag::ChunkOut }, x);
        } else {
            self.fwd_tx
                .get(&chunk)
                .ok_or_else(|| anyhow::anyhow!("no fwd tx for chunk {chunk}"))?
                .send(x)
                .map_err(|_| anyhow::anyhow!("fwd channel out of chunk {chunk} closed"))?;
        }
        Ok(())
    }

    fn backward(&mut self, chunk: usize, mb: usize, with_w: bool) -> Result<()> {
        let content = self.ctx.plan.chunks[chunk];
        let mut dy = if content.has_head {
            let x = self
                .store
                .take(&ActKey { chunk, mb, layer: usize::MAX - 1, tag: ActTag::ChunkOut })?;
            let (mb_rows, seq) = (self.ctx.dims.mb, self.ctx.dims.seq);
            let (_, targets) = self.ctx.corpus.batch(0, self.global_mb(mb), mb_rows, seq);
            let tgt = Tensor::i32(targets, &[mb_rows, seq]);
            let wh = self.params[&chunk].head.as_ref().unwrap();
            let mut out = self.backend.run("head_loss_grad", &[&x, wh, &tgt])?;
            // `x` (the chunk-out activation) dies here — back to the pool.
            self.backend.recycle(x);
            let loss = out[0].scalar_f32()?;
            let dwh = out.pop().unwrap();
            let dx = out.pop().unwrap();
            for t in out {
                self.backend.recycle(t);
            }
            let pc = self.params.get_mut(&chunk).unwrap();
            ChunkParams::accumulate(pc.head_grad.as_mut().unwrap(), &dwh);
            self.backend.recycle(dwh);
            if self.ctx.rank == 0 {
                self.loss_tx.send((self.step, self.ctx.replica, loss)).ok();
            }
            dx
        } else {
            self.bwd_rx
                .get(&chunk)
                .ok_or_else(|| anyhow::anyhow!("no bwd rx for chunk {chunk}"))?
                .recv()
                .map_err(|_| anyhow::anyhow!("bwd channel into chunk {chunk} closed"))?
        };

        for l in (0..Self::chunk_layers(content)).rev() {
            // MLP unit backward — `y` stays borrowed from the store.
            let y = self.store.get(&ActKey { chunk, mb, layer: l, tag: ActTag::MlpIn })?;
            let p = &self.params[&chunk].layers[l];
            let mut dmid = self
                .backend
                .run("mlp_bwd_x", &[y, &dy, &p.gamma2, &p.wg, &p.wu, &p.wd])?
                .remove(0);
            self.ctx.tp.all_reduce_tensor(self.ctx.rank, &mut dmid)?;
            if with_w {
                mlp_weight_grad(&mut *self.backend, &mut self.params, chunk, l, y, &dy)?;
                let y = self.store.take(&ActKey { chunk, mb, layer: l, tag: ActTag::MlpIn })?;
                self.backend.recycle(y);
                self.backend.recycle(dy);
            } else {
                // `dy`'s last use on this path: move it into the stash.
                self.store.put(ActKey { chunk, mb, layer: l, tag: ActTag::MlpGrad }, dy);
            }

            // Attn unit backward.
            let x = self.store.get(&ActKey { chunk, mb, layer: l, tag: ActTag::AttnIn })?;
            let p = &self.params[&chunk].layers[l];
            let mut dx = self
                .backend
                .run("attn_bwd_x", &[x, &dmid, &p.gamma1, &p.wq, &p.wk, &p.wv, &p.wo])?
                .remove(0);
            self.ctx.tp.all_reduce_tensor(self.ctx.rank, &mut dx)?;
            if with_w {
                attn_weight_grad(&mut *self.backend, &mut self.params, chunk, l, x, &dmid)?;
                let x = self.store.take(&ActKey { chunk, mb, layer: l, tag: ActTag::AttnIn })?;
                self.backend.recycle(x);
                self.backend.recycle(dmid);
            } else {
                self.store.put(ActKey { chunk, mb, layer: l, tag: ActTag::AttnGrad }, dmid);
            }
            dy = dx;
        }

        if content.has_embed {
            let tok = self
                .store
                .take(&ActKey { chunk, mb, layer: usize::MAX, tag: ActTag::ChunkOut })?;
            let demb = self.backend.run("embed_bwd", &[&tok, &dy])?.remove(0);
            self.backend.recycle(dy);
            let pc = self.params.get_mut(&chunk).unwrap();
            ChunkParams::accumulate(pc.emb_grad.as_mut().unwrap(), &demb);
            self.backend.recycle(demb);
        } else {
            self.bwd_tx
                .get(&chunk)
                .ok_or_else(|| anyhow::anyhow!("no bwd tx for chunk {chunk}"))?
                .send(dy)
                .map_err(|_| anyhow::anyhow!("bwd channel out of chunk {chunk} closed"))?;
        }
        Ok(())
    }

    fn weight_pass(&mut self, chunk: usize, mb: usize) -> Result<()> {
        let content = self.ctx.plan.chunks[chunk];
        for l in (0..Self::chunk_layers(content)).rev() {
            let y = self.store.take(&ActKey { chunk, mb, layer: l, tag: ActTag::MlpIn })?;
            let dz = self.store.take(&ActKey { chunk, mb, layer: l, tag: ActTag::MlpGrad })?;
            mlp_weight_grad(&mut *self.backend, &mut self.params, chunk, l, &y, &dz)?;
            self.backend.recycle(y);
            self.backend.recycle(dz);
            let x = self.store.take(&ActKey { chunk, mb, layer: l, tag: ActTag::AttnIn })?;
            let dmid = self.store.take(&ActKey { chunk, mb, layer: l, tag: ActTag::AttnGrad })?;
            attn_weight_grad(&mut *self.backend, &mut self.params, chunk, l, &x, &dmid)?;
            self.backend.recycle(x);
            self.backend.recycle(dmid);
        }
        Ok(())
    }

    fn optimizer_step(&mut self) -> Result<()> {
        // Replicated RMSNorm gammas: per-rank grads are partials — sum
        // them across the TP group before stepping (Megatron's layernorm
        // gradient all-reduce). The collectives run on the accumulators
        // in place; every rank walks chunks and layers in the same order.
        //
        // Then the DP gradient all-reduce: every accumulator, in a fixed
        // (chunk, layer, field) order, across this (stage, rank)'s DP
        // group. The group rank is the replica index, so the f32
        // summation tree is replica-index order — deterministic at any
        // worker interleaving — and every replica applies the identical
        // summed update, keeping replica weights bit-identical at every
        // step boundary (the shrink-dp invariant, DESIGN.md §14). The
        // SGD divisor is the fixed global batch `dp · n_mb`.
        let mut chunks: Vec<usize> = self.params.keys().copied().collect();
        chunks.sort_unstable();
        let q = self.ctx.replica;
        for c in chunks {
            let p = self.params.get_mut(&c).unwrap();
            for g in p.grads.iter_mut() {
                self.ctx.tp.all_reduce(self.ctx.rank, &mut g.gamma1)?;
                self.ctx.tp.all_reduce(self.ctx.rank, &mut g.gamma2)?;
            }
            let dpg = &self.ctx.dp_group;
            for g in p.grads.iter_mut() {
                dpg.all_reduce(q, &mut g.gamma1)?;
                dpg.all_reduce(q, &mut g.gamma2)?;
                dpg.all_reduce(q, &mut g.wq)?;
                dpg.all_reduce(q, &mut g.wk)?;
                dpg.all_reduce(q, &mut g.wv)?;
                dpg.all_reduce(q, &mut g.wo)?;
                dpg.all_reduce(q, &mut g.wg)?;
                dpg.all_reduce(q, &mut g.wu)?;
                dpg.all_reduce(q, &mut g.wd)?;
            }
            if let Some(eg) = p.emb_grad.as_mut() {
                dpg.all_reduce(q, eg)?;
            }
            if let Some(hg) = p.head_grad.as_mut() {
                dpg.all_reduce(q, hg)?;
            }
            p.sgd_step(self.ctx.run.lr, self.ctx.run.dp * self.ctx.run.n_mb);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::virtual_dims;

    #[test]
    fn virtual_default_config_is_virtual() {
        let cfg = TrainConfig::virtual_default();
        assert_eq!(cfg.backend, BackendKind::Virtual);
        assert_eq!(cfg.kernels, KernelPath::Blocked);
        assert!(cfg.plan.is_none() && cfg.dims.is_none());
    }

    #[test]
    fn even_plan_distributes_exactly() {
        let mc = ModelConfig { layers: 12, ..ModelConfig::tiny_100m() };
        let plan = even_plan(&mc, 4);
        assert!(plan.chunks.iter().all(|c| c.lm_layers == 3));
        assert!(plan.chunks[0].has_embed && plan.chunks[3].has_head);
    }

    #[test]
    fn virtual_training_reduces_loss_on_every_schedule_family() {
        // A cross-section of op shapes: plain F/B/W (ZB-V), braids (STP),
        // fused backward (GPipe) and offload decorations.
        for kind in [ScheduleKind::Stp, ScheduleKind::ZbV, ScheduleKind::GPipe] {
            let mut cfg = TrainConfig::virtual_default();
            cfg.schedule = kind;
            cfg.steps = 3;
            let r = train(&cfg).unwrap();
            assert_eq!(r.steps.len(), 3, "{kind:?}");
            let v = virtual_dims(2, 2, 2, 8).vocab as f32;
            assert!(
                (r.first_loss() - v.ln()).abs() < 0.2,
                "{kind:?}: first loss {} !~ ln({v})",
                r.first_loss()
            );
            assert!(
                r.last_loss() < r.first_loss(),
                "{kind:?}: {} -> {}",
                r.first_loss(),
                r.last_loss()
            );
            assert!(r.allreduce_bytes > 0, "{kind:?}: TP all-reduce must run");
            assert!(r.executions > 0, "{kind:?}");
        }
    }

    #[test]
    fn virtual_schedules_agree_on_losses() {
        // Every schedule is a different order of the same computation, so
        // per-step mean losses agree to reassociation tolerance.
        let mut base: Option<Vec<f32>> = None;
        for kind in [ScheduleKind::GPipe, ScheduleKind::Stp, ScheduleKind::StpOffload] {
            let mut cfg = TrainConfig::virtual_default();
            cfg.schedule = kind;
            cfg.steps = 2;
            let r = train(&cfg).unwrap();
            let losses: Vec<f32> = r.steps.iter().map(|s| s.mean_loss).collect();
            match &base {
                None => base = Some(losses),
                Some(b) => {
                    for (i, (a, l)) in b.iter().zip(&losses).enumerate() {
                        assert!(
                            (a - l).abs() < 2e-3 * a.abs().max(1.0),
                            "{kind:?} step {i}: {l} != baseline {a}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn steady_state_workspace_allocations_are_zero() {
        // The arena contract across every op shape the schedule families
        // produce: after the warm-up step no device thread heap-allocates
        // kernel scratch again.
        for kind in [ScheduleKind::Stp, ScheduleKind::ZbV, ScheduleKind::GPipe] {
            let mut cfg = TrainConfig::virtual_default();
            cfg.schedule = kind;
            cfg.steps = 3;
            let r = train(&cfg).unwrap();
            assert_eq!(r.workspace_steady_allocs, 0, "{kind:?}: steady state allocated");
            assert!(
                r.workspace_peak_bytes.iter().all(|&b| b > 0),
                "{kind:?}: every stage must have used the arena: {:?}",
                r.workspace_peak_bytes
            );
        }
    }

    #[test]
    fn reference_kernel_path_trains_too() {
        let mut cfg = TrainConfig::virtual_default();
        cfg.kernels = KernelPath::Reference;
        cfg.steps = 2;
        let r = train(&cfg).unwrap();
        assert!(r.last_loss().is_finite());
        // The reference path never touches the arena.
        assert_eq!(r.workspace_steady_allocs, 0);
        assert!(r.workspace_peak_bytes.iter().all(|&b| b == 0));
    }
}
