//! Tiny deterministic RNG (xorshift64* + Box-Muller) — the build vendors
//! no rand crate. Used for weight init and synthetic data; determinism
//! across threads comes from per-purpose seeding.

/// xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // Avoid the all-zero fixed point; splitmix the seed once.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        Rng { state: (z ^ (z >> 31)) | 1 }
    }

    /// Seed derived from a context tuple (stable across runs/threads).
    pub fn for_purpose(seed: u64, a: u64, b: u64, c: u64) -> Rng {
        Rng::new(seed ^ a.wrapping_mul(0x9E37_79B9) ^ b.wrapping_mul(0x85EB_CA6B) ^ c.wrapping_mul(0xC2B2_AE35))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of scaled normals (fan-in init).
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Raw stream position, for checkpointing (`stp-ckpt-v1`).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a stream at a checkpointed position. Any state returned by
    /// [`Rng::state`] is non-zero (xorshift never reaches the zero fixed
    /// point), so saved positions round-trip bit-exactly; a literal 0 is
    /// remapped to keep the generator live.
    pub fn from_state(state: u64) -> Rng {
        Rng { state: if state == 0 { 1 } else { state } }
    }

    /// Advance the stream by `n` draws (checkpoint fast-forward).
    pub fn advance(&mut self, n: u64) {
        for _ in 0..n {
            self.next_u64();
        }
    }

    /// Fork an independent stream seeded from this one's next draw.
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn purpose_seeds_differ() {
        let a = Rng::for_purpose(1, 0, 0, 0).next_u64();
        let b = Rng::for_purpose(1, 0, 0, 1).next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn save_restore_at_arbitrary_split_points_is_bit_exact() {
        // Property over every split point k of an N-draw stream: draw k,
        // checkpoint with state(), restore with from_state(), and the
        // remaining N-k draws must bit-equal an uninterrupted stream —
        // the RNG half of the stp-ckpt-v1 bit-exactness guarantee.
        const N: usize = 257;
        for seed in [0u64, 1, 42, u64::MAX] {
            let mut straight = Rng::new(seed);
            let reference: Vec<u64> = (0..N).map(|_| straight.next_u64()).collect();
            for k in 0..=N {
                let mut r = Rng::new(seed);
                for i in 0..k {
                    assert_eq!(r.next_u64(), reference[i]);
                }
                let mut restored = Rng::from_state(r.state());
                for (i, want) in reference.iter().enumerate().skip(k) {
                    assert_eq!(restored.next_u64(), *want, "seed {seed} split {k} draw {i}");
                }
            }
        }
    }

    #[test]
    fn advance_equals_discarded_draws() {
        for k in [0u64, 1, 7, 100] {
            let mut a = Rng::new(9);
            let mut b = Rng::new(9);
            a.advance(k);
            for _ in 0..k {
                b.next_u64();
            }
            assert_eq!(a.state(), b.state());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_is_deterministic_and_independent() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let mut sa = a.split();
        let mut sb = b.split();
        // Same parent position ⇒ identical child stream; parents stay in
        // lockstep past the fork.
        for _ in 0..50 {
            assert_eq!(sa.next_u64(), sb.next_u64());
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // Child diverges from parent.
        assert_ne!(a.state(), sa.state());
    }

    #[test]
    fn normal_draws_resume_bit_exactly_across_restore() {
        // The f32 path used by weight init and data synthesis must also
        // survive a checkpoint: restore mid-stream and compare bits.
        let mut straight = Rng::new(13);
        let want: Vec<u32> = (0..64).map(|_| straight.normal().to_bits()).collect();
        let mut r = Rng::new(13);
        for w in want.iter().take(20) {
            assert_eq!(r.normal().to_bits(), *w);
        }
        let mut restored = Rng::from_state(r.state());
        for w in want.iter().skip(20) {
            assert_eq!(restored.normal().to_bits(), *w);
        }
    }
}
