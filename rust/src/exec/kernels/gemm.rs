//! Cache-blocked, register-tiled GEMM microkernels for the virtual
//! backend's hot path.
//!
//! Classic three-level blocking (BLIS-style): B is packed into `KC×NC`
//! panels and A into `MC×KC` panels (contiguous micro-panel access, one
//! pass over each operand per block), and a register tile accumulates the
//! innermost product with the depth loop innermost. The three layouts the
//! nine AOT units need — `A·B`, `Aᵀ·B` (weight grads) and `A·Bᵀ` (input
//! grads) — share one core; transposition happens in the packing step, so
//! the microkernel always streams contiguous panels.
//!
//! Two register tiles exist behind [`KernelCtx::simd`] (DESIGN.md §13):
//!
//! * the scalar `MR×NR` = 4×16 tile ([`micro_full`]) — the PR-5 blocked
//!   path;
//! * a portable-SIMD `MR_S×NR` = 6×16 tile ([`micro_full_simd`]) whose
//!   accumulators are fixed-size `[f32; 8]` lane arrays. `std::simd` is
//!   nightly-only at the crate's MSRV, but LLVM reliably vectorizes these
//!   fixed-trip lane loops into packed AVX2/NEON mul+add — the classic
//!   6×16 BLIS geometry that keeps 12 vector registers of C live.
//!
//! **Determinism argument** (DESIGN.md §11, §13): every output element
//! keeps a *single* accumulator whose terms are added in strictly
//! increasing depth order — each tile loads the current `C` values, adds
//! the block's `kc` terms in order, and stores back, so splitting the
//! depth loop into `KC` blocks never re-associates the sum (an f32
//! store/reload is exact), and no `mul_add` is emitted (Rust does not
//! contract `a*b + c`). The tile *geometry* (4×16 vs 6×16, or the row
//! banding the worker pool introduces) only partitions the `(i, j)` output
//! space — it never touches any element's depth chain. The result is
//! therefore **bit-equal** to the naive triple loops in
//! [`super::reference`] on *every* path — scalar, SIMD, and any worker
//! count — which `tests/kernel_parity.rs` pins, and which is what keeps
//! `stp train` bit-deterministic per seed with any kernel selection.
//!
//! **Worker pool**: products big enough to amortize thread handoff
//! (≥ [`PAR_FLOPS`], more rows than one `MC` band) are split into `MC`-row
//! bands with a *fixed* band→worker assignment (`band i → worker i mod
//! nw`), each worker packing its own panels from its own [`Workspace`]
//! arena — parallel panel packing with no shared mutable state beyond the
//! disjoint `C` bands. Packing buffers come from the caller's arenas, so
//! steady-state calls allocate nothing on any path.

use crate::exec::workspace::Workspace;

use super::KernelCtx;

/// Scalar register-tile rows.
const MR: usize = 4;
/// SIMD register-tile rows (6×16 f32 = 12 AVX2 accumulator registers).
const MR_S: usize = 6;
/// Register-tile columns (16 f32 = one cache line / two 8-lane vectors).
const NR: usize = 16;
/// Lanes per SIMD accumulator row half.
const LANES: usize = 8;
/// A-panel rows per block.
const MC: usize = 64;
/// Depth (k) per block — A panel is MC·KC·4 = 64 KiB, inside L2.
const KC: usize = 256;
/// B-panel columns per block — B panel is KC·NC·4 = 512 KiB.
const NC: usize = 512;

/// Below this flop volume the packing overhead dominates; fall through to
/// the naive loops (bit-equal, so dispatch is invisible to numerics).
const SMALL_FLOPS: usize = 1 << 14;

/// Below this flop volume (or at ≤ one `MC` band) the worker-pool handoff
/// costs more than it saves; run the band loop on the calling thread.
/// The `test`-preset unit GEMMs all sit under this, so miniature runs
/// never pay a spawn.
const PAR_FLOPS: usize = 1 << 20;

/// `C += A·B` with `A: [n,k]`, `B: [k,m]`, `C: [n,m]`.
///
/// Accumulates into `out` (pass a zeroed buffer for a plain product).
#[allow(clippy::too_many_arguments)]
pub fn matmul(
    cx: &mut KernelCtx,
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * m);
    if n * k * m <= SMALL_FLOPS {
        return naive(a, b, n, k, m, out);
    }
    gemm_dispatch(cx, n, k, m, out, a, k, false, b, m, false);
}

/// `C += Aᵀ·B` with `A: [k,n]`, `B: [k,m]`, `C: [n,m]` (weight grads).
#[allow(clippy::too_many_arguments)]
pub fn matmul_at(
    cx: &mut KernelCtx,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(out.len(), n * m);
    if n * k * m <= SMALL_FLOPS {
        return naive_at(a, b, k, n, m, out);
    }
    gemm_dispatch(cx, n, k, m, out, a, n, true, b, m, false);
}

/// `C += A·Bᵀ` with `A: [n,k]`, `B: [m,k]`, `C: [n,m]` (input grads).
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt(
    cx: &mut KernelCtx,
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), m * k);
    debug_assert_eq!(out.len(), n * m);
    if n * k * m <= SMALL_FLOPS {
        return naive_bt(a, b, n, k, m, out);
    }
    gemm_dispatch(cx, n, k, m, out, a, k, false, b, k, true);
}

/// Serial-vs-parallel dispatch. Small products (or contexts without a
/// worker pool) run the band loop inline over the main arena; big ones
/// split `C` into `MC`-row bands across the pool. Band→worker assignment
/// is a pure function of the band index, so the partitioning — and with
/// it every output bit — is identical at any worker count.
#[allow(clippy::too_many_arguments)]
fn gemm_dispatch(
    cx: &mut KernelCtx,
    n: usize,
    k: usize,
    m: usize,
    out: &mut [f32],
    a: &[f32],
    lda: usize,
    ta: bool,
    b: &[f32],
    ldb: usize,
    tb: bool,
) {
    let nw = cx.worker_ws.len();
    if nw < 2 || n <= MC || n * k * m < PAR_FLOPS {
        return gemm_band(&mut cx.ws, cx.simd, 0, n, k, m, out, a, lda, ta, b, ldb, tb);
    }
    let simd = cx.simd;
    // Fixed tile→worker assignment: band i (rows [i·MC, (i+1)·MC)) goes
    // to worker i mod nw. Each worker owns disjoint `C` bands and its own
    // arena; A/B are shared read-only, and every worker packs its own
    // panels (redundant B packing buys zero synchronization).
    let mut per_worker: Vec<Vec<(usize, &mut [f32])>> = (0..nw).map(|_| Vec::new()).collect();
    for (i, band) in out.chunks_mut(MC * m).enumerate() {
        per_worker[i % nw].push((i, band));
    }
    std::thread::scope(|scope| {
        for (ws, bands) in cx.worker_ws.iter_mut().zip(per_worker) {
            scope.spawn(move || {
                for (i, band) in bands {
                    let nrows = band.len() / m;
                    gemm_band(ws, simd, i * MC, nrows, k, m, band, a, lda, ta, b, ldb, tb);
                }
            });
        }
    });
}

/// The shared blocked core over one row band: computes `C[row0..row0+n,
/// :] += A[row0.., :]·B` with `out` being the band's rows only. `ta`/`tb`
/// say whether the operand is stored transposed (`a` as `[k,n]` with
/// leading dimension `lda = n`; `b` as `[m,k]` with `ldb = k`); packing
/// normalizes both into row-major panels, so the micro loops never see a
/// stride. `row0 = 0, n = full` is exactly the serial whole-matrix call.
#[allow(clippy::too_many_arguments)]
fn gemm_band(
    ws: &mut Workspace,
    simd: bool,
    row0: usize,
    n: usize,
    k: usize,
    m: usize,
    out: &mut [f32],
    a: &[f32],
    lda: usize,
    ta: bool,
    b: &[f32],
    ldb: usize,
    tb: bool,
) {
    // Pack panels are fully overwritten before every read, so skip the
    // zeroing memset a plain `take` would pay on each GEMM call.
    let mut apack = ws.take_uninit(MC * KC);
    let mut bpack = ws.take_uninit(KC * NC);
    let step = if simd { MR_S } else { MR };
    let mut jc = 0;
    while jc < m {
        let nc = NC.min(m - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(&mut bpack, b, ldb, tb, pc, kc, jc, nc);
            let mut ic = 0;
            while ic < n {
                let mc = MC.min(n - ic);
                pack_a(&mut apack, a, lda, ta, row0 + ic, mc, pc, kc);
                let mut i0 = 0;
                while i0 < mc {
                    let mr = step.min(mc - i0);
                    let mut j0 = 0;
                    while j0 < nc {
                        let nr = NR.min(nc - j0);
                        if nr == NR && mr == step {
                            if simd {
                                micro_full_simd(&apack, kc, i0, &bpack, nc, j0, out, m, ic, jc);
                            } else {
                                micro_full(&apack, kc, i0, &bpack, nc, j0, out, m, ic, jc);
                            }
                        } else {
                            micro_edge(&apack, kc, i0, mr, &bpack, nc, j0, nr, out, m, ic, jc);
                        }
                        j0 += NR;
                    }
                    i0 += step;
                }
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
    ws.give(apack);
    ws.give(bpack);
}

/// Pack `A[ic..ic+mc, pc..pc+kc]` into `apack[i*kc + p]` (row-major).
#[allow(clippy::too_many_arguments)]
fn pack_a(
    apack: &mut [f32],
    a: &[f32],
    lda: usize,
    ta: bool,
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
) {
    if !ta {
        for i in 0..mc {
            let src = (ic + i) * lda + pc;
            apack[i * kc..i * kc + kc].copy_from_slice(&a[src..src + kc]);
        }
    } else {
        // A stored as [k, n]: element (ic+i, pc+p) lives at a[(pc+p)*lda + ic+i].
        for i in 0..mc {
            let dst = &mut apack[i * kc..i * kc + kc];
            for (p, d) in dst.iter_mut().enumerate() {
                *d = a[(pc + p) * lda + ic + i];
            }
        }
    }
}

/// Pack `B[pc..pc+kc, jc..jc+nc]` into `bpack[p*nc + j]` (row-major).
#[allow(clippy::too_many_arguments)]
fn pack_b(
    bpack: &mut [f32],
    b: &[f32],
    ldb: usize,
    tb: bool,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
) {
    if !tb {
        for p in 0..kc {
            let src = (pc + p) * ldb + jc;
            bpack[p * nc..p * nc + nc].copy_from_slice(&b[src..src + nc]);
        }
    } else {
        // B stored as [m, k]: element (pc+p, jc+j) lives at b[(jc+j)*ldb + pc+p].
        for j in 0..nc {
            let src = &b[(jc + j) * ldb + pc..(jc + j) * ldb + pc + kc];
            for (p, &v) in src.iter().enumerate() {
                bpack[p * nc + j] = v;
            }
        }
    }
}

/// Full scalar `MR×NR` register tile: load the C tile, accumulate `kc`
/// depth terms in order, store back.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_full(
    apack: &[f32],
    kc: usize,
    i0: usize,
    bpack: &[f32],
    nc: usize,
    j0: usize,
    out: &mut [f32],
    ldc: usize,
    ic: usize,
    jc: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate() {
        let row = (ic + i0 + r) * ldc + jc + j0;
        accr.copy_from_slice(&out[row..row + NR]);
    }
    for p in 0..kc {
        let brow = &bpack[p * nc + j0..p * nc + j0 + NR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = apack[(i0 + r) * kc + p];
            for j in 0..NR {
                accr[j] += av * brow[j];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let row = (ic + i0 + r) * ldc + jc + j0;
        out[row..row + NR].copy_from_slice(accr);
    }
}

/// Full SIMD `MR_S×NR` register tile: 6 rows × two 8-lane halves of C
/// held in fixed-size lane arrays. The lane loops have constant trip
/// counts and no cross-lane dependency, so LLVM lowers them to packed
/// vector mul+add; the per-element depth chain is the same single-
/// accumulator in-order sum as the scalar tile, hence bit-equal.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_full_simd(
    apack: &[f32],
    kc: usize,
    i0: usize,
    bpack: &[f32],
    nc: usize,
    j0: usize,
    out: &mut [f32],
    ldc: usize,
    ic: usize,
    jc: usize,
) {
    let mut acc = [[[0.0f32; LANES]; 2]; MR_S];
    for (r, accr) in acc.iter_mut().enumerate() {
        let row = (ic + i0 + r) * ldc + jc + j0;
        accr[0].copy_from_slice(&out[row..row + LANES]);
        accr[1].copy_from_slice(&out[row + LANES..row + NR]);
    }
    for p in 0..kc {
        let brow = &bpack[p * nc + j0..p * nc + j0 + NR];
        let mut b0 = [0.0f32; LANES];
        let mut b1 = [0.0f32; LANES];
        b0.copy_from_slice(&brow[..LANES]);
        b1.copy_from_slice(&brow[LANES..]);
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = apack[(i0 + r) * kc + p];
            for j in 0..LANES {
                accr[0][j] += av * b0[j];
            }
            for j in 0..LANES {
                accr[1][j] += av * b1[j];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let row = (ic + i0 + r) * ldc + jc + j0;
        out[row..row + LANES].copy_from_slice(&accr[0]);
        out[row + LANES..row + NR].copy_from_slice(&accr[1]);
    }
}

/// Edge tile (`mr < step` or `nr < NR`): accumulate straight into `C` in
/// the same depth order.
#[allow(clippy::too_many_arguments)]
fn micro_edge(
    apack: &[f32],
    kc: usize,
    i0: usize,
    mr: usize,
    bpack: &[f32],
    nc: usize,
    j0: usize,
    nr: usize,
    out: &mut [f32],
    ldc: usize,
    ic: usize,
    jc: usize,
) {
    for p in 0..kc {
        let brow = &bpack[p * nc + j0..p * nc + j0 + nr];
        for r in 0..mr {
            let av = apack[(i0 + r) * kc + p];
            let row = (ic + i0 + r) * ldc + jc + j0;
            let or = &mut out[row..row + nr];
            for (o, &bv) in or.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Naive accumulate-into fallbacks for tiny products (identical loop
// order to `super::reference`, hence identical bits).
// ---------------------------------------------------------------------------

fn naive(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    for i in 0..n {
        for p in 0..k {
            let av = a[i * k + p];
            let br = &b[p * m..(p + 1) * m];
            let or = &mut out[i * m..(i + 1) * m];
            for (o, &bv) in or.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
}

fn naive_at(a: &[f32], b: &[f32], k: usize, n: usize, m: usize, out: &mut [f32]) {
    for p in 0..k {
        let ar = &a[p * n..(p + 1) * n];
        let br = &b[p * m..(p + 1) * m];
        for i in 0..n {
            let av = ar[i];
            let or = &mut out[i * m..(i + 1) * m];
            for (o, &bv) in or.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
}

fn naive_bt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    for i in 0..n {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * m..(i + 1) * m];
        for j in 0..m {
            let br = &b[j * k..(j + 1) * k];
            let mut acc = or[j];
            for (&av, &bv) in ar.iter().zip(br) {
                acc += av * bv;
            }
            or[j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::exec::Rng;

    fn randn(seed: u64, n: usize) -> Vec<f32> {
        Rng::for_purpose(1234, seed, 1, 0).normal_vec(n, 1.0)
    }

    /// Shapes that force every code path: the small-product fallback,
    /// single-block, multi-block with exact tile fits, and ragged edges
    /// in every dimension — including tails that are not multiples of
    /// MR (4), MR_S (6), NR (16) or KC (256).
    fn shapes() -> Vec<(usize, usize, usize)> {
        vec![
            (1, 1, 1),
            (3, 5, 7),
            (4, 16, 16),
            (6, 256, 16),
            (32, 64, 48),
            (65, 257, 33),
            (64, 256, 512),
            (66, 300, 18),
            (70, 300, 530),
            (127, 255, 514),
            (128, 19, 1037),
        ]
    }

    /// Contexts for both register tiles (scalar blocked + SIMD).
    fn paths() -> [KernelCtx; 2] {
        [KernelCtx::serial(false), KernelCtx::serial(true)]
    }

    #[test]
    fn blocked_matmul_is_bit_equal_to_reference() {
        for mut cx in paths() {
            for (n, k, m) in shapes() {
                let a = randn(n as u64, n * k);
                let b = randn(m as u64 + 100, k * m);
                let want = reference::matmul(&a, &b, n, k, m);
                let mut got = vec![0.0f32; n * m];
                matmul(&mut cx, &a, &b, n, k, m, &mut got);
                assert!(
                    want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "matmul {n}x{k}x{m} (simd={}) diverged from reference",
                    cx.simd
                );
            }
        }
    }

    #[test]
    fn blocked_matmul_at_is_bit_equal_to_reference() {
        for mut cx in paths() {
            for (n, k, m) in shapes() {
                let a = randn(n as u64 + 7, k * n);
                let b = randn(m as u64 + 200, k * m);
                let want = reference::matmul_at(&a, &b, k, n, m);
                let mut got = vec![0.0f32; n * m];
                matmul_at(&mut cx, &a, &b, k, n, m, &mut got);
                assert!(
                    want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "matmul_at {k}x{n}x{m} (simd={}) diverged from reference",
                    cx.simd
                );
            }
        }
    }

    #[test]
    fn blocked_matmul_bt_is_bit_equal_to_reference() {
        for mut cx in paths() {
            for (n, k, m) in shapes() {
                let a = randn(n as u64 + 13, n * k);
                let b = randn(m as u64 + 300, m * k);
                let want = reference::matmul_bt(&a, &b, n, k, m);
                let mut got = vec![0.0f32; n * m];
                matmul_bt(&mut cx, &a, &b, n, k, m, &mut got);
                assert!(
                    want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "matmul_bt {n}x{k}x{m} (simd={}) diverged from reference",
                    cx.simd
                );
            }
        }
    }

    #[test]
    fn parallel_gemm_is_bit_equal_at_any_worker_count() {
        // A shape big enough to engage the pool (> MC rows, ≥ PAR_FLOPS):
        // every worker count must partition into the same fixed bands and
        // reproduce the serial (and reference) bits exactly.
        let (n, k, m) = (300, 200, 64);
        assert!(n > MC && n * k * m >= PAR_FLOPS, "shape must engage the pool");
        let a = randn(61, n * k);
        let b = randn(62, k * m);
        let want = reference::matmul(&a, &b, n, k, m);
        for workers in [1, 2, 3, 8] {
            let mut cx = KernelCtx::with_workers(true, workers);
            let engaged = cx.worker_ws.len() >= 2;
            assert_eq!(engaged, workers >= 2);
            let mut got = vec![0.0f32; n * m];
            matmul(&mut cx, &a, &b, n, k, m, &mut got);
            assert!(
                want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                "parallel matmul {n}x{k}x{m} at {workers} workers diverged"
            );
            if engaged {
                let used: usize =
                    cx.worker_ws.iter().map(|w| w.stats().takes as usize).sum();
                assert!(used > 0, "worker arenas must have served the packing buffers");
            }
        }
    }

    #[test]
    fn gemm_accumulates_into_out() {
        // C += A·B semantics: a second call continues the accumulation
        // chain — bit-identical to the naive accumulate run twice.
        let (n, k, m) = (65, 257, 33);
        let a = randn(1, n * k);
        let b = randn(2, k * m);
        for mut cx in paths() {
            let mut got = vec![0.0f32; n * m];
            matmul(&mut cx, &a, &b, n, k, m, &mut got);
            matmul(&mut cx, &a, &b, n, k, m, &mut got);
            let mut want = vec![0.0f32; n * m];
            naive(&a, &b, n, k, m, &mut want);
            naive(&a, &b, n, k, m, &mut want);
            assert!(
                want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                "accumulation chain diverged (simd={})",
                cx.simd
            );
        }
    }

    #[test]
    fn gemm_reuses_packing_buffers() {
        let (n, k, m) = (70, 300, 530);
        let a = randn(1, n * k);
        let b = randn(2, k * m);
        for mut cx in paths() {
            let mut out = vec![0.0f32; n * m];
            matmul(&mut cx, &a, &b, n, k, m, &mut out);
            let warm = cx.stats().fresh_allocs;
            for _ in 0..5 {
                out.iter_mut().for_each(|v| *v = 0.0);
                matmul(&mut cx, &a, &b, n, k, m, &mut out);
            }
            assert_eq!(cx.stats().fresh_allocs, warm, "steady-state GEMM must not allocate");
        }
    }

    #[test]
    fn parallel_gemm_reuses_worker_arenas() {
        let (n, k, m) = (300, 200, 64);
        let a = randn(3, n * k);
        let b = randn(4, k * m);
        let mut cx = KernelCtx::with_workers(true, 4);
        let mut out = vec![0.0f32; n * m];
        matmul(&mut cx, &a, &b, n, k, m, &mut out);
        let warm = cx.stats().fresh_allocs;
        for _ in 0..3 {
            out.iter_mut().for_each(|v| *v = 0.0);
            matmul(&mut cx, &a, &b, n, k, m, &mut out);
        }
        assert_eq!(cx.stats().fresh_allocs, warm, "steady-state parallel GEMM allocated");
    }
}
