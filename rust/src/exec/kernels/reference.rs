//! The **naive reference kernels** — the virtual backend's numeric oracle.
//!
//! This is the original (pre-arena, pre-blocking) implementation of the
//! nine AOT unit signatures, preserved as-is when the hot path moved to
//! the cache-blocked, workspace-backed kernels in [`super`] — exactly the
//! way the polling simulator survives as `sim::reference`. Plain
//! deterministic f32 triple loops, a fresh `Vec<f32>` per intermediate,
//! no scratch reuse: slow, obviously correct, and bit-deterministic.
//!
//! Two consumers keep it alive:
//! * the **parity suite** (`tests/kernel_parity.rs`) pins the blocked
//!   kernels against these — and the blocked GEMMs are constructed to be
//!   *bit-equal* (they preserve the per-element accumulation order, see
//!   [`super::gemm`]);
//! * the **bench baselines** (`stp bench train` with
//!   `KernelPath::Reference`, `benches/kernel_perf.rs`) measure the
//!   speedup the blocked path buys.
//!
//! The math itself is `python/compile/kernels/ref.py` / `model.py`:
//! forwards are per-TP-rank partials with the fused residual `+ x/t`
//! (paper Eq. 1–2); `*_bwd_x` returns the activation-gradient partial
//! `vjp(dy) + dy/t`; `*_bwd_w` returns rank-local weight gradients plus
//! replicated RMSNorm gamma partials.

// Index-heavy tensor math: offset-based loops are the clearest way to
// write the strided head/sequence indexing below.
#![allow(clippy::needless_range_loop)]

use crate::config::ManifestDims;
use crate::runtime::Tensor;
use crate::Result;

use super::expect_args;

const EPS: f32 = 1e-6;

// ---------------------------------------------------------------------------
// Small dense building blocks (fixed accumulation order).
// ---------------------------------------------------------------------------

/// `[n,k] @ [k,m] -> [n,m]`.
pub fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        for p in 0..k {
            let av = a[i * k + p];
            let br = &b[p * m..(p + 1) * m];
            let or = &mut out[i * m..(i + 1) * m];
            for j in 0..m {
                or[j] += av * br[j];
            }
        }
    }
    out
}

/// `aᵀ @ b` where `a: [k,n]`, `b: [k,m]` → `[n,m]` (weight gradients).
pub fn matmul_at(a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    let mut out = vec![0.0f32; n * m];
    for p in 0..k {
        let ar = &a[p * n..(p + 1) * n];
        let br = &b[p * m..(p + 1) * m];
        for i in 0..n {
            let av = ar[i];
            let or = &mut out[i * m..(i + 1) * m];
            for j in 0..m {
                or[j] += av * br[j];
            }
        }
    }
    out
}

/// `a @ bᵀ` where `a: [n,k]`, `b: [m,k]` → `[n,m]` (input gradients).
pub fn matmul_bt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), m * k);
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * m..(i + 1) * m];
        for j in 0..m {
            let br = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += ar[p] * br[p];
            }
            or[j] = acc;
        }
    }
    out
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// RMSNorm forward: `y = x · rsqrt(mean(x²)+ε) · γ`, per length-`d` row.
fn rmsnorm(x: &[f32], gamma: &[f32], d: usize) -> Vec<f32> {
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for i in 0..d {
            y[r * d + i] = xr[i] * inv * gamma[i];
        }
    }
    y
}

/// RMSNorm backward: given the gradient `dy` at the norm's output,
/// returns `(dx, dγ)`.
///
/// With `r = rsqrt(mean(x²)+ε)`: `dx_j = r·γ_j·dy_j − (r³/d)·x_j·Σᵢ
/// dyᵢγᵢxᵢ` and `dγ_i = Σ_rows dyᵢ·xᵢ·r`.
fn rmsnorm_bwd(x: &[f32], gamma: &[f32], dy: &[f32], d: usize) -> (Vec<f32>, Vec<f32>) {
    let rows = x.len() / d;
    let mut dx = vec![0.0f32; x.len()];
    let mut dg = vec![0.0f32; d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        let mut s = 0.0f32;
        for i in 0..d {
            s += dyr[i] * gamma[i] * xr[i];
            dg[i] += dyr[i] * xr[i] * inv;
        }
        let k = inv * inv * inv * s / d as f32;
        for i in 0..d {
            dx[r * d + i] = inv * gamma[i] * dyr[i] - k * xr[i];
        }
    }
    (dx, dg)
}

// ---------------------------------------------------------------------------
// Attention unit (per-rank head slice, causal, GQA).
// ---------------------------------------------------------------------------

/// Saved forward state of one attention-core evaluation.
struct AttnCache {
    xln: Vec<f32>,   // [rows, d]
    q: Vec<f32>,     // [rows, hq*dh]
    k: Vec<f32>,     // [rows, hkv*dh]
    v: Vec<f32>,     // [rows, hkv*dh]
    probs: Vec<f32>, // [mb, hq, s, s] (0 above the diagonal)
    ctx: Vec<f32>,   // [rows, hq*dh]
}

struct AttnShape {
    mb: usize,
    s: usize,
    d: usize,
    hq: usize,
    hkv: usize,
    dh: usize,
}

impl AttnShape {
    fn of(x: &Tensor, dims: &ManifestDims) -> AttnShape {
        let sh = x.shape();
        AttnShape {
            mb: sh[0],
            s: sh[1],
            d: sh[2],
            hq: dims.q_heads_per_rank(),
            hkv: dims.kv_heads_per_rank(),
            dh: dims.head_dim(),
        }
    }
    fn rows(&self) -> usize {
        self.mb * self.s
    }
}

/// The `[h·dh, (h+1)·dh)` head slice of row `row` in a `[rows, stride]`
/// buffer.
#[inline]
fn head(buf: &[f32], row: usize, stride: usize, h: usize, dh: usize) -> &[f32] {
    &buf[row * stride + h * dh..row * stride + (h + 1) * dh]
}

/// Forward of `attention_core(rmsnorm(x, γ1), …)` keeping everything the
/// backward needs.
fn attn_core(
    x: &[f32],
    gamma1: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    sh: &AttnShape,
) -> AttnCache {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let xln = rmsnorm(x, gamma1, d);
    let q = matmul(&xln, wq, rows, d, qr);
    let k = matmul(&xln, wk, rows, d, kr);
    let v = matmul(&xln, wv, rows, d, kr);
    let group = sh.hq / sh.hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = vec![0.0f32; sh.mb * sh.hq * sh.s * sh.s];
    let mut ctx = vec![0.0f32; rows * qr];
    for n in 0..sh.mb {
        for h in 0..sh.hq {
            let kh = h / group;
            let pbase = ((n * sh.hq) + h) * sh.s * sh.s;
            for t in 0..sh.s {
                let qrow = head(&q, n * sh.s + t, qr, h, dh);
                // Causal scores for u <= t, stable softmax.
                let mut scores = vec![0.0f32; t + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (u, sc) in scores.iter_mut().enumerate() {
                    let krow = head(&k, n * sh.s + u, kr, kh, dh);
                    let mut acc = 0.0f32;
                    for e in 0..dh {
                        acc += qrow[e] * krow[e];
                    }
                    *sc = acc * scale;
                    maxv = maxv.max(*sc);
                }
                let mut z = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxv).exp();
                    z += *sc;
                }
                let cbase = (n * sh.s + t) * qr + h * dh;
                for (u, sc) in scores.iter().enumerate() {
                    let p = sc / z;
                    probs[pbase + t * sh.s + u] = p;
                    let vrow = head(&v, n * sh.s + u, kr, kh, dh);
                    for e in 0..dh {
                        ctx[cbase + e] += p * vrow[e];
                    }
                }
            }
        }
    }
    AttnCache { xln, q, k, v, probs, ctx }
}

/// Gradients of the attention core at `dout` (the gradient of the
/// attention-path output `ctx @ wo`, before the residual).
struct AttnCoreGrads {
    dxln: Vec<f32>,
    dwq: Vec<f32>,
    dwk: Vec<f32>,
    dwv: Vec<f32>,
    dwo: Vec<f32>,
}

fn attn_core_bwd(
    cache: &AttnCache,
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    dout: &[f32],
    sh: &AttnShape,
) -> AttnCoreGrads {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let group = sh.hq / sh.hkv;
    let scale = 1.0 / (dh as f32).sqrt();

    let dctx = matmul_bt(dout, wo, rows, d, qr);
    let dwo = matmul_at(&cache.ctx, dout, rows, qr, d);

    let mut dq = vec![0.0f32; rows * qr];
    let mut dk = vec![0.0f32; rows * kr];
    let mut dv = vec![0.0f32; rows * kr];
    for n in 0..sh.mb {
        for h in 0..sh.hq {
            let kh = h / group;
            let pbase = ((n * sh.hq) + h) * sh.s * sh.s;
            for t in 0..sh.s {
                let dcrow = head(&dctx, n * sh.s + t, qr, h, dh);
                // dP[t,u] and the softmax-backward row sum.
                let mut dp = vec![0.0f32; t + 1];
                let mut rho = 0.0f32;
                for (u, dpu) in dp.iter_mut().enumerate() {
                    let vrow = head(&cache.v, n * sh.s + u, kr, kh, dh);
                    let mut acc = 0.0f32;
                    for e in 0..dh {
                        acc += dcrow[e] * vrow[e];
                    }
                    *dpu = acc;
                    rho += acc * cache.probs[pbase + t * sh.s + u];
                }
                let qrow_base = (n * sh.s + t) * qr + h * dh;
                for (u, dpu) in dp.iter().enumerate() {
                    let p = cache.probs[pbase + t * sh.s + u];
                    let ds = p * (dpu - rho) * scale;
                    let krow_base = (n * sh.s + u) * kr + kh * dh;
                    for e in 0..dh {
                        dq[qrow_base + e] += ds * cache.k[krow_base + e];
                        dk[krow_base + e] += ds * cache.q[qrow_base + e];
                        dv[krow_base + e] += p * dcrow[e];
                    }
                }
            }
        }
    }

    let mut dxln = matmul_bt(&dq, wq, rows, qr, d);
    let dk_x = matmul_bt(&dk, wk, rows, kr, d);
    let dv_x = matmul_bt(&dv, wv, rows, kr, d);
    for ((a, b), c) in dxln.iter_mut().zip(&dk_x).zip(&dv_x) {
        *a += *b + *c;
    }
    let dwq = matmul_at(&cache.xln, &dq, rows, d, qr);
    let dwk = matmul_at(&cache.xln, &dk, rows, d, kr);
    let dwv = matmul_at(&cache.xln, &dv, rows, d, kr);
    AttnCoreGrads { dxln, dwq, dwk, dwv, dwo }
}

/// `attn_fwd`: per-rank partial `Attention_r(RMSNorm(x)) + x/t`.
pub(crate) fn attn_fwd(args: &[&Tensor], dims: &ManifestDims) -> Result<Vec<Tensor>> {
    let [x, g1, wq, wk, wv, wo] = expect_args::<6>("attn_fwd", args)?;
    let sh = AttnShape::of(x, dims);
    let cache =
        attn_core(x.as_f32()?, g1.as_f32()?, wq.as_f32()?, wk.as_f32()?, wv.as_f32()?, &sh);
    let mut out = matmul(&cache.ctx, wo.as_f32()?, sh.rows(), sh.hq * sh.dh, sh.d);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, xi) in out.iter_mut().zip(x.as_f32()?) {
        *o += xi * inv_t;
    }
    Ok(vec![Tensor::f32(out, x.shape())])
}

/// `attn_bwd_x`: activation-gradient partial `vjp(dy) + dy/t`.
pub(crate) fn attn_bwd_x(args: &[&Tensor], dims: &ManifestDims) -> Result<Vec<Tensor>> {
    let [x, dy, g1, wq, wk, wv, wo] = expect_args::<7>("attn_bwd_x", args)?;
    let sh = AttnShape::of(x, dims);
    let (xs, g1s) = (x.as_f32()?, g1.as_f32()?);
    let (wqs, wks, wvs) = (wq.as_f32()?, wk.as_f32()?, wv.as_f32()?);
    let cache = attn_core(xs, g1s, wqs, wks, wvs, &sh);
    let g = attn_core_bwd(&cache, wqs, wks, wvs, wo.as_f32()?, dy.as_f32()?, &sh);
    let (mut dx, _) = rmsnorm_bwd(xs, g1s, &g.dxln, sh.d);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, dyi) in dx.iter_mut().zip(dy.as_f32()?) {
        *o += dyi * inv_t;
    }
    Ok(vec![Tensor::f32(dx, x.shape())])
}

/// `attn_bwd_w`: `(dγ1, dwq, dwk, dwv, dwo)` — dγ1 is a partial the
/// engine All-Reduces, the matrix grads are rank-local.
pub(crate) fn attn_bwd_w(args: &[&Tensor], dims: &ManifestDims) -> Result<Vec<Tensor>> {
    let [x, dy, g1, wq, wk, wv, wo] = expect_args::<7>("attn_bwd_w", args)?;
    let sh = AttnShape::of(x, dims);
    let (xs, g1s) = (x.as_f32()?, g1.as_f32()?);
    let (wqs, wks, wvs) = (wq.as_f32()?, wk.as_f32()?, wv.as_f32()?);
    let cache = attn_core(xs, g1s, wqs, wks, wvs, &sh);
    let g = attn_core_bwd(&cache, wqs, wks, wvs, wo.as_f32()?, dy.as_f32()?, &sh);
    let (_, dg1) = rmsnorm_bwd(xs, g1s, &g.dxln, sh.d);
    Ok(vec![
        Tensor::f32(dg1, g1.shape()),
        Tensor::f32(g.dwq, wq.shape()),
        Tensor::f32(g.dwk, wk.shape()),
        Tensor::f32(g.dwv, wv.shape()),
        Tensor::f32(g.dwo, wo.shape()),
    ])
}

// ---------------------------------------------------------------------------
// MLP unit (SwiGLU, per-rank ffn slice).
// ---------------------------------------------------------------------------

struct MlpCache {
    xln: Vec<f32>, // [rows, d]
    a: Vec<f32>,   // [rows, fr] gate pre-activation
    b: Vec<f32>,   // [rows, fr] up projection
    h: Vec<f32>,   // [rows, fr] silu(a)·b
}

fn mlp_core(x: &[f32], gamma2: &[f32], wg: &[f32], wu: &[f32], d: usize, fr: usize) -> MlpCache {
    let rows = x.len() / d;
    let xln = rmsnorm(x, gamma2, d);
    let a = matmul(&xln, wg, rows, d, fr);
    let b = matmul(&xln, wu, rows, d, fr);
    let mut h = vec![0.0f32; rows * fr];
    for ((hv, &av), &bv) in h.iter_mut().zip(&a).zip(&b) {
        *hv = av * sigmoid(av) * bv;
    }
    MlpCache { xln, a, b, h }
}

/// `mlp_fwd`: per-rank partial `(silu(x̂Wg)·(x̂Wu))Wd + x/t`.
pub(crate) fn mlp_fwd(args: &[&Tensor], dims: &ManifestDims) -> Result<Vec<Tensor>> {
    let [x, g2, wg, wu, wd] = expect_args::<5>("mlp_fwd", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let rows = x.len() / d;
    let cache = mlp_core(x.as_f32()?, g2.as_f32()?, wg.as_f32()?, wu.as_f32()?, d, fr);
    let mut out = matmul(&cache.h, wd.as_f32()?, rows, fr, d);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, xi) in out.iter_mut().zip(x.as_f32()?) {
        *o += xi * inv_t;
    }
    Ok(vec![Tensor::f32(out, x.shape())])
}

struct MlpCoreGrads {
    dxln: Vec<f32>,
    dwg: Vec<f32>,
    dwu: Vec<f32>,
    dwd: Vec<f32>,
}

fn mlp_core_bwd(
    cache: &MlpCache,
    wg: &[f32],
    wu: &[f32],
    wd: &[f32],
    dy: &[f32],
    d: usize,
    fr: usize,
) -> MlpCoreGrads {
    let rows = cache.xln.len() / d;
    let dh_ = matmul_bt(dy, wd, rows, d, fr);
    let dwd = matmul_at(&cache.h, dy, rows, fr, d);
    let mut da = vec![0.0f32; rows * fr];
    let mut db = vec![0.0f32; rows * fr];
    for i in 0..rows * fr {
        let sig = sigmoid(cache.a[i]);
        let silu = cache.a[i] * sig;
        // d silu / da = σ(a)·(1 + a·(1−σ(a)))
        da[i] = dh_[i] * cache.b[i] * sig * (1.0 + cache.a[i] * (1.0 - sig));
        db[i] = dh_[i] * silu;
    }
    let mut dxln = matmul_bt(&da, wg, rows, fr, d);
    let du_x = matmul_bt(&db, wu, rows, fr, d);
    for (a, b) in dxln.iter_mut().zip(&du_x) {
        *a += b;
    }
    let dwg = matmul_at(&cache.xln, &da, rows, d, fr);
    let dwu = matmul_at(&cache.xln, &db, rows, d, fr);
    MlpCoreGrads { dxln, dwg, dwu, dwd }
}

/// `mlp_bwd_x`: activation-gradient partial `vjp(dy) + dy/t`.
pub(crate) fn mlp_bwd_x(args: &[&Tensor], dims: &ManifestDims) -> Result<Vec<Tensor>> {
    let [x, dy, g2, wg, wu, wd] = expect_args::<6>("mlp_bwd_x", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let xs = x.as_f32()?;
    let g2s = g2.as_f32()?;
    let cache = mlp_core(xs, g2s, wg.as_f32()?, wu.as_f32()?, d, fr);
    let g = mlp_core_bwd(&cache, wg.as_f32()?, wu.as_f32()?, wd.as_f32()?, dy.as_f32()?, d, fr);
    let (mut dx, _) = rmsnorm_bwd(xs, g2s, &g.dxln, d);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, dyi) in dx.iter_mut().zip(dy.as_f32()?) {
        *o += dyi * inv_t;
    }
    Ok(vec![Tensor::f32(dx, x.shape())])
}

/// `mlp_bwd_w`: `(dγ2, dwg, dwu, dwd)`.
pub(crate) fn mlp_bwd_w(args: &[&Tensor], dims: &ManifestDims) -> Result<Vec<Tensor>> {
    let [x, dy, g2, wg, wu, wd] = expect_args::<6>("mlp_bwd_w", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let xs = x.as_f32()?;
    let g2s = g2.as_f32()?;
    let cache = mlp_core(xs, g2s, wg.as_f32()?, wu.as_f32()?, d, fr);
    let g = mlp_core_bwd(&cache, wg.as_f32()?, wu.as_f32()?, wd.as_f32()?, dy.as_f32()?, d, fr);
    let (_, dg2) = rmsnorm_bwd(xs, g2s, &g.dxln, d);
    Ok(vec![
        Tensor::f32(dg2, g2.shape()),
        Tensor::f32(g.dwg, wg.shape()),
        Tensor::f32(g.dwu, wu.shape()),
        Tensor::f32(g.dwd, wd.shape()),
    ])
}

// ---------------------------------------------------------------------------
// Pipeline endpoints.
// ---------------------------------------------------------------------------

/// `embed_fwd`: token lookup, `tokens [mb,s] i32 × emb [V,d] → [mb,s,d]`.
pub(crate) fn embed_fwd(args: &[&Tensor]) -> Result<Vec<Tensor>> {
    let [tok, emb] = expect_args::<2>("embed_fwd", args)?;
    let d = emb.shape()[1];
    let vocab = emb.shape()[0];
    let toks = match tok {
        Tensor::I32 { data, .. } => data,
        _ => anyhow::bail!("embed_fwd: tokens must be i32"),
    };
    let es = emb.as_f32()?;
    let mut out = Vec::with_capacity(toks.len() * d);
    for &t in toks {
        let t = t as usize;
        anyhow::ensure!(t < vocab, "embed_fwd: token {t} out of vocab {vocab}");
        out.extend_from_slice(&es[t * d..(t + 1) * d]);
    }
    let shape = [tok.shape()[0], tok.shape()[1], d];
    Ok(vec![Tensor::f32(out, &shape)])
}

/// `embed_bwd`: scatter-add of `dy` rows into token slots → `[V,d]`.
pub(crate) fn embed_bwd(args: &[&Tensor], dims: &ManifestDims) -> Result<Vec<Tensor>> {
    let [tok, dy] = expect_args::<2>("embed_bwd", args)?;
    let d = dy.shape()[2];
    let toks = match tok {
        Tensor::I32 { data, .. } => data,
        _ => anyhow::bail!("embed_bwd: tokens must be i32"),
    };
    let dys = dy.as_f32()?;
    let mut out = vec![0.0f32; dims.vocab * d];
    for (r, &t) in toks.iter().enumerate() {
        let t = t as usize;
        anyhow::ensure!(t < dims.vocab, "embed_bwd: token {t} out of vocab {}", dims.vocab);
        for e in 0..d {
            out[t * d + e] += dys[r * d + e];
        }
    }
    Ok(vec![Tensor::f32(out, &[dims.vocab, d])])
}

/// `head_loss_grad`: fused LM head + mean token cross-entropy; returns
/// `(loss, dx, dw_head)`.
pub(crate) fn head_loss_grad(args: &[&Tensor]) -> Result<Vec<Tensor>> {
    let [x, wh, tgt] = expect_args::<3>("head_loss_grad", args)?;
    let d = x.shape()[2];
    let v = wh.shape()[1];
    let rows = x.len() / d;
    let xs = x.as_f32()?;
    let whs = wh.as_f32()?;
    let tgts = match tgt {
        Tensor::I32 { data, .. } => data,
        _ => anyhow::bail!("head_loss_grad: targets must be i32"),
    };
    anyhow::ensure!(tgts.len() == rows, "head_loss_grad: {} targets for {rows} rows", tgts.len());

    let logits = matmul(xs, whs, rows, d, v);
    let mut dlogits = vec![0.0f32; rows * v];
    let inv_n = 1.0 / rows as f32;
    let mut loss = 0.0f32;
    for r in 0..rows {
        let lr = &logits[r * v..(r + 1) * v];
        let t = tgts[r] as usize;
        anyhow::ensure!(t < v, "head_loss_grad: target {t} out of vocab {v}");
        let maxv = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for &l in lr {
            z += (l - maxv).exp();
        }
        loss += -(lr[t] - maxv - z.ln());
        let dr = &mut dlogits[r * v..(r + 1) * v];
        for j in 0..v {
            let p = (lr[j] - maxv).exp() / z;
            let hot = if j == t { 1.0 } else { 0.0 };
            dr[j] = (p - hot) * inv_n;
        }
    }
    loss *= inv_n;

    let dx = matmul_bt(&dlogits, whs, rows, v, d);
    let dwh = matmul_at(xs, &dlogits, rows, d, v);
    Ok(vec![
        Tensor::f32(vec![loss], &[]),
        Tensor::f32(dx, x.shape()),
        Tensor::f32(dwh, wh.shape()),
    ])
}
