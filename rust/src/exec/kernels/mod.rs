//! Host kernels for the **virtual backend**: the nine AOT unit signatures
//! (`python/compile/aot.py::unit_signatures`) on the crate's execution hot
//! path — cache-blocked GEMM microkernels ([`gemm`]) over a per-thread
//! scratch arena ([`super::workspace::Workspace`]), so a steady-state
//! training step performs zero scratch allocations.
//!
//! The math is exactly the vendored reference kernels'
//! (`python/compile/kernels/ref.py`, `model.py`):
//!
//! * forwards are per-TP-rank **partials** with the fused residual
//!   `+ x/t` (paper Eq. 1–2) — summing over the TP group's ranks (the
//!   engine's All-Reduce) reconstitutes the dense layer;
//! * `*_bwd_x` returns the activation-gradient partial `vjp(dy) + dy/t`
//!   (the residual was detached in forward, so its `+1` Jacobian is
//!   reconstituted explicitly across the All-Reduce);
//! * `*_bwd_w` returns rank-local weight gradients plus the replicated
//!   RMSNorm gamma partials the engine All-Reduces at step time.
//!
//! Everything accumulates in a fixed order — bit-deterministic across
//! runs, and (because the blocked GEMMs preserve the naive per-element
//! accumulation order, see [`gemm`]) **bit-equal** to the preserved
//! [`reference`] implementation, which `tests/kernel_parity.rs` pins.
//! One deliberate work difference: `*_bwd_x` skips the weight-gradient
//! GEMMs the reference computes and discards (outputs are unaffected).
//! The analytic backwards are pinned against central finite differences
//! in the tests below.
//!
//! Buffer discipline: scratch is `ws.take(..)`/`ws.give(..)` paired
//! within each unit; only the tensors a unit *returns* are plain `Vec`
//! allocations (they escape through the activation store and the P2P
//! channels, so the arena cannot reclaim them).

// Index-heavy tensor math: offset-based loops are the clearest way to
// write the strided head/sequence indexing below.
#![allow(clippy::needless_range_loop)]

pub mod gemm;
pub mod reference;

use crate::config::ManifestDims;
use crate::runtime::Tensor;
use crate::Result;

use super::workspace::Workspace;

pub(crate) use reference::{embed_bwd, embed_fwd};

const EPS: f32 = 1e-6;

/// Checked fixed-arity argument destructuring.
pub(crate) fn expect_args<'a, const N: usize>(
    name: &str,
    args: &[&'a Tensor],
) -> Result<[&'a Tensor; N]> {
    anyhow::ensure!(args.len() == N, "{name}: got {} args, expected {N}", args.len());
    let mut it = args.iter().copied();
    Ok(std::array::from_fn(|_| it.next().unwrap()))
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// RMSNorm forward into a caller-provided row buffer:
/// `y = x · rsqrt(mean(x²)+ε) · γ`, per length-`d` row.
fn rmsnorm_into(x: &[f32], gamma: &[f32], d: usize, y: &mut [f32]) {
    let rows = x.len() / d;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for i in 0..d {
            y[r * d + i] = xr[i] * inv * gamma[i];
        }
    }
}

/// RMSNorm backward into caller-provided buffers. `dx` is assigned; `dg`
/// is *accumulated* and must arrive zeroed (`ws.take` zeroes).
///
/// With `r = rsqrt(mean(x²)+ε)`: `dx_j = r·γ_j·dy_j − (r³/d)·x_j·Σᵢ
/// dyᵢγᵢxᵢ` and `dγ_i = Σ_rows dyᵢ·xᵢ·r`.
fn rmsnorm_bwd_into(
    x: &[f32],
    gamma: &[f32],
    dy: &[f32],
    d: usize,
    dx: &mut [f32],
    dg: &mut [f32],
) {
    let rows = x.len() / d;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        let mut s = 0.0f32;
        for i in 0..d {
            s += dyr[i] * gamma[i] * xr[i];
            dg[i] += dyr[i] * xr[i] * inv;
        }
        let k = inv * inv * inv * s / d as f32;
        for i in 0..d {
            dx[r * d + i] = inv * gamma[i] * dyr[i] - k * xr[i];
        }
    }
}

// ---------------------------------------------------------------------------
// Attention unit (per-rank head slice, causal, GQA).
// ---------------------------------------------------------------------------

/// Saved forward state of one attention-core evaluation — every buffer is
/// workspace scratch; call [`AttnCache::release`] when done.
struct AttnCache {
    xln: Vec<f32>,   // [rows, d]
    q: Vec<f32>,     // [rows, hq*dh]
    k: Vec<f32>,     // [rows, hkv*dh]
    v: Vec<f32>,     // [rows, hkv*dh]
    probs: Vec<f32>, // [mb, hq, s, s] (0 above the diagonal)
    ctx: Vec<f32>,   // [rows, hq*dh]
}

impl AttnCache {
    fn release(self, ws: &mut Workspace) {
        ws.give(self.xln);
        ws.give(self.q);
        ws.give(self.k);
        ws.give(self.v);
        ws.give(self.probs);
        ws.give(self.ctx);
    }
}

struct AttnShape {
    mb: usize,
    s: usize,
    d: usize,
    hq: usize,
    hkv: usize,
    dh: usize,
}

impl AttnShape {
    fn of(x: &Tensor, dims: &ManifestDims) -> AttnShape {
        let sh = x.shape();
        AttnShape {
            mb: sh[0],
            s: sh[1],
            d: sh[2],
            hq: dims.q_heads_per_rank(),
            hkv: dims.kv_heads_per_rank(),
            dh: dims.head_dim(),
        }
    }
    fn rows(&self) -> usize {
        self.mb * self.s
    }
}

/// The `[h·dh, (h+1)·dh)` head slice of row `row` in a `[rows, stride]`
/// buffer.
#[inline]
fn head(buf: &[f32], row: usize, stride: usize, h: usize, dh: usize) -> &[f32] {
    &buf[row * stride + h * dh..row * stride + (h + 1) * dh]
}

/// Forward of `attention_core(rmsnorm(x, γ1), …)` keeping everything the
/// backward needs.
fn attn_core(
    ws: &mut Workspace,
    x: &[f32],
    gamma1: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    sh: &AttnShape,
) -> AttnCache {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let mut xln = ws.take(rows * d);
    rmsnorm_into(x, gamma1, d, &mut xln);
    let mut q = ws.take(rows * qr);
    gemm::matmul(ws, &xln, wq, rows, d, qr, &mut q);
    let mut k = ws.take(rows * kr);
    gemm::matmul(ws, &xln, wk, rows, d, kr, &mut k);
    let mut v = ws.take(rows * kr);
    gemm::matmul(ws, &xln, wv, rows, d, kr, &mut v);
    let group = sh.hq / sh.hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = ws.take(sh.mb * sh.hq * sh.s * sh.s);
    let mut ctx = ws.take(rows * qr);
    // One reusable score row (the reference allocates one per (n,h,t)).
    let mut scores = ws.take(sh.s);
    for n in 0..sh.mb {
        for h in 0..sh.hq {
            let kh = h / group;
            let pbase = ((n * sh.hq) + h) * sh.s * sh.s;
            for t in 0..sh.s {
                let qrow = head(&q, n * sh.s + t, qr, h, dh);
                // Causal scores for u <= t, stable softmax.
                let scores = &mut scores[..t + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (u, sc) in scores.iter_mut().enumerate() {
                    let krow = head(&k, n * sh.s + u, kr, kh, dh);
                    let mut acc = 0.0f32;
                    for e in 0..dh {
                        acc += qrow[e] * krow[e];
                    }
                    *sc = acc * scale;
                    maxv = maxv.max(*sc);
                }
                let mut z = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxv).exp();
                    z += *sc;
                }
                let cbase = (n * sh.s + t) * qr + h * dh;
                for (u, sc) in scores.iter().enumerate() {
                    let p = sc / z;
                    probs[pbase + t * sh.s + u] = p;
                    let vrow = head(&v, n * sh.s + u, kr, kh, dh);
                    for e in 0..dh {
                        ctx[cbase + e] += p * vrow[e];
                    }
                }
            }
        }
    }
    ws.give(scores);
    AttnCache { xln, q, k, v, probs, ctx }
}

/// Shared attention-core backward: gradients at Q/K/V from `dout` (the
/// gradient of the attention-path output `ctx @ wo`, before the
/// residual). Returned buffers are workspace scratch the caller gives
/// back.
fn attn_qkv_grads(
    ws: &mut Workspace,
    cache: &AttnCache,
    wo: &[f32],
    dout: &[f32],
    sh: &AttnShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let group = sh.hq / sh.hkv;
    let scale = 1.0 / (dh as f32).sqrt();

    let mut dctx = ws.take(rows * qr);
    gemm::matmul_bt(ws, dout, wo, rows, d, qr, &mut dctx);

    let mut dq = ws.take(rows * qr);
    let mut dk = ws.take(rows * kr);
    let mut dv = ws.take(rows * kr);
    let mut dp = ws.take(sh.s);
    for n in 0..sh.mb {
        for h in 0..sh.hq {
            let kh = h / group;
            let pbase = ((n * sh.hq) + h) * sh.s * sh.s;
            for t in 0..sh.s {
                let dcrow = head(&dctx, n * sh.s + t, qr, h, dh);
                // dP[t,u] and the softmax-backward row sum.
                let dp = &mut dp[..t + 1];
                let mut rho = 0.0f32;
                for (u, dpu) in dp.iter_mut().enumerate() {
                    let vrow = head(&cache.v, n * sh.s + u, kr, kh, dh);
                    let mut acc = 0.0f32;
                    for e in 0..dh {
                        acc += dcrow[e] * vrow[e];
                    }
                    *dpu = acc;
                    rho += acc * cache.probs[pbase + t * sh.s + u];
                }
                let qrow_base = (n * sh.s + t) * qr + h * dh;
                for (u, dpu) in dp.iter().enumerate() {
                    let p = cache.probs[pbase + t * sh.s + u];
                    let ds = p * (dpu - rho) * scale;
                    let krow_base = (n * sh.s + u) * kr + kh * dh;
                    for e in 0..dh {
                        dq[qrow_base + e] += ds * cache.k[krow_base + e];
                        dk[krow_base + e] += ds * cache.q[qrow_base + e];
                        dv[krow_base + e] += p * dcrow[e];
                    }
                }
            }
        }
    }
    ws.give(dp);
    ws.give(dctx);
    (dq, dk, dv)
}

/// `dxln = dq·wqᵀ + dk·wkᵀ + dv·wvᵀ` (same association as the
/// reference: the wk/wv products are formed separately, then added as
/// `dxln += dk_x + dv_x`). Workspace scratch; caller gives it back.
#[allow(clippy::too_many_arguments)]
fn attn_dxln(
    ws: &mut Workspace,
    dq: &[f32],
    dk: &[f32],
    dv: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    sh: &AttnShape,
) -> Vec<f32> {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let mut dxln = ws.take(rows * d);
    gemm::matmul_bt(ws, dq, wq, rows, qr, d, &mut dxln);
    let mut dk_x = ws.take(rows * d);
    gemm::matmul_bt(ws, dk, wk, rows, kr, d, &mut dk_x);
    let mut dv_x = ws.take(rows * d);
    gemm::matmul_bt(ws, dv, wv, rows, kr, d, &mut dv_x);
    for ((a, b), c) in dxln.iter_mut().zip(&dk_x).zip(&dv_x) {
        *a += *b + *c;
    }
    ws.give(dk_x);
    ws.give(dv_x);
    dxln
}

/// `attn_fwd`: per-rank partial `Attention_r(RMSNorm(x)) + x/t`.
pub(crate) fn attn_fwd(
    args: &[&Tensor],
    dims: &ManifestDims,
    ws: &mut Workspace,
) -> Result<Vec<Tensor>> {
    let [x, g1, wq, wk, wv, wo] = expect_args::<6>("attn_fwd", args)?;
    let sh = AttnShape::of(x, dims);
    let xs = x.as_f32()?;
    let cache = attn_core(ws, xs, g1.as_f32()?, wq.as_f32()?, wk.as_f32()?, wv.as_f32()?, &sh);
    let mut out = vec![0.0f32; sh.rows() * sh.d];
    gemm::matmul(ws, &cache.ctx, wo.as_f32()?, sh.rows(), sh.hq * sh.dh, sh.d, &mut out);
    cache.release(ws);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, xi) in out.iter_mut().zip(xs) {
        *o += xi * inv_t;
    }
    Ok(vec![Tensor::f32(out, x.shape())])
}

/// `attn_bwd_x`: activation-gradient partial `vjp(dy) + dy/t`.
pub(crate) fn attn_bwd_x(
    args: &[&Tensor],
    dims: &ManifestDims,
    ws: &mut Workspace,
) -> Result<Vec<Tensor>> {
    let [x, dy, g1, wq, wk, wv, wo] = expect_args::<7>("attn_bwd_x", args)?;
    let sh = AttnShape::of(x, dims);
    let (xs, g1s, dys) = (x.as_f32()?, g1.as_f32()?, dy.as_f32()?);
    let (wqs, wks, wvs) = (wq.as_f32()?, wk.as_f32()?, wv.as_f32()?);
    let cache = attn_core(ws, xs, g1s, wqs, wks, wvs, &sh);
    let (dq, dk, dv) = attn_qkv_grads(ws, &cache, wo.as_f32()?, dys, &sh);
    cache.release(ws);
    let dxln = attn_dxln(ws, &dq, &dk, &dv, wqs, wks, wvs, &sh);
    ws.give(dq);
    ws.give(dk);
    ws.give(dv);
    let mut dx = vec![0.0f32; sh.rows() * sh.d];
    let mut dg_scratch = ws.take(sh.d);
    rmsnorm_bwd_into(xs, g1s, &dxln, sh.d, &mut dx, &mut dg_scratch);
    ws.give(dg_scratch);
    ws.give(dxln);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, dyi) in dx.iter_mut().zip(dys) {
        *o += dyi * inv_t;
    }
    Ok(vec![Tensor::f32(dx, x.shape())])
}

/// `attn_bwd_w`: `(dγ1, dwq, dwk, dwv, dwo)` — dγ1 is a partial the
/// engine All-Reduces, the matrix grads are rank-local.
pub(crate) fn attn_bwd_w(
    args: &[&Tensor],
    dims: &ManifestDims,
    ws: &mut Workspace,
) -> Result<Vec<Tensor>> {
    let [x, dy, g1, wq, wk, wv, wo] = expect_args::<7>("attn_bwd_w", args)?;
    let sh = AttnShape::of(x, dims);
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let (xs, g1s, dys) = (x.as_f32()?, g1.as_f32()?, dy.as_f32()?);
    let (wqs, wks, wvs) = (wq.as_f32()?, wk.as_f32()?, wv.as_f32()?);
    let cache = attn_core(ws, xs, g1s, wqs, wks, wvs, &sh);
    let (dq, dk, dv) = attn_qkv_grads(ws, &cache, wo.as_f32()?, dys, &sh);

    // Rank-local weight gradients (unit outputs: plain allocations).
    let mut dwo = vec![0.0f32; qr * d];
    gemm::matmul_at(ws, &cache.ctx, dys, rows, qr, d, &mut dwo);
    let mut dwq = vec![0.0f32; d * qr];
    gemm::matmul_at(ws, &cache.xln, &dq, rows, d, qr, &mut dwq);
    let mut dwk = vec![0.0f32; d * kr];
    gemm::matmul_at(ws, &cache.xln, &dk, rows, d, kr, &mut dwk);
    let mut dwv = vec![0.0f32; d * kr];
    gemm::matmul_at(ws, &cache.xln, &dv, rows, d, kr, &mut dwv);

    let dxln = attn_dxln(ws, &dq, &dk, &dv, wqs, wks, wvs, &sh);
    ws.give(dq);
    ws.give(dk);
    ws.give(dv);
    cache.release(ws);
    let mut dg1 = vec![0.0f32; d];
    let mut dx_scratch = ws.take(rows * d);
    rmsnorm_bwd_into(xs, g1s, &dxln, d, &mut dx_scratch, &mut dg1);
    ws.give(dx_scratch);
    ws.give(dxln);
    Ok(vec![
        Tensor::f32(dg1, g1.shape()),
        Tensor::f32(dwq, wq.shape()),
        Tensor::f32(dwk, wk.shape()),
        Tensor::f32(dwv, wv.shape()),
        Tensor::f32(dwo, wo.shape()),
    ])
}

// ---------------------------------------------------------------------------
// MLP unit (SwiGLU, per-rank ffn slice).
// ---------------------------------------------------------------------------

/// Saved SwiGLU forward state — workspace scratch, release when done.
struct MlpCache {
    xln: Vec<f32>, // [rows, d]
    a: Vec<f32>,   // [rows, fr] gate pre-activation
    b: Vec<f32>,   // [rows, fr] up projection
    h: Vec<f32>,   // [rows, fr] silu(a)·b
}

impl MlpCache {
    fn release(self, ws: &mut Workspace) {
        ws.give(self.xln);
        ws.give(self.a);
        ws.give(self.b);
        ws.give(self.h);
    }
}

fn mlp_core(
    ws: &mut Workspace,
    x: &[f32],
    gamma2: &[f32],
    wg: &[f32],
    wu: &[f32],
    d: usize,
    fr: usize,
) -> MlpCache {
    let rows = x.len() / d;
    let mut xln = ws.take(rows * d);
    rmsnorm_into(x, gamma2, d, &mut xln);
    let mut a = ws.take(rows * fr);
    gemm::matmul(ws, &xln, wg, rows, d, fr, &mut a);
    let mut b = ws.take(rows * fr);
    gemm::matmul(ws, &xln, wu, rows, d, fr, &mut b);
    let mut h = ws.take(rows * fr);
    for ((hv, &av), &bv) in h.iter_mut().zip(&a).zip(&b) {
        *hv = av * sigmoid(av) * bv;
    }
    MlpCache { xln, a, b, h }
}

/// Gradients at the gate/up pre-activations from `dy` (before the
/// residual). Workspace scratch; caller gives both back.
fn mlp_da_db(
    ws: &mut Workspace,
    cache: &MlpCache,
    wd: &[f32],
    dy: &[f32],
    d: usize,
    fr: usize,
) -> (Vec<f32>, Vec<f32>) {
    let rows = cache.xln.len() / d;
    let mut dh_ = ws.take(rows * fr);
    gemm::matmul_bt(ws, dy, wd, rows, d, fr, &mut dh_);
    let mut da = ws.take(rows * fr);
    let mut db = ws.take(rows * fr);
    for i in 0..rows * fr {
        let sig = sigmoid(cache.a[i]);
        let silu = cache.a[i] * sig;
        // d silu / da = σ(a)·(1 + a·(1−σ(a)))
        da[i] = dh_[i] * cache.b[i] * sig * (1.0 + cache.a[i] * (1.0 - sig));
        db[i] = dh_[i] * silu;
    }
    ws.give(dh_);
    (da, db)
}

/// `dxln = da·wgᵀ + db·wuᵀ` (reference association: `dxln += du_x`).
fn mlp_dxln(
    ws: &mut Workspace,
    da: &[f32],
    db: &[f32],
    wg: &[f32],
    wu: &[f32],
    d: usize,
    fr: usize,
) -> Vec<f32> {
    let rows = da.len() / fr;
    let mut dxln = ws.take(rows * d);
    gemm::matmul_bt(ws, da, wg, rows, fr, d, &mut dxln);
    let mut du_x = ws.take(rows * d);
    gemm::matmul_bt(ws, db, wu, rows, fr, d, &mut du_x);
    for (a, b) in dxln.iter_mut().zip(&du_x) {
        *a += b;
    }
    ws.give(du_x);
    dxln
}

/// `mlp_fwd`: per-rank partial `(silu(x̂Wg)·(x̂Wu))Wd + x/t`.
pub(crate) fn mlp_fwd(
    args: &[&Tensor],
    dims: &ManifestDims,
    ws: &mut Workspace,
) -> Result<Vec<Tensor>> {
    let [x, g2, wg, wu, wd] = expect_args::<5>("mlp_fwd", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let rows = x.len() / d;
    let xs = x.as_f32()?;
    let cache = mlp_core(ws, xs, g2.as_f32()?, wg.as_f32()?, wu.as_f32()?, d, fr);
    let mut out = vec![0.0f32; rows * d];
    gemm::matmul(ws, &cache.h, wd.as_f32()?, rows, fr, d, &mut out);
    cache.release(ws);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, xi) in out.iter_mut().zip(xs) {
        *o += xi * inv_t;
    }
    Ok(vec![Tensor::f32(out, x.shape())])
}

/// `mlp_bwd_x`: activation-gradient partial `vjp(dy) + dy/t`.
pub(crate) fn mlp_bwd_x(
    args: &[&Tensor],
    dims: &ManifestDims,
    ws: &mut Workspace,
) -> Result<Vec<Tensor>> {
    let [x, dy, g2, wg, wu, wd] = expect_args::<6>("mlp_bwd_x", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let (xs, g2s, dys) = (x.as_f32()?, g2.as_f32()?, dy.as_f32()?);
    let (wgs, wus) = (wg.as_f32()?, wu.as_f32()?);
    let cache = mlp_core(ws, xs, g2s, wgs, wus, d, fr);
    let (da, db) = mlp_da_db(ws, &cache, wd.as_f32()?, dys, d, fr);
    cache.release(ws);
    let dxln = mlp_dxln(ws, &da, &db, wgs, wus, d, fr);
    ws.give(da);
    ws.give(db);
    let mut dx = vec![0.0f32; xs.len()];
    let mut dg_scratch = ws.take(d);
    rmsnorm_bwd_into(xs, g2s, &dxln, d, &mut dx, &mut dg_scratch);
    ws.give(dg_scratch);
    ws.give(dxln);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, dyi) in dx.iter_mut().zip(dys) {
        *o += dyi * inv_t;
    }
    Ok(vec![Tensor::f32(dx, x.shape())])
}

/// `mlp_bwd_w`: `(dγ2, dwg, dwu, dwd)`.
pub(crate) fn mlp_bwd_w(
    args: &[&Tensor],
    dims: &ManifestDims,
    ws: &mut Workspace,
) -> Result<Vec<Tensor>> {
    let [x, dy, g2, wg, wu, wd] = expect_args::<6>("mlp_bwd_w", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let rows = x.len() / d;
    let (xs, g2s, dys) = (x.as_f32()?, g2.as_f32()?, dy.as_f32()?);
    let (wgs, wus) = (wg.as_f32()?, wu.as_f32()?);
    let cache = mlp_core(ws, xs, g2s, wgs, wus, d, fr);
    let (da, db) = mlp_da_db(ws, &cache, wd.as_f32()?, dys, d, fr);

    let mut dwd = vec![0.0f32; fr * d];
    gemm::matmul_at(ws, &cache.h, dys, rows, fr, d, &mut dwd);
    let mut dwg = vec![0.0f32; d * fr];
    gemm::matmul_at(ws, &cache.xln, &da, rows, d, fr, &mut dwg);
    let mut dwu = vec![0.0f32; d * fr];
    gemm::matmul_at(ws, &cache.xln, &db, rows, d, fr, &mut dwu);

    let dxln = mlp_dxln(ws, &da, &db, wgs, wus, d, fr);
    ws.give(da);
    ws.give(db);
    cache.release(ws);
    let mut dg2 = vec![0.0f32; d];
    let mut dx_scratch = ws.take(rows * d);
    rmsnorm_bwd_into(xs, g2s, &dxln, d, &mut dx_scratch, &mut dg2);
    ws.give(dx_scratch);
    ws.give(dxln);
    Ok(vec![
        Tensor::f32(dg2, g2.shape()),
        Tensor::f32(dwg, wg.shape()),
        Tensor::f32(dwu, wu.shape()),
        Tensor::f32(dwd, wd.shape()),
    ])
}

// ---------------------------------------------------------------------------
// Pipeline endpoints. `embed_fwd`/`embed_bwd` have no GEMM and no scratch
// worth pooling — the reference implementations are re-exported above and
// serve both kernel paths.
// ---------------------------------------------------------------------------

/// `head_loss_grad`: fused LM head + mean token cross-entropy; returns
/// `(loss, dx, dw_head)`.
pub(crate) fn head_loss_grad(args: &[&Tensor], ws: &mut Workspace) -> Result<Vec<Tensor>> {
    let [x, wh, tgt] = expect_args::<3>("head_loss_grad", args)?;
    let d = x.shape()[2];
    let v = wh.shape()[1];
    let rows = x.len() / d;
    let xs = x.as_f32()?;
    let whs = wh.as_f32()?;
    let tgts = match tgt {
        Tensor::I32 { data, .. } => data,
        _ => anyhow::bail!("head_loss_grad: targets must be i32"),
    };
    anyhow::ensure!(tgts.len() == rows, "head_loss_grad: {} targets for {rows} rows", tgts.len());

    let mut logits = ws.take(rows * v);
    gemm::matmul(ws, xs, whs, rows, d, v, &mut logits);
    let mut dlogits = ws.take(rows * v);
    let inv_n = 1.0 / rows as f32;
    let mut loss = 0.0f32;
    for r in 0..rows {
        let lr = &logits[r * v..(r + 1) * v];
        let t = tgts[r] as usize;
        anyhow::ensure!(t < v, "head_loss_grad: target {t} out of vocab {v}");
        let maxv = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for &l in lr {
            z += (l - maxv).exp();
        }
        loss += -(lr[t] - maxv - z.ln());
        let dr = &mut dlogits[r * v..(r + 1) * v];
        for j in 0..v {
            let p = (lr[j] - maxv).exp() / z;
            let hot = if j == t { 1.0 } else { 0.0 };
            dr[j] = (p - hot) * inv_n;
        }
    }
    loss *= inv_n;

    let mut dx = vec![0.0f32; rows * d];
    gemm::matmul_bt(ws, &dlogits, whs, rows, v, d, &mut dx);
    let mut dwh = vec![0.0f32; d * v];
    gemm::matmul_at(ws, xs, &dlogits, rows, d, v, &mut dwh);
    ws.give(logits);
    ws.give(dlogits);
    Ok(vec![
        Tensor::f32(vec![loss], &[]),
        Tensor::f32(dx, x.shape()),
        Tensor::f32(dwh, wh.shape()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Rng;

    /// Tiny single-rank dims for the finite-difference checks.
    fn dims(tp: usize) -> ManifestDims {
        ManifestDims {
            vocab: 11,
            d: 8,
            q_heads: 2 * tp,
            kv_heads: tp,
            ffn: 6 * tp,
            layers: 2,
            seq: 3,
            mb: 2,
            tp,
            pp: 1,
            vpp: 1,
        }
    }

    fn randn(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        Rng::for_purpose(99, seed, 7, 0).normal_vec(n, scale)
    }

    fn t3(data: Vec<f32>, mb: usize, s: usize, d: usize) -> Tensor {
        Tensor::f32(data, &[mb, s, d])
    }

    /// Weighted-sum loss of a unit's output — a scalar function of any
    /// input tensor whose analytic gradient the unit's `bwd` must match.
    fn weighted(out: &Tensor, w: &[f32]) -> f32 {
        out.as_f32().unwrap().iter().zip(w).map(|(a, b)| a * b).sum()
    }

    /// Central finite differences of `f` at `x` against `analytic` on a
    /// coordinate subset. f32 noise bounds the achievable agreement; the
    /// tolerances are loose but reject any wrong formula (errors there
    /// are O(grad), two orders of magnitude larger).
    fn fd_check(mut f: impl FnMut(&[f32]) -> f32, x: &[f32], analytic: &[f32], label: &str) {
        assert_eq!(x.len(), analytic.len(), "{label}: length");
        let eps = 1e-2f32;
        let stride = (x.len() / 17).max(1);
        for i in (0..x.len()).step_by(stride) {
            let mut xp = x.to_vec();
            xp[i] += eps;
            let fp = f(&xp);
            xp[i] = x[i] - eps;
            let fm = f(&xp);
            let fd = (fp - fm) / (2.0 * eps);
            let a = analytic[i];
            assert!(
                (a - fd).abs() <= 3e-2 + 0.08 * a.abs().max(fd.abs()),
                "{label}[{i}]: analytic {a} vs fd {fd}"
            );
        }
    }

    struct AttnSetup {
        x: Tensor,
        g1: Tensor,
        wq: Tensor,
        wk: Tensor,
        wv: Tensor,
        wo: Tensor,
        dy: Vec<f32>,
    }

    fn attn_setup(dm: &ManifestDims) -> AttnSetup {
        let (mb, s, d) = (dm.mb, dm.seq, dm.d);
        let qr = dm.q_heads_per_rank() * dm.head_dim();
        let kr = dm.kv_heads_per_rank() * dm.head_dim();
        AttnSetup {
            x: t3(randn(1, mb * s * d, 0.5), mb, s, d),
            g1: Tensor::f32(randn(2, d, 0.3).iter().map(|v| 1.0 + v).collect(), &[d]),
            wq: Tensor::f32(randn(3, d * qr, 0.3), &[d, qr]),
            wk: Tensor::f32(randn(4, d * kr, 0.3), &[d, kr]),
            wv: Tensor::f32(randn(5, d * kr, 0.3), &[d, kr]),
            wo: Tensor::f32(randn(6, qr * d, 0.3), &[qr, d]),
            dy: randn(7, mb * s * d, 0.5),
        }
    }

    #[test]
    fn attn_bwd_x_matches_finite_differences() {
        let dm = dims(2); // exercises the /t residual terms
        let su = attn_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut ws = Workspace::new();
        let dx = attn_bwd_x(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, &mut ws)
            .unwrap()
            .remove(0);
        let f = |xs: &[f32]| {
            let mut w = Workspace::new();
            let xt = t3(xs.to_vec(), dm.mb, dm.seq, dm.d);
            let out =
                attn_fwd(&[&xt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, &mut w).unwrap();
            weighted(&out[0], &su.dy)
        };
        fd_check(f, su.x.as_f32().unwrap(), dx.as_f32().unwrap(), "attn dx");
    }

    #[test]
    fn attn_bwd_w_matches_finite_differences() {
        let dm = dims(1);
        let su = attn_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut ws = Workspace::new();
        let grads = attn_bwd_w(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, &mut ws)
            .unwrap();
        // Perturb each weight tensor in turn (index 0 = gamma1 … 4 = wo).
        for (wi, (name, base)) in [
            ("dgamma1", &su.g1),
            ("dwq", &su.wq),
            ("dwk", &su.wk),
            ("dwv", &su.wv),
            ("dwo", &su.wo),
        ]
        .into_iter()
        .enumerate()
        {
            let f = |wsl: &[f32]| {
                let mut w = Workspace::new();
                let mut params =
                    [su.g1.clone(), su.wq.clone(), su.wk.clone(), su.wv.clone(), su.wo.clone()];
                params[wi] = Tensor::f32(wsl.to_vec(), base.shape());
                let [g1, wq, wk, wv, wo] = &params;
                let out = attn_fwd(&[&su.x, g1, wq, wk, wv, wo], &dm, &mut w).unwrap();
                weighted(&out[0], &su.dy)
            };
            fd_check(f, base.as_f32().unwrap(), grads[wi].as_f32().unwrap(), name);
        }
    }

    struct MlpSetup {
        x: Tensor,
        g2: Tensor,
        wg: Tensor,
        wu: Tensor,
        wd: Tensor,
        dy: Vec<f32>,
    }

    fn mlp_setup(dm: &ManifestDims) -> MlpSetup {
        let (mb, s, d) = (dm.mb, dm.seq, dm.d);
        let fr = dm.ffn_per_rank();
        MlpSetup {
            x: t3(randn(11, mb * s * d, 0.5), mb, s, d),
            g2: Tensor::f32(randn(12, d, 0.3).iter().map(|v| 1.0 + v).collect(), &[d]),
            wg: Tensor::f32(randn(13, d * fr, 0.3), &[d, fr]),
            wu: Tensor::f32(randn(14, d * fr, 0.3), &[d, fr]),
            wd: Tensor::f32(randn(15, fr * d, 0.3), &[fr, d]),
            dy: randn(16, mb * s * d, 0.5),
        }
    }

    #[test]
    fn mlp_bwd_x_matches_finite_differences() {
        let dm = dims(2);
        let su = mlp_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut ws = Workspace::new();
        let dx = mlp_bwd_x(&[&su.x, &dyt, &su.g2, &su.wg, &su.wu, &su.wd], &dm, &mut ws)
            .unwrap()
            .remove(0);
        let f = |xs: &[f32]| {
            let mut w = Workspace::new();
            let xt = t3(xs.to_vec(), dm.mb, dm.seq, dm.d);
            let out = mlp_fwd(&[&xt, &su.g2, &su.wg, &su.wu, &su.wd], &dm, &mut w).unwrap();
            weighted(&out[0], &su.dy)
        };
        fd_check(f, su.x.as_f32().unwrap(), dx.as_f32().unwrap(), "mlp dx");
    }

    #[test]
    fn mlp_bwd_w_matches_finite_differences() {
        let dm = dims(1);
        let su = mlp_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut ws = Workspace::new();
        let grads =
            mlp_bwd_w(&[&su.x, &dyt, &su.g2, &su.wg, &su.wu, &su.wd], &dm, &mut ws).unwrap();
        for (wi, (name, base)) in
            [("dgamma2", &su.g2), ("dwg", &su.wg), ("dwu", &su.wu), ("dwd", &su.wd)]
                .into_iter()
                .enumerate()
        {
            let f = |wsl: &[f32]| {
                let mut w = Workspace::new();
                let mut params = [su.g2.clone(), su.wg.clone(), su.wu.clone(), su.wd.clone()];
                params[wi] = Tensor::f32(wsl.to_vec(), base.shape());
                let [g2, wg, wu, wd] = &params;
                let out = mlp_fwd(&[&su.x, g2, wg, wu, wd], &dm, &mut w).unwrap();
                weighted(&out[0], &su.dy)
            };
            fd_check(f, base.as_f32().unwrap(), grads[wi].as_f32().unwrap(), name);
        }
    }

    #[test]
    fn head_loss_grad_matches_finite_differences() {
        let dm = dims(1);
        let (mb, s, d, v) = (dm.mb, dm.seq, dm.d, dm.vocab);
        let x = t3(randn(21, mb * s * d, 0.5), mb, s, d);
        let wh = Tensor::f32(randn(22, d * v, 0.3), &[d, v]);
        let tgt = Tensor::i32((0..(mb * s) as i32).map(|i| i % v as i32).collect(), &[mb, s]);
        let mut ws = Workspace::new();
        let out = head_loss_grad(&[&x, &wh, &tgt], &mut ws).unwrap();
        let loss = out[0].scalar_f32().unwrap();
        assert!(loss.is_finite() && loss > 0.0);

        let fx = |xs: &[f32]| {
            let mut w = Workspace::new();
            let xt = t3(xs.to_vec(), mb, s, d);
            head_loss_grad(&[&xt, &wh, &tgt], &mut w).unwrap()[0].scalar_f32().unwrap()
        };
        fd_check(fx, x.as_f32().unwrap(), out[1].as_f32().unwrap(), "head dx");
        let fw = |wsl: &[f32]| {
            let mut w = Workspace::new();
            let wt = Tensor::f32(wsl.to_vec(), &[d, v]);
            head_loss_grad(&[&x, &wt, &tgt], &mut w).unwrap()[0].scalar_f32().unwrap()
        };
        fd_check(fw, wh.as_f32().unwrap(), out[2].as_f32().unwrap(), "head dwh");
    }

    #[test]
    fn embed_roundtrip_and_gradient() {
        let dm = dims(1);
        let tok = Tensor::i32(vec![1, 4, 1, 0, 2, 3], &[dm.mb, dm.seq]);
        let emb = Tensor::f32(randn(31, dm.vocab * dm.d, 0.5), &[dm.vocab, dm.d]);
        let x = embed_fwd(&[&tok, &emb]).unwrap().remove(0);
        assert_eq!(x.shape(), &[dm.mb, dm.seq, dm.d]);
        // Row 0 of the output is embedding row of token 1.
        assert_eq!(&x.as_f32().unwrap()[..dm.d], &emb.as_f32().unwrap()[dm.d..2 * dm.d]);

        // Gradient: scatter-add — duplicated token 1 accumulates twice.
        let dy = t3(vec![1.0; dm.mb * dm.seq * dm.d], dm.mb, dm.seq, dm.d);
        let de = embed_bwd(&[&tok, &dy], &dm).unwrap().remove(0);
        assert_eq!(de.shape(), &[dm.vocab, dm.d]);
        let des = de.as_f32().unwrap();
        assert_eq!(des[dm.d], 2.0); // token 1 appears twice
        assert_eq!(des[0], 1.0); // token 0 once
        assert_eq!(des[5 * dm.d], 0.0); // token 5 never
    }

    #[test]
    fn tp_partials_sum_to_the_dense_layer() {
        // Paper Eq. 1 invariant (the python tests' "sum over ranks ==
        // dense"): AR(attn_fwd over 2 ranks' shards) == attn_fwd of the
        // dense layer at tp=1 — same x, sharded weights.
        let dm2 = dims(2);
        let mut dm1 = dims(1);
        // Same underlying dense model: tp=1 dims carry all heads.
        dm1.q_heads = dm2.q_heads;
        dm1.kv_heads = dm2.kv_heads;
        dm1.ffn = dm2.ffn;
        let (mb, s, d) = (dm2.mb, dm2.seq, dm2.d);
        let dh = dm2.head_dim();
        let (qd, kd) = (dm2.q_heads * dh, dm2.kv_heads * dh);
        let x = t3(randn(41, mb * s * d, 0.5), mb, s, d);
        let g1 = Tensor::f32(vec![1.0; d], &[d]);
        let wq = randn(42, d * qd, 0.3);
        let wk = randn(43, d * kd, 0.3);
        let wv = randn(44, d * kd, 0.3);
        let wo = randn(45, qd * d, 0.3);

        let mut ws = Workspace::new();
        let wqt = Tensor::f32(wq.clone(), &[d, qd]);
        let wkt = Tensor::f32(wk.clone(), &[d, kd]);
        let wvt = Tensor::f32(wv.clone(), &[d, kd]);
        let wot = Tensor::f32(wo.clone(), &[qd, d]);
        let dense =
            attn_fwd(&[&x, &g1, &wqt, &wkt, &wvt, &wot], &dm1, &mut ws).unwrap().remove(0);

        let col = |w: &[f32], cols: usize, c0: usize, c1: usize| -> Vec<f32> {
            let rows = w.len() / cols;
            let mut out = Vec::new();
            for r in 0..rows {
                out.extend_from_slice(&w[r * cols + c0..r * cols + c1]);
            }
            out
        };
        let mut summed = vec![0.0f32; mb * s * d];
        for r in 0..2 {
            let (qr, kr) = (qd / 2, kd / 2);
            let wqs = Tensor::f32(col(&wq, qd, r * qr, (r + 1) * qr), &[d, qr]);
            let wks = Tensor::f32(col(&wk, kd, r * kr, (r + 1) * kr), &[d, kr]);
            let wvs = Tensor::f32(col(&wv, kd, r * kr, (r + 1) * kr), &[d, kr]);
            let wos = Tensor::f32(wo[r * qr * d..(r + 1) * qr * d].to_vec(), &[qr, d]);
            let part =
                attn_fwd(&[&x, &g1, &wqs, &wks, &wvs, &wos], &dm2, &mut ws).unwrap().remove(0);
            for (a, b) in summed.iter_mut().zip(part.as_f32().unwrap()) {
                *a += b;
            }
        }
        for (i, (a, b)) in summed.iter().zip(dense.as_f32().unwrap()).enumerate() {
            assert!((a - b).abs() < 1e-4, "elem {i}: sharded {a} vs dense {b}");
        }
    }

    #[test]
    fn units_return_all_workspace_scratch() {
        // Take/give pairing: running every arena-backed unit a second
        // time on the same workspace allocates nothing — a leaked buffer
        // would surface here (and as a nonzero steady-state count in
        // `tests/train_virtual.rs`).
        let dm = dims(2);
        let su = attn_setup(&dm);
        let mu = mlp_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let wh = Tensor::f32(randn(51, dm.d * dm.vocab, 0.3), &[dm.d, dm.vocab]);
        let tgt = Tensor::i32(vec![1; dm.mb * dm.seq], &[dm.mb, dm.seq]);
        let mut ws = Workspace::new();
        let mut run_all = |ws: &mut Workspace| {
            attn_fwd(&[&su.x, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, ws).unwrap();
            attn_bwd_x(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, ws).unwrap();
            attn_bwd_w(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, ws).unwrap();
            mlp_fwd(&[&mu.x, &mu.g2, &mu.wg, &mu.wu, &mu.wd], &dm, ws).unwrap();
            mlp_bwd_x(&[&mu.x, &dyt, &mu.g2, &mu.wg, &mu.wu, &mu.wd], &dm, ws).unwrap();
            mlp_bwd_w(&[&mu.x, &dyt, &mu.g2, &mu.wg, &mu.wu, &mu.wd], &dm, ws).unwrap();
            head_loss_grad(&[&su.x, &wh, &tgt], ws).unwrap();
        };
        run_all(&mut ws);
        let warm = ws.stats().fresh_allocs;
        assert!(warm > 0, "arena-backed units must use the workspace");
        run_all(&mut ws);
        assert_eq!(ws.stats().fresh_allocs, warm, "second run must recycle every buffer");
    }
}
