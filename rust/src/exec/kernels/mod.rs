//! Host kernels for the **virtual backend**: the nine AOT unit signatures
//! (`python/compile/aot.py::unit_signatures`) on the crate's execution hot
//! path — cache-blocked, optionally SIMD-tiled and multithreaded GEMM
//! microkernels ([`gemm`]) over per-thread scratch arenas
//! ([`super::workspace::Workspace`]) carried in a [`KernelCtx`], so a
//! steady-state training step performs zero scratch allocations.
//!
//! The math is exactly the vendored reference kernels'
//! (`python/compile/kernels/ref.py`, `model.py`):
//!
//! * forwards are per-TP-rank **partials** with the fused residual
//!   `+ x/t` (paper Eq. 1–2) — summing over the TP group's ranks (the
//!   engine's All-Reduce) reconstitutes the dense layer;
//! * `*_bwd_x` returns the activation-gradient partial `vjp(dy) + dy/t`
//!   (the residual was detached in forward, so its `+1` Jacobian is
//!   reconstituted explicitly across the All-Reduce);
//! * `*_bwd_w` returns rank-local weight gradients plus the replicated
//!   RMSNorm gamma partials the engine All-Reduces at step time.
//!
//! Everything accumulates in a fixed order — bit-deterministic across
//! runs. **Oracle policy** (DESIGN.md §13): on the scalar/blocked path
//! every unit is **bit-equal** to the preserved [`reference`]
//! implementation (the blocked GEMMs preserve the naive per-element
//! accumulation order, see [`gemm`]), which `tests/kernel_parity.rs`
//! pins. The SIMD path keeps the GEMMs bit-equal too (wider tiles only
//! repartition the output space, never a depth chain) but swaps the
//! attention core for the flash-tiled [`attn_core_flash`], whose blocked
//! online softmax *reassociates* the row sums — that one path is held to
//! a documented **≤1e-5** tolerance against the dense core instead of
//! bit equality. One deliberate work difference from the reference:
//! `*_bwd_x` skips the weight-gradient GEMMs the reference computes and
//! discards (outputs are unaffected).  The analytic backwards are pinned
//! against central finite differences in the tests below.
//!
//! Buffer discipline: scratch is `ws.take(..)`/`ws.give(..)` paired
//! within each unit. Since the [`super::Backend::recycle`] seam landed,
//! unit *outputs* are arena-backed too — the engine hands each returned
//! tensor's storage back to the pool at its death site, so even the
//! escaping buffers (activation store, P2P channels) recirculate and
//! `workspace_steady_allocs == 0` holds across the whole step.

// Index-heavy tensor math: offset-based loops are the clearest way to
// write the strided head/sequence indexing below.
#![allow(clippy::needless_range_loop)]

pub mod gemm;
pub mod reference;

use crate::config::ManifestDims;
use crate::runtime::Tensor;
use crate::Result;

use super::workspace::{Workspace, WorkspaceStats};

const EPS: f32 = 1e-6;

/// Key-block width of the flash-tiled attention core: scores live in one
/// stack-resident block of this many f32s instead of an O(s²) `probs`
/// buffer, so attention scratch is O(s·block) per call.
const FLASH_BLK: usize = 32;

/// Execution context threaded through every kernel: the calling thread's
/// scratch arena, the register-tile selection, and (when the worker pool
/// is enabled) one private arena per GEMM worker so parallel panel
/// packing never contends.
pub struct KernelCtx {
    /// The calling thread's arena (packing panels, unit scratch, outputs).
    pub ws: Workspace,
    /// `true` → SIMD register tiles + flash-tiled attention
    /// ([`super::KernelPath::Simd`]); `false` → the scalar blocked path.
    pub simd: bool,
    /// Worker-pool arenas for parallel GEMM bands; empty (len < 2) means
    /// every GEMM runs on the calling thread.
    pub worker_ws: Vec<Workspace>,
}

impl KernelCtx {
    /// Single-threaded context (no worker pool).
    pub fn serial(simd: bool) -> KernelCtx {
        KernelCtx { ws: Workspace::new(), simd, worker_ws: Vec::new() }
    }

    /// Context with a bounded worker pool of `workers` threads. Fewer
    /// than two workers degenerates to the serial context (one worker
    /// would just move the same serial work off-thread).
    pub fn with_workers(simd: bool, workers: usize) -> KernelCtx {
        let worker_ws =
            if workers >= 2 { (0..workers).map(|_| Workspace::new()).collect() } else { Vec::new() };
        KernelCtx { ws: Workspace::new(), simd, worker_ws }
    }

    /// Aggregate stats over the main arena and every worker arena, so the
    /// steady-state zero-allocation invariant covers the pool too.
    pub fn stats(&self) -> WorkspaceStats {
        let mut s = self.ws.stats();
        for w in &self.worker_ws {
            let t = w.stats();
            s.fresh_allocs += t.fresh_allocs;
            s.takes += t.takes;
            s.peak_bytes += t.peak_bytes;
        }
        s
    }
}

/// Checked fixed-arity argument destructuring.
pub(crate) fn expect_args<'a, const N: usize>(
    name: &str,
    args: &[&'a Tensor],
) -> Result<[&'a Tensor; N]> {
    anyhow::ensure!(args.len() == N, "{name}: got {} args, expected {N}", args.len());
    let mut it = args.iter().copied();
    Ok(std::array::from_fn(|_| it.next().unwrap()))
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// RMSNorm forward into a caller-provided row buffer:
/// `y = x · rsqrt(mean(x²)+ε) · γ`, per length-`d` row.
fn rmsnorm_into(x: &[f32], gamma: &[f32], d: usize, y: &mut [f32]) {
    let rows = x.len() / d;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for i in 0..d {
            y[r * d + i] = xr[i] * inv * gamma[i];
        }
    }
}

/// RMSNorm backward into caller-provided buffers. `dx` is assigned; `dg`
/// is *accumulated* and must arrive zeroed (`ws.take` zeroes).
///
/// With `r = rsqrt(mean(x²)+ε)`: `dx_j = r·γ_j·dy_j − (r³/d)·x_j·Σᵢ
/// dyᵢγᵢxᵢ` and `dγ_i = Σ_rows dyᵢ·xᵢ·r`.
fn rmsnorm_bwd_into(
    x: &[f32],
    gamma: &[f32],
    dy: &[f32],
    d: usize,
    dx: &mut [f32],
    dg: &mut [f32],
) {
    let rows = x.len() / d;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        let mut s = 0.0f32;
        for i in 0..d {
            s += dyr[i] * gamma[i] * xr[i];
            dg[i] += dyr[i] * xr[i] * inv;
        }
        let k = inv * inv * inv * s / d as f32;
        for i in 0..d {
            dx[r * d + i] = inv * gamma[i] * dyr[i] - k * xr[i];
        }
    }
}

// ---------------------------------------------------------------------------
// Attention unit (per-rank head slice, causal, GQA).
// ---------------------------------------------------------------------------

/// Saved forward state of one attention-core evaluation — every buffer is
/// workspace scratch; call [`AttnCache::release`] when done.
///
/// The two cores save different state: the dense core fills `probs`
/// (O(s²) per head) and leaves `m`/`l` empty; the flash core leaves
/// `probs` empty and saves only the per-row softmax statistics `m`
/// (running max) and `l` (denominator) — O(s) per head — from which the
/// backward recomputes any probability it needs.
struct AttnCache {
    xln: Vec<f32>,   // [rows, d]
    q: Vec<f32>,     // [rows, hq*dh]
    k: Vec<f32>,     // [rows, hkv*dh]
    v: Vec<f32>,     // [rows, hkv*dh]
    probs: Vec<f32>, // dense: [mb, hq, s, s] (0 above the diagonal); flash: empty
    m: Vec<f32>,     // flash: [mb, hq, s] row max; dense: empty
    l: Vec<f32>,     // flash: [mb, hq, s] softmax denominator; dense: empty
    ctx: Vec<f32>,   // [rows, hq*dh]
}

impl AttnCache {
    fn release(self, ws: &mut Workspace) {
        ws.give(self.xln);
        ws.give(self.q);
        ws.give(self.k);
        ws.give(self.v);
        ws.give(self.probs);
        ws.give(self.m);
        ws.give(self.l);
        ws.give(self.ctx);
    }
}

struct AttnShape {
    mb: usize,
    s: usize,
    d: usize,
    hq: usize,
    hkv: usize,
    dh: usize,
}

impl AttnShape {
    fn of(x: &Tensor, dims: &ManifestDims) -> AttnShape {
        let sh = x.shape();
        AttnShape {
            mb: sh[0],
            s: sh[1],
            d: sh[2],
            hq: dims.q_heads_per_rank(),
            hkv: dims.kv_heads_per_rank(),
            dh: dims.head_dim(),
        }
    }
    fn rows(&self) -> usize {
        self.mb * self.s
    }
}

/// The `[h·dh, (h+1)·dh)` head slice of row `row` in a `[rows, stride]`
/// buffer.
#[inline]
fn head(buf: &[f32], row: usize, stride: usize, h: usize, dh: usize) -> &[f32] {
    &buf[row * stride + h * dh..row * stride + (h + 1) * dh]
}

/// RMSNorm + Q/K/V projections shared by both attention cores.
#[allow(clippy::type_complexity)]
fn attn_proj(
    cx: &mut KernelCtx,
    x: &[f32],
    gamma1: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    sh: &AttnShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let mut xln = cx.ws.take(rows * d);
    rmsnorm_into(x, gamma1, d, &mut xln);
    let mut q = cx.ws.take(rows * qr);
    gemm::matmul(cx, &xln, wq, rows, d, qr, &mut q);
    let mut k = cx.ws.take(rows * kr);
    gemm::matmul(cx, &xln, wk, rows, d, kr, &mut k);
    let mut v = cx.ws.take(rows * kr);
    gemm::matmul(cx, &xln, wv, rows, d, kr, &mut v);
    (xln, q, k, v)
}

/// Forward of `attention_core(rmsnorm(x, γ1), …)` keeping everything the
/// backward needs. Dispatches on [`KernelCtx::simd`]: the dense core
/// (bit-equal to the reference) or the flash-tiled core (≤1e-5).
fn attn_core(
    cx: &mut KernelCtx,
    x: &[f32],
    gamma1: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    sh: &AttnShape,
) -> AttnCache {
    if cx.simd {
        return attn_core_flash(cx, x, gamma1, wq, wk, wv, sh);
    }
    let (rows, dh) = (sh.rows(), sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let (xln, q, k, v) = attn_proj(cx, x, gamma1, wq, wk, wv, sh);
    let group = sh.hq / sh.hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = cx.ws.take(sh.mb * sh.hq * sh.s * sh.s);
    let mut ctx = cx.ws.take(rows * qr);
    // One reusable score row (the reference allocates one per (n,h,t)).
    let mut scores = cx.ws.take(sh.s);
    for n in 0..sh.mb {
        for h in 0..sh.hq {
            let kh = h / group;
            let pbase = ((n * sh.hq) + h) * sh.s * sh.s;
            for t in 0..sh.s {
                let qrow = head(&q, n * sh.s + t, qr, h, dh);
                // Causal scores for u <= t, stable softmax.
                let scores = &mut scores[..t + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (u, sc) in scores.iter_mut().enumerate() {
                    let krow = head(&k, n * sh.s + u, kr, kh, dh);
                    let mut acc = 0.0f32;
                    for e in 0..dh {
                        acc += qrow[e] * krow[e];
                    }
                    *sc = acc * scale;
                    maxv = maxv.max(*sc);
                }
                let mut z = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxv).exp();
                    z += *sc;
                }
                let cbase = (n * sh.s + t) * qr + h * dh;
                for (u, sc) in scores.iter().enumerate() {
                    let p = sc / z;
                    probs[pbase + t * sh.s + u] = p;
                    let vrow = head(&v, n * sh.s + u, kr, kh, dh);
                    for e in 0..dh {
                        ctx[cbase + e] += p * vrow[e];
                    }
                }
            }
        }
    }
    cx.ws.give(scores);
    AttnCache { xln, q, k, v, probs, m: Vec::new(), l: Vec::new(), ctx }
}

/// Flash-tiled attention forward (blocked online softmax, following the
/// `python/compile/kernels/attention.py` exemplar): per query row a
/// running max `m` and denominator `l` are maintained across
/// [`FLASH_BLK`]-wide key blocks, rescaling the partial context row in
/// place — no `probs` buffer, no per-head score row; the only score
/// storage is one stack block. The saved `(m, l)` statistics let the
/// backward recompute probabilities on the fly.
///
/// The rescale factor `exp(m − m_new)` is applied unconditionally:
/// `m = −inf` before the first block makes it `exp(−inf) = 0` exactly,
/// wiping the (zero-initialized) accumulators without a branch.
fn attn_core_flash(
    cx: &mut KernelCtx,
    x: &[f32],
    gamma1: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    sh: &AttnShape,
) -> AttnCache {
    let (rows, dh) = (sh.rows(), sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let (xln, q, k, v) = attn_proj(cx, x, gamma1, wq, wk, wv, sh);
    let group = sh.hq / sh.hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = cx.ws.take(rows * qr);
    let mut mstat = cx.ws.take(sh.mb * sh.hq * sh.s);
    let mut lstat = cx.ws.take(sh.mb * sh.hq * sh.s);
    for n in 0..sh.mb {
        for h in 0..sh.hq {
            let kh = h / group;
            for t in 0..sh.s {
                let qrow = head(&q, n * sh.s + t, qr, h, dh);
                let cbase = (n * sh.s + t) * qr + h * dh;
                let mut m = f32::NEG_INFINITY;
                let mut l = 0.0f32;
                let mut u0 = 0;
                while u0 <= t {
                    let blk = FLASH_BLK.min(t + 1 - u0);
                    let mut sc = [0.0f32; FLASH_BLK];
                    let mut bmax = f32::NEG_INFINITY;
                    for (j, scj) in sc[..blk].iter_mut().enumerate() {
                        let krow = head(&k, n * sh.s + u0 + j, kr, kh, dh);
                        let mut acc = 0.0f32;
                        for e in 0..dh {
                            acc += qrow[e] * krow[e];
                        }
                        *scj = acc * scale;
                        bmax = bmax.max(*scj);
                    }
                    let mnew = m.max(bmax);
                    let corr = (m - mnew).exp();
                    l *= corr;
                    for e in 0..dh {
                        ctx[cbase + e] *= corr;
                    }
                    for (j, &scj) in sc[..blk].iter().enumerate() {
                        let p = (scj - mnew).exp();
                        l += p;
                        let vrow = head(&v, n * sh.s + u0 + j, kr, kh, dh);
                        for e in 0..dh {
                            ctx[cbase + e] += p * vrow[e];
                        }
                    }
                    m = mnew;
                    u0 += FLASH_BLK;
                }
                let inv = 1.0 / l;
                for e in 0..dh {
                    ctx[cbase + e] *= inv;
                }
                let stat = (n * sh.hq + h) * sh.s + t;
                mstat[stat] = m;
                lstat[stat] = l;
            }
        }
    }
    AttnCache { xln, q, k, v, probs: Vec::new(), m: mstat, l: lstat, ctx }
}

/// Shared attention-core backward: gradients at Q/K/V from `dout` (the
/// gradient of the attention-path output `ctx @ wo`, before the
/// residual). Returned buffers are workspace scratch the caller gives
/// back. Dispatches on the cache's shape: a dense cache replays the
/// stored `probs`; a flash cache recomputes `p = exp(s·scale − m)/l`
/// per key from the saved statistics and gets the softmax row sum `ρ`
/// for free as `dout_ctx · ctx` (since `ctx = Σ p·v`, the
/// flash-attention-2 trick) — still no O(s²) buffer.
fn attn_qkv_grads(
    cx: &mut KernelCtx,
    cache: &AttnCache,
    wo: &[f32],
    dout: &[f32],
    sh: &AttnShape,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let group = sh.hq / sh.hkv;
    let scale = 1.0 / (dh as f32).sqrt();

    let mut dctx = cx.ws.take(rows * qr);
    gemm::matmul_bt(cx, dout, wo, rows, d, qr, &mut dctx);

    let mut dq = cx.ws.take(rows * qr);
    let mut dk = cx.ws.take(rows * kr);
    let mut dv = cx.ws.take(rows * kr);
    if cache.probs.is_empty() {
        // Flash backward: per (t, u) the probability is recomputed from
        // the row statistics; ρ comes from one dh-length dot product.
        for n in 0..sh.mb {
            for h in 0..sh.hq {
                let kh = h / group;
                for t in 0..sh.s {
                    let dcrow = head(&dctx, n * sh.s + t, qr, h, dh);
                    let crow = head(&cache.ctx, n * sh.s + t, qr, h, dh);
                    let mut rho = 0.0f32;
                    for e in 0..dh {
                        rho += dcrow[e] * crow[e];
                    }
                    let stat = (n * sh.hq + h) * sh.s + t;
                    let m = cache.m[stat];
                    let linv = 1.0 / cache.l[stat];
                    let qrow_base = (n * sh.s + t) * qr + h * dh;
                    for u in 0..=t {
                        let krow_base = (n * sh.s + u) * kr + kh * dh;
                        let mut acc = 0.0f32;
                        for e in 0..dh {
                            acc += cache.q[qrow_base + e] * cache.k[krow_base + e];
                        }
                        let p = (acc * scale - m).exp() * linv;
                        let mut dpu = 0.0f32;
                        for e in 0..dh {
                            dpu += dcrow[e] * cache.v[krow_base + e];
                        }
                        let ds = p * (dpu - rho) * scale;
                        for e in 0..dh {
                            dq[qrow_base + e] += ds * cache.k[krow_base + e];
                            dk[krow_base + e] += ds * cache.q[qrow_base + e];
                            dv[krow_base + e] += p * dcrow[e];
                        }
                    }
                }
            }
        }
        cx.ws.give(dctx);
        return (dq, dk, dv);
    }
    let mut dp = cx.ws.take(sh.s);
    for n in 0..sh.mb {
        for h in 0..sh.hq {
            let kh = h / group;
            let pbase = ((n * sh.hq) + h) * sh.s * sh.s;
            for t in 0..sh.s {
                let dcrow = head(&dctx, n * sh.s + t, qr, h, dh);
                // dP[t,u] and the softmax-backward row sum.
                let dp = &mut dp[..t + 1];
                let mut rho = 0.0f32;
                for (u, dpu) in dp.iter_mut().enumerate() {
                    let vrow = head(&cache.v, n * sh.s + u, kr, kh, dh);
                    let mut acc = 0.0f32;
                    for e in 0..dh {
                        acc += dcrow[e] * vrow[e];
                    }
                    *dpu = acc;
                    rho += acc * cache.probs[pbase + t * sh.s + u];
                }
                let qrow_base = (n * sh.s + t) * qr + h * dh;
                for (u, dpu) in dp.iter().enumerate() {
                    let p = cache.probs[pbase + t * sh.s + u];
                    let ds = p * (dpu - rho) * scale;
                    let krow_base = (n * sh.s + u) * kr + kh * dh;
                    for e in 0..dh {
                        dq[qrow_base + e] += ds * cache.k[krow_base + e];
                        dk[krow_base + e] += ds * cache.q[qrow_base + e];
                        dv[krow_base + e] += p * dcrow[e];
                    }
                }
            }
        }
    }
    cx.ws.give(dp);
    cx.ws.give(dctx);
    (dq, dk, dv)
}

/// `dxln = dq·wqᵀ + dk·wkᵀ + dv·wvᵀ` (same association as the
/// reference: the wk/wv products are formed separately, then added as
/// `dxln += dk_x + dv_x`). Workspace scratch; caller gives it back.
#[allow(clippy::too_many_arguments)]
fn attn_dxln(
    cx: &mut KernelCtx,
    dq: &[f32],
    dk: &[f32],
    dv: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    sh: &AttnShape,
) -> Vec<f32> {
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let mut dxln = cx.ws.take(rows * d);
    gemm::matmul_bt(cx, dq, wq, rows, qr, d, &mut dxln);
    let mut dk_x = cx.ws.take(rows * d);
    gemm::matmul_bt(cx, dk, wk, rows, kr, d, &mut dk_x);
    let mut dv_x = cx.ws.take(rows * d);
    gemm::matmul_bt(cx, dv, wv, rows, kr, d, &mut dv_x);
    for ((a, b), c) in dxln.iter_mut().zip(&dk_x).zip(&dv_x) {
        *a += *b + *c;
    }
    cx.ws.give(dk_x);
    cx.ws.give(dv_x);
    dxln
}

/// `attn_fwd`: per-rank partial `Attention_r(RMSNorm(x)) + x/t`.
pub(crate) fn attn_fwd(
    args: &[&Tensor],
    dims: &ManifestDims,
    cx: &mut KernelCtx,
) -> Result<Vec<Tensor>> {
    let [x, g1, wq, wk, wv, wo] = expect_args::<6>("attn_fwd", args)?;
    let sh = AttnShape::of(x, dims);
    let xs = x.as_f32()?;
    let cache = attn_core(cx, xs, g1.as_f32()?, wq.as_f32()?, wk.as_f32()?, wv.as_f32()?, &sh);
    let mut out = cx.ws.take(sh.rows() * sh.d);
    gemm::matmul(cx, &cache.ctx, wo.as_f32()?, sh.rows(), sh.hq * sh.dh, sh.d, &mut out);
    cache.release(&mut cx.ws);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, xi) in out.iter_mut().zip(xs) {
        *o += xi * inv_t;
    }
    Ok(vec![Tensor::f32(out, x.shape())])
}

/// `attn_bwd_x`: activation-gradient partial `vjp(dy) + dy/t`.
pub(crate) fn attn_bwd_x(
    args: &[&Tensor],
    dims: &ManifestDims,
    cx: &mut KernelCtx,
) -> Result<Vec<Tensor>> {
    let [x, dy, g1, wq, wk, wv, wo] = expect_args::<7>("attn_bwd_x", args)?;
    let sh = AttnShape::of(x, dims);
    let (xs, g1s, dys) = (x.as_f32()?, g1.as_f32()?, dy.as_f32()?);
    let (wqs, wks, wvs) = (wq.as_f32()?, wk.as_f32()?, wv.as_f32()?);
    let cache = attn_core(cx, xs, g1s, wqs, wks, wvs, &sh);
    let (dq, dk, dv) = attn_qkv_grads(cx, &cache, wo.as_f32()?, dys, &sh);
    cache.release(&mut cx.ws);
    let dxln = attn_dxln(cx, &dq, &dk, &dv, wqs, wks, wvs, &sh);
    cx.ws.give(dq);
    cx.ws.give(dk);
    cx.ws.give(dv);
    let mut dx = cx.ws.take(sh.rows() * sh.d);
    let mut dg_scratch = cx.ws.take(sh.d);
    rmsnorm_bwd_into(xs, g1s, &dxln, sh.d, &mut dx, &mut dg_scratch);
    cx.ws.give(dg_scratch);
    cx.ws.give(dxln);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, dyi) in dx.iter_mut().zip(dys) {
        *o += dyi * inv_t;
    }
    Ok(vec![Tensor::f32(dx, x.shape())])
}

/// `attn_bwd_w`: `(dγ1, dwq, dwk, dwv, dwo)` — dγ1 is a partial the
/// engine All-Reduces, the matrix grads are rank-local.
pub(crate) fn attn_bwd_w(
    args: &[&Tensor],
    dims: &ManifestDims,
    cx: &mut KernelCtx,
) -> Result<Vec<Tensor>> {
    let [x, dy, g1, wq, wk, wv, wo] = expect_args::<7>("attn_bwd_w", args)?;
    let sh = AttnShape::of(x, dims);
    let (rows, d, dh) = (sh.rows(), sh.d, sh.dh);
    let (qr, kr) = (sh.hq * dh, sh.hkv * dh);
    let (xs, g1s, dys) = (x.as_f32()?, g1.as_f32()?, dy.as_f32()?);
    let (wqs, wks, wvs) = (wq.as_f32()?, wk.as_f32()?, wv.as_f32()?);
    let cache = attn_core(cx, xs, g1s, wqs, wks, wvs, &sh);
    let (dq, dk, dv) = attn_qkv_grads(cx, &cache, wo.as_f32()?, dys, &sh);

    // Rank-local weight gradients (unit outputs: arena-backed, recycled
    // by the engine after the optimizer accumulates them).
    let mut dwo = cx.ws.take(qr * d);
    gemm::matmul_at(cx, &cache.ctx, dys, rows, qr, d, &mut dwo);
    let mut dwq = cx.ws.take(d * qr);
    gemm::matmul_at(cx, &cache.xln, &dq, rows, d, qr, &mut dwq);
    let mut dwk = cx.ws.take(d * kr);
    gemm::matmul_at(cx, &cache.xln, &dk, rows, d, kr, &mut dwk);
    let mut dwv = cx.ws.take(d * kr);
    gemm::matmul_at(cx, &cache.xln, &dv, rows, d, kr, &mut dwv);

    let dxln = attn_dxln(cx, &dq, &dk, &dv, wqs, wks, wvs, &sh);
    cx.ws.give(dq);
    cx.ws.give(dk);
    cx.ws.give(dv);
    cache.release(&mut cx.ws);
    let mut dg1 = cx.ws.take(d);
    let mut dx_scratch = cx.ws.take(rows * d);
    rmsnorm_bwd_into(xs, g1s, &dxln, d, &mut dx_scratch, &mut dg1);
    cx.ws.give(dx_scratch);
    cx.ws.give(dxln);
    Ok(vec![
        Tensor::f32(dg1, g1.shape()),
        Tensor::f32(dwq, wq.shape()),
        Tensor::f32(dwk, wk.shape()),
        Tensor::f32(dwv, wv.shape()),
        Tensor::f32(dwo, wo.shape()),
    ])
}

// ---------------------------------------------------------------------------
// MLP unit (SwiGLU, per-rank ffn slice).
// ---------------------------------------------------------------------------

/// Saved SwiGLU forward state — workspace scratch, release when done.
struct MlpCache {
    xln: Vec<f32>, // [rows, d]
    a: Vec<f32>,   // [rows, fr] gate pre-activation
    b: Vec<f32>,   // [rows, fr] up projection
    h: Vec<f32>,   // [rows, fr] silu(a)·b
}

impl MlpCache {
    fn release(self, ws: &mut Workspace) {
        ws.give(self.xln);
        ws.give(self.a);
        ws.give(self.b);
        ws.give(self.h);
    }
}

fn mlp_core(
    cx: &mut KernelCtx,
    x: &[f32],
    gamma2: &[f32],
    wg: &[f32],
    wu: &[f32],
    d: usize,
    fr: usize,
) -> MlpCache {
    let rows = x.len() / d;
    let mut xln = cx.ws.take(rows * d);
    rmsnorm_into(x, gamma2, d, &mut xln);
    let mut a = cx.ws.take(rows * fr);
    gemm::matmul(cx, &xln, wg, rows, d, fr, &mut a);
    let mut b = cx.ws.take(rows * fr);
    gemm::matmul(cx, &xln, wu, rows, d, fr, &mut b);
    let mut h = cx.ws.take(rows * fr);
    for ((hv, &av), &bv) in h.iter_mut().zip(&a).zip(&b) {
        *hv = av * sigmoid(av) * bv;
    }
    MlpCache { xln, a, b, h }
}

/// Gradients at the gate/up pre-activations from `dy` (before the
/// residual). Workspace scratch; caller gives both back.
fn mlp_da_db(
    cx: &mut KernelCtx,
    cache: &MlpCache,
    wd: &[f32],
    dy: &[f32],
    d: usize,
    fr: usize,
) -> (Vec<f32>, Vec<f32>) {
    let rows = cache.xln.len() / d;
    let mut dh_ = cx.ws.take(rows * fr);
    gemm::matmul_bt(cx, dy, wd, rows, d, fr, &mut dh_);
    let mut da = cx.ws.take(rows * fr);
    let mut db = cx.ws.take(rows * fr);
    for i in 0..rows * fr {
        let sig = sigmoid(cache.a[i]);
        let silu = cache.a[i] * sig;
        // d silu / da = σ(a)·(1 + a·(1−σ(a)))
        da[i] = dh_[i] * cache.b[i] * sig * (1.0 + cache.a[i] * (1.0 - sig));
        db[i] = dh_[i] * silu;
    }
    cx.ws.give(dh_);
    (da, db)
}

/// `dxln = da·wgᵀ + db·wuᵀ` (reference association: `dxln += du_x`).
fn mlp_dxln(
    cx: &mut KernelCtx,
    da: &[f32],
    db: &[f32],
    wg: &[f32],
    wu: &[f32],
    d: usize,
    fr: usize,
) -> Vec<f32> {
    let rows = da.len() / fr;
    let mut dxln = cx.ws.take(rows * d);
    gemm::matmul_bt(cx, da, wg, rows, fr, d, &mut dxln);
    let mut du_x = cx.ws.take(rows * d);
    gemm::matmul_bt(cx, db, wu, rows, fr, d, &mut du_x);
    for (a, b) in dxln.iter_mut().zip(&du_x) {
        *a += b;
    }
    cx.ws.give(du_x);
    dxln
}

/// `mlp_fwd`: per-rank partial `(silu(x̂Wg)·(x̂Wu))Wd + x/t`.
pub(crate) fn mlp_fwd(
    args: &[&Tensor],
    dims: &ManifestDims,
    cx: &mut KernelCtx,
) -> Result<Vec<Tensor>> {
    let [x, g2, wg, wu, wd] = expect_args::<5>("mlp_fwd", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let rows = x.len() / d;
    let xs = x.as_f32()?;
    let cache = mlp_core(cx, xs, g2.as_f32()?, wg.as_f32()?, wu.as_f32()?, d, fr);
    let mut out = cx.ws.take(rows * d);
    gemm::matmul(cx, &cache.h, wd.as_f32()?, rows, fr, d, &mut out);
    cache.release(&mut cx.ws);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, xi) in out.iter_mut().zip(xs) {
        *o += xi * inv_t;
    }
    Ok(vec![Tensor::f32(out, x.shape())])
}

/// `mlp_bwd_x`: activation-gradient partial `vjp(dy) + dy/t`.
pub(crate) fn mlp_bwd_x(
    args: &[&Tensor],
    dims: &ManifestDims,
    cx: &mut KernelCtx,
) -> Result<Vec<Tensor>> {
    let [x, dy, g2, wg, wu, wd] = expect_args::<6>("mlp_bwd_x", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let (xs, g2s, dys) = (x.as_f32()?, g2.as_f32()?, dy.as_f32()?);
    let (wgs, wus) = (wg.as_f32()?, wu.as_f32()?);
    let cache = mlp_core(cx, xs, g2s, wgs, wus, d, fr);
    let (da, db) = mlp_da_db(cx, &cache, wd.as_f32()?, dys, d, fr);
    cache.release(&mut cx.ws);
    let dxln = mlp_dxln(cx, &da, &db, wgs, wus, d, fr);
    cx.ws.give(da);
    cx.ws.give(db);
    let mut dx = cx.ws.take(xs.len());
    let mut dg_scratch = cx.ws.take(d);
    rmsnorm_bwd_into(xs, g2s, &dxln, d, &mut dx, &mut dg_scratch);
    cx.ws.give(dg_scratch);
    cx.ws.give(dxln);
    let inv_t = 1.0 / dims.tp as f32;
    for (o, dyi) in dx.iter_mut().zip(dys) {
        *o += dyi * inv_t;
    }
    Ok(vec![Tensor::f32(dx, x.shape())])
}

/// `mlp_bwd_w`: `(dγ2, dwg, dwu, dwd)`.
pub(crate) fn mlp_bwd_w(
    args: &[&Tensor],
    dims: &ManifestDims,
    cx: &mut KernelCtx,
) -> Result<Vec<Tensor>> {
    let [x, dy, g2, wg, wu, wd] = expect_args::<6>("mlp_bwd_w", args)?;
    let d = x.shape()[2];
    let fr = dims.ffn_per_rank();
    let rows = x.len() / d;
    let (xs, g2s, dys) = (x.as_f32()?, g2.as_f32()?, dy.as_f32()?);
    let (wgs, wus) = (wg.as_f32()?, wu.as_f32()?);
    let cache = mlp_core(cx, xs, g2s, wgs, wus, d, fr);
    let (da, db) = mlp_da_db(cx, &cache, wd.as_f32()?, dys, d, fr);

    let mut dwd = cx.ws.take(fr * d);
    gemm::matmul_at(cx, &cache.h, dys, rows, fr, d, &mut dwd);
    let mut dwg = cx.ws.take(d * fr);
    gemm::matmul_at(cx, &cache.xln, &da, rows, d, fr, &mut dwg);
    let mut dwu = cx.ws.take(d * fr);
    gemm::matmul_at(cx, &cache.xln, &db, rows, d, fr, &mut dwu);

    let dxln = mlp_dxln(cx, &da, &db, wgs, wus, d, fr);
    cx.ws.give(da);
    cx.ws.give(db);
    cache.release(&mut cx.ws);
    let mut dg2 = cx.ws.take(d);
    let mut dx_scratch = cx.ws.take(rows * d);
    rmsnorm_bwd_into(xs, g2s, &dxln, d, &mut dx_scratch, &mut dg2);
    cx.ws.give(dx_scratch);
    cx.ws.give(dxln);
    Ok(vec![
        Tensor::f32(dg2, g2.shape()),
        Tensor::f32(dwg, wg.shape()),
        Tensor::f32(dwu, wu.shape()),
        Tensor::f32(dwd, wd.shape()),
    ])
}

// ---------------------------------------------------------------------------
// Pipeline endpoints. Arena-backed (the reference keeps its own plain-Vec
// versions): their outputs flow back through `Backend::recycle` like every
// other unit's, keeping the steady-state pool balanced.
// ---------------------------------------------------------------------------

/// `embed_fwd`: token lookup, `tokens [mb,s] i32 × emb [V,d] → [mb,s,d]`.
pub(crate) fn embed_fwd(args: &[&Tensor], cx: &mut KernelCtx) -> Result<Vec<Tensor>> {
    let [tok, emb] = expect_args::<2>("embed_fwd", args)?;
    let d = emb.shape()[1];
    let vocab = emb.shape()[0];
    let toks = match tok {
        Tensor::I32 { data, .. } => data,
        _ => anyhow::bail!("embed_fwd: tokens must be i32"),
    };
    let es = emb.as_f32()?;
    // Every row is copied below — no need for the zeroing take.
    let mut out = cx.ws.take_uninit(toks.len() * d);
    for (r, &t) in toks.iter().enumerate() {
        let t = t as usize;
        anyhow::ensure!(t < vocab, "embed_fwd: token {t} out of vocab {vocab}");
        out[r * d..(r + 1) * d].copy_from_slice(&es[t * d..(t + 1) * d]);
    }
    let shape = [tok.shape()[0], tok.shape()[1], d];
    Ok(vec![Tensor::f32(out, &shape)])
}

/// `embed_bwd`: scatter-add of `dy` rows into token slots → `[V,d]`.
pub(crate) fn embed_bwd(
    args: &[&Tensor],
    dims: &ManifestDims,
    cx: &mut KernelCtx,
) -> Result<Vec<Tensor>> {
    let [tok, dy] = expect_args::<2>("embed_bwd", args)?;
    let d = dy.shape()[2];
    let toks = match tok {
        Tensor::I32 { data, .. } => data,
        _ => anyhow::bail!("embed_bwd: tokens must be i32"),
    };
    let dys = dy.as_f32()?;
    let mut out = cx.ws.take(dims.vocab * d);
    for (r, &t) in toks.iter().enumerate() {
        let t = t as usize;
        anyhow::ensure!(t < dims.vocab, "embed_bwd: token {t} out of vocab {}", dims.vocab);
        for e in 0..d {
            out[t * d + e] += dys[r * d + e];
        }
    }
    Ok(vec![Tensor::f32(out, &[dims.vocab, d])])
}

/// `head_loss_grad`: fused LM head + mean token cross-entropy; returns
/// `(loss, dx, dw_head)`.
pub(crate) fn head_loss_grad(args: &[&Tensor], cx: &mut KernelCtx) -> Result<Vec<Tensor>> {
    let [x, wh, tgt] = expect_args::<3>("head_loss_grad", args)?;
    let d = x.shape()[2];
    let v = wh.shape()[1];
    let rows = x.len() / d;
    let xs = x.as_f32()?;
    let whs = wh.as_f32()?;
    let tgts = match tgt {
        Tensor::I32 { data, .. } => data,
        _ => anyhow::bail!("head_loss_grad: targets must be i32"),
    };
    anyhow::ensure!(tgts.len() == rows, "head_loss_grad: {} targets for {rows} rows", tgts.len());

    let mut logits = cx.ws.take(rows * v);
    gemm::matmul(cx, xs, whs, rows, d, v, &mut logits);
    let mut dlogits = cx.ws.take(rows * v);
    let inv_n = 1.0 / rows as f32;
    let mut loss = 0.0f32;
    for r in 0..rows {
        let lr = &logits[r * v..(r + 1) * v];
        let t = tgts[r] as usize;
        anyhow::ensure!(t < v, "head_loss_grad: target {t} out of vocab {v}");
        let maxv = lr.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for &l in lr {
            z += (l - maxv).exp();
        }
        loss += -(lr[t] - maxv - z.ln());
        let dr = &mut dlogits[r * v..(r + 1) * v];
        for j in 0..v {
            let p = (lr[j] - maxv).exp() / z;
            let hot = if j == t { 1.0 } else { 0.0 };
            dr[j] = (p - hot) * inv_n;
        }
    }
    loss *= inv_n;

    let mut dx = cx.ws.take(rows * d);
    gemm::matmul_bt(cx, &dlogits, whs, rows, v, d, &mut dx);
    let mut dwh = cx.ws.take(d * v);
    gemm::matmul_at(cx, xs, &dlogits, rows, d, v, &mut dwh);
    cx.ws.give(logits);
    cx.ws.give(dlogits);
    let mut lbuf = cx.ws.take(1);
    lbuf[0] = loss;
    Ok(vec![
        Tensor::F32 { data: lbuf, shape: Vec::new() },
        Tensor::f32(dx, x.shape()),
        Tensor::f32(dwh, wh.shape()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Rng;

    /// Tiny single-rank dims for the finite-difference checks.
    fn dims(tp: usize) -> ManifestDims {
        ManifestDims {
            vocab: 11,
            d: 8,
            q_heads: 2 * tp,
            kv_heads: tp,
            ffn: 6 * tp,
            layers: 2,
            seq: 3,
            mb: 2,
            tp,
            pp: 1,
            vpp: 1,
        }
    }

    fn randn(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        Rng::for_purpose(99, seed, 7, 0).normal_vec(n, scale)
    }

    fn t3(data: Vec<f32>, mb: usize, s: usize, d: usize) -> Tensor {
        Tensor::f32(data, &[mb, s, d])
    }

    /// Weighted-sum loss of a unit's output — a scalar function of any
    /// input tensor whose analytic gradient the unit's `bwd` must match.
    fn weighted(out: &Tensor, w: &[f32]) -> f32 {
        out.as_f32().unwrap().iter().zip(w).map(|(a, b)| a * b).sum()
    }

    /// Central finite differences of `f` at `x` against `analytic` on a
    /// coordinate subset. f32 noise bounds the achievable agreement; the
    /// tolerances are loose but reject any wrong formula (errors there
    /// are O(grad), two orders of magnitude larger).
    fn fd_check(mut f: impl FnMut(&[f32]) -> f32, x: &[f32], analytic: &[f32], label: &str) {
        assert_eq!(x.len(), analytic.len(), "{label}: length");
        let eps = 1e-2f32;
        let stride = (x.len() / 17).max(1);
        for i in (0..x.len()).step_by(stride) {
            let mut xp = x.to_vec();
            xp[i] += eps;
            let fp = f(&xp);
            xp[i] = x[i] - eps;
            let fm = f(&xp);
            let fd = (fp - fm) / (2.0 * eps);
            let a = analytic[i];
            assert!(
                (a - fd).abs() <= 3e-2 + 0.08 * a.abs().max(fd.abs()),
                "{label}[{i}]: analytic {a} vs fd {fd}"
            );
        }
    }

    struct AttnSetup {
        x: Tensor,
        g1: Tensor,
        wq: Tensor,
        wk: Tensor,
        wv: Tensor,
        wo: Tensor,
        dy: Vec<f32>,
    }

    fn attn_setup(dm: &ManifestDims) -> AttnSetup {
        let (mb, s, d) = (dm.mb, dm.seq, dm.d);
        let qr = dm.q_heads_per_rank() * dm.head_dim();
        let kr = dm.kv_heads_per_rank() * dm.head_dim();
        AttnSetup {
            x: t3(randn(1, mb * s * d, 0.5), mb, s, d),
            g1: Tensor::f32(randn(2, d, 0.3).iter().map(|v| 1.0 + v).collect(), &[d]),
            wq: Tensor::f32(randn(3, d * qr, 0.3), &[d, qr]),
            wk: Tensor::f32(randn(4, d * kr, 0.3), &[d, kr]),
            wv: Tensor::f32(randn(5, d * kr, 0.3), &[d, kr]),
            wo: Tensor::f32(randn(6, qr * d, 0.3), &[qr, d]),
            dy: randn(7, mb * s * d, 0.5),
        }
    }

    /// Both `bwd` paths — dense (simd=false) and flash (simd=true) —
    /// must agree with finite differences of their own forward.
    fn attn_fd_for(simd: bool) {
        let dm = dims(2); // exercises the /t residual terms
        let su = attn_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut cx = KernelCtx::serial(simd);
        let dx = attn_bwd_x(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, &mut cx)
            .unwrap()
            .remove(0);
        let f = |xs: &[f32]| {
            let mut w = KernelCtx::serial(simd);
            let xt = t3(xs.to_vec(), dm.mb, dm.seq, dm.d);
            let out =
                attn_fwd(&[&xt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, &mut w).unwrap();
            weighted(&out[0], &su.dy)
        };
        fd_check(f, su.x.as_f32().unwrap(), dx.as_f32().unwrap(), "attn dx");
    }

    #[test]
    fn attn_bwd_x_matches_finite_differences() {
        attn_fd_for(false);
    }

    #[test]
    fn flash_attn_bwd_x_matches_finite_differences() {
        attn_fd_for(true);
    }

    #[test]
    fn attn_bwd_w_matches_finite_differences() {
        let dm = dims(1);
        let su = attn_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut cx = KernelCtx::serial(false);
        let grads =
            attn_bwd_w(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, &mut cx)
                .unwrap();
        // Perturb each weight tensor in turn (index 0 = gamma1 … 4 = wo).
        for (wi, (name, base)) in [
            ("dgamma1", &su.g1),
            ("dwq", &su.wq),
            ("dwk", &su.wk),
            ("dwv", &su.wv),
            ("dwo", &su.wo),
        ]
        .into_iter()
        .enumerate()
        {
            let f = |wsl: &[f32]| {
                let mut w = KernelCtx::serial(false);
                let mut params =
                    [su.g1.clone(), su.wq.clone(), su.wk.clone(), su.wv.clone(), su.wo.clone()];
                params[wi] = Tensor::f32(wsl.to_vec(), base.shape());
                let [g1, wq, wk, wv, wo] = &params;
                let out = attn_fwd(&[&su.x, g1, wq, wk, wv, wo], &dm, &mut w).unwrap();
                weighted(&out[0], &su.dy)
            };
            fd_check(f, base.as_f32().unwrap(), grads[wi].as_f32().unwrap(), name);
        }
    }

    #[test]
    fn flash_attention_matches_dense_within_tolerance() {
        // The documented ≤1e-5 oracle for the one reassociated path:
        // forward outputs and activation gradients of the flash core vs
        // the dense core on the same inputs, including seq long enough
        // to span multiple FLASH_BLK key blocks.
        let mut dm = dims(2);
        dm.seq = 2 * FLASH_BLK + 5; // ragged multi-block rows
        let su = attn_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let args_f = [&su.x, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo];
        let args_b = [&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo];
        let mut dense_cx = KernelCtx::serial(false);
        let mut flash_cx = KernelCtx::serial(true);
        for (label, a, b) in [
            (
                "fwd",
                attn_fwd(&args_f, &dm, &mut dense_cx).unwrap().remove(0),
                attn_fwd(&args_f, &dm, &mut flash_cx).unwrap().remove(0),
            ),
            (
                "bwd_x",
                attn_bwd_x(&args_b, &dm, &mut dense_cx).unwrap().remove(0),
                attn_bwd_x(&args_b, &dm, &mut flash_cx).unwrap().remove(0),
            ),
        ] {
            for (i, (x, y)) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-5 + 1e-5 * x.abs().max(y.abs()),
                    "attn {label}[{i}]: dense {x} vs flash {y}"
                );
            }
        }
    }

    struct MlpSetup {
        x: Tensor,
        g2: Tensor,
        wg: Tensor,
        wu: Tensor,
        wd: Tensor,
        dy: Vec<f32>,
    }

    fn mlp_setup(dm: &ManifestDims) -> MlpSetup {
        let (mb, s, d) = (dm.mb, dm.seq, dm.d);
        let fr = dm.ffn_per_rank();
        MlpSetup {
            x: t3(randn(11, mb * s * d, 0.5), mb, s, d),
            g2: Tensor::f32(randn(12, d, 0.3).iter().map(|v| 1.0 + v).collect(), &[d]),
            wg: Tensor::f32(randn(13, d * fr, 0.3), &[d, fr]),
            wu: Tensor::f32(randn(14, d * fr, 0.3), &[d, fr]),
            wd: Tensor::f32(randn(15, fr * d, 0.3), &[fr, d]),
            dy: randn(16, mb * s * d, 0.5),
        }
    }

    #[test]
    fn mlp_bwd_x_matches_finite_differences() {
        let dm = dims(2);
        let su = mlp_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut cx = KernelCtx::serial(false);
        let dx = mlp_bwd_x(&[&su.x, &dyt, &su.g2, &su.wg, &su.wu, &su.wd], &dm, &mut cx)
            .unwrap()
            .remove(0);
        let f = |xs: &[f32]| {
            let mut w = KernelCtx::serial(false);
            let xt = t3(xs.to_vec(), dm.mb, dm.seq, dm.d);
            let out = mlp_fwd(&[&xt, &su.g2, &su.wg, &su.wu, &su.wd], &dm, &mut w).unwrap();
            weighted(&out[0], &su.dy)
        };
        fd_check(f, su.x.as_f32().unwrap(), dx.as_f32().unwrap(), "mlp dx");
    }

    #[test]
    fn mlp_bwd_w_matches_finite_differences() {
        let dm = dims(1);
        let su = mlp_setup(&dm);
        let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
        let mut cx = KernelCtx::serial(false);
        let grads =
            mlp_bwd_w(&[&su.x, &dyt, &su.g2, &su.wg, &su.wu, &su.wd], &dm, &mut cx).unwrap();
        for (wi, (name, base)) in
            [("dgamma2", &su.g2), ("dwg", &su.wg), ("dwu", &su.wu), ("dwd", &su.wd)]
                .into_iter()
                .enumerate()
        {
            let f = |wsl: &[f32]| {
                let mut w = KernelCtx::serial(false);
                let mut params = [su.g2.clone(), su.wg.clone(), su.wu.clone(), su.wd.clone()];
                params[wi] = Tensor::f32(wsl.to_vec(), base.shape());
                let [g2, wg, wu, wd] = &params;
                let out = mlp_fwd(&[&su.x, g2, wg, wu, wd], &dm, &mut w).unwrap();
                weighted(&out[0], &su.dy)
            };
            fd_check(f, base.as_f32().unwrap(), grads[wi].as_f32().unwrap(), name);
        }
    }

    #[test]
    fn head_loss_grad_matches_finite_differences() {
        let dm = dims(1);
        let (mb, s, d, v) = (dm.mb, dm.seq, dm.d, dm.vocab);
        let x = t3(randn(21, mb * s * d, 0.5), mb, s, d);
        let wh = Tensor::f32(randn(22, d * v, 0.3), &[d, v]);
        let tgt = Tensor::i32((0..(mb * s) as i32).map(|i| i % v as i32).collect(), &[mb, s]);
        let mut cx = KernelCtx::serial(false);
        let out = head_loss_grad(&[&x, &wh, &tgt], &mut cx).unwrap();
        let loss = out[0].scalar_f32().unwrap();
        assert!(loss.is_finite() && loss > 0.0);

        let fx = |xs: &[f32]| {
            let mut w = KernelCtx::serial(false);
            let xt = t3(xs.to_vec(), mb, s, d);
            head_loss_grad(&[&xt, &wh, &tgt], &mut w).unwrap()[0].scalar_f32().unwrap()
        };
        fd_check(fx, x.as_f32().unwrap(), out[1].as_f32().unwrap(), "head dx");
        let fw = |wsl: &[f32]| {
            let mut w = KernelCtx::serial(false);
            let wt = Tensor::f32(wsl.to_vec(), &[d, v]);
            head_loss_grad(&[&x, &wt, &tgt], &mut w).unwrap()[0].scalar_f32().unwrap()
        };
        fd_check(fw, wh.as_f32().unwrap(), out[2].as_f32().unwrap(), "head dwh");
    }

    #[test]
    fn embed_roundtrip_and_gradient() {
        let dm = dims(1);
        let tok = Tensor::i32(vec![1, 4, 1, 0, 2, 3], &[dm.mb, dm.seq]);
        let emb = Tensor::f32(randn(31, dm.vocab * dm.d, 0.5), &[dm.vocab, dm.d]);
        let mut cx = KernelCtx::serial(false);
        let x = embed_fwd(&[&tok, &emb], &mut cx).unwrap().remove(0);
        assert_eq!(x.shape(), &[dm.mb, dm.seq, dm.d]);
        // Row 0 of the output is embedding row of token 1.
        assert_eq!(&x.as_f32().unwrap()[..dm.d], &emb.as_f32().unwrap()[dm.d..2 * dm.d]);

        // Gradient: scatter-add — duplicated token 1 accumulates twice.
        let dy = t3(vec![1.0; dm.mb * dm.seq * dm.d], dm.mb, dm.seq, dm.d);
        let de = embed_bwd(&[&tok, &dy], &dm, &mut cx).unwrap().remove(0);
        assert_eq!(de.shape(), &[dm.vocab, dm.d]);
        let des = de.as_f32().unwrap();
        assert_eq!(des[dm.d], 2.0); // token 1 appears twice
        assert_eq!(des[0], 1.0); // token 0 once
        assert_eq!(des[5 * dm.d], 0.0); // token 5 never
    }

    #[test]
    fn tp_partials_sum_to_the_dense_layer() {
        // Paper Eq. 1 invariant (the python tests' "sum over ranks ==
        // dense"): AR(attn_fwd over 2 ranks' shards) == attn_fwd of the
        // dense layer at tp=1 — same x, sharded weights.
        let dm2 = dims(2);
        let mut dm1 = dims(1);
        // Same underlying dense model: tp=1 dims carry all heads.
        dm1.q_heads = dm2.q_heads;
        dm1.kv_heads = dm2.kv_heads;
        dm1.ffn = dm2.ffn;
        let (mb, s, d) = (dm2.mb, dm2.seq, dm2.d);
        let dh = dm2.head_dim();
        let (qd, kd) = (dm2.q_heads * dh, dm2.kv_heads * dh);
        let x = t3(randn(41, mb * s * d, 0.5), mb, s, d);
        let g1 = Tensor::f32(vec![1.0; d], &[d]);
        let wq = randn(42, d * qd, 0.3);
        let wk = randn(43, d * kd, 0.3);
        let wv = randn(44, d * kd, 0.3);
        let wo = randn(45, qd * d, 0.3);

        let mut cx = KernelCtx::serial(false);
        let wqt = Tensor::f32(wq.clone(), &[d, qd]);
        let wkt = Tensor::f32(wk.clone(), &[d, kd]);
        let wvt = Tensor::f32(wv.clone(), &[d, kd]);
        let wot = Tensor::f32(wo.clone(), &[qd, d]);
        let dense =
            attn_fwd(&[&x, &g1, &wqt, &wkt, &wvt, &wot], &dm1, &mut cx).unwrap().remove(0);

        let col = |w: &[f32], cols: usize, c0: usize, c1: usize| -> Vec<f32> {
            let rows = w.len() / cols;
            let mut out = Vec::new();
            for r in 0..rows {
                out.extend_from_slice(&w[r * cols + c0..r * cols + c1]);
            }
            out
        };
        let mut summed = vec![0.0f32; mb * s * d];
        for r in 0..2 {
            let (qr, kr) = (qd / 2, kd / 2);
            let wqs = Tensor::f32(col(&wq, qd, r * qr, (r + 1) * qr), &[d, qr]);
            let wks = Tensor::f32(col(&wk, kd, r * kr, (r + 1) * kr), &[d, kr]);
            let wvs = Tensor::f32(col(&wv, kd, r * kr, (r + 1) * kr), &[d, kr]);
            let wos = Tensor::f32(wo[r * qr * d..(r + 1) * qr * d].to_vec(), &[qr, d]);
            let part =
                attn_fwd(&[&x, &g1, &wqs, &wks, &wvs, &wos], &dm2, &mut cx).unwrap().remove(0);
            for (a, b) in summed.iter_mut().zip(part.as_f32().unwrap()) {
                *a += b;
            }
        }
        for (i, (a, b)) in summed.iter().zip(dense.as_f32().unwrap()).enumerate() {
            assert!((a - b).abs() < 1e-4, "elem {i}: sharded {a} vs dense {b}");
        }
    }

    #[test]
    fn units_return_all_workspace_scratch() {
        // Take/give pairing: running every arena-backed unit a second
        // time on the same workspace allocates nothing. Unit outputs are
        // arena-backed now, so the test plays the engine's role and
        // recycles them — a leaked scratch buffer (or an output the
        // engine couldn't return) would surface here and as a nonzero
        // steady-state count in `tests/train_virtual.rs`.
        for simd in [false, true] {
            let dm = dims(2);
            let su = attn_setup(&dm);
            let mu = mlp_setup(&dm);
            let dyt = t3(su.dy.clone(), dm.mb, dm.seq, dm.d);
            let wh = Tensor::f32(randn(51, dm.d * dm.vocab, 0.3), &[dm.d, dm.vocab]);
            let tgt = Tensor::i32(vec![1; dm.mb * dm.seq], &[dm.mb, dm.seq]);
            let tok = Tensor::i32(vec![1; dm.mb * dm.seq], &[dm.mb, dm.seq]);
            let emb = Tensor::f32(randn(52, dm.vocab * dm.d, 0.3), &[dm.vocab, dm.d]);
            let mut cx = KernelCtx::serial(simd);
            let mut run_all = |cx: &mut KernelCtx| {
                let mut outs = Vec::new();
                outs.extend(
                    attn_fwd(&[&su.x, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, cx).unwrap(),
                );
                outs.extend(
                    attn_bwd_x(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, cx)
                        .unwrap(),
                );
                outs.extend(
                    attn_bwd_w(&[&su.x, &dyt, &su.g1, &su.wq, &su.wk, &su.wv, &su.wo], &dm, cx)
                        .unwrap(),
                );
                outs.extend(mlp_fwd(&[&mu.x, &mu.g2, &mu.wg, &mu.wu, &mu.wd], &dm, cx).unwrap());
                outs.extend(
                    mlp_bwd_x(&[&mu.x, &dyt, &mu.g2, &mu.wg, &mu.wu, &mu.wd], &dm, cx).unwrap(),
                );
                outs.extend(
                    mlp_bwd_w(&[&mu.x, &dyt, &mu.g2, &mu.wg, &mu.wu, &mu.wd], &dm, cx).unwrap(),
                );
                outs.extend(head_loss_grad(&[&su.x, &wh, &tgt], cx).unwrap());
                outs.extend(embed_fwd(&[&tok, &emb], cx).unwrap());
                outs.extend(embed_bwd(&[&tok, &dyt], &dm, cx).unwrap());
                // Play the engine: recycle every output back to the pool.
                for t in outs {
                    if let Tensor::F32 { data, .. } = t {
                        cx.ws.give(data);
                    }
                }
            };
            run_all(&mut cx);
            let warm = cx.stats().fresh_allocs;
            assert!(warm > 0, "arena-backed units must use the workspace");
            run_all(&mut cx);
            assert_eq!(
                cx.stats().fresh_allocs,
                warm,
                "second run must recycle every buffer (simd={simd})"
            );
        }
    }
}
