//! Per-rank model parameters, gradients and the SGD update.
//!
//! Weights are initialized by materializing the *full* matrix from a
//! deterministic RNG keyed by (seed, chunk, layer, matrix) and slicing the
//! rank's Megatron shard — so every TP configuration of the same seed
//! trains exactly the same underlying model (the invariant the python
//! tests state as "sum over ranks == dense").

use crate::config::ManifestDims;
use crate::runtime::Tensor;

use super::rng::Rng;

/// Matrix ids for seeding (stable across layouts).
const M_WQ: u64 = 1;
const M_WK: u64 = 2;
const M_WV: u64 = 3;
const M_WO: u64 = 4;
const M_WG: u64 = 5;
const M_WU: u64 = 6;
const M_WD: u64 = 7;
const M_EMB: u64 = 8;
const M_HEAD: u64 = 9;

/// One transformer layer's per-rank parameters (order matches the AOT
/// artifact signatures).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerParams {
    pub gamma1: Tensor,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub gamma2: Tensor,
    pub wg: Tensor,
    pub wu: Tensor,
    pub wd: Tensor,
}

/// Gradient accumulator mirroring [`LayerParams`].
#[derive(Debug, Clone, PartialEq)]
pub struct LayerGrads {
    pub gamma1: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub gamma2: Vec<f32>,
    pub wg: Vec<f32>,
    pub wu: Vec<f32>,
    pub wd: Vec<f32>,
}

impl LayerGrads {
    /// Fresh zero accumulators matching `p`'s shapes (checkpoint restore
    /// rebuilds grads this way: snapshots are taken at step boundaries,
    /// where `sgd_step` has provably zeroed them).
    pub fn zeros_like(p: &LayerParams) -> LayerGrads {
        LayerGrads {
            gamma1: vec![0.0; p.gamma1.len()],
            wq: vec![0.0; p.wq.len()],
            wk: vec![0.0; p.wk.len()],
            wv: vec![0.0; p.wv.len()],
            wo: vec![0.0; p.wo.len()],
            gamma2: vec![0.0; p.gamma2.len()],
            wg: vec![0.0; p.wg.len()],
            wu: vec![0.0; p.wu.len()],
            wd: vec![0.0; p.wd.len()],
        }
    }
}

/// All parameters a device thread owns for one chunk.
pub struct ChunkParams {
    /// Transformer layers in walk order: the chunk's ViT layers (MLLM
    /// plans; `n_vit` of them) followed by its LM layers. The executor
    /// treats both identically — vision towers are proxied as extra
    /// transformer depth on the hidden stream (DESIGN.md §14).
    pub layers: Vec<LayerParams>,
    /// How many leading entries of `layers` are ViT layers (checkpoint
    /// snapshots split the vector here).
    pub n_vit: usize,
    pub grads: Vec<LayerGrads>,
    /// Embedding table (chunk 0 only); replicated across TP ranks.
    pub emb: Option<Tensor>,
    pub emb_grad: Option<Vec<f32>>,
    /// LM head (last chunk only); replicated.
    pub head: Option<Tensor>,
    pub head_grad: Option<Vec<f32>>,
}

/// Generate the full matrix then slice columns `[c0, c1)`.
fn col_slice(rng: &mut Rng, rows: usize, cols: usize, c0: usize, c1: usize, scale: f32) -> Vec<f32> {
    let full = rng.normal_vec(rows * cols, scale);
    let mut out = Vec::with_capacity(rows * (c1 - c0));
    for r in 0..rows {
        out.extend_from_slice(&full[r * cols + c0..r * cols + c1]);
    }
    out
}

/// Generate the full matrix then slice rows `[r0, r1)`.
fn row_slice(rng: &mut Rng, rows: usize, cols: usize, r0: usize, r1: usize, scale: f32) -> Vec<f32> {
    let full = rng.normal_vec(rows * cols, scale);
    full[r0 * cols..r1 * cols].to_vec()
}

impl ChunkParams {
    /// Initialize the rank's shard of `chunk`: `n_vit` ViT layers then
    /// `n_layers` LM transformer layers (the chunk's share under the
    /// run's stage plan — uniform or weighted) plus the embed/head
    /// endpoints this chunk owns. ViT layers draw from a disjoint seed
    /// key space so adding vision depth never perturbs the LM weights.
    pub fn init(
        dims: &ManifestDims,
        chunk: usize,
        tp_rank: usize,
        n_vit: usize,
        n_layers: usize,
        has_embed: bool,
        has_head: bool,
        seed: u64,
    ) -> ChunkParams {
        let d = dims.d;
        let dh = dims.head_dim();
        let qr = dims.q_heads_per_rank() * dh;
        let kr = dims.kv_heads_per_rank() * dh;
        let fr = dims.ffn_per_rank();
        let kv = dims.kv_heads * dh;
        let q0 = tp_rank * qr;
        let k0 = tp_rank * kr;
        let f0 = tp_rank * fr;
        let s_d = 1.0 / (d as f32).sqrt();
        // GPT-2-style residual-output scaling: each layer adds two branch
        // outputs into the residual stream, so scale wo/wd by 1/sqrt(2L)
        // to keep the stream near unit variance at any depth (the lowered
        // model has no final norm before the head).
        let s_res = s_d / (2.0 * dims.layers as f32).sqrt();

        let mut layers = Vec::new();
        // ViT layers key from a disjoint id range (500_000 + ...) so a
        // chunk's LM weights are identical with or without a vision
        // prefix of any depth.
        let layer_keys = (0..n_vit)
            .map(|l| (500_000 + chunk * 1000 + l) as u64)
            .chain((0..n_layers).map(|l| (chunk * 1000 + l) as u64));
        for key in layer_keys {
            let r = |m: u64| Rng::for_purpose(seed, key, m, 0);
            layers.push(LayerParams {
                gamma1: Tensor::f32(vec![1.0; d], &[d]),
                wq: Tensor::f32(col_slice(&mut r(M_WQ), d, d, q0, q0 + qr, s_d), &[d, qr]),
                wk: Tensor::f32(col_slice(&mut r(M_WK), d, kv, k0, k0 + kr, s_d), &[d, kr]),
                wv: Tensor::f32(col_slice(&mut r(M_WV), d, kv, k0, k0 + kr, s_d), &[d, kr]),
                wo: Tensor::f32(row_slice(&mut r(M_WO), d, d, q0, q0 + qr, s_res), &[qr, d]),
                gamma2: Tensor::f32(vec![1.0; d], &[d]),
                wg: Tensor::f32(col_slice(&mut r(M_WG), d, dims.ffn, f0, f0 + fr, s_d), &[d, fr]),
                wu: Tensor::f32(col_slice(&mut r(M_WU), d, dims.ffn, f0, f0 + fr, s_d), &[d, fr]),
                wd: Tensor::f32(
                    row_slice(
                        &mut r(M_WD),
                        dims.ffn,
                        d,
                        f0,
                        f0 + fr,
                        1.0 / (dims.ffn as f32).sqrt() / (2.0 * dims.layers as f32).sqrt(),
                    ),
                    &[fr, d],
                ),
            });
        }
        let grads = layers.iter().map(LayerGrads::zeros_like).collect();

        let emb = has_embed.then(|| {
            let mut r = Rng::for_purpose(seed, 0, M_EMB, 0);
            Tensor::f32(r.normal_vec(dims.vocab * d, 0.02), &[dims.vocab, d])
        });
        let head = has_head.then(|| {
            let mut r = Rng::for_purpose(seed, 0, M_HEAD, 0);
            Tensor::f32(r.normal_vec(d * dims.vocab, 0.02), &[d, dims.vocab])
        });
        let emb_grad = emb.as_ref().map(|t| vec![0.0; t.len()]);
        let head_grad = head.as_ref().map(|t| vec![0.0; t.len()]);

        ChunkParams { layers, n_vit, grads, emb, emb_grad, head, head_grad }
    }

    /// Accumulate `g` into the accumulator slice.
    pub fn accumulate(acc: &mut [f32], g: &Tensor) {
        let g = g.as_f32().expect("gradient must be f32");
        debug_assert_eq!(acc.len(), g.len());
        for (a, v) in acc.iter_mut().zip(g) {
            *a += v;
        }
    }

    /// SGD with per-tensor RMS gradient clipping (update RMS ≤ 0.002 —
    /// deep-residual f32 SGD needs it for stability): `p -= lr/n_mb · g`,
    /// then zero the accumulators. Gamma grads must already be All-Reduced
    /// by the caller.
    pub fn sgd_step(&mut self, lr: f32, n_mb: usize) {
        const CLIP_RMS: f32 = 0.002;
        let scale = lr / n_mb as f32;
        let apply = |p: &mut Tensor, g: &mut Vec<f32>| {
            let rms =
                (g.iter().map(|x| (x * scale) * (x * scale)).sum::<f32>() / g.len() as f32).sqrt();
            let clip = if rms > CLIP_RMS { CLIP_RMS / rms } else { 1.0 };
            let pd = p.as_f32_mut().expect("param f32");
            for (w, gv) in pd.iter_mut().zip(g.iter()) {
                *w -= scale * clip * gv;
            }
            g.iter_mut().for_each(|x| *x = 0.0);
        };
        for (p, g) in self.layers.iter_mut().zip(self.grads.iter_mut()) {
            apply(&mut p.gamma1, &mut g.gamma1);
            apply(&mut p.wq, &mut g.wq);
            apply(&mut p.wk, &mut g.wk);
            apply(&mut p.wv, &mut g.wv);
            apply(&mut p.wo, &mut g.wo);
            apply(&mut p.gamma2, &mut g.gamma2);
            apply(&mut p.wg, &mut g.wg);
            apply(&mut p.wu, &mut g.wu);
            apply(&mut p.wd, &mut g.wd);
        }
        if let (Some(e), Some(g)) = (self.emb.as_mut(), self.emb_grad.as_mut()) {
            apply(e, g);
        }
        if let (Some(h), Some(g)) = (self.head.as_mut(), self.head_grad.as_mut()) {
            apply(h, g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ManifestDims {
        ManifestDims {
            vocab: 64,
            d: 16,
            q_heads: 4,
            kv_heads: 2,
            ffn: 24,
            layers: 4,
            seq: 8,
            mb: 1,
            tp: 2,
            pp: 2,
            vpp: 2,
        }
    }

    #[test]
    fn shard_shapes() {
        let d = dims();
        let p = ChunkParams::init(&d, 0, 0, 0, 1, true, false, 7);
        assert_eq!(p.layers.len(), 1);
        assert_eq!(p.layers[0].wq.shape(), &[16, 8]); // qr = 2 heads * 4
        assert_eq!(p.layers[0].wk.shape(), &[16, 4]); // kr = 1 head * 4
        assert_eq!(p.layers[0].wo.shape(), &[8, 16]);
        assert_eq!(p.layers[0].wg.shape(), &[16, 12]);
        assert_eq!(p.layers[0].wd.shape(), &[12, 16]);
        assert!(p.emb.is_some());
        assert!(p.head.is_none());
    }

    #[test]
    fn ranks_slice_the_same_full_matrix() {
        let d = dims();
        let p0 = ChunkParams::init(&d, 1, 0, 0, 1, false, false, 7);
        let p1 = ChunkParams::init(&d, 1, 1, 0, 1, false, false, 7);
        // Different shards of the same full wq (no overlap expected, but
        // deterministically regenerated from the same stream).
        assert_ne!(
            p0.layers[0].wq.as_f32().unwrap(),
            p1.layers[0].wq.as_f32().unwrap()
        );
        // And the same (chunk, rank) shard reproduces bit-for-bit.
        let p0b = ChunkParams::init(&d, 1, 0, 0, 1, false, false, 7);
        assert_eq!(
            p0.layers[0].wq.as_f32().unwrap(),
            p0b.layers[0].wq.as_f32().unwrap()
        );
    }

    #[test]
    fn vit_prefix_never_perturbs_lm_weights() {
        let d = dims();
        let plain = ChunkParams::init(&d, 1, 0, 0, 1, false, false, 7);
        let mixed = ChunkParams::init(&d, 1, 0, 2, 1, false, false, 7);
        assert_eq!(mixed.n_vit, 2);
        assert_eq!(mixed.layers.len(), 3);
        // The LM layer after the ViT prefix is bit-identical to the
        // text-only init (disjoint seed key spaces).
        assert_eq!(mixed.layers[2], plain.layers[0]);
        // And the ViT layers differ from the LM layer they precede.
        assert_ne!(mixed.layers[0].wq, mixed.layers[2].wq);
    }

    #[test]
    fn sgd_moves_params_and_clears_grads() {
        let d = dims();
        let mut p = ChunkParams::init(&d, 0, 0, 0, 1, false, false, 7);
        let before = p.layers[0].wq.as_f32().unwrap()[0];
        // Small gradients (below the RMS clip): exact SGD step expected.
        p.grads[0].wq.iter_mut().for_each(|g| *g = 0.02);
        p.sgd_step(0.1, 2);
        let after = p.layers[0].wq.as_f32().unwrap()[0];
        assert!((before - after - 0.001).abs() < 1e-7, "delta {}", before - after);
        assert!(p.grads[0].wq.iter().all(|&g| g == 0.0));
    }

    #[test]
    fn sgd_clips_large_updates() {
        let d = dims();
        let mut p = ChunkParams::init(&d, 0, 0, 0, 1, false, false, 7);
        let before = p.layers[0].wq.as_f32().unwrap()[0];
        p.grads[0].wq.iter_mut().for_each(|g| *g = 100.0);
        p.sgd_step(0.1, 1);
        let after = p.layers[0].wq.as_f32().unwrap()[0];
        // Uniform grads ⇒ every element's update capped at exactly CLIP_RMS.
        assert!((before - after - 0.002).abs() < 1e-6, "delta {}", before - after);
    }
}
