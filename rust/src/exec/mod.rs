//! The real pipeline executor and its support types.
//!
//! The executor itself (`train` / `TrainConfig` in `engine`) drives
//! AOT HLO artifacts through PJRT and therefore requires the `pjrt`
//! feature. The PJRT-free support types — the deterministic [`Rng`], the
//! synthetic [`Corpus`], and the host-side parameter store
//! ([`ChunkParams`]) — are always available; tests and the property-test
//! harness use them without any accelerator runtime.

mod data;
mod params;
mod rng;

#[cfg(feature = "pjrt")]
mod engine;

pub use data::Corpus;
pub use params::{ChunkParams, LayerGrads, LayerParams};
pub use rng::Rng;

#[cfg(feature = "pjrt")]
pub use engine::{train, RunReport, StepStat, TrainConfig};
