//! The real pipeline executor and its support types.
//!
//! Since the backend-abstraction refactor (DESIGN.md §10) the op-walking
//! engine (`train` / `TrainConfig` in [`engine`]) is **always compiled**:
//! it drives a pluggable [`Backend`] — the deterministic
//! [`VirtualBackend`] (reference-kernel math on host tensors, no PJRT)
//! in every build, or the PJRT runtime over AOT HLO artifacts behind the
//! `pjrt` feature. The braided thread choreography (per-(stage, tp-rank)
//! threads, aligned collectives, bounded P2P channels, activation
//! store/offload) is therefore testable offline, and
//! `stp plan --emit-plan` → `stp train --plan` hands planner-chosen
//! schedules straight to it.

mod backend;
mod data;
mod engine;
mod kernels;
mod params;
mod rng;

pub use backend::{virtual_dims, Backend, BackendKind, VirtualBackend};
pub use data::Corpus;
pub use engine::{train, RunReport, StepStat, TrainConfig};
pub use params::{ChunkParams, LayerGrads, LayerParams};
pub use rng::Rng;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
