//! The real pipeline executor and its support types.
//!
//! Since the backend-abstraction refactor (DESIGN.md §10) the op-walking
//! engine (`train` / `TrainConfig` in [`engine`]) is **always compiled**:
//! it drives a pluggable [`Backend`] — the deterministic
//! [`VirtualBackend`] (host kernels, no PJRT) in every build, or the PJRT
//! runtime over AOT HLO artifacts behind the `pjrt` feature. The braided
//! thread choreography (per-(stage, tp-rank) threads, aligned
//! collectives, bounded P2P channels, activation store/offload) is
//! therefore testable offline, and `stp plan --emit-plan` →
//! `stp train --plan` hands planner-chosen schedules straight to it.
//!
//! The execution hot path is zero-copy and allocation-free at steady
//! state (DESIGN.md §11): [`Backend::run`] borrows its inputs, kernel
//! scratch lives in a per-thread [`Workspace`] arena, and the GEMMs are
//! cache-blocked microkernels ([`kernels::gemm`]) that stay bit-equal to
//! the preserved naive oracle ([`kernels::reference`]).
//!
//! On top of that sits the vectorized tier (DESIGN.md §13): `--kernels
//! simd` selects register-tiled SIMD GEMM microkernels with an optional
//! bounded worker pool (deterministic at any width — fixed band→worker
//! assignment, per-worker arenas) and a flash-style tiled attention core
//! whose scratch is O(seq·block) instead of O(seq²). SIMD GEMMs stay
//! bit-equal to the oracle (one accumulator per output element, depth
//! order preserved); only flash attention reassociates, under a
//! documented ≤1e-5 tolerance. Unit outputs are arena-backed too: the
//! engine hands dead tensors back through [`Backend::recycle`], keeping
//! steady-state allocations at zero end to end.

mod backend;
mod data;
mod engine;
pub mod kernels;
mod params;
mod rng;
mod workspace;

pub use backend::{
    host_virtual_scale, virtual_dims, virtual_dims_scaled, Backend, BackendKind, KernelPath,
    VirtualBackend,
};
pub use data::{global_mb_index, Corpus};
pub use engine::{train, RunReport, StepStat, TrainConfig};
pub use params::{ChunkParams, LayerGrads, LayerParams};
pub use rng::Rng;
pub use workspace::{Workspace, WorkspaceStats};

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
