//! Synthetic training data: a learnable bigram stream.
//!
//! Every thread regenerates batches deterministically from (seed, step,
//! microbatch) — no data distribution plumbing needed. The sequence
//! follows a fixed random permutation bigram table with ε-noise, so a
//! competent model drives the loss from ln(V) toward the bigram entropy —
//! exactly the visible-loss-curve signal the e2e example must produce.

use super::rng::Rng;

/// Global microbatch id for replica `replica`'s local microbatch `mb`
/// when every replica runs `n_mb` microbatches per step.
///
/// Data-parallel replicas partition the fixed global batch
/// `dp · n_mb · mb_size` contiguously: replica q consumes global ids
/// `q·n_mb .. (q+1)·n_mb`. Because the corpus keys batches by the
/// global id (not by replica), shrinking `dp` and rescaling `n_mb`
/// under the same product re-covers exactly the same sample set — the
/// invariant the elastic shrink-dp recovery relies on (DESIGN.md §14).
/// At `dp = 1` this is the identity, preserving pre-DP batch streams.
pub fn global_mb_index(replica: usize, n_mb: usize, mb: usize) -> usize {
    replica * n_mb + mb
}

/// Deterministic bigram corpus generator.
pub struct Corpus {
    vocab: usize,
    next_tok: Vec<i32>,
    seed: u64,
    noise: f64,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        // The corpus uses an *active* subset of the vocabulary (≤512
        // symbols) so each bigram is visited often enough for the loss to
        // move visibly within tens of steps at ~256 tokens/step — the
        // model still predicts over the full vocab, so the curve starts
        // at ln(V) and first learns the active-set support.
        let active = vocab.min(512);
        let mut perm: Vec<i32> = (0..active as i32).collect();
        let mut rng = Rng::for_purpose(seed, 77, 0, 0);
        for i in (1..active).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        Corpus { vocab: active, next_tok: perm, seed, noise: 0.1 }
    }

    /// (tokens, targets) for one microbatch: shapes [mb, seq] flattened.
    pub fn batch(&self, step: usize, mb_index: usize, mb: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::for_purpose(self.seed, step as u64, mb_index as u64, 13);
        let mut tokens = Vec::with_capacity(mb * seq);
        let mut targets = Vec::with_capacity(mb * seq);
        for _ in 0..mb {
            let mut t = rng.below(self.vocab) as i32;
            for _ in 0..seq {
                tokens.push(t);
                let next = if rng.uniform() < self.noise {
                    rng.below(self.vocab) as i32
                } else {
                    self.next_tok[t as usize]
                };
                targets.push(next);
                t = next;
            }
        }
        (tokens, targets)
    }

    /// Entropy floor of the stream in nats (best achievable loss):
    /// `H = -(1-ε+ε/V)·ln(1-ε+ε/V) - (V-1)·(ε/V)·ln(ε/V)`.
    pub fn entropy_floor(&self) -> f64 {
        let e = self.noise;
        let v = self.vocab as f64;
        let p_rule = 1.0 - e + e / v;
        let p_other = e / v;
        -(p_rule * p_rule.ln()) - (v - 1.0) * p_other * p_other.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let c = Corpus::new(64, 5);
        let (a, at) = c.batch(3, 1, 2, 16);
        let (b, bt) = c.batch(3, 1, 2, 16);
        assert_eq!(a, b);
        assert_eq!(at, bt);
        let (d, _) = c.batch(3, 2, 2, 16);
        assert_ne!(a, d);
    }

    #[test]
    fn targets_mostly_follow_bigram_rule() {
        let c = Corpus::new(64, 5);
        let (tok, tgt) = c.batch(0, 0, 4, 64);
        let follows = tok
            .iter()
            .zip(&tgt)
            .filter(|(t, g)| c.next_tok[**t as usize] == **g)
            .count();
        let frac = follows as f64 / tok.len() as f64;
        assert!(frac > 0.8, "only {frac:.2} follow the bigram rule");
    }

    #[test]
    fn global_ids_cover_the_batch_once_at_any_dp_split() {
        // dp=2 × n_mb=4 and dp=1 × n_mb=8 enumerate the same global ids.
        let mut wide: Vec<usize> = (0..2)
            .flat_map(|q| (0..4).map(move |j| global_mb_index(q, 4, j)))
            .collect();
        wide.sort_unstable();
        let narrow: Vec<usize> = (0..8).map(|j| global_mb_index(0, 8, j)).collect();
        assert_eq!(wide, narrow);
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::new(32, 9);
        let (tok, tgt) = c.batch(1, 0, 2, 32);
        assert!(tok.iter().chain(&tgt).all(|&t| (0..32).contains(&t)));
    }
}
