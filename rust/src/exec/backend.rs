//! Pluggable execution backends for the pipeline engine.
//!
//! The engine (`exec::engine`) walks compiled schedules and manages the
//! braided thread choreography — per-(stage, tp-rank) threads, aligned
//! TP collectives, bounded P2P channels, activation store/offload —
//! while everything *numerical* goes through one seam: [`Backend::run`],
//! keyed by the nine AOT unit names (`python/compile/aot.py`).
//!
//! * [`VirtualBackend`] — always compiled: deterministic host tensors
//!   through the reference-kernel math in [`super::kernels`]. This is
//!   what makes the executor (and the planner→executor handoff)
//!   testable in the default offline build.
//! * `PjrtBackend` (feature `pjrt`) — a thin adapter over
//!   [`crate::runtime::Runtime`]: AOT HLO artifacts executed through
//!   PJRT, exactly the pre-refactor path.

use std::str::FromStr;

use crate::config::ManifestDims;
use crate::runtime::Tensor;
use crate::Result;

use super::kernels;

/// Which execution backend a training run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Host reference kernels, no PJRT — available in every build.
    Virtual,
    /// AOT HLO artifacts through PJRT (needs the `pjrt` feature and a
    /// compiled artifact directory).
    Pjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Virtual => "virtual",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "virtual" | "cpu" | "host" => Ok(BackendKind::Virtual),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend '{other}' (expected virtual|pjrt)")),
        }
    }
}

/// One device thread's compute provider: executes a named unit over host
/// tensors. Implementations are constructed per OS thread (the PJRT
/// wrapper types are `!Send`), so the trait needs no `Send` bound.
pub trait Backend {
    /// Execute unit `name` (an AOT artifact name) on `args`.
    fn run(&mut self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>>;
    /// Cumulative unit executions (metrics).
    fn executions(&self) -> u64;
    /// Stable backend label for reports.
    fn kind(&self) -> BackendKind;
}

/// The deterministic no-PJRT backend: reference-kernel math on host
/// tensors, shaped by the run's [`ManifestDims`].
pub struct VirtualBackend {
    dims: ManifestDims,
    executions: u64,
}

impl VirtualBackend {
    pub fn new(dims: ManifestDims) -> VirtualBackend {
        VirtualBackend { dims, executions: 0 }
    }
}

impl Backend for VirtualBackend {
    fn run(&mut self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let out = match name {
            "attn_fwd" => kernels::attn_fwd(args, &self.dims),
            "attn_bwd_x" => kernels::attn_bwd_x(args, &self.dims),
            "attn_bwd_w" => kernels::attn_bwd_w(args, &self.dims),
            "mlp_fwd" => kernels::mlp_fwd(args, &self.dims),
            "mlp_bwd_x" => kernels::mlp_bwd_x(args, &self.dims),
            "mlp_bwd_w" => kernels::mlp_bwd_w(args, &self.dims),
            "embed_fwd" => kernels::embed_fwd(args),
            "embed_bwd" => kernels::embed_bwd(args, &self.dims),
            "head_loss_grad" => kernels::head_loss_grad(args),
            other => anyhow::bail!("virtual backend: unknown unit '{other}'"),
        }?;
        self.executions += 1;
        Ok(out)
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Virtual
    }
}

/// The unit names every backend must serve (the engine's working set).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub(crate) const UNIT_NAMES: [&str; 9] = [
    "attn_fwd",
    "attn_bwd_x",
    "attn_bwd_w",
    "mlp_fwd",
    "mlp_bwd_x",
    "mlp_bwd_w",
    "embed_fwd",
    "embed_bwd",
    "head_loss_grad",
];

/// PJRT adapter: the pre-refactor execution path behind the seam.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    rt: crate::runtime::Runtime,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Compile the engine's unit set from `manifest`'s artifacts.
    pub fn load(manifest: &crate::config::Manifest) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: crate::runtime::Runtime::load(manifest, &UNIT_NAMES)? })
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn run(&mut self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        self.rt.run(name, args)
    }

    fn executions(&self) -> u64 {
        self.rt.executions
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }
}

/// Construct the configured backend for one device thread.
pub(crate) fn make_backend(
    kind: BackendKind,
    manifest: Option<&crate::config::Manifest>,
    dims: &ManifestDims,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Virtual => Ok(Box::new(VirtualBackend::new(dims.clone()))),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => {
            let m = manifest
                .ok_or_else(|| anyhow::anyhow!("pjrt backend needs an artifact manifest"))?;
            Ok(Box::new(PjrtBackend::load(m)?))
        }
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => {
            let _ = manifest;
            anyhow::bail!(
                "the pjrt backend needs the PJRT runtime — rebuild with `--features pjrt` \
                 (and real xla bindings, see rust/Cargo.toml), or use `--backend virtual`"
            )
        }
    }
}

/// Miniature-but-consistent model dims for virtual execution of a plan:
/// every TP divisibility rule holds for `tp` and the layer budget is the
/// plan's, so the choreography (thread grid, channels, collectives,
/// per-chunk parameter shapes) is exercised at negligible per-op cost.
pub fn virtual_dims(tp: usize, pp: usize, vpp: usize, layers: usize) -> ManifestDims {
    assert!(tp >= 1 && pp >= 1 && vpp >= 1);
    ManifestDims {
        vocab: 256,
        d: 8 * tp,
        q_heads: 2 * tp,
        kv_heads: tp,
        ffn: 16 * tp,
        layers,
        seq: 16,
        mb: 2,
        tp,
        pp,
        vpp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("virtual".parse::<BackendKind>().unwrap(), BackendKind::Virtual);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("gpu".parse::<BackendKind>().is_err());
    }

    #[test]
    fn virtual_backend_serves_every_unit_name() {
        let dims = virtual_dims(1, 1, 1, 1);
        let mut b = VirtualBackend::new(dims.clone());
        // Shapes per the AOT signatures at these dims.
        let d = dims.d;
        let x = Tensor::f32(vec![0.1; dims.mb * dims.seq * d], &[dims.mb, dims.seq, d]);
        let g = Tensor::f32(vec![1.0; d], &[d]);
        let qr = dims.q_heads_per_rank() * dims.head_dim();
        let kr = dims.kv_heads_per_rank() * dims.head_dim();
        let fr = dims.ffn_per_rank();
        let wq = Tensor::f32(vec![0.1; d * qr], &[d, qr]);
        let wk = Tensor::f32(vec![0.1; d * kr], &[d, kr]);
        let wv = Tensor::f32(vec![0.1; d * kr], &[d, kr]);
        let wo = Tensor::f32(vec![0.1; qr * d], &[qr, d]);
        let wg = Tensor::f32(vec![0.1; d * fr], &[d, fr]);
        let wu = Tensor::f32(vec![0.1; d * fr], &[d, fr]);
        let wd = Tensor::f32(vec![0.1; fr * d], &[fr, d]);
        let tok = Tensor::i32(vec![3; dims.mb * dims.seq], &[dims.mb, dims.seq]);
        let emb = Tensor::f32(vec![0.1; dims.vocab * d], &[dims.vocab, d]);
        let wh = Tensor::f32(vec![0.1; d * dims.vocab], &[d, dims.vocab]);

        let attn = [x.clone(), g.clone(), wq, wk, wv, wo];
        assert_eq!(b.run("attn_fwd", &attn).unwrap().len(), 1);
        let attn_b = [
            attn[0].clone(),
            x.clone(),
            attn[1].clone(),
            attn[2].clone(),
            attn[3].clone(),
            attn[4].clone(),
            attn[5].clone(),
        ];
        assert_eq!(b.run("attn_bwd_x", &attn_b).unwrap().len(), 1);
        assert_eq!(b.run("attn_bwd_w", &attn_b).unwrap().len(), 5);
        let mlp = [x.clone(), g, wg, wu, wd];
        assert_eq!(b.run("mlp_fwd", &mlp).unwrap().len(), 1);
        let mlp_b = [
            mlp[0].clone(),
            x.clone(),
            mlp[1].clone(),
            mlp[2].clone(),
            mlp[3].clone(),
            mlp[4].clone(),
        ];
        assert_eq!(b.run("mlp_bwd_x", &mlp_b).unwrap().len(), 1);
        assert_eq!(b.run("mlp_bwd_w", &mlp_b).unwrap().len(), 4);
        assert_eq!(b.run("embed_fwd", &[tok.clone(), emb]).unwrap().len(), 1);
        assert_eq!(b.run("embed_bwd", &[tok.clone(), x.clone()]).unwrap().len(), 1);
        assert_eq!(b.run("head_loss_grad", &[x, wh, tok]).unwrap().len(), 3);
        assert!(b.run("nope", &[]).is_err());
        assert_eq!(b.executions(), 9);
    }

    #[test]
    fn virtual_dims_respect_tp_divisibility() {
        for tp in [1, 2, 4, 8] {
            let d = virtual_dims(tp, 2, 2, 8);
            assert_eq!(d.q_heads % tp, 0);
            assert_eq!(d.kv_heads % tp, 0);
            assert_eq!(d.ffn % tp, 0);
            assert_eq!(d.d % d.q_heads, 0);
            assert!(d.q_heads_per_rank() >= 1 && d.head_dim() >= 1);
        }
    }
}
