//! Pluggable execution backends for the pipeline engine.
//!
//! The engine (`exec::engine`) walks compiled schedules and manages the
//! braided thread choreography — per-(stage, tp-rank) threads, aligned
//! TP collectives, bounded P2P channels, activation store/offload —
//! while everything *numerical* goes through one seam: [`Backend::run`],
//! keyed by the nine AOT unit names (`python/compile/aot.py`).
//!
//! `run` **borrows** its inputs (`&[&Tensor]`): the engine hands weight
//! and activation tensors straight from its parameter tables and
//! activation store, so the per-op clones the pre-arena executor paid
//! (a full weight copy per layer per microbatch per step) are gone. The
//! return leg is closed by [`Backend::recycle`]: when the engine is done
//! with an output tensor (consumed activation, accumulated gradient), it
//! hands the storage back so the arena can serve the next op from the
//! pool — zero steady-state allocations in *both* directions.
//!
//! * [`VirtualBackend`] — always compiled: deterministic host tensors
//!   through the kernels in [`super::kernels`]. Three paths: the
//!   cache-blocked workspace-backed hot path ([`KernelPath::Blocked`],
//!   default), the SIMD-tiled multithreaded flash-attention path
//!   ([`KernelPath::Simd`]), and the preserved naive oracle
//!   ([`KernelPath::Reference`]). Blocked is bit-equal to Reference
//!   (DESIGN.md §11); Simd is bit-equal on every GEMM and ≤1e-5 on the
//!   flash-reassociated attention path (DESIGN.md §13).
//! * `PjrtBackend` (feature `pjrt`) — a thin adapter over
//!   [`crate::runtime::Runtime`]: AOT HLO artifacts executed through
//!   PJRT, exactly the pre-refactor path.

use std::str::FromStr;

use crate::config::ManifestDims;
use crate::runtime::Tensor;
use crate::Result;

use super::kernels;
use super::workspace::WorkspaceStats;

/// Which execution backend a training run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Host reference kernels, no PJRT — available in every build.
    Virtual,
    /// AOT HLO artifacts through PJRT (needs the `pjrt` feature and a
    /// compiled artifact directory).
    Pjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Virtual => "virtual",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "virtual" | "cpu" | "host" => Ok(BackendKind::Virtual),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend '{other}' (expected virtual|pjrt)")),
        }
    }
}

/// Which kernel implementation the virtual backend computes with.
/// `Blocked` and `Reference` produce bit-identical tensors; `Simd` keeps
/// bit equality on every GEMM and holds the flash-tiled attention core
/// to a documented ≤1e-5 tolerance (DESIGN.md §13). `Reference` exists
/// as the parity oracle and the bench baseline (`stp bench train`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Cache-blocked scalar GEMM microkernels over the per-thread
    /// workspace arena.
    Blocked,
    /// SIMD register tiles, the GEMM worker pool, and flash-tiled
    /// attention — the fastest path.
    Simd,
    /// The preserved naive kernels (`kernels::reference`): fresh
    /// allocations per op, triple-loop GEMMs.
    Reference,
}

impl KernelPath {
    pub fn name(&self) -> &'static str {
        match self {
            KernelPath::Blocked => "blocked",
            KernelPath::Simd => "simd",
            KernelPath::Reference => "reference",
        }
    }
}

impl FromStr for KernelPath {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "blocked" | "arena" => Ok(KernelPath::Blocked),
            "simd" | "vector" | "fast" => Ok(KernelPath::Simd),
            "reference" | "naive" | "ref" => Ok(KernelPath::Reference),
            other => {
                Err(format!("unknown kernel path '{other}' (expected blocked|simd|reference)"))
            }
        }
    }
}

/// One device thread's compute provider: executes a named unit over host
/// tensors. Implementations are constructed per OS thread (the PJRT
/// wrapper types are `!Send`), so the trait needs no `Send` bound.
pub trait Backend {
    /// Execute unit `name` (an AOT artifact name) on borrowed `args`.
    fn run(&mut self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>>;
    /// Return a tensor this backend produced (via [`Backend::run`]) whose
    /// life is over, letting the backend reclaim the storage. Optional:
    /// the default drops the tensor, which is always correct — recycling
    /// is purely an allocation-count optimization.
    fn recycle(&mut self, t: Tensor) {
        let _ = t;
    }
    /// Cumulative unit executions (metrics).
    fn executions(&self) -> u64;
    /// Stable backend label for reports.
    fn kind(&self) -> BackendKind;
    /// Scratch-arena counters, if this backend owns one (the virtual
    /// backend's zero-steady-state-allocation contract is asserted
    /// through this).
    fn workspace_stats(&self) -> Option<WorkspaceStats> {
        None
    }
}

/// The deterministic no-PJRT backend: host kernels shaped by the run's
/// [`ManifestDims`], with a per-thread [`kernels::KernelCtx`] carrying
/// the scratch arena, the tile selection, and (for [`KernelPath::Simd`])
/// the GEMM worker-pool arenas.
pub struct VirtualBackend {
    dims: ManifestDims,
    cx: kernels::KernelCtx,
    path: KernelPath,
    executions: u64,
}

impl VirtualBackend {
    pub fn new(dims: ManifestDims) -> VirtualBackend {
        VirtualBackend::with_path(dims, KernelPath::Blocked)
    }

    pub fn with_path(dims: ManifestDims, path: KernelPath) -> VirtualBackend {
        VirtualBackend::with_opts(dims, path, 1)
    }

    /// Full constructor: `workers` sizes the GEMM worker pool (only the
    /// `Simd` path uses it; `< 2` means all GEMMs stay on this thread).
    pub fn with_opts(dims: ManifestDims, path: KernelPath, workers: usize) -> VirtualBackend {
        let cx = match path {
            KernelPath::Simd => kernels::KernelCtx::with_workers(true, workers),
            KernelPath::Blocked | KernelPath::Reference => kernels::KernelCtx::serial(false),
        };
        VirtualBackend { dims, cx, path, executions: 0 }
    }

    pub fn kernel_path(&self) -> KernelPath {
        self.path
    }
}

impl Backend for VirtualBackend {
    fn run(&mut self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let out = match self.path {
            KernelPath::Blocked | KernelPath::Simd => {
                let cx = &mut self.cx;
                match name {
                    "attn_fwd" => kernels::attn_fwd(args, &self.dims, cx),
                    "attn_bwd_x" => kernels::attn_bwd_x(args, &self.dims, cx),
                    "attn_bwd_w" => kernels::attn_bwd_w(args, &self.dims, cx),
                    "mlp_fwd" => kernels::mlp_fwd(args, &self.dims, cx),
                    "mlp_bwd_x" => kernels::mlp_bwd_x(args, &self.dims, cx),
                    "mlp_bwd_w" => kernels::mlp_bwd_w(args, &self.dims, cx),
                    "embed_fwd" => kernels::embed_fwd(args, cx),
                    "embed_bwd" => kernels::embed_bwd(args, &self.dims, cx),
                    "head_loss_grad" => kernels::head_loss_grad(args, cx),
                    other => anyhow::bail!("virtual backend: unknown unit '{other}'"),
                }
            }
            KernelPath::Reference => match name {
                "attn_fwd" => kernels::reference::attn_fwd(args, &self.dims),
                "attn_bwd_x" => kernels::reference::attn_bwd_x(args, &self.dims),
                "attn_bwd_w" => kernels::reference::attn_bwd_w(args, &self.dims),
                "mlp_fwd" => kernels::reference::mlp_fwd(args, &self.dims),
                "mlp_bwd_x" => kernels::reference::mlp_bwd_x(args, &self.dims),
                "mlp_bwd_w" => kernels::reference::mlp_bwd_w(args, &self.dims),
                "embed_fwd" => kernels::reference::embed_fwd(args),
                "embed_bwd" => kernels::reference::embed_bwd(args, &self.dims),
                "head_loss_grad" => kernels::reference::head_loss_grad(args),
                other => anyhow::bail!("virtual backend: unknown unit '{other}'"),
            },
        }?;
        self.executions += 1;
        Ok(out)
    }

    fn recycle(&mut self, t: Tensor) {
        // Reference outputs are plain allocations sized to their tensor,
        // not to a pool class — feeding them in would skew the pools and
        // the path is not perf-relevant anyway. I32 tensors (tokens,
        // targets) never come from the f32 arena.
        if self.path == KernelPath::Reference {
            return;
        }
        if let Tensor::F32 { data, .. } = t {
            self.cx.ws.give(data);
        }
    }

    fn executions(&self) -> u64 {
        self.executions
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Virtual
    }

    fn workspace_stats(&self) -> Option<WorkspaceStats> {
        Some(self.cx.stats())
    }
}

/// The unit names every backend must serve (the engine's working set).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub(crate) const UNIT_NAMES: [&str; 9] = [
    "attn_fwd",
    "attn_bwd_x",
    "attn_bwd_w",
    "mlp_fwd",
    "mlp_bwd_x",
    "mlp_bwd_w",
    "embed_fwd",
    "embed_bwd",
    "head_loss_grad",
];

/// PJRT adapter: the pre-refactor execution path behind the seam.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    rt: crate::runtime::Runtime,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Compile the engine's unit set from `manifest`'s artifacts.
    pub fn load(manifest: &crate::config::Manifest) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: crate::runtime::Runtime::load(manifest, &UNIT_NAMES)? })
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn run(&mut self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.rt.run(name, args)
    }

    fn executions(&self) -> u64 {
        self.rt.executions
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }
}

/// Construct the configured backend for one device thread. `workers`
/// sizes the virtual backend's GEMM worker pool (ignored elsewhere).
pub(crate) fn make_backend(
    kind: BackendKind,
    manifest: Option<&crate::config::Manifest>,
    dims: &ManifestDims,
    path: KernelPath,
    workers: usize,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Virtual => {
            Ok(Box::new(VirtualBackend::with_opts(dims.clone(), path, workers)))
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => {
            let m = manifest
                .ok_or_else(|| anyhow::anyhow!("pjrt backend needs an artifact manifest"))?;
            Ok(Box::new(PjrtBackend::load(m)?))
        }
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => {
            let _ = manifest;
            anyhow::bail!(
                "the pjrt backend needs the PJRT runtime — rebuild with `--features pjrt` \
                 (and real xla bindings, see rust/Cargo.toml), or use `--backend virtual`"
            )
        }
    }
}

/// Miniature-but-consistent model dims for virtual execution of a plan:
/// every TP divisibility rule holds for `tp` and the layer budget is the
/// plan's, so the choreography (thread grid, channels, collectives,
/// per-chunk parameter shapes) is exercised at negligible per-op cost.
pub fn virtual_dims(tp: usize, pp: usize, vpp: usize, layers: usize) -> ManifestDims {
    virtual_dims_scaled(tp, pp, vpp, layers, 1.0)
}

/// [`virtual_dims`] with a width multiplier: `scale` (rounded to an
/// integer factor ≥ 1) multiplies the hidden and ffn widths, preserving
/// every TP divisibility rule. `scale = 1.0` is exactly the classic
/// miniature proxy; larger factors make the per-op tensors big enough to
/// be meaningful on beefy hosts (`stp train --virtual-scale auto`).
pub fn virtual_dims_scaled(
    tp: usize,
    pp: usize,
    vpp: usize,
    layers: usize,
    scale: f64,
) -> ManifestDims {
    assert!(tp >= 1 && pp >= 1 && vpp >= 1);
    assert!(scale.is_finite() && scale >= 1.0, "virtual scale must be ≥ 1, got {scale}");
    let f = scale.round().max(1.0) as usize;
    ManifestDims {
        vocab: 256,
        d: 8 * tp * f,
        q_heads: 2 * tp,
        kv_heads: tp,
        ffn: 16 * tp * f,
        layers,
        seq: 16,
        mb: 2,
        tp,
        pp,
        vpp,
    }
}

/// Width factor matched to this host: 1 on small CI runners, growing
/// with the core count so big machines exercise non-trivial tensors
/// (clamped to 8 ⇒ d = 64·tp at most).
pub fn host_virtual_scale() -> f64 {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    (cores as f64 / 8.0).clamp(1.0, 8.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("virtual".parse::<BackendKind>().unwrap(), BackendKind::Virtual);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("gpu".parse::<BackendKind>().is_err());
    }

    #[test]
    fn kernel_path_parses() {
        assert_eq!("blocked".parse::<KernelPath>().unwrap(), KernelPath::Blocked);
        assert_eq!("naive".parse::<KernelPath>().unwrap(), KernelPath::Reference);
        assert_eq!("simd".parse::<KernelPath>().unwrap(), KernelPath::Simd);
        assert_eq!("vector".parse::<KernelPath>().unwrap(), KernelPath::Simd);
        assert!("avx9".parse::<KernelPath>().is_err());
        assert_eq!(KernelPath::Blocked.name(), "blocked");
        assert_eq!(KernelPath::Simd.name(), "simd");
    }

    #[test]
    fn virtual_backend_serves_every_unit_name() {
        for path in [KernelPath::Blocked, KernelPath::Simd, KernelPath::Reference] {
            let dims = virtual_dims(1, 1, 1, 1);
            let mut b = VirtualBackend::with_opts(dims.clone(), path, 2);
            // Shapes per the AOT signatures at these dims.
            let d = dims.d;
            let x = Tensor::f32(vec![0.1; dims.mb * dims.seq * d], &[dims.mb, dims.seq, d]);
            let g = Tensor::f32(vec![1.0; d], &[d]);
            let qr = dims.q_heads_per_rank() * dims.head_dim();
            let kr = dims.kv_heads_per_rank() * dims.head_dim();
            let fr = dims.ffn_per_rank();
            let wq = Tensor::f32(vec![0.1; d * qr], &[d, qr]);
            let wk = Tensor::f32(vec![0.1; d * kr], &[d, kr]);
            let wv = Tensor::f32(vec![0.1; d * kr], &[d, kr]);
            let wo = Tensor::f32(vec![0.1; qr * d], &[qr, d]);
            let wg = Tensor::f32(vec![0.1; d * fr], &[d, fr]);
            let wu = Tensor::f32(vec![0.1; d * fr], &[d, fr]);
            let wd = Tensor::f32(vec![0.1; fr * d], &[fr, d]);
            let tok = Tensor::i32(vec![3; dims.mb * dims.seq], &[dims.mb, dims.seq]);
            let emb = Tensor::f32(vec![0.1; dims.vocab * d], &[dims.vocab, d]);
            let wh = Tensor::f32(vec![0.1; d * dims.vocab], &[d, dims.vocab]);

            assert_eq!(b.run("attn_fwd", &[&x, &g, &wq, &wk, &wv, &wo]).unwrap().len(), 1);
            let attn_b = [&x, &x, &g, &wq, &wk, &wv, &wo];
            assert_eq!(b.run("attn_bwd_x", &attn_b).unwrap().len(), 1);
            assert_eq!(b.run("attn_bwd_w", &attn_b).unwrap().len(), 5);
            assert_eq!(b.run("mlp_fwd", &[&x, &g, &wg, &wu, &wd]).unwrap().len(), 1);
            let mlp_b = [&x, &x, &g, &wg, &wu, &wd];
            assert_eq!(b.run("mlp_bwd_x", &mlp_b).unwrap().len(), 1);
            assert_eq!(b.run("mlp_bwd_w", &mlp_b).unwrap().len(), 4);
            assert_eq!(b.run("embed_fwd", &[&tok, &emb]).unwrap().len(), 1);
            assert_eq!(b.run("embed_bwd", &[&tok, &x]).unwrap().len(), 1);
            assert_eq!(b.run("head_loss_grad", &[&x, &wh, &tok]).unwrap().len(), 3);
            assert!(b.run("nope", &[]).is_err());
            assert_eq!(b.executions(), 9, "{path:?}");
            let stats = b.workspace_stats().unwrap();
            match path {
                KernelPath::Blocked | KernelPath::Simd => {
                    assert!(stats.takes > 0, "{path:?} path must use the arena")
                }
                KernelPath::Reference => assert_eq!(stats.takes, 0),
            }
        }
    }

    #[test]
    fn recycled_outputs_feed_the_next_run() {
        // The recycle seam's contract: running a unit, recycling its
        // outputs, and running again serves the second run's outputs
        // from the pool (no fresh allocations).
        let dims = virtual_dims(1, 1, 1, 1);
        let mut b = VirtualBackend::with_path(dims.clone(), KernelPath::Simd);
        let d = dims.d;
        let x = Tensor::f32(vec![0.1; dims.mb * dims.seq * d], &[dims.mb, dims.seq, d]);
        let wh = Tensor::f32(vec![0.1; d * dims.vocab], &[d, dims.vocab]);
        let tgt = Tensor::i32(vec![3; dims.mb * dims.seq], &[dims.mb, dims.seq]);
        let mut go = |b: &mut VirtualBackend| {
            let outs = b.run("head_loss_grad", &[&x, &wh, &tgt]).unwrap();
            for t in outs {
                b.recycle(t);
            }
        };
        go(&mut b);
        let warm = b.workspace_stats().unwrap().fresh_allocs;
        assert!(warm > 0);
        for _ in 0..3 {
            go(&mut b);
        }
        assert_eq!(b.workspace_stats().unwrap().fresh_allocs, warm, "recycle must close the loop");

        // Reference path: recycle is a deliberate no-op (plain Vecs).
        let mut r = VirtualBackend::with_path(dims, KernelPath::Reference);
        let outs = r.run("head_loss_grad", &[&x, &wh, &tgt]).unwrap();
        for t in outs {
            r.recycle(t);
        }
        assert_eq!(r.workspace_stats().unwrap().takes, 0);
    }

    #[test]
    fn virtual_dims_respect_tp_divisibility() {
        for tp in [1, 2, 4, 8] {
            for scale in [1.0, 2.0, 4.0] {
                let d = virtual_dims_scaled(tp, 2, 2, 8, scale);
                assert_eq!(d.q_heads % tp, 0);
                assert_eq!(d.kv_heads % tp, 0);
                assert_eq!(d.ffn % tp, 0);
                assert_eq!(d.d % d.q_heads, 0);
                assert!(d.q_heads_per_rank() >= 1 && d.head_dim() >= 1);
            }
        }
    }

    #[test]
    fn scaled_dims_default_to_the_classic_miniature() {
        let a = virtual_dims(2, 2, 2, 8);
        let b = virtual_dims_scaled(2, 2, 2, 8, 1.0);
        assert_eq!((a.d, a.ffn, a.vocab, a.seq), (b.d, b.ffn, b.vocab, b.seq));
        assert_eq!(a.d, 16);
        let big = virtual_dims_scaled(2, 2, 2, 8, 4.0);
        assert_eq!(big.d, 64);
        assert_eq!(big.ffn, 128);
        assert!(host_virtual_scale() >= 1.0);
    }
}
