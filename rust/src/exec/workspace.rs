//! Per-device-thread scratch arena for the virtual backend's kernels.
//!
//! Every kernel-internal buffer (normed activations, Q/K/V projections,
//! attention probabilities, packed GEMM panels, …) is borrowed from a
//! [`Workspace`] and returned when the op finishes, so a steady-state
//! training step performs **zero** scratch allocations: the first step
//! populates the size-classed pools, every later step recycles them.
//! `tests/train_virtual.rs` pins that contract through
//! [`RunReport::workspace_steady_allocs`](super::RunReport).
//!
//! Buffers are plain `Vec<f32>`s handed out by value (no lifetimes to
//! fight through the kernel call graph); discipline is take/give pairing
//! inside one kernel. A leaked buffer is not a correctness bug — the next
//! `take` of that class simply heap-allocates — but it shows up as a
//! nonzero steady-state allocation count, which is exactly what the test
//! watches.

/// Snapshot of a workspace's counters (cheap, `Copy`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// Heap allocations performed because no pooled buffer fit.
    pub fresh_allocs: u64,
    /// Total take calls served (pooled + fresh).
    pub takes: u64,
    /// High-water mark of arena-tracked bytes (pooled + checked out).
    pub peak_bytes: usize,
}

/// Size-classed (power-of-two) free-list pool of `Vec<f32>` buffers.
#[derive(Default)]
pub struct Workspace {
    /// `pools[c]` holds buffers with capacity in `[2^c, 2^(c+1))`.
    pools: Vec<Vec<Vec<f32>>>,
    /// f32 slots currently sitting in the pools.
    pooled: usize,
    /// f32 slots currently checked out to kernels.
    out: usize,
    stats: WorkspaceStats,
}

/// Debug-build fill pattern for [`Workspace::take_uninit`]: a quiet NaN
/// whose payload spells out where it came from. Any arithmetic on an
/// unwritten slot propagates NaN straight into a test assertion.
pub const POISON_BITS: u32 = 0x7fc0_dead;

/// Class that can serve a request for `n` elements (`2^c >= n`).
fn class_for_request(n: usize) -> usize {
    n.next_power_of_two().trailing_zeros() as usize
}

/// Class a buffer of capacity `cap` belongs to (`2^c <= cap`).
fn class_for_capacity(cap: usize) -> usize {
    debug_assert!(cap > 0);
    (usize::BITS - 1 - cap.leading_zeros()) as usize
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Borrow a zeroed buffer of exactly `n` elements. The capacity is the
    /// request's power-of-two class, so a recycled buffer never reallocates
    /// when resized for a different `n` of the same class.
    pub fn take(&mut self, n: usize) -> Vec<f32> {
        self.take_inner(n, true)
    }

    /// Like [`Workspace::take`] but recycled contents are **not** zeroed —
    /// for buffers the caller fully overwrites before reading (the GEMM
    /// packing panels). Length is still exactly `n`; values are
    /// unspecified-but-initialized f32s.
    pub fn take_uninit(&mut self, n: usize) -> Vec<f32> {
        self.take_inner(n, false)
    }

    fn take_inner(&mut self, n: usize, zero: bool) -> Vec<f32> {
        self.stats.takes += 1;
        let class = class_for_request(n.max(1));
        let mut buf = match self.pools.get_mut(class).and_then(Vec::pop) {
            Some(b) => {
                self.pooled -= b.capacity();
                b
            }
            None => {
                self.stats.fresh_allocs += 1;
                Vec::with_capacity(1usize << class)
            }
        };
        if zero {
            buf.clear();
            buf.resize(n, 0.0);
        } else if cfg!(debug_assertions) {
            // Poison recycled contents with a recognizable signaling
            // pattern so a read-before-write in a `take_uninit` consumer
            // surfaces as NaN in debug builds instead of silently reusing
            // stale values (the release fast path keeps them).
            buf.clear();
            buf.resize(n, f32::from_bits(POISON_BITS));
        } else {
            // Pads growth only (stale prefix kept) or truncates — no
            // memset over contents the caller will overwrite.
            buf.resize(n, 0.0);
        }
        self.out += buf.capacity();
        self.stats.peak_bytes = self.stats.peak_bytes.max(4 * (self.pooled + self.out));
        buf
    }

    /// Return a buffer to the pool. Accepts any `Vec<f32>` (classed by its
    /// capacity), so buffers survive round-trips through callers that
    /// resized them within their capacity.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let class = class_for_capacity(buf.capacity());
        if self.pools.len() <= class {
            self.pools.resize_with(class + 1, Vec::new);
        }
        self.out = self.out.saturating_sub(buf.capacity());
        self.pooled += buf.capacity();
        self.stats.peak_bytes = self.stats.peak_bytes.max(4 * (self.pooled + self.out));
        self.pools[class].push(buf);
    }

    pub fn stats(&self) -> WorkspaceStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_give_recycles_by_class() {
        let mut ws = Workspace::new();
        let a = ws.take(100); // class 7 (128)
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|&v| v == 0.0));
        assert!(a.capacity() >= 128);
        ws.give(a);
        // Same class, different length: served from the pool, re-zeroed.
        let mut b = ws.take(65);
        assert_eq!(b.len(), 65);
        assert_eq!(ws.stats().fresh_allocs, 1);
        b.iter_mut().for_each(|v| *v = 9.0);
        ws.give(b);
        let c = ws.take(128);
        assert!(c.iter().all(|&v| v == 0.0), "recycled buffer must be zeroed");
        assert_eq!(ws.stats().fresh_allocs, 1);
        assert_eq!(ws.stats().takes, 3);
    }

    #[test]
    fn distinct_classes_do_not_share() {
        let mut ws = Workspace::new();
        let a = ws.take(16);
        ws.give(a);
        let _b = ws.take(17); // class 5 (32): pool for class 4 cannot serve it
        assert_eq!(ws.stats().fresh_allocs, 2);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let mut ws = Workspace::new();
        // Simulated op: three concurrent buffers of repeating shapes.
        for _ in 0..10 {
            let x = ws.take(300);
            let y = ws.take(300);
            let z = ws.take(40);
            ws.give(x);
            ws.give(y);
            ws.give(z);
        }
        let warm = ws.stats().fresh_allocs;
        assert_eq!(warm, 3);
        for _ in 0..100 {
            let x = ws.take(300);
            let y = ws.take(257); // same class as 300
            let z = ws.take(33);
            ws.give(z);
            ws.give(y);
            ws.give(x);
        }
        assert_eq!(ws.stats().fresh_allocs, warm, "steady state must not allocate");
    }

    #[test]
    fn peak_bytes_tracks_high_water() {
        let mut ws = Workspace::new();
        let a = ws.take(1024);
        let b = ws.take(1024);
        ws.give(a);
        ws.give(b);
        let peak = ws.stats().peak_bytes;
        assert!(peak >= 2 * 1024 * 4, "peak {peak}");
        // Reuse does not move the peak.
        let c = ws.take(1024);
        ws.give(c);
        assert_eq!(ws.stats().peak_bytes, peak);
    }

    #[test]
    fn zero_length_requests_are_served() {
        let mut ws = Workspace::new();
        let a = ws.take(0);
        assert!(a.is_empty());
        ws.give(a);
    }

    #[test]
    fn take_uninit_recycles_without_zeroing_guarantee() {
        let mut ws = Workspace::new();
        let mut a = ws.take_uninit(64);
        assert_eq!(a.len(), 64);
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.give(a);
        // Same class: recycled, correct length, no fresh allocation —
        // contents are unspecified, so only shape is asserted.
        let b = ws.take_uninit(40);
        assert_eq!(b.len(), 40);
        assert_eq!(ws.stats().fresh_allocs, 1);
        ws.give(b);
        // A zeroed take of the same class must still come back clean.
        let c = ws.take(64);
        assert!(c.iter().all(|&v| v == 0.0));
        assert_eq!(ws.stats().fresh_allocs, 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    fn take_uninit_is_poisoned_in_debug_builds() {
        // Debug builds must hand out the NaN pattern, fresh and recycled
        // alike — a consumer reading before writing cannot see stale
        // (plausible-looking) values from an earlier kernel.
        let mut ws = Workspace::new();
        let mut a = ws.take_uninit(32);
        assert!(a.iter().all(|v| v.to_bits() == POISON_BITS), "fresh take_uninit not poisoned");
        a.iter_mut().for_each(|v| *v = 3.0);
        ws.give(a);
        let b = ws.take_uninit(32);
        assert!(
            b.iter().all(|v| v.to_bits() == POISON_BITS),
            "recycled take_uninit not poisoned"
        );
    }
}
