//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! One [`Runtime`] per OS thread (PJRT wrapper types hold raw pointers and
//! are `!Send`): `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Interchange is HLO **text** — the crate's
//! xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit ids).
//! Adapted from /opt/xla-example/load_hlo.
//!
//! [`Tensor`] (the host-side interchange type used by `comm`, `memory`
//! and `exec::params`) is always available; [`Runtime`] and the PJRT
//! literal conversions require the `pjrt` cargo feature (see Cargo.toml).

#[cfg(feature = "pjrt")]
use std::collections::HashMap;

#[cfg(feature = "pjrt")]
use crate::config::{DType, Manifest};
use crate::Result;

/// A host tensor crossing the rust↔PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32 { data, shape: shape.to_vec() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32 { data, shape: shape.to_vec() }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => anyhow::bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => anyhow::bail!("tensor is i32, expected f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        anyhow::ensure!(!d.is_empty(), "empty tensor");
        Ok(d[0])
    }
}

/// The PJRT boundary of [`Tensor`] — only meaningful with a client.
#[cfg(feature = "pjrt")]
impl Tensor {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32 { data, shape } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Tensor::I32 { data, shape } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { data: lit.to_vec::<f32>()?, shape: dims }),
            xla::ElementType::S32 => Ok(Tensor::I32 { data: lit.to_vec::<i32>()?, shape: dims }),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        }
    }
}

/// A per-thread PJRT runtime holding compiled executables by name.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    /// Cumulative executions (metrics).
    pub executions: u64,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a runtime for `manifest`, compiling the named artifacts
    /// (or every artifact if `names` is empty).
    pub fn load(manifest: &Manifest, names: &[&str]) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let mut execs = HashMap::new();
        let all: Vec<String> = if names.is_empty() {
            manifest.artifacts.keys().cloned().collect()
        } else {
            names.iter().map(|s| s.to_string()).collect()
        };
        for name in all {
            let path = manifest.artifact_path(&name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            execs.insert(name, client.compile(&comp)?);
        }
        Ok(Runtime { client, execs, manifest: manifest.clone(), executions: 0 })
    }

    pub fn has(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    /// Execute artifact `name` on borrowed inputs (the executor hands
    /// weight tensors straight from its parameter tables — no clones).
    /// Inputs are validated against the manifest; the lowered module
    /// returns a tuple (return_tuple=True) which is decomposed into
    /// per-output tensors.
    pub fn run(&mut self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(
            args.len() == spec.args.len(),
            "{name}: got {} args, manifest says {}",
            args.len(),
            spec.args.len()
        );
        for (i, (a, s)) in args.iter().zip(&spec.args).enumerate() {
            anyhow::ensure!(
                a.shape() == &s.shape[..],
                "{name} arg {i}: shape {:?} != manifest {:?}",
                a.shape(),
                s.shape
            );
            let ok = matches!(
                (a, s.dtype),
                (Tensor::F32 { .. }, DType::F32) | (Tensor::I32 { .. }, DType::I32)
            );
            anyhow::ensure!(ok, "{name} arg {i}: dtype mismatch");
        }
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not compiled in this runtime"))?;
        let lits: Vec<xla::Literal> =
            args.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        self.executions += 1;
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == spec.n_outputs,
            "{name}: {} outputs, manifest says {}",
            parts.len(),
            spec.n_outputs
        );
        parts.iter().map(Tensor::from_literal).collect()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_accessors() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.bytes(), 16);
        assert!(t.as_f32().is_ok());
        let i = Tensor::i32(vec![1, 2], &[2]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    fn zeros_shape() {
        let t = Tensor::zeros(&[3, 5]);
        assert_eq!(t.len(), 15);
        assert_eq!(t.scalar_f32().unwrap(), 0.0);
    }
}
