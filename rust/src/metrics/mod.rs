//! Table formatting and small metric helpers for the bench harness.

/// A simple fixed-width text table (the bench harness prints paper-style
/// rows; no external tabulation crates in this environment).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format bytes as GB with one decimal (the paper's memory unit).
pub fn gb(bytes: usize) -> String {
    format!("{:.1}", bytes as f64 / 1e9)
}

/// Format a ratio as a percent string.
pub fn pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

/// Format seconds compactly.
pub fn secs(x: f64) -> String {
    if x >= 1.0 {
        format!("{x:.3}s")
    } else {
        format!("{:.3}ms", x * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(vec!["a", "bbbb"]);
        t.row(vec!["12345", "1"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("bbbb"));
        assert!(lines[2].contains("12345"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new(vec!["a"]).row(vec!["1", "2"]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(gb(43_000_000_000), "43.0");
        assert_eq!(pct(0.9286), "92.86");
        assert_eq!(secs(0.0123), "12.300ms");
    }
}
