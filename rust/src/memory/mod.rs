//! Activation memory management for the real executor.
//!
//! [`ActivationStore`] holds the per-(chunk, microbatch, layer, tag)
//! tensors the backward units consume, with byte accounting that mirrors
//! the simulator's tracker. [`OffloadManager`] is the §4.4 enhanced
//! variant's substrate: activations move to a host arena ("CPU" side of
//! the paper's PCIe link; here a separate accounting domain) and return on
//! reload — the policy (what, when, ratio α) lives in the schedule IR.

use std::collections::HashMap;

use crate::runtime::Tensor;
use crate::Result;

/// Per-device static (weights + grads + optimizer-state) bytes under an
/// arbitrary layer→device split: each device gets a layer-proportional
/// share of `state_bytes` (the whole job's parameter state for one TP
/// shard, i.e. params × bytes-per-param ÷ tp) plus the fixed per-device
/// `overhead`. The uniform split reduces to the historical
/// `state ÷ pp + overhead` scalar; weighted splits (heterogeneous pools,
/// DESIGN.md §8) concentrate state on the layer-heavy devices.
pub fn split_static_bytes(state_bytes: f64, dev_layers: &[usize], overhead: usize) -> Vec<usize> {
    let total: usize = dev_layers.iter().sum();
    dev_layers
        .iter()
        .map(|&l| (state_bytes * l as f64 / total.max(1) as f64) as usize + overhead)
        .collect()
}

/// Key of a stored activation: (chunk, microbatch, layer-in-chunk, tag).
/// Tags distinguish the unit inputs within a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ActKey {
    pub chunk: usize,
    pub mb: usize,
    pub layer: usize,
    pub tag: ActTag,
}

/// Which saved tensor within a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActTag {
    /// Input to the Attn unit (pre-LN residual stream).
    AttnIn,
    /// Input to the MLP unit.
    MlpIn,
    /// Output of the chunk (input to the head for the last chunk).
    ChunkOut,
    /// Upstream gradient stashed for a deferred weight pass.
    AttnGrad,
    MlpGrad,
}

/// Byte-accounted activation storage for one device thread.
#[derive(Default)]
pub struct ActivationStore {
    map: HashMap<ActKey, Tensor>,
    live_bytes: usize,
    peak_bytes: usize,
}

impl ActivationStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, key: ActKey, t: Tensor) {
        self.live_bytes += t.bytes();
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
        if let Some(old) = self.map.insert(key, t) {
            self.live_bytes -= old.bytes();
        }
    }

    /// Remove and return (backward consumes its stash exactly once).
    pub fn take(&mut self, key: &ActKey) -> Result<Tensor> {
        let t = self
            .map
            .remove(key)
            .ok_or_else(|| anyhow::anyhow!("activation {key:?} not stashed"))?;
        self.live_bytes -= t.bytes();
        Ok(t)
    }

    /// Borrow without consuming (weight pass may follow activation pass).
    pub fn get(&self, key: &ActKey) -> Result<&Tensor> {
        self.map.get(key).ok_or_else(|| anyhow::anyhow!("activation {key:?} not stashed"))
    }

    pub fn contains(&self, key: &ActKey) -> bool {
        self.map.contains_key(key)
    }

    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Move every stored tensor for (chunk, mb) matching `pred` out to the
    /// offload manager, returning how many bytes moved.
    pub fn offload_matching(
        &mut self,
        off: &mut OffloadManager,
        chunk: usize,
        mb: usize,
        ratio: f32,
    ) -> usize {
        let keys: Vec<ActKey> = self
            .map
            .keys()
            .filter(|k| k.chunk == chunk && k.mb == mb)
            .copied()
            .collect();
        // α selects a prefix of the layer stashes (the paper offloads a
        // tunable fraction of each microbatch's activations).
        let n = ((keys.len() as f32) * ratio).round() as usize;
        let mut moved = 0;
        for k in keys.into_iter().take(n) {
            let t = self.take(&k).expect("key just listed");
            moved += t.bytes();
            off.put(k, t);
        }
        moved
    }

    /// Reload everything the manager holds for (chunk, mb).
    pub fn reload_all(&mut self, off: &mut OffloadManager, chunk: usize, mb: usize) -> usize {
        let mut moved = 0;
        for (k, t) in off.take_matching(chunk, mb) {
            moved += t.bytes();
            self.put(k, t);
        }
        moved
    }
}

/// Host-side arena for offloaded activations (the paper's CPU memory).
#[derive(Default)]
pub struct OffloadManager {
    arena: HashMap<ActKey, Tensor>,
    host_bytes: usize,
    peak_host_bytes: usize,
    /// Cumulative traffic in each direction (PCIe accounting).
    pub offloaded_bytes: u64,
    pub reloaded_bytes: u64,
}

impl OffloadManager {
    pub fn new() -> Self {
        Self::default()
    }

    fn put(&mut self, key: ActKey, t: Tensor) {
        self.host_bytes += t.bytes();
        self.offloaded_bytes += t.bytes() as u64;
        self.peak_host_bytes = self.peak_host_bytes.max(self.host_bytes);
        self.arena.insert(key, t);
    }

    fn take_matching(&mut self, chunk: usize, mb: usize) -> Vec<(ActKey, Tensor)> {
        let keys: Vec<ActKey> = self
            .arena
            .keys()
            .filter(|k| k.chunk == chunk && k.mb == mb)
            .copied()
            .collect();
        keys.into_iter()
            .map(|k| {
                let t = self.arena.remove(&k).unwrap();
                self.host_bytes -= t.bytes();
                self.reloaded_bytes += t.bytes() as u64;
                (k, t)
            })
            .collect()
    }

    pub fn host_bytes(&self) -> usize {
        self.host_bytes
    }

    pub fn peak_host_bytes(&self) -> usize {
        self.peak_host_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(chunk: usize, mb: usize, layer: usize) -> ActKey {
        ActKey { chunk, mb, layer, tag: ActTag::AttnIn }
    }

    #[test]
    fn split_static_bytes_is_layer_proportional() {
        let v = split_static_bytes(1200.0, &[3, 1], 10);
        assert_eq!(v, vec![910, 310]);
        // Uniform split collapses to the scalar formula.
        let u = split_static_bytes(1200.0, &[2, 2], 10);
        assert_eq!(u, vec![610, 610]);
        // Degenerate empty split must not divide by zero.
        assert_eq!(split_static_bytes(1200.0, &[0, 0], 10), vec![10, 10]);
    }

    #[test]
    fn put_take_accounting() {
        let mut s = ActivationStore::new();
        s.put(key(0, 0, 0), Tensor::zeros(&[4, 4]));
        s.put(key(0, 0, 1), Tensor::zeros(&[4, 4]));
        assert_eq!(s.live_bytes(), 2 * 64);
        let _ = s.take(&key(0, 0, 0)).unwrap();
        assert_eq!(s.live_bytes(), 64);
        assert_eq!(s.peak_bytes(), 128);
        assert!(s.take(&key(0, 0, 0)).is_err());
    }

    #[test]
    fn offload_reload_roundtrip() {
        let mut s = ActivationStore::new();
        let mut off = OffloadManager::new();
        for l in 0..4 {
            s.put(key(1, 2, l), Tensor::f32(vec![l as f32; 8], &[8]));
        }
        let moved = s.offload_matching(&mut off, 1, 2, 0.5);
        assert_eq!(moved, 2 * 32);
        assert_eq!(s.len(), 2);
        assert_eq!(off.host_bytes(), 64);
        let back = s.reload_all(&mut off, 1, 2);
        assert_eq!(back, 64);
        assert_eq!(s.len(), 4);
        assert_eq!(off.host_bytes(), 0);
        assert_eq!(off.offloaded_bytes, 64);
        assert_eq!(off.reloaded_bytes, 64);
    }

    #[test]
    fn offload_only_touches_requested_microbatch() {
        let mut s = ActivationStore::new();
        let mut off = OffloadManager::new();
        s.put(key(0, 0, 0), Tensor::zeros(&[2]));
        s.put(key(0, 1, 0), Tensor::zeros(&[2]));
        s.offload_matching(&mut off, 0, 0, 1.0);
        assert!(!s.contains(&key(0, 0, 0)));
        assert!(s.contains(&key(0, 1, 0)));
    }
}
