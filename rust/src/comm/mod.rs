//! In-process communication substrate.
//!
//! Substitutes NCCL (DESIGN.md §1): TP All-Reduce is a real
//! rendezvous-and-sum across the rank threads of a TP group, and pipeline
//! P2P is real channel transfer between stage threads — the same
//! synchronization structure the paper's schedules manage, minus CUDA.

use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Barrier, Condvar, Mutex};

use crate::runtime::Tensor;
use crate::Result;

/// A tensor-parallel group: `t` rank threads all-reducing f32 buffers.
///
/// Implementation: two-phase rendezvous. Every rank deposits a reference
/// copy of its buffer; the last one to arrive sums all contributions;
/// everyone copies the sum out. Byte counters feed the metrics.
pub struct TpGroup {
    size: usize,
    slots: Mutex<Slots>,
    barrier: Barrier,
    done: Condvar,
    /// Total bytes all-reduced (for metrics / Table 11 style accounting).
    bytes: Mutex<u64>,
    /// Number of collectives executed.
    ops: Mutex<u64>,
}

struct Slots {
    bufs: Vec<Option<Vec<f32>>>,
    sum: Option<Vec<f32>>,
    arrived: usize,
    generation: u64,
}

impl TpGroup {
    pub fn new(size: usize) -> Arc<TpGroup> {
        Arc::new(TpGroup {
            size,
            slots: Mutex::new(Slots {
                bufs: (0..size).map(|_| None).collect(),
                sum: None,
                arrived: 0,
                generation: 0,
            }),
            barrier: Barrier::new(size),
            done: Condvar::new(),
            bytes: Mutex::new(0),
            ops: Mutex::new(0),
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// All-reduce (sum) `buf` in place across the group. Call exactly once
    /// per rank per collective; collectives must be issued in the same
    /// order on every rank (the usual NCCL contract).
    pub fn all_reduce(&self, rank: usize, buf: &mut [f32]) -> Result<()> {
        if self.size == 1 {
            return Ok(());
        }
        anyhow::ensure!(rank < self.size, "rank {rank} out of group size {}", self.size);
        let mut slots = self.slots.lock().unwrap();
        anyhow::ensure!(slots.bufs[rank].is_none(), "rank {rank} double-deposited");
        slots.bufs[rank] = Some(buf.to_vec());
        slots.arrived += 1;
        if slots.arrived == self.size {
            // Last arrival performs the reduction.
            let mut sum = vec![0.0f32; buf.len()];
            for b in slots.bufs.iter_mut() {
                let b = b.take().unwrap();
                anyhow::ensure!(b.len() == sum.len(), "all-reduce length mismatch");
                for (s, v) in sum.iter_mut().zip(&b) {
                    *s += v;
                }
            }
            slots.sum = Some(sum);
            slots.arrived = 0;
            slots.generation += 1;
            self.done.notify_all();
        } else {
            let gen = slots.generation;
            while slots.generation == gen {
                slots = self.done.wait(slots).unwrap();
            }
        }
        buf.copy_from_slice(slots.sum.as_ref().unwrap());
        drop(slots);
        // Hold every rank until all have copied out; the next collective's
        // reduction simply overwrites `sum` afterwards.
        self.barrier.wait();
        *self.bytes.lock().unwrap() += (buf.len() * 4) as u64;
        *self.ops.lock().unwrap() += 1;
        Ok(())
    }

    /// All-reduce a [`Tensor`] in place (f32 only).
    pub fn all_reduce_tensor(&self, rank: usize, t: &mut Tensor) -> Result<()> {
        self.all_reduce(rank, t.as_f32_mut()?)
    }

    /// Total bytes all-reduced so far (per-rank counting).
    pub fn bytes_reduced(&self) -> u64 {
        *self.bytes.lock().unwrap()
    }

    pub fn collectives(&self) -> u64 {
        *self.ops.lock().unwrap()
    }
}

/// A P2P pipeline channel endpoint pair (activations or gradients between
/// adjacent stages of one TP rank).
pub struct P2p;

impl P2p {
    /// Bounded channel: backpressure mirrors the finite buffering between
    /// pipeline stages.
    pub fn channel(depth: usize) -> (SyncSender<Tensor>, Receiver<Tensor>) {
        std::sync::mpsc::sync_channel(depth)
    }

    /// Unbounded channel (metrics/loss reporting).
    pub fn unbounded() -> (Sender<Tensor>, Receiver<Tensor>) {
        std::sync::mpsc::channel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn all_reduce_sums_across_ranks() {
        let g = TpGroup::new(4);
        let mut handles = Vec::new();
        for r in 0..4 {
            let g = g.clone();
            handles.push(thread::spawn(move || {
                let mut buf = vec![r as f32; 8];
                g.all_reduce(r, &mut buf).unwrap();
                buf
            }));
        }
        for h in handles {
            let buf = h.join().unwrap();
            assert_eq!(buf, vec![6.0; 8]); // 0+1+2+3
        }
        assert_eq!(g.collectives(), 4); // per-rank counting
    }

    #[test]
    fn repeated_collectives_reuse_group() {
        let g = TpGroup::new(2);
        let mut handles = Vec::new();
        for r in 0..2 {
            let g = g.clone();
            handles.push(thread::spawn(move || {
                let mut out = Vec::new();
                for round in 0..16 {
                    let mut buf = vec![(r + round) as f32; 4];
                    g.all_reduce(r, &mut buf).unwrap();
                    out.push(buf[0]);
                }
                out
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            let want: Vec<f32> = (0..16).map(|round| (2 * round + 1) as f32).collect();
            assert_eq!(out, want);
        }
    }

    #[test]
    fn single_rank_group_is_noop() {
        let g = TpGroup::new(1);
        let mut buf = vec![5.0; 3];
        g.all_reduce(0, &mut buf).unwrap();
        assert_eq!(buf, vec![5.0; 3]);
    }

    #[test]
    fn p2p_channel_transfers_tensors() {
        let (tx, rx) = P2p::channel(2);
        let t = Tensor::f32(vec![1.0, 2.0], &[2]);
        tx.send(t.clone()).unwrap();
        assert_eq!(rx.recv().unwrap(), t);
    }
}
