//! `stp` — leader entrypoint. See `stp help` for subcommands.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match stp::coordinator::run_cli(args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
