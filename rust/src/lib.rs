//! # STP — Synergistic Tensor and Pipeline Parallelism
//!
//! Production-quality reproduction of *"Synergistic Tensor and Pipeline
//! Parallelism"* (NeurIPS 2025). The library is the L3 (rust) layer of a
//! three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the fine-grained
//!   computation units (Pre-Attn, Attn, Pre-MLP, MLP) with fused residuals
//!   (paper Eq. 1–2), built once at compile time.
//! * **L2** — JAX model (`python/compile/model.py`): per-TP-rank forward and
//!   vjp-decomposed backward (activation-grad `B` / weight-grad `W`) of a
//!   Qwen2-style transformer chunk, AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: schedule generators (GPipe, 1F1B, 1F1B-I, ZB-V,
//!   and the paper's STP schedule with braided execution blocks), a
//!   discrete-event cluster simulator that regenerates every table and
//!   figure of the paper's evaluation, a parallelism **auto-planner**
//!   ([`plan`]) that searches (TP, PP, DP) × schedule × microbatch-count
//!   for a GPU budget under a memory cap, and a real multi-threaded,
//!   **backend-abstract** pipeline executor ([`exec`]) with in-process
//!   All-Reduce: the deterministic virtual backend runs in every build
//!   (`stp plan --emit-plan` → `stp train --plan` replays the planner's
//!   winning schedule offline), while the AOT-artifact PJRT backend sits
//!   behind the `pjrt` feature.
//!
//! ## Quick tour
//!
//! ```no_run
//! use stp::model::ModelConfig;
//! use stp::cluster::{ClusterSpec, HardwareProfile, Topology};
//! use stp::schedule::{ScheduleKind, build_schedule};
//! use stp::sim::{CostModel, Simulator};
//!
//! let model = ModelConfig::qwen2_12b();
//! let topo = Topology::new(8, 2, 1); // TP=8, PP=2, DP=1
//! // A uniform pool; try `ClusterSpec::mixed_a800_h20()` for a mixed one.
//! let cluster = ClusterSpec::uniform(HardwareProfile::a800());
//! let sched = build_schedule(ScheduleKind::Stp, &topo, 64);
//! let cost = CostModel::analytic(&model, &topo, &cluster, 6144, 1);
//! let report = Simulator::new(&cost).run(&sched);
//! println!("throughput = {:.2} samples/s", report.throughput());
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to its regenerator.

pub mod bench;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod elastic;
pub mod exec;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod plan;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod trace;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
