//! Event-driven replay of a schedule under a cost model.
//!
//! Each device executes its op list **in order** (the IR is an explicit
//! per-device program); cross-device edges (pipeline activations/gradients)
//! and intra-device structures (activation memory, PCIe offload stream)
//! are resolved during the replay. The output [`SimReport`] carries the
//! iteration time, the TP/PP bubble decomposition and per-device peak
//! memory — the quantities every paper table and figure is built from.
//!
//! Unlike the polling oracle ([`super::reference`]), this core never
//! retries a blocked op: dependencies are **pre-counted** at compile time
//! ([`CompiledSchedule`] — prior op on the device, cross-chunk F/B
//! edges), per-hop P2P costs are resolved once into a [`HopTable`], and
//! the replay is a single ready-queue pass in O(ops). Two planner-facing
//! modes shave the remaining constants: [`Simulator::without_trace`]
//! skips `TraceEvent` collection entirely, and [`Simulator::try_run_in`]
//! reuses a [`SimArena`] so repeated candidate evaluations stop
//! allocating. The golden suite (`tests/sim_equivalence.rs`) pins this
//! core bit-identical to the oracle.

use crate::elastic::{FaultEvent, FaultPlan};
use crate::schedule::{CompiledSchedule, Op, PassKind, Schedule, ScheduleKind, NO_OP};

use super::block::BlockTiming;
use super::cost::{CostModel, HopTable};
use super::reference::{explicit_hop_cost, op_timing};
use super::report::{finalize_report, RunTotals, SimReport, TraceEvent};
use super::{SimError, EXPLICIT_PRODUCER_FRAC};

/// Reusable scratch buffers for [`Simulator::try_run_in`]: the compiled
/// program, dependency counters, the ready queue, per-(chunk, mb) done
/// times, per-device accumulators, the PCIe/offload state and the
/// per-chunk timing memo. One arena per worker thread keeps the
/// planner's no-trace evaluation loop allocation-free after warm-up.
/// (Traced runs are the exception: the returned report takes ownership
/// of the event vec, so that one buffer is allocated per traced run.)
#[derive(Debug, Default)]
pub struct SimArena {
    compiled: CompiledSchedule,
    hops: HopTable,
    n_deps: Vec<u32>,
    ready: Vec<u32>,
    done_f: Vec<f64>,
    done_b: Vec<f64>,
    dev_time: Vec<f64>,
    busy: Vec<f64>,
    compute: Vec<f64>,
    exposed_ar: Vec<f64>,
    mem: Vec<i64>,
    mem_peak: Vec<i64>,
    done_per_dev: Vec<u32>,
    offloaded: Vec<f32>,
    reload_done: Vec<f64>,
    offload_done: Vec<f64>,
    pcie_time: Vec<f64>,
    pcie_busy: Vec<f64>,
    // Per-slot "already released my consumers" flags — only used for
    // duplicate-producer schedules (per-edge dependency counting: the
    // first completion of any producer of a slot releases its consumers
    // exactly once).
    f_emitted: Vec<bool>,
    b_emitted: Vec<bool>,
    // Timing memo (reset per run — the cost model may change between
    // runs): plain passes by (pass kind, chunk), braided blocks by
    // (b_full, f_chunk, b_chunk), F&W braids by (f_chunk, w_chunk).
    timing_plain: Vec<Option<BlockTiming>>,
    timing_braided: Vec<Option<BlockTiming>>,
    timing_braided_fw: Vec<Option<BlockTiming>>,
    events: Vec<TraceEvent>,
}

/// The simulator: replays schedules under a cost model.
pub struct Simulator<'a> {
    cost: &'a CostModel,
    /// Charge P2P sends on the producer's compute stream (the paper notes
    /// STP's explicit pipeline communication "is executed immediately after
    /// computation and cannot be overlapped", §5.2).
    explicit_p2p: Option<bool>,
    /// Collect per-op [`TraceEvent`]s (planning only needs the scalars).
    trace: bool,
    /// Event-time fault injection (DESIGN.md §12). `None` (the default)
    /// keeps the replay bit-identical to the fault-free core: no fault
    /// code path touches a timing unless a fault is actually active.
    faults: Option<FaultPlan>,
}

/// Earliest start implied by the forward pipeline edge of `(c, m)`.
#[inline]
fn f_ready(
    done_f: &[f64],
    n_mb: usize,
    hops: &HopTable,
    edge_frac: f64,
    c: usize,
    m: usize,
) -> f64 {
    if c == 0 {
        0.0
    } else {
        done_f[(c - 1) * n_mb + m] + edge_frac * hops.next[c - 1]
    }
}

/// Earliest start implied by the backward edges of `(c, m)` (own forward
/// plus the gradient arriving from chunk `c + 1`).
#[inline]
#[allow(clippy::too_many_arguments)]
fn b_ready(
    done_f: &[f64],
    done_b: &[f64],
    n_chunks: usize,
    n_mb: usize,
    hops: &HopTable,
    edge_frac: f64,
    c: usize,
    m: usize,
) -> f64 {
    let own = done_f[c * n_mb + m];
    if c + 1 == n_chunks {
        own
    } else {
        own.max(done_b[(c + 1) * n_mb + m] + edge_frac * hops.prev[c + 1])
    }
}

/// Resolve one dependency of `id`; enqueue it once the count hits zero.
#[inline]
fn dec(n_deps: &mut [u32], ready: &mut Vec<u32>, id: u32) {
    if id == NO_OP {
        return;
    }
    let i = id as usize;
    debug_assert!(n_deps[i] > 0, "dependency underflow at op {i}");
    n_deps[i] -= 1;
    if n_deps[i] == 0 {
        ready.push(id);
    }
}

/// Memoized two-stream timing of one op (keyed by chunk ids, so each
/// distinct block shape is timed once per replay instead of once per
/// microbatch).
#[inline]
fn timing_for(
    cost: &CostModel,
    n_chunks: usize,
    plain: &mut [Option<BlockTiming>],
    braided: &mut [Option<BlockTiming>],
    braided_fw: &mut [Option<BlockTiming>],
    op: &Op,
) -> BlockTiming {
    let slot = match *op {
        Op::Pass { kind, chunk, .. } => {
            let k = match kind {
                PassKind::F => 0,
                PassKind::B => 1,
                PassKind::W => 2,
                PassKind::BFull => 3,
            };
            &mut plain[k * n_chunks + chunk]
        }
        Op::Braided { f_chunk, b_chunk, b_full, .. } => {
            &mut braided[((b_full as usize) * n_chunks + f_chunk) * n_chunks + b_chunk]
        }
        Op::BraidedFW { f_chunk, w_chunk, .. } => &mut braided_fw[f_chunk * n_chunks + w_chunk],
        Op::Offload { .. } | Op::Reload { .. } => return op_timing(cost, op),
    };
    *slot.get_or_insert_with(|| op_timing(cost, op))
}

impl<'a> Simulator<'a> {
    pub fn new(cost: &'a CostModel) -> Self {
        Simulator { cost, explicit_p2p: None, trace: true, faults: None }
    }

    /// Inject a deterministic fault plan into the replay. A dead device
    /// executes nothing from its death time onward, so its surviving
    /// consumers starve and the replay surfaces the loss through the
    /// existing stuck-device [`SimError`] — that error *is* the
    /// detection signal. Stragglers stretch op durations (event-time)
    /// from their onset; an empty plan changes nothing, bit-for-bit.
    /// The replay models a single DP replica, so only replica-0 events
    /// apply; events aimed at other replicas are the executor's concern.
    pub fn with_faults(mut self, f: FaultPlan) -> Self {
        self.faults = Some(f);
        self
    }

    /// Override the explicit-P2P rule (default: STP-family schedules only).
    pub fn with_explicit_p2p(mut self, v: bool) -> Self {
        self.explicit_p2p = Some(v);
        self
    }

    /// Planning mode: skip [`TraceEvent`] collection (the report's
    /// `events` come back empty; every scalar is unchanged).
    pub fn without_trace(mut self) -> Self {
        self.trace = false;
        self
    }

    /// Replay `s` and produce the report, panicking on deadlock (the
    /// historical behavior; prefer [`Simulator::try_run`]).
    pub fn run(&self, s: &Schedule) -> SimReport {
        match self.try_run(s) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// Replay `s`; a stuck device yields a [`SimError`] instead of a
    /// panic so one malformed candidate cannot abort a planner run.
    pub fn try_run(&self, s: &Schedule) -> Result<SimReport, SimError> {
        let mut arena = SimArena::default();
        self.try_run_in(s, &mut arena)
    }

    /// [`Simulator::try_run`] against caller-owned scratch buffers.
    pub fn try_run_in(&self, s: &Schedule, arena: &mut SimArena) -> Result<SimReport, SimError> {
        let explicit_p2p = self.explicit_p2p.unwrap_or(matches!(
            s.kind,
            ScheduleKind::Stp | ScheduleKind::StpMemEff | ScheduleKind::StpOffload
        ));
        let edge_frac = if explicit_p2p { 1.0 - EXPLICIT_PRODUCER_FRAC } else { 1.0 };

        // Disjoint borrows of every arena buffer.
        let SimArena {
            compiled,
            hops,
            n_deps,
            ready,
            done_f,
            done_b,
            dev_time,
            busy,
            compute,
            exposed_ar,
            mem,
            mem_peak,
            done_per_dev,
            offloaded,
            reload_done,
            offload_done,
            pcie_time,
            pcie_busy,
            f_emitted,
            b_emitted,
            timing_plain,
            timing_braided,
            timing_braided_fw,
            events,
        } = arena;

        compiled.compile_from(s);
        self.cost.hop_table_into(s, hops);
        let c: &CompiledSchedule = compiled;
        let n_chunks = c.n_chunks;
        let n_mb = c.n_mb;
        let n_dev = c.n_dev();
        let n_ops = c.ops.len();
        let slots = n_chunks * n_mb;
        let w_frac = self.cost.w_frac;

        n_deps.clear();
        n_deps.extend_from_slice(&c.base_deps);
        ready.clear();
        reset(done_f, slots, f64::NAN);
        reset(done_b, slots, f64::NAN);
        reset(dev_time, n_dev, 0.0);
        reset(busy, n_dev, 0.0);
        reset(compute, n_dev, 0.0);
        reset(exposed_ar, n_dev, 0.0);
        reset(mem, n_dev, 0i64);
        reset(mem_peak, n_dev, 0i64);
        reset(done_per_dev, n_dev, 0u32);
        reset(offloaded, slots, 0f32);
        reset(reload_done, slots, 0.0);
        reset(offload_done, slots, 0.0);
        reset(pcie_time, n_dev, 0.0);
        reset(pcie_busy, n_dev, 0.0);
        let unique = c.unique_producers;
        if !unique {
            reset(f_emitted, slots, false);
            reset(b_emitted, slots, false);
        }
        reset(timing_plain, 4 * n_chunks, None);
        reset(timing_braided, 2 * n_chunks * n_chunks, None);
        reset(timing_braided_fw, n_chunks * n_chunks, None);
        events.clear();
        if self.trace {
            // The report takes ownership of the events at the end, so a
            // traced run cannot amortize this buffer across runs — make
            // it one exact allocation instead of repeated growth.
            events.reserve_exact(n_ops);
        }

        // Fold the fault plan into per-device views. Allocates only when
        // faults are injected — the planner's hot no-fault loop stays
        // arena-only. Event steps are irrelevant here (one-iteration
        // replay); the wall-clock fields place each event in time.
        let fault_view = self.faults.as_ref().map(|f| {
            let mut dead_at = vec![f64::INFINITY; n_dev];
            let mut slow: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_dev];
            for ev in &f.events {
                match *ev {
                    FaultEvent::DeadRank { stage, replica, at_secs, .. }
                        if stage < n_dev && replica == 0 =>
                    {
                        dead_at[stage] = dead_at[stage].min(at_secs);
                    }
                    FaultEvent::Straggler { stage, replica, slowdown, from_secs, .. }
                        if stage < n_dev && replica == 0 =>
                    {
                        slow[stage].push((from_secs, slowdown));
                    }
                    _ => {}
                }
            }
            (dead_at, slow)
        });

        for (j, &d) in n_deps.iter().enumerate() {
            if d == 0 {
                ready.push(j as u32);
            }
        }

        let mut remaining = n_ops;
        while let Some(id) = ready.pop() {
            let j = id as usize;
            let d = c.op_dev[j] as usize;
            let op = c.ops[j];

            // --- readiness (all producers have completed) ----------------
            let ready_t = match op {
                Op::Pass { kind: PassKind::F, chunk, mb } => {
                    f_ready(done_f, n_mb, hops, edge_frac, chunk, mb)
                }
                Op::Pass { kind: PassKind::B | PassKind::BFull, chunk, mb } => {
                    b_ready(done_f, done_b, n_chunks, n_mb, hops, edge_frac, chunk, mb)
                        .max(reload_done[chunk * n_mb + mb])
                }
                Op::Pass { kind: PassKind::W, .. } => 0.0, // B precedes in-order
                Op::Braided { f_chunk, f_mb, b_chunk, b_mb, .. } => {
                    let a = f_ready(done_f, n_mb, hops, edge_frac, f_chunk, f_mb);
                    let b =
                        b_ready(done_f, done_b, n_chunks, n_mb, hops, edge_frac, b_chunk, b_mb);
                    a.max(b).max(reload_done[b_chunk * n_mb + b_mb])
                }
                Op::BraidedFW { f_chunk, f_mb, .. } => {
                    f_ready(done_f, n_mb, hops, edge_frac, f_chunk, f_mb)
                }
                Op::Offload { .. } | Op::Reload { .. } => 0.0,
            };

            // --- duration & bookkeeping ---------------------------------
            let start = dev_time[d].max(ready_t);
            if let Some((dead_at, _)) = &fault_view {
                if start >= dead_at[d] {
                    // Device lost before this op could start: it never
                    // runs, its consumers are never released, and the
                    // stuck-device scan below reports the casualty.
                    continue;
                }
            }
            match op {
                Op::Offload { chunk, mb, ratio } => {
                    // Runs on the PCIe stream in parallel with compute;
                    // clamp the ratio so the transfer fits under one
                    // forward (paper §4.4: T_o < T_F).
                    let t_f = self.cost.chunks[chunk].t_f();
                    let full = self.cost.offload_secs(chunk, 1.0);
                    let eff = if full > 0.0 {
                        (ratio as f64).min(t_f / full).max(0.0) as f32
                    } else {
                        ratio
                    };
                    let dur = self.cost.offload_secs(chunk, eff);
                    let t0 = pcie_time[d].max(dev_time[d]);
                    pcie_time[d] = t0 + dur;
                    pcie_busy[d] += dur;
                    offload_done[chunk * n_mb + mb] = pcie_time[d];
                    offloaded[chunk * n_mb + mb] = eff;
                    // Memory freed once the transfer completes;
                    // conservatively count it as freed at completion by
                    // subtracting now (peak sampled at op starts).
                    mem[d] -= (self.cost.act_bytes[chunk] as f64 * eff as f64) as i64;
                }
                Op::Reload { chunk, mb } => {
                    let eff = offloaded[chunk * n_mb + mb];
                    let dur = self.cost.offload_secs(chunk, eff);
                    let t0 =
                        pcie_time[d].max(dev_time[d]).max(offload_done[chunk * n_mb + mb]);
                    pcie_time[d] = t0 + dur;
                    pcie_busy[d] += dur;
                    reload_done[chunk * n_mb + mb] = pcie_time[d];
                    mem[d] += (self.cost.act_bytes[chunk] as f64 * eff as f64) as i64;
                    mem_peak[d] = mem_peak[d].max(mem[d]);
                    // Data is back on device: the backward frees it like
                    // any resident activation.
                    offloaded[chunk * n_mb + mb] = 0.0;
                }
                _ => {
                    let timing = timing_for(
                        self.cost,
                        n_chunks,
                        timing_plain,
                        timing_braided,
                        timing_braided_fw,
                        &op,
                    );
                    // Active stragglers stretch this op (compound, like
                    // the executor's `straggler_factor`). Scale only when
                    // a fault is live so the `None` path stays bit-exact.
                    let mut dur = timing.duration;
                    let mut f_off = timing.f_done;
                    let mut b_off = timing.b_done;
                    if let Some((_, slow)) = &fault_view {
                        let mut factor = 1.0f64;
                        for &(from, s) in &slow[d] {
                            if start >= from {
                                factor *= s.max(1.0);
                            }
                        }
                        if factor > 1.0 {
                            dur *= factor;
                            f_off *= factor;
                            b_off *= factor;
                        }
                    }
                    let mut finish = start + dur;

                    // Explicit (non-overlapped) pipeline sends: the
                    // producer's compute stream pays the hop right after
                    // the op (STP-family).
                    let mut hop = 0.0;
                    if explicit_p2p {
                        hop = explicit_hop_cost(hops, n_chunks, &op);
                        finish += hop;
                    }

                    dev_time[d] = finish;
                    busy[d] += finish - start;
                    compute[d] += timing.compute;
                    exposed_ar[d] += timing.exposed_ar;
                    if self.trace {
                        events.push(TraceEvent { device: d, op, start, end: finish });
                    }

                    // Completion bookkeeping + memory events. Inside a
                    // braided block each direction completes at its own
                    // sub-stream time — a braid does not serialize the
                    // pipeline chain behind its full duration.
                    if let Some((cc, m)) = op.forward_part() {
                        done_f[cc * n_mb + m] = start + f_off + hop;
                        mem[d] += self.cost.act_bytes[cc] as i64;
                        mem_peak[d] = mem_peak[d].max(mem[d]);
                    }
                    if let Some((cc, m)) = op.backward_part() {
                        done_b[cc * n_mb + m] = start + b_off + hop;
                        let act = self.cost.act_bytes[cc] as f64;
                        let kept = offloaded[cc * n_mb + m] as f64; // already subtracted
                        if op.weight_part() == Some((cc, m)) {
                            mem[d] -= (act * (1.0 - kept)) as i64;
                        } else {
                            mem[d] -= (act * (1.0 - w_frac - kept).max(0.0)) as i64;
                        }
                    }
                    if let Some((cc, m)) = op.weight_part() {
                        if op.backward_part() != Some((cc, m)) {
                            // Deferred W frees the retained weight-grad inputs.
                            let _ = m;
                            mem[d] -= (self.cost.act_bytes[cc] as f64 * w_frac) as i64;
                        }
                    }
                }
            }

            // --- completion: release consumers, then the program
            // successor. The successor is released *last* so the LIFO
            // ready stack pops it first — the same greedy
            // advance-this-device-as-far-as-possible order the polling
            // oracle's rescan loop produces, which is what keeps the
            // done-time overwrites of duplicate-producer schedules
            // bit-aligned with it.
            remaining -= 1;
            done_per_dev[d] += 1;
            if unique {
                // Single producer per slot: its consumers are the next
                // chunk's forward producer and the slot's own backward
                // producer, resolved through the producer tables.
                if let Some((cc, m)) = op.forward_part() {
                    if cc + 1 < n_chunks {
                        dec(n_deps, ready, c.f_producer[(cc + 1) * n_mb + m]);
                    }
                    dec(n_deps, ready, c.b_producer[cc * n_mb + m]);
                }
                if let Some((cc, m)) = op.backward_part() {
                    if cc > 0 {
                        dec(n_deps, ready, c.b_producer[(cc - 1) * n_mb + m]);
                    }
                }
            } else {
                // Duplicate producers (recomputation-style schedules):
                // per-edge counting through the CSR consumer lists. The
                // first producer to complete releases the slot's
                // consumers; later producers only refresh the done time —
                // exactly the polling oracle's readiness rule.
                if let Some((cc, m)) = op.forward_part() {
                    let slot = cc * n_mb + m;
                    if !f_emitted[slot] {
                        f_emitted[slot] = true;
                        for &k in c.f_consumers(slot) {
                            dec(n_deps, ready, k);
                        }
                    }
                }
                if let Some((cc, m)) = op.backward_part() {
                    let slot = cc * n_mb + m;
                    if !b_emitted[slot] {
                        b_emitted[slot] = true;
                        for &k in c.b_consumers(slot) {
                            dec(n_deps, ready, k);
                        }
                    }
                }
            }
            let next = id + 1;
            if next < c.dev_start[d + 1] {
                dec(n_deps, ready, next);
            }
        }

        // Unexecuted ops mean an illegal schedule — report the first
        // stuck device (same contract as the polling oracle).
        if remaining > 0 {
            for d in 0..n_dev {
                let total = (c.dev_start[d + 1] - c.dev_start[d]) as usize;
                let done = done_per_dev[d] as usize;
                if done < total {
                    return Err(SimError {
                        device: d,
                        op_index: done,
                        ops_left: total - done,
                        op: Some(c.ops[c.dev_start[d] as usize + done]),
                    });
                }
            }
            unreachable!("remaining ops but every device complete");
        }

        Ok(finalize_report(
            self.cost,
            s.kind,
            s.n_mb,
            RunTotals {
                dev_time: dev_time.as_slice(),
                busy: busy.as_slice(),
                compute: compute.as_slice(),
                exposed_ar: exposed_ar.as_slice(),
                mem_peak: mem_peak.as_slice(),
                pcie_busy: pcie_busy.as_slice(),
            },
            if self.trace { std::mem::take(events) } else { Vec::new() },
        ))
    }
}

/// `clear` + `resize` so every element is reinitialized to `v`.
#[inline]
fn reset<T: Clone>(buf: &mut Vec<T>, len: usize, v: T) {
    buf.clear();
    buf.resize(len, v);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, HardwareProfile, Topology};
    use crate::model::ModelConfig;
    use crate::schedule::{build_schedule, Placement, ScheduleKind};

    fn setup(tp: usize, pp: usize) -> (CostModel, Topology) {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(tp, pp, 1);
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        (CostModel::analytic(&m, &topo, &cluster, 3072, 1), topo)
    }

    #[test]
    fn all_schedules_simulate_without_deadlock() {
        let (cost, topo) = setup(4, 4);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, 8);
            let r = Simulator::new(&cost).run(&s);
            assert!(r.iteration_secs > 0.0, "{kind:?}");
            assert!(r.iteration_secs.is_finite(), "{kind:?}");
        }
    }

    #[test]
    fn stp_beats_baselines_at_tp8() {
        // The headline claim (Fig. 7 right): at TP=8/PP=2, STP > 1F1B-I, ZB-V.
        let (cost, topo) = setup(8, 2);
        let time = |k| {
            let s = build_schedule(k, &topo, 64);
            Simulator::new(&cost).run(&s).iteration_secs
        };
        let ours = time(ScheduleKind::Stp);
        let i1f1b = time(ScheduleKind::OneF1BInterleaved);
        let zbv = time(ScheduleKind::ZbV);
        assert!(ours < i1f1b, "STP {ours:.4}s !< 1F1B-I {i1f1b:.4}s");
        assert!(ours < zbv, "STP {ours:.4}s !< ZB-V {zbv:.4}s");
    }

    #[test]
    fn throughput_improvement_in_paper_range() {
        // Paper: up to ~12% over 1F1B-I on LLMs at TP=8, seq 6144, PP=2.
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(8, 2, 1);
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        let cost = CostModel::analytic(&m, &topo, &cluster, 6144, 1);
        let time = |k| {
            let s = build_schedule(k, &topo, 64);
            Simulator::new(&cost).run(&s).iteration_secs
        };
        let gain = time(ScheduleKind::OneF1BInterleaved) / time(ScheduleKind::Stp) - 1.0;
        assert!(
            (0.02..0.35).contains(&gain),
            "STP over 1F1B-I gain {:.1}% outside plausible band",
            gain * 100.0
        );
    }

    #[test]
    fn memory_ordering_matches_table1() {
        // Table 1 (peak activation): ZB-V (2p) < 1F1B-I (3p-2) < Ours (3p),
        // comparing the hottest device of each schedule. Chunk sizes are
        // non-uniform (last stage two layers short), so allow 1F1B-I ≈
        // Ours within one M_a, but ZB-V must be strictly lowest.
        let (cost, topo) = setup(4, 4);
        let peak = |k| {
            let s = build_schedule(k, &topo, 16);
            let r = Simulator::new(&cost).run(&s);
            r.devices.iter().map(|d| d.peak_activation_bytes).max().unwrap()
        };
        let zbv = peak(ScheduleKind::ZbV);
        let i = peak(ScheduleKind::OneF1BInterleaved);
        let ours = peak(ScheduleKind::Stp);
        let ma = *cost.act_bytes.iter().max().unwrap();
        assert!(zbv < i, "ZB-V {zbv} !< 1F1B-I {i}");
        assert!(ours + ma > i, "Ours {ours} not within one M_a above 1F1B-I {i}");
        assert!(ours > zbv, "Ours {ours} !> ZB-V {zbv}");
    }

    #[test]
    fn offload_reduces_peak_memory() {
        let (cost, topo) = setup(4, 4);
        let peak = |k| {
            let s = build_schedule(k, &topo, 16);
            let r = Simulator::new(&cost).run(&s);
            r.devices.iter().map(|d| d.peak_activation_bytes).max().unwrap()
        };
        let std = peak(ScheduleKind::Stp);
        let off = peak(ScheduleKind::StpOffload);
        assert!(off < std, "offload {off} !< standard {std}");
        // Paper §5.4: 10–19.2% peak reduction. Allow a wide band.
        let red = 1.0 - off as f64 / std as f64;
        assert!(red > 0.05, "only {:.1}% reduction", red * 100.0);
    }

    #[test]
    fn more_microbatches_amortize_bubbles() {
        let (cost, topo) = setup(4, 4);
        let thr = |m| {
            let s = build_schedule(ScheduleKind::Stp, &topo, m);
            let r = Simulator::new(&cost).run(&s);
            r.throughput()
        };
        assert!(thr(64) < thr(192) * 1.02);
    }

    #[test]
    fn no_trace_mode_matches_traced_scalars() {
        let (cost, topo) = setup(4, 4);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, 12);
            let traced = Simulator::new(&cost).run(&s);
            let bare = Simulator::new(&cost).without_trace().run(&s);
            assert!(bare.events.is_empty(), "{kind:?}");
            assert!(!traced.events.is_empty(), "{kind:?}");
            assert_eq!(
                traced.iteration_secs.to_bits(),
                bare.iteration_secs.to_bits(),
                "{kind:?}"
            );
            for (a, b) in traced.devices.iter().zip(&bare.devices) {
                assert_eq!(a.busy.to_bits(), b.busy.to_bits(), "{kind:?}");
                assert_eq!(a.exposed_ar.to_bits(), b.exposed_ar.to_bits(), "{kind:?}");
                assert_eq!(a.peak_activation_bytes, b.peak_activation_bytes, "{kind:?}");
            }
        }
    }

    #[test]
    fn arena_reuse_is_deterministic_across_schedules() {
        let (cost, topo) = setup(4, 4);
        let mut arena = SimArena::default();
        // Interleave kinds so every buffer is resized up and down.
        for &m in &[16usize, 8, 24] {
            for kind in ScheduleKind::all() {
                let s = build_schedule(kind, &topo, m);
                let reused = Simulator::new(&cost)
                    .without_trace()
                    .try_run_in(&s, &mut arena)
                    .unwrap();
                let fresh = Simulator::new(&cost).without_trace().try_run(&s).unwrap();
                assert_eq!(
                    reused.iteration_secs.to_bits(),
                    fresh.iteration_secs.to_bits(),
                    "{kind:?} m={m}"
                );
                assert_eq!(
                    reused.devices.iter().map(|d| d.peak_activation_bytes).max(),
                    fresh.devices.iter().map(|d| d.peak_activation_bytes).max(),
                    "{kind:?} m={m}"
                );
            }
        }
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_to_no_faults() {
        // Compiling the fault machinery in must not perturb a single
        // timing: `with_faults(none)` and no faults at all agree to the
        // bit on every schedule kind.
        let (cost, topo) = setup(4, 4);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, 12);
            let plain = Simulator::new(&cost).run(&s);
            let faulted = Simulator::new(&cost).with_faults(FaultPlan::none()).run(&s);
            assert_eq!(
                plain.iteration_secs.to_bits(),
                faulted.iteration_secs.to_bits(),
                "{kind:?}"
            );
            for (a, b) in plain.devices.iter().zip(&faulted.devices) {
                assert_eq!(a.busy.to_bits(), b.busy.to_bits(), "{kind:?}");
                assert_eq!(a.peak_activation_bytes, b.peak_activation_bytes, "{kind:?}");
            }
        }
    }

    #[test]
    fn straggler_stretches_the_iteration() {
        let (cost, topo) = setup(4, 4);
        let s = build_schedule(ScheduleKind::Stp, &topo, 8);
        let base = Simulator::new(&cost).run(&s).iteration_secs;
        let mut faults = FaultPlan::none();
        faults.events.push(FaultEvent::Straggler {
            step: 0,
            stage: 1,
            replica: 0,
            slowdown: 1.5,
            from_secs: 0.0,
        });
        let slow = Simulator::new(&cost).with_faults(faults).run(&s).iteration_secs;
        // The pipeline serializes behind the slow stage, but the other
        // stages' work is unchanged — strictly slower, less than 1.5×.
        assert!(slow > base, "straggler {slow:.4}s !> baseline {base:.4}s");
        assert!(slow < base * 1.5, "straggler {slow:.4}s !< 1.5x baseline {base:.4}s");
    }

    #[test]
    fn dead_device_surfaces_as_a_stuck_replay() {
        let (cost, topo) = setup(4, 4);
        let s = build_schedule(ScheduleKind::Stp, &topo, 8);
        let base = Simulator::new(&cost).run(&s).iteration_secs;
        let mut faults = FaultPlan::none();
        // Kill stage 1 halfway through the iteration: everything it had
        // not started stays unexecuted and its peers starve.
        faults.events.push(FaultEvent::DeadRank {
            step: 0,
            stage: 1,
            replica: 0,
            at_secs: base / 2.0,
        });
        let err = Simulator::new(&cost).with_faults(faults).try_run(&s).unwrap_err();
        assert!(err.ops_left > 0);
    }

    #[test]
    fn malformed_schedule_is_an_error_not_a_panic() {
        let (cost, topo) = setup(1, 2);
        // A backward with no forward anywhere: device 0 can never start it.
        let s = crate::schedule::Schedule {
            kind: ScheduleKind::Stp,
            topo,
            n_mb: 1,
            placement: Placement::VShape,
            devices: vec![vec![crate::schedule::Op::b(0, 0)], vec![]],
        };
        let err = Simulator::new(&cost).try_run(&s).unwrap_err();
        assert_eq!(err.device, 0);
        assert_eq!(err.op_index, 0);
        assert_eq!(err.ops_left, 1);
    }
}
