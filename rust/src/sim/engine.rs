//! Discrete-event replay of a schedule under a cost model.
//!
//! Each device executes its op list **in order** (the IR is an explicit
//! per-device program); cross-device edges (pipeline activations/gradients)
//! and intra-device structures (activation memory, PCIe offload stream)
//! are resolved during the replay. The output [`SimReport`] carries the
//! iteration time, the TP/PP bubble decomposition and per-device peak
//! memory — the quantities every paper table and figure is built from.

use crate::schedule::{Op, PassKind, Schedule, ScheduleKind};

use super::cost::CostModel;
use super::report::{DeviceReport, SimReport};

/// Fraction of a pipeline hop that blocks the producer's compute stream
/// under STP's explicit (non-overlapped-launch) P2P communication; the
/// remainder is pure link time that only delays the consumer.
const EXPLICIT_PRODUCER_FRAC: f64 = 0.5;

/// The simulator: replays schedules under a cost model.
pub struct Simulator<'a> {
    cost: &'a CostModel,
    /// Charge P2P sends on the producer's compute stream (the paper notes
    /// STP's explicit pipeline communication "is executed immediately after
    /// computation and cannot be overlapped", §5.2).
    explicit_p2p: Option<bool>,
}

impl<'a> Simulator<'a> {
    pub fn new(cost: &'a CostModel) -> Self {
        Simulator { cost, explicit_p2p: None }
    }

    /// Override the explicit-P2P rule (default: STP-family schedules only).
    pub fn with_explicit_p2p(mut self, v: bool) -> Self {
        self.explicit_p2p = Some(v);
        self
    }

    /// Replay `s` and produce the report.
    pub fn run(&self, s: &Schedule) -> SimReport {
        let n_chunks = s.n_chunks();
        let n_dev = s.devices.len();
        let explicit_p2p = self.explicit_p2p.unwrap_or(matches!(
            s.kind,
            ScheduleKind::Stp | ScheduleKind::StpMemEff | ScheduleKind::StpOffload
        ));

        let mut events: Vec<super::report::TraceEvent> = Vec::with_capacity(s.num_ops());
        let mut done_f = vec![vec![f64::NAN; s.n_mb]; n_chunks];
        let mut done_b = vec![vec![f64::NAN; s.n_mb]; n_chunks];
        let mut cursor = vec![0usize; n_dev];
        let mut dev_time = vec![0.0f64; n_dev];
        let mut busy = vec![0.0f64; n_dev];
        let mut exposed_ar = vec![0.0f64; n_dev];
        let mut compute_time = vec![0.0f64; n_dev];

        // Memory tracking (bytes of live activations per device).
        let mut mem = vec![0i64; n_dev];
        let mut mem_peak = vec![0i64; n_dev];
        // Offloaded fraction per (chunk, mb): ratio actually moved to host.
        let mut offloaded = vec![vec![0f32; s.n_mb]; n_chunks];
        // PCIe stream frontier and reload-finish gate per (chunk, mb).
        let mut pcie_time = vec![0.0f64; n_dev];
        let mut reload_done = vec![vec![0.0f64; s.n_mb]; n_chunks];
        let mut offload_done = vec![vec![0.0f64; s.n_mb]; n_chunks];
        let mut pcie_busy = vec![0.0f64; n_dev];

        let dev_of = |c: usize| s.device_of(c);
        let w_frac = self.cost.w_frac;

        loop {
            let mut advanced = false;
            for d in 0..n_dev {
                while cursor[d] < s.devices[d].len() {
                    let op = s.devices[d][cursor[d]];
                    // --- readiness ---------------------------------------
                    // STP's explicit sends block the producer's compute
                    // stream for the launch + part of the DMA (charged in
                    // `explicit_hop_cost`); the rest of the transfer rides
                    // the link and delays only the consumer edge.
                    let edge_frac = if explicit_p2p { 1.0 - EXPLICIT_PRODUCER_FRAC } else { 1.0 };
                    let f_ready = |c: usize, m: usize, done_f: &Vec<Vec<f64>>| -> Option<f64> {
                        if c == 0 {
                            Some(0.0)
                        } else {
                            let t = done_f[c - 1][m];
                            if t.is_nan() {
                                None
                            } else {
                                Some(t + edge_frac * self.cost.p2p_secs(dev_of(c - 1), dev_of(c)))
                            }
                        }
                    };
                    let b_ready = |c: usize, m: usize, done_f: &Vec<Vec<f64>>, done_b: &Vec<Vec<f64>>| -> Option<f64> {
                        let own = done_f[c][m];
                        if own.is_nan() {
                            return None;
                        }
                        if c + 1 == n_chunks {
                            Some(own)
                        } else {
                            let t = done_b[c + 1][m];
                            if t.is_nan() {
                                None
                            } else {
                                Some(own.max(t + edge_frac * self.cost.p2p_secs(dev_of(c + 1), dev_of(c))))
                            }
                        }
                    };

                    let ready: Option<f64> = match op {
                        Op::Pass { kind: PassKind::F, chunk, mb } => f_ready(chunk, mb, &done_f),
                        Op::Pass { kind: PassKind::B | PassKind::BFull, chunk, mb } => {
                            b_ready(chunk, mb, &done_f, &done_b)
                                .map(|t| t.max(reload_done[chunk][mb]))
                        }
                        Op::Pass { kind: PassKind::W, .. } => Some(0.0), // B precedes in-order
                        Op::Braided { f_chunk, f_mb, b_chunk, b_mb, .. } => {
                            match (
                                f_ready(f_chunk, f_mb, &done_f),
                                b_ready(b_chunk, b_mb, &done_f, &done_b),
                            ) {
                                (Some(a), Some(b)) => {
                                    Some(a.max(b).max(reload_done[b_chunk][b_mb]))
                                }
                                _ => None,
                            }
                        }
                        Op::BraidedFW { f_chunk, f_mb, .. } => f_ready(f_chunk, f_mb, &done_f),
                        Op::Offload { .. } | Op::Reload { .. } => Some(0.0),
                    };
                    let Some(ready) = ready else { break };

                    // --- duration & bookkeeping --------------------------
                    let start = dev_time[d].max(ready);
                    match op {
                        Op::Offload { chunk, mb, ratio } => {
                            // Runs on the PCIe stream in parallel with
                            // compute; clamp the ratio so the transfer fits
                            // under one forward (paper §4.4: T_o < T_F).
                            let t_f = self.cost.chunks[chunk].t_f();
                            let full = self.cost.offload_secs(chunk, 1.0);
                            let eff = if full > 0.0 {
                                (ratio as f64).min(t_f / full).max(0.0) as f32
                            } else {
                                ratio
                            };
                            let dur = self.cost.offload_secs(chunk, eff);
                            let t0 = pcie_time[d].max(dev_time[d]);
                            pcie_time[d] = t0 + dur;
                            pcie_busy[d] += dur;
                            offload_done[chunk][mb] = pcie_time[d];
                            offloaded[chunk][mb] = eff;
                            // Memory freed once the transfer completes;
                            // conservatively count it as freed at completion
                            // by subtracting now (peak sampled at op starts).
                            mem[d] -= (self.cost.act_bytes[chunk] as f64 * eff as f64) as i64;
                            cursor[d] += 1;
                            advanced = true;
                            continue;
                        }
                        Op::Reload { chunk, mb } => {
                            let eff = offloaded[chunk][mb];
                            let dur = self.cost.offload_secs(chunk, eff);
                            let t0 = pcie_time[d].max(dev_time[d]).max(offload_done[chunk][mb]);
                            pcie_time[d] = t0 + dur;
                            pcie_busy[d] += dur;
                            reload_done[chunk][mb] = pcie_time[d];
                            mem[d] += (self.cost.act_bytes[chunk] as f64 * eff as f64) as i64;
                            mem_peak[d] = mem_peak[d].max(mem[d]);
                            // Data is back on device: the backward frees it
                            // like any resident activation.
                            offloaded[chunk][mb] = 0.0;
                            cursor[d] += 1;
                            advanced = true;
                            continue;
                        }
                        _ => {}
                    }

                    let timing = self.op_timing(&op);
                    let mut finish = start + timing.duration;

                    // Explicit (non-overlapped) pipeline sends: the
                    // producer's compute stream pays the hop right after
                    // the op (STP-family).
                    let mut hop = 0.0;
                    if explicit_p2p {
                        hop = self.explicit_hop_cost(s, &op);
                        finish += hop;
                    }

                    dev_time[d] = finish;
                    busy[d] += finish - start;
                    compute_time[d] += timing.compute;
                    exposed_ar[d] += timing.exposed_ar;
                    events.push(super::report::TraceEvent { device: d, op, start, end: finish });

                    // Completion bookkeeping + memory events. Inside a
                    // braided block each direction completes at its own
                    // sub-stream time — a braid does not serialize the
                    // pipeline chain behind its full duration.
                    if let Some((c, m)) = op.forward_part() {
                        done_f[c][m] = start + timing.f_done + hop;
                        mem[d] += self.cost.act_bytes[c] as i64;
                        mem_peak[d] = mem_peak[d].max(mem[d]);
                    }
                    if let Some((c, m)) = op.backward_part() {
                        done_b[c][m] = start + timing.b_done + hop;
                        let act = self.cost.act_bytes[c] as f64;
                        let kept = offloaded[c][m] as f64; // already subtracted
                        if op.weight_part() == Some((c, m)) {
                            mem[d] -= (act * (1.0 - kept)) as i64;
                        } else {
                            mem[d] -= (act * (1.0 - w_frac - kept).max(0.0)) as i64;
                        }
                    }
                    if let Some((c, m)) = op.weight_part() {
                        if op.backward_part() != Some((c, m)) {
                            // Deferred W frees the retained weight-grad inputs.
                            let _ = m;
                            mem[d] -= (self.cost.act_bytes[c] as f64 * w_frac) as i64;
                        }
                    }
                    cursor[d] += 1;
                    advanced = true;
                }
            }
            if !advanced {
                break;
            }
        }

        // Any stuck device means an illegal schedule — surface loudly.
        for d in 0..n_dev {
            assert!(
                cursor[d] == s.devices[d].len(),
                "simulator deadlock: device {d} stuck at op {:?} ({}/{} ops)",
                s.devices[d].get(cursor[d]),
                cursor[d],
                s.devices[d].len()
            );
        }

        let iteration = dev_time.iter().cloned().fold(0.0, f64::max);
        let devices: Vec<DeviceReport> = (0..n_dev)
            .map(|d| {
                let hw = self.cost.dev_profile(d);
                DeviceReport {
                    busy: busy[d],
                    compute: compute_time[d],
                    exposed_ar: exposed_ar[d],
                    idle: iteration - busy[d],
                    peak_activation_bytes: mem_peak[d].max(0) as usize,
                    pcie_busy: pcie_busy[d],
                    mem_capacity_bytes: (hw.mem_gib * (1u64 << 30) as f64) as usize,
                    hw_name: hw.name.clone(),
                }
            })
            .collect();

        // Aggregate peak FLOPs over the whole job: each PP rank is a
        // TP×CP group replicated DP times; sum per *group* so a uniform
        // pool reduces to the old `world_size × per-device peak` product.
        let topo = &self.cost.topo;
        let ranks_per_group =
            self.cost.view.ranks_per_group(self.cost.cluster.groups.len());
        let aggregate_peak_flops: f64 = ranks_per_group
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(g, &n)| {
                let gpus = n * topo.tp * topo.cp * topo.dp;
                gpus as f64 * (self.cost.cluster.groups[g].hw.bf16_tflops * 1e12)
            })
            .sum();

        SimReport {
            kind: s.kind,
            iteration_secs: iteration,
            devices,
            events,
            n_mb: s.n_mb,
            mb_size: self.cost.mb_size,
            static_bytes: self.cost.static_bytes,
            world_size: self.cost.topo.world_size(),
            aggregate_peak_flops,
            model_flops_per_sample: self.cost.model_flops_per_sample,
        }
    }

    /// Two-stream timing of one op.
    fn op_timing(&self, op: &Op) -> super::block::BlockTiming {
        let ch = &self.cost.chunks;
        match *op {
            Op::Pass { kind: PassKind::F, chunk, .. } => ch[chunk].time_f(),
            Op::Pass { kind: PassKind::B, chunk, .. } => ch[chunk].time_b(),
            Op::Pass { kind: PassKind::W, chunk, .. } => ch[chunk].time_w(),
            Op::Pass { kind: PassKind::BFull, chunk, .. } => ch[chunk].time_b_full(),
            Op::Braided { f_chunk, b_chunk, b_full, .. } => {
                ch[f_chunk].time_braided(&ch[b_chunk], b_full)
            }
            Op::BraidedFW { f_chunk, w_chunk, .. } => ch[f_chunk].time_braided_fw(&ch[w_chunk]),
            Op::Offload { .. } | Op::Reload { .. } => super::block::BlockTiming {
                duration: 0.0,
                compute: 0.0,
                exposed_ar: 0.0,
                f_done: 0.0,
                b_done: 0.0,
            },
        }
    }

    /// Cost of the explicit pipeline sends an op performs (STP-family):
    /// the producer's compute stream is blocked for the launch plus the
    /// head of the DMA.
    fn explicit_hop_cost(&self, s: &Schedule, op: &Op) -> f64 {
        let n_chunks = s.n_chunks();
        let mut t = 0.0;
        if let Some((c, _)) = op.forward_part() {
            if c + 1 < n_chunks {
                t += self.cost.p2p_secs(s.device_of(c), s.device_of(c + 1));
            }
        }
        if let Some((c, _)) = op.backward_part() {
            if c > 0 {
                t += self.cost.p2p_secs(s.device_of(c), s.device_of(c - 1));
            }
        }
        EXPLICIT_PRODUCER_FRAC * t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, HardwareProfile, Topology};
    use crate::model::ModelConfig;
    use crate::schedule::{build_schedule, ScheduleKind};

    fn setup(tp: usize, pp: usize) -> (CostModel, Topology) {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(tp, pp, 1);
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        (CostModel::analytic(&m, &topo, &cluster, 3072, 1), topo)
    }

    #[test]
    fn all_schedules_simulate_without_deadlock() {
        let (cost, topo) = setup(4, 4);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, 8);
            let r = Simulator::new(&cost).run(&s);
            assert!(r.iteration_secs > 0.0, "{kind:?}");
            assert!(r.iteration_secs.is_finite(), "{kind:?}");
        }
    }

    #[test]
    fn stp_beats_baselines_at_tp8() {
        // The headline claim (Fig. 7 right): at TP=8/PP=2, STP > 1F1B-I, ZB-V.
        let (cost, topo) = setup(8, 2);
        let time = |k| {
            let s = build_schedule(k, &topo, 64);
            Simulator::new(&cost).run(&s).iteration_secs
        };
        let ours = time(ScheduleKind::Stp);
        let i1f1b = time(ScheduleKind::OneF1BInterleaved);
        let zbv = time(ScheduleKind::ZbV);
        assert!(ours < i1f1b, "STP {ours:.4}s !< 1F1B-I {i1f1b:.4}s");
        assert!(ours < zbv, "STP {ours:.4}s !< ZB-V {zbv:.4}s");
    }

    #[test]
    fn throughput_improvement_in_paper_range() {
        // Paper: up to ~12% over 1F1B-I on LLMs at TP=8, seq 6144, PP=2.
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(8, 2, 1);
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        let cost = CostModel::analytic(&m, &topo, &cluster, 6144, 1);
        let time = |k| {
            let s = build_schedule(k, &topo, 64);
            Simulator::new(&cost).run(&s).iteration_secs
        };
        let gain = time(ScheduleKind::OneF1BInterleaved) / time(ScheduleKind::Stp) - 1.0;
        assert!(
            (0.02..0.35).contains(&gain),
            "STP over 1F1B-I gain {:.1}% outside plausible band",
            gain * 100.0
        );
    }

    #[test]
    fn memory_ordering_matches_table1() {
        // Table 1 (peak activation): ZB-V (2p) < 1F1B-I (3p-2) < Ours (3p),
        // comparing the hottest device of each schedule. Chunk sizes are
        // non-uniform (last stage two layers short), so allow 1F1B-I ≈
        // Ours within one M_a, but ZB-V must be strictly lowest.
        let (cost, topo) = setup(4, 4);
        let peak = |k| {
            let s = build_schedule(k, &topo, 16);
            let r = Simulator::new(&cost).run(&s);
            r.devices.iter().map(|d| d.peak_activation_bytes).max().unwrap()
        };
        let zbv = peak(ScheduleKind::ZbV);
        let i = peak(ScheduleKind::OneF1BInterleaved);
        let ours = peak(ScheduleKind::Stp);
        let ma = *cost.act_bytes.iter().max().unwrap();
        assert!(zbv < i, "ZB-V {zbv} !< 1F1B-I {i}");
        assert!(ours + ma > i, "Ours {ours} not within one M_a above 1F1B-I {i}");
        assert!(ours > zbv, "Ours {ours} !> ZB-V {zbv}");
    }

    #[test]
    fn offload_reduces_peak_memory() {
        let (cost, topo) = setup(4, 4);
        let peak = |k| {
            let s = build_schedule(k, &topo, 16);
            let r = Simulator::new(&cost).run(&s);
            r.devices.iter().map(|d| d.peak_activation_bytes).max().unwrap()
        };
        let std = peak(ScheduleKind::Stp);
        let off = peak(ScheduleKind::StpOffload);
        assert!(off < std, "offload {off} !< standard {std}");
        // Paper §5.4: 10–19.2% peak reduction. Allow a wide band.
        let red = 1.0 - off as f64 / std as f64;
        assert!(red > 0.05, "only {:.1}% reduction", red * 100.0);
    }

    #[test]
    fn more_microbatches_amortize_bubbles() {
        let (cost, topo) = setup(4, 4);
        let thr = |m| {
            let s = build_schedule(ScheduleKind::Stp, &topo, m);
            let r = Simulator::new(&cost).run(&s);
            r.throughput()
        };
        assert!(thr(64) < thr(192) * 1.02);
    }
}
