//! Symmetry folding: simulate one representative replica per
//! equivalence class instead of the whole fleet (DESIGN.md §15).
//!
//! The replay core models a single DP replica; a fleet-scale result is
//! the slowest replica's timeline. When every replica is *symmetric* —
//! same per-stage hardware, same fault script — all dp replays are
//! bit-identical, so one replay stands for all of them. A
//! [`FoldedTopology`] partitions the replicas of a `(ClusterSpec,
//! Topology, GroupOrder, FaultPlan)` tuple into equivalence classes:
//!
//! * one class → fully folded, one replay, `dp×` less work;
//! * several classes → the fold *declines* ([`FoldDecline`]) and
//!   [`FleetSim`] replays one representative per class.
//!
//! Folding invariants (pinned by `tests/sim_equivalence.rs`):
//!
//! 1. **Bit-equality.** `run_folded` and `run_unfolded` return the same
//!    [`SimReport`] to the bit wherever the class partition is exact:
//!    symmetric replicas replay with identical arithmetic, and the
//!    slowest-class merge keeps the earliest replica on ties, which is
//!    exactly what the unfolded max-merge over all `dp` replays does.
//! 2. **Transparency.** On a symmetric fault-free pool the folded replay
//!    *is* the single-replica [`Simulator`] replay — same bits, so every
//!    pre-fold golden vector still pins this path.
//! 3. **Honest decline.** Replica-targeted faults
//!    ([`FoldDecline::ReplicaFaults`]) and replicas straddling
//!    heterogeneous node groups ([`FoldDecline::HeterogeneousReplicas`])
//!    break symmetry; the fold must detect both and fall back to
//!    per-class replay rather than extrapolate.

use crate::cluster::{ClusterSpec, DeviceView, GroupOrder, Topology};
use crate::elastic::{FaultEvent, FaultPlan};
use crate::schedule::Schedule;

use super::cost::CostModel;
use super::engine::{SimArena, Simulator};
use super::report::SimReport;
use super::SimError;

/// How the planner's evaluation loop replays multi-replica candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Simulate one representative per replica equivalence class
    /// (the default; bit-equal to [`SimMode::Unfolded`] by invariant 1).
    Folded,
    /// Replay every DP replica — the pre-fold baseline the bench and
    /// the golden suite compare against.
    Unfolded,
}

impl SimMode {
    pub fn label(&self) -> &'static str {
        match self {
            SimMode::Folded => "folded",
            SimMode::Unfolded => "unfolded",
        }
    }
}

/// Why a pool could not be folded to a single representative replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FoldDecline {
    /// The fault script targets specific replicas, so their timelines
    /// diverge (stragglers, dead ranks addressed at `replica > 0`).
    ReplicaFaults,
    /// Replicas resolve to different node groups (the stage-granular
    /// view failed and the per-replica packing straddles hardware
    /// tiers), so their unit timings differ.
    HeterogeneousReplicas,
}

impl FoldDecline {
    pub fn reason(&self) -> &'static str {
        match self {
            FoldDecline::ReplicaFaults => "replica-targeted faults",
            FoldDecline::HeterogeneousReplicas => "replicas straddle node groups",
        }
    }
}

/// One equivalence class of time-identical replicas.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaClass {
    /// The replica whose replay stands for the whole class (its lowest
    /// member, so ties in the merge resolve to the earliest replica).
    pub representative: usize,
    /// All replica indices in the class, ascending.
    pub members: Vec<usize>,
}

/// The fold of a concrete (cluster, topology, order, faults) tuple:
/// which replicas share a timeline, and therefore how few replays a
/// fleet-exact report needs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FoldedTopology {
    /// Data-parallel width being folded.
    pub dp: usize,
    /// Equivalence classes in order of their first member; their union
    /// is exactly `0..dp`.
    pub classes: Vec<ReplicaClass>,
    /// `None` when fully folded to one class.
    pub decline: Option<FoldDecline>,
}

impl FoldedTopology {
    /// Partition the `dp` replicas into time-identical classes. Two
    /// replicas are equivalent iff they resolve to the same per-stage
    /// node groups *and* the fault script addresses them identically.
    /// `None` when the pool cannot host the topology even at
    /// per-replica granularity.
    pub fn derive(
        cluster: &ClusterSpec,
        topo: &Topology,
        order: GroupOrder,
        faults: Option<&FaultPlan>,
    ) -> Option<FoldedTopology> {
        let dp = topo.dp.max(1);
        // Hot-path shortcut (the planner's no-fault evaluation loop): a
        // stage-granular view hosts every replica on identical hardware,
        // so with no faults the fold is total — skip the per-replica
        // view materialization entirely.
        let no_faults = faults.map(|f| f.events.is_empty()).unwrap_or(true);
        if no_faults && cluster.device_view(topo, order).is_some() {
            return Some(FoldedTopology {
                dp,
                classes: vec![ReplicaClass { representative: 0, members: (0..dp).collect() }],
                decline: None,
            });
        }
        let views = cluster.replica_device_views(topo, order)?;
        let fault_sigs: Vec<Vec<usize>> = (0..dp)
            .map(|r| {
                faults
                    .map(|f| {
                        f.events
                            .iter()
                            .enumerate()
                            .filter(|(_, ev)| ev.replica() == r)
                            .map(|(i, _)| i)
                            .collect()
                    })
                    .unwrap_or_default()
            })
            .collect();

        let mut classes: Vec<ReplicaClass> = Vec::new();
        let mut keys: Vec<(&DeviceView, &Vec<usize>)> = Vec::new();
        for r in 0..dp {
            let key = (&views[r], &fault_sigs[r]);
            match keys.iter().position(|k| *k == key) {
                Some(i) => classes[i].members.push(r),
                None => {
                    keys.push(key);
                    classes.push(ReplicaClass { representative: r, members: vec![r] });
                }
            }
        }

        let decline = if classes.len() <= 1 {
            None
        } else if classes.iter().any(|c| views[c.representative] != views[0]) {
            Some(FoldDecline::HeterogeneousReplicas)
        } else {
            Some(FoldDecline::ReplicaFaults)
        };
        Some(FoldedTopology { dp, classes, decline })
    }

    /// Whether one replay covers the whole fleet.
    pub fn is_folded(&self) -> bool {
        self.classes.len() == 1
    }

    /// Replays needed for a fleet-exact report.
    pub fn n_replays(&self) -> usize {
        self.classes.len()
    }

    /// Replica-replay reduction factor (`dp / n_replays`).
    pub fn fold_factor(&self) -> f64 {
        self.dp as f64 / self.classes.len().max(1) as f64
    }
}

/// The fault script as replica `r` experiences it: events addressed at
/// `r`, relabeled to replica 0 so the single-replica replay core (which
/// only applies replica-0 events) injects them unchanged.
pub fn replica_fault_plan(faults: &FaultPlan, replica: usize) -> FaultPlan {
    FaultPlan {
        events: faults
            .events
            .iter()
            .filter(|ev| ev.replica() == replica)
            .map(|ev| match *ev {
                FaultEvent::DeadRank { step, stage, at_secs, .. } => {
                    FaultEvent::DeadRank { step, stage, replica: 0, at_secs }
                }
                FaultEvent::Straggler { step, stage, slowdown, from_secs, .. } => {
                    FaultEvent::Straggler { step, stage, replica: 0, slowdown, from_secs }
                }
            })
            .collect(),
    }
}

/// Fleet-scale replay driver: runs one [`Simulator`] replay per replica
/// equivalence class (folded) or per replica (unfolded) and merges by
/// keeping the slowest replica's report — the fleet's iteration time is
/// its laggard's. Ties keep the earliest replay, which makes the two
/// paths bit-identical whenever the class partition is exact.
pub struct FleetSim<'a> {
    cost: &'a CostModel,
    faults: Option<FaultPlan>,
    trace: bool,
}

impl<'a> FleetSim<'a> {
    pub fn new(cost: &'a CostModel) -> Self {
        FleetSim { cost, faults: None, trace: true }
    }

    /// Inject a fleet-wide fault plan (replica coordinates respected).
    pub fn with_faults(mut self, f: FaultPlan) -> Self {
        self.faults = Some(f);
        self
    }

    /// Skip trace collection (planner mode).
    pub fn without_trace(mut self) -> Self {
        self.trace = false;
        self
    }

    fn replica_sim(&self, replica: usize) -> Simulator<'a> {
        let mut sim = match &self.faults {
            Some(f) => Simulator::new(self.cost).with_faults(replica_fault_plan(f, replica)),
            None => Simulator::new(self.cost),
        };
        if !self.trace {
            sim = sim.without_trace();
        }
        sim
    }

    fn run_replicas<I>(
        &self,
        s: &Schedule,
        replicas: I,
        arena: &mut SimArena,
    ) -> Result<SimReport, SimError>
    where
        I: IntoIterator<Item = usize>,
    {
        let mut slowest: Option<SimReport> = None;
        for r in replicas {
            let report = self.replica_sim(r).try_run_in(s, arena)?;
            slowest = Some(match slowest {
                Some(cur) if cur.iteration_secs >= report.iteration_secs => cur,
                _ => report,
            });
        }
        Ok(slowest.expect("at least one replica to replay"))
    }

    /// Replay one representative per equivalence class and merge.
    pub fn run_folded(
        &self,
        s: &Schedule,
        fold: &FoldedTopology,
        arena: &mut SimArena,
    ) -> Result<SimReport, SimError> {
        self.run_replicas(s, fold.classes.iter().map(|c| c.representative), arena)
    }

    /// Replay every replica and merge — the pre-fold baseline.
    pub fn run_unfolded(
        &self,
        s: &Schedule,
        dp: usize,
        arena: &mut SimArena,
    ) -> Result<SimReport, SimError> {
        self.run_replicas(s, 0..dp.max(1), arena)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::HardwareProfile;

    #[test]
    fn symmetric_pool_folds_to_one_class() {
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        let topo = Topology::new(2, 2, 4);
        let fold = FoldedTopology::derive(&cluster, &topo, GroupOrder::Declared, None).unwrap();
        assert!(fold.is_folded());
        assert_eq!(fold.n_replays(), 1);
        assert_eq!(fold.fold_factor(), 4.0);
        assert_eq!(fold.decline, None);
        assert_eq!(fold.classes[0].members, vec![0, 1, 2, 3]);
    }

    #[test]
    fn replica_targeted_faults_split_classes() {
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        let topo = Topology::new(2, 2, 4);
        let mut faults = FaultPlan::none();
        faults.events.push(FaultEvent::Straggler {
            step: 0,
            stage: 1,
            replica: 2,
            slowdown: 3.0,
            from_secs: 0.0,
        });
        let fold =
            FoldedTopology::derive(&cluster, &topo, GroupOrder::Declared, Some(&faults)).unwrap();
        assert!(!fold.is_folded());
        assert_eq!(fold.decline, Some(FoldDecline::ReplicaFaults));
        assert_eq!(fold.n_replays(), 2);
        assert_eq!(fold.classes[0].members, vec![0, 1, 3]);
        assert_eq!(fold.classes[1].members, vec![2]);
        assert_eq!(fold.classes[1].representative, 2);
    }

    #[test]
    fn replica_zero_faults_still_split_at_dp_above_one() {
        // A replica-0 fault breaks symmetry too: the other replicas are
        // clean. Two classes, and the fleet merge picks the slower.
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        let topo = Topology::new(2, 2, 2);
        let faults = FaultPlan::dead_rank_at(0, 0);
        let fold =
            FoldedTopology::derive(&cluster, &topo, GroupOrder::Declared, Some(&faults)).unwrap();
        assert_eq!(fold.n_replays(), 2);
        assert_eq!(fold.decline, Some(FoldDecline::ReplicaFaults));
    }

    #[test]
    fn straddling_replicas_decline_as_heterogeneous() {
        // 12 GPUs of (tp=2, pp=1, dp=6) on the 8+8 mixed pool: replicas
        // 0–3 pack onto the A800 node, 4–5 onto the H20 node.
        let cluster = ClusterSpec::mixed_a800_h20();
        let topo = Topology::new(2, 1, 6);
        let fold = FoldedTopology::derive(&cluster, &topo, GroupOrder::Declared, None).unwrap();
        assert!(!fold.is_folded());
        assert_eq!(fold.decline, Some(FoldDecline::HeterogeneousReplicas));
        assert_eq!(fold.classes.len(), 2);
        assert_eq!(fold.classes[0].members, vec![0, 1, 2, 3]);
        assert_eq!(fold.classes[1].members, vec![4, 5]);
        // An unhostable topology has no fold at all.
        let big = Topology::new(8, 4, 1);
        assert!(FoldedTopology::derive(&cluster, &big, GroupOrder::Declared, None).is_none());
    }

    #[test]
    fn relabeled_fault_plans_keep_only_their_replica() {
        let mut faults = FaultPlan::none();
        faults.events.push(FaultEvent::Straggler {
            step: 0,
            stage: 1,
            replica: 1,
            slowdown: 2.0,
            from_secs: 0.5,
        });
        faults.events.push(FaultEvent::DeadRank { step: 3, stage: 0, replica: 2, at_secs: 1.0 });
        let r1 = replica_fault_plan(&faults, 1);
        assert_eq!(r1.events.len(), 1);
        assert_eq!(r1.events[0].replica(), 0);
        assert_eq!(r1.events[0].stage(), 1);
        let r2 = replica_fault_plan(&faults, 2);
        assert_eq!(r2.events.len(), 1);
        assert!(matches!(r2.events[0], FaultEvent::DeadRank { replica: 0, at_secs, .. }
            if at_secs == 1.0));
        assert!(replica_fault_plan(&faults, 0).events.is_empty());
    }

    #[test]
    fn sim_mode_labels() {
        assert_eq!(SimMode::Folded.label(), "folded");
        assert_eq!(SimMode::Unfolded.label(), "unfolded");
    }
}
