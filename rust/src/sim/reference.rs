//! The original polling replay, kept as the simulator's **oracle**.
//!
//! Each round rescans every device and retries its next op until nothing
//! advances (quadratic in the worst case). The event-driven core in
//! [`super::engine`] replaces it on every path — including
//! duplicate-producer schedules, replayed natively via per-edge
//! dependency counting; this module survives so the golden equivalence
//! suite (`tests/sim_equivalence.rs`) can prove the rewrite
//! bit-identical against a core that assumes nothing about the program.

use crate::schedule::{Op, PassKind, Schedule, ScheduleKind};

use super::cost::{CostModel, HopTable};
use super::report::{finalize_report, RunTotals, SimReport, TraceEvent};
use super::{SimError, EXPLICIT_PRODUCER_FRAC};

/// The polling simulator: replays schedules by round-robin rescanning.
pub struct Simulator<'a> {
    cost: &'a CostModel,
    /// Charge P2P sends on the producer's compute stream (the paper notes
    /// STP's explicit pipeline communication "is executed immediately after
    /// computation and cannot be overlapped", §5.2).
    explicit_p2p: Option<bool>,
}

impl<'a> Simulator<'a> {
    pub fn new(cost: &'a CostModel) -> Self {
        Simulator { cost, explicit_p2p: None }
    }

    /// Override the explicit-P2P rule (default: STP-family schedules only).
    pub fn with_explicit_p2p(mut self, v: bool) -> Self {
        self.explicit_p2p = Some(v);
        self
    }

    /// Replay `s` and produce the report, panicking on deadlock (the
    /// historical behavior; prefer [`Simulator::try_run`]).
    pub fn run(&self, s: &Schedule) -> SimReport {
        match self.try_run(s) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// Replay `s`; a stuck device yields a [`SimError`] instead of a panic
    /// so one malformed candidate cannot abort a whole planner run.
    pub fn try_run(&self, s: &Schedule) -> Result<SimReport, SimError> {
        let n_chunks = s.n_chunks();
        let n_dev = s.devices.len();
        let explicit_p2p = self.explicit_p2p.unwrap_or(matches!(
            s.kind,
            ScheduleKind::Stp | ScheduleKind::StpMemEff | ScheduleKind::StpOffload
        ));
        // Hop costs hoisted out of the readiness closures: one P2P
        // resolution per (chunk, direction) instead of one per poll.
        let hops = self.cost.hop_table(s);

        let mut events: Vec<TraceEvent> = Vec::with_capacity(s.num_ops());
        let mut done_f = vec![vec![f64::NAN; s.n_mb]; n_chunks];
        let mut done_b = vec![vec![f64::NAN; s.n_mb]; n_chunks];
        let mut cursor = vec![0usize; n_dev];
        let mut dev_time = vec![0.0f64; n_dev];
        let mut busy = vec![0.0f64; n_dev];
        let mut exposed_ar = vec![0.0f64; n_dev];
        let mut compute_time = vec![0.0f64; n_dev];

        // Memory tracking (bytes of live activations per device).
        let mut mem = vec![0i64; n_dev];
        let mut mem_peak = vec![0i64; n_dev];
        // Offloaded fraction per (chunk, mb): ratio actually moved to host.
        let mut offloaded = vec![vec![0f32; s.n_mb]; n_chunks];
        // PCIe stream frontier and reload-finish gate per (chunk, mb).
        let mut pcie_time = vec![0.0f64; n_dev];
        let mut reload_done = vec![vec![0.0f64; s.n_mb]; n_chunks];
        let mut offload_done = vec![vec![0.0f64; s.n_mb]; n_chunks];
        let mut pcie_busy = vec![0.0f64; n_dev];

        let w_frac = self.cost.w_frac;

        loop {
            let mut advanced = false;
            for d in 0..n_dev {
                while cursor[d] < s.devices[d].len() {
                    let op = s.devices[d][cursor[d]];
                    // --- readiness ---------------------------------------
                    // STP's explicit sends block the producer's compute
                    // stream for the launch + part of the DMA (charged in
                    // `explicit_hop_cost`); the rest of the transfer rides
                    // the link and delays only the consumer edge.
                    let edge_frac = if explicit_p2p { 1.0 - EXPLICIT_PRODUCER_FRAC } else { 1.0 };
                    let f_ready = |c: usize, m: usize, done_f: &Vec<Vec<f64>>| -> Option<f64> {
                        if c == 0 {
                            Some(0.0)
                        } else {
                            let t = done_f[c - 1][m];
                            if t.is_nan() {
                                None
                            } else {
                                Some(t + edge_frac * hops.next[c - 1])
                            }
                        }
                    };
                    let b_ready = |c: usize, m: usize, done_f: &Vec<Vec<f64>>, done_b: &Vec<Vec<f64>>| -> Option<f64> {
                        let own = done_f[c][m];
                        if own.is_nan() {
                            return None;
                        }
                        if c + 1 == n_chunks {
                            Some(own)
                        } else {
                            let t = done_b[c + 1][m];
                            if t.is_nan() {
                                None
                            } else {
                                Some(own.max(t + edge_frac * hops.prev[c + 1]))
                            }
                        }
                    };

                    let ready: Option<f64> = match op {
                        Op::Pass { kind: PassKind::F, chunk, mb } => f_ready(chunk, mb, &done_f),
                        Op::Pass { kind: PassKind::B | PassKind::BFull, chunk, mb } => {
                            b_ready(chunk, mb, &done_f, &done_b)
                                .map(|t| t.max(reload_done[chunk][mb]))
                        }
                        Op::Pass { kind: PassKind::W, .. } => Some(0.0), // B precedes in-order
                        Op::Braided { f_chunk, f_mb, b_chunk, b_mb, .. } => {
                            match (
                                f_ready(f_chunk, f_mb, &done_f),
                                b_ready(b_chunk, b_mb, &done_f, &done_b),
                            ) {
                                (Some(a), Some(b)) => {
                                    Some(a.max(b).max(reload_done[b_chunk][b_mb]))
                                }
                                _ => None,
                            }
                        }
                        Op::BraidedFW { f_chunk, f_mb, .. } => f_ready(f_chunk, f_mb, &done_f),
                        Op::Offload { .. } | Op::Reload { .. } => Some(0.0),
                    };
                    let Some(ready) = ready else { break };

                    // --- duration & bookkeeping --------------------------
                    let start = dev_time[d].max(ready);
                    match op {
                        Op::Offload { chunk, mb, ratio } => {
                            // Runs on the PCIe stream in parallel with
                            // compute; clamp the ratio so the transfer fits
                            // under one forward (paper §4.4: T_o < T_F).
                            let t_f = self.cost.chunks[chunk].t_f();
                            let full = self.cost.offload_secs(chunk, 1.0);
                            let eff = if full > 0.0 {
                                (ratio as f64).min(t_f / full).max(0.0) as f32
                            } else {
                                ratio
                            };
                            let dur = self.cost.offload_secs(chunk, eff);
                            let t0 = pcie_time[d].max(dev_time[d]);
                            pcie_time[d] = t0 + dur;
                            pcie_busy[d] += dur;
                            offload_done[chunk][mb] = pcie_time[d];
                            offloaded[chunk][mb] = eff;
                            // Memory freed once the transfer completes;
                            // conservatively count it as freed at completion
                            // by subtracting now (peak sampled at op starts).
                            mem[d] -= (self.cost.act_bytes[chunk] as f64 * eff as f64) as i64;
                            cursor[d] += 1;
                            advanced = true;
                            continue;
                        }
                        Op::Reload { chunk, mb } => {
                            let eff = offloaded[chunk][mb];
                            let dur = self.cost.offload_secs(chunk, eff);
                            let t0 = pcie_time[d].max(dev_time[d]).max(offload_done[chunk][mb]);
                            pcie_time[d] = t0 + dur;
                            pcie_busy[d] += dur;
                            reload_done[chunk][mb] = pcie_time[d];
                            mem[d] += (self.cost.act_bytes[chunk] as f64 * eff as f64) as i64;
                            mem_peak[d] = mem_peak[d].max(mem[d]);
                            // Data is back on device: the backward frees it
                            // like any resident activation.
                            offloaded[chunk][mb] = 0.0;
                            cursor[d] += 1;
                            advanced = true;
                            continue;
                        }
                        _ => {}
                    }

                    let timing = self.op_timing(&op);
                    let mut finish = start + timing.duration;

                    // Explicit (non-overlapped) pipeline sends: the
                    // producer's compute stream pays the hop right after
                    // the op (STP-family).
                    let mut hop = 0.0;
                    if explicit_p2p {
                        hop = explicit_hop_cost(&hops, n_chunks, &op);
                        finish += hop;
                    }

                    dev_time[d] = finish;
                    busy[d] += finish - start;
                    compute_time[d] += timing.compute;
                    exposed_ar[d] += timing.exposed_ar;
                    events.push(TraceEvent { device: d, op, start, end: finish });

                    // Completion bookkeeping + memory events. Inside a
                    // braided block each direction completes at its own
                    // sub-stream time — a braid does not serialize the
                    // pipeline chain behind its full duration.
                    if let Some((c, m)) = op.forward_part() {
                        done_f[c][m] = start + timing.f_done + hop;
                        mem[d] += self.cost.act_bytes[c] as i64;
                        mem_peak[d] = mem_peak[d].max(mem[d]);
                    }
                    if let Some((c, m)) = op.backward_part() {
                        done_b[c][m] = start + timing.b_done + hop;
                        let act = self.cost.act_bytes[c] as f64;
                        let kept = offloaded[c][m] as f64; // already subtracted
                        if op.weight_part() == Some((c, m)) {
                            mem[d] -= (act * (1.0 - kept)) as i64;
                        } else {
                            mem[d] -= (act * (1.0 - w_frac - kept).max(0.0)) as i64;
                        }
                    }
                    if let Some((c, m)) = op.weight_part() {
                        if op.backward_part() != Some((c, m)) {
                            // Deferred W frees the retained weight-grad inputs.
                            let _ = m;
                            mem[d] -= (self.cost.act_bytes[c] as f64 * w_frac) as i64;
                        }
                    }
                    cursor[d] += 1;
                    advanced = true;
                }
            }
            if !advanced {
                break;
            }
        }

        // Any stuck device means an illegal schedule — surface it as an
        // error (the planner marks the candidate infeasible; direct
        // callers going through `run` keep the historical panic).
        for d in 0..n_dev {
            if cursor[d] != s.devices[d].len() {
                return Err(SimError {
                    device: d,
                    op_index: cursor[d],
                    ops_left: s.devices[d].len() - cursor[d],
                    op: s.devices[d].get(cursor[d]).copied(),
                });
            }
        }

        Ok(finalize_report(
            self.cost,
            s.kind,
            s.n_mb,
            RunTotals {
                dev_time: &dev_time,
                busy: &busy,
                compute: &compute_time,
                exposed_ar: &exposed_ar,
                mem_peak: &mem_peak,
                pcie_busy: &pcie_busy,
            },
            events,
        ))
    }

    /// Two-stream timing of one op.
    fn op_timing(&self, op: &Op) -> super::block::BlockTiming {
        op_timing(self.cost, op)
    }
}

/// Two-stream timing of one op against a cost model (shared by both
/// replay cores; the event-driven engine memoizes the results).
pub(crate) fn op_timing(cost: &CostModel, op: &Op) -> super::block::BlockTiming {
    let ch = &cost.chunks;
    match *op {
        Op::Pass { kind: PassKind::F, chunk, .. } => ch[chunk].time_f(),
        Op::Pass { kind: PassKind::B, chunk, .. } => ch[chunk].time_b(),
        Op::Pass { kind: PassKind::W, chunk, .. } => ch[chunk].time_w(),
        Op::Pass { kind: PassKind::BFull, chunk, .. } => ch[chunk].time_b_full(),
        Op::Braided { f_chunk, b_chunk, b_full, .. } => {
            ch[f_chunk].time_braided(&ch[b_chunk], b_full)
        }
        Op::BraidedFW { f_chunk, w_chunk, .. } => ch[f_chunk].time_braided_fw(&ch[w_chunk]),
        Op::Offload { .. } | Op::Reload { .. } => super::block::BlockTiming {
            duration: 0.0,
            compute: 0.0,
            exposed_ar: 0.0,
            f_done: 0.0,
            b_done: 0.0,
        },
    }
}

/// Cost of the explicit pipeline sends an op performs (STP-family):
/// the producer's compute stream is blocked for the launch plus the
/// head of the DMA. Shared by both replay cores.
pub(crate) fn explicit_hop_cost(hops: &HopTable, n_chunks: usize, op: &Op) -> f64 {
    let mut t = 0.0;
    if let Some((c, _)) = op.forward_part() {
        if c + 1 < n_chunks {
            t += hops.next[c];
        }
    }
    if let Some((c, _)) = op.backward_part() {
        if c > 0 {
            t += hops.prev[c];
        }
    }
    EXPLICIT_PRODUCER_FRAC * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, HardwareProfile, Topology};
    use crate::model::ModelConfig;
    use crate::schedule::{build_schedule, Placement, ScheduleKind};

    fn setup(tp: usize, pp: usize) -> (CostModel, Topology) {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(tp, pp, 1);
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        (CostModel::analytic(&m, &topo, &cluster, 3072, 1), topo)
    }

    #[test]
    fn all_schedules_replay_without_deadlock() {
        let (cost, topo) = setup(4, 4);
        for kind in ScheduleKind::all() {
            let s = build_schedule(kind, &topo, 8);
            let r = Simulator::new(&cost).run(&s);
            assert!(r.iteration_secs > 0.0, "{kind:?}");
            assert!(r.iteration_secs.is_finite(), "{kind:?}");
        }
    }

    #[test]
    fn malformed_schedule_is_an_error_not_a_panic() {
        let (cost, topo) = setup(1, 2);
        // A backward with no forward anywhere: device 0 can never start it.
        let s = crate::schedule::Schedule {
            kind: ScheduleKind::Stp,
            topo,
            n_mb: 1,
            placement: Placement::VShape,
            devices: vec![vec![crate::schedule::Op::b(0, 0)], vec![]],
        };
        let err = Simulator::new(&cost).try_run(&s).unwrap_err();
        assert_eq!(err.device, 0);
        assert_eq!(err.ops_left, 1);
        assert!(err.to_string().contains("deadlock"));
    }
}
