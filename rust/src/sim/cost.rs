//! Analytic cost model: model config × topology × cluster → per-chunk,
//! per-device unit timings, activation bytes and communication costs.
//!
//! This is the substitution for the paper's measured A800/H20 timings
//! (DESIGN.md §1): every simulated quantity is a function of
//! (FLOPs ÷ effective throughput, bytes ÷ bandwidth), so who-wins shapes
//! are preserved while absolute samples/s are not claimed. Since the
//! heterogeneous-cluster refactor (DESIGN.md §8) every chunk is costed
//! against the [`HardwareProfile`] of the device that actually executes
//! it, resolved through a [`ClusterSpec`]/[`DeviceView`] pair; a uniform
//! spec reproduces the old single-profile arithmetic exactly.

use crate::cluster::{
    partition_llm_weighted, ChunkContent, ClusterSpec, DeviceView, GroupOrder, HardwareProfile,
    StagePlan, Topology,
};
use crate::model::{LayerFlops, ModelConfig, VitConfig};
use crate::schedule::Placement;

use super::block::{ChunkUnits, Unit};

/// Calibration of the analytic activation footprint to Megatron-Core's
/// *measured* footprints (paper Appendix C reports ~20% implementation
/// overhead on top of theory; allocator fragmentation, comm buffers and
/// recompute workspaces account for the rest — the paper's absolute GB
/// columns are only reproduced with this factor).
const ACT_WORKSPACE_FACTOR: f64 = 1.8;

/// Fixed per-device runtime overhead (CUDA context, NCCL buffers,
/// cuDNN workspaces) counted against device memory for OOM detection.
const RUNTIME_OVERHEAD_BYTES: usize = 6 << 30;

/// Activation-checkpointing configurations (paper Table 9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcMode {
    None,
    /// Checkpoint the MLP modules only.
    Mlp,
    /// Checkpoint Attention + MLP.
    AttnMlp,
    /// Checkpoint Attention + MLP + Norms.
    All,
}

impl AcMode {
    /// All modes in a fixed order (the evo planner cycles through it).
    pub fn all() -> [AcMode; 4] {
        [AcMode::None, AcMode::Mlp, AcMode::AttnMlp, AcMode::All]
    }

    pub fn name(&self) -> &'static str {
        match self {
            AcMode::None => "none",
            AcMode::Mlp => "mlp",
            AcMode::AttnMlp => "attn+mlp",
            AcMode::All => "all",
        }
    }
}

/// Precomputed per-chunk pipeline-hop P2P costs for one schedule's
/// chunk→device placement. Hoisted out of the simulator's readiness
/// paths: the polling replay used to recompute `p2p_secs(dev, dev±1)`
/// inside the inner closures on every poll of every op; both replay
/// cores now build this table once per run.
#[derive(Debug, Clone, Default)]
pub struct HopTable {
    /// `next[c]` = P2P seconds for the hop chunk `c` → chunk `c+1`
    /// (0.0 for the last chunk).
    pub next: Vec<f64>,
    /// `prev[c]` = P2P seconds for the hop chunk `c` → chunk `c-1`
    /// (0.0 for chunk 0).
    pub prev: Vec<f64>,
}

/// Fully-resolved per-chunk costs consumed by the simulator engine.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Unit sequences per chunk (index = chunk id), timed against the
    /// profile of the chunk's owning device.
    pub chunks: Vec<ChunkUnits>,
    /// Activation bytes (`M_a`) per chunk per microbatch.
    pub act_bytes: Vec<usize>,
    /// Fraction of `M_a` retained after a decoupled `B` until `W` runs
    /// (weight-grad matmul inputs).
    pub w_frac: f64,
    /// P2P bytes per pipeline hop per microbatch.
    pub p2p_bytes: usize,
    /// The device pool (per-device profiles, link tiers, memory caps).
    pub cluster: ClusterSpec,
    /// PP rank → node group resolution for this topology.
    pub view: DeviceView,
    /// Device (PP rank) each chunk's costs were attributed to.
    pub chunk_dev: Vec<usize>,
    /// The layer→chunk split the chunks were costed from.
    pub stage_plan: StagePlan,
    /// Topology (TP size decides AR cost; PP for hop locality).
    pub topo: Topology,
    /// Uniform-split static bytes per device (weights + grads + optimizer
    /// state), the historical scalar every theory bound was derived with.
    pub static_bytes: usize,
    /// Per-device static bytes under the *actual* layer split: device `d`
    /// holds its owned chunks' layers, so a non-uniform weighted split
    /// (mixed pools, DESIGN.md §8) concentrates parameter state on the
    /// layer-heavy devices. Indexed by PP rank.
    pub static_bytes_per_dev: Vec<usize>,
    /// Samples per microbatch (micro batch size).
    pub mb_size: usize,
    /// Model-FLOPs per sample fwd+bwd (for MFU), whole model.
    pub model_flops_per_sample: f64,
}

impl CostModel {
    /// Cost model for an LLM partitioned over the topology's chunks: the
    /// uniform §5.1 split on uniform pools, the stage-time-balanced split
    /// on heterogeneous ones. Stages fill groups in declared order under
    /// the V-shape placement; use [`CostModel::analytic_for`] for other
    /// orderings/placements.
    pub fn analytic(
        model: &ModelConfig,
        topo: &Topology,
        cluster: &ClusterSpec,
        seq: usize,
        mb_size: usize,
    ) -> CostModel {
        Self::analytic_for(model, topo, cluster, GroupOrder::Declared, Placement::VShape, seq, mb_size)
    }

    /// [`CostModel::analytic`] with explicit group ordering and chunk
    /// placement (the planner enumerates both on mixed pools).
    pub fn analytic_for(
        model: &ModelConfig,
        topo: &Topology,
        cluster: &ClusterSpec,
        order: GroupOrder,
        placement: Placement,
        seq: usize,
        mb_size: usize,
    ) -> CostModel {
        let view = resolve_view(cluster, topo, order);
        Self::analytic_for_view(model, topo, cluster, view, placement, seq, mb_size)
    }

    /// [`CostModel::analytic_for`] with an explicit, already-resolved
    /// [`DeviceView`] — the evo planner's mapped candidates pin each PP
    /// rank of each replica class onto an arbitrary node group, so the
    /// view does not come from [`ClusterSpec::device_view`].
    #[allow(clippy::too_many_arguments)]
    pub fn analytic_for_view(
        model: &ModelConfig,
        topo: &Topology,
        cluster: &ClusterSpec,
        view: DeviceView,
        placement: Placement,
        seq: usize,
        mb_size: usize,
    ) -> CostModel {
        let plan = if cluster.is_uniform() {
            crate::cluster::partition_llm(model, topo.chunks())
        } else {
            let weights: Vec<f64> = (0..topo.chunks())
                .map(|c| {
                    cluster
                        .profile_of(&view, placement.device_of(c, topo))
                        .matmul_flops_per_sec()
                })
                .collect();
            partition_llm_weighted(model, topo.chunks(), &weights)
        };
        Self::from_plan(model, None, &plan, topo, cluster, view, placement, seq, 0, mb_size)
    }

    /// Cost model for an LLM with an explicit stage plan (e.g. to compare
    /// the uniform layer split against the balanced one on a mixed pool).
    pub fn analytic_planned(
        model: &ModelConfig,
        plan: &StagePlan,
        topo: &Topology,
        cluster: &ClusterSpec,
        seq: usize,
        mb_size: usize,
    ) -> CostModel {
        let view = resolve_view(cluster, topo, GroupOrder::Declared);
        Self::from_plan(model, None, plan, topo, cluster, view, Placement::VShape, seq, 0, mb_size)
    }

    /// Cost model for an MLLM stage plan (`vit_tokens` patch tokens into
    /// the first chunk, `seq` LM tokens elsewhere).
    #[allow(clippy::too_many_arguments)]
    pub fn analytic_mllm(
        lm: &ModelConfig,
        vit: &VitConfig,
        plan: &StagePlan,
        topo: &Topology,
        cluster: &ClusterSpec,
        lm_seq: usize,
        vit_tokens: usize,
        mb_size: usize,
    ) -> CostModel {
        Self::analytic_mllm_for(
            lm,
            vit,
            plan,
            topo,
            cluster,
            GroupOrder::Declared,
            Placement::VShape,
            lm_seq,
            vit_tokens,
            mb_size,
        )
    }

    /// [`CostModel::analytic_mllm`] with explicit ordering and placement.
    #[allow(clippy::too_many_arguments)]
    pub fn analytic_mllm_for(
        lm: &ModelConfig,
        vit: &VitConfig,
        plan: &StagePlan,
        topo: &Topology,
        cluster: &ClusterSpec,
        order: GroupOrder,
        placement: Placement,
        lm_seq: usize,
        vit_tokens: usize,
        mb_size: usize,
    ) -> CostModel {
        let view = resolve_view(cluster, topo, order);
        Self::analytic_mllm_for_view(
            lm, vit, plan, topo, cluster, view, placement, lm_seq, vit_tokens, mb_size,
        )
    }

    /// [`CostModel::analytic_mllm_for`] with an explicit, already-resolved
    /// [`DeviceView`] (mapped-candidate counterpart, see
    /// [`CostModel::analytic_for_view`]).
    #[allow(clippy::too_many_arguments)]
    pub fn analytic_mllm_for_view(
        lm: &ModelConfig,
        vit: &VitConfig,
        plan: &StagePlan,
        topo: &Topology,
        cluster: &ClusterSpec,
        view: DeviceView,
        placement: Placement,
        lm_seq: usize,
        vit_tokens: usize,
        mb_size: usize,
    ) -> CostModel {
        Self::from_plan(
            lm,
            Some(vit),
            plan,
            topo,
            cluster,
            view,
            placement,
            lm_seq,
            vit_tokens,
            mb_size,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn from_plan(
        lm: &ModelConfig,
        vit: Option<&VitConfig>,
        plan: &StagePlan,
        topo: &Topology,
        cluster: &ClusterSpec,
        view: DeviceView,
        placement: Placement,
        seq: usize,
        vit_tokens: usize,
        mb_size: usize,
    ) -> CostModel {
        assert_eq!(
            plan.chunks.len(),
            topo.chunks(),
            "stage plan must cover every virtual stage"
        );
        let tp = topo.tp;
        // Context parallelism splits the sequence across cp ranks.
        let seq_cp = seq / topo.cp;

        let chunk_dev: Vec<usize> =
            (0..topo.chunks()).map(|c| placement.device_of(c, topo)).collect();
        let mut chunks = Vec::with_capacity(plan.chunks.len());
        let mut act_bytes = Vec::with_capacity(plan.chunks.len());
        for (c, content) in plan.chunks.iter().enumerate() {
            let hw = cluster.profile_of(&view, chunk_dev[c]);
            let flops_sec = hw.matmul_flops_per_sec();
            let hbm = hw.hbm_gbps * 1e9;
            let (units, bytes) =
                chunk_costs(lm, vit, content, seq_cp, vit_tokens, mb_size, tp, flops_sec, hbm, hw);
            chunks.push(units);
            act_bytes.push(bytes);
        }

        let act_bytes: Vec<usize> =
            act_bytes.into_iter().map(|b| (b as f64 * ACT_WORKSPACE_FACTOR) as usize).collect();

        // Static memory per device: params sharded over tp×(chunks/device);
        // mixed-precision Adam ≈ 18 bytes/param (bf16 p+g, fp32 m/v/master),
        // plus the fixed runtime overhead.
        let total_params =
            lm.total_params() + vit.map(|v| v.total_params()).unwrap_or(0);
        let static_bytes = (total_params as f64 * 18.0 / (tp as f64 * topo.pp as f64)) as usize
            + RUNTIME_OVERHEAD_BYTES;

        // Per-device static state follows the layer split: a device's
        // parameter (and grad/optimizer) bytes are proportional to the
        // layers its chunks actually hold, not to 1/pp. The uniform
        // scalar above is preserved for the theory-bound arithmetic.
        let mut dev_layers = vec![0usize; topo.pp];
        for (c, content) in plan.chunks.iter().enumerate() {
            dev_layers[chunk_dev[c]] += content.lm_layers + content.vit_layers;
        }
        let static_bytes_per_dev = crate::memory::split_static_bytes(
            total_params as f64 * 18.0 / tp as f64,
            &dev_layers,
            RUNTIME_OVERHEAD_BYTES,
        );

        let model_flops_per_sample = lm.train_flops_per_token(seq) * seq as f64
            + vit
                .map(|v| 3.0 * v.layer_fwd_flops(vit_tokens) * v.layers as f64)
                .unwrap_or(0.0);

        CostModel {
            chunks,
            act_bytes,
            w_frac: 0.45,
            p2p_bytes: mb_size * seq_cp * lm.hidden * lm.dtype_bytes,
            cluster: cluster.clone(),
            view,
            chunk_dev,
            stage_plan: plan.clone(),
            topo: *topo,
            static_bytes,
            static_bytes_per_dev,
            mb_size,
            model_flops_per_sample,
        }
    }

    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Profile of the device holding PP rank `dev`.
    pub fn dev_profile(&self, dev: usize) -> &HardwareProfile {
        self.cluster.profile_of(&self.view, dev)
    }

    /// P2P time for one activation/gradient hop between PP ranks
    /// (cross-group hops pay the slower link tier).
    pub fn p2p_secs(&self, from_dev: usize, to_dev: usize) -> f64 {
        self.cluster.p2p_secs(&self.view, &self.topo, from_dev, to_dev, self.p2p_bytes)
    }

    /// Build the per-chunk hop-cost table for a schedule's placement
    /// (`s.device_of` resolves chunks to devices; the schedule's
    /// placement may differ from the one this model was costed with,
    /// e.g. when a V-shape cost model replays an interleaved baseline).
    pub fn hop_table(&self, s: &crate::schedule::Schedule) -> HopTable {
        let mut hops = HopTable::default();
        self.hop_table_into(s, &mut hops);
        hops
    }

    /// [`CostModel::hop_table`] into reused buffers (the simulator arena).
    pub fn hop_table_into(&self, s: &crate::schedule::Schedule, hops: &mut HopTable) {
        let n = s.n_chunks();
        hops.next.clear();
        hops.next.resize(n, 0.0);
        hops.prev.clear();
        hops.prev.resize(n, 0.0);
        for c in 0..n {
            if c + 1 < n {
                hops.next[c] = self.p2p_secs(s.device_of(c), s.device_of(c + 1));
            }
            if c > 0 {
                hops.prev[c] = self.p2p_secs(s.device_of(c), s.device_of(c - 1));
            }
        }
    }

    /// PCIe transfer time for offloading `ratio` of chunk `c`'s activation
    /// (on the chunk's own device).
    pub fn offload_secs(&self, chunk: usize, ratio: f32) -> f64 {
        self.dev_profile(self.chunk_dev[chunk])
            .pcie_secs((self.act_bytes[chunk] as f64 * ratio as f64) as usize)
    }

    /// Mean per-chunk `T_F`/`T_B`/`T_W`/`T_AR` (theory-formula inputs).
    pub fn theory_inputs(&self, n_mb: usize) -> crate::schedule::TheoryInputs {
        let n = self.chunks.len() as f64;
        let t_f = self.chunks.iter().map(|c| c.t_f()).sum::<f64>() / n;
        let t_b = self.chunks.iter().map(|c| c.t_b()).sum::<f64>() / n;
        let t_w = self.chunks.iter().map(|c| c.t_w()).sum::<f64>() / n;
        let t_ar = self.chunks.iter().map(|c| c.t_ar_fwd()).sum::<f64>() / n;
        crate::schedule::TheoryInputs { p: self.topo.pp, m: n_mb, t_f, t_b, t_w, t_ar }
    }

    /// Apply activation checkpointing (paper Appendix E.1, Table 9): the
    /// checkpointed units' inputs are dropped from the stash (peak memory
    /// shrinks) and their forward is recomputed at the head of the
    /// backward pass (T_B grows). Fractions follow the paper's measured
    /// reductions on Qwen2-12.1B.
    pub fn with_activation_checkpoint(mut self, mode: AcMode) -> CostModel {
        let (drop_frac, recompute_attn, recompute_mlp, recompute_norm) = match mode {
            AcMode::None => (0.0, false, false, false),
            AcMode::Mlp => (0.20, false, true, false),
            AcMode::AttnMlp => (0.26, true, true, false),
            AcMode::All => (0.35, true, true, true),
        };
        if drop_frac == 0.0 {
            return self;
        }
        for (c, bytes) in self.chunks.iter_mut().zip(self.act_bytes.iter_mut()) {
            *bytes = (*bytes as f64 * (1.0 - drop_frac)) as usize;
            // Recompute: prepend the checkpointed units' forward compute to
            // the backward stream (unit granularity; every 4 fwd units =
            // one layer: [pre_attn, attn, pre_mlp, mlp]).
            let mut extra = Vec::new();
            let mut ar_seen = 0usize;
            for u in c.fwd.iter() {
                // AR-carrying units alternate Attn, MLP within each layer;
                // AR-free units are norms/endpoints.
                let is_norm = u.ar == 0.0;
                let is_attn = !is_norm && ar_seen % 2 == 0;
                let is_mlp = !is_norm && ar_seen % 2 == 1;
                if !is_norm {
                    ar_seen += 1;
                }
                if (is_attn && recompute_attn)
                    || (is_mlp && recompute_mlp)
                    || (is_norm && recompute_norm)
                {
                    extra.push(super::block::Unit::b(u.compute, 0.0));
                }
            }
            let mut bwd = extra;
            bwd.extend(c.bwd.iter().copied());
            c.bwd = bwd;
        }
        self
    }

    /// Relative compute scale per chunk (passed to the schedule builders
    /// so MLLM imbalance steers construction).
    pub fn chunk_scales(&self) -> Vec<f64> {
        let mean = self.chunks.iter().map(|c| c.t_f()).sum::<f64>() / self.chunks.len() as f64;
        self.chunks.iter().map(|c| if mean > 0.0 { c.t_f() / mean } else { 1.0 }).collect()
    }
}

/// Resolve a topology against a cluster, panicking with a clear message
/// when the pool cannot host it (the planner pre-filters such candidates;
/// direct constructors treat it as a caller error).
fn resolve_view(cluster: &ClusterSpec, topo: &Topology, order: GroupOrder) -> DeviceView {
    cluster.device_view(topo, order).unwrap_or_else(|| {
        panic!(
            "cluster '{}' ({} devices) cannot host {} ({} devices)",
            cluster.name,
            cluster.total_devices(),
            topo,
            topo.world_size()
        )
    })
}

/// Build the unit sequence + activation bytes of one chunk.
#[allow(clippy::too_many_arguments)]
fn chunk_costs(
    lm: &ModelConfig,
    vit: Option<&VitConfig>,
    content: &ChunkContent,
    seq: usize,
    vit_tokens: usize,
    mb_size: usize,
    tp: usize,
    flops_sec: f64,
    hbm: f64,
    hw: &HardwareProfile,
) -> (ChunkUnits, usize) {
    let mut fwd = Vec::new();
    let mut bwd = Vec::new();
    let mut wgrad = Vec::new();
    let mut bytes = 0usize;

    // ViT layers (MLLM chunk 0). Modelled as two units (attn, mlp) per
    // layer with the same AR structure (Megatron ViT is TP-partitioned too).
    if content.vit_layers > 0 {
        let v = vit.expect("chunk has vit layers but no vit config");
        let lf = v.layer_fwd_flops(vit_tokens) * mb_size as f64;
        let ar = hw.allreduce_secs(v.ar_bytes_per_layer(vit_tokens, mb_size) / 2, tp);
        for _ in 0..content.vit_layers {
            // attn ~55% of layer flops, mlp ~45% for mlp_ratio 4.
            let t_attn = 0.55 * lf / (tp as f64) / flops_sec;
            let t_mlp = 0.45 * lf / (tp as f64) / flops_sec;
            let t_norm = (vit_tokens * mb_size * v.hidden * v.dtype_bytes) as f64 * 4.0 / hbm;
            fwd.push(Unit::f(t_norm, 0.0));
            fwd.push(Unit::f(t_attn, ar));
            fwd.push(Unit::f(t_norm, 0.0));
            fwd.push(Unit::f(t_mlp, ar));
            bwd.push(Unit::b(t_mlp, ar));
            bwd.push(Unit::b(1.5 * t_norm, 0.0));
            bwd.push(Unit::b(t_attn * 1.2, ar));
            bwd.push(Unit::b(1.5 * t_norm, 0.0));
            wgrad.push(Unit::w(t_mlp * 0.9));
            wgrad.push(Unit::w(t_attn * 0.7));
            bytes += v.activation_bytes_per_layer(vit_tokens, mb_size) / tp;
        }
    }

    // LM layers: the four paper units per layer.
    if content.lm_layers > 0 {
        let lf = LayerFlops::of(lm, seq, mb_size);
        let ar = hw.allreduce_secs(lm.ar_bytes_per_layer(seq, mb_size) / 2, tp);
        let per_rank = |f: f64| f / (tp as f64) / flops_sec;
        let norm_bytes = (seq * mb_size * lm.hidden * lm.dtype_bytes) as f64;
        for _ in 0..content.lm_layers {
            let t_pre = norm_bytes * 4.0 / hbm;
            fwd.push(Unit::f(t_pre, 0.0));
            fwd.push(Unit::f(per_rank(lf.attn.fwd), ar));
            fwd.push(Unit::f(t_pre, 0.0));
            fwd.push(Unit::f(per_rank(lf.mlp.fwd), ar));
            // Backward walks the layer in reverse: MLP then Attn.
            bwd.push(Unit::b(per_rank(lf.mlp.bwd_x), ar));
            bwd.push(Unit::b(1.5 * t_pre, 0.0));
            bwd.push(Unit::b(per_rank(lf.attn.bwd_x), ar));
            bwd.push(Unit::b(1.5 * t_pre, 0.0));
            wgrad.push(Unit::w(per_rank(lf.mlp.bwd_w)));
            wgrad.push(Unit::w(per_rank(lf.attn.bwd_w)));
            bytes += lm.activation_bytes_per_layer(seq, mb_size) / tp;
        }
    }

    // Embedding / head endpoints.
    if content.has_embed && content.lm_layers > 0 {
        let t = (seq * mb_size * lm.hidden * lm.dtype_bytes) as f64 / hbm;
        fwd.insert(0, Unit::f(t, 0.0));
        bwd.push(Unit::b(t, 0.0));
    }
    if content.has_head {
        let t = mb_size * seq * lm.hidden * lm.vocab;
        let flops = 2.0 * t as f64 / (tp as f64) / flops_sec;
        // Vocab-parallel head: logits AR folded into the unit's AR slot.
        let ar = hw.allreduce_secs(mb_size * seq * 4, tp); // loss scalar-ish reduce
        fwd.push(Unit::f(flops, ar));
        bwd.insert(0, Unit::b(flops, ar));
        wgrad.insert(0, Unit::w(flops));
        bytes += mb_size * seq * lm.hidden * lm.dtype_bytes / tp;
    }

    (ChunkUnits { fwd, bwd, wgrad }, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition_mllm;
    use crate::model::MllmConfig;

    fn a800() -> ClusterSpec {
        ClusterSpec::uniform(HardwareProfile::a800())
    }

    #[test]
    fn llm_cost_model_basic_shape() {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(8, 2, 1);
        let cm = CostModel::analytic(&m, &topo, &a800(), 6144, 1);
        assert_eq!(cm.n_chunks(), 4);
        for c in &cm.chunks {
            assert!(c.t_f() > 0.0);
            assert!(c.t_b() > c.t_w(), "T_B > T_W expected");
            assert!(c.t_ar_fwd() > 0.0);
        }
    }

    #[test]
    fn tp_bubble_share_grows_with_tp() {
        // Fig. 1: the TP-communication share of a layer grows with TP size.
        let m = ModelConfig::qwen2_12b();
        let cluster = a800();
        let share = |tp: usize| {
            let topo = Topology::new(tp, 2, 1);
            let cm = CostModel::analytic(&m, &topo, &cluster, 6144, 1);
            let c = &cm.chunks[0];
            c.t_ar_fwd() / (c.t_f() + c.t_ar_fwd())
        };
        assert!(share(4) > share(2));
        assert!(share(8) > share(4));
        // Paper: ~27.5% at TP=8/seq 6144 (whole fwd+bwd; forward alone is
        // in the same ballpark).
        let s8 = share(8);
        assert!((0.10..0.45).contains(&s8), "TP=8 share = {s8:.3}");
    }

    #[test]
    fn h20_has_smaller_comm_share_than_a800() {
        // Fig. 13 / appendix D.
        let m = ModelConfig::qwen2_12b();
        let share = |cluster: &ClusterSpec| {
            let topo = Topology::new(8, 2, 1);
            let cm = CostModel::analytic(&m, &topo, cluster, 6144, 1);
            let c = &cm.chunks[0];
            c.t_ar_fwd() / (c.t_f() + c.t_ar_fwd())
        };
        assert!(share(&ClusterSpec::uniform(HardwareProfile::h20())) < share(&a800()));
    }

    #[test]
    fn mllm_chunk_zero_is_vit() {
        let m = MllmConfig::qwen2vl_14_9b();
        let topo = Topology::new(4, 4, 1);
        let plan = partition_mllm(&m, topo.chunks());
        let cm = CostModel::analytic_mllm(&m.lm, &m.vit, &plan, &topo, &a800(), 5120, 3136, 1);
        assert_eq!(cm.n_chunks(), 8);
        assert!(cm.chunks[0].t_f() > 0.0);
        // ViT chunk imbalance surfaces in chunk scales.
        let scales = cm.chunk_scales();
        let spread = scales.iter().cloned().fold(f64::MIN, f64::max)
            - scales.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.01);
    }

    #[test]
    fn static_bytes_scale_down_with_parallelism() {
        let m = ModelConfig::qwen2_12b();
        let cluster = a800();
        let a = CostModel::analytic(&m, &Topology::new(4, 4, 1), &cluster, 4096, 1).static_bytes;
        let b = CostModel::analytic(&m, &Topology::new(8, 4, 1), &cluster, 4096, 1).static_bytes;
        assert!(b < a);
    }

    #[test]
    fn per_device_static_follows_the_weighted_split() {
        // Satellite of DESIGN.md §12: under the stage-time-balanced split
        // on a mixed pool the fast device carries more layers, hence more
        // parameter/optimizer state. The per-device vector must (a) order
        // like the layer counts, (b) conserve the total parameter bytes
        // of the uniform scalar, and (c) collapse to the scalar when the
        // split is uniform.
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(8, 2, 1);
        let spec = ClusterSpec::mixed_a800_h20();
        let cm = CostModel::analytic_for(
            &m,
            &topo,
            &spec,
            GroupOrder::FastFirst,
            Placement::VShape,
            4096,
            1,
        );
        let dev_layers = |d: usize| -> usize {
            cm.stage_plan
                .chunks
                .iter()
                .enumerate()
                .filter(|(c, _)| cm.chunk_dev[*c] == d)
                .map(|(_, ch)| ch.lm_layers)
                .sum()
        };
        assert_eq!(cm.static_bytes_per_dev.len(), topo.pp);
        assert!(dev_layers(0) > dev_layers(1), "fast device should hold more layers");
        assert!(
            cm.static_bytes_per_dev[0] > cm.static_bytes_per_dev[1],
            "static bytes must follow the layer split: {:?}",
            cm.static_bytes_per_dev
        );
        // Parameter bytes (overhead excluded) are conserved across the split.
        let split_params: usize =
            cm.static_bytes_per_dev.iter().map(|&b| b - RUNTIME_OVERHEAD_BYTES).sum();
        let scalar_params = (cm.static_bytes - RUNTIME_OVERHEAD_BYTES) * topo.pp;
        let diff = split_params.abs_diff(scalar_params);
        assert!(diff < 1 << 20, "split {split_params} vs scalar {scalar_params}");

        // Uniform pool, evenly divisible split: per-device == scalar.
        let even = CostModel::analytic(&m, &Topology::new(8, 2, 1), &a800(), 4096, 1);
        let uniform_counts: Vec<usize> =
            even.stage_plan.chunks.iter().map(|c| c.lm_layers).collect();
        if uniform_counts.iter().all(|&c| c == uniform_counts[0]) {
            for &b in &even.static_bytes_per_dev {
                assert!(b.abs_diff(even.static_bytes) < 1 << 20);
            }
        }
    }

    #[test]
    fn cp_divides_sequence() {
        let m = ModelConfig::qwen2_12b();
        let cluster = a800();
        let base = CostModel::analytic(&m, &Topology::new(2, 4, 1), &cluster, 12288, 1);
        let cp = CostModel::analytic(&m, &Topology::new(2, 4, 1).with_cp(2), &cluster, 12288, 1);
        assert!(cp.chunks[0].t_f() < base.chunks[0].t_f());
    }

    #[test]
    fn uniform_cluster_keeps_uniform_partition() {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(8, 2, 1);
        let cm = CostModel::analytic(&m, &topo, &a800(), 4096, 1);
        assert_eq!(cm.stage_plan, crate::cluster::partition_llm(&m, topo.chunks()));
        // Every chunk was costed against the same profile: AR time equals
        // the direct single-profile arithmetic.
        let hw = HardwareProfile::a800();
        let expect_ar = hw.allreduce_secs(m.ar_bytes_per_layer(4096, 1) / 2, topo.tp);
        let u = cm.chunks[0].fwd.iter().find(|u| u.ar > 0.0).unwrap();
        assert_eq!(u.ar, expect_ar);
    }

    #[test]
    fn hop_table_matches_direct_p2p_calls() {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(2, 4, 1);
        for spec in [a800(), ClusterSpec::mixed_a800_h20()] {
            let cm = CostModel::analytic(&m, &topo, &spec, 4096, 1);
            for kind in
                [crate::schedule::ScheduleKind::Stp, crate::schedule::ScheduleKind::OneF1BInterleaved]
            {
                let s = crate::schedule::build_schedule(kind, &topo, 8);
                let hops = cm.hop_table(&s);
                let n = s.n_chunks();
                assert_eq!(hops.next.len(), n);
                for c in 0..n {
                    if c + 1 < n {
                        assert_eq!(
                            hops.next[c].to_bits(),
                            cm.p2p_secs(s.device_of(c), s.device_of(c + 1)).to_bits()
                        );
                    } else {
                        assert_eq!(hops.next[c], 0.0);
                    }
                    if c > 0 {
                        assert_eq!(
                            hops.prev[c].to_bits(),
                            cm.p2p_secs(s.device_of(c), s.device_of(c - 1)).to_bits()
                        );
                    } else {
                        assert_eq!(hops.prev[c], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_cluster_balances_stage_time() {
        let m = ModelConfig::qwen2_12b();
        let topo = Topology::new(8, 2, 1); // chunks 0,1,2,3 on devs 0,1,1,0
        let spec = ClusterSpec::mixed_a800_h20();
        let cm = CostModel::analytic_for(
            &m,
            &topo,
            &spec,
            GroupOrder::FastFirst,
            Placement::VShape,
            4096,
            1,
        );
        // Non-uniform split: the A800-owned chunks carry more layers.
        let counts: Vec<usize> = cm.stage_plan.chunks.iter().map(|c| c.lm_layers).collect();
        assert!(counts[0] > counts[1], "fast chunk should carry more layers: {counts:?}");
        // Per-device stage times (sum of owned chunks' T_F) balance far
        // better than the uniform split would.
        let stage = |cm: &CostModel, d: usize| -> f64 {
            cm.chunks
                .iter()
                .enumerate()
                .filter(|(c, _)| cm.chunk_dev[*c] == d)
                .map(|(_, u)| u.t_f())
                .sum()
        };
        let balanced_skew = stage(&cm, 0).max(stage(&cm, 1)) / stage(&cm, 0).min(stage(&cm, 1));
        let uniform = CostModel::analytic_planned(
            &m,
            &crate::cluster::partition_llm(&m, topo.chunks()),
            &topo,
            &spec,
            4096,
            1,
        );
        let uniform_skew =
            stage(&uniform, 0).max(stage(&uniform, 1)) / stage(&uniform, 0).min(stage(&uniform, 1));
        assert!(
            balanced_skew < uniform_skew,
            "balanced skew {balanced_skew:.3} !< uniform skew {uniform_skew:.3}"
        );
    }
}
