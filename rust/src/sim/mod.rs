//! Discrete-event cluster simulator.
//!
//! Substitutes the paper's 16–32-GPU testbed (DESIGN.md §1): the analytic
//! [`CostModel`] turns (model, topology, hardware profile) into per-chunk
//! unit timings; the two-stream [`block`] machine times individual
//! execution blocks (Fig. 1 / Fig. 3 semantics); the [`Simulator`] replays
//! whole schedules and reports throughput, MFU, TP/PP bubble decomposition
//! and per-device peak memory (every quantity in Figures 7–10 and
//! Tables 3–8).
//!
//! Two replay cores share the block machine and the report finalizer
//! (DESIGN.md §9):
//!
//! * [`Simulator`] (`engine`) — the **event-driven** core: dependencies
//!   are pre-counted at compile time
//!   ([`crate::schedule::CompiledSchedule`]) and the replay is one
//!   ready-queue pass in O(ops), with an optional no-trace mode and a
//!   reusable [`SimArena`] for the planner's hot loop.
//! * [`reference::Simulator`] — the original **polling** replay, kept as
//!   the oracle: the golden suite (`tests/sim_equivalence.rs`) asserts
//!   the event-driven core reproduces its [`SimReport`]s bit-for-bit.
//!
//! [`FoldedTopology`] + [`FleetSim`] (`fold`) lift the event core to
//! fleet scale via symmetry folding: time-identical DP replicas are
//! replayed once per equivalence class and merged by slowest replica,
//! bit-equal to replaying every replica (DESIGN.md §15).

pub mod block;
mod cost;
mod engine;
mod fold;
pub mod reference;
mod report;

pub use block::{braid, time_block, BlockTiming, ChunkUnits, Unit};
pub use cost::{AcMode, CostModel, HopTable};
pub use engine::{SimArena, Simulator};
pub use fold::{replica_fault_plan, FleetSim, FoldDecline, FoldedTopology, ReplicaClass, SimMode};
pub use report::{DeviceReport, SimReport, TraceEvent};

/// Fraction of a pipeline hop that blocks the producer's compute stream
/// under STP's explicit (non-overlapped-launch) P2P communication; the
/// remainder is pure link time that only delays the consumer.
pub(crate) const EXPLICIT_PRODUCER_FRAC: f64 = 0.5;

/// A replay that could not run to completion: some device's program is
/// blocked forever (a malformed schedule — e.g. a backward whose forward
/// is never produced). The planner maps this to an infeasible candidate
/// instead of aborting the whole search.
#[derive(Debug, Clone, PartialEq)]
pub struct SimError {
    /// First stuck device.
    pub device: usize,
    /// Index of the op that device is blocked on.
    pub op_index: usize,
    /// Ops that device still had to run.
    pub ops_left: usize,
    /// The blocked op, if the device had one (for the message).
    pub op: Option<crate::schedule::Op>,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "simulator deadlock: device {} stuck at op {:?} ({} ops left)",
            self.device, self.op, self.ops_left
        )
    }
}

impl std::error::Error for SimError {}
