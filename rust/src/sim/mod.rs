//! Discrete-event cluster simulator.
//!
//! Substitutes the paper's 16–32-GPU testbed (DESIGN.md §1): the analytic
//! [`CostModel`] turns (model, topology, hardware profile) into per-chunk
//! unit timings; the two-stream [`block`] machine times individual
//! execution blocks (Fig. 1 / Fig. 3 semantics); the [`Simulator`] replays
//! whole schedules and reports throughput, MFU, TP/PP bubble decomposition
//! and per-device peak memory (every quantity in Figures 7–10 and
//! Tables 3–8).

pub mod block;
mod cost;
mod engine;
mod report;

pub use block::{braid, time_block, BlockTiming, ChunkUnits, Unit};
pub use cost::{AcMode, CostModel};
pub use engine::Simulator;
pub use report::{DeviceReport, SimReport, TraceEvent};
