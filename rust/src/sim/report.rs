//! Simulation reports: the metrics every paper table/figure is built from.


use crate::schedule::ScheduleKind;

/// Per-device accounting.
#[derive(Debug, Clone)]
pub struct DeviceReport {
    /// Time the compute stream held ops (including exposed AR inside ops).
    pub busy: f64,
    /// Pure compute time.
    pub compute: f64,
    /// Non-overlapped TP communication (the device's TP bubble).
    pub exposed_ar: f64,
    /// Idle time (the device's PP bubble, including waiting on P2P).
    pub idle: f64,
    /// Peak live activation bytes.
    pub peak_activation_bytes: usize,
    /// PCIe stream occupancy (offload variant).
    pub pcie_busy: f64,
}

/// One timed op occurrence (feeds the Chrome-trace / ASCII timelines).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub device: usize,
    pub op: crate::schedule::Op,
    pub start: f64,
    pub end: f64,
}

/// One simulated training iteration.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub kind: ScheduleKind,
    pub iteration_secs: f64,
    pub devices: Vec<DeviceReport>,
    /// Per-op timeline (in schedule order per device).
    pub events: Vec<TraceEvent>,
    pub n_mb: usize,
    pub mb_size: usize,
    /// Static (weights+grads+optimizer) bytes per device.
    pub static_bytes: usize,
    pub mem_capacity_bytes: usize,
    pub world_size: usize,
    pub peak_flops_per_dev: f64,
    pub model_flops_per_sample: f64,
}

impl SimReport {
    /// Samples per second for the whole job.
    pub fn throughput(&self) -> f64 {
        (self.n_mb * self.mb_size) as f64 / self.iteration_secs
    }

    /// Model FLOPs Utilization (fraction of aggregate peak).
    pub fn mfu(&self) -> f64 {
        let useful = self.model_flops_per_sample * (self.n_mb * self.mb_size) as f64;
        useful / (self.iteration_secs * self.world_size as f64 * self.peak_flops_per_dev)
    }

    /// Total TP bubble time (sum over devices of exposed AR).
    pub fn tp_bubble(&self) -> f64 {
        self.devices.iter().map(|d| d.exposed_ar).sum()
    }

    /// Total PP bubble time (sum of idle).
    pub fn pp_bubble(&self) -> f64 {
        self.devices.iter().map(|d| d.idle).sum()
    }

    /// Mean per-device TP bubble.
    pub fn tp_bubble_per_device(&self) -> f64 {
        self.tp_bubble() / self.devices.len() as f64
    }

    /// Mean per-device PP bubble.
    pub fn pp_bubble_per_device(&self) -> f64 {
        self.pp_bubble() / self.devices.len() as f64
    }

    /// Bubble rate: idle+exposed over total device-time.
    pub fn bubble_rate(&self) -> f64 {
        let total = self.iteration_secs * self.devices.len() as f64;
        (self.tp_bubble() + self.pp_bubble()) / total
    }

    /// Peak total memory (static + activations) across devices, bytes.
    pub fn peak_memory_bytes(&self) -> usize {
        self.devices
            .iter()
            .map(|d| d.peak_activation_bytes + self.static_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Peak activation-only memory across devices, GB (paper Fig. 9 unit).
    pub fn peak_activation_gb(&self) -> f64 {
        self.devices.iter().map(|d| d.peak_activation_bytes).max().unwrap_or(0) as f64 / 1e9
    }

    /// Per-device activation peaks in GB (Fig. 10 right).
    pub fn activation_gb_per_device(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.peak_activation_bytes as f64 / 1e9).collect()
    }

    /// Would this run OOM on the profile's device memory?
    pub fn is_oom(&self) -> bool {
        self.peak_memory_bytes() > self.mem_capacity_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(iter: f64, n_mb: usize) -> SimReport {
        SimReport {
            kind: ScheduleKind::Stp,
            iteration_secs: iter,
            events: Vec::new(),
            devices: vec![
                DeviceReport {
                    busy: iter * 0.9,
                    compute: iter * 0.8,
                    exposed_ar: iter * 0.1,
                    idle: iter * 0.1,
                    peak_activation_bytes: 10 << 30,
                    pcie_busy: 0.0,
                },
                DeviceReport {
                    busy: iter,
                    compute: iter * 0.9,
                    exposed_ar: iter * 0.1,
                    idle: 0.0,
                    peak_activation_bytes: 20 << 30,
                    pcie_busy: 0.0,
                },
            ],
            n_mb,
            mb_size: 1,
            static_bytes: 30 << 30,
            mem_capacity_bytes: 80 << 30,
            world_size: 16,
            peak_flops_per_dev: 312e12,
            model_flops_per_sample: 1e15,
        }
    }

    #[test]
    fn throughput_is_samples_over_time() {
        let r = mk(10.0, 64);
        assert!((r.throughput() - 6.4).abs() < 1e-9);
    }

    #[test]
    fn oom_detection() {
        let mut r = mk(10.0, 64);
        assert!(!r.is_oom()); // 20+30=50 GiB-ish < 80
        r.devices[1].peak_activation_bytes = 60 << 30;
        assert!(r.is_oom());
    }

    #[test]
    fn bubble_rate_bounded() {
        let r = mk(10.0, 64);
        assert!(r.bubble_rate() > 0.0 && r.bubble_rate() < 1.0);
    }

    #[test]
    fn mfu_sane() {
        let r = mk(100.0, 64);
        let mfu = r.mfu();
        assert!(mfu > 0.0 && mfu < 1.0, "mfu={mfu}");
    }
}
