//! Simulation reports: the metrics every paper table/figure is built from.
//!
//! [`finalize_report`] turns one replay's raw accumulators into a
//! [`SimReport`]; both replay cores (the event-driven `sim::Simulator`
//! and the polling `sim::reference`) share it, so their aggregation
//! arithmetic is identical by construction — a precondition of the
//! bit-equivalence guarantee the golden suite asserts.


use crate::schedule::ScheduleKind;

/// Per-device accounting.
#[derive(Debug, Clone)]
pub struct DeviceReport {
    /// Time the compute stream held ops (including exposed AR inside ops).
    pub busy: f64,
    /// Pure compute time.
    pub compute: f64,
    /// Non-overlapped TP communication (the device's TP bubble).
    pub exposed_ar: f64,
    /// Idle time (the device's PP bubble, including waiting on P2P).
    pub idle: f64,
    /// Peak live activation bytes.
    pub peak_activation_bytes: usize,
    /// This device's static (weights + grads + optimizer) bytes under
    /// the actual layer split — non-uniform weighted splits concentrate
    /// parameter state on layer-heavy devices.
    pub static_bytes: usize,
    /// PCIe stream occupancy (offload variant).
    pub pcie_busy: f64,
    /// This device's own memory capacity (its profile's `mem_gib`) —
    /// per-device OOM detection on heterogeneous pools.
    pub mem_capacity_bytes: usize,
    /// Profile name of the device ("a800-sxm4-80g"), surfaced in traces
    /// so mixed-pool timelines stay readable.
    pub hw_name: String,
}

/// One timed op occurrence (feeds the Chrome-trace / ASCII timelines).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub device: usize,
    pub op: crate::schedule::Op,
    pub start: f64,
    pub end: f64,
}

/// One simulated training iteration.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub kind: ScheduleKind,
    pub iteration_secs: f64,
    pub devices: Vec<DeviceReport>,
    /// Per-op timeline (in schedule order per device).
    pub events: Vec<TraceEvent>,
    pub n_mb: usize,
    pub mb_size: usize,
    /// Static (weights+grads+optimizer) bytes per device.
    pub static_bytes: usize,
    pub world_size: usize,
    /// Sum of peak BF16 FLOPs over every GPU of the job (per-group peaks
    /// on heterogeneous pools) — the MFU denominator.
    pub aggregate_peak_flops: f64,
    pub model_flops_per_sample: f64,
}

/// Raw per-device accumulators of one replay, borrowed from whichever
/// engine produced them (all slices are indexed by PP rank).
pub(crate) struct RunTotals<'a> {
    pub dev_time: &'a [f64],
    pub busy: &'a [f64],
    pub compute: &'a [f64],
    pub exposed_ar: &'a [f64],
    pub mem_peak: &'a [i64],
    pub pcie_busy: &'a [f64],
}

/// Fold one replay's accumulators into the report (iteration time,
/// per-device accounting, aggregate peak FLOPs for MFU).
pub(crate) fn finalize_report(
    cost: &super::cost::CostModel,
    kind: ScheduleKind,
    n_mb: usize,
    t: RunTotals,
    events: Vec<TraceEvent>,
) -> SimReport {
    let n_dev = t.dev_time.len();
    let iteration = t.dev_time.iter().cloned().fold(0.0, f64::max);
    let devices: Vec<DeviceReport> = (0..n_dev)
        .map(|d| {
            let hw = cost.dev_profile(d);
            DeviceReport {
                busy: t.busy[d],
                compute: t.compute[d],
                exposed_ar: t.exposed_ar[d],
                idle: iteration - t.busy[d],
                peak_activation_bytes: t.mem_peak[d].max(0) as usize,
                static_bytes: cost
                    .static_bytes_per_dev
                    .get(d)
                    .copied()
                    .unwrap_or(cost.static_bytes),
                pcie_busy: t.pcie_busy[d],
                mem_capacity_bytes: (hw.mem_gib * (1u64 << 30) as f64) as usize,
                hw_name: hw.name.clone(),
            }
        })
        .collect();

    // Aggregate peak FLOPs over the whole job: each PP rank is a
    // TP×CP group replicated DP times; sum per *group* so a uniform
    // pool reduces to the old `world_size × per-device peak` product.
    let topo = &cost.topo;
    let ranks_per_group = cost.view.ranks_per_group(cost.cluster.groups.len());
    let aggregate_peak_flops: f64 = ranks_per_group
        .iter()
        .enumerate()
        .filter(|(_, &n)| n > 0)
        .map(|(g, &n)| {
            let gpus = n * topo.tp * topo.cp * topo.dp;
            gpus as f64 * (cost.cluster.groups[g].hw.bf16_tflops * 1e12)
        })
        .sum();

    SimReport {
        kind,
        iteration_secs: iteration,
        devices,
        events,
        n_mb,
        mb_size: cost.mb_size,
        static_bytes: cost.static_bytes,
        world_size: cost.topo.world_size(),
        aggregate_peak_flops,
        model_flops_per_sample: cost.model_flops_per_sample,
    }
}

impl SimReport {
    /// Samples per second for the whole job.
    pub fn throughput(&self) -> f64 {
        (self.n_mb * self.mb_size) as f64 / self.iteration_secs
    }

    /// Model FLOPs Utilization (fraction of aggregate peak).
    pub fn mfu(&self) -> f64 {
        let useful = self.model_flops_per_sample * (self.n_mb * self.mb_size) as f64;
        useful / (self.iteration_secs * self.aggregate_peak_flops)
    }

    /// Total TP bubble time (sum over devices of exposed AR).
    pub fn tp_bubble(&self) -> f64 {
        self.devices.iter().map(|d| d.exposed_ar).sum()
    }

    /// Total PP bubble time (sum of idle).
    pub fn pp_bubble(&self) -> f64 {
        self.devices.iter().map(|d| d.idle).sum()
    }

    /// Mean per-device TP bubble.
    pub fn tp_bubble_per_device(&self) -> f64 {
        self.tp_bubble() / self.devices.len() as f64
    }

    /// Mean per-device PP bubble.
    pub fn pp_bubble_per_device(&self) -> f64 {
        self.pp_bubble() / self.devices.len() as f64
    }

    /// Bubble rate: idle+exposed over total device-time.
    pub fn bubble_rate(&self) -> f64 {
        let total = self.iteration_secs * self.devices.len() as f64;
        (self.tp_bubble() + self.pp_bubble()) / total
    }

    /// Peak total memory (static + activations) across devices, bytes.
    /// Each device contributes its *own* static share — under a weighted
    /// layer split the layer-heavy device carries more parameter state.
    pub fn peak_memory_bytes(&self) -> usize {
        self.devices
            .iter()
            .map(|d| d.peak_activation_bytes + d.static_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Peak activation-only memory across devices, GB (paper Fig. 9 unit).
    pub fn peak_activation_gb(&self) -> f64 {
        self.devices.iter().map(|d| d.peak_activation_bytes).max().unwrap_or(0) as f64 / 1e9
    }

    /// Per-device activation peaks in GB (Fig. 10 right).
    pub fn activation_gb_per_device(&self) -> Vec<f64> {
        self.devices.iter().map(|d| d.peak_activation_bytes as f64 / 1e9).collect()
    }

    /// Would this run OOM? Each device is checked against its *own*
    /// memory capacity (mixed pools have per-group `mem_gib`) and its
    /// own static share (weighted splits have per-device parameters).
    pub fn is_oom(&self) -> bool {
        self.devices
            .iter()
            .any(|d| d.peak_activation_bytes + d.static_bytes > d.mem_capacity_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(iter: f64, n_mb: usize) -> SimReport {
        SimReport {
            kind: ScheduleKind::Stp,
            iteration_secs: iter,
            events: Vec::new(),
            devices: vec![
                DeviceReport {
                    busy: iter * 0.9,
                    compute: iter * 0.8,
                    exposed_ar: iter * 0.1,
                    idle: iter * 0.1,
                    peak_activation_bytes: 10 << 30,
                    static_bytes: 30 << 30,
                    pcie_busy: 0.0,
                    mem_capacity_bytes: 80 << 30,
                    hw_name: "a800-sxm4-80g".into(),
                },
                DeviceReport {
                    busy: iter,
                    compute: iter * 0.9,
                    exposed_ar: iter * 0.1,
                    idle: 0.0,
                    peak_activation_bytes: 20 << 30,
                    static_bytes: 30 << 30,
                    pcie_busy: 0.0,
                    mem_capacity_bytes: 96 << 30,
                    hw_name: "h20-96g".into(),
                },
            ],
            n_mb,
            mb_size: 1,
            static_bytes: 30 << 30,
            world_size: 16,
            aggregate_peak_flops: 16.0 * 312e12,
            model_flops_per_sample: 1e15,
        }
    }

    #[test]
    fn throughput_is_samples_over_time() {
        let r = mk(10.0, 64);
        assert!((r.throughput() - 6.4).abs() < 1e-9);
    }

    #[test]
    fn oom_detection_uses_each_devices_own_capacity() {
        let mut r = mk(10.0, 64);
        assert!(!r.is_oom()); // 10+30 < 80 and 20+30 < 96
        // 60+30 = 90 GiB fits the 96G device...
        r.devices[1].peak_activation_bytes = 60 << 30;
        assert!(!r.is_oom());
        // ...but not the 80G one.
        r.devices[0].peak_activation_bytes = 60 << 30;
        assert!(r.is_oom());
    }

    #[test]
    fn bubble_rate_bounded() {
        let r = mk(10.0, 64);
        assert!(r.bubble_rate() > 0.0 && r.bubble_rate() < 1.0);
    }

    #[test]
    fn mfu_sane() {
        let r = mk(100.0, 64);
        let mfu = r.mfu();
        assert!(mfu > 0.0 && mfu < 1.0, "mfu={mfu}");
    }
}
