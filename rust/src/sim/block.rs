//! Two-stream (compute + communication) timing of execution blocks.
//!
//! This is the paper's Fig. 3 at timing granularity: a device owns one
//! compute stream and one TP-communication stream. Every fine-grained unit
//! (Pre-Attn, Attn, Pre-MLP, MLP and their backward counterparts) runs on
//! the compute stream; the All-Reduce a unit emits runs on the comm stream;
//! and the next unit *of the same direction* after an AR must wait for that
//! AR (data dependency), while units of the braided partner direction keep
//! the compute stream busy. Exactly this rule makes the braided blocks
//! hide TP communication and exposes it for bare F/B passes.

/// One compute unit: `compute` seconds on the compute stream, then an
/// optional All-Reduce of `ar` seconds on the comm stream. `stream` tags
/// the direction (0 = forward, 1 = backward, 2 = weight-grad) so the
/// AR-waiting rule can be applied per direction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Unit {
    pub compute: f64,
    pub ar: f64,
    pub stream: u8,
}

impl Unit {
    pub fn f(compute: f64, ar: f64) -> Unit {
        Unit { compute, ar, stream: 0 }
    }
    pub fn b(compute: f64, ar: f64) -> Unit {
        Unit { compute, ar, stream: 1 }
    }
    pub fn w(compute: f64) -> Unit {
        Unit { compute, ar: 0.0, stream: 2 }
    }
}

/// Result of timing a block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockTiming {
    /// Wall-clock duration of the block.
    pub duration: f64,
    /// Total compute time (lower bound on duration).
    pub compute: f64,
    /// Comm time that did **not** overlap compute (the block's TP bubble).
    pub exposed_ar: f64,
    /// Completion offset of the forward sub-stream (last F unit + its AR).
    /// Downstream consumers (the next pipeline stage) can start here, not
    /// at `duration` — braids do not serialize the pipeline chain.
    pub f_done: f64,
    /// Completion offset of the backward sub-stream.
    pub b_done: f64,
}

/// Execute a unit sequence on the two-stream machine.
///
/// `units` is the braided order in which the compute stream runs the
/// units. Each unit may start only after (a) the compute stream is free
/// and (b) the previous AR *of its own stream* has finished (the AR carries
/// the activations/gradients the unit consumes). ARs are serialized on the
/// comm stream in emission order. The block's `duration` includes any
/// trailing AR (it must finish before the block's results are usable).
pub fn time_block(units: &[Unit]) -> BlockTiming {
    let mut t_compute = 0.0f64; // compute stream frontier
    let mut t_comm = 0.0f64; // comm stream frontier
    let mut stream_gate = [0.0f64; 3]; // per-direction AR barrier
    let mut stream_done = [0.0f64; 3]; // per-direction completion
    let mut compute_total = 0.0f64;
    let mut busy_until = 0.0f64; // last compute finish

    for u in units {
        let start = t_compute.max(stream_gate[u.stream as usize]);
        let finish = start + u.compute;
        t_compute = finish;
        busy_until = finish;
        compute_total += u.compute;
        stream_done[u.stream as usize] = finish;
        if u.ar > 0.0 {
            let ar_start = t_comm.max(finish);
            let ar_finish = ar_start + u.ar;
            t_comm = ar_finish;
            stream_gate[u.stream as usize] = ar_finish;
            stream_done[u.stream as usize] = ar_finish;
        }
    }
    let duration = busy_until.max(t_comm);
    BlockTiming {
        duration,
        compute: compute_total,
        exposed_ar: duration - compute_total,
        f_done: if stream_done[0] > 0.0 { stream_done[0] } else { duration },
        b_done: if stream_done[1] > 0.0 { stream_done[1] } else { duration },
    }
}

/// Interleave two unit sequences one-for-one (the braided order of
/// Fig. 3a): `a0 b0 a1 b1 …` with the tail of the longer sequence
/// appended. The compute stream alternates directions, so each stream's
/// AR hides under the other stream's next unit.
pub fn braid(a: &[Unit], b: &[Unit]) -> Vec<Unit> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let n = a.len().max(b.len());
    for i in 0..n {
        if i < a.len() {
            out.push(a[i]);
        }
        if i < b.len() {
            out.push(b[i]);
        }
    }
    out
}

/// Per-chunk unit sequences (built by the cost model).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChunkUnits {
    /// Forward units in execution order.
    pub fwd: Vec<Unit>,
    /// Activation-backward units in execution order.
    pub bwd: Vec<Unit>,
    /// Weight-gradient units (no ARs).
    pub wgrad: Vec<Unit>,
}

impl ChunkUnits {
    /// Bare forward pass: serialized units → every AR exposed.
    pub fn time_f(&self) -> BlockTiming {
        time_block(&self.fwd)
    }

    /// Bare decoupled backward: every AR exposed (the ZB-V penalty).
    pub fn time_b(&self) -> BlockTiming {
        time_block(&self.bwd)
    }

    /// Weight-gradient pass.
    pub fn time_w(&self) -> BlockTiming {
        time_block(&self.wgrad)
    }

    /// Full backward (B+W fused): W units braided after each B unit so the
    /// backward ARs hide under weight-grad compute (paper Fig. 3a, blue).
    pub fn time_b_full(&self) -> BlockTiming {
        time_block(&braid(&self.bwd, &self.wgrad))
    }

    /// Braided F&B block (Fig. 3a/3b). `self` provides the forward units;
    /// `b_chunk` the backward (possibly a different chunk); `b_full`
    /// appends the weight-grad units into the braid.
    pub fn time_braided(&self, b_chunk: &ChunkUnits, b_full: bool) -> BlockTiming {
        if b_full {
            let bw = braid(&b_chunk.bwd, &b_chunk.wgrad);
            time_block(&braid(&self.fwd, &bw))
        } else {
            time_block(&braid(&self.fwd, &b_chunk.bwd))
        }
    }

    /// Braided F&W block (warm-up): forward ARs hide under W compute.
    pub fn time_braided_fw(&self, w_chunk: &ChunkUnits) -> BlockTiming {
        time_block(&braid(&self.fwd, &w_chunk.wgrad))
    }

    /// Sum of forward compute (no ARs) — `T_F` in the paper's notation.
    pub fn t_f(&self) -> f64 {
        self.fwd.iter().map(|u| u.compute).sum()
    }
    /// `T_B`.
    pub fn t_b(&self) -> f64 {
        self.bwd.iter().map(|u| u.compute).sum()
    }
    /// `T_W`.
    pub fn t_w(&self) -> f64 {
        self.wgrad.iter().map(|u| u.compute).sum()
    }
    /// One-direction TP communication `T_AR` (forward total).
    pub fn t_ar_fwd(&self) -> f64 {
        self.fwd.iter().map(|u| u.ar).sum()
    }
    pub fn t_ar_bwd(&self) -> f64 {
        self.bwd.iter().map(|u| u.ar).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer_units() -> ChunkUnits {
        // Two layers: pre-attn, attn(+AR), pre-mlp, mlp(+AR) each — a
        // chunk-sized block (single-layer blocks keep an unavoidable AR
        // tail; multi-layer chunks amortize it, as in the paper's chunks).
        let f = vec![Unit::f(0.1, 0.0), Unit::f(1.0, 0.5), Unit::f(0.1, 0.0), Unit::f(1.0, 0.5)];
        let b = vec![Unit::b(1.1, 0.5), Unit::b(0.15, 0.0), Unit::b(1.1, 0.5), Unit::b(0.15, 0.0)];
        let w = vec![Unit::w(0.8), Unit::w(0.8)];
        ChunkUnits {
            fwd: [f.clone(), f].concat(),
            bwd: [b.clone(), b].concat(),
            wgrad: [w.clone(), w].concat(),
        }
    }

    #[test]
    fn bare_forward_exposes_all_ar() {
        let c = layer_units();
        let t = c.time_f();
        assert!((t.duration - (c.t_f() + c.t_ar_fwd())).abs() < 1e-9);
        assert!((t.exposed_ar - c.t_ar_fwd()).abs() < 1e-9);
    }

    #[test]
    fn braided_block_hides_ar() {
        let c = layer_units();
        let braided = c.time_braided(&c, true);
        let serial = c.time_f().duration + c.time_b_full().duration;
        assert!(braided.duration < serial, "braided {} !< serial {serial}", braided.duration);
        // Compute dominates: most AR hidden (a short tail AR per block is
        // unavoidable — see paper Fig. 3).
        assert!(
            braided.exposed_ar < 0.35 * (c.t_ar_fwd() + c.t_ar_bwd()),
            "exposed {} of {}",
            braided.exposed_ar,
            c.t_ar_fwd() + c.t_ar_bwd()
        );
    }

    #[test]
    fn braided_substreams_complete_before_block_end() {
        let c = layer_units();
        let t = c.time_braided(&c, true);
        assert!(t.b_done <= t.duration + 1e-12);
        assert!(t.f_done <= t.duration + 1e-12);
    }

    #[test]
    fn full_backward_hides_bwd_ar_under_w() {
        let c = layer_units();
        let fused = c.time_b_full();
        let decoupled = c.time_b().duration + c.time_w().duration;
        assert!(fused.duration < decoupled);
    }

    #[test]
    fn empty_block() {
        let t = time_block(&[]);
        assert_eq!(t.duration, 0.0);
        assert_eq!(t.exposed_ar, 0.0);
    }

    #[test]
    fn trailing_ar_counts_toward_duration() {
        let t = time_block(&[Unit::f(1.0, 2.0)]);
        assert!((t.duration - 3.0).abs() < 1e-9);
        assert!((t.exposed_ar - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ar_dependency_gates_same_stream_only() {
        // F-unit AR gates the next F unit, but a B unit may run meanwhile.
        let units = vec![Unit::f(1.0, 1.0), Unit::b(1.0, 0.0), Unit::f(1.0, 0.0)];
        let t = time_block(&units);
        // timeline: F0 [0,1], AR [1,2], B [1,2], F1 waits AR -> [2,3].
        assert!((t.duration - 3.0).abs() < 1e-9);
        assert!((t.exposed_ar - 0.0).abs() < 1e-9);
    }

    #[test]
    fn comm_stream_serializes_ars() {
        let units = vec![Unit::f(0.1, 1.0), Unit::b(0.1, 1.0)];
        let t = time_block(&units);
        // F [0,.1], AR_f [.1,1.1]; B [.1,.2], AR_b [1.1,2.1].
        assert!((t.duration - 2.1).abs() < 1e-9);
    }
}
