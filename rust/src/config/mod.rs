//! Configuration: the AOT artifact manifest (written by
//! `python/compile/aot.py`) and run configuration for the CLI.

pub mod json;

pub use json::Json;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::Result;

/// Element type of an artifact argument/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype {other}"),
        }
    }
}

/// One argument/output tensor description.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub n_outputs: usize,
    /// Output indices the coordinator must All-Reduce across the TP group.
    pub ar_outputs: Vec<usize>,
}

/// Model dimensions as recorded by the AOT pipeline (mirrors
/// `python/compile/config.py::Dims`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestDims {
    pub vocab: usize,
    pub d: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub ffn: usize,
    pub layers: usize,
    pub seq: usize,
    pub mb: usize,
    pub tp: usize,
    pub pp: usize,
    pub vpp: usize,
}

impl ManifestDims {
    /// The python `test` preset (`python/compile/config.py::TEST`) — the
    /// miniature Qwen2-family dims the AOT pytest suite lowers. Single
    /// source for everything rust-side that claims to mirror it
    /// (`stp bench train`, the kernel parity suite).
    pub fn test_preset() -> ManifestDims {
        ManifestDims {
            vocab: 256,
            d: 64,
            q_heads: 4,
            kv_heads: 2,
            ffn: 96,
            layers: 4,
            seq: 16,
            mb: 2,
            tp: 2,
            pp: 2,
            vpp: 2,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.q_heads
    }
    pub fn q_heads_per_rank(&self) -> usize {
        self.q_heads / self.tp
    }
    pub fn kv_heads_per_rank(&self) -> usize {
        self.kv_heads / self.tp
    }
    pub fn ffn_per_rank(&self) -> usize {
        self.ffn / self.tp
    }
    pub fn n_chunks(&self) -> usize {
        self.pp * self.vpp
    }
    pub fn layers_per_chunk(&self) -> usize {
        self.layers / self.n_chunks()
    }
}

/// The AOT manifest: everything rust needs to load and call the artifacts.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub dims: ManifestDims,
    pub params_count: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Directory the manifest was loaded from (artifact files live here).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("reading {}/manifest.json: {e}", dir.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let dims_v = v.get("dims").ok_or_else(|| anyhow::anyhow!("manifest missing dims"))?;
        let u = |k: &str| -> Result<usize> {
            dims_v
                .get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("dims.{k} missing"))
        };
        let dims = ManifestDims {
            vocab: u("vocab")?,
            d: u("d")?,
            q_heads: u("q_heads")?,
            kv_heads: u("kv_heads")?,
            ffn: u("ffn")?,
            layers: u("layers")?,
            seq: u("seq")?,
            mb: u("mb")?,
            tp: u("tp")?,
            pp: u("pp")?,
            vpp: u("vpp")?,
        };

        let mut artifacts = BTreeMap::new();
        let arts = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?;
        for (name, a) in arts {
            let mut args = Vec::new();
            for arg in a.get("args").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape = arg
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|xs| xs.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                let dtype =
                    DType::parse(arg.get("dtype").and_then(Json::as_str).unwrap_or("float32"))?;
                args.push(TensorSpec { shape, dtype });
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.get("file").and_then(Json::as_str).unwrap_or_default().to_string(),
                    args,
                    n_outputs: a.get("n_outputs").and_then(Json::as_usize).unwrap_or(1),
                    ar_outputs: a
                        .get("ar_outputs")
                        .and_then(Json::as_arr)
                        .map(|xs| xs.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default(),
                },
            );
        }

        Ok(Manifest {
            preset: v.get("preset").and_then(Json::as_str).unwrap_or("?").to_string(),
            dims,
            params_count: v.get("params_count").and_then(Json::as_usize).unwrap_or(0),
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("stp-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"preset":"test","params_count":123,
                "dims":{"vocab":256,"d":64,"q_heads":4,"kv_heads":2,"ffn":96,
                         "layers":4,"seq":16,"mb":2,"tp":2,"pp":2,"vpp":2},
                "artifacts":{"smoke":{"file":"smoke.hlo.txt",
                    "args":[{"shape":[2,2],"dtype":"float32"}],
                    "n_outputs":1,"ar_outputs":[]}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "test");
        assert_eq!(m.dims.layers_per_chunk(), 1);
        assert_eq!(m.artifact("smoke").unwrap().args[0].shape, vec![2, 2]);
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
