//! Minimal JSON parser/serializer (the build environment vendors no serde;
//! see Cargo.toml). Covers the full JSON grammar; used for the AOT
//! manifest, golden vectors, run reports and Chrome traces.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// f32 vector from a numeric array.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization; `to_string()` comes for free via [`ToString`].
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c\n"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c\n"));
        assert!(matches!(v.get("d"), Some(Json::Null)));
    }

    #[test]
    fn f32_vec() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
