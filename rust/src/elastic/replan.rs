//! Mid-run replanning: device loss → shrink the pool → re-search → migrate.
//!
//! When a dead-rank fault halts a segment, the elastic driver (1) shrinks
//! the [`ClusterSpec`] by the node that hosted the dead stage
//! ([`shrink_cluster`]), (2) re-invokes the planner's beam search on the
//! surviving pool under the **fixed global batch** (`n_mb` is pinned —
//! elasticity must not silently change the optimization trajectory's
//! batch size) and (3) re-buckets the last checkpoint's parameter shards
//! across the new plan's stage split ([`migrate_checkpoint`]).
//!
//! One invariant makes migration a pure re-bucketing instead of a
//! resharding: **TP width is fixed across replans**. Shards are
//! Megatron-partitioned by `(tp_rank, dims)` only — the chunk a layer
//! lives in never affects its rank slice — so moving layers between
//! chunks is a move of whole `LayerParams`, bit-exact by construction.
//! The replanner therefore only considers candidates with the old `tp`.

use crate::cluster::ClusterSpec;
use crate::plan::{plan, PlanArtifact, PlanModel, PlanQuery, SearchMode};
use crate::Result;

use super::checkpoint::{shard_key, Checkpoint, ChunkShard};

/// Remove the node that died from `group`. Bounded groups lose one node
/// (the whole group disappears when its last node dies); the unbounded
/// uniform sentinel (`nodes == 0`) is returned unchanged — its capacity
/// already hosts any topology, so the shrink is carried entirely by the
/// caller's reduced GPU budget.
pub fn shrink_cluster(spec: &ClusterSpec, group: usize) -> Result<ClusterSpec> {
    anyhow::ensure!(
        group < spec.groups.len(),
        "shrink_cluster: group {group} out of range ({} groups)",
        spec.groups.len()
    );
    let mut out = spec.clone();
    match out.groups[group].nodes {
        0 => {}
        1 => {
            out.groups.remove(group);
            anyhow::ensure!(
                !out.groups.is_empty(),
                "shrink_cluster: losing group {group} empties the pool"
            );
        }
        n => out.groups[group].nodes = n - 1,
    }
    Ok(out)
}

/// Re-plan after losing the node hosting pipeline stage `dead_stage` of
/// `old`. Returns the shrunk pool and the new beam-searched artifact.
///
/// The search is constrained to the old plan's `tp` (see module docs)
/// and `n_mb` (fixed global batch); everything else — pp, vpp, schedule
/// kind, weighted split, group order, offload — is re-optimized on the
/// surviving devices. `mem_cap_gib <= 0` means "use the pool default".
#[allow(clippy::too_many_arguments)]
pub fn replan_after_loss(
    model: &PlanModel,
    cluster: &ClusterSpec,
    old: &PlanArtifact,
    dead_stage: usize,
    seq: usize,
    mb_size: usize,
    mem_cap_gib: f64,
    beam_width: usize,
) -> Result<(ClusterSpec, PlanArtifact)> {
    anyhow::ensure!(
        dead_stage < old.pp,
        "replan: dead stage {dead_stage} out of range (pp {})",
        old.pp
    );
    let topo = crate::cluster::Topology::new(old.tp, old.pp, old.dp).with_vpp(old.vpp);
    let view = cluster
        .device_view(&topo, old.order)
        .ok_or_else(|| anyhow::anyhow!("replan: pool cannot host the old topology"))?;
    let group = view.group_of(dead_stage);

    let shrunk = shrink_cluster(cluster, group)?;
    let old_gpus = old.tp * old.pp * old.dp;
    // Bounded groups lose the dead node's full complement; the unbounded
    // sentinel has no node accounting, so exactly the dead stage's
    // devices leave the budget.
    let lost = if cluster.groups[group].nodes == 0 {
        old.tp * old.dp
    } else {
        cluster.groups[group].hw.gpus_per_node
    };
    anyhow::ensure!(
        old_gpus > lost,
        "replan: losing {lost} of {old_gpus} GPUs leaves nothing to train on"
    );

    let mut q = PlanQuery::new(model.clone(), shrunk.clone(), old_gpus - lost);
    q.seq = seq;
    q.mb_size = mb_size;
    if mem_cap_gib > 0.0 {
        q.mem_cap_gib = mem_cap_gib;
    }
    q.n_mb_options = vec![old.n_mb];
    q.search = SearchMode::Beam { width: beam_width.max(1) };
    let report = plan(&q);

    let ctx = q.eval_context();
    let e = report
        .ranked
        .iter()
        .find(|e| e.feasible && e.candidate.tp == old.tp)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "replan: no feasible plan at tp{} n_mb{} on {} GPUs",
                old.tp,
                old.n_mb,
                old_gpus - lost
            )
        })?;
    Ok((shrunk, PlanArtifact::for_evaluation(&ctx, e)))
}

/// Re-bucket a checkpoint's shards onto `new`'s stage split. The global
/// layer order is chunk-index-major, so per rank this concatenates the
/// old chunks' layer lists and re-splits them at `new.stage_layers`'
/// prefix sums; the embedding moves to the new chunk 0 and the head to
/// the new last chunk. RNG stream positions are dropped (stages are
/// renumbered — device threads re-derive and fast-forward on resume).
pub fn migrate_checkpoint(ck: &Checkpoint, new: &PlanArtifact) -> Result<Checkpoint> {
    anyhow::ensure!(
        new.tp == ck.tp,
        "migrate: TP width is fixed across replans (checkpoint tp{}, plan tp{})",
        ck.tp,
        new.tp
    );
    anyhow::ensure!(
        new.total_layers() == ck.total_layers(),
        "migrate: plan covers {} layers, checkpoint holds {}",
        new.total_layers(),
        ck.total_layers()
    );
    anyhow::ensure!(
        new.total_vit_layers() == 0,
        "migrate: ViT chunks are not supported by the virtual executor"
    );
    ck.validate()?;

    let old_chunks = ck.n_chunks();
    let new_chunks = new.n_chunks();
    let mut shards = std::collections::BTreeMap::new();
    for rank in 0..ck.tp {
        let mut flat: Vec<crate::exec::LayerParams> = Vec::with_capacity(ck.total_layers());
        for c in 0..old_chunks {
            let s = ck
                .shard(c, rank)
                .ok_or_else(|| anyhow::anyhow!("migrate: missing shard c{c}r{rank}"))?;
            flat.extend(s.layers.iter().cloned());
        }
        let emb = ck.shard(0, rank).and_then(|s| s.emb.clone());
        let head = ck.shard(old_chunks - 1, rank).and_then(|s| s.head.clone());

        let mut taken = 0;
        for (c, &n) in new.stage_layers.iter().enumerate() {
            let layers = flat[taken..taken + n].to_vec();
            taken += n;
            shards.insert(
                shard_key(c, rank),
                ChunkShard {
                    chunk: c,
                    rank,
                    layers,
                    emb: if c == 0 { emb.clone() } else { None },
                    head: if c == new_chunks - 1 { head.clone() } else { None },
                },
            );
        }
    }

    let mut dims = ck.dims.clone();
    dims.pp = new.pp;
    dims.vpp = new.vpp;
    let migrated = Checkpoint {
        step: ck.step,
        seed: ck.seed,
        n_mb: ck.n_mb,
        schedule: new.kind.name().to_string(),
        tp: ck.tp,
        pp: new.pp,
        vpp: new.vpp,
        dims,
        stage_layers: new.stage_layers.clone(),
        data_cursor: ck.data_cursor,
        optimizer: ck.optimizer.clone(),
        rng_states: std::collections::BTreeMap::new(),
        shards,
    };
    migrated.validate()?;
    Ok(migrated)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GroupOrder, HardwareProfile, NodeGroup};
    use crate::config::ManifestDims;
    use crate::exec::ChunkParams;
    use crate::model::ModelConfig;
    use crate::schedule::{OffloadParams, ScheduleKind};
    use std::collections::BTreeMap;

    fn bounded_pool(groups: usize, gpus_per_node: usize) -> ClusterSpec {
        let mut hw = HardwareProfile::a800();
        hw.gpus_per_node = gpus_per_node;
        ClusterSpec {
            name: format!("bounded-{groups}x{gpus_per_node}"),
            groups: (0..groups).map(|_| NodeGroup { nodes: 1, hw: hw.clone() }).collect(),
            intergroup_gbps: 0.0,
        }
    }

    #[test]
    fn shrink_removes_nodes_then_groups() {
        let mut pool = bounded_pool(2, 4);
        pool.groups[0].nodes = 3;
        let s = shrink_cluster(&pool, 0).unwrap();
        assert_eq!(s.groups[0].nodes, 2);
        assert_eq!(s.groups.len(), 2);
        // A single-node group disappears entirely.
        let s = shrink_cluster(&pool, 1).unwrap();
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].nodes, 3);
        // Unbounded sentinel passes through untouched.
        let uni = ClusterSpec::uniform(HardwareProfile::a800());
        assert_eq!(shrink_cluster(&uni, 0).unwrap(), uni);
        // Out-of-range group is an error.
        assert!(shrink_cluster(&pool, 9).is_err());
    }

    fn tiny_ckpt(stage_layers: &[usize], tp: usize) -> Checkpoint {
        let n_chunks = stage_layers.len();
        let dims = ManifestDims {
            vocab: 32,
            d: 16,
            q_heads: 4,
            kv_heads: 2,
            ffn: 24,
            layers: stage_layers.iter().sum(),
            seq: 8,
            mb: 1,
            tp,
            pp: n_chunks,
            vpp: 1,
        };
        let mut shards = BTreeMap::new();
        for c in 0..n_chunks {
            for r in 0..tp {
                let p = ChunkParams::init(
                    &dims,
                    c,
                    r,
                    stage_layers[c],
                    c == 0,
                    c == n_chunks - 1,
                    7,
                );
                shards.insert(
                    shard_key(c, r),
                    ChunkShard {
                        chunk: c,
                        rank: r,
                        layers: p.layers,
                        emb: p.emb,
                        head: p.head,
                    },
                );
            }
        }
        Checkpoint {
            step: 2,
            seed: 7,
            n_mb: 4,
            schedule: "stp".into(),
            tp,
            pp: n_chunks,
            vpp: 1,
            dims,
            stage_layers: stage_layers.to_vec(),
            data_cursor: 2,
            optimizer: "sgd".into(),
            rng_states: BTreeMap::new(),
            shards,
        }
    }

    fn artifact(tp: usize, pp: usize, vpp: usize, stage_layers: Vec<usize>) -> PlanArtifact {
        let chunks = pp * vpp;
        assert_eq!(stage_layers.len(), chunks);
        PlanArtifact {
            model: "tiny".into(),
            cluster: "test".into(),
            seq: 8,
            mb_size: 1,
            kind: ScheduleKind::Stp,
            tp,
            pp,
            dp: 1,
            vpp,
            n_mb: 4,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            stage_layers,
            stage_vit_layers: vec![0; chunks],
            chunk_scales: vec![1.0; chunks],
            throughput: 0.0,
        }
    }

    #[test]
    fn migration_rebuckets_layers_in_global_order() {
        // 4 layers over [2, 2] → non-uniform [3, 1]: the third layer of
        // the new chunk 0 must be (bit-equal to) the first layer of the
        // old chunk 1, for every rank.
        let ck = tiny_ckpt(&[2, 2], 2);
        let m = migrate_checkpoint(&ck, &artifact(2, 2, 1, vec![3, 1])).unwrap();
        for r in 0..2 {
            let new0 = &m.shard(0, r).unwrap().layers;
            assert_eq!(new0.len(), 3);
            assert_eq!(new0[2], ck.shard(1, r).unwrap().layers[0]);
            assert_eq!(new0[0], ck.shard(0, r).unwrap().layers[0]);
            // Endpoints rode along to the new first/last chunks.
            assert_eq!(m.shard(0, r).unwrap().emb, ck.shard(0, r).unwrap().emb);
            assert_eq!(m.shard(1, r).unwrap().head, ck.shard(1, r).unwrap().head);
        }
        assert_eq!(m.step, ck.step);
        assert!(m.rng_states.is_empty(), "stage renumbering invalidates RNG keys");
        m.validate().unwrap();
    }

    #[test]
    fn migration_collapses_chunks_and_preserves_totals() {
        // Two chunks fold into one: emb AND head land on the same shard.
        let ck = tiny_ckpt(&[1, 1], 2);
        let m = migrate_checkpoint(&ck, &artifact(2, 1, 1, vec![2])).unwrap();
        for r in 0..2 {
            let s = m.shard(0, r).unwrap();
            assert_eq!(s.layers.len(), 2);
            assert!(s.emb.is_some() && s.head.is_some());
        }
        // TP or layer-count mismatches are hard errors.
        assert!(migrate_checkpoint(&ck, &artifact(1, 1, 1, vec![2])).is_err());
        assert!(migrate_checkpoint(&ck, &artifact(2, 1, 1, vec![3])).is_err());
    }

    #[test]
    fn replan_moves_to_a_shallower_pipeline_on_the_shrunk_pool() {
        // 4 nodes x 2 GPUs, tiny model at tp2-pp4-dp1 m8. Killing stage 1
        // removes its node: 6 GPUs survive, and the only tp2 shape left
        // that any group can host is pp3 (a stage needs tp·dp = 2 GPUs;
        // each surviving group holds exactly one).
        let pool = bounded_pool(4, 2);
        let model = PlanModel::Llm(ModelConfig::tiny_100m());
        let mut q = PlanQuery::new(model.clone(), pool.clone(), 8);
        q.seq = 512;
        q.n_mb_options = vec![8];
        q.threads = 2;
        let ctx = q.eval_context();
        let c = crate::plan::Candidate {
            id: 0,
            tp: 2,
            pp: 4,
            dp: 1,
            kind: ScheduleKind::Stp,
            n_mb: 8,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            offload_variant: 0,
        };
        let e = crate::plan::evaluate(&ctx, &c);
        assert!(e.feasible, "tiny model at tp2-pp4 must fit");
        let old = PlanArtifact::for_evaluation(&ctx, &e);

        let (shrunk, new) =
            replan_after_loss(&model, &pool, &old, 1, 512, 1, 0.0, 4).unwrap();
        assert_eq!(shrunk.groups.len(), 3);
        assert_eq!(new.tp, 2, "TP must be preserved");
        assert_eq!(new.n_mb, old.n_mb, "global batch must be preserved");
        assert_eq!(new.pp, 3);
        assert_eq!(new.total_layers(), ModelConfig::tiny_100m().layers);

        // And the checkpoint migrates onto the new split.
        let ck = tiny_ckpt_for(&old);
        let m = migrate_checkpoint(&ck, &new).unwrap();
        assert_eq!(m.pp, 3);
        assert_eq!(m.total_layers(), ck.total_layers());
    }

    /// A checkpoint shaped like `a`'s split (init-weight payload — enough
    /// for migration shape tests).
    fn tiny_ckpt_for(a: &PlanArtifact) -> Checkpoint {
        let mut ck = tiny_ckpt(&vec![1; a.n_chunks()], a.tp);
        // Rewrite the split to the artifact's (layer payloads are per
        // (chunk, layer-index) inits; only shapes matter here).
        let dims = ManifestDims { layers: a.total_layers(), ..ck.dims.clone() };
        let mut shards = BTreeMap::new();
        for c in 0..a.n_chunks() {
            for r in 0..a.tp {
                let p = ChunkParams::init(
                    &dims,
                    c,
                    r,
                    a.stage_layers[c],
                    c == 0,
                    c == a.n_chunks() - 1,
                    7,
                );
                shards.insert(
                    shard_key(c, r),
                    ChunkShard { chunk: c, rank: r, layers: p.layers, emb: p.emb, head: p.head },
                );
            }
        }
        ck.dims = ManifestDims { pp: a.pp, vpp: a.vpp, ..dims };
        ck.pp = a.pp;
        ck.vpp = a.vpp;
        ck.stage_layers = a.stage_layers.clone();
        ck.shards = shards;
        ck.validate().unwrap();
        ck
    }
}
