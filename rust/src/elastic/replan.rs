//! Mid-run replanning: device loss → shrink → (maybe) re-search → migrate.
//!
//! Recovery is tiered (DESIGN.md §14). While `dp > 1`, losing a rank
//! quarantines its whole replica: [`shrink_dp_checkpoint`] drops to the
//! widest surviving DP width that divides the **fixed global batch**
//! (`dp · n_mb` is pinned — elasticity must not silently change the
//! optimization trajectory's batch size), rescaling the per-replica
//! microbatch count to compensate. No re-search, no re-split: replica
//! weights are bit-identical at step boundaries, so the survivor's
//! shards simply clone across the shrunk grid.
//!
//! Only when the *last* replica loses a rank does the pipeline itself
//! reshape: the driver (1) shrinks the [`ClusterSpec`] by the node that
//! hosted the dead stage ([`shrink_cluster`]), (2) re-invokes the
//! planner's beam search on the surviving pool under the same pinned
//! batch and (3) re-buckets the checkpoint's parameter shards across the
//! new plan's stage split ([`migrate_checkpoint`]).
//!
//! One invariant makes migration a pure re-bucketing instead of a
//! resharding: **TP width is fixed across replans**. Shards are
//! Megatron-partitioned by `(tp_rank, dims)` only — the chunk a layer
//! lives in never affects its rank slice — so moving layers between
//! chunks is a move of whole `LayerParams`, bit-exact by construction
//! (ViT prefixes re-bucket the same way, along their own chunk-major
//! order). The replanner therefore only considers candidates with the
//! old `tp`.

use crate::cluster::ClusterSpec;
use crate::plan::{plan, PlanArtifact, PlanModel, PlanQuery, SearchMode};
use crate::Result;

use super::checkpoint::{shard_key, Checkpoint, ChunkShard};

/// Remove the node that died from `group`. Bounded groups lose one node
/// (the whole group disappears when its last node dies); the unbounded
/// uniform sentinel (`nodes == 0`) is returned unchanged — its capacity
/// already hosts any topology, so the shrink is carried entirely by the
/// caller's reduced GPU budget.
pub fn shrink_cluster(spec: &ClusterSpec, group: usize) -> Result<ClusterSpec> {
    anyhow::ensure!(
        group < spec.groups.len(),
        "shrink_cluster: group {group} out of range ({} groups)",
        spec.groups.len()
    );
    let mut out = spec.clone();
    match out.groups[group].nodes {
        0 => {}
        1 => {
            out.groups.remove(group);
            anyhow::ensure!(
                !out.groups.is_empty(),
                "shrink_cluster: losing group {group} empties the pool"
            );
        }
        n => out.groups[group].nodes = n - 1,
    }
    Ok(out)
}

/// Re-plan after losing the node hosting pipeline stage `dead_stage` of
/// `old`. Returns the shrunk pool and the new beam-searched artifact.
///
/// The search is constrained to the old plan's `tp` (see module docs)
/// and `n_mb` (fixed global batch); everything else — pp, vpp, schedule
/// kind, weighted split, group order, offload — is re-optimized on the
/// surviving devices. `mem_cap_gib <= 0` means "use the pool default".
#[allow(clippy::too_many_arguments)]
pub fn replan_after_loss(
    model: &PlanModel,
    cluster: &ClusterSpec,
    old: &PlanArtifact,
    dead_stage: usize,
    seq: usize,
    mb_size: usize,
    mem_cap_gib: f64,
    beam_width: usize,
) -> Result<(ClusterSpec, PlanArtifact)> {
    anyhow::ensure!(
        dead_stage < old.pp,
        "replan: dead stage {dead_stage} out of range (pp {})",
        old.pp
    );
    let topo = crate::cluster::Topology::new(old.tp, old.pp, old.dp).with_vpp(old.vpp);
    let view = cluster
        .device_view(&topo, old.order)
        .ok_or_else(|| anyhow::anyhow!("replan: pool cannot host the old topology"))?;
    let group = view.group_of(dead_stage);

    let shrunk = shrink_cluster(cluster, group)?;
    let old_gpus = old.tp * old.pp * old.dp;
    // Bounded groups lose the dead node's full complement; the unbounded
    // sentinel has no node accounting, so exactly the dead stage's
    // devices leave the budget.
    let lost = if cluster.groups[group].nodes == 0 {
        old.tp * old.dp
    } else {
        cluster.groups[group].hw.gpus_per_node
    };
    anyhow::ensure!(
        old_gpus > lost,
        "replan: losing {lost} of {old_gpus} GPUs leaves nothing to train on"
    );

    let mut q = PlanQuery::new(model.clone(), shrunk.clone(), old_gpus - lost);
    q.seq = seq;
    q.mb_size = mb_size;
    if mem_cap_gib > 0.0 {
        q.mem_cap_gib = mem_cap_gib;
    }
    q.n_mb_options = vec![old.n_mb];
    q.search = SearchMode::Beam { width: beam_width.max(1) };
    let report = plan(&q);

    let ctx = q.eval_context();
    let e = report
        .ranked
        .iter()
        .find(|e| e.feasible && e.candidate.tp == old.tp)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "replan: no feasible plan at tp{} n_mb{} on {} GPUs",
                old.tp,
                old.n_mb,
                old_gpus - lost
            )
        })?;
    Ok((shrunk, PlanArtifact::for_evaluation(&ctx, e)))
}

/// Re-bucket a checkpoint's shards onto `new`'s stage split, replica by
/// replica. The global layer order is chunk-index-major, so per
/// (replica, rank) this concatenates the old chunks' layer lists and
/// re-splits them at `new.stage_layers`' prefix sums — and likewise the
/// ViT prefixes at `new.stage_vit_layers`' (the two stacks re-bucket
/// independently along their own chunk-major orders). The embedding
/// moves to the new chunk 0 and the head to the new last chunk. RNG
/// stream positions are dropped (stages are renumbered — device threads
/// re-derive and fast-forward on resume).
pub fn migrate_checkpoint(ck: &Checkpoint, new: &PlanArtifact) -> Result<Checkpoint> {
    anyhow::ensure!(
        new.tp == ck.tp,
        "migrate: TP width is fixed across replans (checkpoint tp{}, plan tp{})",
        ck.tp,
        new.tp
    );
    anyhow::ensure!(
        new.total_layers() == ck.total_layers(),
        "migrate: plan covers {} layers, checkpoint holds {}",
        new.total_layers(),
        ck.total_layers()
    );
    anyhow::ensure!(
        new.total_vit_layers() == ck.total_vit_layers(),
        "migrate: plan carries {} ViT layers, checkpoint holds {}",
        new.total_vit_layers(),
        ck.total_vit_layers()
    );
    ck.validate()?;

    let old_chunks = ck.n_chunks();
    let new_chunks = new.n_chunks();
    let mut shards = std::collections::BTreeMap::new();
    for q in 0..ck.dp {
        for rank in 0..ck.tp {
            let mut flat: Vec<crate::exec::LayerParams> = Vec::with_capacity(ck.total_layers());
            let mut flat_vit: Vec<crate::exec::LayerParams> =
                Vec::with_capacity(ck.total_vit_layers());
            for c in 0..old_chunks {
                let s = ck
                    .shard(q, c, rank)
                    .ok_or_else(|| anyhow::anyhow!("migrate: missing shard d{q}c{c}r{rank}"))?;
                flat_vit.extend(s.vit_layers.iter().cloned());
                flat.extend(s.layers.iter().cloned());
            }
            let emb = ck.shard(q, 0, rank).and_then(|s| s.emb.clone());
            let head = ck.shard(q, old_chunks - 1, rank).and_then(|s| s.head.clone());

            let (mut taken, mut taken_vit) = (0, 0);
            for c in 0..new_chunks {
                let n = new.stage_layers[c];
                let nv = new.stage_vit_layers[c];
                let layers = flat[taken..taken + n].to_vec();
                let vit_layers = flat_vit[taken_vit..taken_vit + nv].to_vec();
                taken += n;
                taken_vit += nv;
                shards.insert(
                    shard_key(q, c, rank),
                    ChunkShard {
                        replica: q,
                        chunk: c,
                        rank,
                        vit_layers,
                        layers,
                        emb: if c == 0 { emb.clone() } else { None },
                        head: if c == new_chunks - 1 { head.clone() } else { None },
                    },
                );
            }
        }
    }

    let mut dims = ck.dims.clone();
    dims.pp = new.pp;
    dims.vpp = new.vpp;
    let migrated = Checkpoint {
        step: ck.step,
        seed: ck.seed,
        n_mb: ck.n_mb,
        schedule: new.kind.name().to_string(),
        tp: ck.tp,
        pp: new.pp,
        dp: ck.dp,
        vpp: new.vpp,
        dims,
        stage_layers: new.stage_layers.clone(),
        stage_vit_layers: new.stage_vit_layers.clone(),
        data_cursor: ck.data_cursor,
        optimizer: ck.optimizer.clone(),
        rng_states: std::collections::BTreeMap::new(),
        shards,
    };
    migrated.validate()?;
    Ok(migrated)
}

/// Quarantine `dead_replica` and shrink to the widest data-parallel
/// width that both fits the survivors and divides the fixed global batch
/// `dp · n_mb` (the per-replica microbatch count rescales to keep the
/// product — and therefore the optimization trajectory — unchanged).
///
/// The DP gradient all-reduce hands every replica the identical summed
/// update each step, so replica weights are bit-identical at every step
/// boundary: shrinking is cloning the lowest surviving replica's shards
/// across the new grid — no arithmetic touches a tensor. RNG stream
/// positions are dropped (replicas are renumbered — device threads
/// re-derive and fast-forward on resume).
pub fn shrink_dp_checkpoint(ck: &Checkpoint, dead_replica: usize) -> Result<Checkpoint> {
    anyhow::ensure!(
        ck.dp > 1,
        "shrink-dp: dp 1 has no replica to quarantine (that loss needs a pipeline re-split)"
    );
    anyhow::ensure!(
        dead_replica < ck.dp,
        "shrink-dp: dead replica {dead_replica} out of range (dp {})",
        ck.dp
    );
    ck.validate()?;

    let global = ck.dp * ck.n_mb;
    // dp' = 1 always divides, so the search cannot come up empty.
    let dp = (1..ck.dp).rev().find(|d| global % d == 0).unwrap_or(1);
    let n_mb = global / dp;
    let survivor = usize::from(dead_replica == 0);
    let mut shards = std::collections::BTreeMap::new();
    for q in 0..dp {
        for c in 0..ck.n_chunks() {
            for r in 0..ck.tp {
                let s = ck.shard(survivor, c, r).ok_or_else(|| {
                    anyhow::anyhow!("shrink-dp: missing shard d{survivor}c{c}r{r}")
                })?;
                shards.insert(shard_key(q, c, r), ChunkShard { replica: q, ..s.clone() });
            }
        }
    }
    let shrunk = Checkpoint {
        n_mb,
        dp,
        rng_states: std::collections::BTreeMap::new(),
        shards,
        ..ck.clone()
    };
    shrunk.validate()?;
    Ok(shrunk)
}

/// The plan artifact for continuing at a shrunk DP width: the same
/// schedule, topology and layer split — only the replica count and the
/// per-replica microbatch count change (their product is pinned, which
/// [`shrink_dp_checkpoint`] guarantees by construction).
pub fn shrink_dp_plan(old: &PlanArtifact, dp: usize, n_mb: usize) -> PlanArtifact {
    let mut out = old.clone();
    out.dp = dp;
    out.n_mb = n_mb;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GroupOrder, HardwareProfile, NodeGroup};
    use crate::config::ManifestDims;
    use crate::exec::ChunkParams;
    use crate::model::ModelConfig;
    use crate::schedule::{OffloadParams, ScheduleKind};
    use std::collections::BTreeMap;

    fn bounded_pool(groups: usize, gpus_per_node: usize) -> ClusterSpec {
        let mut hw = HardwareProfile::a800();
        hw.gpus_per_node = gpus_per_node;
        ClusterSpec {
            name: format!("bounded-{groups}x{gpus_per_node}"),
            groups: (0..groups).map(|_| NodeGroup { nodes: 1, hw: hw.clone() }).collect(),
            intergroup_gbps: 0.0,
        }
    }

    #[test]
    fn shrink_removes_nodes_then_groups() {
        let mut pool = bounded_pool(2, 4);
        pool.groups[0].nodes = 3;
        let s = shrink_cluster(&pool, 0).unwrap();
        assert_eq!(s.groups[0].nodes, 2);
        assert_eq!(s.groups.len(), 2);
        // A single-node group disappears entirely.
        let s = shrink_cluster(&pool, 1).unwrap();
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].nodes, 3);
        // Unbounded sentinel passes through untouched.
        let uni = ClusterSpec::uniform(HardwareProfile::a800());
        assert_eq!(shrink_cluster(&uni, 0).unwrap(), uni);
        // Out-of-range group is an error.
        assert!(shrink_cluster(&pool, 9).is_err());
    }

    fn tiny_ckpt_dp(stage_layers: &[usize], tp: usize, dp: usize) -> Checkpoint {
        let n_chunks = stage_layers.len();
        let dims = ManifestDims {
            vocab: 32,
            d: 16,
            q_heads: 4,
            kv_heads: 2,
            ffn: 24,
            layers: stage_layers.iter().sum(),
            seq: 8,
            mb: 1,
            tp,
            pp: n_chunks,
            vpp: 1,
        };
        let mut shards = BTreeMap::new();
        for q in 0..dp {
            for c in 0..n_chunks {
                for r in 0..tp {
                    let p = ChunkParams::init(
                        &dims,
                        c,
                        r,
                        0,
                        stage_layers[c],
                        c == 0,
                        c == n_chunks - 1,
                        7,
                    );
                    shards.insert(
                        shard_key(q, c, r),
                        ChunkShard {
                            replica: q,
                            chunk: c,
                            rank: r,
                            vit_layers: Vec::new(),
                            layers: p.layers,
                            emb: p.emb,
                            head: p.head,
                        },
                    );
                }
            }
        }
        Checkpoint {
            step: 2,
            seed: 7,
            n_mb: 4,
            schedule: "stp".into(),
            tp,
            pp: n_chunks,
            dp,
            vpp: 1,
            dims,
            stage_layers: stage_layers.to_vec(),
            stage_vit_layers: vec![0; n_chunks],
            data_cursor: 2,
            optimizer: "sgd".into(),
            rng_states: BTreeMap::new(),
            shards,
        }
    }

    fn tiny_ckpt(stage_layers: &[usize], tp: usize) -> Checkpoint {
        tiny_ckpt_dp(stage_layers, tp, 1)
    }

    fn artifact(tp: usize, pp: usize, vpp: usize, stage_layers: Vec<usize>) -> PlanArtifact {
        let chunks = pp * vpp;
        assert_eq!(stage_layers.len(), chunks);
        PlanArtifact {
            model: "tiny".into(),
            cluster: "test".into(),
            seq: 8,
            mb_size: 1,
            kind: ScheduleKind::Stp,
            tp,
            pp,
            dp: 1,
            vpp,
            n_mb: 4,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            ac: crate::sim::AcMode::None,
            stage_layers,
            stage_vit_layers: vec![0; chunks],
            chunk_scales: vec![1.0; chunks],
            throughput: 0.0,
        }
    }

    #[test]
    fn migration_rebuckets_layers_in_global_order() {
        // 4 layers over [2, 2] → non-uniform [3, 1]: the third layer of
        // the new chunk 0 must be (bit-equal to) the first layer of the
        // old chunk 1, for every rank.
        let ck = tiny_ckpt(&[2, 2], 2);
        let m = migrate_checkpoint(&ck, &artifact(2, 2, 1, vec![3, 1])).unwrap();
        for r in 0..2 {
            let new0 = &m.shard(0, 0, r).unwrap().layers;
            assert_eq!(new0.len(), 3);
            assert_eq!(new0[2], ck.shard(0, 1, r).unwrap().layers[0]);
            assert_eq!(new0[0], ck.shard(0, 0, r).unwrap().layers[0]);
            // Endpoints rode along to the new first/last chunks.
            assert_eq!(m.shard(0, 0, r).unwrap().emb, ck.shard(0, 0, r).unwrap().emb);
            assert_eq!(m.shard(0, 1, r).unwrap().head, ck.shard(0, 1, r).unwrap().head);
        }
        assert_eq!(m.step, ck.step);
        assert!(m.rng_states.is_empty(), "stage renumbering invalidates RNG keys");
        m.validate().unwrap();
    }

    #[test]
    fn migration_collapses_chunks_and_preserves_totals() {
        // Two chunks fold into one: emb AND head land on the same shard.
        let ck = tiny_ckpt(&[1, 1], 2);
        let m = migrate_checkpoint(&ck, &artifact(2, 1, 1, vec![2])).unwrap();
        for r in 0..2 {
            let s = m.shard(0, 0, r).unwrap();
            assert_eq!(s.layers.len(), 2);
            assert!(s.emb.is_some() && s.head.is_some());
        }
        // TP or layer-count mismatches are hard errors.
        assert!(migrate_checkpoint(&ck, &artifact(1, 1, 1, vec![2])).is_err());
        assert!(migrate_checkpoint(&ck, &artifact(2, 1, 1, vec![3])).is_err());
    }

    #[test]
    fn replan_moves_to_a_shallower_pipeline_on_the_shrunk_pool() {
        // 4 nodes x 2 GPUs, tiny model at tp2-pp4-dp1 m8. Killing stage 1
        // removes its node: 6 GPUs survive, and the only tp2 shape left
        // that any group can host is pp3 (a stage needs tp·dp = 2 GPUs;
        // each surviving group holds exactly one).
        let pool = bounded_pool(4, 2);
        let model = PlanModel::Llm(ModelConfig::tiny_100m());
        let mut q = PlanQuery::new(model.clone(), pool.clone(), 8);
        q.seq = 512;
        q.n_mb_options = vec![8];
        q.threads = 2;
        let ctx = q.eval_context();
        let c = crate::plan::Candidate {
            id: 0,
            tp: 2,
            pp: 4,
            dp: 1,
            kind: ScheduleKind::Stp,
            n_mb: 8,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            offload_variant: 0,
            ac: crate::sim::AcMode::None,
            map: None,
            vpp_gene: 0,
        };
        let e = crate::plan::evaluate(&ctx, &c);
        assert!(e.feasible, "tiny model at tp2-pp4 must fit");
        let old = PlanArtifact::for_evaluation(&ctx, &e);

        let (shrunk, new) =
            replan_after_loss(&model, &pool, &old, 1, 512, 1, 0.0, 4).unwrap();
        assert_eq!(shrunk.groups.len(), 3);
        assert_eq!(new.tp, 2, "TP must be preserved");
        assert_eq!(new.n_mb, old.n_mb, "global batch must be preserved");
        assert_eq!(new.pp, 3);
        assert_eq!(new.total_layers(), ModelConfig::tiny_100m().layers);

        // And the checkpoint migrates onto the new split.
        let ck = tiny_ckpt_for(&old);
        let m = migrate_checkpoint(&ck, &new).unwrap();
        assert_eq!(m.pp, 3);
        assert_eq!(m.total_layers(), ck.total_layers());
    }

    /// A checkpoint shaped like `a`'s split (init-weight payload — enough
    /// for migration shape tests).
    fn tiny_ckpt_for(a: &PlanArtifact) -> Checkpoint {
        let mut ck = tiny_ckpt(&vec![1; a.n_chunks()], a.tp);
        // Rewrite the split to the artifact's (layer payloads are per
        // (chunk, layer-index) inits; only shapes matter here).
        let dims = ManifestDims { layers: a.total_layers(), ..ck.dims.clone() };
        let mut shards = BTreeMap::new();
        for c in 0..a.n_chunks() {
            for r in 0..a.tp {
                let p = ChunkParams::init(
                    &dims,
                    c,
                    r,
                    0,
                    a.stage_layers[c],
                    c == 0,
                    c == a.n_chunks() - 1,
                    7,
                );
                shards.insert(
                    shard_key(0, c, r),
                    ChunkShard {
                        replica: 0,
                        chunk: c,
                        rank: r,
                        vit_layers: Vec::new(),
                        layers: p.layers,
                        emb: p.emb,
                        head: p.head,
                    },
                );
            }
        }
        ck.dims = ManifestDims { pp: a.pp, vpp: a.vpp, ..dims };
        ck.pp = a.pp;
        ck.vpp = a.vpp;
        ck.stage_layers = a.stage_layers.clone();
        ck.stage_vit_layers = vec![0; a.n_chunks()];
        ck.shards = shards;
        ck.validate().unwrap();
        ck
    }

    #[test]
    fn shrink_dp_clones_the_survivor_and_preserves_the_global_batch() {
        // dp=2 × n_mb=4 → dp=1 × n_mb=8 after replica 1 dies.
        let ck = tiny_ckpt_dp(&[1, 1], 2, 2);
        let s = shrink_dp_checkpoint(&ck, 1).unwrap();
        assert_eq!((s.dp, s.n_mb), (1, 8));
        assert_eq!(s.dp * s.n_mb, ck.dp * ck.n_mb, "global batch is pinned");
        for c in 0..2 {
            for r in 0..2 {
                let got = s.shard(0, c, r).unwrap();
                let want = ck.shard(0, c, r).unwrap();
                assert_eq!(got.layers, want.layers);
                assert_eq!(got.emb, want.emb);
                assert_eq!(got.head, want.head);
            }
        }
        assert!(s.rng_states.is_empty(), "replica renumbering invalidates RNG keys");
        s.validate().unwrap();

        // Killing replica 0 clones from the lowest survivor (replica 1).
        let s0 = shrink_dp_checkpoint(&ck, 0).unwrap();
        assert_eq!(s0.shard(0, 0, 0).unwrap().layers, ck.shard(1, 0, 0).unwrap().layers);

        // dp=1 is pipeline-resplit territory; off-grid replicas rejected.
        assert!(shrink_dp_checkpoint(&s, 0).is_err());
        assert!(shrink_dp_checkpoint(&ck, 2).is_err());

        // dp=3 × n_mb=4: the widest width under 3 dividing 12 is 2, so
        // one replica loss only costs one replica.
        let ck3 = tiny_ckpt_dp(&[1, 1], 1, 3);
        let s3 = shrink_dp_checkpoint(&ck3, 2).unwrap();
        assert_eq!((s3.dp, s3.n_mb), (2, 6));

        // And the plan rides along with only (dp, n_mb) changed.
        let a = artifact(2, 2, 1, vec![1, 1]);
        let shrunk_plan = shrink_dp_plan(&a, 1, 8);
        assert_eq!((shrunk_plan.dp, shrunk_plan.n_mb), (1, 8));
        assert_eq!(shrunk_plan.stage_layers, a.stage_layers);
        assert_eq!(shrunk_plan.kind, a.kind);
    }
}
