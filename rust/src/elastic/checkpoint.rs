//! Bit-exact training snapshots: the `stp-ckpt-v2` document.
//!
//! A [`Checkpoint`] captures everything the virtual executor needs to
//! continue a run as if it had never stopped: the per-(replica, chunk,
//! tp-rank) parameter shards ([`ChunkShard`]), the optimizer state (the
//! SGD engine is momentless, so moments serialize empty — the field
//! exists so Adam-class optimizers slot into the same schema), every
//! device thread's `exec::rng` stream position, the data-loader cursor
//! and the step counter.
//!
//! v2 grows the **replica axis** (DESIGN.md §14): shards key as
//! `d{replica}c{chunk}r{rank}`, RNG streams as
//! `d{replica}s{stage}r{rank}`, and the document records `dp` plus the
//! per-chunk ViT layer split (`stage_vit_layers`) for MLLM plans. v1
//! documents upgrade strictly on load — they describe one replica, so
//! every shard lands on replica 0, `dp = 1`, and ViT counts are zero.
//! This build always writes v2.
//!
//! **Bit-exactness is the contract**, not an aspiration: f32 tensors are
//! serialized as their IEEE-754 bit patterns (`f32::to_bits`, printed as
//! JSON integers — exact in the f64-backed parser), gradient
//! accumulators are provably zero at the step boundary the snapshot is
//! taken on (`sgd_step` zeroes them), and `tests/elastic.rs` asserts
//! save→restore→train equals an uninterrupted run bit-for-bit.
//!
//! **Crash-safety is also the contract**: [`Checkpoint::save`] writes to
//! a `.tmp` sibling and renames into place, so a mid-write death never
//! leaves a torn document under the final name, and
//! [`Checkpoint::load_latest`] falls back over the `ckpt-step-N.json`
//! chain (newest first) when `latest.json` is torn anyway (e.g. by an
//! older writer or a filesystem that lost the rename).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::json::Json;
use crate::config::ManifestDims;
use crate::exec::LayerParams;
use crate::runtime::Tensor;
use crate::Result;

/// Schema tag of the checkpoint format this crate writes.
pub const CKPT_SCHEMA: &str = "stp-ckpt-v2";

/// The pre-DP schema this crate still reads (upgraded to v2 on load).
pub const CKPT_SCHEMA_V1: &str = "stp-ckpt-v1";

/// Map key for a (replica, chunk, tp-rank) shard.
pub fn shard_key(replica: usize, chunk: usize, rank: usize) -> String {
    format!("d{replica}c{chunk}r{rank}")
}

/// Map key for a (replica, stage, tp-rank) device thread's RNG stream.
pub fn rng_key(replica: usize, stage: usize, rank: usize) -> String {
    format!("d{replica}s{stage}r{rank}")
}

/// One (replica, chunk, tp-rank)'s parameters — the executor's
/// ownership unit.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkShard {
    pub replica: usize,
    pub chunk: usize,
    pub rank: usize,
    /// ViT layers (MLLM chunks only; run before `layers` in the walk).
    pub vit_layers: Vec<LayerParams>,
    /// LM layers.
    pub layers: Vec<LayerParams>,
    /// Embedding table (chunk 0 only; replicated across TP ranks).
    pub emb: Option<Tensor>,
    /// LM head (last chunk only; replicated).
    pub head: Option<Tensor>,
}

/// A versioned, bit-exact snapshot of one training run.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Next step to run (steps `0..step` are complete).
    pub step: usize,
    pub seed: u64,
    /// Microbatches per replica per step (global batch = dp · n_mb · mb).
    pub n_mb: usize,
    /// Schedule kind name the segment ran ("stp", "zb-v", ...).
    pub schedule: String,
    pub tp: usize,
    pub pp: usize,
    /// Data-parallel replica count the shards were trained under.
    pub dp: usize,
    pub vpp: usize,
    pub dims: ManifestDims,
    /// LM layers per chunk (the split the shards were trained under).
    pub stage_layers: Vec<usize>,
    /// ViT layers per chunk (all-zero for text-only plans).
    pub stage_vit_layers: Vec<usize>,
    /// Data-loader cursor. The corpus keys batches by (step, mb) with a
    /// step-pinned stream today, so this equals `step`; recorded so a
    /// streaming loader can adopt the schema unchanged.
    pub data_cursor: usize,
    /// Optimizer family ("sgd"); moments are empty for it.
    pub optimizer: String,
    /// Per-device-thread RNG positions, keyed by [`rng_key`].
    pub rng_states: BTreeMap<String, u64>,
    /// Parameter shards, keyed by [`shard_key`].
    pub shards: BTreeMap<String, ChunkShard>,
}

/// f32 tensor → `{"shape": [...], "bits": [u32...]}` (bit-exact: a u32
/// is exactly representable in the parser's f64 numbers).
fn tensor_to_json(t: &Tensor) -> Result<Json> {
    let data = t.as_f32()?;
    let mut o = BTreeMap::new();
    o.insert(
        "shape".into(),
        Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    o.insert(
        "bits".into(),
        Json::Arr(data.iter().map(|x| Json::Num(x.to_bits() as f64)).collect()),
    );
    Ok(Json::Obj(o))
}

fn tensor_from_json(v: &Json, what: &str) -> Result<Tensor> {
    let shape: Vec<usize> = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("checkpoint: {what}: missing 'shape'"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow::anyhow!("checkpoint: {what}: bad shape")))
        .collect::<Result<_>>()?;
    let bits = v
        .get("bits")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("checkpoint: {what}: missing 'bits'"))?;
    let data: Vec<f32> = bits
        .iter()
        .map(|x| {
            x.as_f64()
                .filter(|b| b.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(b))
                .map(|b| f32::from_bits(b as u32))
                .ok_or_else(|| anyhow::anyhow!("checkpoint: {what}: bad bits entry"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "checkpoint: {what}: {} values for shape {:?}",
        data.len(),
        shape
    );
    Ok(Tensor::f32(data, &shape))
}

/// The nine per-layer tensors, in artifact-signature order.
const LAYER_FIELDS: [&str; 9] =
    ["gamma1", "wq", "wk", "wv", "wo", "gamma2", "wg", "wu", "wd"];

fn layer_to_json(p: &LayerParams) -> Result<Json> {
    let mut o = BTreeMap::new();
    for (name, t) in LAYER_FIELDS.iter().zip([
        &p.gamma1, &p.wq, &p.wk, &p.wv, &p.wo, &p.gamma2, &p.wg, &p.wu, &p.wd,
    ]) {
        o.insert((*name).into(), tensor_to_json(t)?);
    }
    Ok(Json::Obj(o))
}

fn layer_from_json(v: &Json, what: &str) -> Result<LayerParams> {
    let mut get = |name: &str| -> Result<Tensor> {
        let t = v
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: {what}: missing tensor '{name}'"))?;
        tensor_from_json(t, &format!("{what}.{name}"))
    };
    Ok(LayerParams {
        gamma1: get("gamma1")?,
        wq: get("wq")?,
        wk: get("wk")?,
        wv: get("wv")?,
        wo: get("wo")?,
        gamma2: get("gamma2")?,
        wg: get("wg")?,
        wu: get("wu")?,
        wd: get("wd")?,
    })
}

fn dims_to_json(d: &ManifestDims) -> Json {
    let mut o = BTreeMap::new();
    for (k, v) in [
        ("vocab", d.vocab),
        ("d", d.d),
        ("q_heads", d.q_heads),
        ("kv_heads", d.kv_heads),
        ("ffn", d.ffn),
        ("layers", d.layers),
        ("seq", d.seq),
        ("mb", d.mb),
        ("tp", d.tp),
        ("pp", d.pp),
        ("vpp", d.vpp),
    ] {
        o.insert(k.into(), Json::Num(v as f64));
    }
    Json::Obj(o)
}

fn dims_from_json(v: &Json) -> Result<ManifestDims> {
    let req = |k: &str| -> Result<usize> {
        v.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: dims: missing number '{k}'"))
    };
    Ok(ManifestDims {
        vocab: req("vocab")?,
        d: req("d")?,
        q_heads: req("q_heads")?,
        kv_heads: req("kv_heads")?,
        ffn: req("ffn")?,
        layers: req("layers")?,
        seq: req("seq")?,
        mb: req("mb")?,
        tp: req("tp")?,
        pp: req("pp")?,
        vpp: req("vpp")?,
    })
}

/// Step snapshots under `dir`, as `(step, path)` sorted newest first.
fn step_snapshots(dir: &Path) -> Result<Vec<(usize, PathBuf)>> {
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("listing checkpoint dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("listing {}: {e}", dir.display()))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(step) = name
            .strip_prefix("ckpt-step-")
            .and_then(|s| s.strip_suffix(".json"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            out.push((step, entry.path()));
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    Ok(out)
}

/// Delete step snapshots beyond the `keep` newest (`latest.json` is
/// never touched). Returns how many files were removed.
pub fn prune_snapshots(dir: &Path, keep: usize) -> Result<usize> {
    let snaps = step_snapshots(dir)?;
    let mut removed = 0;
    for (_, path) in snaps.iter().skip(keep.max(1)) {
        std::fs::remove_file(path)
            .map_err(|e| anyhow::anyhow!("pruning checkpoint {}: {e}", path.display()))?;
        removed += 1;
    }
    Ok(removed)
}

impl Checkpoint {
    /// The shard for a (replica, chunk, rank), if present.
    pub fn shard(&self, replica: usize, chunk: usize, rank: usize) -> Option<&ChunkShard> {
        self.shards.get(&shard_key(replica, chunk, rank))
    }

    pub fn n_chunks(&self) -> usize {
        self.pp * self.vpp
    }

    pub fn total_layers(&self) -> usize {
        self.stage_layers.iter().sum()
    }

    pub fn total_vit_layers(&self) -> usize {
        self.stage_vit_layers.iter().sum()
    }

    /// Shape consistency: every (replica, chunk, rank) shard present,
    /// layer counts matching the per-chunk splits, endpoints on the
    /// right chunks.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.tp >= 1 && self.pp >= 1 && self.dp >= 1 && self.vpp >= 1 && self.n_mb >= 1,
            "checkpoint: tp/pp/dp/vpp/n_mb must be positive"
        );
        let chunks = self.n_chunks();
        anyhow::ensure!(
            self.stage_layers.len() == chunks,
            "checkpoint: {} stage_layers for {} chunks (pp·vpp)",
            self.stage_layers.len(),
            chunks
        );
        anyhow::ensure!(
            self.stage_vit_layers.len() == chunks,
            "checkpoint: {} stage_vit_layers for {} chunks (pp·vpp)",
            self.stage_vit_layers.len(),
            chunks
        );
        anyhow::ensure!(
            self.shards.len() == self.dp * chunks * self.tp,
            "checkpoint: {} shards for a dp{} x {} chunks x tp{} grid",
            self.shards.len(),
            self.dp,
            chunks,
            self.tp
        );
        for q in 0..self.dp {
            for c in 0..chunks {
                for r in 0..self.tp {
                    let s = self.shard(q, c, r).ok_or_else(|| {
                        anyhow::anyhow!("checkpoint: missing shard d{q}c{c}r{r}")
                    })?;
                    anyhow::ensure!(
                        s.replica == q && s.chunk == c && s.rank == r,
                        "checkpoint: shard keyed d{q}c{c}r{r} claims (replica {}, chunk {}, \
                         rank {})",
                        s.replica,
                        s.chunk,
                        s.rank
                    );
                    anyhow::ensure!(
                        s.layers.len() == self.stage_layers[c],
                        "checkpoint: shard d{q}c{c}r{r} has {} layers, stage_layers says {}",
                        s.layers.len(),
                        self.stage_layers[c]
                    );
                    anyhow::ensure!(
                        s.vit_layers.len() == self.stage_vit_layers[c],
                        "checkpoint: shard d{q}c{c}r{r} has {} vit layers, stage_vit_layers \
                         says {}",
                        s.vit_layers.len(),
                        self.stage_vit_layers[c]
                    );
                    anyhow::ensure!(
                        s.emb.is_some() == (c == 0),
                        "checkpoint: shard d{q}c{c}r{r}: embedding belongs to chunk 0 only"
                    );
                    anyhow::ensure!(
                        s.head.is_some() == (c == chunks - 1),
                        "checkpoint: shard d{q}c{c}r{r}: head belongs to the last chunk only"
                    );
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Result<Json> {
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Str(CKPT_SCHEMA.into()));
        root.insert("step".into(), Json::Num(self.step as f64));
        root.insert("seed".into(), Json::Num(self.seed as f64));
        root.insert("n_mb".into(), Json::Num(self.n_mb as f64));
        root.insert("schedule".into(), Json::Str(self.schedule.clone()));
        root.insert("tp".into(), Json::Num(self.tp as f64));
        root.insert("pp".into(), Json::Num(self.pp as f64));
        root.insert("dp".into(), Json::Num(self.dp as f64));
        root.insert("vpp".into(), Json::Num(self.vpp as f64));
        root.insert("dims".into(), dims_to_json(&self.dims));
        root.insert(
            "stage_layers".into(),
            Json::Arr(self.stage_layers.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        root.insert(
            "stage_vit_layers".into(),
            Json::Arr(self.stage_vit_layers.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        root.insert("data_cursor".into(), Json::Num(self.data_cursor as f64));
        let mut opt = BTreeMap::new();
        opt.insert("family".into(), Json::Str(self.optimizer.clone()));
        opt.insert("moments".into(), Json::Obj(BTreeMap::new()));
        root.insert("optimizer".into(), Json::Obj(opt));
        root.insert(
            "rng_states".into(),
            Json::Obj(
                self.rng_states
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                    .collect(),
            ),
        );
        let mut shards = BTreeMap::new();
        for (key, s) in &self.shards {
            let mut o = BTreeMap::new();
            o.insert("replica".into(), Json::Num(s.replica as f64));
            o.insert("chunk".into(), Json::Num(s.chunk as f64));
            o.insert("rank".into(), Json::Num(s.rank as f64));
            o.insert(
                "vit_layers".into(),
                Json::Arr(s.vit_layers.iter().map(layer_to_json).collect::<Result<_>>()?),
            );
            o.insert(
                "layers".into(),
                Json::Arr(s.layers.iter().map(layer_to_json).collect::<Result<_>>()?),
            );
            if let Some(e) = &s.emb {
                o.insert("emb".into(), tensor_to_json(e)?);
            }
            if let Some(h) = &s.head {
                o.insert("head".into(), tensor_to_json(h)?);
            }
            shards.insert(key.clone(), Json::Obj(o));
        }
        root.insert("shards".into(), Json::Obj(shards));
        Ok(Json::Obj(root))
    }

    /// Strict parse + validate (the plan-artifact idiom: a half-parsed
    /// snapshot must never seed a training run). Reads v2 natively and
    /// upgrades v1 in place: a v1 document describes one replica, so its
    /// `c{c}r{r}` shards become `d0c{c}r{r}`, its `s{s}r{r}` RNG streams
    /// become `d0s{s}r{r}`, `dp = 1` and all ViT counts are zero.
    pub fn from_json(v: &Json) -> Result<Checkpoint> {
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: missing 'schema'"))?;
        let v1 = match schema {
            CKPT_SCHEMA => false,
            CKPT_SCHEMA_V1 => true,
            other => anyhow::bail!(
                "checkpoint: unsupported schema '{other}' (this build reads '{CKPT_SCHEMA}' \
                 and upgrades '{CKPT_SCHEMA_V1}')"
            ),
        };
        let req = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: missing number '{k}'"))
        };
        let seed = v
            .get("seed")
            .and_then(Json::as_f64)
            .filter(|s| s.fract() == 0.0 && *s >= 0.0)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: missing number 'seed'"))?
            as u64;
        let dims = dims_from_json(
            v.get("dims").ok_or_else(|| anyhow::anyhow!("checkpoint: missing 'dims'"))?,
        )?;
        let usize_arr = |k: &str| -> Result<Vec<usize>> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: missing array '{k}'"))?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("checkpoint: non-number in '{k}'"))
                })
                .collect()
        };
        let stage_layers = usize_arr("stage_layers")?;
        let dp = if v1 { 1 } else { req("dp")? };
        let stage_vit_layers =
            if v1 { vec![0; stage_layers.len()] } else { usize_arr("stage_vit_layers")? };
        let optimizer = v
            .get("optimizer")
            .and_then(|o| o.get("family"))
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: missing 'optimizer.family'"))?
            .to_string();
        let mut rng_states = BTreeMap::new();
        for (k, x) in v
            .get("rng_states")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: missing object 'rng_states'"))?
        {
            let s = x
                .as_f64()
                .filter(|b| b.fract() == 0.0 && *b >= 0.0)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: rng_states['{k}'] not an integer"))?;
            let key = if v1 { format!("d0{k}") } else { k.clone() };
            rng_states.insert(key, s as u64);
        }
        let mut shards = BTreeMap::new();
        for (key, s) in v
            .get("shards")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("checkpoint: missing object 'shards'"))?
        {
            let chunk = s
                .get("chunk")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: shard '{key}': missing 'chunk'"))?;
            let rank = s
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: shard '{key}': missing 'rank'"))?;
            let replica = if v1 {
                0
            } else {
                s.get("replica").and_then(Json::as_usize).ok_or_else(|| {
                    anyhow::anyhow!("checkpoint: shard '{key}': missing 'replica'")
                })?
            };
            let layer_arr = |field: &str| -> Result<Vec<LayerParams>> {
                match s.get(field) {
                    Some(arr) => arr
                        .as_arr()
                        .ok_or_else(|| {
                            anyhow::anyhow!("checkpoint: shard '{key}': '{field}' not an array")
                        })?
                        .iter()
                        .enumerate()
                        .map(|(l, lv)| layer_from_json(lv, &format!("shard {key} {field} {l}")))
                        .collect(),
                    None => Ok(Vec::new()),
                }
            };
            let vit_layers = layer_arr("vit_layers")?;
            let layers = s
                .get("layers")
                .ok_or_else(|| anyhow::anyhow!("checkpoint: shard '{key}': missing 'layers'"))
                .and_then(|_| layer_arr("layers"))?;
            let emb = s
                .get("emb")
                .map(|t| tensor_from_json(t, &format!("shard {key} emb")))
                .transpose()?;
            let head = s
                .get("head")
                .map(|t| tensor_from_json(t, &format!("shard {key} head")))
                .transpose()?;
            // v1 keys are `c{c}r{r}`; re-key onto replica 0 of the grid.
            let stored_key = if v1 { shard_key(0, chunk, rank) } else { key.clone() };
            shards.insert(
                stored_key,
                ChunkShard { replica, chunk, rank, vit_layers, layers, emb, head },
            );
        }
        let ck = Checkpoint {
            step: req("step")?,
            seed,
            n_mb: req("n_mb")?,
            schedule: v
                .get("schedule")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("checkpoint: missing string 'schedule'"))?
                .to_string(),
            tp: req("tp")?,
            pp: req("pp")?,
            dp,
            vpp: req("vpp")?,
            dims,
            stage_layers,
            stage_vit_layers,
            data_cursor: req("data_cursor")?,
            optimizer,
            rng_states,
            shards,
        };
        ck.validate()?;
        Ok(ck)
    }

    /// Crash-safe write: serialize to `{path}.tmp`, then rename into
    /// place. A death mid-write leaves only the orphaned tmp file — the
    /// final name is either absent or a complete document.
    pub fn save(&self, path: &Path) -> Result<()> {
        let text = self.to_json()?.to_string();
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = PathBuf::from(tmp_name);
        std::fs::write(&tmp, text)
            .map_err(|e| anyhow::anyhow!("writing checkpoint {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            anyhow::anyhow!("committing checkpoint {} -> {}: {e}", tmp.display(), path.display())
        })
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {}: {e}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))?;
        Self::from_json(&v).map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))
    }

    /// Load the newest usable snapshot under a checkpoint directory:
    /// `latest.json` if it parses, else the `ckpt-step-N.json` chain in
    /// descending step order (a torn file falls through to the previous
    /// complete snapshot).
    pub fn load_latest(dir: &Path) -> Result<Checkpoint> {
        let latest = dir.join("latest.json");
        if latest.exists() {
            if let Ok(ck) = Self::load(&latest) {
                return Ok(ck);
            }
        }
        for (_, path) in step_snapshots(dir)? {
            if let Ok(ck) = Self::load(&path) {
                return Ok(ck);
            }
        }
        anyhow::bail!(
            "no usable checkpoint under {} (latest.json absent or torn, and no complete \
             ckpt-step-N.json)",
            dir.display()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ChunkParams;

    fn tiny() -> Checkpoint {
        let dims = ManifestDims {
            vocab: 32,
            d: 16,
            q_heads: 4,
            kv_heads: 2,
            ffn: 24,
            layers: 2,
            seq: 8,
            mb: 1,
            tp: 2,
            pp: 2,
            vpp: 1,
        };
        let mut shards = BTreeMap::new();
        for c in 0..2 {
            for r in 0..2 {
                let p = ChunkParams::init(&dims, c, r, 0, 1, c == 0, c == 1, 7);
                shards.insert(
                    shard_key(0, c, r),
                    ChunkShard {
                        replica: 0,
                        chunk: c,
                        rank: r,
                        vit_layers: Vec::new(),
                        layers: p.layers.clone(),
                        emb: p.emb.clone(),
                        head: p.head.clone(),
                    },
                );
            }
        }
        let mut rng_states = BTreeMap::new();
        rng_states.insert(rng_key(0, 0, 0), 0xDEAD_BEEFu64);
        Checkpoint {
            step: 3,
            seed: 7,
            n_mb: 4,
            schedule: "stp".into(),
            tp: 2,
            pp: 2,
            dp: 1,
            vpp: 1,
            dims,
            stage_layers: vec![1, 1],
            stage_vit_layers: vec![0, 0],
            data_cursor: 3,
            optimizer: "sgd".into(),
            rng_states,
            shards,
        }
    }

    #[test]
    fn roundtrips_bit_exactly_through_json() {
        let ck = tiny();
        let text = ck.to_json().unwrap().to_string();
        let back = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        // PartialEq on Tensor compares the f32 payloads exactly, so this
        // is the bit-exactness assertion (to_bits spot-check included).
        assert_eq!(ck, back);
        let a = ck.shard(0, 0, 0).unwrap().layers[0].wq.as_f32().unwrap();
        let b = back.shard(0, 0, 0).unwrap().layers[0].wq.as_f32().unwrap();
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn extreme_bit_patterns_survive_serialization() {
        // Denormals, infinities, NaN payloads, -0.0: the bits channel
        // must carry them all unchanged.
        let vals = [0.0f32, -0.0, f32::MIN_POSITIVE / 2.0, f32::INFINITY, f32::NEG_INFINITY,
            f32::from_bits(0x7FC0_1234), f32::MAX, -f32::MIN_POSITIVE];
        let t = Tensor::f32(vals.to_vec(), &[vals.len()]);
        let j = tensor_to_json(&t).unwrap();
        let back = tensor_from_json(&Json::parse(&j.to_string()).unwrap(), "x").unwrap();
        for (a, b) in vals.iter().zip(back.as_f32().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn validation_rejects_inconsistent_snapshots() {
        let ck = tiny();
        // Missing shard.
        let mut broken = ck.clone();
        broken.shards.remove(&shard_key(0, 1, 1));
        assert!(broken.validate().is_err());
        // Layer count mismatch.
        let mut broken = ck.clone();
        broken.stage_layers = vec![2, 0];
        assert!(broken.validate().is_err());
        // Wrong schema tag.
        let text = ck.to_json().unwrap().to_string().replace(CKPT_SCHEMA, "stp-ckpt-v9");
        assert!(Checkpoint::from_json(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn v1_documents_upgrade_to_replica_zero() {
        // Demote tiny() to the v1 wire format by hand: strip the DP-era
        // fields and keys, then parse — the upgrade path must land every
        // shard on replica 0 with zero ViT layers.
        let ck = tiny();
        let Json::Obj(mut root) = ck.to_json().unwrap() else { unreachable!() };
        root.insert("schema".into(), Json::Str(CKPT_SCHEMA_V1.into()));
        root.remove("dp");
        root.remove("stage_vit_layers");
        let Some(Json::Obj(shards)) = root.remove("shards") else { unreachable!() };
        let mut v1_shards = BTreeMap::new();
        for (key, shard) in shards {
            let Json::Obj(mut o) = shard else { unreachable!() };
            o.remove("replica");
            o.remove("vit_layers");
            v1_shards.insert(key.strip_prefix("d0").unwrap().to_string(), Json::Obj(o));
        }
        root.insert("shards".into(), Json::Obj(v1_shards));
        let Some(Json::Obj(rngs)) = root.remove("rng_states") else { unreachable!() };
        let v1_rngs: BTreeMap<String, Json> = rngs
            .into_iter()
            .map(|(k, x)| (k.strip_prefix("d0").unwrap().to_string(), x))
            .collect();
        root.insert("rng_states".into(), Json::Obj(v1_rngs));

        let text = Json::Obj(root).to_string();
        let upgraded = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(upgraded, ck);
        // Re-serializing an upgraded snapshot writes v2.
        let rewritten = upgraded.to_json().unwrap().to_string();
        assert!(rewritten.contains(CKPT_SCHEMA));
    }

    #[test]
    fn save_load_roundtrip_on_disk_leaves_no_tmp() {
        let dir = std::env::temp_dir().join(format!("stp-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        let ck = tiny();
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        assert!(!dir.join("ck.json.tmp").exists(), "atomic save must clean up its tmp file");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prune_keeps_the_newest_snapshots_and_latest() {
        let dir = std::env::temp_dir().join(format!("stp-ckpt-prune-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ck = tiny();
        for step in [1usize, 2, 3, 4] {
            ck.save(&dir.join(format!("ckpt-step-{step}.json"))).unwrap();
        }
        ck.save(&dir.join("latest.json")).unwrap();
        let removed = prune_snapshots(&dir, 2).unwrap();
        assert_eq!(removed, 2);
        assert!(!dir.join("ckpt-step-1.json").exists());
        assert!(!dir.join("ckpt-step-2.json").exists());
        assert!(dir.join("ckpt-step-3.json").exists());
        assert!(dir.join("ckpt-step-4.json").exists());
        assert!(dir.join("latest.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_latest_falls_back_over_torn_files() {
        let dir = std::env::temp_dir().join(format!("stp-ckpt-torn-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut ck = tiny();
        ck.step = 2;
        ck.save(&dir.join("ckpt-step-2.json")).unwrap();
        ck.step = 4;
        ck.save(&dir.join("ckpt-step-4.json")).unwrap();
        ck.save(&dir.join("latest.json")).unwrap();
        // Healthy chain: latest.json wins.
        assert_eq!(Checkpoint::load_latest(&dir).unwrap().step, 4);
        // Tear latest.json and the newest snapshot mid-file: the scan
        // must fall back to the previous complete snapshot.
        let full = std::fs::read_to_string(dir.join("latest.json")).unwrap();
        std::fs::write(dir.join("latest.json"), &full[..full.len() / 2]).unwrap();
        std::fs::write(dir.join("ckpt-step-4.json"), &full[..full.len() / 3]).unwrap();
        assert_eq!(Checkpoint::load_latest(&dir).unwrap().step, 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
