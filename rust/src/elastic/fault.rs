//! Deterministic fault injection: the `stp-faults-v1` document.
//!
//! A [`FaultPlan`] is an explicit, replayable list of failure events —
//! dead ranks and stragglers — consumed by *both* replay engines:
//!
//! * the event-driven simulator ([`crate::sim::Simulator::with_faults`])
//!   applies events in **simulated time** (`at_secs` / `from_secs`
//!   within its single replayed iteration);
//! * the virtual executor ([`crate::exec::train`]) applies events in
//!   **step time**: device threads consult the plan at op boundaries,
//!   and a `dead-rank` at step `k` halts the whole pipeline at the
//!   step-`k` boundary — a consistent cut at which parameters equal the
//!   post-step-`k-1` state and gradient accumulators are zero, which is
//!   exactly what `stp-ckpt-v2` snapshots.
//!
//! Since the DP axis landed (DESIGN.md §14) every event also carries a
//! `replica` coordinate: hand-written v1 scripts that omit the field
//! parse as replica 0, so existing CI documents keep their meaning. The
//! executor quarantines the replica a dead rank belongs to; only when
//! the dying replica is the last one does the failure escalate to the
//! pipeline re-split path.
//!
//! The fail-stop model is deliberate: real elastic runners (and the
//! multi-controller design sketched in DESIGN.md §12) detect loss via
//! heartbeat and fence the step boundary before acting; injecting the
//! same announced boundary keeps recovery testable and bit-exact.
//!
//! Plans are JSON-loadable (`stp train --faults F.json`, hand-writable
//! for CI) and preset-generatable from a seed ([`FaultPlan::seeded`]),
//! so chaos runs are reproducible by construction.

use std::collections::BTreeMap;

use crate::config::json::Json;
use crate::exec::Rng;
use crate::Result;

/// Schema tag of the fault-plan format this crate reads and writes.
pub const FAULTS_SCHEMA: &str = "stp-faults-v1";

/// One injected failure event.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// A pipeline stage's device in `replica` fails before executing
    /// `step`. The simulator kills the device at `at_secs` into its
    /// iteration instead (ops not yet *started* there never run).
    DeadRank { step: usize, stage: usize, replica: usize, at_secs: f64 },
    /// A stage in `replica` computes `slowdown`× slower from `step` on
    /// (executor) / from `from_secs` on (simulator). Wall-clock only —
    /// numerics are untouched, so bit-determinism survives straggler
    /// injection.
    Straggler { step: usize, stage: usize, replica: usize, slowdown: f64, from_secs: f64 },
}

impl FaultEvent {
    /// The pipeline stage this event targets.
    pub fn stage(&self) -> usize {
        match *self {
            FaultEvent::DeadRank { stage, .. } => stage,
            FaultEvent::Straggler { stage, .. } => stage,
        }
    }

    /// The executor step this event fires at.
    pub fn step(&self) -> usize {
        match *self {
            FaultEvent::DeadRank { step, .. } => step,
            FaultEvent::Straggler { step, .. } => step,
        }
    }

    /// The data-parallel replica this event targets (0 when the script
    /// predates the DP axis).
    pub fn replica(&self) -> usize {
        match *self {
            FaultEvent::DeadRank { replica, .. } => replica,
            FaultEvent::Straggler { replica, .. } => replica,
        }
    }
}

/// A deterministic, replayable failure script.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: machinery compiled in, nothing injected. Runs
    /// under `Some(FaultPlan::none())` must be bit-equal to `None`.
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    /// A single dead-rank event: `stage` of replica 0 fails before
    /// executing `step`.
    pub fn dead_rank_at(step: usize, stage: usize) -> FaultPlan {
        Self::dead_rank_in_replica(step, stage, 0)
    }

    /// A single dead-rank event addressed at one replica of the DP grid.
    pub fn dead_rank_in_replica(step: usize, stage: usize, replica: usize) -> FaultPlan {
        FaultPlan { events: vec![FaultEvent::DeadRank { step, stage, replica, at_secs: 0.0 }] }
    }

    /// Seeded chaos preset: `n` events over `steps × stages` in replica
    /// 0, roughly one straggler per death, reproducible from the seed
    /// alone.
    pub fn seeded(seed: u64, n: usize, steps: usize, stages: usize) -> FaultPlan {
        let mut rng = Rng::for_purpose(seed, 0xFA, 0x17, 0);
        let events = (0..n)
            .map(|_| {
                let step = rng.below(steps.max(1));
                let stage = rng.below(stages.max(1));
                if rng.uniform() < 0.5 {
                    FaultEvent::DeadRank { step, stage, replica: 0, at_secs: 0.0 }
                } else {
                    FaultEvent::Straggler {
                        step,
                        stage,
                        replica: 0,
                        slowdown: 1.5 + 2.0 * rng.uniform(),
                        from_secs: 0.0,
                    }
                }
            })
            .collect();
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Earliest dead-rank event in `[start, end)` as `(step, stage,
    /// replica)` — the executor's halt boundary for one segment.
    pub fn first_death_in(&self, start: usize, end: usize) -> Option<(usize, usize, usize)> {
        self.events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::DeadRank { step, stage, replica, .. }
                    if (start..end).contains(&step) =>
                {
                    Some((step, stage, replica))
                }
                _ => None,
            })
            .min()
    }

    /// Combined slowdown factor for `stage` of `replica` active at
    /// `step` (events with `step' <= step` persist; 1.0 = healthy).
    pub fn straggler_factor(&self, step: usize, stage: usize, replica: usize) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::Straggler { step: s, stage: d, replica: q, slowdown, .. }
                    if d == stage && q == replica && s <= step =>
                {
                    Some(slowdown)
                }
                _ => None,
            })
            .product::<f64>()
            .max(1.0)
    }

    /// The plan that remains after recovering from a halt at `step`:
    /// consumed events (step ≤ halt) are dropped so the resumed segment
    /// does not re-fire them. Post-recovery, surviving events address
    /// the *new* stage/replica numbering (documented in DESIGN.md §12
    /// and §14).
    pub fn after(&self, step: usize) -> FaultPlan {
        FaultPlan { events: self.events.iter().filter(|e| e.step() > step).cloned().collect() }
    }

    /// Drop events that fell out of frame after a recovery reshaped the
    /// grid (stage ≥ `pp` after a re-split, replica ≥ `dp` after a
    /// shrink). Survivors address the new numbering.
    pub fn retain_in_frame(&self, pp: usize, dp: usize) -> FaultPlan {
        FaultPlan {
            events: self
                .events
                .iter()
                .filter(|e| e.stage() < pp && e.replica() < dp)
                .cloned()
                .collect(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            if let FaultEvent::Straggler { slowdown, .. } = e {
                anyhow::ensure!(
                    slowdown.is_finite() && *slowdown >= 1.0,
                    "fault plan: event {i}: slowdown must be finite and >= 1.0, got {slowdown}"
                );
            }
            let secs = match *e {
                FaultEvent::DeadRank { at_secs, .. } => at_secs,
                FaultEvent::Straggler { from_secs, .. } => from_secs,
            };
            anyhow::ensure!(
                secs.is_finite() && secs >= 0.0,
                "fault plan: event {i}: sim time must be finite and >= 0"
            );
        }
        Ok(())
    }

    /// Reject events that can never fire on a `pp × dp` grid running
    /// through step `end_step` (exclusive): a silently-dead fault script
    /// is a test that always passes, so the executor surfaces the
    /// mismatch before spawning a single thread.
    pub fn validate_for(&self, pp: usize, dp: usize, end_step: usize) -> Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            anyhow::ensure!(
                e.stage() < pp,
                "fault plan: event {i} targets stage {} but the run has {pp} stage(s) — \
                 it can never fire",
                e.stage()
            );
            anyhow::ensure!(
                e.replica() < dp,
                "fault plan: event {i} targets replica {} but the run has {dp} replica(s) — \
                 it can never fire",
                e.replica()
            );
            anyhow::ensure!(
                e.step() < end_step,
                "fault plan: event {i} fires at step {} but the run ends at step {end_step} — \
                 it can never fire",
                e.step()
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                match *e {
                    FaultEvent::DeadRank { step, stage, replica, at_secs } => {
                        o.insert("kind".into(), Json::Str("dead-rank".into()));
                        o.insert("step".into(), Json::Num(step as f64));
                        o.insert("stage".into(), Json::Num(stage as f64));
                        o.insert("replica".into(), Json::Num(replica as f64));
                        o.insert("at_secs".into(), Json::Num(at_secs));
                    }
                    FaultEvent::Straggler { step, stage, replica, slowdown, from_secs } => {
                        o.insert("kind".into(), Json::Str("straggler".into()));
                        o.insert("step".into(), Json::Num(step as f64));
                        o.insert("stage".into(), Json::Num(stage as f64));
                        o.insert("replica".into(), Json::Num(replica as f64));
                        o.insert("slowdown".into(), Json::Num(slowdown));
                        o.insert("from_secs".into(), Json::Num(from_secs));
                    }
                }
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Str(FAULTS_SCHEMA.into()));
        root.insert("events".into(), Json::Arr(events));
        Json::Obj(root)
    }

    /// Strict parse: unknown schema, kinds or missing fields are hard
    /// errors (the plan-artifact idiom — a half-parsed fault script must
    /// never drive a run). `replica` is the one optional coordinate:
    /// pre-DP scripts omit it and mean replica 0.
    pub fn from_json(v: &Json) -> Result<FaultPlan> {
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("fault plan: missing 'schema'"))?;
        anyhow::ensure!(
            schema == FAULTS_SCHEMA,
            "fault plan: unsupported schema '{schema}' (this build reads '{FAULTS_SCHEMA}')"
        );
        let arr = v
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("fault plan: missing array 'events'"))?;
        let mut events = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let req = |key: &str| -> Result<usize> {
                e.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("fault plan: event {i}: missing number '{key}'"))
            };
            let kind = e
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("fault plan: event {i}: missing 'kind'"))?;
            let replica = e.get("replica").and_then(Json::as_usize).unwrap_or(0);
            match kind {
                "dead-rank" => events.push(FaultEvent::DeadRank {
                    step: req("step")?,
                    stage: req("stage")?,
                    replica,
                    at_secs: e.get("at_secs").and_then(Json::as_f64).unwrap_or(0.0),
                }),
                "straggler" => events.push(FaultEvent::Straggler {
                    step: req("step")?,
                    stage: req("stage")?,
                    replica,
                    slowdown: e.get("slowdown").and_then(Json::as_f64).ok_or_else(|| {
                        anyhow::anyhow!("fault plan: event {i}: missing number 'slowdown'")
                    })?,
                    from_secs: e.get("from_secs").and_then(Json::as_f64).unwrap_or(0.0),
                }),
                other => anyhow::bail!("fault plan: event {i}: unknown kind '{other}'"),
            }
        }
        let plan = FaultPlan { events };
        plan.validate()?;
        Ok(plan)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing fault plan {path}: {e}"))
    }

    pub fn load(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading fault plan {path}: {e}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("fault plan {path}: {e}"))?;
        Self::from_json(&v).map_err(|e| anyhow::anyhow!("fault plan {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_through_json() {
        let p = FaultPlan {
            events: vec![
                FaultEvent::DeadRank { step: 2, stage: 1, replica: 1, at_secs: 0.5 },
                FaultEvent::Straggler {
                    step: 0,
                    stage: 0,
                    replica: 0,
                    slowdown: 3.0,
                    from_secs: 0.1,
                },
            ],
        };
        let back = FaultPlan::from_json(&Json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn hand_written_minimal_document_parses() {
        // The CI heredoc format: sim-time fields are optional, and a
        // pre-DP script with no 'replica' coordinate means replica 0.
        let txt = r#"{"schema":"stp-faults-v1","events":[{"kind":"dead-rank","step":2,"stage":1}]}"#;
        let p = FaultPlan::from_json(&Json::parse(txt).unwrap()).unwrap();
        assert_eq!(p.first_death_in(0, 10), Some((2, 1, 0)));
        assert_eq!(p.first_death_in(3, 10), None);
        assert_eq!(p.events[0].replica(), 0);
    }

    #[test]
    fn strict_parse_rejects_bad_documents() {
        let parse = |s: &str| FaultPlan::from_json(&Json::parse(s).unwrap());
        assert!(parse(r#"{"schema":"stp-faults-v99","events":[]}"#).is_err());
        assert!(parse(r#"{"schema":"stp-faults-v1"}"#).is_err());
        assert!(parse(r#"{"schema":"stp-faults-v1","events":[{"kind":"meteor","step":1,"stage":0}]}"#).is_err());
        assert!(parse(r#"{"schema":"stp-faults-v1","events":[{"kind":"straggler","step":1,"stage":0,"slowdown":0.5}]}"#).is_err());
    }

    #[test]
    fn seeded_preset_is_reproducible() {
        let a = FaultPlan::seeded(7, 5, 10, 4);
        let b = FaultPlan::seeded(7, 5, 10, 4);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 5);
        assert_ne!(a, FaultPlan::seeded(8, 5, 10, 4));
        a.validate().unwrap();
    }

    #[test]
    fn straggler_factors_compose_and_persist() {
        let p = FaultPlan {
            events: vec![
                FaultEvent::Straggler {
                    step: 1,
                    stage: 0,
                    replica: 0,
                    slowdown: 2.0,
                    from_secs: 0.0,
                },
                FaultEvent::Straggler {
                    step: 3,
                    stage: 0,
                    replica: 0,
                    slowdown: 1.5,
                    from_secs: 0.0,
                },
            ],
        };
        assert_eq!(p.straggler_factor(0, 0, 0), 1.0);
        assert_eq!(p.straggler_factor(1, 0, 0), 2.0);
        assert_eq!(p.straggler_factor(4, 0, 0), 3.0);
        assert_eq!(p.straggler_factor(4, 1, 0), 1.0);
        assert_eq!(p.straggler_factor(4, 0, 1), 1.0);
    }

    #[test]
    fn after_drops_consumed_events() {
        let p = FaultPlan {
            events: vec![
                FaultEvent::DeadRank { step: 2, stage: 1, replica: 0, at_secs: 0.0 },
                FaultEvent::DeadRank { step: 5, stage: 0, replica: 0, at_secs: 0.0 },
            ],
        };
        let rest = p.after(2);
        assert_eq!(rest.events.len(), 1);
        assert_eq!(rest.first_death_in(0, 10), Some((5, 0, 0)));
    }

    #[test]
    fn validate_for_rejects_unfireable_events() {
        let ok = FaultPlan::dead_rank_in_replica(2, 1, 1);
        ok.validate_for(2, 2, 4).unwrap();
        assert!(FaultPlan::dead_rank_at(2, 5).validate_for(2, 1, 4).is_err());
        assert!(FaultPlan::dead_rank_in_replica(2, 0, 3).validate_for(2, 2, 4).is_err());
        assert!(FaultPlan::dead_rank_at(4, 0).validate_for(2, 1, 4).is_err());
    }

    #[test]
    fn retain_in_frame_drops_out_of_grid_events() {
        let p = FaultPlan {
            events: vec![
                FaultEvent::DeadRank { step: 3, stage: 1, replica: 0, at_secs: 0.0 },
                FaultEvent::DeadRank { step: 4, stage: 3, replica: 0, at_secs: 0.0 },
                FaultEvent::DeadRank { step: 5, stage: 0, replica: 1, at_secs: 0.0 },
            ],
        };
        let kept = p.retain_in_frame(2, 1);
        assert_eq!(kept.events.len(), 1);
        assert_eq!(kept.first_death_in(0, 10), Some((3, 1, 0)));
    }
}
