//! Deterministic fault injection: the `stp-faults-v1` document.
//!
//! A [`FaultPlan`] is an explicit, replayable list of failure events —
//! dead ranks and stragglers — consumed by *both* replay engines:
//!
//! * the event-driven simulator ([`crate::sim::Simulator::with_faults`])
//!   applies events in **simulated time** (`at_secs` / `from_secs`
//!   within its single replayed iteration);
//! * the virtual executor ([`crate::exec::train`]) applies events in
//!   **step time**: device threads consult the plan at op boundaries,
//!   and a `dead-rank` at step `k` halts the whole pipeline at the
//!   step-`k` boundary — a consistent cut at which parameters equal the
//!   post-step-`k-1` state and gradient accumulators are zero, which is
//!   exactly what `stp-ckpt-v1` snapshots.
//!
//! The fail-stop model is deliberate: real elastic runners (and the
//! multi-controller design sketched in DESIGN.md §12) detect loss via
//! heartbeat and fence the step boundary before acting; injecting the
//! same announced boundary keeps recovery testable and bit-exact.
//!
//! Plans are JSON-loadable (`stp train --faults F.json`, hand-writable
//! for CI) and preset-generatable from a seed ([`FaultPlan::seeded`]),
//! so chaos runs are reproducible by construction.

use std::collections::BTreeMap;

use crate::config::json::Json;
use crate::exec::Rng;
use crate::Result;

/// Schema tag of the fault-plan format this crate reads and writes.
pub const FAULTS_SCHEMA: &str = "stp-faults-v1";

/// One injected failure event.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// A pipeline stage's device fails before executing `step`. The
    /// simulator kills the device at `at_secs` into its iteration
    /// instead (ops not yet *started* there never run).
    DeadRank { step: usize, stage: usize, at_secs: f64 },
    /// A stage computes `slowdown`× slower from `step` on (executor) /
    /// from `from_secs` on (simulator). Wall-clock only — numerics are
    /// untouched, so bit-determinism survives straggler injection.
    Straggler { step: usize, stage: usize, slowdown: f64, from_secs: f64 },
}

impl FaultEvent {
    /// The pipeline stage this event targets.
    pub fn stage(&self) -> usize {
        match *self {
            FaultEvent::DeadRank { stage, .. } => stage,
            FaultEvent::Straggler { stage, .. } => stage,
        }
    }

    /// The executor step this event fires at.
    pub fn step(&self) -> usize {
        match *self {
            FaultEvent::DeadRank { step, .. } => step,
            FaultEvent::Straggler { step, .. } => step,
        }
    }
}

/// A deterministic, replayable failure script.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: machinery compiled in, nothing injected. Runs
    /// under `Some(FaultPlan::none())` must be bit-equal to `None`.
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    /// A single dead-rank event: `stage` fails before executing `step`.
    pub fn dead_rank_at(step: usize, stage: usize) -> FaultPlan {
        FaultPlan { events: vec![FaultEvent::DeadRank { step, stage, at_secs: 0.0 }] }
    }

    /// Seeded chaos preset: `n` events over `steps × stages`, roughly
    /// one straggler per death, reproducible from the seed alone.
    pub fn seeded(seed: u64, n: usize, steps: usize, stages: usize) -> FaultPlan {
        let mut rng = Rng::for_purpose(seed, 0xFA, 0x17, 0);
        let events = (0..n)
            .map(|_| {
                let step = rng.below(steps.max(1));
                let stage = rng.below(stages.max(1));
                if rng.uniform() < 0.5 {
                    FaultEvent::DeadRank { step, stage, at_secs: 0.0 }
                } else {
                    FaultEvent::Straggler {
                        step,
                        stage,
                        slowdown: 1.5 + 2.0 * rng.uniform(),
                        from_secs: 0.0,
                    }
                }
            })
            .collect();
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Earliest dead-rank event in `[start, end)` as `(step, stage)` —
    /// the executor's halt boundary for one segment.
    pub fn first_death_in(&self, start: usize, end: usize) -> Option<(usize, usize)> {
        self.events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::DeadRank { step, stage, .. } if (start..end).contains(&step) => {
                    Some((step, stage))
                }
                _ => None,
            })
            .min()
    }

    /// Combined slowdown factor for `stage` active at `step` (events
    /// with `step' <= step` persist; 1.0 = healthy).
    pub fn straggler_factor(&self, step: usize, stage: usize) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match *e {
                FaultEvent::Straggler { step: s, stage: d, slowdown, .. }
                    if d == stage && s <= step =>
                {
                    Some(slowdown)
                }
                _ => None,
            })
            .product::<f64>()
            .max(1.0)
    }

    /// The plan that remains after recovering from a halt at `step`:
    /// consumed events (step ≤ halt) are dropped so the resumed segment
    /// does not re-fire them. Post-replan, surviving events address the
    /// *new* stage numbering (documented in DESIGN.md §12).
    pub fn after(&self, step: usize) -> FaultPlan {
        FaultPlan { events: self.events.iter().filter(|e| e.step() > step).cloned().collect() }
    }

    pub fn validate(&self) -> Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            if let FaultEvent::Straggler { slowdown, .. } = e {
                anyhow::ensure!(
                    slowdown.is_finite() && *slowdown >= 1.0,
                    "fault plan: event {i}: slowdown must be finite and >= 1.0, got {slowdown}"
                );
            }
            let secs = match *e {
                FaultEvent::DeadRank { at_secs, .. } => at_secs,
                FaultEvent::Straggler { from_secs, .. } => from_secs,
            };
            anyhow::ensure!(
                secs.is_finite() && secs >= 0.0,
                "fault plan: event {i}: sim time must be finite and >= 0"
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                match *e {
                    FaultEvent::DeadRank { step, stage, at_secs } => {
                        o.insert("kind".into(), Json::Str("dead-rank".into()));
                        o.insert("step".into(), Json::Num(step as f64));
                        o.insert("stage".into(), Json::Num(stage as f64));
                        o.insert("at_secs".into(), Json::Num(at_secs));
                    }
                    FaultEvent::Straggler { step, stage, slowdown, from_secs } => {
                        o.insert("kind".into(), Json::Str("straggler".into()));
                        o.insert("step".into(), Json::Num(step as f64));
                        o.insert("stage".into(), Json::Num(stage as f64));
                        o.insert("slowdown".into(), Json::Num(slowdown));
                        o.insert("from_secs".into(), Json::Num(from_secs));
                    }
                }
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Str(FAULTS_SCHEMA.into()));
        root.insert("events".into(), Json::Arr(events));
        Json::Obj(root)
    }

    /// Strict parse: unknown schema, kinds or missing fields are hard
    /// errors (the plan-artifact idiom — a half-parsed fault script must
    /// never drive a run).
    pub fn from_json(v: &Json) -> Result<FaultPlan> {
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("fault plan: missing 'schema'"))?;
        anyhow::ensure!(
            schema == FAULTS_SCHEMA,
            "fault plan: unsupported schema '{schema}' (this build reads '{FAULTS_SCHEMA}')"
        );
        let arr = v
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("fault plan: missing array 'events'"))?;
        let mut events = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let req = |key: &str| -> Result<usize> {
                e.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("fault plan: event {i}: missing number '{key}'"))
            };
            let kind = e
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("fault plan: event {i}: missing 'kind'"))?;
            match kind {
                "dead-rank" => events.push(FaultEvent::DeadRank {
                    step: req("step")?,
                    stage: req("stage")?,
                    at_secs: e.get("at_secs").and_then(Json::as_f64).unwrap_or(0.0),
                }),
                "straggler" => events.push(FaultEvent::Straggler {
                    step: req("step")?,
                    stage: req("stage")?,
                    slowdown: e.get("slowdown").and_then(Json::as_f64).ok_or_else(|| {
                        anyhow::anyhow!("fault plan: event {i}: missing number 'slowdown'")
                    })?,
                    from_secs: e.get("from_secs").and_then(Json::as_f64).unwrap_or(0.0),
                }),
                other => anyhow::bail!("fault plan: event {i}: unknown kind '{other}'"),
            }
        }
        let plan = FaultPlan { events };
        plan.validate()?;
        Ok(plan)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing fault plan {path}: {e}"))
    }

    pub fn load(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading fault plan {path}: {e}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("fault plan {path}: {e}"))?;
        Self::from_json(&v).map_err(|e| anyhow::anyhow!("fault plan {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_through_json() {
        let p = FaultPlan {
            events: vec![
                FaultEvent::DeadRank { step: 2, stage: 1, at_secs: 0.5 },
                FaultEvent::Straggler { step: 0, stage: 0, slowdown: 3.0, from_secs: 0.1 },
            ],
        };
        let back = FaultPlan::from_json(&Json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn hand_written_minimal_document_parses() {
        // The CI heredoc format: sim-time fields are optional.
        let txt = r#"{"schema":"stp-faults-v1","events":[{"kind":"dead-rank","step":2,"stage":1}]}"#;
        let p = FaultPlan::from_json(&Json::parse(txt).unwrap()).unwrap();
        assert_eq!(p.first_death_in(0, 10), Some((2, 1)));
        assert_eq!(p.first_death_in(3, 10), None);
    }

    #[test]
    fn strict_parse_rejects_bad_documents() {
        let parse = |s: &str| FaultPlan::from_json(&Json::parse(s).unwrap());
        assert!(parse(r#"{"schema":"stp-faults-v99","events":[]}"#).is_err());
        assert!(parse(r#"{"schema":"stp-faults-v1"}"#).is_err());
        assert!(parse(r#"{"schema":"stp-faults-v1","events":[{"kind":"meteor","step":1,"stage":0}]}"#).is_err());
        assert!(parse(r#"{"schema":"stp-faults-v1","events":[{"kind":"straggler","step":1,"stage":0,"slowdown":0.5}]}"#).is_err());
    }

    #[test]
    fn seeded_preset_is_reproducible() {
        let a = FaultPlan::seeded(7, 5, 10, 4);
        let b = FaultPlan::seeded(7, 5, 10, 4);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 5);
        assert_ne!(a, FaultPlan::seeded(8, 5, 10, 4));
        a.validate().unwrap();
    }

    #[test]
    fn straggler_factors_compose_and_persist() {
        let p = FaultPlan {
            events: vec![
                FaultEvent::Straggler { step: 1, stage: 0, slowdown: 2.0, from_secs: 0.0 },
                FaultEvent::Straggler { step: 3, stage: 0, slowdown: 1.5, from_secs: 0.0 },
            ],
        };
        assert_eq!(p.straggler_factor(0, 0), 1.0);
        assert_eq!(p.straggler_factor(1, 0), 2.0);
        assert_eq!(p.straggler_factor(4, 0), 3.0);
        assert_eq!(p.straggler_factor(4, 1), 1.0);
    }

    #[test]
    fn after_drops_consumed_events() {
        let p = FaultPlan {
            events: vec![
                FaultEvent::DeadRank { step: 2, stage: 1, at_secs: 0.0 },
                FaultEvent::DeadRank { step: 5, stage: 0, at_secs: 0.0 },
            ],
        };
        let rest = p.after(2);
        assert_eq!(rest.events.len(), 1);
        assert_eq!(rest.first_death_in(0, 10), Some((5, 0)));
    }
}
