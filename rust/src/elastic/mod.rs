//! Elastic training: fault injection, bit-exact checkpoint/restore and
//! mid-run replanning (DESIGN.md §12).
//!
//! This subsystem closes the plan → train handoff into a self-healing
//! loop. The three braided pieces:
//!
//! - [`fault`] — a deterministic, seeded [`FaultPlan`] (dead-rank and
//!   straggler events with a DP replica coordinate; JSON `stp-faults-v1`)
//!   injected into both the event-driven simulator and the virtual
//!   executor.
//! - [`checkpoint`] — versioned, crash-safe `stp-ckpt-v2` snapshots of
//!   the engine state (atomic tmp+rename writes, torn-file fallback),
//!   with save → restore → train proven *bit-identical* to an
//!   uninterrupted run (`tests/elastic.rs`).
//! - [`replan`] — on device loss, quarantine the dying replica while
//!   `dp > 1` ([`shrink_dp_checkpoint`]); only the last replica's loss
//!   shrinks the [`ClusterSpec`], re-invokes the planner's beam search
//!   under the fixed global batch and migrates the checkpoint onto the
//!   new stage split.
//!
//! [`run_elastic`] is the driver state machine (DESIGN.md §14):
//!
//! ```text
//!   TRAIN ──(segment completes)──────────────────────────▶ DONE
//!     │
//!     └─(dead rank at step k: halt at the step-k cut,
//!        snapshot written)
//!          │
//!          ├─ dp > 1:     QUARANTINE replica ▶ SHRINK-DP ─▶ TRAIN
//!          ├─ replan off: RESTORE(ckpt) ──────────────────▶ TRAIN
//!          └─ replan on:  SHRINK ▶ RE-SEARCH ▶ MIGRATE ───▶ TRAIN
//! ```
//!
//! Every transition is deterministic, so an elastic run is replayable
//! end-to-end from (seed, plan, fault plan).

pub mod checkpoint;
pub mod fault;
pub mod replan;

pub use checkpoint::{
    prune_snapshots, rng_key, shard_key, Checkpoint, ChunkShard, CKPT_SCHEMA, CKPT_SCHEMA_V1,
};
pub use fault::{FaultEvent, FaultPlan, FAULTS_SCHEMA};
pub use replan::{
    migrate_checkpoint, replan_after_loss, shrink_cluster, shrink_dp_checkpoint, shrink_dp_plan,
};

use crate::cluster::ClusterSpec;
use crate::exec::{train, RunReport, StepStat, TrainConfig};
use crate::plan::{PlanArtifact, PlanModel};
use crate::Result;

/// What the driver needs to re-plan after a device loss (the planner
/// query the original plan was searched with, minus the dead node).
#[derive(Debug, Clone)]
pub struct ReplanContext {
    pub model: PlanModel,
    /// The pool the *current* plan runs on; shrunk on every loss.
    pub cluster: ClusterSpec,
    pub seq: usize,
    pub mb_size: usize,
    /// `<= 0` uses the pool's default cap.
    pub mem_cap_gib: f64,
    pub beam_width: usize,
}

/// An elastic run: a base training config plus the optional replanning
/// context (`None` = restore-in-place on the original shape).
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    pub train: TrainConfig,
    pub replan: Option<ReplanContext>,
}

/// The full multi-segment outcome.
#[derive(Debug)]
pub struct ElasticReport {
    /// One [`RunReport`] per segment, in order.
    pub segments: Vec<RunReport>,
    /// The artifacts adopted at each pipeline re-split (empty when
    /// replanning is off, no device died, or every loss was absorbed by
    /// a DP shrink).
    pub replanned: Vec<PlanArtifact>,
    /// One human-readable marker per recovery, in order — "shrink-dp
    /// (…)", "re-split (…)" or "restore (…)" (CI greps these).
    pub recoveries: Vec<String>,
    /// The surviving pool after all losses (replanning runs only).
    pub cluster: Option<ClusterSpec>,
    /// Concatenated per-step stats across segments — the continuous
    /// loss trajectory `tests/elastic.rs` checks.
    pub steps: Vec<StepStat>,
}

impl ElasticReport {
    pub fn first_loss(&self) -> f32 {
        self.steps.first().map(|s| s.mean_loss).unwrap_or(f32::NAN)
    }
    pub fn last_loss(&self) -> f32 {
        self.steps.last().map(|s| s.mean_loss).unwrap_or(f32::NAN)
    }
}

/// Run training to the configured step target, surviving every injected
/// dead-rank fault: each death halts the segment at a step-boundary cut
/// and a snapshot is written. Recovery is tiered: while the run has
/// `dp > 1`, the dying replica is quarantined and the survivors continue
/// at the widest batch-preserving DP width (no re-split); only the last
/// replica's loss escalates to restore-in-place (replanning off) or
/// shrink → re-search → migrate (replanning on). Training resumes until
/// the target step is reached.
pub fn run_elastic(cfg: &ElasticConfig) -> Result<ElasticReport> {
    let mut seg_cfg = cfg.train.clone();
    let start = seg_cfg.resume.as_ref().map(|c| c.step).unwrap_or(0);
    let target_end = start + seg_cfg.steps;
    let has_faults = seg_cfg.faults.as_ref().map(|f| !f.is_empty()).unwrap_or(false);
    anyhow::ensure!(
        !has_faults || seg_cfg.checkpoint_dir.is_some(),
        "elastic: fault injection requires --checkpoint-dir (a restart needs a snapshot)"
    );

    let mut cluster = cfg.replan.as_ref().map(|r| r.cluster.clone());
    let mut segments: Vec<RunReport> = Vec::new();
    let mut replanned: Vec<PlanArtifact> = Vec::new();
    let mut recoveries: Vec<String> = Vec::new();
    // Each segment consumes at least one fault event, so this bounds the
    // loop without ever cutting a legitimate run short.
    let max_segments = seg_cfg.faults.as_ref().map(|f| f.events.len()).unwrap_or(0) + 1;
    for _ in 0..max_segments {
        let report = train(&seg_cfg)?;
        let halt = report.interrupted_at;
        let stage = report.fault_stage;
        let replica = report.fault_replica;
        let ckpt_path = report.checkpoint_path.clone();
        segments.push(report);
        let Some(halt) = halt else { break };

        let path = ckpt_path.ok_or_else(|| {
            anyhow::anyhow!("elastic: fault halted step {halt} but no checkpoint was written")
        })?;
        let mut ck = Checkpoint::load(&path)?;
        if ck.dp > 1 {
            // Tier 1: quarantine the dying replica, keep the pipeline.
            let dead = replica.expect("interrupted segments report the dead replica");
            let (old_dp, old_mb) = (ck.dp, ck.n_mb);
            ck = shrink_dp_checkpoint(&ck, dead)?;
            recoveries.push(format!(
                "shrink-dp (step {halt}: replica {dead} quarantined; dp {old_dp} -> {}, \
                 n_mb {old_mb} -> {})",
                ck.dp, ck.n_mb
            ));
            seg_cfg.dp = Some(ck.dp);
            seg_cfg.n_mb = ck.n_mb;
            if let Some(p) = &seg_cfg.plan {
                seg_cfg.plan = Some(shrink_dp_plan(p, ck.dp, ck.n_mb));
            }
        } else if let Some(rc) = &cfg.replan {
            let stage = stage.expect("interrupted segments report the dead stage");
            let pool = cluster.as_ref().expect("replan context carries the pool");
            let old = seg_cfg.plan.as_ref().ok_or_else(|| {
                anyhow::anyhow!("elastic: replanning requires running from a plan artifact")
            })?;
            let (shrunk, new_plan) = replan_after_loss(
                &rc.model,
                pool,
                old,
                stage,
                rc.seq,
                rc.mb_size,
                rc.mem_cap_gib,
                rc.beam_width,
            )?;
            ck = migrate_checkpoint(&ck, &new_plan)?;
            recoveries.push(format!(
                "re-split (step {halt}: stage {stage} lost; pp {} -> {})",
                old.pp, new_plan.pp
            ));
            // The migrated dims carry the new (pp, vpp); pin them so the
            // engine cannot re-derive a mismatching grid.
            seg_cfg.dims = Some(ck.dims.clone());
            seg_cfg.plan = Some(new_plan.clone());
            replanned.push(new_plan);
            cluster = Some(shrunk);
        } else {
            recoveries.push(format!("restore (step {halt}: same shape)"));
        }
        // Consumed events go; so do events the reshaped grid can no
        // longer host (a quarantined replica, a folded stage) — the
        // next segment's validation would otherwise reject them.
        seg_cfg.faults =
            seg_cfg.faults.as_ref().map(|f| f.after(halt).retain_in_frame(ck.pp, ck.dp));
        seg_cfg.steps = target_end - halt;
        seg_cfg.resume = Some(ck);
    }

    let steps = segments.iter().flat_map(|r| r.steps.iter().cloned()).collect();
    Ok(ElasticReport { segments, replanned, recoveries, cluster, steps })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_without_a_checkpoint_dir_are_rejected_up_front() {
        let mut train = TrainConfig::virtual_default();
        train.faults = Some(FaultPlan::dead_rank_at(1, 0));
        let err = run_elastic(&ElasticConfig { train, replan: None }).unwrap_err();
        assert!(err.to_string().contains("checkpoint-dir"), "{err}");
    }
}
