//! Cluster specification for (possibly heterogeneous) device pools.
//!
//! The paper's two testbeds — A800 SXM4 80G and H20 96G — already show
//! that hardware asymmetry changes which schedule wins (Fig. 13 /
//! Table 8: "TP bubbles matter less on H20"). A [`ClusterSpec`] describes
//! a mixed pool as *node groups* (`nodes × HardwareProfile`) plus an
//! inter-group link tier; a [`DeviceView`] resolves any PP rank to its
//! group (and thus its profile) and decides the link tier of each
//! pipeline hop. `ClusterSpec::uniform(hw)` reproduces the old
//! single-profile behavior exactly, so every pre-existing call site
//! converts mechanically.

use crate::config::json::Json;

use super::profile::HardwareProfile;
use super::topology::Topology;

use std::collections::BTreeMap;

/// One homogeneous group of nodes inside a pool.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeGroup {
    /// Node count; `0` means "unbounded" (the uniform-spec sentinel, so a
    /// uniform pool can host any topology, exactly like the old global
    /// profile did).
    pub nodes: usize,
    pub hw: HardwareProfile,
}

impl NodeGroup {
    /// Devices (GPUs) this group contributes.
    pub fn devices(&self) -> usize {
        if self.nodes == 0 {
            usize::MAX
        } else {
            self.nodes.saturating_mul(self.hw.gpus_per_node)
        }
    }
}

/// How pipeline stages are assigned to node groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum GroupOrder {
    /// Fill groups in declaration order (the only order a uniform pool
    /// enumerates — it is a no-op there).
    Declared,
    /// Fill the highest-effective-FLOPs group first (early stages, which
    /// hold the embedding and the deepest warm-up, land on fast devices).
    FastFirst,
    /// Round-robin stages across groups: every pipeline hop crosses the
    /// inter-group tier, but fast and slow devices alternate.
    Interleaved,
}

impl GroupOrder {
    pub fn name(&self) -> &'static str {
        match self {
            GroupOrder::Declared => "declared",
            GroupOrder::FastFirst => "fast-first",
            GroupOrder::Interleaved => "interleaved",
        }
    }
}

/// Resolution of one concrete topology against a [`ClusterSpec`]: which
/// group (and therefore which [`HardwareProfile`]) each PP rank runs on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceView {
    /// `groups[d]` = group index of PP rank `d`.
    groups: Vec<usize>,
}

impl DeviceView {
    /// Build a view from an explicit stage→group assignment. Normal code
    /// obtains views from [`ClusterSpec::device_view`]; this constructor
    /// exists for tests and for the symmetry-folding probe
    /// ([`ClusterSpec::replica_device_views`]), which assembles
    /// per-replica views out of finer-grained packings.
    pub fn from_groups(groups: Vec<usize>) -> DeviceView {
        DeviceView { groups }
    }

    /// Group index of a PP rank.
    pub fn group_of(&self, dev: usize) -> usize {
        self.groups[dev]
    }

    /// Number of PP ranks resolved.
    pub fn n_devices(&self) -> usize {
        self.groups.len()
    }

    /// PP-rank count per group (indexed by group id).
    pub fn ranks_per_group(&self, n_groups: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n_groups];
        for &g in &self.groups {
            counts[g] += 1;
        }
        counts
    }
}

/// One (JSON key, getter, setter) row per numeric [`HardwareProfile`]
/// field — the single source both `to_json` and `from_json` iterate, so
/// the two cannot drift when a profile field is added. (`gpus_per_node`
/// is handled separately: it is integral and validated.)
fn profile_fields() -> [(
    &'static str,
    fn(&HardwareProfile) -> f64,
    fn(&mut HardwareProfile, f64),
); 10] {
    [
        ("bf16_tflops", |hw| hw.bf16_tflops, |hw, v| hw.bf16_tflops = v),
        ("matmul_efficiency", |hw| hw.matmul_efficiency, |hw, v| hw.matmul_efficiency = v),
        ("hbm_gbps", |hw| hw.hbm_gbps, |hw, v| hw.hbm_gbps = v),
        ("nvlink_gbps", |hw| hw.nvlink_gbps, |hw, v| hw.nvlink_gbps = v),
        (
            "allreduce_efficiency",
            |hw| hw.allreduce_efficiency,
            |hw, v| hw.allreduce_efficiency = v,
        ),
        ("collective_latency", |hw| hw.collective_latency, |hw, v| hw.collective_latency = v),
        ("p2p_latency", |hw| hw.p2p_latency, |hw, v| hw.p2p_latency = v),
        ("internode_gbps", |hw| hw.internode_gbps, |hw, v| hw.internode_gbps = v),
        ("pcie_gbps", |hw| hw.pcie_gbps, |hw, v| hw.pcie_gbps = v),
        ("mem_gib", |hw| hw.mem_gib, |hw, v| hw.mem_gib = v),
    ]
}

/// A (possibly mixed) device pool: node groups plus inter-group link tier.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub groups: Vec<NodeGroup>,
    /// Inter-group link bandwidth per GPU, GB/s. `0.0` means "limited by
    /// the groups' own inter-node NICs" (cross-group hops then pay the
    /// slower of the two endpoints' `internode_gbps`).
    pub intergroup_gbps: f64,
}

impl ClusterSpec {
    /// A uniform pool: one unbounded group — behavior-preserving stand-in
    /// for the old global `HardwareProfile`.
    pub fn uniform(hw: HardwareProfile) -> ClusterSpec {
        ClusterSpec {
            name: hw.name.clone(),
            groups: vec![NodeGroup { nodes: 0, hw }],
            intergroup_gbps: 0.0,
        }
    }

    /// The mixed testbed preset: one A800 node + one H20 node (16 GPUs),
    /// joined by a shared IB tier. This is the runnable Fig. 13-style
    /// "who wins flips with hardware" demo pool.
    pub fn mixed_a800_h20() -> ClusterSpec {
        ClusterSpec {
            name: "mixed-a800-h20".into(),
            groups: vec![
                NodeGroup { nodes: 1, hw: HardwareProfile::a800() },
                NodeGroup { nodes: 1, hw: HardwareProfile::h20() },
            ],
            intergroup_gbps: 25.0,
        }
    }

    /// The fleet-scale mixed preset: 8 A800 nodes + 8 H20 nodes
    /// (128 GPUs) on the same shared IB tier — the pool the evo planner's
    /// stage→group placement search is benchmarked on (DESIGN.md §16).
    pub fn mixed_a800_h20_large() -> ClusterSpec {
        ClusterSpec {
            name: "mixed-a800-h20-large".into(),
            groups: vec![
                NodeGroup { nodes: 8, hw: HardwareProfile::a800() },
                NodeGroup { nodes: 8, hw: HardwareProfile::h20() },
            ],
            intergroup_gbps: 25.0,
        }
    }

    /// Whether every device shares one profile (the fast path that keeps
    /// all legacy arithmetic bit-for-bit identical).
    pub fn is_uniform(&self) -> bool {
        self.groups.len() <= 1 || self.groups.iter().all(|g| g.hw == self.groups[0].hw)
    }

    /// Total devices across groups (saturating; unbounded groups dominate).
    pub fn total_devices(&self) -> usize {
        self.groups.iter().fold(0usize, |acc, g| acc.saturating_add(g.devices()))
    }

    /// Smallest per-device memory across groups, GiB.
    pub fn min_mem_gib(&self) -> f64 {
        self.groups.iter().map(|g| g.hw.mem_gib).fold(f64::INFINITY, f64::min)
    }

    /// Largest per-device memory across groups, GiB.
    pub fn max_mem_gib(&self) -> f64 {
        self.groups.iter().map(|g| g.hw.mem_gib).fold(0.0, f64::max)
    }

    /// Group orderings worth enumerating for this pool. Uniform pools get
    /// exactly one (so planner candidate ids match the single-profile
    /// enumeration); mixed pools search fast-group-first vs interleaved.
    pub fn group_orders(&self) -> Vec<GroupOrder> {
        if self.is_uniform() {
            vec![GroupOrder::Declared]
        } else {
            vec![GroupOrder::FastFirst, GroupOrder::Interleaved]
        }
    }

    /// Profile of the group a view maps `dev` to.
    pub fn profile_of<'a>(&'a self, view: &DeviceView, dev: usize) -> &'a HardwareProfile {
        &self.groups[view.group_of(dev)].hw
    }

    /// Resolve a topology against this pool: assign each of the `pp`
    /// pipeline stages (each consuming `tp·cp` GPUs in every one of the
    /// `dp` replicas) to a group, in the requested order. `None` when the
    /// pool cannot host the topology.
    pub fn device_view(&self, topo: &Topology, order: GroupOrder) -> Option<DeviceView> {
        let per_stage = topo.tp * topo.cp * topo.dp;
        self.assign_units(order, topo.pp, per_stage).map(|groups| DeviceView { groups })
    }

    /// Per-replica stage→group resolution at `tp·cp` granularity. When the
    /// stage-granular [`Self::device_view`] succeeds, every replica sees
    /// that same view (the whole `tp·cp·dp` block of a stage sits in one
    /// group), so the two resolutions agree by construction. The finer
    /// packing only engages on pools that cannot host whole stages: units
    /// are placed in replica-major rank order (matching the Megatron rank
    /// layout, dp outermost), so replicas of the same stage may land on
    /// different groups — the asymmetry the symmetry fold must detect.
    /// `None` when even per-replica packing fails.
    pub fn replica_device_views(
        &self,
        topo: &Topology,
        order: GroupOrder,
    ) -> Option<Vec<DeviceView>> {
        if let Some(view) = self.device_view(topo, order) {
            return Some(vec![view; topo.dp.max(1)]);
        }
        let per_unit = topo.tp * topo.cp;
        let slots = self.assign_units(order, topo.pp.checked_mul(topo.dp)?, per_unit)?;
        Some(
            (0..topo.dp)
                .map(|r| DeviceView { groups: slots[r * topo.pp..(r + 1) * topo.pp].to_vec() })
                .collect(),
        )
    }

    /// Greedy group assignment shared by the stage-granular and
    /// replica-granular views: place `n_units` units of `per_unit` GPUs
    /// each, visiting groups in the requested order.
    fn assign_units(
        &self,
        order: GroupOrder,
        n_units: usize,
        per_unit: usize,
    ) -> Option<Vec<usize>> {
        if per_unit == 0 {
            return None;
        }
        let mut caps: Vec<usize> = self.groups.iter().map(|g| g.devices() / per_unit).collect();
        let seq: Vec<usize> = match order {
            GroupOrder::Declared | GroupOrder::Interleaved => (0..self.groups.len()).collect(),
            GroupOrder::FastFirst => {
                let mut idx: Vec<usize> = (0..self.groups.len()).collect();
                idx.sort_by(|&a, &b| {
                    self.groups[b]
                        .hw
                        .matmul_flops_per_sec()
                        .partial_cmp(&self.groups[a].hw.matmul_flops_per_sec())
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                idx
            }
        };

        let mut assigned = Vec::with_capacity(n_units);
        match order {
            GroupOrder::Interleaved => {
                while assigned.len() < n_units {
                    let before = assigned.len();
                    for &g in &seq {
                        if assigned.len() == n_units {
                            break;
                        }
                        if caps[g] > 0 {
                            caps[g] -= 1;
                            assigned.push(g);
                        }
                    }
                    if assigned.len() == before {
                        return None; // every group exhausted
                    }
                }
            }
            _ => {
                for &g in &seq {
                    while caps[g] > 0 && assigned.len() < n_units {
                        caps[g] -= 1;
                        assigned.push(g);
                    }
                }
                if assigned.len() < n_units {
                    return None;
                }
            }
        }
        Some(assigned)
    }

    /// Point-to-point time for one pipeline hop between PP ranks under a
    /// view. Same-group hops use that group's profile (node-locality rule
    /// unchanged); cross-group hops pay the slower link tier of the two
    /// endpoints (capped further by `intergroup_gbps` when set) plus the
    /// larger launch latency.
    pub fn p2p_secs(
        &self,
        view: &DeviceView,
        topo: &Topology,
        from: usize,
        to: usize,
        bytes: usize,
    ) -> f64 {
        if from == to {
            return 0.0;
        }
        let (gf, gt) = (view.group_of(from), view.group_of(to));
        if gf == gt {
            let hw = &self.groups[gf].hw;
            hw.p2p_secs(bytes, topo.pp_hop_cross_node(from, to, hw.gpus_per_node))
        } else {
            let a = &self.groups[gf].hw;
            let b = &self.groups[gt].hw;
            let mut bw = a.internode_gbps.min(b.internode_gbps);
            if self.intergroup_gbps > 0.0 {
                bw = bw.min(self.intergroup_gbps);
            }
            bytes as f64 / (bw * 1e9) + a.p2p_latency.max(b.p2p_latency)
        }
    }

    /// Serialize (the `--cluster <json>` file format).
    pub fn to_json(&self) -> Json {
        let groups: Vec<Json> = self
            .groups
            .iter()
            .map(|g| {
                let mut o = BTreeMap::new();
                o.insert("hw".into(), Json::Str(g.hw.name.clone()));
                o.insert("nodes".into(), Json::Num(g.nodes as f64));
                for (key, get, _) in profile_fields() {
                    o.insert(key.into(), Json::Num(get(&g.hw)));
                }
                o.insert("gpus_per_node".into(), Json::Num(g.hw.gpus_per_node as f64));
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("name".into(), Json::Str(self.name.clone()));
        root.insert("intergroup_gbps".into(), Json::Num(self.intergroup_gbps));
        root.insert("groups".into(), Json::Arr(groups));
        Json::Obj(root)
    }

    /// Parse a `--cluster <json>` value. Each group names a base preset
    /// (`"hw": "a800" | "h20" | "cpu"`) and may override any profile
    /// field; `nodes` defaults to 1 (`0` = unbounded).
    pub fn from_json(v: &Json) -> Result<ClusterSpec, String> {
        let groups_json =
            v.get("groups").and_then(Json::as_arr).ok_or("cluster spec needs a 'groups' array")?;
        if groups_json.is_empty() {
            return Err("cluster spec needs at least one group".into());
        }
        let mut groups = Vec::with_capacity(groups_json.len());
        for (i, g) in groups_json.iter().enumerate() {
            let base = g.get("hw").and_then(Json::as_str).unwrap_or("a800");
            let mut hw = match base {
                "a800" | "a800-sxm4-80g" => HardwareProfile::a800(),
                "h20" | "h20-96g" => HardwareProfile::h20(),
                "cpu" | "cpu-sim" => HardwareProfile::cpu_sim(),
                other => return Err(format!("group {i}: unknown hw preset '{other}'")),
            };
            hw.name = base.to_string();
            let num = |key: &str| g.get(key).and_then(Json::as_f64);
            for (key, _, set) in profile_fields() {
                if let Some(x) = num(key) {
                    set(&mut hw, x);
                }
            }
            if let Some(x) = num("gpus_per_node") {
                if x < 1.0 {
                    return Err(format!("group {i}: gpus_per_node must be >= 1"));
                }
                hw.gpus_per_node = x as usize;
            }
            let nodes = match num("nodes") {
                Some(x) if x < 0.0 || x.fract() != 0.0 => {
                    return Err(format!("group {i}: nodes must be a non-negative integer"));
                }
                Some(x) => x as usize, // 0 = unbounded
                None => 1,
            };
            groups.push(NodeGroup { nodes, hw });
        }
        Ok(ClusterSpec {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("cluster")
                .to_string(),
            groups,
            intergroup_gbps: v.get("intergroup_gbps").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_hosts_any_topology_on_group_zero() {
        let spec = ClusterSpec::uniform(HardwareProfile::a800());
        assert!(spec.is_uniform());
        assert_eq!(spec.group_orders(), vec![GroupOrder::Declared]);
        for topo in [Topology::new(8, 2, 1), Topology::new(1, 16, 4)] {
            let v = spec.device_view(&topo, GroupOrder::Declared).unwrap();
            assert_eq!(v.n_devices(), topo.pp);
            assert!((0..topo.pp).all(|d| v.group_of(d) == 0));
        }
    }

    #[test]
    fn uniform_p2p_matches_profile_arithmetic() {
        let hw = HardwareProfile::a800();
        let spec = ClusterSpec::uniform(hw.clone());
        let topo = Topology::new(8, 2, 1);
        let view = spec.device_view(&topo, GroupOrder::Declared).unwrap();
        let bytes = 64 << 20;
        let cross = topo.pp_hop_cross_node(0, 1, hw.gpus_per_node);
        assert_eq!(spec.p2p_secs(&view, &topo, 0, 1, bytes), hw.p2p_secs(bytes, cross));
        assert_eq!(spec.p2p_secs(&view, &topo, 1, 1, bytes), 0.0);
    }

    #[test]
    fn mixed_fast_first_puts_a800_on_early_stages() {
        let spec = ClusterSpec::mixed_a800_h20();
        assert!(!spec.is_uniform());
        let topo = Topology::new(4, 4, 1); // 4 GPUs per stage: 2 stages per node
        let v = spec.device_view(&topo, GroupOrder::FastFirst).unwrap();
        assert_eq!((0..4).map(|d| v.group_of(d)).collect::<Vec<_>>(), vec![0, 0, 1, 1]);
        let vi = spec.device_view(&topo, GroupOrder::Interleaved).unwrap();
        assert_eq!((0..4).map(|d| vi.group_of(d)).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn capacity_rejects_oversized_topologies() {
        let spec = ClusterSpec::mixed_a800_h20(); // 8 + 8 GPUs
        // 16 GPUs per stage: no group can host even one stage.
        assert!(spec.device_view(&Topology::new(8, 2, 2), GroupOrder::FastFirst).is_none());
        // 32 GPUs total requested.
        assert!(spec.device_view(&Topology::new(8, 4, 1), GroupOrder::FastFirst).is_none());
        // Exactly fits.
        assert!(spec.device_view(&Topology::new(8, 2, 1), GroupOrder::FastFirst).is_some());
    }

    #[test]
    fn replica_views_match_stage_view_when_it_exists() {
        // Whenever the stage-granular view resolves, every replica gets
        // exactly that view — the fold's symmetric fast path.
        let spec = ClusterSpec::mixed_a800_h20();
        let topo = Topology::new(4, 2, 2); // 8 GPUs per stage: one stage per node
        for order in spec.group_orders() {
            let stage = spec.device_view(&topo, order).unwrap();
            let views = spec.replica_device_views(&topo, order).unwrap();
            assert_eq!(views.len(), topo.dp);
            assert!(views.iter().all(|v| *v == stage));
        }
    }

    #[test]
    fn replica_views_pack_finer_than_stage_blocks() {
        // 2 GPUs per (tp·cp) unit, 6 replicas of a 1-stage pipeline:
        // the stage-granular view needs 12 contiguous GPUs in one group
        // (impossible on 8+8), but per-replica packing fits 4 replicas
        // on the A800 node and 2 on the H20 node — replicas straddle
        // groups, which is exactly what the fold must detect.
        let spec = ClusterSpec::mixed_a800_h20();
        let topo = Topology::new(2, 1, 6);
        assert!(spec.device_view(&topo, GroupOrder::Declared).is_none());
        let views = spec.replica_device_views(&topo, GroupOrder::Declared).unwrap();
        let groups: Vec<usize> = views.iter().map(|v| v.group_of(0)).collect();
        assert_eq!(groups, vec![0, 0, 0, 0, 1, 1]);
        // A pool that cannot host the replicas at all still declines.
        assert!(spec.replica_device_views(&Topology::new(8, 4, 1), GroupOrder::Declared).is_none());
    }

    #[test]
    fn cross_group_hop_pays_slower_tier() {
        let spec = ClusterSpec::mixed_a800_h20();
        let topo = Topology::new(8, 2, 1);
        let v = spec.device_view(&topo, GroupOrder::FastFirst).unwrap();
        assert_ne!(v.group_of(0), v.group_of(1));
        let bytes = 64 << 20;
        let t = spec.p2p_secs(&v, &topo, 0, 1, bytes);
        // intergroup 25 GB/s is the binding tier (A800 NIC 25, H20 NIC 50).
        let expect = bytes as f64 / (25.0 * 1e9)
            + spec.groups[0].hw.p2p_latency.max(spec.groups[1].hw.p2p_latency);
        assert!((t - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn json_roundtrip_preserves_spec() {
        let spec = ClusterSpec::mixed_a800_h20();
        let j = spec.to_json().to_string();
        let back = ClusterSpec::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.groups.len(), 2);
        assert_eq!(back.intergroup_gbps, spec.intergroup_gbps);
        for (a, b) in back.groups.iter().zip(&spec.groups) {
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.hw.bf16_tflops, b.hw.bf16_tflops);
            assert_eq!(a.hw.mem_gib, b.hw.mem_gib);
            assert_eq!(a.hw.gpus_per_node, b.hw.gpus_per_node);
        }
    }

    #[test]
    fn from_json_applies_overrides() {
        let j = Json::parse(
            r#"{"name":"derated","groups":[{"hw":"a800","nodes":2,"mem_gib":40.0}]}"#,
        )
        .unwrap();
        let spec = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(spec.name, "derated");
        assert_eq!(spec.groups[0].nodes, 2);
        assert_eq!(spec.groups[0].hw.mem_gib, 40.0);
        assert_eq!(spec.groups[0].hw.gpus_per_node, 8);
        assert!(ClusterSpec::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn from_json_rejects_bad_inputs() {
        let parse = |s: &str| ClusterSpec::from_json(&Json::parse(s).unwrap());
        // Unknown hw preset must error, not silently become an A800.
        assert!(parse(r#"{"groups":[{"hw":"h100"}]}"#).is_err());
        // Negative node counts must not alias the 0 = unbounded sentinel.
        assert!(parse(r#"{"groups":[{"hw":"a800","nodes":-1}]}"#).is_err());
        assert!(parse(r#"{"groups":[{"hw":"a800","nodes":1.5}]}"#).is_err());
        // 0 stays the documented unbounded marker.
        let spec = parse(r#"{"groups":[{"hw":"a800","nodes":0}]}"#).unwrap();
        assert_eq!(spec.groups[0].devices(), usize::MAX);
    }
}
