//! Hardware profiles.
//!
//! The paper's testbeds are NVIDIA A800 SXM4 80G (4 nodes × 8) and NVIDIA
//! H20 96G (PCIe Gen5 hosts). Neither exists here (repro band 0/5), so the
//! profile is the substitution: a small struct of peak FLOPs and bandwidths
//! that the analytic cost model consumes. The A800/H20 asymmetry (H20 has
//! ~2.1× the NVLink bandwidth at ~0.47× the BF16 FLOPs) is what reproduces
//! Fig. 13 / Table 8's "TP bubbles matter less on H20".


/// Peak capabilities of one accelerator plus its interconnect.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareProfile {
    pub name: String,
    /// Peak dense BF16 TFLOPs of one device.
    pub bf16_tflops: f64,
    /// Achievable matmul efficiency (fraction of peak for large GEMMs).
    pub matmul_efficiency: f64,
    /// HBM bandwidth, GB/s (bounds the norm units).
    pub hbm_gbps: f64,
    /// Intra-node (NVLink/NVSwitch) per-direction bandwidth, GB/s.
    pub nvlink_gbps: f64,
    /// Achievable fraction of link bandwidth for ring all-reduce (NCCL
    /// protocol overheads, chunking, SM contention).
    pub allreduce_efficiency: f64,
    /// Fixed launch/synchronization latency per collective, seconds.
    pub collective_latency: f64,
    /// Fixed launch latency per point-to-point transfer, seconds.
    pub p2p_latency: f64,
    /// Inter-node bandwidth per GPU, GB/s (IB HDR ≈ 25 GB/s).
    pub internode_gbps: f64,
    /// Host↔device (PCIe) bandwidth, GB/s — bounds activation offloading.
    pub pcie_gbps: f64,
    /// Device memory capacity, GiB (OOM detection for Table 4).
    pub mem_gib: f64,
    /// GPUs per node (TP groups larger than this pay inter-node AR).
    pub gpus_per_node: usize,
}

impl HardwareProfile {
    /// NVIDIA A800 SXM4 80G: A100 silicon with NVLink capped at 400 GB/s.
    pub fn a800() -> Self {
        Self {
            name: "a800-sxm4-80g".into(),
            bf16_tflops: 312.0,
            matmul_efficiency: 0.62,
            hbm_gbps: 2039.0,
            nvlink_gbps: 400.0,
            allreduce_efficiency: 0.55,
            collective_latency: 25e-6,
            p2p_latency: 5e-6,
            internode_gbps: 25.0,
            pcie_gbps: 32.0, // Gen4 x16
            mem_gib: 80.0,
            gpus_per_node: 8,
        }
    }

    /// NVIDIA H20 96G: Hopper interconnect (900 GB/s) with heavily cut
    /// compute (~148 TFLOPs BF16) and PCIe Gen5 hosts.
    pub fn h20() -> Self {
        Self {
            name: "h20-96g".into(),
            bf16_tflops: 148.0,
            matmul_efficiency: 0.72,
            hbm_gbps: 4000.0,
            nvlink_gbps: 900.0,
            allreduce_efficiency: 0.65,
            collective_latency: 20e-6,
            p2p_latency: 5e-6,
            internode_gbps: 50.0,
            pcie_gbps: 64.0, // Gen5 x16
            mem_gib: 96.0,
            gpus_per_node: 8,
        }
    }

    /// The CPU host running the real executor (sanity profile for the
    /// measured-cost path; numbers are per-core rough order).
    pub fn cpu_sim() -> Self {
        Self {
            name: "cpu-sim".into(),
            bf16_tflops: 0.05,
            matmul_efficiency: 0.5,
            hbm_gbps: 20.0,
            nvlink_gbps: 10.0,
            allreduce_efficiency: 0.8,
            collective_latency: 5e-6,
            p2p_latency: 5e-6,
            internode_gbps: 10.0,
            pcie_gbps: 10.0,
            mem_gib: 16.0,
            gpus_per_node: 64,
        }
    }

    /// Effective per-device achievable matmul FLOPs (TFLOPs → FLOPs/s).
    pub fn matmul_flops_per_sec(&self) -> f64 {
        self.bf16_tflops * 1e12 * self.matmul_efficiency
    }

    /// Ring all-reduce time (seconds) for `bytes` over a TP group of size
    /// `t`: `2·(t-1)/t · bytes / bw`, with the bandwidth picked by whether
    /// the group fits in one node.
    pub fn allreduce_secs(&self, bytes: usize, t: usize) -> f64 {
        if t <= 1 {
            return 0.0;
        }
        let bw = if t <= self.gpus_per_node { self.nvlink_gbps } else { self.internode_gbps };
        let factor = 2.0 * (t as f64 - 1.0) / t as f64;
        factor * bytes as f64 / (bw * self.allreduce_efficiency * 1e9) + self.collective_latency
    }

    /// Point-to-point transfer time (seconds) for `bytes`; `cross_node`
    /// selects the interconnect tier.
    pub fn p2p_secs(&self, bytes: usize, cross_node: bool) -> f64 {
        let bw = if cross_node { self.internode_gbps } else { self.nvlink_gbps };
        bytes as f64 / (bw * 1e9) + self.p2p_latency
    }

    /// Host offload/reload time for `bytes` over PCIe.
    pub fn pcie_secs(&self, bytes: usize) -> f64 {
        bytes as f64 / (self.pcie_gbps * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h20_has_more_bandwidth_less_compute_than_a800() {
        let a = HardwareProfile::a800();
        let h = HardwareProfile::h20();
        assert!(h.nvlink_gbps > 2.0 * a.nvlink_gbps);
        assert!(h.bf16_tflops < 0.5 * a.bf16_tflops);
    }

    #[test]
    fn allreduce_zero_for_single_rank() {
        assert_eq!(HardwareProfile::a800().allreduce_secs(1 << 20, 1), 0.0);
    }

    #[test]
    fn allreduce_grows_with_group_size() {
        let hw = HardwareProfile::a800();
        let b = 64 << 20;
        assert!(hw.allreduce_secs(b, 4) > hw.allreduce_secs(b, 2));
        assert!(hw.allreduce_secs(b, 8) > hw.allreduce_secs(b, 4));
    }

    #[test]
    fn allreduce_crossing_node_boundary_is_much_slower() {
        let hw = HardwareProfile::a800();
        let b = 64 << 20;
        assert!(hw.allreduce_secs(b, 16) > 5.0 * hw.allreduce_secs(b, 8));
    }

    #[test]
    fn ring_factor_approaches_two() {
        let hw = HardwareProfile::a800();
        let b = 1 << 30;
        let t8 = hw.allreduce_secs(b, 8);
        let expect = 2.0 * 7.0 / 8.0 * (b as f64)
            / (hw.nvlink_gbps * hw.allreduce_efficiency * 1e9)
            + hw.collective_latency;
        assert!((t8 - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn p2p_latency_is_a_profile_field() {
        let mut hw = HardwareProfile::a800();
        assert_eq!(hw.p2p_secs(0, false), hw.p2p_latency);
        hw.p2p_latency = 1e-3;
        assert_eq!(hw.p2p_secs(0, true), 1e-3);
    }

    #[test]
    fn collective_latency_dominates_tiny_messages() {
        let hw = HardwareProfile::a800();
        let t = hw.allreduce_secs(64, 8);
        assert!(t >= hw.collective_latency);
        assert!(t < 2.0 * hw.collective_latency);
    }
}
