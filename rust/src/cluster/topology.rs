//! Parallel topology: TP × PP × DP (× CP) device grid.


/// A TP×PP×DP(×CP) device grid. Ranks are laid out TP-fastest (Megatron
/// order): `global = ((dp * pp_size + pp) * cp_size + cp) * tp_size + tp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
    pub cp: usize,
    /// Virtual pipeline stages (model chunks) per PP rank. The paper fixes
    /// this to 2 for all compared schedules.
    pub vpp: usize,
}

impl Topology {
    /// TP×PP×DP with 2 virtual stages per device (the paper's setting).
    pub fn new(tp: usize, pp: usize, dp: usize) -> Self {
        Self { tp, pp, dp, cp: 1, vpp: 2 }
    }

    pub fn with_cp(mut self, cp: usize) -> Self {
        self.cp = cp;
        self
    }

    pub fn with_vpp(mut self, vpp: usize) -> Self {
        self.vpp = vpp;
        self
    }

    /// Total devices.
    pub fn world_size(&self) -> usize {
        self.tp * self.pp * self.dp * self.cp
    }

    /// Total model chunks (virtual stages) across the pipeline.
    pub fn chunks(&self) -> usize {
        self.pp * self.vpp
    }

    /// Global rank from coordinates.
    pub fn rank_of(&self, dp: usize, pp: usize, cp: usize, tp: usize) -> usize {
        ((dp * self.pp + pp) * self.cp + cp) * self.tp + tp
    }

    /// (dp, pp, cp, tp) coordinates of a global rank.
    pub fn coords_of(&self, rank: usize) -> (usize, usize, usize, usize) {
        let tp = rank % self.tp;
        let r = rank / self.tp;
        let cp = r % self.cp;
        let r = r / self.cp;
        let pp = r % self.pp;
        let dp = r / self.pp;
        (dp, pp, cp, tp)
    }

    /// Device (PP-rank) that owns virtual-stage `chunk` under the **V-shape
    /// dataflow** (paper §4.1, Fig. 4): chunk 0 runs stages 0..p-1
    /// descending the grid, chunk 1 runs p-1..0 back up, so a microbatch
    /// traverses devices `0,1,..,p-1,p-1,..,1,0`.
    pub fn v_shape_device(&self, chunk: usize) -> usize {
        assert!(chunk < self.chunks());
        let round = chunk / self.pp;
        let pos = chunk % self.pp;
        if round % 2 == 0 {
            pos
        } else {
            self.pp - 1 - pos
        }
    }

    /// Device for `chunk` under the **parallel dataflow** of 1F1B-I
    /// (Megatron interleaving): chunk `c` lives on device `c % pp`.
    pub fn interleaved_device(&self, chunk: usize) -> usize {
        assert!(chunk < self.chunks());
        chunk % self.pp
    }

    /// Whether a pipeline hop between PP ranks `a` and `b` crosses a node
    /// boundary, assuming nodes hold `gpus_per_node / tp` consecutive PP
    /// ranks of one DP replica.
    pub fn pp_hop_cross_node(&self, a: usize, b: usize, gpus_per_node: usize) -> bool {
        let per_node = (gpus_per_node / (self.tp * self.cp)).max(1);
        (a / per_node) != (b / per_node)
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tp{}-pp{}-dp{}", self.tp, self.pp, self.dp)?;
        if self.cp > 1 {
            write!(f, "-cp{}", self.cp)?;
        }
        write!(f, "-v{}", self.vpp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_coords_roundtrip() {
        let t = Topology::new(4, 4, 2).with_cp(2);
        for r in 0..t.world_size() {
            let (dp, pp, cp, tp) = t.coords_of(r);
            assert_eq!(t.rank_of(dp, pp, cp, tp), r);
        }
    }

    #[test]
    fn world_size() {
        assert_eq!(Topology::new(8, 2, 1).world_size(), 16);
        assert_eq!(Topology::new(4, 8, 1).world_size(), 32);
    }

    #[test]
    fn v_shape_is_a_v() {
        // p=4, vpp=2: chunks 0..3 on devices 0,1,2,3; chunks 4..7 on 3,2,1,0.
        let t = Topology::new(1, 4, 1);
        let path: Vec<usize> = (0..t.chunks()).map(|c| t.v_shape_device(c)).collect();
        assert_eq!(path, vec![0, 1, 2, 3, 3, 2, 1, 0]);
    }

    #[test]
    fn v_shape_first_device_holds_first_and_last_chunk() {
        // The early backward on device 0 (Fig. 4) requires chunk `2p-1` there.
        let t = Topology::new(1, 4, 1);
        assert_eq!(t.v_shape_device(0), 0);
        assert_eq!(t.v_shape_device(t.chunks() - 1), 0);
    }

    #[test]
    fn interleaved_is_parallel_flow() {
        let t = Topology::new(1, 4, 1);
        let path: Vec<usize> = (0..t.chunks()).map(|c| t.interleaved_device(c)).collect();
        assert_eq!(path, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn display_compact() {
        assert_eq!(Topology::new(8, 2, 1).to_string(), "tp8-pp2-dp1-v2");
    }
}
