//! Cluster description: hardware profiles, parallel topology, and the
//! layer→stage partitioner (LLM uniform split and MLLM ViT-first split).

mod partition;
mod profile;
mod topology;

pub use partition::{partition_llm, partition_mllm, StagePlan, ChunkContent};
pub use profile::HardwareProfile;
pub use topology::Topology;
