//! Cluster description: hardware profiles, (possibly heterogeneous) pool
//! specifications, parallel topology, and the layer→stage partitioners
//! (LLM uniform split, stage-time-balanced heterogeneous split, MLLM
//! ViT-first split).

mod partition;
mod profile;
mod spec;
mod topology;

pub use partition::{
    partition_llm, partition_llm_weighted, partition_mllm, ChunkContent, StagePlan,
};
pub use profile::HardwareProfile;
pub use spec::{ClusterSpec, DeviceView, GroupOrder, NodeGroup};
pub use topology::Topology;
