//! Layer → virtual-stage partitioner.
//!
//! Paper §5.1: for LLMs the model is split uniformly across `pp·vpp` chunks
//! with the **last stage two layers short** to compensate for the output
//! head over Qwen's 152k vocabulary. For MLLMs the ViT encoder occupies the
//! first virtual stage on device 0 and the LM is distributed uniformly over
//! the remaining chunks (again, last chunk two layers short).


use crate::model::{MllmConfig, ModelConfig};

/// What one virtual stage (model chunk) contains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkContent {
    /// Number of LM (decoder) layers in this chunk.
    pub lm_layers: usize,
    /// Number of ViT (encoder) layers in this chunk (MLLM only).
    pub vit_layers: usize,
    /// First chunk: owns the token embedding.
    pub has_embed: bool,
    /// Last chunk: owns the LM head + loss.
    pub has_head: bool,
}

/// The partition of a model over `pp·vpp` chunks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StagePlan {
    pub chunks: Vec<ChunkContent>,
}

impl StagePlan {
    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    pub fn total_lm_layers(&self) -> usize {
        self.chunks.iter().map(|c| c.lm_layers).sum()
    }

    pub fn total_vit_layers(&self) -> usize {
        self.chunks.iter().map(|c| c.vit_layers).sum()
    }
}

/// Uniform LLM split over `n_chunks` virtual stages, last stage two layers
/// short (floored at 1). Remainder layers go to the earliest stages.
pub fn partition_llm(model: &ModelConfig, n_chunks: usize) -> StagePlan {
    assert!(n_chunks >= 1);
    assert!(
        model.layers >= n_chunks,
        "{} layers cannot fill {} chunks",
        model.layers,
        n_chunks
    );
    let mut counts = vec![0usize; n_chunks];
    if n_chunks == 1 {
        counts[0] = model.layers;
    } else {
        // Give the last chunk (base - 2); spread the rest uniformly.
        let base = (model.layers + 2) / n_chunks;
        let last = base.saturating_sub(2).max(1);
        let mut remaining = model.layers - last;
        for c in counts.iter_mut().take(n_chunks - 1) {
            *c = remaining / (n_chunks - 1);
        }
        let mut leftover = remaining - counts[..n_chunks - 1].iter().sum::<usize>();
        for c in counts.iter_mut().take(n_chunks - 1) {
            if leftover == 0 {
                break;
            }
            *c += 1;
            leftover -= 1;
        }
        counts[n_chunks - 1] = last;
        remaining = 0;
        let _ = remaining;
    }
    let chunks = counts
        .iter()
        .enumerate()
        .map(|(i, &n)| ChunkContent {
            lm_layers: n,
            vit_layers: 0,
            has_embed: i == 0,
            has_head: i == n_chunks - 1,
        })
        .collect();
    StagePlan { chunks }
}

/// Heterogeneity-aware LLM split: distribute layers over `n_chunks` in
/// proportion to `weights` (each chunk's effective FLOPs — the profile of
/// the device that will execute it), so *stage time* (layers ÷ effective
/// FLOPs) balances instead of layer count. Every chunk keeps ≥ 1 layer;
/// the last chunk donates up to two layers to the fastest chunk to
/// compensate for the output head, mirroring `partition_llm`'s §5.1 rule.
/// Deterministic: largest-remainder apportionment, ties to lower index.
pub fn partition_llm_weighted(
    model: &ModelConfig,
    n_chunks: usize,
    weights: &[f64],
) -> StagePlan {
    assert_eq!(weights.len(), n_chunks, "one weight per chunk");
    assert!(n_chunks >= 1);
    assert!(
        model.layers >= n_chunks,
        "{} layers cannot fill {} chunks",
        model.layers,
        n_chunks
    );
    assert!(weights.iter().all(|&w| w > 0.0), "weights must be positive");

    let mut counts = vec![1usize; n_chunks];
    let remaining = model.layers - n_chunks;
    let sum_w: f64 = weights.iter().sum();
    let shares: Vec<f64> = weights.iter().map(|w| remaining as f64 * w / sum_w).collect();
    for (c, s) in counts.iter_mut().zip(&shares) {
        *c += *s as usize;
    }
    let mut leftover = model.layers - counts.iter().sum::<usize>();
    // Largest fractional part first; ties broken toward the lower index.
    let mut order: Vec<usize> = (0..n_chunks).collect();
    order.sort_by(|&a, &b| {
        let fa = shares[a] - shares[a].floor();
        let fb = shares[b] - shares[b].floor();
        fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    for &i in order.iter().cycle().take(n_chunks * (leftover / n_chunks.max(1) + 1)) {
        if leftover == 0 {
            break;
        }
        counts[i] += 1;
        leftover -= 1;
    }

    // Head compensation: the last chunk carries the vocabulary head, so
    // shift up to two of its layers onto the fastest chunk.
    if n_chunks >= 2 {
        let fastest = (0..n_chunks - 1)
            .max_by(|&a, &b| {
                weights[a]
                    .partial_cmp(&weights[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.cmp(&a))
            })
            .unwrap();
        let give = 2.min(counts[n_chunks - 1].saturating_sub(1));
        counts[n_chunks - 1] -= give;
        counts[fastest] += give;
    }

    let chunks = counts
        .iter()
        .enumerate()
        .map(|(i, &n)| ChunkContent {
            lm_layers: n,
            vit_layers: 0,
            has_embed: i == 0,
            has_head: i == n_chunks - 1,
        })
        .collect();
    StagePlan { chunks }
}

/// MLLM split: the whole ViT on chunk 0 (first virtual stage of device 0),
/// the LM uniformly over chunks `1..n_chunks` with the last two layers
/// short (paper §5.1).
pub fn partition_mllm(model: &MllmConfig, n_chunks: usize) -> StagePlan {
    assert!(n_chunks >= 2, "MLLM needs at least 2 chunks (ViT + LM)");
    let lm_chunks = n_chunks - 1;
    let mut plan = partition_llm(&model.lm, lm_chunks);
    let mut chunks = vec![ChunkContent {
        lm_layers: 0,
        vit_layers: model.vit.layers,
        has_embed: true,
        has_head: false,
    }];
    for (i, c) in plan.chunks.drain(..).enumerate() {
        chunks.push(ChunkContent {
            lm_layers: c.lm_layers,
            vit_layers: 0,
            has_embed: false,
            has_head: i == lm_chunks - 1,
        });
    }
    StagePlan { chunks }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llm_partition_conserves_layers() {
        let m = ModelConfig::qwen2_12b(); // 40 layers
        for n in [1, 2, 4, 8] {
            let p = partition_llm(&m, n);
            assert_eq!(p.total_lm_layers(), m.layers, "n_chunks={n}");
            assert_eq!(p.num_chunks(), n);
        }
    }

    #[test]
    fn llm_last_stage_two_short() {
        let m = ModelConfig::qwen2_12b(); // 40 layers, 8 chunks (pp4 x v2)
        let p = partition_llm(&m, 8);
        let counts: Vec<usize> = p.chunks.iter().map(|c| c.lm_layers).collect();
        let last = *counts.last().unwrap();
        let first = counts[0];
        assert!(first >= last + 2, "first={first} last={last}");
    }

    #[test]
    fn llm_embed_and_head_placement() {
        let p = partition_llm(&ModelConfig::qwen2_26b(), 8);
        assert!(p.chunks[0].has_embed);
        assert!(p.chunks[7].has_head);
        assert_eq!(p.chunks.iter().filter(|c| c.has_embed).count(), 1);
        assert_eq!(p.chunks.iter().filter(|c| c.has_head).count(), 1);
    }

    #[test]
    fn weighted_partition_conserves_layers_and_biases_fast_chunks() {
        let m = ModelConfig::qwen2_12b(); // 40 layers
        // A800/H20 effective-FLOPs ratio under the V-shape (fast, slow,
        // slow, fast).
        let w = [1.814, 1.0, 1.0, 1.814];
        let p = partition_llm_weighted(&m, 4, &w);
        assert_eq!(p.total_lm_layers(), m.layers);
        let counts: Vec<usize> = p.chunks.iter().map(|c| c.lm_layers).collect();
        assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
        assert!(counts[0] > counts[1], "fast chunk should carry more: {counts:?}");
        assert!(p.chunks[0].has_embed && p.chunks[3].has_head);
    }

    #[test]
    fn weighted_partition_is_deterministic() {
        let m = ModelConfig::qwen2_26b();
        let w = vec![2.0, 1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0];
        assert_eq!(partition_llm_weighted(&m, 8, &w), partition_llm_weighted(&m, 8, &w));
    }

    #[test]
    fn mllm_vit_first_chunk() {
        let m = MllmConfig::qwen2vl_14_9b();
        let p = partition_mllm(&m, 8); // pp4 x v2
        assert_eq!(p.chunks[0].vit_layers, m.vit.layers);
        assert_eq!(p.chunks[0].lm_layers, 0);
        assert_eq!(p.total_lm_layers(), m.lm.layers);
        assert!(p.chunks[7].has_head);
    }

    #[test]
    fn mllm_chunk_imbalance_exists() {
        // Pattern-(1) braiding is defeated by exactly this imbalance
        // (paper §4.1) — assert our partitioner actually produces it.
        let m = MllmConfig::qwen2vl_28_8b();
        let p = partition_mllm(&m, 4); // pp2 x v2
        let unit_counts: Vec<usize> =
            p.chunks.iter().map(|c| c.lm_layers * 4 + c.vit_layers * 4).collect();
        let min = unit_counts.iter().min().unwrap();
        let max = unit_counts.iter().max().unwrap();
        assert!(max > min);
    }
}
