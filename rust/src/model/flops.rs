//! Per-unit FLOP accounting for the fine-grained computation units.
//!
//! The paper decomposes each transformer layer into **Pre-Attn**, **Attn**,
//! **Pre-MLP**, **MLP** units (Fig. 2/3), with the backward of Attn/MLP
//! further split into activation-gradient (`B`) and weight-gradient (`W`)
//! components. The cost model needs FLOPs for each so it can derive
//! `T_F`, `T_B`, `T_W` (per chunk) and per-unit times for braided-block
//! duration computation.

use super::ModelConfig;

/// FLOPs of a single computation unit, split by backward component.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitFlops {
    /// Forward FLOPs.
    pub fwd: f64,
    /// Backward activation-gradient FLOPs (`B`: dX path).
    pub bwd_x: f64,
    /// Backward weight-gradient FLOPs (`W`: dW path; zero for norm units).
    pub bwd_w: f64,
}

impl UnitFlops {
    pub const ZERO: UnitFlops = UnitFlops { fwd: 0.0, bwd_x: 0.0, bwd_w: 0.0 };

    pub fn total_bwd(&self) -> f64 {
        self.bwd_x + self.bwd_w
    }
}

impl std::ops::Add for UnitFlops {
    type Output = UnitFlops;
    fn add(self, o: UnitFlops) -> UnitFlops {
        UnitFlops { fwd: self.fwd + o.fwd, bwd_x: self.bwd_x + o.bwd_x, bwd_w: self.bwd_w + o.bwd_w }
    }
}

/// FLOPs of the four units of one transformer layer for one microbatch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerFlops {
    pub pre_attn: UnitFlops,
    pub attn: UnitFlops,
    pub pre_mlp: UnitFlops,
    pub mlp: UnitFlops,
}

impl LayerFlops {
    /// FLOP breakdown of one layer for a microbatch of `mbs` samples of
    /// `seq` tokens (whole layer, before TP division).
    ///
    /// Matmul convention: `C[m,n] = A[m,k]·B[k,n]` costs `2·m·n·k` FLOPs
    /// forward; backward costs the same for each of dA and dB (so matmul
    /// bwd_x = fwd, bwd_w = fwd). Attention score/AV matmuls have no
    /// weights: their backward (two matmuls each) lands entirely in `B`.
    /// RMSNorm is modelled as ~8 flops/element fwd, 12 bwd (no weight-grad
    /// matmul; the tiny dγ reduction is folded into bwd_x).
    pub fn of(cfg: &ModelConfig, seq: usize, mbs: usize) -> LayerFlops {
        let t = (seq * mbs) as f64; // tokens
        let d = cfg.hidden as f64;
        let kv = cfg.kv_dim() as f64;
        let f = cfg.ffn as f64;
        let s = seq as f64;

        // Norm units: bandwidth-bound; flop counts kept for completeness.
        let norm = UnitFlops { fwd: 8.0 * t * d, bwd_x: 12.0 * t * d, bwd_w: 0.0 };

        // Attention unit: qkv proj + scores + AV + out proj (+residual add).
        let qkv = 2.0 * t * d * (d + 2.0 * kv);
        let score_av = 2.0 * 2.0 * t * s * d; // QK^T and AV, full causal cost
        let out = 2.0 * t * d * d;
        let resid = t * d;
        let attn = UnitFlops {
            fwd: qkv + score_av + out + resid,
            bwd_x: qkv + 2.0 * score_av + out + resid,
            bwd_w: qkv + out,
        };

        // MLP unit (SwiGLU: gate, up, down) + residual add.
        let mlp_mm = 3.0 * 2.0 * t * d * f;
        let act = 4.0 * t * f;
        let mlp = UnitFlops {
            fwd: mlp_mm + act + resid,
            bwd_x: mlp_mm + 2.0 * act + resid,
            bwd_w: mlp_mm,
        };

        LayerFlops { pre_attn: norm, attn, pre_mlp: norm, mlp }
    }

    /// Total forward FLOPs of the layer.
    pub fn fwd_flops(&self) -> f64 {
        self.pre_attn.fwd + self.attn.fwd + self.pre_mlp.fwd + self.mlp.fwd
    }

    /// Forward matmul-only FLOPs (used for MFU — norms excluded).
    pub fn fwd_matmul_flops(&self) -> f64 {
        self.attn.fwd + self.mlp.fwd
    }

    /// Total activation-gradient FLOPs.
    pub fn bwd_x_flops(&self) -> f64 {
        self.pre_attn.bwd_x + self.attn.bwd_x + self.pre_mlp.bwd_x + self.mlp.bwd_x
    }

    /// Total weight-gradient FLOPs.
    pub fn bwd_w_flops(&self) -> f64 {
        self.attn.bwd_w + self.mlp.bwd_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::qwen2_12b()
    }

    #[test]
    fn backward_roughly_twice_forward() {
        let lf = LayerFlops::of(&cfg(), 4096, 1);
        let ratio = (lf.bwd_x_flops() + lf.bwd_w_flops()) / lf.fwd_flops();
        assert!((1.7..2.3).contains(&ratio), "bwd/fwd = {ratio:.2}");
    }

    #[test]
    fn activation_grad_exceeds_weight_grad() {
        // Paper appendix B: T_B > T_W — attention scores have no weights.
        let lf = LayerFlops::of(&cfg(), 4096, 1);
        assert!(lf.bwd_x_flops() > lf.bwd_w_flops());
    }

    #[test]
    fn flops_scale_linearly_in_mbs() {
        let a = LayerFlops::of(&cfg(), 1024, 1).fwd_flops();
        let b = LayerFlops::of(&cfg(), 1024, 4).fwd_flops();
        assert!((b / a - 4.0).abs() < 1e-9);
    }

    #[test]
    fn attention_quadratic_in_seq() {
        // Doubling seq more than doubles attention-unit flops.
        let a = LayerFlops::of(&cfg(), 2048, 1).attn.fwd;
        let b = LayerFlops::of(&cfg(), 4096, 1).attn.fwd;
        assert!(b > 2.0 * a);
        assert!(b < 4.0 * a);
    }

    #[test]
    fn norm_units_have_no_weight_grad_matmul() {
        let lf = LayerFlops::of(&cfg(), 1024, 1);
        assert_eq!(lf.pre_attn.bwd_w, 0.0);
        assert_eq!(lf.pre_mlp.bwd_w, 0.0);
    }
}
