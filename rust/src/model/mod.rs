//! Model configurations and analytic accounting (FLOPs, bytes, parameters).
//!
//! These drive the cost model of the discrete-event simulator
//! ([`crate::sim`]) and the MFU computation of [`crate::metrics`]. The
//! configurations mirror the paper's Table 2 (Qwen2 12.1B / 26.3B LLMs and
//! Qwen2-VL 14.9B / 28.8B MLLMs); where the published table is ambiguous we
//! pick the self-consistent variant whose parameter count matches the
//! headline scale (documented per constructor).

mod flops;
pub use flops::{LayerFlops, UnitFlops};


/// Transformer (decoder) model configuration, Qwen2-style: GQA attention,
/// SwiGLU MLP, RMSNorm, tied large vocabulary.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Human-readable name ("qwen2-12.1b").
    pub name: String,
    /// Number of transformer layers.
    pub layers: usize,
    /// Hidden dimension.
    pub hidden: usize,
    /// Number of query heads.
    pub q_heads: usize,
    /// Number of key/value heads (GQA).
    pub kv_heads: usize,
    /// MLP intermediate (SwiGLU, 3 matmuls of `hidden x ffn`).
    pub ffn: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Bytes per parameter / activation element (2 = bf16).
    pub dtype_bytes: usize,
}

impl ModelConfig {
    /// Paper Table 2 row 1: 12.1B LLM (40 layers, 40 Q heads, 8 KV heads,
    /// hidden 5120, SwiGLU ffn 13824, vocab 152064 — ≈12.2B params).
    pub fn qwen2_12b() -> Self {
        Self {
            name: "qwen2-12.1b".into(),
            layers: 40,
            hidden: 5120,
            q_heads: 40,
            kv_heads: 8,
            ffn: 13824,
            vocab: 152_064,
            dtype_bytes: 2,
        }
    }

    /// Paper Table 2 row 2: 26.3B LLM (46 layers, 56 Q heads, 8 KV heads,
    /// hidden 7168, ffn 18944, vocab 152064 — ≈26.3B params).
    pub fn qwen2_26b() -> Self {
        Self {
            name: "qwen2-26.3b".into(),
            layers: 46,
            hidden: 7168,
            q_heads: 56,
            kv_heads: 8,
            ffn: 18944,
            vocab: 152_064,
            dtype_bytes: 2,
        }
    }

    /// Tiny (~100M param) config for the real end-to-end training example —
    /// same architecture family, sized for CPU PJRT execution. Must stay in
    /// sync with `python/compile/config.py::E2E`.
    pub fn tiny_100m() -> Self {
        Self {
            name: "tiny-100m".into(),
            layers: 20,
            hidden: 512,
            q_heads: 8,
            kv_heads: 4,
            ffn: 2048,
            vocab: 8192,
            dtype_bytes: 4, // f32 on CPU
        }
    }

    /// Head dimension (= hidden / q_heads).
    pub fn head_dim(&self) -> usize {
        self.hidden / self.q_heads
    }

    /// KV projection width (GQA): kv_heads * head_dim.
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim()
    }

    /// Parameters of one transformer layer.
    pub fn layer_params(&self) -> usize {
        let d = self.hidden;
        let attn = d * d + 2 * d * self.kv_dim() + d * d; // q, kv, o
        let mlp = 3 * d * self.ffn; // gate, up, down
        let norms = 2 * d;
        attn + mlp + norms
    }

    /// Total parameters (layers + tied embedding + final norm).
    pub fn total_params(&self) -> usize {
        self.layers * self.layer_params() + 2 * self.vocab * self.hidden + self.hidden
    }

    /// Megatron-style activation bytes of one layer for one microbatch
    /// (FlashAttention-style: the `5·s·a/h` score term is dropped).
    /// ≈ `s·b·h·34` bytes at bf16; scaled by dtype and the SwiGLU widening.
    pub fn activation_bytes_per_layer(&self, seq: usize, mbs: usize) -> usize {
        // inputs to: ln1, qkv, attn-out, ln2, gate, up, down + residuals
        let d = self.hidden;
        let per_tok =
            (2 * d)              // ln1 in + attn in
            + (d + 2 * self.kv_dim()) // q,k,v
            + d                  // attn out (proj in)
            + (2 * d)            // ln2 in + mlp in
            + (2 * self.ffn)     // gate, up
            + self.ffn           // act (down in)
            + (2 * d); // residual streams
        seq * mbs * per_tok * self.dtype_bytes
    }

    /// Bytes all-reduced per layer per direction per microbatch: two ARs
    /// (post-Attn, post-MLP) of a `[mbs, seq, hidden]` tensor each.
    pub fn ar_bytes_per_layer(&self, seq: usize, mbs: usize) -> usize {
        2 * mbs * seq * self.hidden * self.dtype_bytes
    }

    /// Model FLOPs per token for one full fwd+bwd pass (the MFU numerator),
    /// including the attention quadratic term; standard 3x-forward rule
    /// applied to matmul FLOPs.
    pub fn train_flops_per_token(&self, seq: usize) -> f64 {
        let lf = LayerFlops::of(self, seq, 1);
        let per_layer = 3.0 * (lf.fwd_matmul_flops() / (seq as f64));
        let head = 3.0 * 2.0 * (self.hidden as f64) * (self.vocab as f64);
        (self.layers as f64) * per_layer + head
    }
}

/// Vision encoder configuration (MLLM front-end, ViT).
#[derive(Debug, Clone, PartialEq)]
pub struct VitConfig {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// MLP ratio (classic 4x GeLU MLP, 2 matmuls).
    pub mlp_ratio: usize,
    pub dtype_bytes: usize,
}

impl VitConfig {
    /// 1.7B ViT of the 14.9B MLLM (32 layers, 16 heads, hidden 2048).
    pub fn vit_1_7b() -> Self {
        Self { layers: 32, hidden: 2048, heads: 16, mlp_ratio: 4, dtype_bytes: 2 }
    }

    /// 5.6B ViT of the 28.8B / 30.3B MLLMs (26 layers, 16 heads, hidden 4096).
    pub fn vit_5_6b() -> Self {
        Self { layers: 26, hidden: 4096, heads: 16, mlp_ratio: 4, dtype_bytes: 2 }
    }

    pub fn layer_params(&self) -> usize {
        let d = self.hidden;
        4 * d * d + 2 * d * (self.mlp_ratio * d) + 2 * d
    }

    pub fn total_params(&self) -> usize {
        self.layers * self.layer_params()
    }

    /// Forward matmul FLOPs of one ViT layer for `tokens` patch tokens.
    pub fn layer_fwd_flops(&self, tokens: usize) -> f64 {
        let d = self.hidden as f64;
        let t = tokens as f64;
        let proj = 2.0 * t * d * d * 4.0; // qkv + o
        let score = 4.0 * t * t * d;
        let mlp = 2.0 * t * d * (self.mlp_ratio as f64 * d) * 2.0;
        proj + score + mlp
    }

    /// Activation bytes per ViT layer per microbatch of `tokens` tokens.
    pub fn activation_bytes_per_layer(&self, tokens: usize, mbs: usize) -> usize {
        let d = self.hidden;
        let per_tok = 2 * d + 3 * d + d + 2 * d + 2 * self.mlp_ratio * d + 2 * d;
        tokens * mbs * per_tok * self.dtype_bytes
    }

    /// AR bytes per ViT layer per direction per microbatch.
    pub fn ar_bytes_per_layer(&self, tokens: usize, mbs: usize) -> usize {
        2 * mbs * tokens * self.hidden * self.dtype_bytes
    }
}

/// Multimodal model = ViT encoder + LM decoder (paper Table 2 bottom rows).
#[derive(Debug, Clone, PartialEq)]
pub struct MllmConfig {
    pub name: String,
    pub vit: VitConfig,
    pub lm: ModelConfig,
}

impl MllmConfig {
    /// 14.9B MLLM = 1.7B ViT + 13.2B LM (42-layer, hidden-5120 decoder).
    pub fn qwen2vl_14_9b() -> Self {
        let mut lm = ModelConfig::qwen2_12b();
        lm.name = "qwen2vl-lm-13.2b".into();
        lm.layers = 42;
        Self { name: "qwen2vl-14.9b".into(), vit: VitConfig::vit_1_7b(), lm }
    }

    /// 28.8B MLLM = 5.6B ViT + 23.2B LM (40-layer, hidden-7168 decoder).
    pub fn qwen2vl_28_8b() -> Self {
        let mut lm = ModelConfig::qwen2_26b();
        lm.name = "qwen2vl-lm-23.2b".into();
        lm.layers = 40;
        Self { name: "qwen2vl-28.8b".into(), vit: VitConfig::vit_5_6b(), lm }
    }

    /// 30.3B MLLM variant (Table 3 bottom block): 5.6B ViT + 24.7B LM (43 layers).
    pub fn qwen2vl_30_3b() -> Self {
        let mut lm = ModelConfig::qwen2_26b();
        lm.name = "qwen2vl-lm-24.7b".into();
        lm.layers = 43;
        Self { name: "qwen2vl-30.3b".into(), vit: VitConfig::vit_5_6b(), lm }
    }

    pub fn total_params(&self) -> usize {
        self.vit.total_params() + self.lm.total_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen2_12b_param_count_matches_headline() {
        let p = ModelConfig::qwen2_12b().total_params() as f64 / 1e9;
        assert!((11.5..13.0).contains(&p), "12.1B config has {p:.2}B params");
    }

    #[test]
    fn qwen2_26b_param_count_matches_headline() {
        let p = ModelConfig::qwen2_26b().total_params() as f64 / 1e9;
        assert!((25.0..27.5).contains(&p), "26.3B config has {p:.2}B params");
    }

    #[test]
    fn tiny_config_is_about_100m() {
        let p = ModelConfig::tiny_100m().total_params() as f64 / 1e6;
        assert!((50.0..150.0).contains(&p), "tiny config has {p:.1}M params");
    }

    #[test]
    fn vit_param_counts() {
        let v17 = VitConfig::vit_1_7b().total_params() as f64 / 1e9;
        assert!((1.3..2.1).contains(&v17), "1.7B ViT has {v17:.2}B");
        let v56 = VitConfig::vit_5_6b().total_params() as f64 / 1e9;
        assert!((4.5..6.5).contains(&v56), "5.6B ViT has {v56:.2}B");
    }

    #[test]
    fn mllm_total_params() {
        let m = MllmConfig::qwen2vl_14_9b().total_params() as f64 / 1e9;
        assert!((13.5..16.5).contains(&m), "14.9B MLLM has {m:.2}B");
        let m = MllmConfig::qwen2vl_28_8b().total_params() as f64 / 1e9;
        assert!((26.5..31.0).contains(&m), "28.8B MLLM has {m:.2}B");
    }

    #[test]
    fn gqa_dims_consistent() {
        let c = ModelConfig::qwen2_12b();
        assert_eq!(c.head_dim(), 128);
        assert_eq!(c.kv_dim(), 1024);
    }

    #[test]
    fn activation_bytes_scale_linearly_in_tokens() {
        let c = ModelConfig::qwen2_12b();
        let a = c.activation_bytes_per_layer(1024, 1);
        let b = c.activation_bytes_per_layer(2048, 1);
        assert_eq!(2 * a, b);
        let d = c.activation_bytes_per_layer(1024, 2);
        assert_eq!(2 * a, d);
    }

    #[test]
    fn ar_bytes_two_allreduces_per_layer() {
        let c = ModelConfig::qwen2_12b();
        assert_eq!(c.ar_bytes_per_layer(10, 1), 2 * 10 * 5120 * 2);
    }
}
