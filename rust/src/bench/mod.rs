//! The paper-experiment harness: one regenerator per table and figure of
//! the evaluation (DESIGN.md §5 maps each to this module). Every function
//! returns the formatted report it prints, so integration tests can assert
//! on the *shape* of the results (who wins, by roughly what factor) without
//! re-parsing stdout.

use crate::cluster::{partition_mllm, ClusterSpec, HardwareProfile, Topology};
use crate::metrics::{gb, pct, Table};
use crate::model::{MllmConfig, ModelConfig};
use crate::schedule::{build_schedule, build_schedule_scaled, theory, ScheduleKind, TheoryInputs};
use crate::sim::{AcMode, CostModel, SimReport, Simulator};

/// Simulate one (model, topo, seq, mb_size, schedule) point.
#[allow(clippy::too_many_arguments)]
pub fn run_llm(
    model: &ModelConfig,
    cluster: &ClusterSpec,
    tp: usize,
    pp: usize,
    seq: usize,
    mb_size: usize,
    n_mb: usize,
    kind: ScheduleKind,
) -> SimReport {
    let topo = Topology::new(tp, pp, 1);
    let cost = CostModel::analytic(model, &topo, cluster, seq, mb_size);
    let s = build_schedule_scaled(kind, &topo, n_mb, cost.chunk_scales());
    Simulator::new(&cost).run(&s)
}

/// Fig. 1 — TP-communication share of a transformer layer and the overlap
/// speedup of braided execution, vs TP size (Qwen2-12.1B, seq 6144).
pub fn fig1() -> String {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let mut t = Table::new(vec![
        "tp", "comm share fwd %", "naive fwd (ms)", "overlapped fwd (ms)", "overlap speedup",
    ]);
    for tp in [2usize, 4, 8] {
        let topo = Topology::new(tp, 2, 1);
        let cost = CostModel::analytic(&model, &topo, &cluster, 6144, 1);
        let c = &cost.chunks[0];
        let share = c.t_ar_fwd() / (c.t_f() + c.t_ar_fwd());
        // Paper Fig. 1's definition: forward pass with exposed AR (naive)
        // vs forward inside a braided block, where the fwd AR hides under
        // the partner backward's compute; the braid's residual exposure is
        // attributed to the forward proportionally.
        let naive_fwd = c.t_f() + c.t_ar_fwd();
        let braided = c.time_braided(c, true);
        let fwd_frac = c.t_ar_fwd() / (c.t_ar_fwd() + c.t_ar_bwd()).max(1e-12);
        let overlapped_fwd = c.t_f() + braided.exposed_ar * fwd_frac;
        t.row(vec![
            tp.to_string(),
            pct(share),
            format!("{:.2}", naive_fwd * 1e3),
            format!("{:.2}", overlapped_fwd * 1e3),
            format!("{:.2}x", naive_fwd / overlapped_fwd),
        ]);
    }
    format!("== Fig. 1: TP communication share & braided overlap (12.1B, seq 6144, A800)\n{}", t.render())
}

/// Table 1 — theoretical bubbles/memory vs simulated, side by side.
pub fn table1() -> String {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let (tp, pp, seq, m) = (8, 4, 4096, 64);
    let topo = Topology::new(tp, pp, 1);
    let cost = CostModel::analytic(&model, &topo, &cluster, seq, 1);
    let ti: TheoryInputs = cost.theory_inputs(m);
    let ma = *cost.act_bytes.iter().max().unwrap() as f64;

    let mut t = Table::new(vec![
        "schedule",
        "PP bubble (theory s)",
        "PP bubble (sim s)",
        "TP bubble (theory s)",
        "TP bubble (sim s)",
        "peak act (theory GB)",
        "peak act (sim GB)",
    ]);
    for kind in ScheduleKind::paper_trio() {
        let row = theory(kind, &ti);
        let s = build_schedule(kind, &topo, m);
        let r = Simulator::new(&cost).run(&s);
        t.row(vec![
            kind.name().to_string(),
            format!("{:.3}", row.pp_bubble),
            format!("{:.3}", r.pp_bubble_per_device()),
            format!("{:.3}", row.tp_bubble),
            format!("{:.3}", r.tp_bubble_per_device()),
            format!("{:.1}", row.peak_act_ma * ma / 1e9),
            format!("{:.1}", r.peak_activation_gb()),
        ]);
    }
    format!(
        "== Table 1: theory vs simulation (12.1B, tp{tp} pp{pp} seq{seq} m{m}, A800)\n\
         T_F={:.4} T_B={:.4} T_W={:.4} T_AR={:.4}\n{}",
        ti.t_f,
        ti.t_b,
        ti.t_w,
        ti.t_ar,
        t.render()
    )
}

/// Shared grid printer for the LLM throughput experiments.
fn llm_grid(title: &str, model: &ModelConfig, grid: &[(usize, usize, usize, usize)]) -> String {
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let mut t = Table::new(vec![
        "seq", "tp", "pp", "mbs", "1f1b-i", "zb-v", "ours", "gain vs 1f1b-i",
    ]);
    for &(seq, tp, pp, mb_size) in grid {
        for n_mb in [64usize, 128, 192] {
            let thr: Vec<f64> = ScheduleKind::paper_trio()
                .iter()
                .map(|&k| run_llm(model, &cluster, tp, pp, seq, mb_size, n_mb, k).throughput())
                .collect();
            t.row(vec![
                seq.to_string(),
                tp.to_string(),
                pp.to_string(),
                n_mb.to_string(),
                format!("{:.2}", thr[0]),
                format!("{:.2}", thr[1]),
                format!("{:.2}", thr[2]),
                format!("{:+.1}%", 100.0 * (thr[2] / thr[0] - 1.0)),
            ]);
        }
    }
    format!("== {title}\n{}", t.render())
}

/// Fig. 7 / Tables 6 slice — 12.1B LLM on 16 GPUs.
pub fn fig7() -> String {
    llm_grid(
        "Fig. 7: 12.1B LLM, 16 GPUs (A800), throughput samples/s",
        &ModelConfig::qwen2_12b(),
        &[(3072, 4, 4, 2), (3072, 8, 2, 2), (6144, 4, 4, 1), (6144, 8, 2, 1)],
    )
}

/// Fig. 8 — 26.3B LLM on 32 GPUs.
pub fn fig8() -> String {
    llm_grid(
        "Fig. 8: 26.3B LLM, 32 GPUs (A800), throughput samples/s",
        &ModelConfig::qwen2_26b(),
        &[(2048, 4, 8, 2), (2048, 8, 4, 2), (4096, 4, 8, 1), (4096, 8, 4, 1)],
    )
}

/// Fig. 9 — peak activation memory, 12.1B, PP∈{4,2}.
pub fn fig9() -> String {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let mut t = Table::new(vec!["seq", "tp", "pp", "1f1b-i GB", "zb-v GB", "ours GB"]);
    for (seq, tp, pp) in [(3072, 4, 4), (3072, 8, 2), (6144, 4, 4), (6144, 8, 2)] {
        let mems: Vec<f64> = ScheduleKind::paper_trio()
            .iter()
            .map(|&k| run_llm(&model, &cluster, tp, pp, seq, 2, 64, k).peak_activation_gb())
            .collect();
        t.row(vec![
            seq.to_string(),
            tp.to_string(),
            pp.to_string(),
            format!("{:.1}", mems[0]),
            format!("{:.1}", mems[1]),
            format!("{:.1}", mems[2]),
        ]);
    }
    format!("== Fig. 9: peak activation memory, 12.1B, A800\n{}", t.render())
}

/// Simulate one MLLM point.
#[allow(clippy::too_many_arguments)]
pub fn run_mllm(
    mllm: &MllmConfig,
    cluster: &ClusterSpec,
    tp: usize,
    pp: usize,
    vit_tokens: usize,
    lm_seq: usize,
    n_mb: usize,
    kind: ScheduleKind,
) -> SimReport {
    let topo = Topology::new(tp, pp, 1);
    let plan = partition_mllm(mllm, topo.chunks());
    let cost =
        CostModel::analytic_mllm(&mllm.lm, &mllm.vit, &plan, &topo, cluster, lm_seq, vit_tokens, 1);
    let s = build_schedule_scaled(kind, &topo, n_mb, cost.chunk_scales());
    Simulator::new(&cost).run(&s)
}

/// Table 3 — MLLM throughput + peak memory.
pub fn table3() -> String {
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let mut t = Table::new(vec![
        "model", "vit len", "lm len", "tp", "pp", "schedule", "mbs=64/96", "mbs=128/176",
        "mbs=192/256", "mem GB",
    ]);
    let cases: Vec<(MllmConfig, usize, usize, usize, usize, [usize; 3])> = vec![
        (MllmConfig::qwen2vl_14_9b(), 3136, 5120, 4, 4, [64, 128, 192]),
        (MllmConfig::qwen2vl_14_9b(), 3136, 5120, 8, 2, [64, 128, 192]),
        (MllmConfig::qwen2vl_28_8b(), 9216, 5120, 4, 8, [96, 176, 256]),
        (MllmConfig::qwen2vl_30_3b(), 6400, 8192, 8, 4, [96, 176, 256]),
    ];
    for (mllm, vit_len, lm_len, tp, pp, mbs) in &cases {
        for kind in ScheduleKind::paper_trio() {
            let rs: Vec<SimReport> = mbs
                .iter()
                .map(|&m| run_mllm(mllm, &cluster, *tp, *pp, *vit_len, *lm_len, m, kind))
                .collect();
            t.row(vec![
                mllm.name.clone(),
                vit_len.to_string(),
                lm_len.to_string(),
                tp.to_string(),
                pp.to_string(),
                kind.name().to_string(),
                format!("{:.2}", rs[0].throughput()),
                format!("{:.2}", rs[1].throughput()),
                format!("{:.2}", rs[2].throughput()),
                format!("{:.0}", rs[2].peak_activation_gb() + rs[2].static_bytes as f64 / 1e9),
            ]);
        }
    }
    format!("== Table 3: MLLM throughput (samples/s) + peak memory, A800\n{}", t.render())
}

/// Fig. 10 — enhanced (offloading) variant on H20: throughput + per-stage
/// activation memory over 4 PP stages.
pub fn fig10() -> String {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::h20());
    let mut t = Table::new(vec!["schedule", "thr (samples/s)", "per-stage act GB", "peak GB"]);
    for kind in [
        ScheduleKind::OneF1BInterleaved,
        ScheduleKind::ZbV,
        ScheduleKind::Stp,
        ScheduleKind::StpOffload,
    ] {
        let r = run_llm(&model, &cluster, 4, 4, 6144, 1, 128, kind);
        let per: Vec<String> =
            r.activation_gb_per_device().iter().map(|g| format!("{g:.1}")).collect();
        t.row(vec![
            kind.name().to_string(),
            format!("{:.2}", r.throughput()),
            per.join("/"),
            format!("{:.1}", r.peak_activation_gb()),
        ]);
    }
    format!("== Fig. 10: offloading variant, 12.1B, tp4 pp4, H20\n{}", t.render())
}

/// Table 4 — maximized memory utilization on 16 H20 96G GPUs.
pub fn table4() -> String {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::h20());
    let mut t = Table::new(vec![
        "tp", "pp", "mb size", "schedule", "thr", "MFU %", "mem GB", "status",
    ]);
    let cases: Vec<(usize, usize, usize, ScheduleKind)> = vec![
        (2, 8, 1, ScheduleKind::OneF1BInterleaved),
        (2, 8, 1, ScheduleKind::ZbV),
        (2, 8, 1, ScheduleKind::Stp),
        (2, 8, 1, ScheduleKind::StpOffload),
        (4, 4, 1, ScheduleKind::OneF1BInterleaved),
        (4, 4, 1, ScheduleKind::ZbV),
        (4, 4, 1, ScheduleKind::Stp),
        (4, 4, 2, ScheduleKind::OneF1BInterleaved),
        (4, 4, 2, ScheduleKind::ZbV),
        (4, 4, 2, ScheduleKind::StpOffload),
        (8, 2, 1, ScheduleKind::OneF1BInterleaved),
        (8, 2, 1, ScheduleKind::ZbV),
        (8, 2, 1, ScheduleKind::Stp),
        (8, 2, 2, ScheduleKind::OneF1BInterleaved),
        (8, 2, 2, ScheduleKind::ZbV),
        (8, 2, 2, ScheduleKind::StpOffload),
        (8, 2, 3, ScheduleKind::OneF1BInterleaved),
        (8, 2, 3, ScheduleKind::ZbV),
        (8, 2, 3, ScheduleKind::StpOffload),
    ];
    for (tp, pp, mb_size, kind) in cases {
        let r = run_llm(&model, &cluster, tp, pp, 8192, mb_size, 192, kind);
        let oom = r.is_oom();
        t.row(vec![
            tp.to_string(),
            pp.to_string(),
            mb_size.to_string(),
            kind.name().to_string(),
            if oom { "-".into() } else { format!("{:.2}", r.throughput()) },
            if oom { "-".into() } else { pct(r.mfu()) },
            gb(r.peak_memory_bytes()),
            if oom { "OOM".into() } else { "ok".into() },
        ]);
    }
    format!("== Table 4: maximized memory utilization, 12.1B, seq 8192, mbs=192, 16x H20 96G\n{}", t.render())
}

/// Tables 5/6/7 — appendix grids (peak memory / throughput / MFU).
pub fn table567() -> String {
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let mut t = Table::new(vec![
        "model", "seq", "tp", "pp", "schedule", "thr", "MFU %", "act GB",
    ]);
    let cases: Vec<(ModelConfig, usize, usize, usize, usize)> = vec![
        (ModelConfig::qwen2_12b(), 3072, 4, 4, 2),
        (ModelConfig::qwen2_12b(), 3072, 8, 2, 2),
        (ModelConfig::qwen2_12b(), 6144, 4, 4, 1),
        (ModelConfig::qwen2_12b(), 6144, 8, 2, 1),
        (ModelConfig::qwen2_26b(), 2048, 4, 8, 2),
        (ModelConfig::qwen2_26b(), 2048, 8, 4, 2),
        (ModelConfig::qwen2_26b(), 4096, 4, 8, 1),
        (ModelConfig::qwen2_26b(), 4096, 8, 4, 1),
    ];
    for (model, seq, tp, pp, mb_size) in &cases {
        for kind in ScheduleKind::paper_trio() {
            let r = run_llm(model, &cluster, *tp, *pp, *seq, *mb_size, 192, kind);
            t.row(vec![
                model.name.clone(),
                seq.to_string(),
                tp.to_string(),
                pp.to_string(),
                kind.name().to_string(),
                format!("{:.2}", r.throughput()),
                pct(r.mfu()),
                format!("{:.1}", r.peak_activation_gb()),
            ]);
        }
    }
    format!("== Tables 5/6/7: appendix grids (mbs=192, A800)\n{}", t.render())
}

/// Table 8 — H20 throughput grid.
pub fn table8() -> String {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::h20());
    let mut t = Table::new(vec!["tp", "pp", "schedule", "thr", "MFU %", "mem GB"]);
    for (tp, pp) in [(2usize, 8usize), (4, 4), (8, 2)] {
        for kind in ScheduleKind::paper_trio() {
            let r = run_llm(&model, &cluster, tp, pp, 6144, 1, 192, kind);
            t.row(vec![
                tp.to_string(),
                pp.to_string(),
                kind.name().to_string(),
                format!("{:.2}", r.throughput()),
                pct(r.mfu()),
                gb(r.peak_memory_bytes()),
            ]);
        }
    }
    format!("== Table 8: 12.1B on 16x H20, seq 6144, mbs=192\n{}", t.render())
}

/// Fig. 13 — compute vs TP-communication proportion of Attn/MLP modules on
/// A800 vs H20 (why H20 gains are smaller).
pub fn fig13() -> String {
    let model = ModelConfig::qwen2_12b();
    let mut t = Table::new(vec!["hw", "tp", "attn comm %", "mlp comm %", "layer comm %"]);
    for hw in [HardwareProfile::a800(), HardwareProfile::h20()] {
        let cluster = ClusterSpec::uniform(hw.clone());
        for tp in [4usize, 8] {
            let topo = Topology::new(tp, 2, 1);
            let cost = CostModel::analytic(&model, &topo, &cluster, 6144, 1);
            let c = &cost.chunks[0];
            // Units alternate [norm, attn(+ar), norm, mlp(+ar)]; gather per-kind.
            let mut attn_c = 0.0;
            let mut attn_a = 0.0;
            let mut mlp_c = 0.0;
            let mut mlp_a = 0.0;
            let mut ar_seen = 0;
            for u in &c.fwd {
                if u.ar > 0.0 {
                    if ar_seen % 2 == 0 {
                        attn_c += u.compute;
                        attn_a += u.ar;
                    } else {
                        mlp_c += u.compute;
                        mlp_a += u.ar;
                    }
                    ar_seen += 1;
                }
            }
            t.row(vec![
                hw.name.clone(),
                tp.to_string(),
                pct(attn_a / (attn_c + attn_a)),
                pct(mlp_a / (mlp_c + mlp_a)),
                pct((attn_a + mlp_a) / (c.t_f() + c.t_ar_fwd())),
            ]);
        }
    }
    format!("== Fig. 13: TP communication proportion, A800 vs H20 (12.1B, seq 6144)\n{}", t.render())
}

/// Table 9 — activation-checkpointing compatibility.
pub fn table9() -> String {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let topo = Topology::new(4, 4, 1);
    let mut t = Table::new(vec!["config", "thr (samples/s)", "peak act GB"]);
    for (label, mode) in [
        ("AC disabled", AcMode::None),
        ("AC on MLP", AcMode::Mlp),
        ("AC on Attn+MLP", AcMode::AttnMlp),
        ("AC on Attn+MLP+Norm", AcMode::All),
    ] {
        let cost =
            CostModel::analytic(&model, &topo, &cluster, 6144, 1).with_activation_checkpoint(mode);
        let s = build_schedule_scaled(ScheduleKind::Stp, &topo, 128, cost.chunk_scales());
        let r = Simulator::new(&cost).run(&s);
        t.row(vec![
            label.to_string(),
            format!("{:.2}", r.throughput()),
            format!("{:.1}", r.peak_activation_gb()),
        ]);
    }
    format!("== Table 9: STP + activation checkpointing (12.1B, tp4 pp4, seq 6144, mbs=128)\n{}", t.render())
}

/// Table 10 — data parallelism and context parallelism compatibility.
pub fn table10() -> String {
    let model = ModelConfig::qwen2_12b();
    let hw = HardwareProfile::a800();
    let cluster = ClusterSpec::uniform(hw.clone());
    let mut t = Table::new(vec!["mode", "tp", "pp", "x", "seq", "schedule", "thr"]);
    // CP=2, seq 12k.
    for kind in ScheduleKind::paper_trio() {
        let topo = Topology::new(2, 4, 1).with_cp(2);
        let cost = CostModel::analytic(&model, &topo, &cluster, 12288, 1);
        let s = build_schedule_scaled(kind, &topo, 128, cost.chunk_scales());
        let r = Simulator::new(&cost).run(&s);
        t.row(vec![
            "CP".into(),
            "2".into(),
            "4".into(),
            "2".into(),
            "12k".into(),
            kind.name().into(),
            format!("{:.2}", r.throughput()),
        ]);
    }
    // DP=2, seq 4k: two replicas; throughput doubles minus a gradient
    // all-reduce tax modelled from param bytes over the internode link.
    for kind in ScheduleKind::paper_trio() {
        let topo = Topology::new(2, 4, 2);
        let cost = CostModel::analytic(&model, &topo, &cluster, 4096, 1);
        let s = build_schedule_scaled(kind, &topo, 128, cost.chunk_scales());
        let r = Simulator::new(&cost).run(&s);
        let grad_bytes = model.total_params() * 2 / (topo.tp * topo.pp);
        let dp_tax = hw.allreduce_secs(grad_bytes, topo.dp);
        let thr = (2 * r.n_mb * r.mb_size) as f64 / (r.iteration_secs + dp_tax);
        t.row(vec![
            "DP".into(),
            "2".into(),
            "4".into(),
            "2".into(),
            "4k".into(),
            kind.name().into(),
            format!("{thr:.2}"),
        ]);
    }
    format!("== Table 10: DP / CP compatibility (12.1B, mbs=128, A800)\n{}", t.render())
}

/// Table 11 (simulated counterpart) — GEMM/All-Reduce overlap: sequential
/// vs overlapped execution under the two-stream model. The *measured*
/// version runs in `benches/table11_overlap.rs` on real PJRT + real
/// in-process all-reduce.
pub fn table11_sim() -> String {
    use crate::sim::{time_block, Unit};
    let mut t = Table::new(vec!["scenario", "gemm ms", "ar ms", "sequential ms", "overlapped ms", "saving %"]);
    for (label, gemm, ar) in [
        ("GEMM dominates", 8.605e-3, 3.364e-3),
        ("AR dominates", 0.334e-3, 1.643e-3),
    ] {
        let seq = gemm + ar;
        // Overlapped: the AR of a previous op rides the comm stream while
        // this GEMM computes (partner provides the hiding compute).
        let overlapped = time_block(&[Unit::b(0.0, ar), Unit::f(gemm, 0.0)]).duration;
        t.row(vec![
            label.to_string(),
            format!("{:.3}", gemm * 1e3),
            format!("{:.3}", ar * 1e3),
            format!("{:.3}", seq * 1e3),
            format!("{:.3}", overlapped * 1e3),
            pct(1.0 - overlapped / seq),
        ]);
    }
    format!("== Table 11 (two-stream model): GEMM + AllReduce overlap\n{}", t.render())
}

/// Auto-planner demo — a paper-table sweep expressed as a plan query:
/// rank every (TP, PP, DP) × schedule × microbatch candidate for a
/// 16-GPU A800 budget and print the funnel plus the top plans, then run
/// the search-perf sweep (exhaustive vs beam over growing GPU budgets)
/// and record it in `BENCH_plan_search.json` at the repo root so future
/// PRs can track the planner's perf trajectory. The winner's executable
/// plan artifact lands next to it as `BENCH_plan_artifact.json`
/// (`stp train --plan`-ready).
pub fn plan16() -> String {
    use crate::plan::{plan, PlanModel, PlanQuery};
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::uniform(HardwareProfile::a800()),
        16,
    );
    // Lighter sweep than the CLI default: the bench target is shape, not
    // exhaustiveness.
    q.n_mb_options = vec![16, 64];
    let report = plan(&q);
    let artifact_note = match &report.best_artifact {
        Some(a) => {
            let path = "BENCH_plan_artifact.json";
            match a.save(path) {
                Ok(()) => format!("wrote {path} ({})", a.label()),
                Err(e) => format!("could not write {path}: {e}"),
            }
        }
        None => "no feasible plan — no artifact emitted".to_string(),
    };
    format!("{}\n{artifact_note}\n{}", report.render(10), plan_perf(true))
}

/// Search-perf sweep: plan the same model over growing GPU budgets with
/// exhaustive enumeration vs beam search, report wall-clock and
/// candidates/sec, and write the machine-readable trajectory record
/// `BENCH_plan_search.json` at the repo root. `quick` limits the sweep
/// to {16, 128} GPUs (the CI perf-smoke mode); the full sweep adds 64
/// and 256. A second, fleet-scale sweep ({16, 256, 1024, 4096} devices,
/// beam search) measures symmetry-folded vs per-replica simulation —
/// the unfolded baseline replays every DP replica, so it is skipped
/// beyond 1024 devices in quick mode — and records per size whether the
/// two reports are byte-identical.
pub fn plan_perf(quick: bool) -> String {
    use std::time::Instant;

    use crate::config::json::Json;
    use crate::plan::{plan, PlanModel, PlanQuery, SearchMode};
    use crate::sim::SimMode;
    use std::collections::BTreeMap;

    let budgets: Vec<usize> = if quick { vec![16, 128] } else { vec![16, 64, 128, 256] };
    let beam_width = 8usize;
    // The CLI's evo defaults, so the bench row answers "what does
    // `--search evo` buy me out of the box".
    let (evo_gens, evo_pop, evo_seed) = (12usize, 24usize, 42u64);
    let mut t = Table::new(vec![
        "pool", "gpus", "search", "simulated", "wall s", "cands/s", "speedup", "best plan",
    ]);
    let mut entries: Vec<Json> = Vec::new();
    let pools =
        [ClusterSpec::uniform(HardwareProfile::a800()), ClusterSpec::mixed_a800_h20_large()];
    for cluster in &pools {
        for &gpus in &budgets {
            if gpus > cluster.total_devices() {
                continue; // the mixed preset tops out at 128 devices
            }
            let mut exhaustive_secs = 0.0f64;
            let mut exhaustive_enumerated = 0usize;
            for mode in [
                SearchMode::Exhaustive,
                SearchMode::Beam { width: beam_width },
                SearchMode::Evo { generations: evo_gens, population: evo_pop, seed: evo_seed },
            ] {
                let mut q = PlanQuery::new(
                    PlanModel::Llm(ModelConfig::qwen2_12b()),
                    cluster.clone(),
                    gpus,
                );
                q.search = mode;
                let t0 = Instant::now();
                let r = plan(&q);
                let secs = t0.elapsed().as_secs_f64();
                let speedup = match mode {
                    SearchMode::Exhaustive => {
                        exhaustive_secs = secs;
                        exhaustive_enumerated = r.n_enumerated;
                        1.0
                    }
                    _ => exhaustive_secs / secs.max(1e-9),
                };
                let best = r
                    .best()
                    .map(|b| b.candidate.label())
                    .unwrap_or_else(|| "no feasible plan".into());
                let best_thr = r.best().map(|b| b.throughput).unwrap_or(0.0);
                let best_iter = r.best().map(|b| b.iteration_secs).unwrap_or(0.0);
                t.row(vec![
                    cluster.name.clone(),
                    gpus.to_string(),
                    r.search_mode.clone(),
                    r.n_simulated().to_string(),
                    format!("{secs:.3}"),
                    format!("{:.0}", r.n_simulated() as f64 / secs.max(1e-9)),
                    format!("{speedup:.1}x"),
                    best.clone(),
                ]);
                let mut o = BTreeMap::new();
                o.insert("cluster".to_string(), Json::Str(cluster.name.clone()));
                o.insert("gpus".to_string(), Json::Num(gpus as f64));
                o.insert("mode".to_string(), Json::Str(r.search_mode.clone()));
                o.insert("wall_secs".to_string(), Json::Num(secs));
                o.insert("enumerated".to_string(), Json::Num(r.n_enumerated as f64));
                o.insert("simulated".to_string(), Json::Num(r.n_simulated() as f64));
                o.insert(
                    "candidates_per_sec".to_string(),
                    Json::Num(r.n_simulated() as f64 / secs.max(1e-9)),
                );
                o.insert("speedup_vs_exhaustive".to_string(), Json::Num(speedup));
                o.insert("best".to_string(), Json::Str(best));
                o.insert("best_throughput".to_string(), Json::Num(best_thr));
                o.insert("best_iteration_secs".to_string(), Json::Num(best_iter));
                if matches!(mode, SearchMode::Evo { .. }) && exhaustive_enumerated > 0 {
                    // The acceptance ratio: what slice of the exhaustive
                    // candidate space did evolution actually simulate.
                    o.insert(
                        "space_fraction_simulated".to_string(),
                        Json::Num(r.n_simulated() as f64 / exhaustive_enumerated as f64),
                    );
                }
                entries.push(Json::Obj(o));
            }
        }
    }

    // Fleet-scale sweep (the folding measurement): symmetry-folded vs
    // per-replica beam search at growing device counts. The folded path
    // replays one representative per replica class — wall-clock flat in
    // dp — while the unfolded baseline replays every replica, so it is
    // skipped beyond `unfold_cap` (it would dominate the bench).
    let fleet_sizes: Vec<usize> = vec![16, 256, 1024, 4096];
    let unfold_cap = if quick { 1024 } else { 4096 };
    let mut fleet_entries: Vec<Json> = Vec::new();
    for &gpus in &fleet_sizes {
        let fleet_query = |sim: SimMode| {
            let mut q = PlanQuery::new(
                PlanModel::Llm(ModelConfig::qwen2_12b()),
                ClusterSpec::uniform(HardwareProfile::a800()),
                gpus,
            );
            q.n_mb_options = vec![16, 64];
            q.search = SearchMode::Beam { width: beam_width };
            q.sim = sim;
            q
        };
        let t0 = Instant::now();
        let folded = plan(&fleet_query(SimMode::Folded));
        let folded_secs = t0.elapsed().as_secs_f64();
        let best = folded
            .best()
            .map(|b| b.candidate.label())
            .unwrap_or_else(|| "no feasible plan".into());
        let mut o = BTreeMap::new();
        o.insert("gpus".to_string(), Json::Num(gpus as f64));
        o.insert("folded_wall_secs".to_string(), Json::Num(folded_secs));
        o.insert("simulated".to_string(), Json::Num(folded.n_simulated() as f64));
        o.insert("best".to_string(), Json::Str(best.clone()));
        let speedup_cell = if gpus <= unfold_cap {
            let t1 = Instant::now();
            let unfolded = plan(&fleet_query(SimMode::Unfolded));
            let unfolded_secs = t1.elapsed().as_secs_f64();
            let speedup = unfolded_secs / folded_secs.max(1e-9);
            o.insert("unfolded_wall_secs".to_string(), Json::Num(unfolded_secs));
            o.insert("speedup".to_string(), Json::Num(speedup));
            o.insert(
                "reports_identical".to_string(),
                Json::Bool(folded.to_json().to_string() == unfolded.to_json().to_string()),
            );
            format!("{speedup:.1}x vs unfolded")
        } else {
            o.insert("unfolded_skipped".to_string(), Json::Bool(true));
            "- (unfolded skipped)".to_string()
        };
        t.row(vec![
            "a800-uniform".to_string(),
            gpus.to_string(),
            format!("fleet beam-{beam_width}"),
            folded.n_simulated().to_string(),
            format!("{folded_secs:.3}"),
            format!("{:.0}", folded.n_simulated() as f64 / folded_secs.max(1e-9)),
            speedup_cell,
            best,
        ]);
        fleet_entries.push(Json::Obj(o));
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("plan_search".into()));
    root.insert("quick".to_string(), Json::Bool(quick));
    root.insert("beam_width".to_string(), Json::Num(beam_width as f64));
    root.insert("evo_generations".to_string(), Json::Num(evo_gens as f64));
    root.insert("evo_population".to_string(), Json::Num(evo_pop as f64));
    root.insert("evo_seed".to_string(), Json::Num(evo_seed as f64));
    root.insert(
        "gpus_swept".to_string(),
        Json::Arr(budgets.iter().map(|&g| Json::Num(g as f64)).collect()),
    );
    root.insert(
        "fleet_sizes".to_string(),
        Json::Arr(fleet_sizes.iter().map(|&g| Json::Num(g as f64)).collect()),
    );
    root.insert("entries".to_string(), Json::Arr(entries));
    root.insert("fleet".to_string(), Json::Arr(fleet_entries));
    let path = "BENCH_plan_search.json";
    let note = match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => format!("wrote {path}"),
        Err(e) => format!("could not write {path}: {e}"),
    };
    format!(
        "== plan-search perf: exhaustive vs beam-{beam_width} vs \
         evo-{evo_gens}-{evo_pop}-{evo_seed} on uniform A800 and the large mixed pool, \
         plus the fleet-scale folded-vs-unfolded sweep (12.1B)\n{}\n{note}",
        t.render()
    )
}

/// Heterogeneous auto-planner demo — the runnable Fig. 13-style "who wins
/// flips with hardware" result: plan the same 16-GPU budget over a
/// uniform A800 pool, a uniform H20 pool, and the mixed A800+H20 preset.
/// On the mixed pool the planner balances *stage time* (non-uniform layer
/// split), enumerates fast-first vs interleaved group orders, and rejects
/// per-device OOM against each group's own `mem_gib`.
pub fn plan_mixed() -> String {
    use crate::plan::{plan, PlanModel, PlanQuery};
    let pools = [
        ClusterSpec::uniform(HardwareProfile::a800()),
        ClusterSpec::uniform(HardwareProfile::h20()),
        ClusterSpec::mixed_a800_h20(),
    ];
    let mut out = Vec::new();
    let mut best_lines = Vec::new();
    for cluster in pools {
        let mut q = PlanQuery::new(
            PlanModel::Llm(ModelConfig::qwen2_12b()),
            cluster,
            16,
        );
        q.n_mb_options = vec![16, 64];
        let r = plan(&q);
        best_lines.push(format!(
            "{:16} -> {}",
            r.cluster_name,
            r.best().map(|b| b.candidate.label()).unwrap_or_else(|| "no feasible plan".into())
        ));
        out.push(r.render(5));
    }
    format!(
        "{}\n== who wins flips with hardware (best plan per pool)\n{}",
        out.join("\n"),
        best_lines.join("\n")
    )
}

/// `stp bench train` — the executor perf trajectory: time real virtual
/// training across schedule kinds on the python `test` preset's dims
/// (`python/compile/config.py::TEST`), with the naive
/// `kernels::reference` path as the baseline, and record tokens/sec +
/// per-step seconds in `BENCH_train_virtual.json` at the **repo root**
/// (resolved from the crate manifest, not the cwd) so later PRs can
/// prove they don't regress the hot path. `quick` trims the schedule
/// sweep (the CI perf-smoke mode); `filter` restricts the kernel paths
/// measured (`--kernels simd` times reference + simd only — the
/// reference baseline always runs so speedups stay comparable).
pub fn train_virtual(quick: bool, filter: Option<crate::exec::KernelPath>) -> String {
    use std::collections::BTreeMap;

    use crate::config::json::Json;
    use crate::config::ManifestDims;
    use crate::exec::{train, KernelPath, TrainConfig};

    // The python `test` preset: miniature Qwen2 family, tp2·pp2·vpp2.
    let dims = ManifestDims::test_preset();
    let n_mb = 8;
    let steps = if quick { 3 } else { 4 };
    // vpp = 2 dims ⇒ the vpp-2 schedule families plus GPipe (which keeps
    // arbitrary vpp); 1f1b/zb-h1 rebuild the topo at vpp = 1 and would
    // not match the preset's chunk grid.
    let kinds: &[ScheduleKind] = if quick {
        &[ScheduleKind::Stp, ScheduleKind::ZbV]
    } else {
        &[ScheduleKind::Stp, ScheduleKind::ZbV, ScheduleKind::GPipe, ScheduleKind::StpMemEff]
    };
    let paths: Vec<KernelPath> = match filter {
        None => vec![KernelPath::Reference, KernelPath::Blocked, KernelPath::Simd],
        Some(KernelPath::Reference) => vec![KernelPath::Reference],
        Some(k) => vec![KernelPath::Reference, k],
    };

    let run_one = |kind: ScheduleKind, path: KernelPath| {
        let mut cfg = TrainConfig::virtual_default();
        cfg.schedule = kind;
        cfg.steps = steps;
        cfg.n_mb = n_mb;
        cfg.dims = Some(dims.clone());
        cfg.kernels = path;
        train(&cfg).expect("virtual training failed in bench")
    };

    let mut t = Table::new(vec![
        "schedule", "kernels", "tokens/s", "per-step s", "ws peak KB", "speedup",
    ]);
    let mut entries: Vec<Json> = Vec::new();
    let mut speedup_stp = 0.0f64;
    for &kind in kinds {
        // The reference baseline runs once per kind (it is the slow leg).
        let mut baseline_tps = 0.0f64;
        let mut blocked_tps = 0.0f64;
        for &path in &paths {
            let r = run_one(kind, path);
            // Steady-state: step 0 (spawn + arena warm-up) excluded.
            let tps = r.tokens_per_sec(n_mb, dims.mb, dims.seq);
            let speedup = match path {
                KernelPath::Reference => {
                    baseline_tps = tps;
                    1.0
                }
                _ => {
                    if path == KernelPath::Blocked {
                        blocked_tps = tps;
                    }
                    tps / baseline_tps.max(1e-12)
                }
            };
            if kind == ScheduleKind::Stp && path != KernelPath::Reference {
                speedup_stp = speedup;
            }
            let per_step: Vec<f64> = r.steps.iter().map(|s| s.secs).collect();
            let ws_peak = r.workspace_peak_bytes.iter().copied().max().unwrap_or(0);
            t.row(vec![
                kind.name().to_string(),
                path.name().to_string(),
                format!("{tps:.0}"),
                per_step.iter().skip(1).map(|s| format!("{s:.4}")).collect::<Vec<_>>().join(" "),
                (ws_peak / 1024).to_string(),
                format!("{speedup:.2}x"),
            ]);
            let mut o = BTreeMap::new();
            o.insert("schedule".to_string(), Json::Str(kind.name().into()));
            o.insert("kernels".to_string(), Json::Str(path.name().into()));
            o.insert("tokens_per_sec".to_string(), Json::Num(tps));
            o.insert(
                "per_step_secs".to_string(),
                Json::Arr(per_step.iter().map(|&s| Json::Num(s)).collect()),
            );
            o.insert("workspace_peak_bytes".to_string(), Json::Num(ws_peak as f64));
            o.insert(
                "workspace_steady_allocs".to_string(),
                Json::Num(r.workspace_steady_allocs as f64),
            );
            o.insert("speedup_vs_reference".to_string(), Json::Num(speedup));
            if path == KernelPath::Simd && blocked_tps > 0.0 {
                // The tentpole number: SIMD + workers + flash vs the PR-5
                // blocked kernels, same schedule, same preset.
                o.insert(
                    "speedup_vs_blocked".to_string(),
                    Json::Num(tps / blocked_tps.max(1e-12)),
                );
            }
            o.insert("first_loss".to_string(), Json::Num(r.first_loss() as f64));
            o.insert("last_loss".to_string(), Json::Num(r.last_loss() as f64));
            entries.push(Json::Obj(o));
        }
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("train_virtual".into()));
    root.insert("preset".to_string(), Json::Str("test".into()));
    root.insert("quick".to_string(), Json::Bool(quick));
    root.insert("n_mb".to_string(), Json::Num(n_mb as f64));
    root.insert("steps".to_string(), Json::Num(steps as f64));
    root.insert(
        "tokens_per_step".to_string(),
        Json::Num((n_mb * dims.mb * dims.seq) as f64),
    );
    root.insert("entries".to_string(), Json::Arr(entries));
    // Anchor at the repo root (the crate lives in rust/) so CI and local
    // runs agree on where the trajectory record lands, cwd-independent.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|r| r.join("BENCH_train_virtual.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_train_virtual.json"));
    let note = match std::fs::write(&path, Json::Obj(root).to_string()) {
        Ok(()) => format!("wrote {}", path.display()),
        Err(e) => format!("could not write {}: {e}", path.display()),
    };
    format!(
        "== train-virtual perf: arena kernel paths vs naive reference (test preset, \
         tp2-pp2-vpp2, m{n_mb})\n{}\nstp fastest-vs-reference speedup: {speedup_stp:.2}x\n{note}",
        t.render()
    )
}

/// Run every regenerator (the `stp bench all` target).
pub fn all() -> String {
    [
        fig1(),
        table1(),
        fig7(),
        fig8(),
        fig9(),
        table3(),
        fig10(),
        table4(),
        table567(),
        table8(),
        fig13(),
        table9(),
        table10(),
        table11_sim(),
    ]
    .join("\n")
}

/// Dispatch by experiment id.
pub fn by_name(name: &str) -> Option<String> {
    by_name_with(name, None)
}

/// Dispatch by experiment id, with an optional kernel-path filter for the
/// training benches (`stp bench train --kernels simd`). Non-training
/// benches ignore the filter.
pub fn by_name_with(name: &str, kernels: Option<crate::exec::KernelPath>) -> Option<String> {
    Some(match name {
        "fig1" => fig1(),
        "table1" => table1(),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "table3" => table3(),
        "fig10" => fig10(),
        "table4" => table4(),
        "table5" | "table6" | "table7" | "table567" => table567(),
        "table8" => table8(),
        "fig13" => fig13(),
        "table9" => table9(),
        "table10" => table10(),
        "table11" => table11_sim(),
        "plan" => plan16(),
        "plan-perf" => plan_perf(false),
        "plan-quick" | "plan-perf-quick" => plan_perf(true),
        "plan-mixed" | "plan-hetero" => plan_mixed(),
        "train" | "train-perf" => train_virtual(false, kernels),
        "train-quick" => train_virtual(true, kernels),
        "all" => all(),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_overlap_improves_with_tp() {
        let out = fig1();
        assert!(out.contains("Fig. 1"));
        // 3 data rows.
        assert_eq!(out.lines().count(), 2 + 1 + 3);
    }

    #[test]
    fn table9_memory_monotone_decreasing() {
        let out = table9();
        let gbs: Vec<f64> = out
            .lines()
            .skip(3)
            .filter_map(|l| l.split_whitespace().last()?.parse().ok())
            .collect();
        assert_eq!(gbs.len(), 4);
        assert!(gbs.windows(2).all(|w| w[1] < w[0]), "AC memory not monotone: {gbs:?}");
    }

    #[test]
    fn table11_overlap_saves_time() {
        let out = table11_sim();
        assert!(out.contains("GEMM dominates"));
    }
}
