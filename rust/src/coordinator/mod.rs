//! Leader/CLI coordinator: parses arguments, builds topologies, dispatches
//! to the simulator, the bench harness, the tracer, the validator or the
//! real training executor. Hand-rolled argument parsing (no clap in this
//! offline environment).

use std::collections::HashMap;

use crate::cluster::{ClusterSpec, HardwareProfile, Topology};
use crate::model::ModelConfig;
use crate::schedule::{build_schedule, build_schedule_scaled, validate, ScheduleKind};
use crate::sim::{CostModel, Simulator};
use crate::trace::{ascii_timeline, chrome_trace};
use crate::Result;

const USAGE: &str = "\
stp — Synergistic Tensor and Pipeline Parallelism (NeurIPS 2025 reproduction)

USAGE:
  stp sim      --tp N --pp N [--model 12b|26b] [--seq N] [--mbsize N]
               [--mb N] [--schedule KIND] [--hw a800|h20]
               [--cluster mixed|FILE.json]
  stp bench    <fig1|table1|fig7|fig8|fig9|table3|fig10|table4|table567|
                table8|fig13|table9|table10|table11|plan|plan-mixed|
                plan-perf|plan-quick|train|train-quick|all>
               [--kernels blocked|simd|reference]
  stp trace    [--schedule KIND] [--pp N] [--tp N] [--mb N] [--width N]
               [--chrome FILE] [--all-schedules] [--cluster mixed|FILE.json]
  stp validate [--schedule KIND] [--pp N] [--mb N]
  stp plan     --gpus N [--mem-gib F] [--model 12b|26b|tiny|mllm-14.9b|
               mllm-28.8b] [--hw a800|h20] [--cluster mixed|FILE.json]
               [--seq N] [--mbsize N] [--topk N] [--threads N]
               [--search exhaustive|beam|evo] [--beam-width N]
               [--generations N] [--population N] [--evo-seed N]
               [--emit-plan FILE.json] [--verbose] [--json]
  stp serve    [--threads N]
  stp train    [--plan FILE.json] [--backend virtual|pjrt]
               [--kernels blocked|simd|reference] [--workers N]
               [--virtual-scale auto|F]
               [--artifacts DIR] [--schedule KIND] [--steps N] [--mb N]
               [--dp N] [--lr F] [--seed N] [--quiet]
               [--faults FILE.json] [--checkpoint-dir DIR]
               [--keep-checkpoints K] [--resume CKPT.json|latest|DIR]
               [--elastic] [--replan [--beam-width N]]

Schedules: gpipe 1f1b 1f1b-i zb-v zb-h1 stp stp-memeff stp-offload
Serve:     planning-as-a-service — one JSON query object per stdin line
           (keys: model, cluster, hw, gpus, mem_gib, seq, mbsize,
           search, beam_width, generations, population, evo_seed),
           one PlanReport JSON per stdout line,
           byte-identical to `stp plan --json` for the same query.
           Reports are cached by canonical query key; cluster/budget
           deltas re-simulate only candidates whose resolved hardware
           changed. Diagnostics go to stderr.
Clusters:  --cluster mixed (1 A800 node + 1 H20 node), mixed-large
           (8 + 8 nodes) or a JSON spec file; without it the pool is
           uniform over --hw.
Training:  the virtual backend (default) runs everywhere on miniature
           deterministic tensors; --backend pjrt executes AOT artifacts
           from --artifacts and needs the `pjrt` feature. --plan replays
           a `stp plan --emit-plan` artifact (schedule, topology, layer
           split) through the executor. --kernels reference selects the
           naive oracle kernels (bit-equal, slow — the bench baseline);
           --kernels simd adds register-tiled SIMD GEMMs, a worker pool
           (--workers N threads per device thread, 0 = auto) and flash
           attention (deterministic at any width, ≤1e-5 vs the oracle);
           --virtual-scale widens the proxy model by an integer width
           factor (fractional values round to the nearest factor;
           auto = match the host's core count).
Elastic:   --dp runs N data-parallel replicas of the pipeline (fixed
           global batch dp*mb); --faults injects a deterministic
           stp-faults-v1 script (events carry a DP replica; a dead rank
           halts the run at that step's cut and --checkpoint-dir
           receives a crash-safe stp-ckpt-v2 snapshot, with
           --keep-checkpoints pruning all but the newest K);
           --resume restarts bit-identically from a snapshot file, or
           from the newest complete snapshot in a directory ('latest'
           uses --checkpoint-dir; torn files fall back one step);
           --elastic auto-recovers after each death: while dp > 1 the
           dead replica is quarantined and the survivors continue at a
           batch-preserving width; --replan additionally shrinks the
           pool, re-searches the plan and migrates the checkpoint when
           the last replica loses a pipeline stage (requires --plan).
";

/// Parse `--key value` pairs after the subcommand.
pub fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn flag<T: std::str::FromStr>(f: &HashMap<String, String>, key: &str, default: T) -> T {
    f.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Model lookup shared by the CLI and the examples.
pub fn model_by_name(name: &str) -> ModelConfig {
    match name {
        "26b" | "qwen2-26b" | "qwen2-26.3b" => ModelConfig::qwen2_26b(),
        "tiny" | "tiny-100m" => ModelConfig::tiny_100m(),
        _ => ModelConfig::qwen2_12b(),
    }
}

/// Planner-model lookup (LLMs plus the MLLM configs).
pub fn plan_model_by_name(name: &str) -> crate::plan::PlanModel {
    use crate::plan::PlanModel;
    match name {
        "mllm-14.9b" | "mllm-14.9" | "qwen2vl-14.9b" => {
            PlanModel::Mllm(crate::model::MllmConfig::qwen2vl_14_9b())
        }
        "mllm-28.8b" | "mllm-28.8" | "qwen2vl-28.8b" => {
            PlanModel::Mllm(crate::model::MllmConfig::qwen2vl_28_8b())
        }
        _ => PlanModel::Llm(model_by_name(name)),
    }
}

/// Hardware-profile lookup shared by the CLI and the examples.
pub fn hw_by_name(name: &str) -> HardwareProfile {
    match name {
        "h20" | "h20-96g" => HardwareProfile::h20(),
        "cpu" | "cpu-sim" => HardwareProfile::cpu_sim(),
        _ => HardwareProfile::a800(),
    }
}

/// Cluster lookup shared by the CLI and the examples: a preset name
/// ("mixed"), a path to a JSON spec, or a uniform pool over a profile
/// name ("a800" / "h20" / "cpu").
pub fn cluster_by_name(name: &str) -> Result<ClusterSpec> {
    match name {
        "mixed" | "mixed-a800-h20" | "a800+h20" => Ok(ClusterSpec::mixed_a800_h20()),
        "mixed-large" | "mixed-a800-h20-large" => Ok(ClusterSpec::mixed_a800_h20_large()),
        path if path.ends_with(".json") => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cluster spec {path}: {e}"))?;
            let json = crate::config::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("cluster spec {path}: {e}"))?;
            ClusterSpec::from_json(&json).map_err(|e| anyhow::anyhow!("cluster spec {path}: {e}"))
        }
        // Bare names and the full profile names a plan artifact records
        // as its `cluster` field — replanning resolves pools from those.
        "a800" | "h20" | "cpu" | "a800-sxm4-80g" | "h20-96g" | "cpu-sim" => {
            Ok(ClusterSpec::uniform(hw_by_name(name)))
        }
        other => Err(anyhow::anyhow!(
            "unknown cluster '{other}' (expected 'mixed', 'mixed-large', a .json spec path, \
             or a800|h20|cpu)"
        )),
    }
}

/// Resolve the pool for a subcommand: `--cluster` wins, else a uniform
/// pool over `--hw`.
fn cluster_from_flags(flags: &HashMap<String, String>) -> Result<ClusterSpec> {
    match flags.get("cluster") {
        Some(name) => cluster_by_name(name),
        None => Ok(ClusterSpec::uniform(hw_by_name(&flag::<String>(
            flags,
            "hw",
            "a800".into(),
        )))),
    }
}

/// Graceful CLI error (instead of the cost model's panic) when a pool
/// cannot host the requested topology.
fn check_hosts(cluster: &ClusterSpec, topo: &Topology) -> Result<()> {
    if cluster.device_view(topo, crate::cluster::GroupOrder::Declared).is_none() {
        anyhow::bail!(
            "cluster '{}' ({} devices) cannot host {topo} ({} devices)",
            cluster.name,
            cluster.total_devices(),
            topo.world_size()
        );
    }
    Ok(())
}

/// CLI entry point. Returns the process exit code.
pub fn run_cli(args: Vec<String>) -> Result<i32> {
    let Some(cmd) = args.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(2);
    };
    let flags = parse_flags(&args[1..]);
    match cmd {
        "sim" => {
            let model = model_by_name(&flag::<String>(&flags, "model", "12b".into()));
            let cluster = cluster_from_flags(&flags)?;
            let topo = Topology::new(
                flag(&flags, "tp", 8usize),
                flag(&flags, "pp", 2usize),
                flag(&flags, "dp", 1usize),
            )
            .with_cp(flag(&flags, "cp", 1usize));
            let seq = flag(&flags, "seq", 6144usize);
            let mb_size = flag(&flags, "mbsize", 1usize);
            let n_mb = flag(&flags, "mb", 64usize);
            let kind: ScheduleKind =
                flag::<String>(&flags, "schedule", "stp".into()).parse().map_err(|e| anyhow::anyhow!("{e}"))?;
            check_hosts(&cluster, &topo)?;
            let cost = CostModel::analytic_for(
                &model,
                &topo,
                &cluster,
                crate::cluster::GroupOrder::Declared,
                kind.placement(),
                seq,
                mb_size,
            );
            let s = build_schedule_scaled(kind, &topo, n_mb, cost.chunk_scales());
            let r = Simulator::new(&cost).run(&s);
            println!(
                "{} | {} {} seq={seq} mbsize={mb_size} m={n_mb} cluster={}\n\
                 iteration      {:>10.3} s\n\
                 throughput     {:>10.2} samples/s\n\
                 MFU            {:>10.2} %\n\
                 TP bubble/dev  {:>10.3} s\n\
                 PP bubble/dev  {:>10.3} s\n\
                 peak act mem   {:>10.1} GB\n\
                 peak total mem {:>10.1} GB{}",
                kind.name(),
                model.name,
                topo,
                cluster.name,
                r.iteration_secs,
                r.throughput(),
                100.0 * r.mfu(),
                r.tp_bubble_per_device(),
                r.pp_bubble_per_device(),
                r.peak_activation_gb(),
                r.peak_memory_bytes() as f64 / 1e9,
                if r.is_oom() { "  [OOM]" } else { "" },
            );
            Ok(0)
        }
        "bench" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            let kfilter = match flags.get("kernels") {
                Some(k) => Some(
                    k.parse::<crate::exec::KernelPath>().map_err(|e| anyhow::anyhow!("{e}"))?,
                ),
                None => None,
            };
            match crate::bench::by_name_with(which, kfilter) {
                Some(out) => {
                    println!("{out}");
                    Ok(0)
                }
                None => {
                    eprintln!("unknown bench '{which}'\n{USAGE}");
                    Ok(2)
                }
            }
        }
        "trace" => {
            let topo = Topology::new(flag(&flags, "tp", 1usize), flag(&flags, "pp", 4usize), 1);
            let n_mb = flag(&flags, "mb", 12usize);
            let width = flag(&flags, "width", 160usize);
            let model = model_by_name(&flag::<String>(&flags, "model", "12b".into()));
            let cluster = cluster_from_flags(&flags)?;
            check_hosts(&cluster, &topo)?;
            let kinds: Vec<ScheduleKind> = if flags.contains_key("all-schedules") {
                ScheduleKind::all().to_vec()
            } else {
                vec![flag::<String>(&flags, "schedule", "stp".into())
                    .parse()
                    .map_err(|e| anyhow::anyhow!("{e}"))?]
            };
            for kind in kinds {
                let cost = CostModel::analytic_for(
                    &model,
                    &topo,
                    &cluster,
                    crate::cluster::GroupOrder::Declared,
                    kind.placement(),
                    4096,
                    1,
                );
                let s = build_schedule(kind, &topo, n_mb);
                let r = Simulator::new(&cost).run(&s);
                println!("{}", ascii_timeline(&r, width));
                if let Some(path) = flags.get("chrome") {
                    let file = format!("{path}.{}.json", kind.name());
                    std::fs::write(&file, chrome_trace(&r))?;
                    println!("wrote {file}");
                }
            }
            Ok(0)
        }
        "validate" => {
            let topo = Topology::new(flag(&flags, "tp", 1usize), flag(&flags, "pp", 4usize), 1);
            let n_mb = flag(&flags, "mb", 12usize);
            let mut bad = 0;
            let kinds: Vec<ScheduleKind> = match flags.get("schedule") {
                Some(k) => vec![k.parse().map_err(|e| anyhow::anyhow!("{e}"))?],
                None => ScheduleKind::all().to_vec(),
            };
            for kind in kinds {
                let s = build_schedule(kind, &topo, n_mb);
                let v = validate(&s);
                if v.is_empty() {
                    println!("{:12} OK ({} ops)", kind.name(), s.num_ops());
                } else {
                    bad += 1;
                    println!("{:12} {} violations", kind.name(), v.len());
                    for x in v.iter().take(5) {
                        println!("    {x}");
                    }
                }
            }
            Ok(if bad == 0 { 0 } else { 1 })
        }
        "plan" => run_plan(&flags),
        "serve" => run_serve(&flags),
        "train" => run_train(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            Ok(2)
        }
    }
}

/// `stp plan`: run the parallelism auto-planner over a GPU budget.
fn run_plan(flags: &HashMap<String, String>) -> Result<i32> {
    use crate::plan::{plan, PlanQuery, SearchMode};

    let model = plan_model_by_name(&flag::<String>(flags, "model", "12b".into()));
    let cluster = cluster_from_flags(flags)?;
    let gpus = flag(flags, "gpus", 16usize);
    let mut q = PlanQuery::new(model, cluster, gpus);
    q.mem_cap_gib = flag(flags, "mem-gib", q.mem_cap_gib);
    q.seq = flag(flags, "seq", q.seq);
    q.mb_size = flag(flags, "mbsize", q.mb_size);
    q.threads = flag(flags, "threads", q.threads);
    let width = flag(flags, "beam-width", 8usize);
    let generations = flag(flags, "generations", 12usize);
    let population = flag(flags, "population", 24usize);
    let evo_seed = flag(flags, "evo-seed", 42u64);
    q.search = match flag::<String>(flags, "search", "exhaustive".into()).as_str() {
        "beam" => {
            anyhow::ensure!(width >= 1, "--beam-width must be at least 1");
            SearchMode::Beam { width }
        }
        "evo" | "evolutionary" => {
            anyhow::ensure!(generations >= 1, "--generations must be at least 1");
            anyhow::ensure!(population >= 1, "--population must be at least 1");
            SearchMode::Evo { generations, population, seed: evo_seed }
        }
        "exhaustive" | "full" => SearchMode::Exhaustive,
        other => anyhow::bail!("unknown search mode '{other}' (expected exhaustive|beam|evo)"),
    };
    let topk = flag(flags, "topk", 10usize);
    let json = flags.contains_key("json");
    let report = plan(&q);
    if json {
        // One machine-readable line; exactly the bytes `stp serve`
        // answers for the same query, so the CI smoke can `cmp` them.
        println!("{}", report.to_json());
    } else {
        println!("{}", report.render(topk));
        if flags.contains_key("verbose") {
            println!("{}", report.reject_tally_line());
        }
    }
    if let Some(path) = flags.get("emit-plan") {
        match &report.best_artifact {
            Some(a) => {
                a.save(path)?;
                let note = format!("wrote plan artifact {path} ({})", a.label());
                if json {
                    eprintln!("{note}");
                } else {
                    println!("{note}");
                }
            }
            None => anyhow::bail!("no memory-feasible plan to emit"),
        }
    }
    match report.best() {
        Some(_) => Ok(0),
        None => {
            eprintln!("{}", report.no_plan_diagnostic());
            Ok(1)
        }
    }
}

/// Resolve a serve query's device pool: a preset/path name string, an
/// inline `ClusterSpec` JSON object, or (absent) a uniform pool over
/// the query's `hw` field — the same ladder as the `stp plan` flags.
fn serve_cluster(spec: Option<&crate::config::Json>, hw: &str) -> Result<ClusterSpec> {
    use crate::config::Json;
    match spec {
        None => Ok(ClusterSpec::uniform(hw_by_name(hw))),
        Some(Json::Str(name)) => cluster_by_name(name),
        Some(obj @ Json::Obj(_)) => {
            ClusterSpec::from_json(obj).map_err(|e| anyhow::anyhow!("inline cluster spec: {e}"))
        }
        Some(other) => {
            anyhow::bail!("'cluster' must be a preset name or an inline spec object, got {other}")
        }
    }
}

/// Build the [`PlanQuery`](crate::plan::PlanQuery) for one serve line —
/// field for field the same construction as the `stp plan` flags, so
/// the answer is byte-identical to `stp plan --json`.
fn serve_query(
    line: &crate::config::Json,
    flags: &HashMap<String, String>,
) -> Result<crate::plan::PlanQuery> {
    use crate::config::Json;
    use crate::plan::{PlanQuery, SearchMode};

    let str_of = |key: &str, default: &str| -> String {
        line.get(key).and_then(Json::as_str).unwrap_or(default).to_string()
    };
    let model = plan_model_by_name(&str_of("model", "12b"));
    let cluster = serve_cluster(line.get("cluster"), &str_of("hw", "a800"))?;
    let gpus = line.get("gpus").and_then(Json::as_usize).unwrap_or(16);
    let mut q = PlanQuery::new(model, cluster, gpus);
    if let Some(v) = line.get("mem_gib").and_then(Json::as_f64) {
        q.mem_cap_gib = v;
    }
    if let Some(v) = line.get("seq").and_then(Json::as_usize) {
        q.seq = v;
    }
    if let Some(v) = line.get("mbsize").and_then(Json::as_usize) {
        q.mb_size = v;
    }
    q.threads = flag(flags, "threads", q.threads);
    let width = line.get("beam_width").and_then(Json::as_usize).unwrap_or(8);
    let generations = line.get("generations").and_then(Json::as_usize).unwrap_or(12);
    let population = line.get("population").and_then(Json::as_usize).unwrap_or(24);
    let evo_seed = line.get("evo_seed").and_then(Json::as_usize).unwrap_or(42) as u64;
    q.search = match str_of("search", "exhaustive").as_str() {
        "beam" => {
            anyhow::ensure!(width >= 1, "beam_width must be at least 1");
            SearchMode::Beam { width }
        }
        "evo" | "evolutionary" => {
            anyhow::ensure!(generations >= 1, "generations must be at least 1");
            anyhow::ensure!(population >= 1, "population must be at least 1");
            SearchMode::Evo { generations, population, seed: evo_seed }
        }
        "exhaustive" | "full" => SearchMode::Exhaustive,
        other => anyhow::bail!("unknown search mode '{other}' (expected exhaustive|beam|evo)"),
    };
    Ok(q)
}

/// `stp serve`: the planning daemon — one JSON query per stdin line,
/// one `PlanReport` JSON line on stdout, answered through the keyed
/// [`PlanCache`](crate::plan::PlanCache): exact repeats come from the
/// report store, cluster/budget deltas re-search with memoized
/// evaluations. Malformed queries answer `{"error": ...}` and keep the
/// daemon alive; diagnostics go to stderr.
fn run_serve(flags: &HashMap<String, String>) -> Result<i32> {
    use std::io::{BufRead, Write};

    use crate::config::Json;
    use crate::plan::PlanCache;

    let mut cache = PlanCache::new();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let mut n = 0usize;
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        n += 1;
        let t0 = std::time::Instant::now();
        let parsed = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("bad query JSON: {e}"))
            .and_then(|j| serve_query(&j, flags));
        match parsed {
            Ok(q) => {
                let a = cache.query(&q);
                out.write_all(a.json.as_bytes())?;
                out.write_all(b"\n")?;
                out.flush()?;
                eprintln!(
                    "serve: query {n} {} in {:.1} ms ({} sims run, {} reused)",
                    if a.hit { "cache-hit" } else { "planned" },
                    t0.elapsed().as_secs_f64() * 1e3,
                    a.sims_run,
                    a.sims_reused,
                );
            }
            Err(e) => {
                let mut obj = std::collections::BTreeMap::new();
                obj.insert("error".to_string(), Json::Str(e.to_string()));
                out.write_all(Json::Obj(obj).to_string().as_bytes())?;
                out.write_all(b"\n")?;
                out.flush()?;
                eprintln!("serve: query {n} rejected: {e}");
            }
        }
    }
    eprintln!("serve: answered {n} queries ({} cached reports)", cache.len());
    Ok(0)
}

/// `stp train`: pipeline training through the backend-abstract executor —
/// the virtual backend in any build, PJRT with the `pjrt` feature, and
/// optionally a `stp plan --emit-plan` artifact as the schedule source.
fn run_train(flags: &HashMap<String, String>) -> Result<i32> {
    use std::path::PathBuf;

    use crate::exec::{host_virtual_scale, train, BackendKind, KernelPath, TrainConfig};
    use crate::plan::PlanArtifact;

    let backend: BackendKind = flag::<String>(flags, "backend", "virtual".into())
        .parse()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let kernels: KernelPath = flag::<String>(flags, "kernels", "blocked".into())
        .parse()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let virtual_scale = match flags.get("virtual-scale").map(String::as_str) {
        None => 1.0,
        Some("auto") => host_virtual_scale(),
        Some(v) => {
            let s: f64 = v.parse().map_err(|_| {
                anyhow::anyhow!("bad --virtual-scale '{v}' (expected 'auto' or a number ≥ 1)")
            })?;
            anyhow::ensure!(s.is_finite() && s >= 1.0, "--virtual-scale must be ≥ 1, got {s}");
            if s.round() != s {
                eprintln!("--virtual-scale {s} rounds to the integer width factor {}", s.round());
            }
            s
        }
    };
    let plan_artifact = match flags.get("plan") {
        Some(path) => Some(PlanArtifact::load(path)?),
        None => None,
    };
    let faults = match flags.get("faults") {
        Some(path) => Some(crate::elastic::FaultPlan::load(path)?),
        None => None,
    };
    let checkpoint_dir = flags.get("checkpoint-dir").map(PathBuf::from);
    let resume = match flags.get("resume").map(String::as_str) {
        Some("latest") => {
            let dir = checkpoint_dir
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("--resume latest needs --checkpoint-dir"))?;
            Some(crate::elastic::Checkpoint::load_latest(dir)?)
        }
        Some(path) if std::path::Path::new(path).is_dir() => {
            Some(crate::elastic::Checkpoint::load_latest(std::path::Path::new(path))?)
        }
        Some(path) => Some(crate::elastic::Checkpoint::load(std::path::Path::new(path))?),
        None => None,
    };
    let cfg = TrainConfig {
        backend,
        kernels,
        artifacts_dir: PathBuf::from(flag::<String>(
            flags,
            "artifacts",
            "artifacts/e2e".into(),
        )),
        schedule: flag::<String>(flags, "schedule", "stp".into())
            .parse()
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        n_mb: flag(flags, "mb", 4usize),
        dp: flags.get("dp").and_then(|v| v.parse().ok()),
        steps: flag(flags, "steps", 20usize),
        lr: flag(flags, "lr", 0.1f32),
        seed: flag(flags, "seed", 42u64),
        verbose: !flags.contains_key("quiet"),
        dims: None,
        virtual_scale,
        plan: plan_artifact,
        faults,
        checkpoint_dir,
        keep_checkpoints: flags.get("keep-checkpoints").and_then(|v| v.parse().ok()),
        resume,
        workers: flag(flags, "workers", 0usize),
    };
    let what = match &cfg.plan {
        Some(p) => format!("plan {}", p.label()),
        None => format!("{} schedule", cfg.schedule.name()),
    };

    if flags.contains_key("replan") || flags.contains_key("elastic") {
        use crate::elastic::{run_elastic, ElasticConfig, ReplanContext};
        let replan = if flags.contains_key("replan") {
            let artifact = cfg.plan.clone().ok_or_else(|| {
                anyhow::anyhow!("--replan needs --plan FILE.json to re-search from")
            })?;
            Some(ReplanContext {
                model: plan_model_by_name(&artifact.model),
                cluster: cluster_by_name(&artifact.cluster)?,
                seq: artifact.seq,
                mb_size: artifact.mb_size,
                mem_cap_gib: flag(flags, "mem-gib", 0.0f64),
                beam_width: flag(flags, "beam-width", 8usize),
            })
        } else {
            None
        };
        let ecfg = ElasticConfig { train: cfg, replan };
        let report = run_elastic(&ecfg)?;
        println!(
            "elastic: {} segments, {} replans ({what}): loss {:.4} -> {:.4}",
            report.segments.len(),
            report.replanned.len(),
            report.first_loss(),
            report.last_loss(),
        );
        for marker in &report.recoveries {
            println!("recovered: {marker}");
        }
        for plan in &report.replanned {
            println!("replanned onto {}", plan.label());
        }
        anyhow::ensure!(report.last_loss().is_finite(), "training diverged: non-finite loss");
        return Ok(0);
    }

    let report = train(&cfg)?;
    println!(
        "trained {} steps ({what}, {} backend, {} kernels): loss {:.4} -> {:.4}, {:.1}s wall, \
         {} unit execs, {:.1} MB all-reduced, peak act/stage {:?} MB, \
         ws peak/stage {:?} KB ({} steady allocs)",
        report.steps.len(),
        report.backend.name(),
        cfg.kernels.name(),
        report.first_loss(),
        report.last_loss(),
        report.wall_secs,
        report.executions,
        report.allreduce_bytes as f64 / 1e6,
        report
            .peak_activation_bytes
            .iter()
            .map(|b| (b / 1_000_000).to_string())
            .collect::<Vec<_>>(),
        report
            .workspace_peak_bytes
            .iter()
            .map(|b| (b / 1024).to_string())
            .collect::<Vec<_>>(),
        report.workspace_steady_allocs,
    );
    if let Some(halt) = report.interrupted_at {
        println!(
            "fault: replica {} stage {} died, halted at the step-{halt} cut{}",
            report.fault_replica.map(|q| q.to_string()).unwrap_or_else(|| "?".into()),
            report.fault_stage.map(|s| s.to_string()).unwrap_or_else(|| "?".into()),
            report
                .checkpoint_path
                .as_ref()
                .map(|p| format!(", checkpoint {}", p.display()))
                .unwrap_or_default(),
        );
    }
    anyhow::ensure!(report.last_loss().is_finite(), "training diverged: non-finite loss");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing() {
        let args: Vec<String> =
            ["--tp", "8", "--quiet", "--schedule", "zb-v"].iter().map(|s| s.to_string()).collect();
        let f = parse_flags(&args);
        assert_eq!(flag(&f, "tp", 0usize), 8);
        assert_eq!(f.get("quiet").unwrap(), "true");
        assert_eq!(f.get("schedule").unwrap(), "zb-v");
        assert_eq!(flag(&f, "missing", 7usize), 7);
    }

    #[test]
    fn serve_query_matches_the_plan_flag_construction() {
        use crate::config::Json;
        use crate::plan::{canonical_key, PlanQuery};

        let j = Json::parse("{\"model\":\"tiny\",\"gpus\":4,\"seq\":1024}").unwrap();
        let q = serve_query(&j, &HashMap::new()).unwrap();
        let mut want = PlanQuery::new(
            plan_model_by_name("tiny"),
            ClusterSpec::uniform(hw_by_name("a800")),
            4,
        );
        want.seq = 1024;
        assert_eq!(canonical_key(&q), canonical_key(&want), "defaults must mirror `stp plan`");

        let delta =
            Json::parse("{\"model\":\"tiny\",\"gpus\":4,\"seq\":1024,\"cluster\":\"h20\"}")
                .unwrap();
        let q2 = serve_query(&delta, &HashMap::new()).unwrap();
        assert_ne!(canonical_key(&q), canonical_key(&q2), "cluster deltas must re-key");

        assert!(serve_query(&Json::parse("{\"search\":\"sideways\"}").unwrap(), &HashMap::new())
            .is_err());
    }

    #[test]
    fn serve_query_accepts_and_guards_the_evo_mode() {
        use crate::config::Json;
        use crate::plan::SearchMode;

        let j = Json::parse(
            "{\"model\":\"tiny\",\"gpus\":4,\"search\":\"evo\",\
             \"generations\":5,\"population\":9,\"evo_seed\":3}",
        )
        .unwrap();
        let q = serve_query(&j, &HashMap::new()).unwrap();
        assert_eq!(q.search, SearchMode::Evo { generations: 5, population: 9, seed: 3 });

        // Defaults mirror the `stp plan` flag defaults.
        let j = Json::parse("{\"model\":\"tiny\",\"gpus\":4,\"search\":\"evo\"}").unwrap();
        let q = serve_query(&j, &HashMap::new()).unwrap();
        assert_eq!(q.search, SearchMode::Evo { generations: 12, population: 24, seed: 42 });

        // Degenerate budgets are one-line errors, not silent clamps.
        for bad in [
            "{\"search\":\"evo\",\"generations\":0}",
            "{\"search\":\"evo\",\"population\":0}",
            "{\"search\":\"beam\",\"beam_width\":0}",
        ] {
            assert!(
                serve_query(&Json::parse(bad).unwrap(), &HashMap::new()).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn plan_subcommand_rejects_bad_search_flags() {
        // Unknown modes and zero-valued budgets must error out (the
        // binary maps the Err to exit code 1), never fall back silently.
        for args in [
            vec!["plan", "--gpus", "4", "--model", "tiny", "--search", "sideways"],
            vec!["plan", "--gpus", "4", "--model", "tiny", "--search", "beam", "--beam-width", "0"],
            vec!["plan", "--gpus", "4", "--model", "tiny", "--search", "evo", "--population", "0"],
            vec!["plan", "--gpus", "4", "--model", "tiny", "--search", "evo", "--generations", "0"],
        ] {
            let owned: Vec<String> = args.iter().map(|s| s.to_string()).collect();
            let err = run_cli(owned).expect_err("bad search flags must error");
            assert!(
                err.to_string().contains("search mode") || err.to_string().contains("at least 1"),
                "unhelpful error: {err}"
            );
        }
    }

    #[test]
    fn validate_subcommand_all_green() {
        let code = run_cli(vec!["validate".into(), "--pp".into(), "2".into(), "--mb".into(), "6".into()])
            .unwrap();
        assert_eq!(code, 0);
    }
}
