//! The executable **plan artifact**: the planner → executor handoff.
//!
//! `stp plan --emit-plan FILE.json` serializes the winning candidate as a
//! versioned, strictly-validated JSON document carrying everything the
//! executor needs to replay the *same* schedule the simulator ranked —
//! the schedule kind, the (tp, pp, dp, vpp) shape, the microbatch count,
//! the group-assignment order, the offload parameters, the weighted
//! per-chunk layer split (the candidate's
//! [`StagePlan`](crate::cluster::StagePlan)) and the chunk compute
//! scales the scaled builders consumed. `stp train --plan FILE.json`
//! lowers it through [`crate::schedule::CompiledSchedule`] into the
//! engine, so sim and exec consume one schedule by construction
//! (DESIGN.md §10).

use std::collections::BTreeMap;

use crate::cluster::{ChunkContent, GroupOrder, StagePlan, Topology};
use crate::config::json::Json;
use crate::schedule::{
    build_schedule_scaled, stp, OffloadParams, Schedule, ScheduleKind, ShapeCosts,
};
use crate::sim::AcMode;
use crate::Result;

use super::evaluate::{EvalContext, Evaluation};

/// Schema tag of the artifact format this crate reads and writes.
pub const PLAN_SCHEMA: &str = "stp-plan-v1";

/// One executable plan — see the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanArtifact {
    /// Model the plan was searched for (informational).
    pub model: String,
    /// Pool the plan was searched on (informational).
    pub cluster: String,
    pub seq: usize,
    pub mb_size: usize,
    pub kind: ScheduleKind,
    pub tp: usize,
    pub pp: usize,
    /// DP replica count the planner chose; the executor spawns this many
    /// replicas, each walking the same per-replica schedule (`stp train
    /// --dp` can override it).
    pub dp: usize,
    pub vpp: usize,
    /// Microbatches per iteration per replica.
    pub n_mb: usize,
    pub order: GroupOrder,
    pub offload: OffloadParams,
    /// Activation-checkpointing mode the planner chose (`None` outside
    /// the evo search; the executor recomputes the checkpointed units).
    pub ac: AcMode,
    /// LM layers per chunk (the candidate's weighted split).
    pub stage_layers: Vec<usize>,
    /// ViT layers per chunk (MLLM plans; all zero for LLMs).
    pub stage_vit_layers: Vec<usize>,
    /// Relative per-chunk compute scales the schedule builders consumed.
    pub chunk_scales: Vec<f64>,
    /// Simulated whole-job throughput, samples/s (informational).
    pub throughput: f64,
}

impl PlanArtifact {
    /// Build the artifact for one simulated candidate (the winner, in
    /// [`super::plan`]'s case).
    pub fn for_evaluation(ctx: &EvalContext, e: &Evaluation) -> PlanArtifact {
        let c = &e.candidate;
        let cost = ctx.cost_model(c);
        PlanArtifact {
            model: ctx.model.name().to_string(),
            cluster: ctx.cluster.name.clone(),
            seq: ctx.seq,
            mb_size: ctx.mb_size,
            kind: c.kind,
            tp: c.tp,
            pp: c.pp,
            dp: c.dp,
            vpp: c.vpp(),
            n_mb: c.n_mb,
            order: c.order,
            offload: c.offload,
            ac: c.ac,
            stage_layers: cost.stage_plan.chunks.iter().map(|ch| ch.lm_layers).collect(),
            stage_vit_layers: cost.stage_plan.chunks.iter().map(|ch| ch.vit_layers).collect(),
            chunk_scales: cost.chunk_scales(),
            throughput: e.throughput,
        }
    }

    pub fn n_chunks(&self) -> usize {
        self.pp * self.vpp
    }

    pub fn total_layers(&self) -> usize {
        self.stage_layers.iter().sum()
    }

    pub fn total_vit_layers(&self) -> usize {
        self.stage_vit_layers.iter().sum()
    }

    /// Compact label ("tp2-pp2-dp1 stp m4").
    pub fn label(&self) -> String {
        format!("tp{}-pp{}-dp{} {} m{}", self.tp, self.pp, self.dp, self.kind.name(), self.n_mb)
    }

    /// The topology the executor runs. `dp` rides along for the replica
    /// count; the schedule builders only consume the (tp, pp, vpp) grid,
    /// so each replica runs the same per-replica schedule independently.
    pub fn topology(&self) -> Topology {
        Topology::new(self.tp, self.pp, self.dp.max(1)).with_vpp(self.vpp)
    }

    /// The chunk → content split the executor partitions parameters by.
    pub fn stage_plan(&self) -> StagePlan {
        let last = self.n_chunks() - 1;
        StagePlan {
            chunks: self
                .stage_layers
                .iter()
                .zip(&self.stage_vit_layers)
                .enumerate()
                .map(|(i, (&lm, &vit))| ChunkContent {
                    lm_layers: lm,
                    vit_layers: vit,
                    has_embed: i == 0,
                    has_head: i == last,
                })
                .collect(),
        }
    }

    /// Rebuild the candidate's schedule — the exact op lists the planner
    /// simulated (same kind, topology, n_mb, chunk scales and offload
    /// parameters ⇒ the builders are deterministic).
    pub fn build_schedule(&self) -> Schedule {
        let topo = self.topology();
        match self.kind {
            ScheduleKind::StpOffload => stp::build_stp_offload(
                &topo,
                self.n_mb,
                ShapeCosts::default(),
                self.chunk_scales.clone(),
                self.offload,
            ),
            kind => build_schedule_scaled(kind, &topo, self.n_mb, self.chunk_scales.clone()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("schema".into(), Json::Str(PLAN_SCHEMA.into()));
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("cluster".into(), Json::Str(self.cluster.clone()));
        o.insert("seq".into(), Json::Num(self.seq as f64));
        o.insert("mb_size".into(), Json::Num(self.mb_size as f64));
        o.insert("schedule".into(), Json::Str(self.kind.name().into()));
        o.insert("tp".into(), Json::Num(self.tp as f64));
        o.insert("pp".into(), Json::Num(self.pp as f64));
        o.insert("dp".into(), Json::Num(self.dp as f64));
        o.insert("vpp".into(), Json::Num(self.vpp as f64));
        o.insert("n_mb".into(), Json::Num(self.n_mb as f64));
        o.insert("order".into(), Json::Str(self.order.name().into()));
        let mut off = BTreeMap::new();
        off.insert("alpha_warmup".into(), Json::Num(self.offload.alpha_warmup as f64));
        off.insert("alpha_steady".into(), Json::Num(self.offload.alpha_steady as f64));
        off.insert("reload_lead".into(), Json::Num(self.offload.reload_lead as f64));
        o.insert("offload".into(), Json::Obj(off));
        o.insert("ac".into(), Json::Str(self.ac.name().into()));
        o.insert(
            "stage_layers".into(),
            Json::Arr(self.stage_layers.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        o.insert(
            "stage_vit_layers".into(),
            Json::Arr(self.stage_vit_layers.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        o.insert(
            "chunk_scales".into(),
            Json::Arr(self.chunk_scales.iter().map(|&s| Json::Num(s)).collect()),
        );
        o.insert("throughput".into(), Json::Num(self.throughput));
        Json::Obj(o)
    }

    /// Strict deserialization: unknown schema, missing fields, wrong
    /// types and inconsistent shapes are all hard errors — a plan that
    /// fails validation must never reach the executor half-parsed.
    pub fn from_json(v: &Json) -> Result<PlanArtifact> {
        let schema = v
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("plan artifact: missing 'schema'"))?;
        anyhow::ensure!(
            schema == PLAN_SCHEMA,
            "plan artifact: unsupported schema '{schema}' (this build reads '{PLAN_SCHEMA}')"
        );
        let req_str = |key: &str| -> Result<String> {
            v.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("plan artifact: missing string '{key}'"))
        };
        let req_usize = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("plan artifact: missing number '{key}'"))
        };
        let req_f64 = |of: &Json, key: &str| -> Result<f64> {
            of.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("plan artifact: missing number '{key}'"))
        };
        let usize_arr = |key: &str| -> Result<Vec<usize>> {
            let arr = v
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("plan artifact: missing array '{key}'"))?;
            arr.iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("plan artifact: non-number in '{key}'"))
                })
                .collect()
        };

        let kind: ScheduleKind = req_str("schedule")?
            .parse()
            .map_err(|e| anyhow::anyhow!("plan artifact: {e}"))?;
        let order = match req_str("order")?.as_str() {
            "declared" => GroupOrder::Declared,
            "fast-first" => GroupOrder::FastFirst,
            "interleaved" => GroupOrder::Interleaved,
            other => anyhow::bail!("plan artifact: unknown order '{other}'"),
        };
        // Optional for older documents (pre-evo plans never checkpoint);
        // present-but-unknown values are still hard errors.
        let ac = match v.get("ac").and_then(Json::as_str) {
            None => AcMode::None,
            Some("none") => AcMode::None,
            Some("mlp") => AcMode::Mlp,
            Some("attn+mlp") => AcMode::AttnMlp,
            Some("all") => AcMode::All,
            Some(other) => anyhow::bail!("plan artifact: unknown ac mode '{other}'"),
        };
        let off = v
            .get("offload")
            .ok_or_else(|| anyhow::anyhow!("plan artifact: missing 'offload'"))?;
        let offload = OffloadParams {
            alpha_warmup: req_f64(off, "alpha_warmup")? as f32,
            alpha_steady: req_f64(off, "alpha_steady")? as f32,
            reload_lead: off
                .get("reload_lead")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("plan artifact: missing number 'reload_lead'"))?,
        };
        let chunk_scales: Vec<f64> = {
            let arr = v
                .get("chunk_scales")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("plan artifact: missing array 'chunk_scales'"))?;
            arr.iter()
                .map(|x| {
                    x.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("plan artifact: non-number in 'chunk_scales'")
                    })
                })
                .collect::<Result<_>>()?
        };

        let a = PlanArtifact {
            model: req_str("model")?,
            cluster: req_str("cluster")?,
            seq: req_usize("seq")?,
            mb_size: req_usize("mb_size")?,
            kind,
            tp: req_usize("tp")?,
            pp: req_usize("pp")?,
            dp: req_usize("dp")?,
            vpp: req_usize("vpp")?,
            n_mb: req_usize("n_mb")?,
            order,
            offload,
            ac,
            stage_layers: usize_arr("stage_layers")?,
            stage_vit_layers: usize_arr("stage_vit_layers")?,
            chunk_scales,
            throughput: v.get("throughput").and_then(Json::as_f64).unwrap_or(0.0),
        };
        a.validate()?;
        Ok(a)
    }

    /// Shape consistency (shared by `from_json` and direct constructors).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.tp >= 1 && self.pp >= 1 && self.dp >= 1 && self.vpp >= 1 && self.n_mb >= 1,
            "plan artifact: tp/pp/dp/vpp/n_mb must be positive"
        );
        let chunks = self.n_chunks();
        anyhow::ensure!(
            self.stage_layers.len() == chunks,
            "plan artifact: {} stage_layers for {} chunks (pp·vpp)",
            self.stage_layers.len(),
            chunks
        );
        anyhow::ensure!(
            self.stage_vit_layers.len() == chunks,
            "plan artifact: {} stage_vit_layers for {} chunks",
            self.stage_vit_layers.len(),
            chunks
        );
        anyhow::ensure!(
            self.chunk_scales.len() == chunks,
            "plan artifact: {} chunk_scales for {} chunks",
            self.chunk_scales.len(),
            chunks
        );
        anyhow::ensure!(
            self.chunk_scales.iter().all(|&s| s.is_finite() && s > 0.0),
            "plan artifact: chunk_scales must be positive and finite"
        );
        anyhow::ensure!(
            self.stage_layers
                .iter()
                .zip(&self.stage_vit_layers)
                .all(|(&lm, &vit)| lm + vit >= 1),
            "plan artifact: every chunk needs at least one layer"
        );
        Ok(())
    }

    /// Write the artifact to `path` as pretty-enough JSON.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing plan artifact {path}: {e}"))
    }

    /// Load and strictly validate an artifact from `path`.
    pub fn load(path: &str) -> Result<PlanArtifact> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading plan artifact {path}: {e}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("plan artifact {path}: {e}"))?;
        Self::from_json(&v).map_err(|e| anyhow::anyhow!("plan artifact {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, HardwareProfile};
    use crate::model::ModelConfig;
    use crate::plan::{PlanModel, PlanQuery};
    use crate::schedule::assert_valid;

    fn tiny_artifact() -> PlanArtifact {
        let mut q = PlanQuery::new(
            PlanModel::Llm(ModelConfig::tiny_100m()),
            ClusterSpec::uniform(HardwareProfile::a800()),
            4,
        );
        q.seq = 1024;
        q.n_mb_options = vec![4];
        q.threads = 2;
        let r = crate::plan::plan(&q);
        r.best_artifact.expect("tiny model on 4 GPUs must produce a plan")
    }

    #[test]
    fn winning_plan_roundtrips_through_json() {
        let a = tiny_artifact();
        let text = a.to_json().to_string();
        let b = PlanArtifact::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.total_layers(), ModelConfig::tiny_100m().layers);
        assert_eq!(b.total_vit_layers(), 0);
    }

    #[test]
    fn artifact_schedule_is_valid_and_matches_shape() {
        let a = tiny_artifact();
        let s = a.build_schedule();
        assert_valid(&s);
        assert_eq!(s.n_mb, a.n_mb);
        assert_eq!(s.n_chunks(), a.n_chunks());
        assert_eq!(s.kind, a.kind);
        let sp = a.stage_plan();
        assert_eq!(sp.num_chunks(), a.n_chunks());
        assert!(sp.chunks[0].has_embed);
        assert!(sp.chunks[a.n_chunks() - 1].has_head);
    }

    #[test]
    fn strict_validation_rejects_bad_documents() {
        let a = tiny_artifact();
        // Unknown schema version.
        let mut txt = a.to_json().to_string().replace(PLAN_SCHEMA, "stp-plan-v999");
        assert!(PlanArtifact::from_json(&Json::parse(&txt).unwrap()).is_err());
        // Missing a required field.
        txt = a.to_json().to_string().replace("\"tp\"", "\"tp_gone\"");
        assert!(PlanArtifact::from_json(&Json::parse(&txt).unwrap()).is_err());
        // Inconsistent stage_layers length.
        let mut broken = a.clone();
        broken.stage_layers.push(1);
        assert!(PlanArtifact::from_json(&broken.to_json()).is_err());
        // Non-positive chunk scale.
        let mut broken = a;
        broken.chunk_scales[0] = 0.0;
        assert!(PlanArtifact::from_json(&broken.to_json()).is_err());
    }
}
