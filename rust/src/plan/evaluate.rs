//! Candidate evaluation: the closed-form throughput *estimate* used for
//! pruning, and the full discrete-event *simulation* used for ranking.
//!
//! The simulator models one DP replica; data parallelism enters here as a
//! throughput multiplier plus a per-iteration gradient all-reduce charge
//! (ring over the `dp` replicas of each shard, on the interconnect tier
//! the replica stride lands on).

use crate::cluster::{ClusterSpec, DeviceView, Topology};
use crate::schedule::{build_schedule_scaled, stp, theory, ScheduleKind, ShapeCosts};
use crate::sim::{CostModel, FleetSim, FoldedTopology, SimArena, SimMode, SimReport, Simulator};

use super::cache::CostMemo;
use super::space::{Candidate, PlanModel, StageMap};

/// Everything the planner needs to evaluate candidates for one query.
#[derive(Debug, Clone)]
pub struct EvalContext {
    pub model: PlanModel,
    pub cluster: ClusterSpec,
    /// Global memory-cap override, bytes (the per-device profile caps are
    /// enforced separately by the simulated per-device OOM check).
    pub mem_cap_bytes: usize,
    /// LM sequence length per sample.
    pub seq: usize,
    /// ViT patch tokens per sample (MLLM only; ignored for LLMs).
    pub vit_tokens: usize,
    /// Samples per microbatch.
    pub mb_size: usize,
    /// Replica replay strategy: symmetry-folded (default) or the full
    /// per-replica sweep. Bit-identical results either way (DESIGN.md
    /// §15) — `Unfolded` exists for the bench's baseline measurement.
    pub sim: SimMode,
}

impl EvalContext {
    /// The candidate's cost model, activation checkpointing applied. For
    /// mapped candidates (explicit stage→group placement) this is the
    /// class-0 model — callers that need every class use
    /// [`EvalContext::class_cost_model`] per class.
    pub fn cost_model(&self, c: &Candidate) -> CostModel {
        if c.map.is_some() {
            return self.class_cost_model(c, 0);
        }
        self.model
            .cost_model(
                &c.topo(),
                &self.cluster,
                c.order,
                c.placement(),
                self.seq,
                self.vit_tokens,
                self.mb_size,
            )
            .with_activation_checkpoint(c.ac)
    }

    /// Cost model for replica class `k` of a mapped candidate: the class
    /// topology carries that class's DP width (so per-class aggregate
    /// FLOPs are exact) and the view pins each PP rank onto the mapped
    /// node group.
    pub fn class_cost_model(&self, c: &Candidate, k: usize) -> CostModel {
        let map = c.map.as_deref().expect("class_cost_model: unmapped candidate");
        let topo = Topology::new(c.tp, c.pp, map.dp_widths[k]).with_vpp(c.vpp());
        let view = DeviceView::from_groups(map.rows[k].clone());
        self.model
            .cost_model_view(
                &topo,
                &self.cluster,
                view,
                c.placement(),
                self.seq,
                self.vit_tokens,
                self.mb_size,
            )
            .with_activation_checkpoint(c.ac)
    }
}

/// One simulated candidate, summarized for ranking and reporting.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub candidate: Candidate,
    /// Simulated single-replica iteration time (seconds).
    pub iteration_secs: f64,
    /// Data-parallel gradient all-reduce charge per iteration (seconds).
    pub dp_grad_secs: f64,
    /// Whole-job samples/second: `dp · n_mb · mb_size / (iter + dp_ar)`.
    pub throughput: f64,
    /// Whole-job model-FLOPs utilization.
    pub mfu: f64,
    pub tp_bubble_per_dev: f64,
    pub pp_bubble_per_dev: f64,
    /// Simulated peak memory (static + activations), bytes.
    pub peak_mem_bytes: usize,
    /// Simulated peak within the memory cap?
    pub feasible: bool,
    /// The replay deadlocked (malformed candidate schedule): always
    /// infeasible, ranked last, never aborts the search.
    pub sim_failed: bool,
}

/// Per-iteration DP gradient all-reduce time. Each device holds
/// `params/(tp·pp)` gradient elements (bf16) and rings them across its
/// `dp` replicas; replicas of one shard sit `tp·pp` ranks apart, so the
/// ring's node-crossing rule depends on the pool's packing (see the span
/// comment below). Stage rings run concurrently; on mixed pools the
/// slowest stage's ring (each stage's replicas live inside one node
/// group) sets the charge.
pub fn dp_gradient_secs(ctx: &EvalContext, c: &Candidate) -> f64 {
    if c.dp <= 1 {
        return 0.0;
    }
    if let Some(map) = c.map.as_deref() {
        return dp_gradient_secs_mapped(ctx, c, map);
    }
    let grad_bytes = ctx.model.total_params() as f64 * 2.0 / (c.tp * c.pp) as f64;
    let factor = 2.0 * (c.dp as f64 - 1.0) / c.dp as f64;
    let topo = c.topo();
    let view = ctx
        .cluster
        .device_view(&topo, c.order)
        .expect("dp_gradient_secs: candidate not hosted by the cluster");
    // Ring span: uniform pools keep the seed's linear Megatron packing
    // (replicas sit tp·pp ranks apart — the ring spans the whole job),
    // so the pre-refactor charge is reproduced exactly. Mixed pools pack
    // stage-major (the DeviceView co-locates one stage's tp·cp·dp GPUs),
    // so the ring leaves the node only when that block does.
    let uniform = ctx.cluster.is_uniform();
    (0..topo.pp)
        .map(|d| {
            let hw = ctx.cluster.profile_of(&view, d);
            let span =
                if uniform { c.tp * c.pp * c.dp } else { c.tp * topo.cp * c.dp };
            let cross_node = span > hw.gpus_per_node;
            let bw = if cross_node { hw.internode_gbps } else { hw.nvlink_gbps };
            factor * grad_bytes / (bw * hw.allreduce_efficiency * 1e9) + hw.collective_latency
        })
        .fold(0.0, f64::max)
}

/// Mapped-candidate DP gradient ring: the replicas of stage `d` live on
/// the node groups `{rows[k][d]}` across the replica classes. A stage
/// whose replicas share one group rings inside that group's fabric under
/// the usual packing rule; a stage straddling groups pays the slowest
/// path — the inter-group link (when capped) at the worst member's
/// efficiency and latency. Concurrent stage rings: the charge is the
/// slowest stage's, as on the unmapped path.
fn dp_gradient_secs_mapped(ctx: &EvalContext, c: &Candidate, map: &StageMap) -> f64 {
    let grad_bytes = ctx.model.total_params() as f64 * 2.0 / (c.tp * c.pp) as f64;
    let factor = 2.0 * (c.dp as f64 - 1.0) / c.dp as f64;
    let topo = c.topo();
    (0..c.pp)
        .map(|d| {
            let mut groups: Vec<usize> = map.rows.iter().map(|row| row[d]).collect();
            groups.sort_unstable();
            groups.dedup();
            if groups.len() == 1 {
                let hw = &ctx.cluster.groups[groups[0]].hw;
                let cross_node = c.tp * topo.cp * c.dp > hw.gpus_per_node;
                let bw = if cross_node { hw.internode_gbps } else { hw.nvlink_gbps };
                factor * grad_bytes / (bw * hw.allreduce_efficiency * 1e9) + hw.collective_latency
            } else {
                let mut bw = f64::INFINITY;
                let mut eff = f64::INFINITY;
                let mut lat = 0.0f64;
                for &g in &groups {
                    let hw = &ctx.cluster.groups[g].hw;
                    bw = bw.min(hw.internode_gbps);
                    eff = eff.min(hw.allreduce_efficiency);
                    lat = lat.max(hw.collective_latency);
                }
                if ctx.cluster.intergroup_gbps > 0.0 {
                    bw = bw.min(ctx.cluster.intergroup_gbps);
                }
                factor * grad_bytes / (bw * eff * 1e9) + lat
            }
        })
        .fold(0.0, f64::max)
}

/// Closed-form iteration-time estimate (Table 1 bubbles on top of the
/// ideal compute) — the pruning score. Not a strict bound, but it ranks
/// candidates the same way the simulator does to within the theory
/// formulas' accuracy.
pub fn estimated_iteration_secs(cost: &CostModel, c: &Candidate) -> f64 {
    let mut ti = cost.theory_inputs(c.n_mb);
    if c.vpp() == 1 {
        // Table-1 formulas are stated in half-device (vpp = 2) chunk
        // units; single-chunk cost models report full-device means.
        ti.t_f /= 2.0;
        ti.t_b /= 2.0;
        ti.t_w /= 2.0;
        ti.t_ar /= 2.0;
    }
    let row = theory(c.kind, &ti);
    ti.ideal_iteration(2) + row.pp_bubble + row.tp_bubble
}

/// Estimated whole-job throughput (samples/s) for pruning.
pub fn estimated_throughput(ctx: &EvalContext, cost: &CostModel, c: &Candidate) -> f64 {
    let total = estimated_iteration_secs(cost, c) + dp_gradient_secs(ctx, c);
    (c.dp * c.n_mb * ctx.mb_size) as f64 / total.max(1e-12)
}

/// Build this candidate's schedule (MLLM chunk imbalance steers the
/// scaled builders; the offload variant carries its own parameters).
pub fn build_candidate_schedule(
    cost: &CostModel,
    c: &Candidate,
) -> crate::schedule::Schedule {
    let topo = c.topo();
    let scales = cost.chunk_scales();
    match c.kind {
        ScheduleKind::StpOffload => {
            stp::build_stp_offload(&topo, c.n_mb, ShapeCosts::default(), scales, c.offload)
        }
        kind => build_schedule_scaled(kind, &topo, c.n_mb, scales),
    }
}

/// Simulate one candidate and return the full report (trace events
/// included — the auto-plan CLI reuses this for top-k Chrome traces).
pub fn simulate_candidate(ctx: &EvalContext, c: &Candidate) -> SimReport {
    let cost = ctx.cost_model(c);
    let s = build_candidate_schedule(&cost, c);
    Simulator::new(&cost).run(&s)
}

/// Full evaluation of one candidate: simulate, then fold in the DP terms.
/// Feasibility requires both the global cap override *and* every device's
/// own memory capacity (per-group `mem_gib` on mixed pools).
pub fn evaluate(ctx: &EvalContext, c: &Candidate) -> Evaluation {
    evaluate_in(ctx, c, &mut SimArena::default())
}

/// [`evaluate`] against a caller-owned simulator arena (the planner keeps
/// one per worker thread): the no-trace event-driven replay, so ranking a
/// candidate allocates nothing beyond its schedule. A deadlocked replay
/// (malformed candidate) comes back as an infeasible [`Evaluation`] with
/// `sim_failed` set instead of aborting the whole `plan` run.
pub fn evaluate_in(ctx: &EvalContext, c: &Candidate, arena: &mut SimArena) -> Evaluation {
    if c.map.is_some() {
        return evaluate_mapped(ctx, c, arena, None);
    }
    let cost = ctx.cost_model(c);
    evaluate_with_cost(ctx, c, &cost, arena)
}

/// [`evaluate_in`] against a prebuilt (memoized) cost model: candidates
/// whose (tp, pp, dp, vpp, order, placement) repeat share one
/// `CostModel` instead of rebuilding it per candidate. The memo is
/// read-only here, so parallel workers can share it.
pub fn evaluate_in_memo(
    ctx: &EvalContext,
    c: &Candidate,
    arena: &mut SimArena,
    costs: &CostMemo,
) -> Evaluation {
    if c.map.is_some() {
        return evaluate_mapped(ctx, c, arena, Some(costs));
    }
    match costs.get(c) {
        Some((cost, _)) => evaluate_with_cost(ctx, c, &cost, arena),
        None => evaluate_in(ctx, c, arena),
    }
}

/// The infeasible, ranked-last evaluation a deadlocked replay maps to.
fn sim_failed_evaluation(c: &Candidate) -> Evaluation {
    Evaluation {
        candidate: c.clone(),
        iteration_secs: f64::INFINITY,
        dp_grad_secs: 0.0,
        throughput: 0.0,
        mfu: 0.0,
        tp_bubble_per_dev: 0.0,
        pp_bubble_per_dev: 0.0,
        peak_mem_bytes: 0,
        feasible: false,
        sim_failed: true,
    }
}

/// Mapped-candidate evaluation: each replica class carries its own cost
/// model (own view + per-class DP width) and is symmetric within itself
/// by construction, so one representative replay per class is exact —
/// the mapped analogue of the symmetry fold. The job's iteration time is
/// the slowest class's (first class kept on exact ties), aggregate peak
/// FLOPs sum over the classes, peak memory is the worst device anywhere,
/// and any per-class OOM or deadlock marks the whole candidate.
fn evaluate_mapped(
    ctx: &EvalContext,
    c: &Candidate,
    arena: &mut SimArena,
    costs: Option<&CostMemo>,
) -> Evaluation {
    let map = c.map.as_deref().expect("evaluate_mapped: unmapped candidate");
    let memo_models = costs.and_then(|m| m.models_of(c));
    let mut iter = -1.0f64;
    let mut tp_bubble = 0.0f64;
    let mut pp_bubble = 0.0f64;
    let mut agg_flops = 0.0f64;
    let mut flops_per_sample = 0.0f64;
    let mut peak = 0usize;
    let mut oom = false;
    for k in 0..map.n_classes() {
        let built;
        let cost: &CostModel = match memo_models.as_deref() {
            Some(models) => &models[k],
            None => {
                built = ctx.class_cost_model(c, k);
                &built
            }
        };
        let s = build_candidate_schedule(cost, c);
        let fleet = FleetSim::new(cost).without_trace();
        let r = match fleet.run_unfolded(&s, 1, arena) {
            Ok(r) => r,
            Err(_) => return sim_failed_evaluation(c),
        };
        if r.iteration_secs > iter {
            iter = r.iteration_secs;
            tp_bubble = r.tp_bubble_per_device();
            pp_bubble = r.pp_bubble_per_device();
        }
        agg_flops += r.aggregate_peak_flops;
        peak = peak.max(r.peak_memory_bytes());
        oom |= r.is_oom();
        if k == 0 {
            flops_per_sample = r.model_flops_per_sample;
        }
    }
    let dp_grad_secs = dp_gradient_secs(ctx, c);
    let total = iter + dp_grad_secs;
    let samples = (c.dp * c.n_mb * ctx.mb_size) as f64;
    let throughput = samples / total.max(1e-12);
    let mfu = flops_per_sample * samples / (total * agg_flops).max(1e-12);
    Evaluation {
        candidate: c.clone(),
        iteration_secs: iter,
        dp_grad_secs,
        throughput,
        mfu,
        tp_bubble_per_dev: tp_bubble,
        pp_bubble_per_dev: pp_bubble,
        peak_mem_bytes: peak,
        feasible: peak <= ctx.mem_cap_bytes && !oom,
        sim_failed: false,
    }
}

fn evaluate_with_cost(
    ctx: &EvalContext,
    c: &Candidate,
    cost: &CostModel,
    arena: &mut SimArena,
) -> Evaluation {
    let s = build_candidate_schedule(cost, c);
    // Replica replay: the fold derives the replica equivalence classes
    // (always one on the planner's fault-free admissible candidates —
    // this is the path that keeps fleet-scale dp free), the unfolded
    // baseline replays every replica; both merge by slowest replica and
    // agree to the bit (DESIGN.md §15).
    let fleet = FleetSim::new(cost).without_trace();
    let replay = match ctx.sim {
        SimMode::Folded => {
            let fold = FoldedTopology::derive(&ctx.cluster, &cost.topo, c.order, None)
                .expect("evaluate: candidate admitted without a hostable view");
            fleet.run_folded(&s, &fold, arena)
        }
        SimMode::Unfolded => fleet.run_unfolded(&s, c.dp, arena),
    };
    let r = match replay {
        Ok(r) => r,
        Err(_) => return sim_failed_evaluation(c),
    };
    let dp_grad_secs = dp_gradient_secs(ctx, c);
    let total = r.iteration_secs + dp_grad_secs;
    let samples = (c.dp * c.n_mb * ctx.mb_size) as f64;
    let throughput = samples / total.max(1e-12);
    let useful = r.model_flops_per_sample * samples;
    let mfu = useful / (total * r.aggregate_peak_flops).max(1e-12);
    let peak_mem_bytes = r.peak_memory_bytes();
    Evaluation {
        candidate: c.clone(),
        iteration_secs: r.iteration_secs,
        dp_grad_secs,
        throughput,
        mfu,
        tp_bubble_per_dev: r.tp_bubble_per_device(),
        pp_bubble_per_dev: r.pp_bubble_per_device(),
        peak_mem_bytes,
        feasible: peak_mem_bytes <= ctx.mem_cap_bytes && !r.is_oom(),
        sim_failed: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GroupOrder, HardwareProfile};
    use crate::model::ModelConfig;
    use crate::schedule::OffloadParams;

    fn ctx() -> EvalContext {
        EvalContext {
            model: PlanModel::Llm(ModelConfig::qwen2_12b()),
            cluster: ClusterSpec::uniform(HardwareProfile::a800()),
            mem_cap_bytes: (80.0 * (1u64 << 30) as f64) as usize,
            seq: 3072,
            vit_tokens: 0,
            mb_size: 1,
            sim: SimMode::Folded,
        }
    }

    fn cand(tp: usize, pp: usize, dp: usize, kind: ScheduleKind, n_mb: usize) -> Candidate {
        Candidate {
            id: 0,
            tp,
            pp,
            dp,
            kind,
            n_mb,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            offload_variant: 0,
            ac: crate::sim::AcMode::None,
            map: None,
            vpp_gene: 0,
        }
    }

    #[test]
    fn evaluation_is_finite_and_positive() {
        let ctx = ctx();
        for kind in ScheduleKind::all() {
            let c = cand(4, 2, 2, kind, 16);
            let e = evaluate(&ctx, &c);
            assert!(e.throughput.is_finite() && e.throughput > 0.0, "{kind:?}");
            assert!(e.mfu > 0.0 && e.mfu < 1.0, "{kind:?} mfu {}", e.mfu);
            assert!(e.peak_mem_bytes > 0);
        }
    }

    #[test]
    fn dp_allreduce_vanishes_without_replicas() {
        let ctx = ctx();
        assert_eq!(dp_gradient_secs(&ctx, &cand(8, 2, 1, ScheduleKind::Stp, 32)), 0.0);
        assert!(dp_gradient_secs(&ctx, &cand(8, 1, 2, ScheduleKind::Stp, 32)) > 0.0);
    }

    #[test]
    fn dp_scales_samples_but_pays_allreduce() {
        let ctx = ctx();
        let single = evaluate(&ctx, &cand(8, 2, 1, ScheduleKind::Stp, 32));
        let double = evaluate(&ctx, &cand(8, 2, 2, ScheduleKind::Stp, 32));
        // Twice the replicas, same per-replica schedule: near-2x but
        // strictly less (the gradient ring costs something).
        assert!(double.throughput > 1.5 * single.throughput);
        assert!(double.throughput < 2.0 * single.throughput);
    }

    #[test]
    fn estimate_tracks_simulation_ordering() {
        // The pruning score must agree with the simulator on the headline
        // comparison (STP vs ZB-V at TP=8).
        let ctx = ctx();
        let stp_c = cand(8, 2, 1, ScheduleKind::Stp, 64);
        let zbv_c = cand(8, 2, 1, ScheduleKind::ZbV, 64);
        let cost = ctx.cost_model(&stp_c);
        let est_stp = estimated_throughput(&ctx, &cost, &stp_c);
        let est_zbv = estimated_throughput(&ctx, &cost, &zbv_c);
        assert!(est_stp > est_zbv);
        let sim_stp = evaluate(&ctx, &stp_c).throughput;
        let sim_zbv = evaluate(&ctx, &zbv_c).throughput;
        assert!(sim_stp > sim_zbv);
    }

    #[test]
    fn arena_evaluation_matches_fresh_evaluation() {
        let ctx = ctx();
        let mut arena = SimArena::default();
        for kind in ScheduleKind::all() {
            let c = cand(4, 2, 2, kind, 16);
            let fresh = evaluate(&ctx, &c);
            let reused = evaluate_in(&ctx, &c, &mut arena);
            assert_eq!(fresh.throughput.to_bits(), reused.throughput.to_bits(), "{kind:?}");
            assert_eq!(fresh.peak_mem_bytes, reused.peak_mem_bytes, "{kind:?}");
            assert_eq!(fresh.feasible, reused.feasible, "{kind:?}");
            assert!(!reused.sim_failed, "{kind:?}");
        }
    }

    #[test]
    fn unfolded_mode_is_bit_identical_to_folded() {
        // The fold's headline invariant at the evaluation layer: on a
        // symmetric pool the folded replay (one representative) and the
        // unfolded sweep (every replica) agree to the bit for all kinds.
        let fctx = ctx();
        let mut uctx = ctx();
        uctx.sim = SimMode::Unfolded;
        for kind in ScheduleKind::all() {
            let c = cand(2, 2, 4, kind, 16);
            let f = evaluate(&fctx, &c);
            let u = evaluate(&uctx, &c);
            assert_eq!(f.iteration_secs.to_bits(), u.iteration_secs.to_bits(), "{kind:?}");
            assert_eq!(f.throughput.to_bits(), u.throughput.to_bits(), "{kind:?}");
            assert_eq!(f.mfu.to_bits(), u.mfu.to_bits(), "{kind:?}");
            assert_eq!(f.peak_mem_bytes, u.peak_mem_bytes, "{kind:?}");
        }
    }

    #[test]
    fn single_chunk_kinds_get_matching_cost_models() {
        // OneF1B re-partitions into `pp` stages; the cost model must have
        // exactly that many chunks or the simulator would mis-cost them.
        let ctx = ctx();
        let c = cand(4, 4, 1, ScheduleKind::OneF1B, 8);
        let cost = ctx.cost_model(&c);
        assert_eq!(cost.n_chunks(), 4);
        let r = simulate_candidate(&ctx, &c);
        assert!(r.iteration_secs > 0.0);
    }
}
