//! Evolutionary search over the *full* co-optimization space
//! (DESIGN.md §16).
//!
//! The exhaustive and beam searches walk the enumerated candidate space:
//! (tp, pp, dp) factorizations × schedule kind × n_mb × group order ×
//! offload variant. The genome here spans strictly more — per-candidate
//! activation checkpointing ([`AcMode`]), virtual-pipeline overrides for
//! the vpp-generic families, and (on mixed pools) explicit stage→group
//! placements with per-class DP widths ([`StageMap`]) — axes whose cross
//! product would be hopeless to enumerate. Mutation and crossover move
//! through that space; fitness is the exact same arena-backed simulation
//! pipeline ([`evaluate_batch`]) the other modes use, so evo inherits
//! cost-model memoization, cross-query eval reuse and thread-count
//! determinism without any new machinery.
//!
//! Determinism argument: the only randomness is one explicitly-threaded
//! xorshift64* stream seeded by `--evo-seed`; populations are plain
//! `Vec`s mutated in a fixed order; every set/map is a BTree keyed by
//! the canonical [`Candidate::genome_key`]; fitness ties break on that
//! key; and each generation's simulations go through one
//! `evaluate_batch` call, which is already bit-deterministic at any
//! thread count. Same seed, same report — `--threads` only changes the
//! wall clock.
//!
//! Funnel accounting: every *novel* genome (never enumerated, never seen
//! before) increments `generated` and lands in exactly one bucket —
//! shape-rejected, memory-pruned, or simulated. Revisits of a seen
//! genome are free (the seen-set answers them); offspring that collide
//! with an enumerated-but-unsimulated candidate simply promote it into
//! the simulated set under its original id. Infeasible genomes become
//! ranked-last rejects, never aborts.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::cluster::GroupOrder;
use crate::schedule::{OffloadParams, ScheduleKind};
use crate::sim::AcMode;

use super::cache::{CostMemo, EvalMemo};
use super::constraints::{admissible, memory_feasible, Reject};
use super::evaluate::{EvalContext, Evaluation};
use super::search::{evaluate_batch, PlanQuery};
use super::space::{divisors, Candidate, StageMap};

/// Virtual-pipeline override options for the vpp-generic families
/// (0 = the family default of 2 chunks/device).
const VPP_OPTIONS: [usize; 4] = [0, 1, 2, 4];

/// What the evolutionary search hands back to the planner funnel.
pub struct EvoOutcome {
    /// Every simulated evaluation (seeds, promoted enumerated
    /// candidates, and novel genomes) — the caller ranks them.
    pub evals: Vec<Evaluation>,
    /// Novel genomes generated beyond the enumerated space (each is in
    /// exactly one funnel bucket: shape-rejected, memory-pruned, or
    /// simulated).
    pub generated: usize,
    /// Shape-rejection tallies over the novel genomes.
    pub shape_rejects: Vec<(Reject, usize)>,
    /// Novel genomes dropped by the closed-form memory pre-filter.
    pub pruned_memory: usize,
}

/// xorshift64* — tiny, seedable, and good enough to drive a GA. The
/// `| 1` guarantees a non-zero state for every seed (xorshift fixes 0).
struct XorShift64(u64);

impl XorShift64 {
    fn new(seed: u64) -> XorShift64 {
        XorShift64(seed | 1)
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform-ish draw in `0..n` (`n > 0`).
    fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 0
    }
}

/// The gene pools mutation draws from (fixed per search).
struct Genes<'a> {
    /// Every (tp, pp, dp) with product = the GPU budget, enumeration
    /// order.
    factorizations: Vec<(usize, usize, usize)>,
    kinds: &'a [ScheduleKind],
    n_mbs: &'a [usize],
    orders: Vec<GroupOrder>,
    offload_variants: &'a [OffloadParams],
    n_groups: usize,
    uniform: bool,
    /// Reshape keeps each genome's global batch (dp·n_mb·mb) fixed, but
    /// bounds the resulting per-replica n_mb so a dp collapse cannot
    /// explode the replay cost.
    max_n_mb: usize,
}

/// Canonicalize a genome after mutation/crossover so every gene is
/// meaningful for its schedule kind and pool — the "repair" step that
/// keeps the operators closed over the valid space (full validity is
/// still [`admissible`]'s call).
fn repair(c: &mut Candidate, g: &Genes) {
    if !matches!(c.kind, ScheduleKind::GPipe | ScheduleKind::OneF1BInterleaved) {
        c.vpp_gene = 0;
    }
    if c.kind != ScheduleKind::StpOffload {
        c.offload = OffloadParams::default();
        c.offload_variant = 0;
    } else if c.offload_variant >= g.offload_variants.len() {
        c.offload_variant = 0;
        c.offload = g.offload_variants[0];
    }
    if g.uniform {
        c.order = GroupOrder::Declared;
        c.map = None;
    }
    if let Some(map) = c.map.as_deref() {
        // A map inherited across a reshape no longer matches (pp, dp):
        // drop it rather than carry a structurally-invalid gene.
        if map.dp_widths.iter().sum::<usize>() != c.dp
            || map.rows.iter().any(|r| r.len() != c.pp)
        {
            c.map = None;
        }
    }
}

/// A fresh random stage→group map for the candidate's (pp, dp): one or
/// two replica classes, each pinned either wholly onto one group or
/// round-robin across the groups.
fn random_map(c: &Candidate, g: &Genes, rng: &mut XorShift64) -> StageMap {
    let n_classes = if c.dp >= 2 && rng.coin() { 2 } else { 1 };
    let dp_widths = if n_classes == 2 {
        let w0 = 1 + rng.below(c.dp - 1);
        vec![w0, c.dp - w0]
    } else {
        vec![c.dp]
    };
    let rows = (0..n_classes)
        .map(|_| {
            if rng.coin() {
                vec![rng.below(g.n_groups); c.pp]
            } else {
                let offset = rng.below(g.n_groups);
                (0..c.pp).map(|d| (d + offset) % g.n_groups).collect()
            }
        })
        .collect();
    StageMap { rows, dp_widths }
}

/// One mutation: pick an applicable operator, apply it, repair.
fn mutate(parent: &Candidate, g: &Genes, rng: &mut XorShift64) -> Candidate {
    let mut c = parent.clone();
    // Operator menu, rebuilt per call because applicability depends on
    // the parent (fixed order keeps the RNG stream deterministic).
    let mut ops: Vec<u8> = Vec::with_capacity(8);
    if g.kinds.len() > 1 {
        ops.push(0); // schedule kind
    }
    ops.push(1); // reshape (tp, pp, dp) under the fixed global batch
    if g.n_mbs.len() > 1 {
        ops.push(2); // microbatch count (changes the global batch)
    }
    if g.orders.len() > 1 {
        ops.push(3); // group order
    }
    if c.kind == ScheduleKind::StpOffload && g.offload_variants.len() > 1 {
        ops.push(4); // offload variant
    }
    ops.push(5); // activation checkpointing
    if matches!(c.kind, ScheduleKind::GPipe | ScheduleKind::OneF1BInterleaved) {
        ops.push(6); // vpp override
    }
    if !g.uniform && g.n_groups >= 2 {
        ops.push(7); // stage→group map
        if c.map.is_some() {
            ops.push(8); // drop the map
        }
    }
    match ops[rng.below(ops.len())] {
        0 => {
            let others: Vec<ScheduleKind> =
                g.kinds.iter().copied().filter(|&k| k != c.kind).collect();
            c.kind = others[rng.below(others.len())];
        }
        1 => {
            // Reshape preserving this genome's global batch: dp' must
            // divide dp·n_mb, and the implied n_mb' stays bounded.
            let batch = c.dp * c.n_mb;
            let opts: Vec<(usize, usize, usize)> = g
                .factorizations
                .iter()
                .copied()
                .filter(|&(tp, pp, dp)| {
                    (tp, pp, dp) != (c.tp, c.pp, c.dp)
                        && batch % dp == 0
                        && batch / dp <= g.max_n_mb
                })
                .collect();
            if !opts.is_empty() {
                let (tp, pp, dp) = opts[rng.below(opts.len())];
                c.tp = tp;
                c.pp = pp;
                c.dp = dp;
                c.n_mb = batch / dp;
            }
        }
        2 => {
            let others: Vec<usize> =
                g.n_mbs.iter().copied().filter(|&m| m != c.n_mb).collect();
            c.n_mb = others[rng.below(others.len())];
        }
        3 => {
            let others: Vec<GroupOrder> =
                g.orders.iter().copied().filter(|&o| o != c.order).collect();
            c.order = others[rng.below(others.len())];
        }
        4 => {
            let others: Vec<usize> =
                (0..g.offload_variants.len()).filter(|&v| v != c.offload_variant).collect();
            c.offload_variant = others[rng.below(others.len())];
            c.offload = g.offload_variants[c.offload_variant];
        }
        5 => {
            let others: Vec<AcMode> =
                AcMode::all().into_iter().filter(|&a| a != c.ac).collect();
            c.ac = others[rng.below(others.len())];
        }
        6 => {
            let others: Vec<usize> =
                VPP_OPTIONS.into_iter().filter(|&v| v != c.vpp_gene).collect();
            c.vpp_gene = others[rng.below(others.len())];
        }
        7 => {
            c.map = Some(Arc::new(random_map(&c, g, rng)));
        }
        _ => {
            c.map = None;
        }
    }
    repair(&mut c, g);
    c
}

/// Uniform crossover: the (tp, pp, dp, n_mb) block travels *jointly*
/// from one parent (it encodes a consistent factorization and global
/// batch); every other gene flips a coin.
fn crossover(a: &Candidate, b: &Candidate, g: &Genes, rng: &mut XorShift64) -> Candidate {
    let shape = if rng.coin() { a } else { b };
    let mut c = shape.clone();
    c.kind = if rng.coin() { a.kind } else { b.kind };
    c.order = if rng.coin() { a.order } else { b.order };
    let off = if rng.coin() { a } else { b };
    c.offload = off.offload;
    c.offload_variant = off.offload_variant;
    c.ac = if rng.coin() { a.ac } else { b.ac };
    c.vpp_gene = if rng.coin() { a.vpp_gene } else { b.vpp_gene };
    // The map gene only makes sense with the shape it was built for;
    // inherit from either parent and let repair drop mismatches.
    c.map = if rng.coin() { a.map.clone() } else { b.map.clone() };
    repair(&mut c, g);
    c
}

/// Fitness of a seen genome: simulated candidates rank by (feasible,
/// throughput); rejected genomes sit strictly below every simulated one
/// (throughput is never negative).
fn fitness(
    key: &str,
    evaluated: &BTreeMap<String, Evaluation>,
    rejected: &BTreeSet<String>,
) -> (bool, f64) {
    match evaluated.get(key) {
        Some(e) => (e.feasible, e.throughput),
        None => {
            debug_assert!(rejected.contains(key), "fitness of unseen genome");
            (false, -1.0)
        }
    }
}

/// `a` strictly fitter than `b` (key breaks exact ties, so tournament
/// outcomes are deterministic).
fn fitter(fa: (bool, f64), ka: &str, fb: (bool, f64), kb: &str) -> bool {
    match fa.0.cmp(&fb.0) {
        std::cmp::Ordering::Greater => true,
        std::cmp::Ordering::Less => false,
        std::cmp::Ordering::Equal => match fa.1.partial_cmp(&fb.1) {
            Some(std::cmp::Ordering::Greater) => true,
            Some(std::cmp::Ordering::Less) => false,
            _ => ka < kb,
        },
    }
}

/// Size-3 tournament over the population.
fn tournament<'a>(
    pop: &'a [String],
    rng: &mut XorShift64,
    evaluated: &BTreeMap<String, Evaluation>,
    rejected: &BTreeSet<String>,
) -> &'a String {
    let mut best = &pop[rng.below(pop.len())];
    for _ in 0..2 {
        let challenger = &pop[rng.below(pop.len())];
        if fitter(
            fitness(challenger, evaluated, rejected),
            challenger,
            fitness(best, evaluated, rejected),
            best,
        ) {
            best = challenger;
        }
    }
    best
}

/// Run the evolutionary search. `scored` is stage 2+3's output (the
/// memory-feasible, theory-estimated slice of the enumerated space, in
/// id order); `next_id` is the first free candidate id for novel
/// genomes; the rest mirrors [`evaluate_batch`].
#[allow(clippy::too_many_arguments)]
pub(super) fn evolve(
    ctx: &EvalContext,
    q: &PlanQuery,
    scored: &[(Candidate, f64)],
    next_id: usize,
    generations: usize,
    population: usize,
    seed: u64,
    threads: usize,
    costs: &mut CostMemo,
    mut memo: Option<&mut EvalMemo>,
) -> EvoOutcome {
    let mut shape_rejects: Vec<(Reject, usize)> =
        Reject::SHAPE_KINDS.iter().map(|&r| (r, 0)).collect();
    if scored.is_empty() {
        return EvoOutcome { evals: Vec::new(), generated: 0, shape_rejects, pruned_memory: 0 };
    }
    let population = population.max(2);
    let mut rng = XorShift64::new(seed);
    let genes = Genes {
        factorizations: {
            let mut f = Vec::new();
            for tp in divisors(q.gpus) {
                for pp in divisors(q.gpus / tp) {
                    f.push((tp, pp, q.gpus / (tp * pp)));
                }
            }
            f
        },
        kinds: &q.kinds,
        n_mbs: &q.n_mb_options,
        orders: q.cluster.group_orders(),
        offload_variants: &q.offload_variants,
        n_groups: q.cluster.groups.len(),
        uniform: q.cluster.is_uniform(),
        max_n_mb: 2 * q.n_mb_options.iter().copied().max().unwrap_or(1),
    };

    // Seen-set state. `evaluated` holds every simulated genome (outcome);
    // `rejected` the infeasible ones; `scored_index` the enumerated
    // candidates evo may still promote into simulation; `genomes` the
    // concrete candidate behind each population key.
    let mut evaluated: BTreeMap<String, Evaluation> = BTreeMap::new();
    let mut rejected: BTreeSet<String> = BTreeSet::new();
    let mut genomes: BTreeMap<String, Candidate> = BTreeMap::new();
    let mut scored_index: BTreeMap<String, usize> =
        scored.iter().enumerate().map(|(i, (c, _))| (c.genome_key(), i)).collect();
    let mut generated = 0usize;
    let mut pruned_memory = 0usize;
    let mut next_id = next_id;

    // Seed generation: the top-`population` theory estimates, plus the
    // best estimate of every uncovered schedule kind and microbatch
    // option — no family or batch regime is written off unsampled.
    let mut by_est: Vec<usize> = (0..scored.len()).collect();
    by_est.sort_by(|&a, &b| {
        scored[b]
            .1
            .partial_cmp(&scored[a].1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(scored[a].0.id.cmp(&scored[b].0.id))
    });
    let mut seed_idxs: Vec<usize> = by_est.iter().copied().take(population).collect();
    let mut kinds_seen: BTreeSet<u8> =
        seed_idxs.iter().map(|&i| scored[i].0.kind as u8).collect();
    for &i in &by_est {
        if kinds_seen.insert(scored[i].0.kind as u8) {
            seed_idxs.push(i);
        }
    }
    let mut mbs_seen: BTreeSet<usize> = seed_idxs.iter().map(|&i| scored[i].0.n_mb).collect();
    for &i in &by_est {
        if mbs_seen.insert(scored[i].0.n_mb) {
            seed_idxs.push(i);
        }
    }
    seed_idxs.sort_unstable();
    seed_idxs.dedup();

    let seeds: Vec<Candidate> = seed_idxs.iter().map(|&i| scored[i].0.clone()).collect();
    for e in evaluate_batch(ctx, &seeds, threads, costs, memo.as_deref_mut()) {
        evaluated.insert(e.candidate.genome_key(), e);
    }
    for c in seeds {
        let key = c.genome_key();
        scored_index.remove(&key);
        genomes.insert(key, c);
    }
    let mut pop: Vec<String> = evaluated.keys().cloned().collect();
    let truncate = |pop: &mut Vec<String>,
                    evaluated: &BTreeMap<String, Evaluation>,
                    rejected: &BTreeSet<String>| {
        pop.sort();
        pop.dedup();
        pop.sort_by(|a, b| {
            let (fa, fb) = (fitness(a, evaluated, rejected), fitness(b, evaluated, rejected));
            fb.0.cmp(&fa.0)
                .then(fb.1.partial_cmp(&fa.1).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.cmp(b))
        });
        pop.truncate(population);
    };
    truncate(&mut pop, &evaluated, &rejected);

    for _gen in 0..generations {
        let mut offspring_keys: Vec<String> = Vec::with_capacity(population);
        let mut to_eval: Vec<Candidate> = Vec::new();
        let mut pending: BTreeSet<String> = BTreeSet::new();
        for _ in 0..population {
            // ~40% crossover, else mutation; parents by 3-way tournament.
            let child = if pop.len() >= 2 && rng.below(5) < 2 {
                let a = tournament(&pop, &mut rng, &evaluated, &rejected).clone();
                let b = tournament(&pop, &mut rng, &evaluated, &rejected).clone();
                crossover(&genomes[&a], &genomes[&b], &genes, &mut rng)
            } else {
                let p = tournament(&pop, &mut rng, &evaluated, &rejected).clone();
                mutate(&genomes[&p], &genes, &mut rng)
            };
            let key = child.genome_key();
            offspring_keys.push(key.clone());
            genomes.entry(key.clone()).or_insert_with(|| child.clone());
            if evaluated.contains_key(&key) || rejected.contains(&key) || pending.contains(&key)
            {
                continue; // seen genome: revisit is free
            }
            if let Some(i) = scored_index.remove(&key) {
                // Enumerated and memory-feasible but never simulated:
                // promote it under its original id (not a novel genome).
                to_eval.push(scored[i].0.clone());
                pending.insert(key);
                continue;
            }
            generated += 1;
            match admissible(&q.model, &q.cluster, &child) {
                Err(r) => {
                    if let Some(t) = shape_rejects.iter_mut().find(|(k, _)| *k == r) {
                        t.1 += 1;
                    }
                    rejected.insert(key);
                }
                Ok(()) => {
                    costs.get_or_build(ctx, &child);
                    let models = costs.models_of(&child).expect("shape just built");
                    let fits = models.iter().all(|m| {
                        memory_feasible(m, child.kind, child.n_mb, ctx.mem_cap_bytes)
                    });
                    if fits {
                        let mut child = child;
                        child.id = next_id;
                        next_id += 1;
                        genomes.insert(key.clone(), child.clone());
                        to_eval.push(child);
                        pending.insert(key);
                    } else {
                        pruned_memory += 1;
                        rejected.insert(key);
                    }
                }
            }
        }
        if !to_eval.is_empty() {
            to_eval.sort_by_key(|c| c.id);
            for e in evaluate_batch(ctx, &to_eval, threads, costs, memo.as_deref_mut()) {
                evaluated.insert(e.candidate.genome_key(), e);
            }
        }
        // Elitist survivor selection over parents ∪ offspring.
        pop.extend(offspring_keys);
        truncate(&mut pop, &evaluated, &rejected);
    }

    EvoOutcome { evals: evaluated.into_values().collect(), generated, shape_rejects, pruned_memory }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, HardwareProfile};
    use crate::model::ModelConfig;
    use crate::plan::space::PlanModel;

    #[test]
    fn xorshift_streams_are_seed_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        let mut c = XorShift64::new(43);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        assert!((0..16).any(|_| c.next_u64() != b.next_u64()));
        // Seed 0 must not collapse to the all-zero fixed point.
        let mut z = XorShift64::new(0);
        assert_ne!(z.next_u64(), 0);
    }

    fn genes(q: &PlanQuery) -> Genes<'_> {
        let mut f = Vec::new();
        for tp in divisors(q.gpus) {
            for pp in divisors(q.gpus / tp) {
                f.push((tp, pp, q.gpus / (tp * pp)));
            }
        }
        Genes {
            factorizations: f,
            kinds: &q.kinds,
            n_mbs: &q.n_mb_options,
            orders: q.cluster.group_orders(),
            offload_variants: &q.offload_variants,
            n_groups: q.cluster.groups.len(),
            uniform: q.cluster.is_uniform(),
            max_n_mb: 2 * q.n_mb_options.iter().copied().max().unwrap_or(1),
        }
    }

    #[test]
    fn mutation_preserves_the_budget_and_repairs_genes() {
        let q = PlanQuery::new(
            PlanModel::Llm(ModelConfig::qwen2_12b()),
            ClusterSpec::mixed_a800_h20(),
            16,
        );
        let g = genes(&q);
        let parent = Candidate {
            id: 0,
            tp: 2,
            pp: 4,
            dp: 2,
            kind: ScheduleKind::Stp,
            n_mb: 16,
            order: GroupOrder::FastFirst,
            offload: OffloadParams::default(),
            offload_variant: 0,
            ac: AcMode::None,
            map: None,
            vpp_gene: 0,
        };
        let mut rng = XorShift64::new(7);
        for _ in 0..200 {
            let c = mutate(&parent, &g, &mut rng);
            assert_eq!(c.tp * c.pp * c.dp, 16, "{}", c.label());
            if !matches!(c.kind, ScheduleKind::GPipe | ScheduleKind::OneF1BInterleaved) {
                assert_eq!(c.vpp_gene, 0, "{}", c.label());
            }
            if c.kind != ScheduleKind::StpOffload {
                assert_eq!(c.offload_variant, 0, "{}", c.label());
            }
            if let Some(map) = c.map.as_deref() {
                assert_eq!(map.dp_widths.iter().sum::<usize>(), c.dp);
                assert!(map.rows.iter().all(|r| r.len() == c.pp));
            }
        }
    }

    #[test]
    fn crossover_inherits_a_consistent_shape_block() {
        let q = PlanQuery::new(
            PlanModel::Llm(ModelConfig::qwen2_12b()),
            ClusterSpec::uniform(HardwareProfile::a800()),
            8,
        );
        let g = genes(&q);
        let mk = |tp: usize, pp: usize, dp: usize, n_mb: usize, kind: ScheduleKind| Candidate {
            id: 0,
            tp,
            pp,
            dp,
            kind,
            n_mb,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            offload_variant: 0,
            ac: AcMode::None,
            map: None,
            vpp_gene: 0,
        };
        let a = mk(8, 1, 1, 16, ScheduleKind::Stp);
        let b = mk(2, 2, 2, 32, ScheduleKind::ZbV);
        let mut rng = XorShift64::new(3);
        for _ in 0..100 {
            let c = crossover(&a, &b, &g, &mut rng);
            let shape = (c.tp, c.pp, c.dp, c.n_mb);
            assert!(
                shape == (8, 1, 1, 16) || shape == (2, 2, 2, 32),
                "shape block must come jointly from one parent, got {shape:?}"
            );
        }
    }

    #[test]
    fn fitter_breaks_ties_on_genome_key() {
        assert!(fitter((true, 1.0), "a", (false, 9.0), "b"));
        assert!(fitter((true, 2.0), "b", (true, 1.0), "a"));
        assert!(fitter((true, 1.0), "a", (true, 1.0), "b"));
        assert!(!fitter((true, 1.0), "b", (true, 1.0), "a"));
    }
}
