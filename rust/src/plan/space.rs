//! Candidate space of the auto-planner: everything a parallel plan can
//! vary — the (TP, PP, DP) factorization of the GPU budget, the schedule
//! kind, the microbatch count, the device→group assignment order on
//! heterogeneous pools, and (for the offload variant) the
//! [`OffloadParams`]. Enumeration is exhaustive and deterministic (nested
//! loops in a fixed order assign stable candidate ids); *pruning* is the
//! job of [`super::constraints`] and [`super::search`].

use std::sync::Arc;

use crate::cluster::{partition_mllm, ClusterSpec, DeviceView, GroupOrder, Topology};
use crate::model::{MllmConfig, ModelConfig};
use crate::schedule::{OffloadParams, Placement, ScheduleKind};
use crate::sim::{AcMode, CostModel};

/// The workload the planner optimizes for: a dense LLM (uniform layer
/// split, paper §5.1) or an MLLM (ViT on the first virtual stage —
/// the chunk-imbalance case that exercises `build_schedule_scaled`).
#[derive(Debug, Clone)]
pub enum PlanModel {
    Llm(ModelConfig),
    Mllm(MllmConfig),
}

impl PlanModel {
    pub fn name(&self) -> &str {
        match self {
            PlanModel::Llm(m) => &m.name,
            PlanModel::Mllm(m) => &m.name,
        }
    }

    /// The language-model config (TP divisibility is decided by it).
    pub fn lm(&self) -> &ModelConfig {
        match self {
            PlanModel::Llm(m) => m,
            PlanModel::Mllm(m) => &m.lm,
        }
    }

    pub fn total_params(&self) -> usize {
        match self {
            PlanModel::Llm(m) => m.total_params(),
            PlanModel::Mllm(m) => m.total_params(),
        }
    }

    /// Minimum virtual-stage count this model can be split into.
    pub fn min_chunks(&self) -> usize {
        match self {
            PlanModel::Llm(_) => 1,
            // ViT chunk + at least one LM chunk.
            PlanModel::Mllm(_) => 2,
        }
    }

    /// Maximum virtual-stage count (one layer per chunk floor).
    pub fn max_chunks(&self) -> usize {
        match self {
            PlanModel::Llm(m) => m.layers,
            PlanModel::Mllm(m) => m.lm.layers + 1,
        }
    }

    /// Analytic cost model for one candidate topology under a pool,
    /// group-assignment order and chunk placement.
    #[allow(clippy::too_many_arguments)]
    pub fn cost_model(
        &self,
        topo: &Topology,
        cluster: &ClusterSpec,
        order: GroupOrder,
        placement: Placement,
        seq: usize,
        vit_tokens: usize,
        mb_size: usize,
    ) -> CostModel {
        match self {
            PlanModel::Llm(m) => {
                CostModel::analytic_for(m, topo, cluster, order, placement, seq, mb_size)
            }
            PlanModel::Mllm(m) => {
                let plan = partition_mllm(m, topo.chunks());
                CostModel::analytic_mllm_for(
                    &m.lm, &m.vit, &plan, topo, cluster, order, placement, seq, vit_tokens,
                    mb_size,
                )
            }
        }
    }

    /// [`PlanModel::cost_model`] with an explicit, already-resolved
    /// [`DeviceView`] — used for the per-class models of mapped
    /// candidates (see [`super::evo`]).
    #[allow(clippy::too_many_arguments)]
    pub fn cost_model_view(
        &self,
        topo: &Topology,
        cluster: &ClusterSpec,
        view: DeviceView,
        placement: Placement,
        seq: usize,
        vit_tokens: usize,
        mb_size: usize,
    ) -> CostModel {
        match self {
            PlanModel::Llm(m) => {
                CostModel::analytic_for_view(m, topo, cluster, view, placement, seq, mb_size)
            }
            PlanModel::Mllm(m) => {
                let plan = partition_mllm(m, topo.chunks());
                CostModel::analytic_mllm_for_view(
                    &m.lm, &m.vit, &plan, topo, cluster, view, placement, seq, vit_tokens,
                    mb_size,
                )
            }
        }
    }
}

/// Explicit stage→group placement with per-class DP widths on mixed
/// pools — the evo planner's placement gene (DESIGN.md §16). The `dp`
/// replicas are partitioned into `rows.len()` classes: class `k` holds
/// `dp_widths[k]` replicas, and each of those replicas pins its PP rank
/// `d` onto node group `rows[k][d]`. `None` on a [`Candidate`] means the
/// ordinary [`ClusterSpec::device_view`] resolution applies.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct StageMap {
    /// Per class: node-group index of each PP rank (`rows[k].len() == pp`).
    pub rows: Vec<Vec<usize>>,
    /// Replicas per class (sums to the candidate's `dp`).
    pub dp_widths: Vec<usize>,
}

impl StageMap {
    pub fn n_classes(&self) -> usize {
        self.rows.len()
    }

    /// Compact deterministic label fragment: "0.0.1.1x2|0.1.0.1x1"
    /// (per class: the group of each PP rank, then `x` replica width).
    pub fn label(&self) -> String {
        self.rows
            .iter()
            .zip(&self.dp_widths)
            .map(|(row, w)| {
                let gs =
                    row.iter().map(|g| g.to_string()).collect::<Vec<_>>().join(".");
                format!("{gs}x{w}")
            })
            .collect::<Vec<_>>()
            .join("|")
    }
}

/// One point of the search space.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Stable id in enumeration order (ties in ranking break on it).
    pub id: usize,
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
    pub kind: ScheduleKind,
    /// Microbatches per iteration *per DP replica*.
    pub n_mb: usize,
    /// Device→group assignment order (always `Declared` on uniform pools).
    pub order: GroupOrder,
    /// Offload parameters (meaningful only for `StpOffload`).
    pub offload: OffloadParams,
    /// Which offload variant this is (0 for non-offload kinds).
    pub offload_variant: usize,
    /// Activation-checkpointing mode (searched by the evo planner;
    /// `AcMode::None` everywhere else keeps the historical behavior).
    pub ac: AcMode,
    /// Explicit stage→group placement + per-class DP widths on mixed
    /// pools (`None` = ordinary `device_view` resolution).
    pub map: Option<Arc<StageMap>>,
    /// Virtual-stage override for the vpp-generic schedule families
    /// (GPipe, interleaved 1F1B). 0 = the family default.
    pub vpp_gene: usize,
}

impl Candidate {
    /// Virtual stages per device for this candidate's schedule kind: the
    /// classic single-chunk schedules (1F1B, ZB-H1) re-partition the model
    /// into `pp` stages; everything else uses the paper's 2 chunks/device.
    pub fn vpp(&self) -> usize {
        match self.kind {
            ScheduleKind::OneF1B | ScheduleKind::ZbH1 => 1,
            ScheduleKind::GPipe | ScheduleKind::OneF1BInterleaved if self.vpp_gene > 0 => {
                self.vpp_gene
            }
            _ => 2,
        }
    }

    /// The topology this candidate builds schedules and cost models with.
    /// Keeping `vpp` consistent between the two is what makes per-chunk
    /// costs line up with the emitted chunk ids.
    pub fn topo(&self) -> Topology {
        Topology::new(self.tp, self.pp, self.dp).with_vpp(self.vpp())
    }

    /// Chunk→device placement of this candidate's schedule family (the
    /// per-device cost attribution on mixed pools depends on it).
    pub fn placement(&self) -> Placement {
        self.kind.placement()
    }

    /// Compact human-readable label ("tp8-pp2-dp1 stp m64"); mixed-pool
    /// candidates append their group order ("[interleaved]").
    pub fn label(&self) -> String {
        let mut s = format!(
            "tp{}-pp{}-dp{} {} m{}",
            self.tp,
            self.pp,
            self.dp,
            self.kind.name(),
            self.n_mb
        );
        if self.kind == ScheduleKind::StpOffload && self.offload_variant > 0 {
            s.push_str(&format!(" o{}", self.offload_variant));
        }
        if self.order != GroupOrder::Declared {
            s.push_str(&format!(" [{}]", self.order.name()));
        }
        if self.ac != AcMode::None {
            s.push_str(&format!(" ac:{}", self.ac.name()));
        }
        if self.vpp_gene > 0
            && matches!(self.kind, ScheduleKind::GPipe | ScheduleKind::OneF1BInterleaved)
        {
            s.push_str(&format!(" v{}", self.vpp_gene));
        }
        if let Some(map) = &self.map {
            s.push_str(&format!(" map[{}]", map.label()));
        }
        s
    }

    /// Canonical genome key: every searched gene, `id` excluded. The evo
    /// planner's seen-set and deterministic tie-breaks are keyed on it.
    pub fn genome_key(&self) -> String {
        let mut s = format!(
            "t{}p{}d{}k{}m{}o{}f{}a{}v{}",
            self.tp,
            self.pp,
            self.dp,
            self.kind.name(),
            self.n_mb,
            self.order.name(),
            self.offload_variant,
            self.ac as u8,
            self.vpp_gene,
        );
        if let Some(map) = &self.map {
            s.push('M');
            s.push_str(&map.label());
        }
        s
    }
}

/// Divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// Enumerate the raw candidate space for a GPU budget: every (TP, PP, DP)
/// factorization × schedule kind × microbatch count × group order ×
/// offload variant (offload variants only multiply `StpOffload`; uniform
/// pools pass a single `Declared` order, which keeps ids identical to the
/// pre-heterogeneity enumeration). No pruning here beyond the
/// factorization itself — ids must be stable regardless of model and
/// memory inputs.
pub fn enumerate(
    gpus: usize,
    kinds: &[ScheduleKind],
    n_mb_options: &[usize],
    orders: &[GroupOrder],
    offload_variants: &[OffloadParams],
) -> Vec<Candidate> {
    assert!(gpus >= 1, "GPU budget must be positive");
    assert!(!orders.is_empty(), "at least one group order");
    let default_variant = [OffloadParams::default()];
    let mut out = Vec::new();
    let mut id = 0;
    for tp in divisors(gpus) {
        for pp in divisors(gpus / tp) {
            let dp = gpus / (tp * pp);
            for &kind in kinds {
                for &n_mb in n_mb_options {
                    for &order in orders {
                        // Offload variants only multiply the offload kind;
                        // everything else gets the single default variant.
                        let variants: &[OffloadParams] = if kind == ScheduleKind::StpOffload {
                            offload_variants
                        } else {
                            &default_variant
                        };
                        for (v, &offload) in variants.iter().enumerate() {
                            out.push(Candidate {
                                id,
                                tp,
                                pp,
                                dp,
                                kind,
                                n_mb,
                                order,
                                offload,
                                offload_variant: v,
                                ac: AcMode::None,
                                map: None,
                                vpp_gene: 0,
                            });
                            id += 1;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const DECLARED: [GroupOrder; 1] = [GroupOrder::Declared];

    #[test]
    fn divisors_of_16() {
        assert_eq!(divisors(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn enumeration_covers_all_factorizations() {
        let kinds = [ScheduleKind::Stp];
        let cands = enumerate(16, &kinds, &[64], &DECLARED, &[OffloadParams::default()]);
        // Ordered triples (tp, pp, dp) with product 16: sum over divisors
        // tp of d(16/tp) = 5+4+3+2+1 = 15.
        assert_eq!(cands.len(), 15);
        assert!(cands.iter().all(|c| c.tp * c.pp * c.dp == 16));
    }

    #[test]
    fn ids_are_stable_and_dense() {
        let kinds = ScheduleKind::all();
        let a = enumerate(8, &kinds, &[16, 32], &DECLARED, &[OffloadParams::default()]);
        let b = enumerate(8, &kinds, &[16, 32], &DECLARED, &[OffloadParams::default()]);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.label(), y.label());
        }
        for (i, c) in a.iter().enumerate() {
            assert_eq!(c.id, i);
        }
    }

    #[test]
    fn group_orders_multiply_the_space() {
        let kinds = [ScheduleKind::Stp];
        let one = enumerate(8, &kinds, &[16], &DECLARED, &[OffloadParams::default()]);
        let two = enumerate(
            8,
            &kinds,
            &[16],
            &[GroupOrder::FastFirst, GroupOrder::Interleaved],
            &[OffloadParams::default()],
        );
        assert_eq!(two.len(), 2 * one.len());
        assert!(two.iter().any(|c| c.label().contains("[interleaved]")));
    }

    #[test]
    fn offload_variants_multiply_only_offload_kind() {
        let kinds = [ScheduleKind::Stp, ScheduleKind::StpOffload];
        let variants = [
            OffloadParams::default(),
            OffloadParams { alpha_warmup: 0.5, alpha_steady: 0.9, reload_lead: 3 },
        ];
        let cands = enumerate(4, &kinds, &[8], &DECLARED, &variants);
        let stp = cands.iter().filter(|c| c.kind == ScheduleKind::Stp).count();
        let off = cands.iter().filter(|c| c.kind == ScheduleKind::StpOffload).count();
        assert_eq!(off, 2 * stp);
    }

    #[test]
    fn vpp_and_placement_match_schedule_family() {
        let c = Candidate {
            id: 0,
            tp: 2,
            pp: 4,
            dp: 1,
            kind: ScheduleKind::OneF1B,
            n_mb: 8,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            offload_variant: 0,
            ac: AcMode::None,
            map: None,
            vpp_gene: 0,
        };
        assert_eq!(c.vpp(), 1);
        assert_eq!(c.topo().chunks(), 4);
        assert_eq!(c.placement(), Placement::Interleaved);
        let c2 = Candidate { kind: ScheduleKind::ZbV, ..c };
        assert_eq!(c2.topo().chunks(), 8);
        assert_eq!(c2.placement(), Placement::VShape);
    }
}
